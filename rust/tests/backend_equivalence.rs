//! Backend equivalence through the typed op-submission API: the same
//! pipeline must produce the same bits on [`HostBackend`],
//! [`ImaxBackend`] and [`ShardedBackend`] at 1/2/4 lanes — sharding and
//! residency are pure scheduling/DMA levers, never numeric ones.
//!
//! Exactness ledger (matches the kernels, see `DESIGN.md`):
//! * Q8_0 lane kernel ≡ host GGML bit-for-bit → host == imax == sharded;
//! * Q3_K lane kernel uses the 5-bit-scale IMAX restructuring → imax ==
//!   sharded bit-for-bit, host only close (cosine).

use imax_sd::ggml::{DType, Tensor};
use imax_sd::imax::ImaxConfig;
use imax_sd::sd::backend::{
    ExecBackend, HostBackend, ImaxBackend, OpDesc, ShardedBackend,
};
use imax_sd::sd::pipeline::{Backend, Pipeline, PipelineConfig};
use imax_sd::sd::plan::replay_unet_steps_sharded;
use imax_sd::sd::QuantModel;
use imax_sd::util::rng::Xoshiro256pp;

// Paper §III-B routing (convs on host), the baseline the historical
// expectations below were written against; the F16 conv-offload
// equivalence suite further down opts in explicitly.
fn cfg(model: QuantModel, backend: Backend) -> PipelineConfig {
    PipelineConfig {
        weight_seed: 0x5D_7B0,
        model: Some(model),
        steps: 2,
        backend,
        conv_offload: false,
    }
}

fn rnd(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut v = vec![0.0f32; rows * cols];
    r.fill_normal(&mut v, 0.5);
    Tensor::f32(rows, cols, v)
}

/// Q8_0: every backend, every lane count, bit-identical images.
#[test]
fn q8_0_pipeline_bit_identical_across_all_backends() {
    let host = Pipeline::new(cfg(QuantModel::Q8_0, Backend::Host { threads: 2 }));
    let (want, _) = host.generate("a lovely cat", 7);
    let imax = Pipeline::new(cfg(
        QuantModel::Q8_0,
        Backend::Imax { config: ImaxConfig::fpga(1), threads: 2 },
    ));
    let (img, r) = imax.generate("a lovely cat", 7);
    assert!(r.offloaded_calls > 0);
    for (a, b) in want.data.iter().zip(&img.data) {
        assert_eq!(a.to_bits(), b.to_bits(), "imax == host");
    }
    for lanes in [1usize, 2, 4] {
        let sharded = Pipeline::new(cfg(
            QuantModel::Q8_0,
            Backend::Sharded { config: ImaxConfig::fpga(lanes), threads: 2 },
        ));
        let (img, r) = sharded.generate("a lovely cat", 7);
        assert!(r.offloaded_calls > 0, "{lanes} lanes offloaded");
        if lanes > 1 {
            assert!(r.lane_submissions > r.offloaded_calls, "{lanes} lanes sharded ops");
        }
        for (a, b) in want.data.iter().zip(&img.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "sharded x{lanes} == host");
        }
    }
}

/// Q3_K: imax and sharded agree bit-for-bit at every lane count (they
/// run the same 5-bit-scale lane numerics); host is only close.
#[test]
fn q3k_pipeline_sharded_bit_identical_to_imax() {
    let imax = Pipeline::new(cfg(
        QuantModel::Q3K,
        Backend::Imax { config: ImaxConfig::fpga(1), threads: 2 },
    ));
    let (want, rw) = imax.generate("a lovely cat", 7);
    assert!(rw.offloaded_calls > 0);
    for lanes in [1usize, 2, 4] {
        let sharded = Pipeline::new(cfg(
            QuantModel::Q3K,
            Backend::Sharded { config: ImaxConfig::fpga(lanes), threads: 2 },
        ));
        let (img, r) = sharded.generate("a lovely cat", 7);
        assert!(r.offloaded_calls > 0);
        for (a, b) in want.data.iter().zip(&img.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "Q3_K sharded x{lanes} == imax");
        }
    }
    let host = Pipeline::new(cfg(QuantModel::Q3K, Backend::Host { threads: 2 }));
    let (h, _) = host.generate("a lovely cat", 7);
    let dot: f32 = h.data.iter().zip(&want.data).map(|(x, y)| x * y).sum();
    let na = h.data.iter().map(|v| v * v).sum::<f32>().sqrt();
    let nb = want.data.iter().map(|v| v * v).sum::<f32>().sqrt();
    assert!(dot / (na * nb) > 0.99, "host stays close: cosine {}", dot / (na * nb));
}

/// Op-level equivalence for every offloadable [`OpKind`] constructor:
/// the submission API is kind-blind numerically.
#[test]
fn every_op_kind_bit_identical_host_vs_sharded() {
    for (dtype, k) in [(DType::Q8_0, 128usize), (DType::Q3K, 256)] {
        let w = rnd(10, k, 1).quantize(dtype).with_wid(imax_sd::ggml::WeightId(3));
        let x = rnd(4, k, 2);
        // The reference lane result (1 lane, whole op).
        let mut one = ShardedBackend::from_config(ImaxConfig::fpga(1), 2);
        let ops: Vec<for<'a> fn(&'a Tensor, &'a Tensor) -> OpDesc<'a>> = vec![
            |w, x| OpDesc::linear(w, x),
            |w, x| OpDesc::conv_im2col(w, x, 3, 1),
            |w, x| OpDesc::time_embed(w, x),
        ];
        for make in &ops {
            let want = one.submit_now(make(&w, &x));
            for lanes in [2usize, 4] {
                let mut b = ShardedBackend::from_config(ImaxConfig::fpga(lanes), 2);
                let got = b.submit_now(make(&w, &x));
                for (p, q) in got.as_f32().iter().zip(want.as_f32()) {
                    assert_eq!(p.to_bits(), q.to_bits(), "{dtype:?} x{lanes}");
                }
            }
        }
        // Q8_0 lane numerics additionally match the host exactly.
        if dtype == DType::Q8_0 {
            let mut host = HostBackend::new(1);
            let a = host.submit_now(OpDesc::linear(&w, &x));
            let mut imax = ImaxBackend::new(ImaxConfig::fpga(1), 1);
            let b = imax.submit_now(OpDesc::linear(&w, &x));
            for (p, q) in a.as_f32().iter().zip(b.as_f32()) {
                assert_eq!(p.to_bits(), q.to_bits());
            }
        }
    }
}

/// Concurrency stress for the lane worker pool: waves of interleaved
/// submissions across several requests, synced in wave-dependent order,
/// repeated — every parallel run must produce bit-identical outputs and
/// an identical [`imax_sd::coordinator::MetricsSnapshot`] to the
/// sequential (threads = 1) reference, regardless of how the OS
/// interleaves the lane workers.
#[test]
fn concurrent_submissions_are_deterministic_across_runs() {
    use imax_sd::coordinator::MetricsSnapshot;
    use imax_sd::ggml::WeightId;
    use imax_sd::sd::backend::RequestId;

    let shapes = [(96usize, 128usize), (64, 256), (48, 256), (128, 128), (80, 256), (33, 128)];
    let weights: Vec<Tensor> = shapes
        .iter()
        .enumerate()
        .map(|(i, &(m, k))| {
            let dtype = if i % 2 == 0 { DType::Q8_0 } else { DType::Q3K };
            rnd(m, k, 40 + i as u64).quantize(dtype).with_wid(WeightId(200 + i as u64))
        })
        .collect();

    let run = |threads: usize| -> (Vec<Vec<u32>>, MetricsSnapshot) {
        let mut b = ShardedBackend::from_config(ImaxConfig::fpga(4), threads);
        b.coordinator().set_min_shard_rows(1); // shard every op lanes-wide
        let mut outs: Vec<Vec<u32>> = Vec::new();
        for round in 0..3u64 {
            // One wave: submit an op per weight across three request
            // tags before syncing any of them.
            let xs: Vec<Tensor> =
                (0..weights.len()).map(|i| rnd(4, shapes[i].1, 90 + round * 16 + i as u64)).collect();
            let mut handles = Vec::new();
            for (i, (w, x)) in weights.iter().zip(&xs).enumerate() {
                b.begin_request(RequestId(1 + i as u64 % 3));
                handles.push(b.submit(OpDesc::linear(w, x)));
            }
            // Alternate the sync order per wave; results must not care.
            if round % 2 == 0 {
                handles.reverse();
            }
            for h in handles {
                outs.push(b.sync(h).as_f32().iter().map(|v| v.to_bits()).collect());
            }
        }
        (outs, b.coordinator().metrics.snapshot())
    };

    let (want_outs, want_metrics) = run(1);
    assert!(want_metrics.shard_submissions > want_metrics.sharded_ops, "ops split lanes-wide");
    for rep in 0..5 {
        let (outs, metrics) = run(4);
        assert_eq!(outs, want_outs, "rep {rep}: outputs must be bit-identical");
        assert_eq!(metrics, want_metrics, "rep {rep}: every counter must match");
    }
}

/// F16 conv offload (`OP_SML16`): every distinct `(cin, cout, k,
/// stride)` conv site of the UNet **and** VAE, bit-identical on host ==
/// imax == sharded×{1,2,4}, at host threads 1 and 4. Includes the
/// strided encoder conv and 1×1 skip convs. The lane kernel accumulates
/// in f32 in host dot order, so exactness holds by construction — this
/// pins it against regressions.
#[test]
fn f16_conv_bit_identical_across_backends_at_all_model_shapes() {
    use imax_sd::coordinator::OffloadPolicy;
    use imax_sd::ggml::WeightId;
    // (cin, cout, k, stride) for every conv site: UNet encoder/decoder
    // (incl. stride-2 down conv and 1x1 skips), then the VAE schedule.
    let shapes: &[(usize, usize, usize, usize)] = &[
        (4, 64, 3, 1),
        (64, 64, 3, 1),
        (64, 128, 3, 2),
        (128, 128, 3, 1),
        (256, 128, 3, 1),
        (256, 128, 1, 1),
        (192, 64, 3, 1),
        (192, 64, 1, 1),
        (64, 4, 3, 1),
        (64, 48, 3, 1),
        (48, 48, 3, 1),
        (48, 32, 3, 1),
        (32, 32, 3, 1),
        (32, 16, 3, 1),
        (16, 16, 3, 1),
        (16, 3, 3, 1),
    ];
    for (i, &(cin, cout, k, stride)) in shapes.iter().enumerate() {
        let kk = cin * k * k;
        let n = 24; // im2col patch rows
        let w = rnd(cout, kk, 100 + i as u64)
            .quantize(DType::F16)
            .with_wid(WeightId(500 + i as u64));
        let x = rnd(n, kk, 200 + i as u64);
        let mut host = HostBackend::new(1);
        let want = host.submit_now(OpDesc::conv_im2col(&w, &x, k, stride));
        for threads in [1usize, 4] {
            let mut imax = ImaxBackend::with_policy(
                ImaxConfig::fpga(1),
                threads,
                OffloadPolicy::QuantizedAndConv,
            );
            let got = imax.submit_now(OpDesc::conv_im2col(&w, &x, k, stride));
            assert_eq!(imax.stats().offloaded_calls, 1, "conv {i} reached the lane");
            for (p, q) in got.as_f32().iter().zip(want.as_f32()) {
                assert_eq!(p.to_bits(), q.to_bits(), "conv {i} imax == host (t{threads})");
            }
            for lanes in [1usize, 2, 4] {
                let mut s = ShardedBackend::from_config_policy(
                    ImaxConfig::fpga(lanes),
                    threads,
                    OffloadPolicy::QuantizedAndConv,
                );
                s.coordinator().set_min_shard_rows(1);
                let got = s.submit_now(OpDesc::conv_im2col(&w, &x, k, stride));
                for (p, q) in got.as_f32().iter().zip(want.as_f32()) {
                    assert_eq!(
                        p.to_bits(),
                        q.to_bits(),
                        "conv {i} sharded x{lanes} == host (t{threads})"
                    );
                }
            }
        }
    }
}

/// F16 conv offload through the full pipeline, including the
/// LMM-tiled im2col path (the VAE's 128×128 convs overflow the
/// transient partition many times over): with the F16 reference model
/// every offloaded op IS a conv, and the images must stay bit-identical
/// to the host across imax and sharded×{1,2,4}, threads 1 and 4. A
/// second denoising step re-hits the pinned conv weights, so warm
/// weight LOAD strictly shrinks (elided bytes show up as cache hits).
#[test]
fn f16_reference_pipeline_conv_offload_bit_identical_and_warms() {
    let mk = |backend| PipelineConfig {
        weight_seed: 0x5D_7B0,
        model: None, // all-F16 reference: offloaded work == conv work
        steps: 2,
        backend,
        conv_offload: true,
    };
    let host = Pipeline::new(mk(Backend::Host { threads: 2 }));
    let (want, rh) = host.generate("a lovely cat", 7);
    assert_eq!(rh.offloaded_calls, 0, "host backend never offloads");
    for threads in [1usize, 4] {
        let imax = Pipeline::new(mk(Backend::Imax {
            config: ImaxConfig::fpga(1),
            threads,
        }));
        let (img, r) = imax.generate("a lovely cat", 7);
        assert!(r.offloaded_calls > 0, "conv sites offloaded (t{threads})");
        assert!(
            r.lane_submissions > r.offloaded_calls,
            "oversized im2col chunks split into multiple lane submissions: {} vs {}",
            r.lane_submissions,
            r.offloaded_calls
        );
        assert!(r.cache.hit_bytes > 0, "step 2 re-hits pinned conv weights (t{threads})");
        for (a, b) in want.data.iter().zip(&img.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "conv-offload imax == host (t{threads})");
        }
        for lanes in [1usize, 2, 4] {
            let sharded = Pipeline::new(mk(Backend::Sharded {
                config: ImaxConfig::fpga(lanes),
                threads,
            }));
            let (img, r) = sharded.generate("a lovely cat", 7);
            assert!(r.offloaded_calls > 0);
            for (a, b) in want.data.iter().zip(&img.data) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "conv-offload sharded x{lanes} == host (t{threads})"
                );
            }
        }
    }
}

/// The acceptance criterion: on a warm step, the per-lane DMA
/// **weight** LOAD bytes shrink as lanes are added — each lane streams
/// only the shards its cache could not hold, and aggregate cache grows
/// with the lane count. (The shared experiment definition also backs
/// `benches/shard_scaling.rs`.)
#[test]
fn warm_per_lane_weight_load_shrinks_with_lanes() {
    for model in [QuantModel::Q8_0, QuantModel::Q3K] {
        let mut warm_max_by_lanes = Vec::new();
        for lanes in [1usize, 2, 4] {
            // 64 KiB/lane cache: small enough that no lane count holds
            // the whole model, so the warm curve stays strictly
            // decreasing instead of saturating at zero.
            let steps = replay_unet_steps_sharded(model, lanes, 512 << 10, 64 << 10, 2);
            let (cold, warm) = (&steps[0], &steps[1]);
            assert!(warm.hits > 0, "{model:?} x{lanes}: warm hits");
            let cold_max = cold.weight_load_per_lane.iter().max().copied().unwrap();
            let warm_max = warm.weight_load_per_lane.iter().max().copied().unwrap();
            assert!(
                warm_max < cold_max,
                "{model:?} x{lanes}: warm lane streams less than cold ({warm_max} vs {cold_max})"
            );
            warm_max_by_lanes.push((lanes, warm_max));
        }
        for pair in warm_max_by_lanes.windows(2) {
            let ((l0, w0), (l1, w1)) = (pair[0], pair[1]);
            assert!(
                w1 < w0,
                "{model:?}: warm per-lane weight LOAD must shrink with lanes \
                 ({l0} lanes: {w0} B, {l1} lanes: {w1} B)"
            );
        }
    }
}
