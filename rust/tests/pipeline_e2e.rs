//! End-to-end integration over the full stack: text encoder → U-Net →
//! sampler → VAE → PNG, on both backends and both quantized models, plus
//! the paper-shape assertions that tie the analytic reproduction
//! together across modules.

use imax_sd::device::{arm_a72, gtx_1080ti, pdp_joules, xeon_w5, Device, ImaxDevice};
use imax_sd::imax::ImaxConfig;
use imax_sd::sd::arch::sd_turbo_512;
use imax_sd::sd::pipeline::{to_rgb8, Backend, Pipeline, PipelineConfig};
use imax_sd::sd::QuantModel;
use imax_sd::util::png::{crc32, encode_png, ColorType};

fn cfg(model: QuantModel, backend: Backend, steps: usize) -> PipelineConfig {
    // Paper §III-B routing: the offload-ratio band below is defined for
    // quantized-only offload (convs on host).
    PipelineConfig {
        weight_seed: 0x5D_7B0,
        model: Some(model),
        steps,
        backend,
        conv_offload: false,
    }
}

#[test]
fn fig5_images_generate_and_are_stable_png_bytes() {
    // The Fig. 5 analog: both models produce deterministic 128x128 PNGs.
    let mut digests = Vec::new();
    for model in [QuantModel::Q3K, QuantModel::Q8_0] {
        let pipe = Pipeline::new(cfg(model, Backend::Host { threads: 2 }, 1));
        let (img, report) = pipe.generate("a lovely cat", 42);
        assert_eq!((img.c, img.h, img.w), (3, 128, 128));
        assert!(report.matmul_calls > 50);
        let png = encode_png(128, 128, ColorType::Rgb, &to_rgb8(&img));
        let (img2, _) = pipe.generate("a lovely cat", 42);
        let png2 = encode_png(128, 128, ColorType::Rgb, &to_rgb8(&img2));
        assert_eq!(crc32(&png), crc32(&png2), "byte-stable output");
        digests.push(crc32(&png));
    }
    assert_ne!(digests[0], digests[1], "Q3_K and Q8_0 images differ (Fig. 5)");
}

#[test]
fn multi_step_ddim_changes_image_and_scales_compute() {
    let p1 = Pipeline::new(cfg(QuantModel::Q8_0, Backend::Host { threads: 2 }, 1));
    let p4 = Pipeline::new(cfg(QuantModel::Q8_0, Backend::Host { threads: 2 }, 4));
    let (i1, r1) = p1.generate("a lovely cat", 7);
    let (i4, r4) = p4.generate("a lovely cat", 7);
    assert_ne!(i1.data, i4.data);
    assert!(r4.matmul_calls > r1.matmul_calls * 2, "{} vs {}", r4.matmul_calls, r1.matmul_calls);
}

#[test]
fn offload_ratio_of_mini_pipeline_matches_paper_band() {
    // Paper: offload ratio < 20% — the mini pipeline's MAC mix lands in
    // the same band because the same policy drives it.
    let pipe = Pipeline::new(cfg(
        QuantModel::Q8_0,
        Backend::Imax { config: ImaxConfig::fpga(1), threads: 2 },
        1,
    ));
    let (_, report) = pipe.generate("a lovely cat", 1);
    let total: u64 = report.macs_by_dtype.iter().map(|(_, v)| *v).sum();
    let quant = report
        .macs_by_dtype
        .iter()
        .find(|(k, _)| *k == "Q8_0")
        .map(|(_, v)| *v)
        .unwrap();
    let ratio = quant as f64 / total as f64;
    assert!(ratio > 0.05 && ratio < 0.30, "offload MAC ratio {ratio}");
    assert!(report.offloaded_calls > 0);
}

#[test]
fn paper_shape_fig6_fig7_orderings() {
    let t = sd_turbo_512(1);
    let e2e = |d: &dyn Device, m| d.e2e_seconds(&t, m);
    let (arm, fpga, asic) = (arm_a72(), ImaxDevice::fpga(1), ImaxDevice::asic(1));
    let (xeon, gpu) = (xeon_w5(), gtx_1080ti());
    // Fig 6 (Q3_K): GPU < Xeon < ASIC < FPGA < ARM.
    let m = QuantModel::Q3K;
    assert!(e2e(&gpu, m) < e2e(&xeon, m));
    assert!(e2e(&xeon, m) < e2e(&asic, m));
    assert!(e2e(&asic, m) < e2e(&fpga, m));
    assert!(e2e(&fpga, m) < e2e(&arm, m));
    // Fig 7 (Q8_0): the crossover — ARM < FPGA, ASIC < ARM.
    let m = QuantModel::Q8_0;
    assert!(e2e(&arm, m) < e2e(&fpga, m), "FPGA must lose on Q8_0");
    assert!(e2e(&asic, m) < e2e(&arm, m), "ASIC must win on Q8_0");
}

#[test]
fn paper_shape_speedup_factors() {
    // "who wins, by roughly what factor": check the big ratios.
    let t = sd_turbo_512(1);
    let m = QuantModel::Q3K;
    let arm = arm_a72().e2e_seconds(&t, m);
    let xeon = xeon_w5().e2e_seconds(&t, m);
    let gpu = gtx_1080ti().e2e_seconds(&t, m);
    let r_arm_xeon = arm / xeon; // paper: 809.7 / 59.3 = 13.7
    let r_xeon_gpu = xeon / gpu; // paper: 59.3 / 16.2 = 3.66
    assert!((10.0..18.0).contains(&r_arm_xeon), "ARM/Xeon {r_arm_xeon}");
    assert!((2.5..5.0).contains(&r_xeon_gpu), "Xeon/GPU {r_xeon_gpu}");
}

#[test]
fn paper_shape_fig8_pdp() {
    let t = sd_turbo_512(1);
    for m in [QuantModel::Q3K, QuantModel::Q8_0] {
        let arm = pdp_joules(&arm_a72(), &t, m).joules;
        let asic = pdp_joules(&ImaxDevice::asic(1), &t, m).joules;
        let xeon = pdp_joules(&xeon_w5(), &t, m).joules;
        assert!(arm < asic, "{m:?}: ARM lowest PDP");
        assert!(asic < xeon, "{m:?}: ASIC beats Xeon");
    }
    let asic3 = pdp_joules(&ImaxDevice::asic(1), &t, QuantModel::Q3K).joules;
    let gpu3 = pdp_joules(&gtx_1080ti(), &t, QuantModel::Q3K).joules;
    assert!(asic3 < gpu3, "ASIC beats GPU on Q3_K");
}

#[test]
fn paper_shape_fig9_10_kernel_and_scaling() {
    let t = sd_turbo_512(1);
    for m in [QuantModel::Q3K, QuantModel::Q8_0] {
        let fpga = ImaxDevice::fpga(1);
        let f1 = fpga.kernel_seconds(&t, m, 1);
        let arm1 = arm_a72().kernel_seconds(&t, m, 1);
        assert!(f1 < arm1, "{m:?}: 145 MHz FPGA beats 1-thread ARM on kernels");
        // Knee at 2: 1->2 near-perfect, 3+ flat.
        let f2 = fpga.kernel_seconds(&t, m, 2);
        let f8 = fpga.kernel_seconds(&t, m, 8);
        assert!((f1 / f2 - 2.0).abs() < 0.01);
        assert!(f8 > f1 / 8.0 * 2.0, "{m:?}: must saturate well above ideal 8x");
    }
}

#[test]
fn asic_kernel_projection_factor() {
    // §IV-A: "approximate 5.8x reduction in IMAX's computation time".
    let t = sd_turbo_512(1);
    let f = ImaxDevice::fpga(1).kernel_seconds(&t, QuantModel::Q3K, 1);
    let a = ImaxDevice::asic(1).kernel_seconds(&t, QuantModel::Q3K, 1);
    assert!((f / a - 5.79).abs() < 0.05, "ratio {}", f / a);
}
