//! Concurrency-correctness sweep: the deterministic interleaving
//! explorer over every protocol model, with **pinned, exact explored-
//! schedule counts**.
//!
//! The explorer is deterministic (ascending thread order, sorted sleep
//! sets, no randomness), so the number of terminal schedules of a model
//! under a given preemption bound is a reproducible constant. The
//! constants below are mirrored verbatim in
//! `python/replica/conc_check_replica.py`, an independent Python
//! implementation of the same search and the same models: a drift in
//! either implementation — a changed model step, a different sleep-set
//! wake rule, an off-by-one in preemption accounting — fails one
//! side's CI.
//!
//! Three layers of claims:
//!
//! 1. **Clean sweeps** — every real protocol model is deadlock-free,
//!    safety-clean and *schedule-invariant* (≤ 1 distinct result
//!    string) at every bound, including the unbounded exhaustive
//!    search, with the pinned schedule count.
//! 2. **Mutant convictions** — re-introducing a specific bug class
//!    (dropped notify, inverted lock order, missing quorum re-check,
//!    notify outside the mutex) is caught with a concrete deadlocking
//!    schedule, at preemption bound ≤ 2.
//! 3. **Witness convictions** — the runtime lock-order witness convicts
//!    an inverted acquisition order from a single sequential run, no
//!    deadlock needed.

use imax_sd::check::lockorder::{current_thread_key, LockOrderWitness, LockTag};
use imax_sd::check::models::{
    CancelModel, DrainModel, PoolIdleModel, RendezvousModel, SlotModel, TwoLockModel,
};
use imax_sd::check::sched::{explore, Config, Model, Report};

/// Bounds swept by every clean-model test; `None` = unbounded.
const BOUNDS: [Option<usize>; 5] = [Some(0), Some(1), Some(2), Some(3), None];

fn config(bound: Option<usize>) -> Config {
    match bound {
        Some(b) => Config::bounded(b),
        None => Config::exhaustive(),
    }
}

/// Sweep a clean model across all bounds, asserting cleanliness and the
/// pinned schedule count per bound.
fn assert_clean_sweep<M: Model>(name: &str, mk: impl Fn() -> M, pinned: [u64; 5]) {
    for (bound, want) in BOUNDS.iter().zip(pinned) {
        let r = explore(&mk(), &config(*bound));
        assert!(
            r.is_clean(),
            "{name} bound={bound:?} not clean: deadlocks={} violations={:?} results={:?}",
            r.deadlocks,
            r.violations,
            r.results
        );
        assert!(!r.truncated, "{name} bound={bound:?} hit a search cap");
        assert_eq!(
            r.schedules, want,
            "{name} bound={bound:?}: {} schedules, pinned {want} \
             (update BOTH this constant and conc_check_replica.py)",
            r.schedules
        );
    }
}

#[test]
fn cancel_model_clean_and_pinned() {
    assert_clean_sweep("cancel", CancelModel::new, [6, 12, 12, 12, 12]);
}

#[test]
fn cancel_model_has_exactly_one_terminal_cause_everywhere() {
    // The satellite claim for util/cancel.rs: over *every* schedule of
    // cancel() racing expire() under an observer, exactly one CAS wins
    // and the observed cause never flips. Schedule invariance collapses
    // all 12 exhaustive schedules to the single result "winners=1".
    let r = explore(&CancelModel::new(), &Config::exhaustive());
    assert!(r.is_clean(), "{:?}", r.violations);
    assert_eq!(r.results.len(), 1);
    assert_eq!(r.results.iter().next().unwrap(), "winners=1");
}

#[test]
fn slot_model_clean_and_pinned() {
    assert_clean_sweep("slot", || SlotModel::new(false), [4, 4, 4, 4, 4]);
    let r = explore(&SlotModel::new(false), &Config::exhaustive());
    assert_eq!(r.results.iter().next().unwrap(), "got1=20 got0=10");
}

#[test]
fn twolock_model_clean_and_pinned() {
    assert_clean_sweep("twolock", || TwoLockModel::new(false), [2, 2, 2, 2, 2]);
}

#[test]
fn rendezvous_model_clean_and_pinned() {
    assert_clean_sweep(
        "rendezvous",
        || RendezvousModel::new(false, false),
        [10, 10, 10, 10, 10],
    );
    // Merged output is schedule-invariant: both members see staged 1+2.
    let r = explore(&RendezvousModel::new(false, false), &Config::exhaustive());
    assert_eq!(r.results.iter().next().unwrap(), "gen=1 out=3,3 merged=3");
}

#[test]
fn drain_model_clean_and_pinned() {
    // The only model where the bound actually cuts schedules — its
    // deadlock-free claim needs (and gets) the full 40-schedule
    // exhaustive search.
    assert_clean_sweep("drain", || DrainModel::new(false), [8, 26, 38, 40, 40]);
}

#[test]
fn pool_idle_model_clean_and_pinned() {
    assert_clean_sweep("pool_idle", || PoolIdleModel::new(false), [2, 3, 3, 3, 3]);
}

// ---------------------------------------------------------------------------
// Mutant convictions: (schedules, deadlocks) at preemption bound 2,
// pinned against the replica.
// ---------------------------------------------------------------------------

fn assert_convicted<M: Model>(name: &str, model: M, pinned: (u64, u64)) -> Report {
    let r = explore(&model, &Config::bounded(2));
    assert!(r.deadlocks > 0, "{name}: mutant must deadlock: {:?}", r.violations);
    assert_eq!((r.schedules, r.deadlocks), pinned, "{name}: counts drifted");
    // Every conviction names the exact schedule that got stuck.
    assert!(
        r.violations.iter().all(|v| v.contains("deadlock after [")),
        "{name}: conviction without a schedule: {:?}",
        r.violations
    );
    r
}

#[test]
fn dropped_fill_notify_is_convicted() {
    assert_convicted("slot_drop_notify", SlotModel::new(true), (3, 2));
}

#[test]
fn inverted_lock_order_is_convicted() {
    let r = assert_convicted("twolock_inverted", TwoLockModel::new(true), (3, 1));
    // The classic AB/BA interleaving: T0 takes A, T1 takes B, both stuck.
    assert!(r.violations[0].contains("T0 T1"), "{:?}", r.violations);
}

#[test]
fn dropped_leave_broadcast_is_convicted() {
    assert_convicted(
        "rendezvous_drop_notify",
        RendezvousModel::new(true, false),
        (6, 2),
    );
}

#[test]
fn missing_quorum_recheck_is_convicted() {
    assert_convicted(
        "rendezvous_no_requeue",
        RendezvousModel::new(false, true),
        (10, 4),
    );
}

#[test]
fn dropped_close_broadcast_is_convicted() {
    assert_convicted("drain_drop_notify", DrainModel::new(true), (34, 9));
}

#[test]
fn unlocked_notify_lost_wakeup_is_convicted() {
    // The bug class this PR fixed in ThreadPool::wait_idle: the worker
    // broadcast outside the done mutex, which can fire between the
    // waiter's counter read and its park. One preemption suffices.
    let r = assert_convicted("pool_unlocked_notify", PoolIdleModel::new(true), (3, 1));
    assert!(r.violations[0].contains("T1"), "the waiter is the stuck thread");
    let fixed = explore(&PoolIdleModel::new(false), &Config::exhaustive());
    assert!(fixed.is_clean(), "locked notify closes the window: {:?}", fixed.violations);
}

// ---------------------------------------------------------------------------
// Lock-order witness: convicts inversions from one sequential run.
// ---------------------------------------------------------------------------

#[test]
fn witness_convicts_the_twolock_mutant_without_a_deadlock() {
    // The explorer needs the unlucky schedule; the witness only needs
    // both orders to *happen* once each, in any schedule at all.
    let w = LockOrderWitness::new(false);
    let me = current_thread_key();
    let a = LockTag { id: 1, rank: 0, name: "A" };
    let b = LockTag { id: 2, rank: 0, name: "B" };
    // Thread 0's order: A then B (completes fine).
    w.acquire_as(me, a);
    w.acquire_as(me, b);
    w.release_as(me, b.id);
    w.release_as(me, a.id);
    // Thread 1's inverted order: B then A (also completes fine).
    w.acquire_as(me, b);
    w.acquire_as(me, a);
    w.release_as(me, a.id);
    w.release_as(me, b.id);
    let v = w.violations();
    assert!(
        v.iter().any(|m| m.contains("cycle")),
        "witness must report the A/B cycle: {v:?}"
    );
}
