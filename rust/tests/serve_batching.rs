//! Serving-stack integration: batched multi-lane submission must be
//! bit-identical to serial per-request submission, strictly cheaper in
//! simulated lane cycles, and report coherent per-request metrics.

use imax_sd::coordinator::{Coordinator, MatMulJob, OffloadPolicy};
use imax_sd::ggml::{DType, Tensor};
use imax_sd::imax::ImaxConfig;
use imax_sd::sd::pipeline::{Backend, PipelineConfig};
use imax_sd::sd::QuantModel;
use imax_sd::serve::{ServeConfig, ServeHarness};
use imax_sd::util::rng::Xoshiro256pp;
use std::sync::Arc;

fn rnd(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut v = vec![0.0f32; rows * cols];
    r.fill_normal(&mut v, 0.5);
    Tensor::f32(rows, cols, v)
}

fn pipe_cfg(model: QuantModel) -> PipelineConfig {
    PipelineConfig {
        weight_seed: 0x5D_7B0,
        model: Some(model),
        steps: 1,
        backend: Backend::Host { threads: 2 },
        // Quantized-only serving semantics under test (the submission
        // counts below assume convs stay on the host); conv-offload
        // serving is covered in `serve::worker`'s tests.
        conv_offload: false,
    }
}

fn prompts(n: usize) -> Vec<(String, u64)> {
    (0..n).map(|i| (format!("request number {i} of a lovely cat"), 42 + i as u64)).collect()
}

/// The ISSUE acceptance regression: coalesced coordinator submission is
/// bit-identical to serial submission, across both lane kernels.
#[test]
fn batched_scheduler_bit_identical_to_serial() {
    for (dtype, k) in [(DType::Q8_0, 128), (DType::Q3K, 256)] {
        let w = Arc::new(rnd(8, k, 1).quantize(dtype));
        let jobs: Vec<MatMulJob> = (0..5u64)
            .map(|r| MatMulJob {
                name: format!("req{r}"),
                w: Arc::clone(&w),
                x: Arc::new(rnd(3 + r as usize % 2, k, 50 + r)),
            })
            .collect();
        let serial = Coordinator::new(ImaxConfig::fpga(1), 2, 2, OffloadPolicy::QuantizedOnly);
        let want: Vec<Tensor> = jobs.iter().map(|j| serial.execute(j)).collect();
        let batched = Coordinator::new(ImaxConfig::fpga(1), 2, 2, OffloadPolicy::QuantizedOnly);
        let got = batched.execute_coalesced(&jobs);
        for (g, w_) in got.iter().zip(&want) {
            assert_eq!(g.rows, w_.rows);
            for (a, b) in g.as_f32().iter().zip(w_.as_f32()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{dtype:?} batched == serial");
            }
        }
        let ord = std::sync::atomic::Ordering::Relaxed;
        assert!(
            batched.metrics.imax_cycles.load(ord) < serial.metrics.imax_cycles.load(ord),
            "{dtype:?}: coalescing must reduce simulated cycles"
        );
    }
}

/// End-to-end: serving the same requests serially and batched produces
/// byte-identical images, and batching improves lane efficiency
/// (cycles per offloaded MAC) — the serve-subsystem acceptance at ≥4
/// concurrent requests.
#[test]
fn serve_batched_matches_serial_and_improves_lane_efficiency() {
    let reqs = prompts(4);
    let serial = ServeHarness::new(pipe_cfg(QuantModel::Q8_0), ServeConfig::serial(1, 2));
    let serial_report = serial.serve(&reqs);
    let batched = ServeHarness::new(
        pipe_cfg(QuantModel::Q8_0),
        ServeConfig {
            lanes: 2,
            host_threads: 2,
            max_batch: 4,
            workers: 1,
            sharded: false,
            queue_capacity: 64,
        },
    );
    let batched_report = batched.serve(&reqs);

    assert_eq!(serial_report.requests(), 4);
    assert_eq!(batched_report.requests(), 4);
    for (a, b) in serial_report.outcomes.iter().zip(&batched_report.outcomes) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.image_crc32, b.image_crc32, "request {:?} image differs", a.id);
        assert_eq!(a.matmul_calls, b.matmul_calls);
    }
    assert_eq!(serial_report.offloaded_macs, batched_report.offloaded_macs);
    assert!(
        batched_report.imax_cycles < serial_report.imax_cycles,
        "batched lane submission must spend fewer simulated cycles ({} vs {})",
        batched_report.imax_cycles,
        serial_report.imax_cycles
    );
    assert!(
        batched_report.cycles_per_offloaded_mac() < serial_report.cycles_per_offloaded_mac(),
        "higher aggregate MAC throughput per simulated cycle"
    );
    assert!(
        batched_report.lane_submissions < serial_report.lane_submissions,
        "merged submissions: {} vs {}",
        batched_report.lane_submissions,
        serial_report.lane_submissions
    );
    assert_eq!(batched_report.coalesced_jobs % 4, 0, "whole micro-batches coalesced");
}

/// Serving must also work for the Q3_K model (the lane's other kernel),
/// and per-request MAC accounting must be uniform across identical
/// requests.
#[test]
fn serve_q3k_model_accounts_per_request() {
    let h = ServeHarness::new(
        pipe_cfg(QuantModel::Q3K),
        ServeConfig {
            lanes: 2,
            host_threads: 2,
            max_batch: 2,
            workers: 2,
            sharded: false,
            queue_capacity: 64,
        },
    );
    let report = h.serve(&prompts(4));
    assert_eq!(report.requests(), 4);
    assert!(report.offloaded_macs > 0, "Q3_K layers offload");
    let macs0 = report.outcomes[0].macs;
    assert!(macs0 > 0);
    for o in &report.outcomes {
        assert_eq!(o.macs, macs0, "identical pipeline => identical per-request MACs");
    }
    let lat = report.latency_summary();
    assert!(lat.min > 0.0 && lat.max < 3600.0);
}
