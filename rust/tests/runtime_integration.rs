//! Cross-language integration: the AOT Pallas/JAX artifacts executed via
//! PJRT from rust must agree with the rust GGML host kernels on the same
//! quantized operands. This closes the L1↔L3 loop: the arithmetic the
//! paper offloads is implemented three times (rust host kernels, the
//! IMAX simulator, the Pallas kernels) and all three must agree.
//!
//! Requires `make artifacts` and a build with `--features pjrt` (the
//! runtime module needs the vendored `xla` bindings); tests skip (with a
//! message) when the artifacts are absent so `cargo test` stays green
//! pre-build, and the whole file compiles away without the feature.
#![cfg(feature = "pjrt")]

use imax_sd::ggml::{q3_k, q8_0, q8_k, DType, Tensor};
use imax_sd::runtime::client::{literal_f32, literal_i8};
use imax_sd::runtime::{find_artifact_dir, ArtifactRuntime};
use imax_sd::util::rng::Xoshiro256pp;

fn runtime() -> Option<ArtifactRuntime> {
    let Some(dir) = find_artifact_dir() else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    };
    Some(ArtifactRuntime::new(dir).expect("PJRT CPU client"))
}

fn random(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut v = vec![0.0f32; rows * cols];
    r.fill_normal(&mut v, 0.7);
    Tensor::f32(rows, cols, v)
}

/// Decompose a Q8_0-quantized tensor into (qs, d) arrays for the artifact.
fn decompose_q8_0(t: &Tensor) -> (Vec<i8>, Vec<f32>) {
    let blocks = match &t.data {
        imax_sd::ggml::tensor::Storage::Q8_0(b) => b,
        _ => panic!("not q8_0"),
    };
    let mut qs = Vec::with_capacity(t.len());
    let mut d = Vec::with_capacity(blocks.len());
    for b in blocks {
        qs.extend_from_slice(&b.qs);
        d.push(b.d.to_f32());
    }
    (qs, d)
}

#[test]
fn q8_0_artifact_matches_rust_ggml() {
    let Some(mut rt) = runtime() else { return };
    let (m, n, k) = (64usize, 32usize, 256usize); // aot.py's fixed shapes
    let w = random(m, k, 1);
    let x = random(n, k, 2);
    let wq = w.quantize(DType::Q8_0);
    let xq = x.quantize(DType::Q8_0);
    let (wqs, wd) = decompose_q8_0(&wq);
    let (xqs, xd) = decompose_q8_0(&xq);

    let exe = rt.load("q8_0_matmul.hlo.txt").expect("compile artifact");
    let out = exe
        .run_f32(&[
            literal_i8(&wqs, m, k).unwrap(),
            literal_f32(&wd, m, k / 32).unwrap(),
            literal_i8(&xqs, n, k).unwrap(),
            literal_f32(&xd, n, k / 32).unwrap(),
        ])
        .expect("execute");

    // Host reference: same quantized weights, activations quantized the
    // same way (we pass the pre-quantized acts through mul_mat by
    // dequantizing-requantizing identically — use vec_dot directly).
    let wb = match &wq.data {
        imax_sd::ggml::tensor::Storage::Q8_0(b) => b,
        _ => unreachable!(),
    };
    let xb = match &xq.data {
        imax_sd::ggml::tensor::Storage::Q8_0(b) => b,
        _ => unreachable!(),
    };
    let bpr = k / 32;
    assert_eq!(out.len(), n * m);
    for nn in 0..n {
        for mm in 0..m {
            let want = q8_0::vec_dot(
                &wb[mm * bpr..(mm + 1) * bpr],
                &xb[nn * bpr..(nn + 1) * bpr],
            );
            let got = out[nn * m + mm];
            assert!(
                (got - want).abs() < 1e-3 * want.abs().max(1.0),
                "[{nn},{mm}] pallas {got} vs ggml {want}"
            );
        }
    }
}

#[test]
fn q3k_artifact_matches_rust_imax5() {
    let Some(mut rt) = runtime() else { return };
    let (m, n, k) = (32usize, 16usize, 512usize);
    let w = random(m, k, 3);
    let x = random(n, k, 4);

    // Rust-side Q3_K quantization + IMAX restructuring (OP_CVT53 view).
    let mut q3 = Vec::with_capacity(m * k);
    let mut s5 = Vec::with_capacity(m * k / 16);
    let mut wd = Vec::with_capacity(m * k / 256);
    let mut w_blocks = Vec::new();
    for r in 0..m {
        let blocks = q3_k::quantize_row(w.row_f32(r));
        for b in &blocks {
            let s = q3_k::to_imax_stream(b);
            q3.extend(s.q3.iter().map(|&v| v as i8));
            s5.extend_from_slice(&s.scales5);
            wd.push(s.d.to_f32());
        }
        w_blocks.push(blocks);
    }
    let mut xqs = Vec::with_capacity(n * k);
    let mut xd = Vec::with_capacity(n * k / 256);
    let mut x_blocks = Vec::new();
    for r in 0..n {
        let blocks = q8_k::quantize_row(x.row_f32(r));
        for b in &blocks {
            xqs.extend_from_slice(&b.qs);
            xd.push(b.d);
        }
        x_blocks.push(blocks);
    }

    let exe = rt.load("q3k_matmul.hlo.txt").expect("compile artifact");
    let out = exe
        .run_f32(&[
            literal_i8(&q3, m, k).unwrap(),
            literal_i8(&s5, m, k / 16).unwrap(),
            literal_f32(&wd, m, k / 256).unwrap(),
            literal_i8(&xqs, n, k).unwrap(),
            literal_f32(&xd, n, k / 256).unwrap(),
        ])
        .expect("execute");

    for nn in 0..n {
        for mm in 0..m {
            let want = q3_k::vec_dot_imax5(&w_blocks[mm], &x_blocks[nn]);
            let got = out[nn * m + mm];
            assert!(
                (got - want).abs() < 1e-3 * want.abs().max(1.0),
                "[{nn},{mm}] pallas {got} vs imax5 {want}"
            );
        }
    }
}

#[test]
fn f16_artifact_matches_rust_f16_mul_mat() {
    let Some(mut rt) = runtime() else { return };
    let (m, n, k) = (64usize, 64usize, 288usize);
    let w = random(m, k, 5);
    let x = random(n, k, 6);
    let exe = rt.load("f16_matmul.hlo.txt").expect("compile artifact");
    let out = exe
        .run_f32(&[
            literal_f32(w.as_f32(), m, k).unwrap(),
            literal_f32(x.as_f32(), n, k).unwrap(),
        ])
        .expect("execute");
    let want = imax_sd::ggml::mul_mat(&w.quantize(DType::F16), &x, 1);
    for (g, wv) in out.iter().zip(want.as_f32()) {
        assert!((g - wv).abs() < 2e-3 * wv.abs().max(1.0), "{g} vs {wv}");
    }
}

#[test]
fn model_artifact_runs_and_is_deterministic() {
    let Some(mut rt) = runtime() else { return };
    let (seq, dim, ctx_len) = (64usize, 256usize, 77usize);
    let x = random(seq, dim, 7);
    let ctx = random(ctx_len, dim, 8);
    let exe = rt.load("model.hlo.txt").expect("compile model artifact");
    let a = exe
        .run_f32(&[
            literal_f32(x.as_f32(), seq, dim).unwrap(),
            literal_f32(ctx.as_f32(), ctx_len, dim).unwrap(),
        ])
        .expect("execute");
    assert_eq!(a.len(), seq * dim);
    assert!(a.iter().all(|v| v.is_finite()));
    let b = exe
        .run_f32(&[
            literal_f32(x.as_f32(), seq, dim).unwrap(),
            literal_f32(ctx.as_f32(), ctx_len, dim).unwrap(),
        ])
        .unwrap();
    assert_eq!(a, b, "artifact execution is deterministic");
    // Residual structure: output correlates with input.
    let dot: f32 = a.iter().zip(x.as_f32()).map(|(p, q)| p * q).sum();
    assert!(dot != 0.0);
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(mut rt) = runtime() else { return };
    rt.load("f16_matmul.hlo.txt").unwrap();
    rt.load("f16_matmul.hlo.txt").unwrap();
    assert_eq!(rt.cached(), 1);
    rt.load("q8_0_matmul.hlo.txt").unwrap();
    assert_eq!(rt.cached(), 2);
}
