//! Property tests for the row-tile shard partitioner: for arbitrary row
//! counts, lane counts and cache budgets the partition must be
//! disjoint, covering, balanced, budget-capped and stably identified —
//! plus integration checks that activation broadcast elision keeps the
//! aggregate activation LOAD bytes flat as lanes grow.

use imax_sd::coordinator::{shard_wid, Coordinator, OffloadPolicy, ShardPlan};
use imax_sd::ggml::{DType, Tensor, WeightId};
use imax_sd::imax::ImaxConfig;
use imax_sd::sd::backend::OpDesc;
use imax_sd::util::prop::{run, Gen};
use imax_sd::util::rng::Xoshiro256pp;

/// Check every invariant of one plan; returns a reason on violation.
fn check_plan(
    m: usize,
    lanes: usize,
    row_bytes: usize,
    budget: usize,
) -> Result<(), String> {
    let cap = ShardPlan::cap_rows(row_bytes, budget, m);
    let parent = WeightId(0xABCD ^ m as u64);
    let plan = ShardPlan::new(m, lanes, cap, 1, Some(parent));

    // Disjoint + covering + ascending: shards tile 0..m exactly.
    let mut next = 0usize;
    for s in &plan.shards {
        if s.rows.start != next {
            return Err(format!("gap/overlap at row {next}: {:?}", s.rows));
        }
        if s.rows.is_empty() {
            return Err(format!("empty shard at {next}"));
        }
        next = s.rows.end;
    }
    if next != m {
        return Err(format!("rows covered {next} != m {m}"));
    }

    // Lane assignment round-robins from the parent-derived base lane
    // and stays in range.
    let base = (parent.0 % lanes as u64) as usize;
    for (i, s) in plan.shards.iter().enumerate() {
        let want = (base + i) % lanes;
        if s.lane != want {
            return Err(format!("shard {i} on lane {} (want {want})", s.lane));
        }
    }

    // Balanced to within one row.
    let min = plan.shards.iter().map(|s| s.len()).min().unwrap();
    let max = plan.shards.iter().map(|s| s.len()).max().unwrap();
    if max - min > 1 {
        return Err(format!("unbalanced shards: {min}..{max} rows"));
    }

    // Budget-capped: whenever one row fits the per-lane cache budget at
    // all, every shard must be cacheable (rows × row_bytes ≤ budget).
    if budget > 0 && row_bytes <= budget {
        for s in &plan.shards {
            if s.len() * row_bytes > budget {
                return Err(format!(
                    "shard of {} rows x {row_bytes} B exceeds the {budget} B lane budget",
                    s.len()
                ));
            }
        }
    }

    // Shard ids: derived from the parent, pairwise distinct, and equal
    // to the independent derivation the pin pass performs.
    let count = plan.shards.len();
    let mut seen = std::collections::HashSet::new();
    for (i, s) in plan.shards.iter().enumerate() {
        let want = shard_wid(parent, i, count);
        if s.wid != Some(want) {
            return Err(format!("shard {i} wid {:?} != derived {want:?}", s.wid));
        }
        if !seen.insert(want.0) {
            return Err(format!("shard {i} reuses a wid"));
        }
    }
    if count == 1 && plan.shards[0].wid != Some(parent) {
        return Err("single shard must keep the parent id".into());
    }
    Ok(())
}

#[test]
fn prop_shard_partitions_disjoint_covering_budgeted() {
    run("shard partition invariants", 150, Gen::usize_in(1..=1500), |&m| {
        // Derive lane counts and byte geometries from the (shrinkable)
        // row count, covering tight, roomy and disabled budgets.
        let mut rng = Xoshiro256pp::seed_from_u64(m as u64);
        for lanes in 1..=8usize {
            for _ in 0..3 {
                let row_bytes = 1 + rng.below(4096) as usize;
                let budget = match rng.below(4) {
                    0 => 0,                                   // cache disabled
                    1 => rng.below(row_bytes as u64) as usize, // sub-row budget
                    2 => row_bytes * (1 + rng.below(16) as usize), // tight
                    _ => row_bytes * m,                       // roomy
                };
                check_plan(m, lanes, row_bytes, budget)?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shard_count_matches_budget_pressure() {
    run("shard count = max(lanes, ceil(m/cap)) clamped to m", 200, Gen::usize_in(1..=2000), |&m| {
        for lanes in [1usize, 2, 4, 8] {
            for cap in [1usize, 3, 17, usize::MAX] {
                // min_rows = 1 disables the cost-model threshold, which
                // restores the original count formula exactly.
                let plan = ShardPlan::new(m, lanes, cap, 1, None);
                let want = lanes.max(m.div_ceil(cap.max(1))).min(m);
                if plan.shards.len() != want {
                    return Err(format!(
                        "m={m} lanes={lanes} cap={cap}: {} shards, want {want}",
                        plan.shards.len()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_min_rows_threshold_limits_shard_count() {
    run("shard count <= max(1, m/min_rows) under a roomy cap", 200, Gen::usize_in(1..=2000), |&m| {
        for lanes in [1usize, 2, 4, 8] {
            for min_rows in [1usize, 7, 64, 500] {
                let plan = ShardPlan::new(m, lanes, usize::MAX, min_rows, None);
                let by_min = (m / min_rows).max(1);
                let want = lanes.min(by_min).min(m);
                if plan.shards.len() != want {
                    return Err(format!(
                        "m={m} lanes={lanes} min_rows={min_rows}: {} shards, want {want}",
                        plan.shards.len()
                    ));
                }
                // Every shard meets the threshold whenever the plan
                // split at all (a single shard may be arbitrarily small).
                if plan.shards.len() > 1 {
                    for s in &plan.shards {
                        if s.len() < min_rows {
                            return Err(format!(
                                "m={m} lanes={lanes} min_rows={min_rows}: shard of {} rows \
                                 below threshold",
                                s.len()
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// Activation broadcast elision: the same op executed over 1/2/4/8 lanes
/// must charge the **same total activation LOAD bytes** — only shard 0
/// streams the activation block; the other lanes read it as a broadcast
/// (bytes elided, DMA cycle occupancy kept). Without elision the
/// activation traffic scaled linearly with the shard count.
#[test]
fn sharded_activation_bytes_do_not_scale_with_lanes() {
    let mut rng = Xoshiro256pp::seed_from_u64(77);
    let (m, k, n) = (128usize, 256usize, 8usize);
    let mut wdata = vec![0.0f32; m * k];
    rng.fill_normal(&mut wdata, 0.5);
    let w = Tensor::f32(m, k, wdata).quantize(DType::Q8_0).with_wid(WeightId(91));
    let mut xdata = vec![0.0f32; n * k];
    rng.fill_normal(&mut xdata, 0.5);
    let x = Tensor::f32(n, k, xdata);

    let mut act_bytes_by_lanes = Vec::new();
    let mut reference: Option<Vec<u32>> = None;
    for lanes in [1usize, 2, 4, 8] {
        let c = Coordinator::new(ImaxConfig::fpga(lanes), lanes, 1, OffloadPolicy::QuantizedOnly);
        let op = OpDesc::linear(&w, &x);
        let run = c.submit_sharded(&op);
        // Activation LOAD = all DMA LOAD bytes minus the weight bytes.
        let act: u64 = c
            .lane_costs()
            .iter()
            .map(|lc| lc.loaded_bytes - lc.weight_load_bytes)
            .sum();
        assert!(act > 0, "the op streams activations at {lanes} lanes");
        assert!(run.shards >= 1);
        act_bytes_by_lanes.push((lanes, run.shards, act));
        let bits: Vec<u32> = run.out.as_f32().iter().map(|v| v.to_bits()).collect();
        match &reference {
            None => reference = Some(bits),
            Some(want) => assert_eq!(&bits, want, "{lanes}-lane output bit-identical"),
        }
    }
    let (_, _, want) = act_bytes_by_lanes[0];
    for (lanes, shards, act) in &act_bytes_by_lanes {
        assert_eq!(
            *act, want,
            "activation LOAD bytes must not scale with lanes \
             (lanes={lanes} shards={shards}: {act} vs single-lane {want}); \
             full accounting: {act_bytes_by_lanes:?}"
        );
    }
    // The sweep only proves something if the op actually sharded wider.
    assert!(
        act_bytes_by_lanes.iter().any(|(_, shards, _)| *shards > 1),
        "op never split: {act_bytes_by_lanes:?}"
    );
}

/// The im2col variant of the broadcast-elision invariant: a conv patch
/// matrix inflates the shared activation stream by k² (each input pixel
/// appears in up to k² patches), so double-charging it per shard would
/// overstate DMA traffic worst of all ops. Sharding an F16 `ConvIm2col`
/// across 1/2/4/8 lanes must keep the total activation LOAD bytes
/// lane-invariant and the stitched output bit-identical.
#[test]
fn sharded_conv_im2col_activation_bytes_do_not_scale_with_lanes() {
    let mut rng = Xoshiro256pp::seed_from_u64(78);
    // A UNet-shaped conv site: cout=128, cin·k·k=1152, 8x8 output tile.
    let (cout, kk, n) = (128usize, 1152usize, 64usize);
    let mut wdata = vec![0.0f32; cout * kk];
    rng.fill_normal(&mut wdata, 0.3);
    let w = Tensor::f32(cout, kk, wdata).quantize(DType::F16).with_wid(WeightId(92));
    let mut patches = vec![0.0f32; n * kk];
    rng.fill_normal(&mut patches, 0.3);
    let x = Tensor::f32(n, kk, patches);

    let mut act_bytes_by_lanes = Vec::new();
    let mut reference: Option<Vec<u32>> = None;
    for lanes in [1usize, 2, 4, 8] {
        let c =
            Coordinator::new(ImaxConfig::fpga(lanes), lanes, 1, OffloadPolicy::QuantizedAndConv);
        c.set_min_shard_rows(1); // isolate the byte accounting from the cost model
        let op = OpDesc::conv_im2col(&w, &x, 3, 1);
        let run = c.submit_sharded(&op);
        let act: u64 = c
            .lane_costs()
            .iter()
            .map(|lc| lc.loaded_bytes - lc.weight_load_bytes)
            .sum();
        assert!(act > 0, "the conv streams its patch matrix at {lanes} lanes");
        act_bytes_by_lanes.push((lanes, run.shards, act));
        let bits: Vec<u32> = run.out.as_f32().iter().map(|v| v.to_bits()).collect();
        match &reference {
            None => reference = Some(bits),
            Some(want) => assert_eq!(&bits, want, "{lanes}-lane conv output bit-identical"),
        }
    }
    let (_, _, want) = act_bytes_by_lanes[0];
    for (lanes, shards, act) in &act_bytes_by_lanes {
        assert_eq!(
            *act, want,
            "im2col LOAD bytes must not scale with lanes \
             (lanes={lanes} shards={shards}: {act} vs single-lane {want}); \
             full accounting: {act_bytes_by_lanes:?}"
        );
    }
    assert!(
        act_bytes_by_lanes.iter().any(|(_, shards, _)| *shards > 1),
        "conv never split: {act_bytes_by_lanes:?}"
    );
}
