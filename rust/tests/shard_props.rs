//! Property tests for the row-tile shard partitioner: for arbitrary row
//! counts, lane counts and cache budgets the partition must be
//! disjoint, covering, balanced, budget-capped and stably identified.

use imax_sd::coordinator::{shard_wid, ShardPlan};
use imax_sd::ggml::WeightId;
use imax_sd::util::prop::{run, Gen};
use imax_sd::util::rng::Xoshiro256pp;

/// Check every invariant of one plan; returns a reason on violation.
fn check_plan(
    m: usize,
    lanes: usize,
    row_bytes: usize,
    budget: usize,
) -> Result<(), String> {
    let cap = ShardPlan::cap_rows(row_bytes, budget, m);
    let parent = WeightId(0xABCD ^ m as u64);
    let plan = ShardPlan::new(m, lanes, cap, Some(parent));

    // Disjoint + covering + ascending: shards tile 0..m exactly.
    let mut next = 0usize;
    for s in &plan.shards {
        if s.rows.start != next {
            return Err(format!("gap/overlap at row {next}: {:?}", s.rows));
        }
        if s.rows.is_empty() {
            return Err(format!("empty shard at {next}"));
        }
        next = s.rows.end;
    }
    if next != m {
        return Err(format!("rows covered {next} != m {m}"));
    }

    // Lane assignment round-robins and stays in range.
    for (i, s) in plan.shards.iter().enumerate() {
        if s.lane != i % lanes {
            return Err(format!("shard {i} on lane {} (want {})", s.lane, i % lanes));
        }
    }

    // Balanced to within one row.
    let min = plan.shards.iter().map(|s| s.len()).min().unwrap();
    let max = plan.shards.iter().map(|s| s.len()).max().unwrap();
    if max - min > 1 {
        return Err(format!("unbalanced shards: {min}..{max} rows"));
    }

    // Budget-capped: whenever one row fits the per-lane cache budget at
    // all, every shard must be cacheable (rows × row_bytes ≤ budget).
    if budget > 0 && row_bytes <= budget {
        for s in &plan.shards {
            if s.len() * row_bytes > budget {
                return Err(format!(
                    "shard of {} rows x {row_bytes} B exceeds the {budget} B lane budget",
                    s.len()
                ));
            }
        }
    }

    // Shard ids: derived from the parent, pairwise distinct, and equal
    // to the independent derivation the pin pass performs.
    let count = plan.shards.len();
    let mut seen = std::collections::HashSet::new();
    for (i, s) in plan.shards.iter().enumerate() {
        let want = shard_wid(parent, i, count);
        if s.wid != Some(want) {
            return Err(format!("shard {i} wid {:?} != derived {want:?}", s.wid));
        }
        if !seen.insert(want.0) {
            return Err(format!("shard {i} reuses a wid"));
        }
    }
    if count == 1 && plan.shards[0].wid != Some(parent) {
        return Err("single shard must keep the parent id".into());
    }
    Ok(())
}

#[test]
fn prop_shard_partitions_disjoint_covering_budgeted() {
    run("shard partition invariants", 150, Gen::usize_in(1..=1500), |&m| {
        // Derive lane counts and byte geometries from the (shrinkable)
        // row count, covering tight, roomy and disabled budgets.
        let mut rng = Xoshiro256pp::seed_from_u64(m as u64);
        for lanes in 1..=8usize {
            for _ in 0..3 {
                let row_bytes = 1 + rng.below(4096) as usize;
                let budget = match rng.below(4) {
                    0 => 0,                                   // cache disabled
                    1 => rng.below(row_bytes as u64) as usize, // sub-row budget
                    2 => row_bytes * (1 + rng.below(16) as usize), // tight
                    _ => row_bytes * m,                       // roomy
                };
                check_plan(m, lanes, row_bytes, budget)?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shard_count_matches_budget_pressure() {
    run("shard count = max(lanes, ceil(m/cap)) clamped to m", 200, Gen::usize_in(1..=2000), |&m| {
        for lanes in [1usize, 2, 4, 8] {
            for cap in [1usize, 3, 17, usize::MAX] {
                let plan = ShardPlan::new(m, lanes, cap, None);
                let want = lanes.max(m.div_ceil(cap.max(1))).min(m);
                if plan.shards.len() != want {
                    return Err(format!(
                        "m={m} lanes={lanes} cap={cap}: {} shards, want {want}",
                        plan.shards.len()
                    ));
                }
            }
        }
        Ok(())
    });
}
