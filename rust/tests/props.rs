//! Property-based tests over the quantization + simulator invariants,
//! using the in-tree prop framework (proptest is not vendored).

use imax_sd::ggml::{q3_k, q8_0, q8_k};
use imax_sd::imax::kernels::{dot_q3_k, dot_q8_0};
use imax_sd::imax::lane::{LaneSim, TilePlan};
use imax_sd::imax::lmm::{Lmm, RegionId};
use imax_sd::imax::{ImaxConfig, KernelConfig, KernelKind};
use imax_sd::util::prop::{run, Gen};
use imax_sd::util::rng::Xoshiro256pp;

fn pad_to(v: &[f32], mult: usize) -> Vec<f32> {
    let mut out = v.to_vec();
    while out.len() % mult != 0 || out.is_empty() {
        out.push(0.0);
    }
    out
}

#[test]
fn prop_q8_0_roundtrip_error_bounded() {
    run("q8_0 |x - deq(q(x))| <= d/2 + eps", 300, Gen::vec_f32(1..=96, -20.0..20.0), |xs| {
        let x = pad_to(xs, 32);
        let blocks = q8_0::quantize_row(&x);
        let back = q8_0::dequantize_row(&blocks);
        for (i, (a, b)) in x.iter().zip(&back).enumerate() {
            let d = blocks[i / 32].d.to_f32();
            // Half-step rounding error plus the f16 rounding of the
            // stored scale itself (relative 2^-11 over |q| <= 127).
            let bound = 0.5 * d + 127.0 * d * 4.9e-4 + 1e-5;
            if (a - b).abs() > bound {
                return Err(format!("elem {i}: {a} vs {b}, d={d}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_q8_k_roundtrip_error_bounded() {
    // Q8_K anchors the max-magnitude element at -128; every other
    // element rounds to the nearest step, except values whose scaled
    // magnitude rounds to 128 and clamps to 127 (error < 1 step).
    run("q8_K |x - deq(q(x))| <= |d| + eps", 300, Gen::vec_f32(1..=96, -25.0..25.0), |xs| {
        let x = pad_to(xs, 256);
        let blocks = q8_k::quantize_row(&x);
        let back = q8_k::dequantize_row(&blocks);
        for (i, (a, b)) in x.iter().zip(&back).enumerate() {
            let d = blocks[i / 256].d.abs();
            let bound = d + 1e-6;
            if (a - b).abs() > bound {
                return Err(format!("elem {i}: {a} vs {b}, d={d}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_q8_k_anchor_is_exact() {
    // The max-magnitude element must reconstruct (near-)exactly: it maps
    // to the -128 anchor by construction.
    run("q8_K anchor reconstructs", 300, Gen::vec_f32(1..=64, -50.0..50.0), |xs| {
        let x = pad_to(xs, 256);
        let blocks = q8_k::quantize_row(&x);
        let back = q8_k::dequantize_row(&blocks);
        for (bi, b) in blocks.iter().enumerate() {
            let chunk = &x[bi * 256..(bi + 1) * 256];
            let (mut amax, mut at) = (0.0f32, 0usize);
            for (j, &v) in chunk.iter().enumerate() {
                if v.abs() > amax {
                    amax = v.abs();
                    at = j;
                }
            }
            if amax == 0.0 {
                continue;
            }
            let got = back[bi * 256 + at];
            let want = chunk[at];
            if (got - want).abs() > 1e-5 * want.abs().max(1.0) {
                return Err(format!("anchor {at}: {want} -> {got} (d={})", b.d));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_q8_0_sim_bit_exact_with_host() {
    let cfg = KernelConfig::q8_0();
    run("imax q8_0 == ggml vec_dot (bits)", 200, Gen::vec_f32(1..=128, -8.0..8.0), |xs| {
        let x = pad_to(xs, 32);
        let mut rng = Xoshiro256pp::seed_from_u64(x.len() as u64);
        let y: Vec<f32> = (0..x.len()).map(|_| rng.normal()).collect();
        let (qa, qb) = (q8_0::quantize_row(&x), q8_0::quantize_row(&y));
        let sim = dot_q8_0(&cfg, &qa, &qb).value;
        let host = q8_0::vec_dot(&qa, &qb);
        if sim.to_bits() != host.to_bits() {
            return Err(format!("sim {sim} != host {host}"));
        }
        Ok(())
    });
}

#[test]
fn prop_q3_k_sim_bit_exact_with_imax5() {
    let cfg = KernelConfig::q3_k();
    run("imax q3_k == vec_dot_imax5 (bits)", 100, Gen::vec_f32(1..=300, -4.0..4.0), |xs| {
        let x = pad_to(xs, 256);
        let mut rng = Xoshiro256pp::seed_from_u64(x.len() as u64 + 1);
        let y: Vec<f32> = (0..x.len()).map(|_| rng.normal()).collect();
        let w = q3_k::quantize_row(&x);
        let a = q8_k::quantize_row(&y);
        let sim = dot_q3_k(&cfg, &w, &a).value;
        let host = q3_k::vec_dot_imax5(&w, &a);
        if sim.to_bits() != host.to_bits() {
            return Err(format!("sim {sim} != host {host}"));
        }
        Ok(())
    });
}

#[test]
fn prop_q3_scale_pack_roundtrip() {
    run("q3_k scale pack/unpack", 500, Gen::vec_f32(16..=16, -32.0..31.0), |xs| {
        let scales: Vec<i8> = xs.iter().map(|v| (*v as i32).clamp(-32, 31) as i8).collect();
        let arr: [i8; 16] = scales.clone().try_into().unwrap();
        let packed = q3_k::BlockQ3K::pack_scales(&arr);
        let blk = q3_k::BlockQ3K { scales: packed, ..Default::default() };
        if blk.unpack_scales() != arr {
            return Err(format!("{arr:?} -> {:?}", blk.unpack_scales()));
        }
        Ok(())
    });
}

#[test]
fn prop_analytic_equals_functional_cycles() {
    // For random shapes, the analytic phase model must equal the cycles
    // the functional walk produces (single source of truth).
    run("analytic == functional breakdown", 40, Gen::vec_f32(3..=3, 1.0..6.0), |dims| {
        let m = dims[0] as usize + 1;
        let n = dims[1] as usize + 1;
        let k = 32 * (dims[2] as usize + 1);
        let mut rng = Xoshiro256pp::seed_from_u64((m * 31 + n * 7 + k) as u64);
        let w: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
        let wb: Vec<_> = (0..m).flat_map(|r| q8_0::quantize_row(&w[r * k..(r + 1) * k])).collect();
        let ab: Vec<_> = (0..n).flat_map(|r| q8_0::quantize_row(&x[r * k..(r + 1) * k])).collect();
        let mut lane = LaneSim::new(ImaxConfig::fpga(1));
        let analytic = lane.analytic_mul_mat(KernelKind::Q8_0, m, n, k, true).unwrap();
        let (_, functional) = lane.mul_mat_q8_0(&wb, m, &ab, n, k).unwrap();
        if analytic != functional {
            return Err(format!("analytic {analytic:?} != functional {functional:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_tile_plans_always_fit_lmm() {
    run("tile plan fits LMM", 200, Gen::vec_f32(3..=3, 1.0..50.0), |dims| {
        let m = dims[0] as usize * 13 + 1;
        let n = dims[1] as usize * 17 + 1;
        let k = 256 * (dims[2] as usize + 1);
        let imax = ImaxConfig::fpga(1);
        match TilePlan::new(&imax, KernelKind::Q3K, m, n, k) {
            Ok(p) => {
                let bytes = p.a_tile * p.a_row_bytes
                    + p.w_tile * p.w_row_bytes
                    + p.w_tile * p.a_tile * 4;
                if bytes > imax.lmm_bytes {
                    return Err(format!("plan {p:?} exceeds LMM: {bytes}"));
                }
                if p.a_tiles() * p.a_tile < n || p.w_tiles() * p.w_tile < m {
                    return Err("tiles do not cover the matrix".into());
                }
                Ok(())
            }
            Err(_) => Ok(()), // reported OOM is a legal outcome for huge K
        }
    });
}

/// Fixed byte size per cache key (a `WeightId` always names the same
/// bytes, and `Lmm::cache_lookup` asserts it).
fn key_bytes(key: u64) -> usize {
    300 * (key as usize + 1)
}

/// Drive one LMM through a random alloc/free/lookup/insert/pin sequence
/// (decoded from the generated floats) and check the allocator/cache
/// invariants after every operation:
///
/// * live regions are disjoint and inside `[0, capacity)`;
/// * `used()` equals both the shadow model and the sum of live extents;
/// * `peak_used` is exactly the running max of post-op occupancy;
/// * transient bytes never exceed the transient partition, cached bytes
///   never exceed the budget;
/// * once a pinned key is resident it is never evicted by the policy.
fn drive_lmm(ops: &[f32], capacity: usize, budget: usize) -> Result<(), String> {
    let mut lmm = Lmm::new(capacity);
    lmm.set_cache_budget(budget);
    let mut live: Vec<(RegionId, usize)> = Vec::new();
    let mut trans_used = 0usize;
    let mut shadow_peak = 0usize;
    let mut pinned_resident: Vec<u64> = Vec::new();

    for (step, &v) in ops.iter().enumerate() {
        let sel = (v.abs() * 1e6) as usize;
        let key = (sel / 5 % 7) as u64;
        match sel % 5 {
            0 | 1 => {
                let bytes = sel % 1997 + 1;
                if let Ok(id) = lmm.alloc(bytes, "t") {
                    live.push((id, bytes));
                    trans_used += bytes;
                }
            }
            2 => {
                if let Some((id, bytes)) = live.pop() {
                    lmm.release(id);
                    trans_used -= bytes;
                }
            }
            3 => {
                if sel % 3 == 0 {
                    lmm.cache_pin(key);
                }
                if !lmm.cache_lookup(key, key_bytes(key))
                    && lmm.cache_insert(key, key_bytes(key), "w")
                    && lmm.cache_contains(key)
                    && sel % 3 == 0
                {
                    pinned_resident.push(key);
                }
            }
            _ => {
                // Lookup only (refreshes recency on hits).
                lmm.cache_lookup(key, key_bytes(key));
            }
        }

        // Invariants.
        let regions = lmm.live_regions();
        let mut sum = 0usize;
        for w in regions.windows(2) {
            if w[0].0 + w[0].1 > w[1].0 {
                return Err(format!("step {step}: overlapping regions {regions:?}"));
            }
        }
        for &(off, bytes) in &regions {
            if off + bytes > capacity {
                return Err(format!("step {step}: region [{off}, {}) outside LMM", off + bytes));
            }
            sum += bytes;
        }
        if lmm.used() != sum {
            return Err(format!("step {step}: used {} != live extents {sum}", lmm.used()));
        }
        if lmm.used() != trans_used + lmm.resident_bytes() {
            return Err(format!(
                "step {step}: used {} != transient {trans_used} + resident {}",
                lmm.used(),
                lmm.resident_bytes()
            ));
        }
        if trans_used > capacity - budget {
            return Err(format!("step {step}: transients overflow their partition"));
        }
        if lmm.resident_bytes() > budget {
            return Err(format!("step {step}: cache overflows its budget"));
        }
        shadow_peak = shadow_peak.max(lmm.used());
        if lmm.peak_used != shadow_peak {
            return Err(format!(
                "step {step}: peak_used {} != running max {shadow_peak}",
                lmm.peak_used
            ));
        }
        for &k in &pinned_resident {
            if !lmm.cache_contains(k) {
                return Err(format!("step {step}: pinned key {k} was evicted"));
            }
        }
    }
    Ok(())
}

#[test]
fn prop_lmm_regions_disjoint_accounting_exact() {
    run(
        "lmm alloc/free/insert invariants",
        150,
        Gen::vec_f32(1..=60, 0.0..1.0),
        |ops| drive_lmm(ops, 10_000, 4_000),
    );
}

#[test]
fn prop_lmm_eviction_never_frees_pinned() {
    // A tiny budget forces constant eviction pressure; the pinned-key
    // invariant inside `drive_lmm` is what this property is about.
    run(
        "lmm LRU churn spares pinned",
        150,
        Gen::vec_f32(1..=80, 0.0..1.0),
        |ops| drive_lmm(ops, 6_000, 1_500),
    );
}

#[test]
fn prop_q8k_bsums_consistent() {
    run("q8_K bsums = group sums", 300, Gen::vec_f32(1..=64, -10.0..10.0), |xs| {
        let x = pad_to(xs, 256);
        for b in q8_k::quantize_row(&x) {
            for (g, chunk) in b.qs.chunks_exact(16).enumerate() {
                let s: i16 = chunk.iter().map(|&q| q as i16).sum();
                if b.bsums[g] != s {
                    return Err(format!("group {g}: {} vs {s}", b.bsums[g]));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_f16_roundtrip_monotone() {
    run("f16 conversion order-preserving", 400, Gen::vec_f32(2..=2, -60000.0..60000.0), |xs| {
        use imax_sd::util::f16::F16;
        let (a, b) = (xs[0], xs[1]);
        let (fa, fb) = (F16::from_f32(a).to_f32(), F16::from_f32(b).to_f32());
        if a <= b && fa > fb {
            return Err(format!("{a} <= {b} but {fa} > {fb}"));
        }
        Ok(())
    });
}
