//! Request-lifecycle integration tests for the serving front-end:
//! cancellation mid-denoise, deadline expiry, graceful drain, and the
//! HTTP round-trip — the contracts `DESIGN.md`'s "Serving front-end"
//! chapter states.
//!
//! The mid-denoise test is deterministic without timing games: a
//! tapping backend counts `OpKind::TimeEmbed` submissions (one fixed
//! group per UNet step, none in the text encoder or the VAE) and fires
//! the request's [`CancelToken`] on the first TimeEmbed of step 2 — so
//! the step-boundary check before step 3 must abort with exactly two
//! steps completed, and the survivors of the micro-batch must come out
//! bit-identical to a batch that never contained the cancelled member.

use imax_sd::sd::backend::{EngineStats, ExecBackend, OpDesc, OpHandle, OpKind, RequestId};
use imax_sd::sd::pipeline::{to_rgb8, Backend, Pipeline, PipelineConfig};
use imax_sd::sd::QuantModel;
use imax_sd::serve::{
    BatchMember, RunnerState, ServeConfig, ServeHarness, ServeRequest, SharedBatch,
};
use imax_sd::server::http::http_call;
use imax_sd::server::{Admission, Json, Runner, RunnerConfig, Server};
use imax_sd::util::cancel::{CancelCause, CancelToken};
use imax_sd::util::png::crc32;
use std::sync::Arc;
use std::time::Duration;

fn pipe_cfg(steps: usize) -> PipelineConfig {
    PipelineConfig {
        weight_seed: 99,
        model: Some(QuantModel::Q8_0),
        steps,
        backend: Backend::Host { threads: 2 },
        conv_offload: false,
    }
}

fn serve_cfg(max_batch: usize, workers: usize, queue_capacity: usize) -> ServeConfig {
    ServeConfig {
        lanes: 1,
        host_threads: 2,
        max_batch,
        workers,
        sharded: false,
        queue_capacity,
    }
}

/// Backend tap: counts TimeEmbed submissions and fires the token when
/// the count reaches `fire_at` (0 = never fire, pure counter).
struct TimeEmbedTap {
    inner: BatchMember,
    token: CancelToken,
    seen: usize,
    fire_at: usize,
}

impl TimeEmbedTap {
    fn new(inner: BatchMember, token: CancelToken, fire_at: usize) -> TimeEmbedTap {
        TimeEmbedTap { inner, token, seen: 0, fire_at }
    }
}

impl ExecBackend for TimeEmbedTap {
    fn submit(&mut self, op: OpDesc<'_>) -> OpHandle {
        if op.kind == OpKind::TimeEmbed {
            self.seen += 1;
            if self.fire_at > 0 && self.seen == self.fire_at {
                self.token.cancel();
            }
        }
        self.inner.submit(op)
    }

    fn sync(&mut self, h: OpHandle) -> imax_sd::ggml::Tensor {
        self.inner.sync(h)
    }

    fn stats(&self) -> &EngineStats {
        self.inner.stats()
    }

    fn begin_request(&mut self, id: RequestId) {
        self.inner.begin_request(id);
    }
}

/// TimeEmbed submissions per denoising step, measured — not assumed —
/// by differencing a 3-step and a 2-step solo run.
fn time_embeds_per_step(pipeline: &Pipeline, harness: &ServeHarness) -> usize {
    let count = |steps: usize| {
        let shared = SharedBatch::new(1, Arc::clone(harness.coordinator()), false);
        let member = BatchMember::new(shared, 0, RequestId(1));
        let mut tap = TimeEmbedTap::new(member, CancelToken::new(), 0);
        pipeline
            .generate_request(&mut tap, RequestId(1), "probe", 1, steps, &CancelToken::new())
            .expect("live probe run completes");
        tap.seen
    };
    let (two, three) = (count(2), count(3));
    let per_step = three - two;
    assert!(per_step > 0, "the UNet submits TimeEmbed ops every step");
    assert_eq!(two, 2 * per_step, "TimeEmbed appears only inside denoising steps");
    per_step
}

#[test]
fn cancel_mid_denoise_aborts_and_survivors_stay_bit_identical() {
    let steps = 4;
    let pipeline = Pipeline::new(pipe_cfg(steps));
    let harness = ServeHarness::new(pipe_cfg(steps), serve_cfg(4, 1, 8));
    let per_step = time_embeds_per_step(&pipeline, &harness);

    let prompts = ["a lovely cat", "an angry robot", "a doomed request"];
    let run = |with_victim: bool| -> Vec<(u32, u64)> {
        let members = if with_victim { 3 } else { 2 };
        let shared = SharedBatch::new(members, Arc::clone(harness.coordinator()), false);
        std::thread::scope(|scope| {
            let survivors: Vec<_> = (0..2usize)
                .map(|slot| {
                    let shared = Arc::clone(&shared);
                    let pipeline = &pipeline;
                    scope.spawn(move || {
                        let rid = RequestId(slot as u64 + 1);
                        let mut eng = BatchMember::new(shared, slot, rid);
                        let (img, _) = pipeline
                            .generate_request(
                                &mut eng,
                                rid,
                                prompts[slot],
                                7 + slot as u64,
                                steps,
                                &CancelToken::new(),
                            )
                            .expect("survivor completes");
                        (crc32(&to_rgb8(&img)), eng.stats().calls)
                    })
                })
                .collect();
            if with_victim {
                let shared = Arc::clone(&shared);
                let pipeline = &pipeline;
                scope.spawn(move || {
                    let token = CancelToken::new();
                    let member = BatchMember::new(shared, 2, RequestId(3));
                    // Fire on the first TimeEmbed of denoising step 2.
                    let mut tap = TimeEmbedTap::new(member, token.clone(), per_step + 1);
                    let err = pipeline
                        .generate_request(&mut tap, RequestId(3), prompts[2], 9, steps, &token)
                        .expect_err("cancelled request aborts");
                    // The abort is cooperative: the step-2 boundary was
                    // already past when the token fired, so exactly two
                    // steps ran and the rest were never submitted.
                    assert_eq!(err.cause, CancelCause::Cancelled);
                    assert_eq!(err.steps_completed, 2, "aborts at the next step boundary");
                    tap.inner.leave();
                });
            }
            survivors.into_iter().map(|h| h.join().unwrap()).collect()
        })
    };

    let with_victim = run(true);
    let reference = run(false);
    assert_eq!(
        with_victim, reference,
        "survivor images and op counts match a batch that never held the victim"
    );
}

#[test]
fn expired_deadline_surfaces_as_expired_in_batch_outcomes() {
    let harness = ServeHarness::new(pipe_cfg(1), serve_cfg(4, 1, 8));
    let doomed = CancelToken::with_deadline(std::time::Instant::now() - Duration::from_millis(1));
    let batch = vec![
        ServeRequest::new(RequestId(1), "a lovely cat".into(), 7, 1),
        ServeRequest::new(RequestId(2), "too late".into(), 8, 1).with_cancel(doomed),
    ];
    let outcomes = harness.run_batch(&batch);
    assert_eq!(outcomes.len(), 2);
    assert_eq!(outcomes[0].state, RunnerState::Succeeded);
    assert!(outcomes[0].image_crc32 != 0);
    assert_eq!(outcomes[1].state, RunnerState::Expired);
    assert_eq!(outcomes[1].steps_completed, 0, "expired before any step");
    assert_eq!(outcomes[1].image_crc32, 0, "no image for an expired request");
}

#[test]
fn graceful_shutdown_drains_queued_work_and_rejects_new() {
    let harness = ServeHarness::new(pipe_cfg(1), serve_cfg(2, 1, 8));
    let runner = Runner::start(harness, RunnerConfig::default());
    let mut ids = Vec::new();
    for i in 0..3u64 {
        match runner.create(&format!("drain me {i}"), i, 1, None, None) {
            Admission::Created { id } => ids.push(id),
            other => panic!("admission refused: {other:?}"),
        }
    }
    // Shutdown must drain everything already admitted...
    let report = runner.shutdown();
    assert_eq!(report.count(RunnerState::Succeeded), 3, "all admitted requests drained");
    for id in ids {
        let st = runner.status(id).expect("drained request still pollable");
        assert_eq!(st.state, RunnerState::Succeeded);
    }
    // ...and everything after the drain is refused.
    assert!(
        matches!(runner.create("too late", 9, 1, None, None), Admission::Draining),
        "a draining runner admits nothing"
    );
}

#[test]
fn http_round_trip_cancel_backpressure_and_drain() {
    // One worker, one-request batches, a one-deep queue: with a slow
    // (8-step) request running and another waiting, the next create
    // must bounce with 429 + Retry-After.
    let harness = ServeHarness::new(pipe_cfg(1), serve_cfg(1, 1, 1));
    let server = Server::start(
        "127.0.0.1:0",
        harness,
        RunnerConfig {
            slo_seconds: 1e9,
            default_steps: 1,
            max_steps: 8,
            ..RunnerConfig::default()
        },
    )
    .expect("bind loopback");
    let addr = server.addr().to_string();

    let create = |prompt: &str, steps: f64| {
        let body = Json::obj(vec![
            ("prompt", Json::Str(prompt.into())),
            ("steps", Json::Num(steps)),
        ]);
        http_call(&addr, "POST", "/predictions", Some(&body)).expect("create round-trip")
    };

    // Fill the server: one long request runs, one waits in the queue.
    let running = create("slow one", 8.0);
    assert_eq!(running.status, 202);
    let running_id = running.json().unwrap().get("id").unwrap().as_u64().unwrap();
    // Wait for the worker to pop it so the one-deep queue is free for
    // the next create (the pop is asynchronous).
    for _ in 0..5_000 {
        let health = http_call(&addr, "GET", "/healthz", None).unwrap();
        let depth = health.json().unwrap().get("queue_depth").unwrap().as_u64().unwrap();
        if depth == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    let queued = create("waiting one", 8.0);
    assert_eq!(queued.status, 202);

    // The queue bound is the backstop: the third create is shed.
    let shed = create("one too many", 1.0);
    assert_eq!(shed.status, 429, "bounded queue sheds with 429");
    assert!(shed.header("retry-after").is_some(), "429 carries Retry-After");
    let retry = shed.json().unwrap().get("retry_after_seconds").unwrap().as_u64().unwrap();
    assert!(retry >= 1);

    // Cancel the running request over HTTP; it must reach a terminal
    // state without completing all 8 steps.
    let cancel = http_call(&addr, "POST", &format!("/predictions/{running_id}/cancel"), None)
        .expect("cancel round-trip");
    assert_eq!(cancel.status, 200);
    let mut state = String::new();
    for _ in 0..5_000 {
        let poll = http_call(&addr, "GET", &format!("/predictions/{running_id}"), None).unwrap();
        state = poll.json().unwrap().get("status").unwrap().as_str().unwrap().to_string();
        if state == RunnerState::Cancelled.name() {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(state, RunnerState::Cancelled.name(), "cancelled over HTTP");

    // Graceful shutdown drains the queued request and reports honestly.
    let report = server.shutdown();
    assert_eq!(report.count(RunnerState::Cancelled), 1);
    assert_eq!(report.count(RunnerState::Succeeded), 1, "queued peer drained to success");
    assert_eq!(report.rejected, 1, "the 429 is on the books");
    let cancelled = report
        .outcomes
        .iter()
        .find(|o| o.state == RunnerState::Cancelled)
        .expect("cancelled outcome present");
    assert!(cancelled.steps_completed < 8, "cancel aborted remaining steps");
}
