//! Weight-residency acceptance: caching must be a pure DMA elision.
//!
//! * Pipeline output with the weight cache enabled is **byte-identical**
//!   to cache-disabled, for both quantized models.
//! * Warm denoising steps cost strictly fewer simulated lane cycles
//!   than the cold step, and reach a steady state.
//! * When every weight fits the LMM cache, a warm step's LOAD bytes are
//!   a small fraction of a cold step's (< 10 % on weight-dominated
//!   shapes; the mini U-Net's activation stream puts a floor around
//!   16 % for Q8_0, asserted at < 50 % for both models).

use imax_sd::ggml::WeightId;
use imax_sd::imax::lane::LaneSim;
use imax_sd::imax::ImaxConfig;
use imax_sd::sd::pipeline::{Backend, Pipeline, PipelineConfig};
use imax_sd::sd::plan::replay_unet_steps;
use imax_sd::sd::QuantModel;
use imax_sd::util::rng::Xoshiro256pp;

fn pipe_cfg(model: QuantModel, imax: ImaxConfig) -> PipelineConfig {
    PipelineConfig {
        weight_seed: 0x5D_7B0,
        model: Some(model),
        steps: 2,
        backend: Backend::Imax { config: imax, threads: 2 },
        // Quantized-only residency semantics under test here; the conv
        // datapath's warm/cold behavior lives in backend_equivalence.
        conv_offload: false,
    }
}

/// Acceptance: bit-identity. Caching only elides redundant DMA; the
/// operands (and therefore every output bit) are unchanged.
#[test]
fn pipeline_bit_identical_with_cache_on_and_off() {
    for model in [QuantModel::Q8_0, QuantModel::Q3K] {
        let on = Pipeline::new(pipe_cfg(model, ImaxConfig::fpga(1)));
        let mut imax_off = ImaxConfig::fpga(1);
        imax_off.weight_cache_bytes = 0;
        let off = Pipeline::new(pipe_cfg(model, imax_off));

        let (img_on, r_on) = on.generate("a lovely cat", 7);
        let (img_off, r_off) = off.generate("a lovely cat", 7);

        assert_eq!(img_on.data.len(), img_off.data.len());
        for (a, b) in img_on.data.iter().zip(&img_off.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "{model:?}: cache must not change bits");
        }
        assert!(r_on.cache.hits > 0, "{model:?}: step 2 hits step 1 residents");
        assert_eq!(
            r_off.cache.hits + r_off.cache.misses,
            0,
            "{model:?}: disabled cache sees no traffic"
        );
        assert_eq!(r_on.plan_divergences, 0, "{model:?}: dispatch followed the plan");
        assert!(
            r_on.imax_phases.total() < r_off.imax_phases.total(),
            "{model:?}: residency must save simulated cycles ({} vs {})",
            r_on.imax_phases.total(),
            r_off.imax_phases.total()
        );
    }
}

/// Acceptance: warm steps are strictly cheaper than the cold step and
/// settle into a steady state where only activations move. Uses the
/// shared experiment definition in [`replay_unet_steps`] (the same one
/// the `weight_reuse` bench reports) with an LMM big enough that the
/// whole quantized weight set is cacheable.
#[test]
fn warm_steps_strictly_cheaper_and_steady() {
    for model in [QuantModel::Q8_0, QuantModel::Q3K] {
        let steps = replay_unet_steps(model, 4 << 20, 2 << 20, 3);
        let (cold, warm) = (steps[0], steps[1]);
        assert!(
            warm.cycles < cold.cycles,
            "{model:?}: warm step must cost fewer lane cycles ({} vs {})",
            warm.cycles,
            cold.cycles
        );
        assert!(
            warm.load_bytes * 2 < cold.load_bytes,
            "{model:?}: resident weights halve LOAD volume at least ({} vs {})",
            warm.load_bytes,
            cold.load_bytes
        );
        assert!(warm.hits > 0 && warm.hit_bytes > 0, "{model:?}: warm hits recorded");
        assert_eq!(steps[1], steps[2], "{model:?}: steps 2 and 3 are identical (steady state)");
    }
}

/// Acceptance (conv offload): on the §VI projection substrate — ASIC
/// clock with a production interconnect, the `future_work` bench's
/// "6.7 GB/s DMA" row — a warm mini U-Net step with conv offload +
/// residency costs strictly fewer total lane-clock cycles than **both**
/// the cold offload step and the host-conv path (quantized-only lane
/// cycles plus the conv MACs priced at the A72's F16 rate). On the
/// prototype DMA the same offload regresses (the Fig. 11 lesson,
/// asserted in `device::future`). Deltas are recorded in EXPERIMENTS.md
/// §Conv offload and replicated by
/// `python/replica/conv_offload_replica.py`.
#[test]
fn conv_offload_warm_step_beats_host_conv_and_cold_offload() {
    use imax_sd::coordinator::OffloadPolicy;
    use imax_sd::device::arm_a72;
    use imax_sd::sd::plan::{replay_unet_steps_policy, unet_step_conv_macs};

    let mut imax = ImaxConfig::asic(1);
    imax.lmm_bytes = 8 << 20;
    imax.weight_cache_bytes = 4 << 20; // conv + quantized weight sets fully resident
    imax.dma_bytes_per_cycle = 8.0; // §VI production interconnect
    for model in [QuantModel::Q8_0, QuantModel::Q3K] {
        let conv =
            replay_unet_steps_policy(model, imax.clone(), 3, OffloadPolicy::QuantizedAndConv);
        let quant = replay_unet_steps_policy(model, imax.clone(), 3, OffloadPolicy::QuantizedOnly);
        let (cold, warm) = (conv[0], conv[1]);
        assert!(
            warm.cycles < cold.cycles,
            "{model:?}: resident conv weights must beat the cold step ({} vs {})",
            warm.cycles,
            cold.cycles
        );
        assert_eq!(conv[1], conv[2], "{model:?}: warm conv steps reach a steady state");
        assert!(
            warm.hit_bytes > quant[1].hit_bytes,
            "{model:?}: conv weights hit the cache on top of the quantized set"
        );
        // The host-conv path: identical quantized lane work plus the F16
        // conv GEMMs on the host, expressed in lane-clock cycles.
        let conv_macs = unet_step_conv_macs(model);
        assert!(conv_macs > 100_000_000, "convs dominate the step ({conv_macs} MACs)");
        let host_conv_cycles =
            (conv_macs as f64 / (arm_a72().gmacs_f16 * 1e9) * imax.clock_hz) as u64;
        let host_path = quant[1].cycles + host_conv_cycles;
        assert!(
            warm.cycles < host_path,
            "{model:?}: warm conv offload ({}) must beat the host-conv path ({} lane + {} host-conv cycles)",
            warm.cycles,
            quant[1].cycles,
            host_conv_cycles
        );
    }
}

/// Acceptance: on weight-dominated shapes (GEMV-style, the LLM-decode
/// pattern the follow-up CGLA work targets) with every weight tile
/// resident, a warm step's LOAD bytes drop below 10 % of the cold step.
#[test]
fn warm_step_load_bytes_below_10_percent_when_weights_fit() {
    // Two [128, 512] weights applied to single activation rows — one
    // "step" is both ops; their 136 KiB (Q8_0) / 55 KiB (Q3_K) fit the
    // default 256 KiB cache budget.
    let (m, n, k) = (128usize, 1usize, 512usize);
    let mut rng = Xoshiro256pp::seed_from_u64(9);
    let mut wdata = vec![0.0f32; m * k];
    let mut xdata = vec![0.0f32; n * k];
    rng.fill_normal(&mut wdata, 0.5);
    rng.fill_normal(&mut xdata, 0.5);

    // Q8_0 lane.
    {
        let rows: Vec<_> = (0..m)
            .flat_map(|r| imax_sd::ggml::q8_0::quantize_row(&wdata[r * k..(r + 1) * k]))
            .collect();
        let acts: Vec<_> = (0..n)
            .flat_map(|r| imax_sd::ggml::q8_0::quantize_row(&xdata[r * k..(r + 1) * k]))
            .collect();
        let mut lane = LaneSim::new(ImaxConfig::fpga(1));
        let step = |lane: &mut LaneSim| {
            let l0 = lane.lmm.loaded_bytes;
            for wid in [WeightId(1), WeightId(2)] {
                lane.mul_mat_q8_0_cached(Some(wid), &rows, m, &acts, n, k).unwrap();
            }
            lane.lmm.loaded_bytes - l0
        };
        let cold = step(&mut lane);
        let warm = step(&mut lane);
        assert!(
            warm * 10 < cold,
            "Q8_0: warm LOAD bytes must drop below 10% ({warm} vs {cold})"
        );
    }

    // Q3_K lane.
    {
        let rows: Vec<_> = (0..m)
            .flat_map(|r| imax_sd::ggml::q3_k::quantize_row(&wdata[r * k..(r + 1) * k]))
            .collect();
        let acts: Vec<_> = (0..n)
            .flat_map(|r| imax_sd::ggml::q8_k::quantize_row(&xdata[r * k..(r + 1) * k]))
            .collect();
        let mut lane = LaneSim::new(ImaxConfig::fpga(1));
        let step = |lane: &mut LaneSim| {
            let l0 = lane.lmm.loaded_bytes;
            for wid in [WeightId(1), WeightId(2)] {
                lane.mul_mat_q3_k_cached(Some(wid), &rows, m, &acts, n, k).unwrap();
            }
            lane.lmm.loaded_bytes - l0
        };
        let cold = step(&mut lane);
        let warm = step(&mut lane);
        assert!(
            warm * 10 < cold,
            "Q3_K: warm LOAD bytes must drop below 10% ({warm} vs {cold})"
        );
    }
}
