//! Webhook delivery integration tests: a loopback [`FaultReceiver`]
//! with scripted faults (5xx, read-timeout stalls) proves the retry
//! schedule, eventual delivery, the events filter end-to-end, and the
//! shutdown drain ordering — terminal states produced mid-drain are
//! delivered before `shutdown()` returns.
//!
//! The backoff schedule is deterministic (seeded jitter keyed on the
//! prediction id), so the tests assert *exact lower bounds* on the
//! gaps between delivery attempts, not just "it retried eventually".

use imax_sd::sd::pipeline::{Backend, PipelineConfig};
use imax_sd::sd::QuantModel;
use imax_sd::serve::{RunnerState, ServeConfig, ServeHarness};
use imax_sd::server::http::http_call;
use imax_sd::server::{
    backoff_schedule, Admission, Fault, FaultReceiver, Json, Runner, RunnerConfig, Server, Webhook,
    WebhookConfig, WebhookSender,
};
use std::time::{Duration, Instant};

fn harness() -> ServeHarness {
    let pipe = PipelineConfig {
        weight_seed: 99,
        model: Some(QuantModel::Q8_0),
        steps: 1,
        backend: Backend::Host { threads: 2 },
        conv_offload: false,
    };
    let serve = ServeConfig {
        lanes: 1,
        host_threads: 2,
        max_batch: 2,
        workers: 1,
        sharded: false,
        queue_capacity: 8,
    };
    ServeHarness::new(pipe, serve)
}

/// End-to-end over HTTP: the receiver fails the first two attempts
/// with 503, so the delivery must follow the pinned backoff schedule
/// for prediction id 1 and land exactly once on the third attempt.
#[test]
fn faulted_receiver_gets_exactly_one_delivery_on_the_pinned_schedule() {
    let receiver = FaultReceiver::start(vec![Fault::Status(503), Fault::Status(503)])
        .expect("bind loopback receiver");
    let server = Server::start("127.0.0.1:0", harness(), RunnerConfig::default())
        .expect("bind loopback server");
    let addr = server.addr().to_string();

    let body = Json::obj(vec![
        ("prompt", Json::Str("a lovely cat".into())),
        ("seed", Json::Num(7.0)),
        ("webhook", Json::Str(receiver.url("/hooks/done"))),
    ]);
    let created = http_call(&addr, "POST", "/predictions", Some(&body)).unwrap();
    assert_eq!(created.status, 202);
    let id = created.json().unwrap().get("id").unwrap().as_u64().unwrap();
    assert_eq!(id, 1, "first prediction takes id 1 (the schedule below is keyed on it)");

    // Shutdown flushes the delivery queue (retries included) before
    // returning, so no polling is needed on the receiver side.
    let report = server.shutdown();

    assert_eq!(receiver.delivered_count(), 1, "delivered exactly once");
    let delivered = receiver.delivered();
    assert_eq!(delivered[0].get("id").unwrap().as_u64(), Some(1));
    assert_eq!(delivered[0].get("status").unwrap().as_str(), Some("succeeded"));
    assert!(delivered[0].get("image_crc32").unwrap().as_u64().unwrap() > 0);
    assert!(delivered[0].get("metrics").is_some(), "full prediction JSON, not a stub");

    // Three connections: 503, 503, 200 — with gaps no shorter than the
    // pinned schedule (45 ms, then 62 ms for id 1 under the default
    // config; `backoff_schedule_is_pinned` asserts the exact values).
    let hits = receiver.hits();
    assert_eq!(hits.len(), 3, "two faulted attempts plus the success");
    let pinned = backoff_schedule(&WebhookConfig::default(), 1, 2);
    for (k, gap_floor_ms) in pinned.iter().enumerate() {
        let gap = hits[k + 1].duration_since(hits[k]);
        assert!(
            gap >= Duration::from_millis(*gap_floor_ms),
            "retry {} fired after {:?}, before its {} ms backoff gate",
            k + 1,
            gap,
            gap_floor_ms
        );
    }

    let wh = &report.webhook;
    assert_eq!(wh.enqueued, 1);
    assert_eq!(wh.attempts, 3);
    assert_eq!(wh.retries, 2);
    assert_eq!(wh.delivered, 1);
    assert_eq!(wh.dead_lettered, 0);
    assert_eq!(wh.latency_seconds.len(), 1, "one delivery-latency sample");
    receiver.stop();
}

/// A receiver that stalls past the client's read timeout costs one
/// attempt; the retry (after the stall has cleared) delivers. Driven
/// at the `WebhookSender` layer so the timeouts and backoff can be
/// pinned tightly without a pipeline in the loop.
#[test]
fn stall_past_read_timeout_retries_and_delivers() {
    let receiver =
        FaultReceiver::start(vec![Fault::StallMs(400)]).expect("bind loopback receiver");
    let cfg = WebhookConfig {
        read_timeout_ms: 150,
        // One retry gate ∈ [500, 1000) ms — comfortably after the
        // receiver's 400 ms stall has cleared its serial accept loop.
        base_backoff_ms: 1000,
        max_backoff_ms: 2000,
        ..WebhookConfig::default()
    };
    let sender = WebhookSender::start(cfg);
    let wh = Webhook::parse(&receiver.url("/hook")).unwrap();
    sender.enqueue(1, &wh, Json::obj(vec![("id", Json::Num(1.0))]), Instant::now());
    sender.flush_and_join(Duration::from_secs(30));

    let stats = sender.stats();
    assert_eq!(stats.attempts, 2, "stalled attempt timed out, retry succeeded");
    assert_eq!(stats.retries, 1);
    assert_eq!(stats.delivered, 1);
    assert_eq!(stats.dead_lettered, 0);
    assert_eq!(receiver.hits().len(), 2);
    receiver.stop();
}

/// Drain ordering: a prediction still queued when shutdown starts
/// reaches its terminal state during the drain, and its webhook is
/// delivered *before* `shutdown()` returns — no poll, no sleep.
#[test]
fn terminal_state_during_drain_is_delivered_before_shutdown_returns() {
    let receiver = FaultReceiver::start(Vec::new()).expect("bind loopback receiver");
    let runner = Runner::start(harness(), RunnerConfig::default());
    let wh = Webhook::parse(&receiver.url("/drained")).unwrap();
    let Admission::Created { id } = runner.create("drain me", 7, 1, None, Some(wh)) else {
        panic!("admission refused");
    };
    // Shut down immediately: the prediction is (at best) just starting.
    let report = runner.shutdown();

    assert_eq!(receiver.delivered_count(), 1, "delivery happened-before shutdown returned");
    let delivered = receiver.delivered();
    assert_eq!(delivered[0].get("id").unwrap().as_u64(), Some(id));
    assert_eq!(delivered[0].get("status").unwrap().as_str(), Some("succeeded"));
    assert_eq!(report.webhook.delivered, 1);
    assert_eq!(report.webhook.dead_lettered, 0);
    assert_eq!(report.count(RunnerState::Succeeded), 1, "the drain ran the prediction");
    receiver.stop();
}

/// The events filter end-to-end over HTTP: a filter that excludes the
/// prediction's terminal state suppresses delivery entirely; a
/// matching filter delivers.
#[test]
fn events_filter_gates_delivery_end_to_end() {
    let receiver = FaultReceiver::start(Vec::new()).expect("bind loopback receiver");
    let server = Server::start("127.0.0.1:0", harness(), RunnerConfig::default())
        .expect("bind loopback server");
    let addr = server.addr().to_string();

    let create = |prompt: &str, filter: &[&str]| {
        let events = filter.iter().map(|s| Json::Str((*s).into())).collect();
        let body = Json::obj(vec![
            ("prompt", Json::Str(prompt.into())),
            ("webhook", Json::Str(receiver.url("/filtered"))),
            ("webhook_events_filter", Json::Arr(events)),
        ]);
        let resp = http_call(&addr, "POST", "/predictions", Some(&body)).unwrap();
        assert_eq!(resp.status, 202);
        resp.json().unwrap().get("id").unwrap().as_u64().unwrap()
    };

    let _suppressed = create("succeeds quietly", &["cancelled", "expired"]);
    let wanted = create("succeeds loudly", &["succeeded"]);
    let report = server.shutdown();

    assert_eq!(receiver.delivered_count(), 1, "only the matching filter delivered");
    let delivered = receiver.delivered();
    assert_eq!(delivered[0].get("id").unwrap().as_u64(), Some(wanted));
    assert_eq!(delivered[0].get("status").unwrap().as_str(), Some("succeeded"));
    assert_eq!(report.webhook.enqueued, 1, "the non-matching transition was never enqueued");
    assert_eq!(report.webhook.delivered, 1);
    receiver.stop();
}
