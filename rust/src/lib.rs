//! # imax-sd
//!
//! Reproduction of "Implementation and Evaluation of Stable Diffusion on a
//! General-Purpose CGLA Accelerator" (Ando, Eto, Nakashima; CS.AR 2025).
//!
//! See `DESIGN.md` for the substitution ledger and experiment index.
pub mod check;
pub mod coordinator;
pub mod device;
pub mod ggml;
pub mod imax;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sd;
pub mod serve;
pub mod server;
pub mod util;
