//! Production-style HTTP serving front-end over the batched serving
//! stack in [`crate::serve`].
//!
//! The paper stops at single-invocation image generation; this
//! subsystem wraps the micro-batching [`ServeHarness`] in the request
//! lifecycle a deployed model server needs (the shape of cog's
//! director/runner split):
//!
//! ```text
//! TCP accept ──▶ http (framing) ──▶ routes ──▶ Runner
//!                                               │ admission: SLO estimate → 429,
//!                                               │            queue bound → 429,
//!                                               │            draining     → 503
//!                                               ▼
//!                                    RequestQueue (bounded)
//!                                               │ step-homogeneous micro-batches
//!                                               ▼
//!                                    ServeHarness::run_batch
//!                                               │ CancelToken checked at every
//!                                               │ denoising-step boundary
//!                                               ▼
//!                                    SharedBatch rendezvous → lanes
//!                                               │ terminal outcome recorded
//!                                               ▼
//!                                    WebhookSender (bounded queue,
//!                                    retry/backoff) ──▶ POST callback URL
//! ```
//!
//! Everything is std-only: hand-rolled HTTP/1.1 framing ([`http`]), a
//! miniature JSON codec ([`json`]), raw `signal(2)` hooks
//! ([`shutdown`]). Cancellation is cooperative end-to-end — the cancel
//! route fires a [`crate::util::cancel::CancelToken`] that the
//! denoising loop consults before every step, and the aborting member
//! leaves its lockstep micro-batch without perturbing the survivors'
//! bits. Clients that pass a `webhook` URL on create get the terminal
//! prediction JSON POSTed back with retry/backoff ([`webhook`]) instead
//! of having to poll. Graceful shutdown (SIGTERM/ctrl-c or
//! [`Server::shutdown`]) stops admission, drains every queued and
//! running request, joins the serving workers, quiesces the
//! coordinator's lane worker pool, **flushes the webhook delivery
//! queue** (drain-deadline bounded), and only then stops the accept
//! loop — terminal states produced mid-drain are still delivered.

pub mod http;
pub mod json;
pub mod routes;
pub mod runner;
pub mod shutdown;
pub mod webhook;

pub use json::Json;
pub use runner::{
    admission_decision, effective_batch_seconds, estimate_queue_seconds, Admission,
    PredictionStatus, Runner, RunnerConfig,
};
pub use webhook::{
    backoff_delay_ms, backoff_schedule, Fault, FaultReceiver, Webhook, WebhookConfig,
    WebhookSender,
};

use crate::serve::{ServeHarness, ServeReport};
use crate::util::sync::Mutex;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// How long the accept loop sleeps when no connection is pending.
const ACCEPT_POLL: Duration = Duration::from_millis(2);
/// Per-connection socket read timeout (a stalled client cannot pin a
/// handler thread forever).
const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// A running HTTP server: a nonblocking accept loop feeding
/// thread-per-connection handlers over a shared [`Runner`].
pub struct Server {
    runner: Arc<Runner>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral test port)
    /// and start serving.
    pub fn start(
        addr: &str,
        harness: ServeHarness,
        config: RunnerConfig,
    ) -> std::io::Result<Server> {
        let runner = Runner::start(harness, config);
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_thread = {
            let runner = Arc::clone(&runner);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || accept_loop(listener, runner, stop))
        };
        Ok(Server { runner, addr: local, stop, accept_thread: Some(accept_thread) })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The lifecycle runner behind the routes.
    pub fn runner(&self) -> &Arc<Runner> {
        &self.runner
    }

    /// Graceful shutdown: drain the runner (new creates see 503 while
    /// in-flight requests finish), then stop accepting and join the
    /// accept loop. Returns the aggregate serving report.
    pub fn shutdown(mut self) -> ServeReport {
        let report = self.runner.shutdown();
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            h.join().expect("accept loop panicked");
        }
        report
    }

    /// Serve until SIGINT/SIGTERM (or
    /// [`shutdown::request_shutdown`]), then drain gracefully — the
    /// `imax-sd serve` main loop.
    pub fn run_until_signalled(self) -> ServeReport {
        shutdown::install_signal_handlers();
        while !shutdown::signalled() {
            std::thread::sleep(Duration::from_millis(50));
        }
        self.shutdown()
    }
}

fn accept_loop(listener: TcpListener, runner: Arc<Runner>, stop: Arc<AtomicBool>) {
    // Handler threads are tracked so the loop can join them on exit —
    // connections are short-lived (one request, `Connection: close`).
    let handlers: Mutex<Vec<std::thread::JoinHandle<()>>> = Mutex::new(Vec::new());
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let runner = Arc::clone(&runner);
                let h = std::thread::spawn(move || handle_connection(stream, &runner));
                let mut live = handlers.lock();
                live.retain(|h| !h.is_finished());
                live.push(h);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => break,
        }
    }
    for h in handlers.into_inner() {
        let _ = h.join();
    }
}

fn handle_connection(stream: TcpStream, runner: &Runner) {
    // Accepted sockets inherit the listener's nonblocking flag on some
    // platforms: force blocking reads with a timeout.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let mut reader = BufReader::new(stream);
    let response = match http::read_request(&mut reader) {
        Ok(req) => routes::handle(runner, &req),
        Err(http::HttpError::Io(_)) => return, // peer vanished; nothing to say
        Err(http::HttpError::Malformed(msg)) => http::Response::text(400, msg),
    };
    let mut stream = reader.into_inner();
    let _ = response.write_to(&mut stream);
}

#[cfg(test)]
mod tests {
    use super::http::http_call;
    use super::*;
    use crate::sd::pipeline::{Backend, PipelineConfig};
    use crate::sd::trace::QuantModel;
    use crate::serve::ServeConfig;

    fn start_server(queue_capacity: usize) -> Server {
        let pipe = PipelineConfig {
            weight_seed: 99,
            model: Some(QuantModel::Q8_0),
            steps: 1,
            backend: Backend::Host { threads: 2 },
            conv_offload: false,
        };
        let serve = ServeConfig {
            lanes: 1,
            host_threads: 2,
            max_batch: 2,
            workers: 1,
            sharded: false,
            queue_capacity,
        };
        Server::start("127.0.0.1:0", ServeHarness::new(pipe, serve), RunnerConfig::default())
            .expect("bind loopback")
    }

    #[test]
    fn http_round_trip_over_loopback() {
        let server = start_server(8);
        let addr = server.addr().to_string();

        let health = http_call(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(health.status, 200);
        assert_eq!(health.json().unwrap().get("status").unwrap().as_str(), Some("ok"));

        let body = Json::obj(vec![
            ("prompt", Json::Str("a lovely cat".into())),
            ("seed", Json::Num(7.0)),
        ]);
        let created = http_call(&addr, "POST", "/predictions", Some(&body)).unwrap();
        assert_eq!(created.status, 202);
        let id = created.json().unwrap().get("id").unwrap().as_u64().unwrap();

        let mut succeeded = false;
        for _ in 0..2000 {
            let poll = http_call(&addr, "GET", &format!("/predictions/{id}"), None).unwrap();
            assert_eq!(poll.status, 200);
            let st = poll.json().unwrap();
            if st.get("status").unwrap().as_str() == Some("succeeded") {
                assert!(st.get("image_crc32").unwrap().as_u64().unwrap() > 0);
                succeeded = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(succeeded, "prediction reached success over HTTP");

        let missing = http_call(&addr, "GET", "/predictions/9999", None).unwrap();
        assert_eq!(missing.status, 404);

        let report = server.shutdown();
        assert_eq!(report.count(crate::serve::RunnerState::Succeeded), 1);
    }

    #[test]
    fn malformed_requests_get_400_and_the_server_survives() {
        use std::io::{Read, Write};
        let server = start_server(8);
        let addr = server.addr();
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        stream.write_all(b"NOT-HTTP\r\n\r\n").unwrap();
        let mut buf = String::new();
        let _ = stream.read_to_string(&mut buf);
        assert!(buf.starts_with("HTTP/1.1 400"), "got: {buf:?}");
        // The server still answers afterwards.
        let health = http_call(&addr.to_string(), "GET", "/healthz", None).unwrap();
        assert_eq!(health.status, 200);
        server.shutdown();
    }
}
