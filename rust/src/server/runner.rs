//! The prediction runner: request registry, admission control, worker
//! pool, and graceful drain — the cog-style lifecycle layer between the
//! HTTP routes and the [`ServeHarness`].
//!
//! Admission uses a queueing estimate: an EWMA of recent micro-batch
//! service times times the number of batch "waves" ahead of a new
//! arrival. When the estimate exceeds the configured SLO the request is
//! refused with a `Retry-After` hint (HTTP 429) instead of building an
//! unbounded backlog — the queue's hard capacity bound is the second,
//! coarser line of defense. Both pieces of arithmetic are pure
//! functions ([`estimate_queue_seconds`], [`admission_decision`])
//! mirrored bit-for-bit by `python/replica/serve_http_replica.py`.

use super::routes;
use super::webhook::{Webhook, WebhookConfig, WebhookSender};
use crate::sd::graph::RequestId;
use crate::serve::{
    PushError, RequestOutcome, RequestQueue, RunnerState, ServeHarness, ServeReport, ServeRequest,
    WebhookStats,
};
use crate::util::cancel::CancelToken;
use crate::util::sync::{lock_or_abort, rank, Mutex};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Seconds a new arrival is predicted to wait before a worker picks it
/// up, from queue occupancy and the smoothed batch service time.
///
/// With `waiting` requests queued and `inflight` running, a new arrival
/// is number `waiting + inflight + 1` in line. The serving stack drains
/// up to `workers * max_batch` requests per batch "wave", each wave
/// taking roughly `ewma_batch_seconds`. An EWMA of zero (no completed
/// batch yet) estimates 0.0 — callers that want cold-start protection
/// substitute a prior via [`effective_batch_seconds`] first.
pub fn estimate_queue_seconds(
    waiting: usize,
    inflight: usize,
    workers: usize,
    max_batch: usize,
    ewma_batch_seconds: f64,
) -> f64 {
    if ewma_batch_seconds <= 0.0 {
        return 0.0;
    }
    let slots = (workers * max_batch).max(1);
    let ahead = waiting + inflight + 1;
    let batches_ahead = ahead.div_ceil(slots);
    batches_ahead as f64 * ewma_batch_seconds
}

/// The batch service time admission should reason with: the EWMA once
/// a batch has completed, and before that a conservative configured
/// prior — **unless the system is idle**, where the first request must
/// always be admitted (an idle cold server shedding its first arrival
/// would never warm up at all).
///
/// This closes the cold-start admission hole: with a zero EWMA the raw
/// estimate was 0.0 regardless of queue depth, so a burst arriving
/// before the first batch completed was admitted unboundedly (and the
/// queue-full `Retry-After` hint was computed from that same zero).
/// Pinned in `cold_start_admission_uses_the_prior` and mirrored by
/// `python/replica/serve_http_replica.py`.
pub fn effective_batch_seconds(
    ewma_batch_seconds: f64,
    cold_start_prior_seconds: f64,
    waiting: usize,
    inflight: usize,
) -> f64 {
    if ewma_batch_seconds > 0.0 {
        ewma_batch_seconds
    } else if waiting + inflight == 0 {
        0.0
    } else {
        cold_start_prior_seconds
    }
}

/// Shed or admit: `None` admits; `Some(retry_after_seconds)` refuses
/// because the estimated wait exceeds the SLO (a non-positive SLO
/// disables shedding). The hint is how long until the backlog should
/// have drained below the SLO, at least 1 second.
pub fn admission_decision(estimated_seconds: f64, slo_seconds: f64) -> Option<u64> {
    if slo_seconds <= 0.0 || estimated_seconds <= slo_seconds {
        None
    } else {
        Some(((estimated_seconds - slo_seconds).ceil() as u64).max(1))
    }
}

/// Runner-level knobs (the execution side comes from
/// [`crate::serve::ServeConfig`]).
#[derive(Debug, Clone)]
pub struct RunnerConfig {
    /// Admission SLO in seconds: refuse new work once the estimated
    /// queue wait exceeds it. `<= 0` disables estimate-based shedding
    /// (the queue capacity bound still applies).
    pub slo_seconds: f64,
    /// Steps when a request does not specify them.
    pub default_steps: usize,
    /// Largest accepted per-request step count.
    pub max_steps: usize,
    /// Batch-seconds prior used while the EWMA has no sample yet and
    /// work is already queued or running (see
    /// [`effective_batch_seconds`]). The load generator derives it from
    /// its probe measurement; the default is a conservative guess.
    pub cold_start_prior_seconds: f64,
    /// Webhook delivery knobs.
    pub webhook: WebhookConfig,
}

impl Default for RunnerConfig {
    fn default() -> Self {
        RunnerConfig {
            slo_seconds: 2.0,
            default_steps: 1,
            max_steps: 8,
            cold_start_prior_seconds: 0.5,
            webhook: WebhookConfig::default(),
        }
    }
}

/// Result of [`Runner::create`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Admission {
    /// Admitted; poll `GET /predictions/{id}`.
    Created {
        /// The new prediction's id.
        id: u64,
    },
    /// Refused under load; retry after the hinted seconds (HTTP 429).
    Busy {
        /// `Retry-After` seconds.
        retry_after: u64,
    },
    /// The server is shutting down and accepts no new work (HTTP 503).
    Draining,
}

/// A poll view of one prediction.
#[derive(Debug, Clone)]
pub struct PredictionStatus {
    /// Prediction id.
    pub id: u64,
    /// Current lifecycle state.
    pub state: RunnerState,
    /// The prompt.
    pub prompt: String,
    /// Terminal outcome, once one exists.
    pub outcome: Option<RequestOutcome>,
}

struct Entry {
    state: RunnerState,
    prompt: String,
    cancel: CancelToken,
    outcome: Option<RequestOutcome>,
    webhook: Option<Webhook>,
}

/// EWMA smoothing factor for batch service seconds.
const EWMA_ALPHA: f64 = 0.3;

/// The serving runner: owns the queue, the registry, and the worker
/// threads. Create once with [`Runner::start`], finish with
/// [`Runner::shutdown`].
pub struct Runner {
    harness: Arc<ServeHarness>,
    queue: Arc<RequestQueue>,
    config: RunnerConfig,
    registry: Mutex<HashMap<u64, Entry>>,
    next_id: AtomicU64,
    inflight: AtomicUsize,
    inflight_peak: AtomicUsize,
    queue_depth_peak: AtomicUsize,
    rejected: AtomicU64,
    /// f64 bits of the smoothed batch service time (0 = no sample yet).
    ewma_bits: AtomicU64,
    draining: AtomicBool,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    webhook: Arc<WebhookSender>,
    t_start: Instant,
    baseline: [u64; 7],
}

impl Runner {
    /// Start the worker pool over a harness. The queue uses the
    /// harness's strict `queue_capacity` bound — unlike offline
    /// [`ServeHarness::serve`], online admission is supposed to fail.
    pub fn start(harness: ServeHarness, config: RunnerConfig) -> Arc<Runner> {
        assert!((1..=config.max_steps).contains(&config.default_steps));
        let harness = Arc::new(harness);
        let queue = Arc::new(RequestQueue::bounded(harness.config.queue_capacity));
        let ord = Ordering::Relaxed;
        let m = &harness.coordinator().metrics;
        let baseline = [
            m.offloaded_macs.load(ord),
            m.imax_cycles.load(ord),
            m.offloaded_jobs.load(ord),
            m.batched_submissions.load(ord),
            m.coalesced_jobs.load(ord),
            m.cache_hit_bytes.load(ord),
            m.cache_miss_bytes.load(ord),
        ];
        let webhook = WebhookSender::start(config.webhook.clone());
        let runner = Arc::new(Runner {
            harness,
            queue,
            config,
            registry: Mutex::ranked(rank::SERVER_REGISTRY, "server.registry", HashMap::new()),
            next_id: AtomicU64::new(1),
            inflight: AtomicUsize::new(0),
            inflight_peak: AtomicUsize::new(0),
            queue_depth_peak: AtomicUsize::new(0),
            rejected: AtomicU64::new(0),
            ewma_bits: AtomicU64::new(0),
            draining: AtomicBool::new(false),
            workers: Mutex::new(Vec::new()),
            webhook,
            t_start: Instant::now(),
            baseline,
        });
        let mut workers = runner.workers.lock();
        for _ in 0..runner.harness.config.workers {
            let rt = Arc::clone(&runner);
            workers.push(std::thread::spawn(move || rt.worker_loop()));
        }
        drop(workers);
        runner
    }

    /// The runner configuration.
    pub fn config(&self) -> &RunnerConfig {
        &self.config
    }

    /// The execution harness.
    pub fn harness(&self) -> &ServeHarness {
        &self.harness
    }

    /// Requests waiting in the queue right now.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Requests currently running in workers.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// The smoothed micro-batch service time (0 before the first batch).
    pub fn ewma_batch_seconds(&self) -> f64 {
        f64::from_bits(self.ewma_bits.load(Ordering::Relaxed))
    }

    /// The batch service time admission reasons with right now: the
    /// EWMA once warm, the configured cold-start prior while cold work
    /// is already in the system.
    fn admission_batch_seconds(&self, waiting: usize, inflight: usize) -> f64 {
        effective_batch_seconds(
            self.ewma_batch_seconds(),
            self.config.cold_start_prior_seconds,
            waiting,
            inflight,
        )
    }

    /// The estimated queue wait a new arrival would see right now.
    pub fn estimated_wait_seconds(&self) -> f64 {
        let (waiting, inflight) = (self.queue.len(), self.inflight());
        estimate_queue_seconds(
            waiting,
            inflight,
            self.harness.config.workers,
            self.harness.config.max_batch,
            self.admission_batch_seconds(waiting, inflight),
        )
    }

    /// Webhook delivery counters so far (final numbers come from the
    /// post-flush [`ServeReport`]).
    pub fn webhook_stats(&self) -> WebhookStats {
        self.webhook.stats()
    }

    /// Webhook deliveries waiting (or backing off) right now.
    pub fn webhook_pending(&self) -> usize {
        self.webhook.pending()
    }

    /// Admit (or refuse) a new prediction. `deadline` bounds the whole
    /// request lifetime — queue wait included; past it the request
    /// expires at its next cancellation check. With a `webhook`, the
    /// full prediction JSON is POSTed to it on the terminal transition
    /// (subject to the webhook's events filter).
    pub fn create(
        &self,
        prompt: &str,
        seed: u64,
        steps: usize,
        deadline: Option<Duration>,
        webhook: Option<Webhook>,
    ) -> Admission {
        assert!(
            (1..=self.config.max_steps).contains(&steps),
            "steps must be in 1..={} (routes validate first)",
            self.config.max_steps
        );
        if self.draining.load(Ordering::Relaxed) {
            return Admission::Draining;
        }
        if let Some(retry_after) =
            admission_decision(self.estimated_wait_seconds(), self.config.slo_seconds)
        {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return Admission::Busy { retry_after };
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let cancel = match deadline {
            Some(d) => CancelToken::with_deadline(Instant::now() + d),
            None => CancelToken::new(),
        };
        let req = ServeRequest::new(RequestId(id), prompt.to_string(), seed, steps)
            .with_cancel(cancel.clone());
        self.registry.lock().insert(
            id,
            Entry {
                state: RunnerState::Queued,
                prompt: prompt.to_string(),
                cancel,
                outcome: None,
                webhook,
            },
        );
        match self.queue.try_push(req) {
            Ok(()) => {
                let depth = self.queue.len();
                self.queue_depth_peak.fetch_max(depth, Ordering::Relaxed);
                Admission::Created { id }
            }
            Err(PushError::Full { .. }) => {
                self.registry.lock().remove(&id);
                self.rejected.fetch_add(1, Ordering::Relaxed);
                // A full queue implies work in the system, so this uses
                // the cold-start prior too — the hint used to come from
                // a possibly-zero EWMA.
                let eff = self.admission_batch_seconds(self.queue.len(), self.inflight());
                let hint = eff.ceil() as u64;
                Admission::Busy { retry_after: hint.max(1) }
            }
            Err(PushError::Closed) => {
                self.registry.lock().remove(&id);
                Admission::Draining
            }
        }
    }

    /// Poll one prediction.
    pub fn status(&self, id: u64) -> Option<PredictionStatus> {
        let reg = self.registry.lock();
        reg.get(&id).map(|e| PredictionStatus {
            id,
            state: e.state,
            prompt: e.prompt.clone(),
            outcome: e.outcome.clone(),
        })
    }

    /// Cancel one prediction. Fires the token (a running request aborts
    /// at its next step boundary and leaves its micro-batch); a request
    /// still queued flips to `Cancelled` immediately. Returns `false`
    /// for unknown ids.
    pub fn cancel(&self, id: u64) -> bool {
        let mut reg = self.registry.lock();
        let Some(e) = reg.get_mut(&id) else {
            return false;
        };
        e.cancel.cancel();
        if e.state == RunnerState::Queued {
            e.state = RunnerState::Cancelled;
        }
        true
    }

    /// Graceful shutdown: stop admitting, drain every queued and
    /// running request, join the workers, quiesce the lane worker
    /// pool, then **flush the webhook delivery queue** (terminal
    /// states produced during the drain are delivered — retries and
    /// backoff included — before this returns, bounded by
    /// [`WebhookConfig::drain_deadline_ms`]). Returns the aggregate
    /// report over the runner's lifetime. Drain-path locks abort on
    /// poisoning instead of cascading a second panic into a hung
    /// shutdown (policy in [`crate::util::sync`] and `DESIGN.md`).
    pub fn shutdown(&self) -> ServeReport {
        self.draining.store(true, Ordering::Relaxed);
        self.queue.close();
        let handles: Vec<_> = lock_or_abort(&self.workers).drain(..).collect();
        for h in handles {
            h.join().expect("serving worker panicked");
        }
        self.harness.coordinator().quiesce();
        // After the worker join every terminal transition has been
        // enqueued; the flush empties the queue or dead-letters at the
        // deadline.
        self.webhook.flush_and_join(Duration::from_millis(self.config.webhook.drain_deadline_ms));
        self.report()
    }

    fn report(&self) -> ServeReport {
        let ord = Ordering::Relaxed;
        let reg = lock_or_abort(&self.registry);
        let mut outcomes: Vec<RequestOutcome> =
            reg.values().filter_map(|e| e.outcome.clone()).collect();
        drop(reg);
        outcomes.sort_by_key(|o| o.id);
        let total_macs = outcomes.iter().map(|o| o.macs).sum();
        let m = &self.harness.coordinator().metrics;
        ServeReport {
            outcomes,
            wall_seconds: self.t_start.elapsed().as_secs_f64(),
            total_macs,
            offloaded_macs: m.offloaded_macs.load(ord) - self.baseline[0],
            imax_cycles: m.imax_cycles.load(ord) - self.baseline[1],
            lane_submissions: m.offloaded_jobs.load(ord) - self.baseline[2],
            batched_submissions: m.batched_submissions.load(ord) - self.baseline[3],
            coalesced_jobs: m.coalesced_jobs.load(ord) - self.baseline[4],
            cache_hit_bytes: m.cache_hit_bytes.load(ord) - self.baseline[5],
            cache_miss_bytes: m.cache_miss_bytes.load(ord) - self.baseline[6],
            rejected: self.rejected.load(ord),
            queue_depth_peak: self.queue_depth_peak.load(ord),
            inflight_peak: self.inflight_peak.load(ord),
            webhook: self.webhook.stats(),
        }
    }

    fn worker_loop(&self) {
        loop {
            let batch = self.queue.pop_batch(self.harness.config.max_batch);
            if batch.is_empty() {
                return; // closed + drained
            }
            let n = batch.len();
            let now = self.inflight.fetch_add(n, Ordering::Relaxed) + n;
            self.inflight_peak.fetch_max(now, Ordering::Relaxed);
            {
                let mut reg = self.registry.lock();
                for req in &batch {
                    if let Some(e) = reg.get_mut(&req.id.0) {
                        // Don't resurrect entries a cancel already
                        // flipped to a terminal state.
                        if e.state == RunnerState::Queued {
                            e.state = RunnerState::Running;
                        }
                    }
                }
            }
            let t0 = Instant::now();
            let outcomes = self.harness.run_batch(&batch);
            self.observe_batch_seconds(t0.elapsed().as_secs_f64());
            self.inflight.fetch_sub(n, Ordering::Relaxed);
            // Every admitted request reaches its terminal state exactly
            // once, right here (cancelled-while-queued entries drain
            // through `run_batch` too), so this is the single webhook
            // enqueue point. Payloads are rendered under the registry
            // lock; the enqueue itself happens after dropping it — the
            // delivery queue's lock is never nested inside the
            // registry's.
            let mut deliveries: Vec<(u64, Webhook, super::json::Json)> = Vec::new();
            let mut reg = self.registry.lock();
            for outcome in outcomes {
                let id = outcome.id.0;
                if let Some(e) = reg.get_mut(&id) {
                    e.state = outcome.state;
                    e.outcome = Some(outcome);
                    if let Some(wh) = &e.webhook {
                        if wh.wants(e.state) {
                            let body = routes::status_json(&PredictionStatus {
                                id,
                                state: e.state,
                                prompt: e.prompt.clone(),
                                outcome: e.outcome.clone(),
                            });
                            deliveries.push((id, wh.clone(), body));
                        }
                    }
                }
            }
            drop(reg);
            let terminal_at = Instant::now();
            for (id, wh, body) in deliveries {
                self.webhook.enqueue(id, &wh, body, terminal_at);
            }
        }
    }

    fn observe_batch_seconds(&self, seconds: f64) {
        // Racy read-modify-write is fine: the EWMA is an admission
        // heuristic, and concurrent batches just pick either sample.
        let old = self.ewma_batch_seconds();
        let new =
            if old == 0.0 { seconds } else { EWMA_ALPHA * seconds + (1.0 - EWMA_ALPHA) * old };
        self.ewma_bits.store(new.to_bits(), Ordering::Relaxed);
    }

    /// Test hook: pretend batches have been taking `seconds`.
    #[cfg(test)]
    fn force_ewma(&self, seconds: f64) {
        self.ewma_bits.store(seconds.to_bits(), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sd::pipeline::{Backend, PipelineConfig};
    use crate::sd::trace::QuantModel;
    use crate::serve::ServeConfig;

    fn pipe_cfg() -> PipelineConfig {
        PipelineConfig {
            weight_seed: 99,
            model: Some(QuantModel::Q8_0),
            steps: 1,
            backend: Backend::Host { threads: 2 },
            conv_offload: false,
        }
    }

    fn serve_cfg() -> ServeConfig {
        ServeConfig {
            lanes: 1,
            host_threads: 2,
            max_batch: 2,
            workers: 1,
            sharded: false,
            queue_capacity: 8,
        }
    }

    fn wait_terminal(rt: &Runner, id: u64) -> PredictionStatus {
        for _ in 0..2000 {
            let st = rt.status(id).expect("known id");
            if st.state.terminal() {
                return st;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        panic!("prediction {id} never reached a terminal state");
    }

    #[test]
    fn queue_estimate_arithmetic() {
        assert_eq!(estimate_queue_seconds(0, 0, 2, 4, 0.0), 0.0, "no signal, no estimate");
        assert_eq!(estimate_queue_seconds(7, 4, 2, 4, 0.5), 1.0, "12 ahead / 8 slots = 2 waves");
        assert_eq!(estimate_queue_seconds(0, 1, 1, 1, 2.0), 4.0, "second in line, serial");
        assert_eq!(estimate_queue_seconds(0, 0, 0, 0, 1.0), 1.0, "slots clamp to 1");
    }

    #[test]
    fn cold_start_admission_uses_the_prior() {
        // Regression for the cold-start admission hole: before this
        // fix, a zero EWMA made the estimate 0.0 regardless of queue
        // depth, so a pre-first-batch burst was admitted unboundedly.
        // These vectors are mirrored by serve_http_replica.py.
        assert_eq!(effective_batch_seconds(0.0, 0.5, 0, 0), 0.0, "idle cold system admits freely");
        assert_eq!(effective_batch_seconds(0.0, 0.5, 3, 1), 0.5, "cold + backlog uses the prior");
        assert_eq!(effective_batch_seconds(0.0, 0.5, 0, 1), 0.5, "inflight alone counts too");
        assert_eq!(effective_batch_seconds(0.7, 0.5, 3, 1), 0.7, "a warm EWMA wins");
        assert_eq!(effective_batch_seconds(0.7, 0.5, 0, 0), 0.7, "warm EWMA used even when idle");
        // End-to-end arithmetic: 10 waiting + 2 inflight on 1 worker x
        // 2 max_batch = 7 waves ahead; at the 0.5s prior that is 3.5s
        // against a 2s SLO => shed with a 2s hint. The un-fixed code
        // said 0.0 => admit.
        let est = estimate_queue_seconds(10, 2, 1, 2, effective_batch_seconds(0.0, 0.5, 10, 2));
        assert_eq!(est, 3.5);
        assert_eq!(admission_decision(est, 2.0), Some(2));
        assert_eq!(
            estimate_queue_seconds(10, 2, 1, 2, 0.0),
            0.0,
            "the raw estimator alone still has no signal — the prior is the fix"
        );
    }

    #[test]
    fn admission_decision_thresholds() {
        assert_eq!(admission_decision(1.0, 2.0), None);
        assert_eq!(admission_decision(2.0, 2.0), None, "at the SLO still admits");
        assert_eq!(admission_decision(2.5, 2.0), Some(1));
        assert_eq!(admission_decision(9.5, 2.0), Some(8));
        assert_eq!(admission_decision(5.0, 0.0), None, "SLO 0 disables shedding");
        assert_eq!(admission_decision(2.0001, 2.0), Some(1), "hint is at least 1s");
    }

    #[test]
    fn lifecycle_create_poll_succeed_shutdown() {
        let rt = Runner::start(
            ServeHarness::new(pipe_cfg(), serve_cfg()),
            RunnerConfig::default(),
        );
        let Admission::Created { id } = rt.create("a lovely cat", 7, 1, None, None) else {
            panic!("idle runner must admit");
        };
        let st = wait_terminal(&rt, id);
        assert_eq!(st.state, RunnerState::Succeeded);
        let outcome = st.outcome.expect("terminal => outcome");
        assert!(outcome.image_crc32 != 0);
        assert_eq!(outcome.steps_completed, 1);
        assert!(rt.status(999).is_none(), "unknown id");
        let report = rt.shutdown();
        assert_eq!(report.requests(), 1);
        assert_eq!(report.count(RunnerState::Succeeded), 1);
        assert_eq!(report.rejected, 0);
    }

    #[test]
    fn estimate_based_shedding_returns_busy_with_retry_hint() {
        let rt = Runner::start(
            ServeHarness::new(pipe_cfg(), serve_cfg()),
            RunnerConfig { slo_seconds: 2.0, ..RunnerConfig::default() },
        );
        // Pretend batches take 10s: the next arrival would wait ~10s
        // >> 2s SLO, so admission must shed with a drain hint.
        rt.force_ewma(10.0);
        match rt.create("too much", 1, 1, None, None) {
            Admission::Busy { retry_after } => assert_eq!(retry_after, 8),
            other => panic!("expected Busy, got {other:?}"),
        }
        let report = rt.shutdown();
        assert_eq!(report.rejected, 1);
        assert_eq!(report.requests(), 0);
    }

    #[test]
    fn cancel_of_a_queued_request_is_immediate_and_sticky() {
        let rt = Runner::start(
            ServeHarness::new(pipe_cfg(), serve_cfg()),
            RunnerConfig::default(),
        );
        // Saturate the single worker so a later request sits queued.
        let mut ids = Vec::new();
        for i in 0..4 {
            if let Admission::Created { id } = rt.create("a lovely cat", i, 1, None, None) {
                ids.push(id);
            }
        }
        let last = *ids.last().expect("capacity 8 admits 4");
        assert!(rt.cancel(last), "known id cancels");
        assert!(!rt.cancel(999), "unknown id does not");
        let st = wait_terminal(&rt, last);
        assert_eq!(st.state, RunnerState::Cancelled);
        let report = rt.shutdown();
        // The cancelled request either never ran (no outcome recorded
        // until its batch drained it) or aborted before its first step.
        let cancelled: Vec<_> = report
            .outcomes
            .iter()
            .filter(|o| o.state == RunnerState::Cancelled)
            .collect();
        for o in &cancelled {
            assert_eq!(o.steps_completed, 0);
            assert_eq!(o.image_crc32, 0);
        }
        assert_eq!(report.count(RunnerState::Succeeded) + cancelled.len(), ids.len());
    }

    #[test]
    fn draining_runner_refuses_new_work() {
        let rt = Runner::start(
            ServeHarness::new(pipe_cfg(), serve_cfg()),
            RunnerConfig::default(),
        );
        for i in 0..3 {
            let adm = rt.create("a lovely cat", i, 1, None, None);
            assert!(matches!(adm, Admission::Created { .. }));
        }
        let report = rt.shutdown();
        assert_eq!(report.requests(), 3, "graceful shutdown drains everything in flight");
        assert_eq!(report.count(RunnerState::Succeeded), 3);
        assert_eq!(rt.create("late", 9, 1, None, None), Admission::Draining);
        assert!(report.inflight_peak >= 1);
    }

    #[test]
    fn deadline_expired_request_reports_expired() {
        let rt = Runner::start(
            ServeHarness::new(pipe_cfg(), serve_cfg()),
            RunnerConfig::default(),
        );
        let Admission::Created { id } =
            rt.create("a lovely cat", 7, 1, Some(Duration::from_secs(0)), None)
        else {
            panic!("idle runner must admit");
        };
        let st = wait_terminal(&rt, id);
        assert_eq!(st.state, RunnerState::Expired);
        let outcome = st.outcome.expect("expired requests still report an outcome");
        assert_eq!(outcome.steps_completed, 0);
        rt.shutdown();
    }
}
