//! HTTP route table: requests in, lifecycle verbs out.
//!
//! The cog-style prediction API:
//!
//! | Route                          | Meaning                              |
//! |--------------------------------|--------------------------------------|
//! | `GET  /healthz`                | liveness + queue/inflight gauges     |
//! | `POST /predictions`            | create → `202 {"id": N}` / `429` / `503` |
//! | `GET  /predictions/{id}`       | poll state, metrics, image CRC       |
//! | `POST /predictions/{id}/cancel`| fire the request's cancel token      |
//!
//! Create bodies are JSON: `{"prompt": "...", "seed": 7, "steps": 1,
//! "deadline_ms": 5000, "webhook": "http://host:port/path",
//! "webhook_events_filter": ["succeeded", "failed"]}` — everything but
//! `prompt` optional. When `webhook` is set, the full prediction JSON
//! (the same shape `GET /predictions/{id}` returns) is POSTed to the
//! URL on every matching terminal transition, with retry/backoff
//! ([`super::webhook`]).

use super::http::{Request, Response};
use super::json::Json;
use super::runner::{Admission, PredictionStatus, Runner};
use super::webhook::Webhook;
use crate::serve::RunnerState;
use std::time::Duration;

/// Dispatch one parsed request against the runner.
pub fn handle(runner: &Runner, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => healthz(runner),
        ("POST", "/predictions") => create(runner, req),
        (method, path) => {
            if let Some(rest) = path.strip_prefix("/predictions/") {
                return prediction_route(runner, method, rest);
            }
            not_found()
        }
    }
}

fn prediction_route(runner: &Runner, method: &str, rest: &str) -> Response {
    if let Some(id_text) = rest.strip_suffix("/cancel") {
        return match (method, id_text.parse::<u64>()) {
            ("POST", Ok(id)) => cancel(runner, id),
            (_, Ok(_)) => method_not_allowed(),
            (_, Err(_)) => not_found(),
        };
    }
    match (method, rest.parse::<u64>()) {
        ("GET", Ok(id)) => status(runner, id),
        (_, Ok(_)) => method_not_allowed(),
        (_, Err(_)) => not_found(),
    }
}

fn healthz(runner: &Runner) -> Response {
    Response::json(
        200,
        &Json::obj(vec![
            ("status", Json::Str("ok".into())),
            ("queue_depth", Json::Num(runner.queue_depth() as f64)),
            ("inflight", Json::Num(runner.inflight() as f64)),
            ("ewma_batch_seconds", Json::Num(runner.ewma_batch_seconds())),
            ("estimated_wait_seconds", Json::Num(runner.estimated_wait_seconds())),
            ("webhook_pending", Json::Num(runner.webhook_pending() as f64)),
        ]),
    )
}

fn create(runner: &Runner, req: &Request) -> Response {
    let Ok(text) = std::str::from_utf8(&req.body) else {
        return bad_request("body is not utf-8");
    };
    let Ok(body) = Json::parse(text) else {
        return bad_request("body is not json");
    };
    let Some(prompt) = body.get("prompt").and_then(Json::as_str) else {
        return bad_request("missing required field: prompt");
    };
    let seed = match body.get("seed") {
        None => 0,
        Some(v) => match v.as_u64() {
            Some(s) => s,
            None => return bad_request("seed must be a non-negative integer"),
        },
    };
    let steps = match body.get("steps") {
        None => runner.config().default_steps,
        Some(v) => match v.as_u64() {
            Some(s) if (1..=runner.config().max_steps as u64).contains(&s) => s as usize,
            _ => return bad_request("steps out of range"),
        },
    };
    let deadline = match body.get("deadline_ms") {
        None => None,
        Some(v) => match v.as_u64() {
            Some(ms) => Some(Duration::from_millis(ms)),
            None => return bad_request("deadline_ms must be a non-negative integer"),
        },
    };
    let webhook = match body.get("webhook") {
        None => None,
        Some(v) => {
            let Some(url) = v.as_str() else {
                return bad_request("webhook must be a string URL");
            };
            let mut wh = match Webhook::parse(url) {
                Ok(wh) => wh,
                Err(msg) => return bad_request(msg),
            };
            match parse_events_filter(&body) {
                Ok(None) => {}
                Ok(Some(events)) => wh = wh.with_events(events),
                Err(msg) => return bad_request(msg),
            }
            Some(wh)
        }
    };
    if webhook.is_none() && body.get("webhook_events_filter").is_some() {
        return bad_request("webhook_events_filter requires webhook");
    }
    match runner.create(prompt, seed, steps, deadline, webhook) {
        Admission::Created { id } => Response::json(
            202,
            &Json::obj(vec![
                ("id", Json::Num(id as f64)),
                ("status", Json::Str(RunnerState::Queued.name().into())),
            ]),
        ),
        Admission::Busy { retry_after } => Response::json(
            429,
            &Json::obj(vec![
                ("error", Json::Str("queue latency above SLO".into())),
                ("retry_after_seconds", Json::Num(retry_after as f64)),
            ]),
        )
        .with_header("Retry-After", &retry_after.to_string()),
        Admission::Draining => Response::json(
            503,
            &Json::obj(vec![("error", Json::Str("server is draining".into()))]),
        ),
    }
}

/// Parse the optional `webhook_events_filter` array: terminal state
/// names only (`succeeded`, `failed`, `cancelled`, `expired`).
fn parse_events_filter(body: &Json) -> Result<Option<Vec<RunnerState>>, &'static str> {
    let Some(v) = body.get("webhook_events_filter") else {
        return Ok(None);
    };
    let Some(items) = v.as_arr() else {
        return Err("webhook_events_filter must be an array of state names");
    };
    if items.is_empty() {
        return Err("webhook_events_filter must not be empty");
    }
    let mut events = Vec::with_capacity(items.len());
    for item in items {
        let state = match item.as_str() {
            Some("succeeded") => RunnerState::Succeeded,
            Some("failed") => RunnerState::Failed,
            Some("cancelled") => RunnerState::Cancelled,
            Some("expired") => RunnerState::Expired,
            _ => return Err("webhook_events_filter entries must be terminal state names"),
        };
        events.push(state);
    }
    Ok(Some(events))
}

fn status(runner: &Runner, id: u64) -> Response {
    let Some(st) = runner.status(id) else {
        return not_found();
    };
    Response::json(200, &status_json(&st))
}

/// The poll-response body for one prediction.
pub fn status_json(st: &PredictionStatus) -> Json {
    let mut fields = vec![
        ("id", Json::Num(st.id as f64)),
        ("status", Json::Str(st.state.name().into())),
        ("prompt", Json::Str(st.prompt.clone())),
    ];
    if let Some(o) = &st.outcome {
        fields.push((
            "metrics",
            Json::obj(vec![
                ("latency_seconds", Json::Num(o.latency_seconds)),
                ("queue_seconds", Json::Num(o.queue_seconds)),
                ("steps_completed", Json::Num(o.steps_completed as f64)),
                ("matmul_calls", Json::Num(o.matmul_calls as f64)),
                ("macs", Json::Num(o.macs as f64)),
            ]),
        ));
        if o.state == RunnerState::Succeeded {
            fields.push(("image_crc32", Json::Num(o.image_crc32 as f64)));
        }
    }
    Json::obj(fields)
}

fn cancel(runner: &Runner, id: u64) -> Response {
    if runner.cancel(id) {
        Response::json(200, &Json::obj(vec![("id", Json::Num(id as f64))]))
    } else {
        not_found()
    }
}

fn bad_request(msg: &str) -> Response {
    Response::json(400, &Json::obj(vec![("error", Json::Str(msg.into()))]))
}

fn not_found() -> Response {
    Response::json(404, &Json::obj(vec![("error", Json::Str("not found".into()))]))
}

fn method_not_allowed() -> Response {
    Response::json(405, &Json::obj(vec![("error", Json::Str("method not allowed".into()))]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sd::pipeline::{Backend, PipelineConfig};
    use crate::sd::trace::QuantModel;
    use crate::serve::{ServeConfig, ServeHarness};
    use crate::server::runner::RunnerConfig;
    use std::sync::Arc;

    fn runner() -> Arc<Runner> {
        let pipe = PipelineConfig {
            weight_seed: 99,
            model: Some(QuantModel::Q8_0),
            steps: 1,
            backend: Backend::Host { threads: 2 },
            conv_offload: false,
        };
        let serve = ServeConfig {
            lanes: 1,
            host_threads: 2,
            max_batch: 2,
            workers: 1,
            sharded: false,
            queue_capacity: 8,
        };
        Runner::start(ServeHarness::new(pipe, serve), RunnerConfig::default())
    }

    fn req(method: &str, path: &str, body: Option<&str>) -> Request {
        Request {
            method: method.into(),
            path: path.into(),
            headers: Vec::new(),
            body: body.map(|b| b.as_bytes().to_vec()).unwrap_or_default(),
        }
    }

    #[test]
    fn full_route_round_trip() {
        let rt = runner();
        let r = handle(&rt, &req("GET", "/healthz", None));
        assert_eq!(r.status, 200);
        let health = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(health.get("status").unwrap().as_str(), Some("ok"));

        let r = handle(
            &rt,
            &req("POST", "/predictions", Some(r#"{"prompt": "a lovely cat", "seed": 7}"#)),
        );
        assert_eq!(r.status, 202);
        let created = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        let id = created.get("id").unwrap().as_u64().unwrap();

        // Poll to terminal.
        let mut last = Json::Null;
        for _ in 0..2000 {
            let r = handle(&rt, &req("GET", &format!("/predictions/{id}"), None));
            assert_eq!(r.status, 200);
            last = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
            if last.get("status").unwrap().as_str() == Some("succeeded") {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(last.get("status").unwrap().as_str(), Some("succeeded"));
        assert!(last.get("image_crc32").unwrap().as_u64().unwrap() > 0);
        let metrics = last.get("metrics").unwrap();
        assert_eq!(metrics.get("steps_completed").unwrap().as_u64(), Some(1));
        rt.shutdown();
    }

    #[test]
    fn create_validation_errors() {
        let rt = runner();
        for (body, why) in [
            ("", "empty body"),
            ("{}", "missing prompt"),
            (r#"{"prompt": 3}"#, "non-string prompt"),
            (r#"{"prompt": "x", "seed": -1}"#, "negative seed"),
            (r#"{"prompt": "x", "steps": 0}"#, "steps too small"),
            (r#"{"prompt": "x", "steps": 99}"#, "steps too large"),
            (r#"{"prompt": "x", "deadline_ms": "soon"}"#, "non-numeric deadline"),
            (r#"{"prompt": "x", "webhook": 7}"#, "non-string webhook"),
            (r#"{"prompt": "x", "webhook": "https://a/b"}"#, "https unsupported"),
            (r#"{"prompt": "x", "webhook": "http://"}"#, "empty host"),
            (
                r#"{"prompt": "x", "webhook": "http://h/p", "webhook_events_filter": "succeeded"}"#,
                "filter not an array",
            ),
            (
                r#"{"prompt": "x", "webhook": "http://h/p", "webhook_events_filter": []}"#,
                "empty filter",
            ),
            (
                r#"{"prompt": "x", "webhook": "http://h/p", "webhook_events_filter": ["queued"]}"#,
                "non-terminal state in filter",
            ),
            (
                r#"{"prompt": "x", "webhook_events_filter": ["succeeded"]}"#,
                "filter without webhook",
            ),
        ] {
            let r = handle(&rt, &req("POST", "/predictions", Some(body)));
            assert_eq!(r.status, 400, "{why}");
        }
        rt.shutdown();
    }

    #[test]
    fn webhook_create_is_admitted_and_filter_gates_enqueue() {
        let rt = runner();
        // A webhook that only fires on `cancelled` never matches this
        // succeeding prediction, so nothing is enqueued and shutdown's
        // webhook flush is a no-op — the route-level contract (202 +
        // filter plumbed through) is still fully exercised.
        let body = r#"{"prompt": "x", "webhook": "http://127.0.0.1:9/hook",
                       "webhook_events_filter": ["cancelled", "expired"]}"#;
        let r = handle(&rt, &req("POST", "/predictions", Some(body)));
        assert_eq!(r.status, 202);
        let report = rt.shutdown();
        assert_eq!(report.webhook.enqueued, 0, "filter suppressed the delivery");
        assert_eq!(report.webhook.dead_lettered, 0);
    }

    #[test]
    fn unknown_routes_and_methods() {
        let rt = runner();
        assert_eq!(handle(&rt, &req("GET", "/nope", None)).status, 404);
        assert_eq!(handle(&rt, &req("GET", "/predictions/42", None)).status, 404, "unknown id");
        assert_eq!(handle(&rt, &req("DELETE", "/predictions/42", None)).status, 405);
        assert_eq!(handle(&rt, &req("GET", "/predictions/abc", None)).status, 404);
        assert_eq!(
            handle(&rt, &req("POST", "/predictions/99/cancel", None)).status,
            404,
            "cancel of unknown id"
        );
        assert_eq!(handle(&rt, &req("GET", "/predictions/1/cancel", None)).status, 405);
        rt.shutdown();
    }

    #[test]
    fn cancel_route_fires_the_token() {
        let rt = runner();
        let r = handle(&rt, &req("POST", "/predictions", Some(r#"{"prompt": "x"}"#)));
        let id = Json::parse(std::str::from_utf8(&r.body).unwrap())
            .unwrap()
            .get("id")
            .unwrap()
            .as_u64()
            .unwrap();
        let r = handle(&rt, &req("POST", &format!("/predictions/{id}/cancel"), None));
        assert_eq!(r.status, 200);
        rt.shutdown();
        // Whether the request was still queued or already running, the
        // terminal state is cancelled-or-succeeded, never stuck.
        let st = rt.status(id).unwrap();
        assert!(st.state.terminal());
    }
}
