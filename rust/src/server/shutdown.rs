//! Process-level shutdown signaling (SIGINT / SIGTERM → a flag).
//!
//! The vendored crate set has no `libc` or `signal-hook`, but std
//! itself links the platform libc, so on unix the raw `signal(2)`
//! symbol is declared directly and pointed at a handler that only sets
//! an `AtomicBool` (the one async-signal-safe thing a handler may do).
//! The accept loop polls [`signalled`] and begins a graceful drain when
//! it flips: admission stops, queued and running requests finish, the
//! webhook delivery queue is flushed (bounded by
//! [`super::webhook::WebhookConfig::drain_deadline_ms`]), and only
//! then does the accept loop stop. On non-unix targets installation is
//! a no-op — tests and the in-process [`request_shutdown`] path still
//! work.

use std::sync::atomic::{AtomicBool, Ordering};

static SIGNALLED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod unix {
    pub const SIGINT: i32 = 2;
    pub const SIGTERM: i32 = 15;

    extern "C" {
        // `signal(2)` from the libc std already links. Takes and
        // returns a handler as a bare address (usize keeps the FFI
        // surface minimal; SIG_ERR is usize::MAX).
        pub fn signal(signum: i32, handler: usize) -> usize;
    }

    pub extern "C" fn on_signal(_signum: i32) {
        // Async-signal-safe: a single relaxed store, nothing else.
        super::SIGNALLED.store(true, std::sync::atomic::Ordering::Relaxed);
    }
}

/// Route SIGINT (ctrl-c) and SIGTERM (orchestrator stop) to the
/// shutdown flag. Idempotent; a no-op off unix.
pub fn install_signal_handlers() {
    #[cfg(unix)]
    // SAFETY: `signal` is the libc prototype; the handler address is a
    // valid `extern "C" fn(i32)` for the life of the process, and the
    // handler body is async-signal-safe (one atomic store).
    unsafe {
        unix::signal(unix::SIGINT, unix::on_signal as usize);
        unix::signal(unix::SIGTERM, unix::on_signal as usize);
    }
}

/// True once a shutdown signal (or [`request_shutdown`]) fired.
pub fn signalled() -> bool {
    SIGNALLED.load(Ordering::Relaxed)
}

/// Trip the shutdown flag from code — the in-process equivalent of
/// SIGTERM, used by tests and the load generator's `--smoke` teardown.
pub fn request_shutdown() {
    SIGNALLED.store(true, Ordering::Relaxed);
}

/// Clear the flag (test isolation; the flag is process-global).
pub fn reset() {
    SIGNALLED.store(false, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    // The flag is process-global and tests run concurrently: serialize
    // the tests that mutate it.
    static FLAG_LOCK: crate::util::sync::Mutex<()> = crate::util::sync::Mutex::new(());

    #[test]
    fn in_process_request_trips_and_resets() {
        let _guard = FLAG_LOCK.lock();
        reset();
        assert!(!signalled());
        request_shutdown();
        assert!(signalled());
        reset();
        assert!(!signalled());
    }

    #[cfg(unix)]
    #[test]
    fn a_real_signal_trips_the_flag() {
        extern "C" {
            fn raise(signum: i32) -> i32;
        }
        let _guard = FLAG_LOCK.lock();
        install_signal_handlers();
        reset();
        // SAFETY: raising a signal whose handler we just installed; the
        // handler only stores to an atomic.
        unsafe {
            raise(unix::SIGTERM);
        }
        // Delivery is synchronous for raise(): the handler ran on this
        // thread before raise returned.
        assert!(signalled());
        reset();
    }
}
