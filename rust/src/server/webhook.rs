//! Webhook completion delivery: POST the terminal prediction JSON to a
//! client-supplied callback URL, with retries, so clients are not
//! forced to poll `GET /predictions/{id}`.
//!
//! ```text
//! Runner worker (terminal outcome recorded, registry lock dropped)
//!        │ enqueue(id, payload)          bounded queue (overflow ⇒ dead-letter)
//!        ▼
//! WebhookSender ── pending: VecDeque<Delivery> ──┐
//!        ▲                                       │ earliest-ready pick
//!        │ requeue with backoff                  ▼
//!        └──────── attempt failed ◀── delivery worker ──▶ POST (connect/read
//!                  (until budget)                          timeouts) ──▶ 2xx ✓
//! ```
//!
//! **Delivery guarantees.** At-least-once per matching terminal
//! transition, up to [`WebhookConfig::max_attempts`] HTTP attempts; a
//! delivery that exhausts its budget (or overflows the bounded queue,
//! or is still pending when the shutdown drain deadline passes) is
//! counted in the dead-letter counter and dropped. There is **no
//! ordering guarantee across predictions** — deliveries retry
//! independently, so a fast success can overtake a backing-off peer.
//! A 2xx response acknowledges; anything else (connect refusal, read
//! timeout, 5xx, torn connection) costs one attempt.
//!
//! **Backoff.** Deterministic full-jitter-style schedule, mirrored
//! bit-for-bit by `python/replica/serve_http_replica.py`: retry *k*
//! (1-based) waits `half + SplitMix64(seed ⊕ id·φ ⊕ k) % half` ms where
//! `half = min(base·2^(k−1), cap) / 2` — i.e. uniform in `[half, 2·half)`,
//! seeded per `(jitter_seed, prediction_id, attempt)` so the exact
//! schedule is pinned in tests ([`backoff_delay_ms`]).
//!
//! **Drain ordering.** `Runner::shutdown` drains the serving workers
//! first (every admitted request reaches a terminal outcome and is
//! enqueued here), then calls [`WebhookSender::flush_and_join`] with
//! the drain deadline, and only then does the server stop its accept
//! loop — so terminal states produced *during* the drain are still
//! delivered before `shutdown()` returns.

use super::http;
use super::json::Json;
use crate::serve::{RunnerState, WebhookStats};
use crate::util::rng::SplitMix64;
use crate::util::sync::{lock_or_abort, rank, Condvar, Mutex};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Knobs for the delivery subsystem (part of
/// [`crate::server::RunnerConfig`]).
#[derive(Debug, Clone)]
pub struct WebhookConfig {
    /// Delivery worker threads draining the queue.
    pub workers: usize,
    /// Bounded queue capacity; an enqueue past it dead-letters the
    /// delivery immediately (counted in `overflowed` too).
    pub queue_capacity: usize,
    /// Total HTTP attempts per delivery (first try + retries).
    pub max_attempts: u32,
    /// Base of the exponential backoff, in milliseconds.
    pub base_backoff_ms: u64,
    /// Cap on the un-jittered exponential term, in milliseconds.
    pub max_backoff_ms: u64,
    /// Seed for the deterministic jitter (see [`backoff_delay_ms`]).
    pub jitter_seed: u64,
    /// Per-attempt TCP connect timeout, in milliseconds.
    pub connect_timeout_ms: u64,
    /// Per-attempt socket read/write timeout, in milliseconds.
    pub read_timeout_ms: u64,
    /// How long [`WebhookSender::flush_and_join`] keeps delivering
    /// after shutdown starts before dead-lettering the remainder.
    pub drain_deadline_ms: u64,
}

impl Default for WebhookConfig {
    fn default() -> Self {
        WebhookConfig {
            workers: 1,
            queue_capacity: 256,
            max_attempts: 5,
            base_backoff_ms: 50,
            max_backoff_ms: 2000,
            jitter_seed: 0xC0FFEE,
            connect_timeout_ms: 1000,
            read_timeout_ms: 2000,
            drain_deadline_ms: 10_000,
        }
    }
}

/// Delay in milliseconds before retry number `attempt` (1-based: the
/// wait after the `attempt`-th failed POST) of `prediction_id`'s
/// delivery. Pure and deterministic — the Python replica mirrors it
/// bit-for-bit, and the fault-injection tests assert the exact
/// schedule.
///
/// The un-jittered term doubles from `base_ms` and saturates at
/// `cap_ms`; the jittered delay is uniform in `[half, 2·half)` with
/// `half = term/2`, drawn from a [`SplitMix64`] seeded per
/// `(seed, prediction_id, attempt)` so concurrent deliveries decorrelate
/// without shared RNG state.
pub fn backoff_delay_ms(
    base_ms: u64,
    cap_ms: u64,
    attempt: u32,
    seed: u64,
    prediction_id: u64,
) -> u64 {
    assert!(attempt >= 1, "attempt is 1-based");
    let term = base_ms.saturating_mul(1u64 << (attempt - 1).min(16)).min(cap_ms);
    let half = (term / 2).max(1);
    let mut sm =
        SplitMix64::new(seed ^ prediction_id.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ attempt as u64);
    half + sm.next_u64() % half
}

/// The full retry schedule (ms per retry, in order) a delivery would
/// follow under `cfg` — what tests and the load generator pin against.
pub fn backoff_schedule(cfg: &WebhookConfig, prediction_id: u64, retries: u32) -> Vec<u64> {
    (1..=retries)
        .map(|a| {
            let (base, cap) = (cfg.base_backoff_ms, cfg.max_backoff_ms);
            backoff_delay_ms(base, cap, a, cfg.jitter_seed, prediction_id)
        })
        .collect()
}

/// A validated webhook target: an absolute `http://host[:port][/path]`
/// URL plus an optional terminal-state filter.
#[derive(Debug, Clone, PartialEq)]
pub struct Webhook {
    /// The URL as the client supplied it.
    pub url: String,
    /// `host:port` to connect to (port defaults to 80).
    addr: String,
    /// Path to POST to (`/` when the URL has none).
    path: String,
    /// Terminal states to deliver; `None` delivers every terminal
    /// transition.
    events: Option<Vec<RunnerState>>,
}

impl Webhook {
    /// Parse and validate a webhook URL. Only absolute `http://` URLs
    /// are accepted — the zero-dep client speaks plaintext HTTP/1.1;
    /// anything else is a create-time `400`, not a delivery-time
    /// surprise.
    pub fn parse(url: &str) -> Result<Webhook, &'static str> {
        let rest = url.strip_prefix("http://").ok_or("webhook must be an absolute http:// url")?;
        let (authority, path) = match rest.find('/') {
            Some(i) => (&rest[..i], &rest[i..]),
            None => (rest, "/"),
        };
        if authority.is_empty() {
            return Err("webhook url has no host");
        }
        if authority.contains('@') {
            return Err("webhook url must not carry userinfo");
        }
        let addr = match authority.rsplit_once(':') {
            Some((host, port)) => {
                if host.is_empty() {
                    return Err("webhook url has no host");
                }
                if port.is_empty() || !port.bytes().all(|b| b.is_ascii_digit()) {
                    return Err("webhook url port is not a number");
                }
                if port.parse::<u16>().is_err() {
                    return Err("webhook url port out of range");
                }
                authority.to_string()
            }
            None => format!("{authority}:80"),
        };
        Ok(Webhook { url: url.to_string(), addr, path: path.to_string(), events: None })
    }

    /// Restrict delivery to the given terminal states.
    pub fn with_events(mut self, events: Vec<RunnerState>) -> Webhook {
        self.events = Some(events);
        self
    }

    /// Whether a terminal transition to `state` should be delivered.
    pub fn wants(&self, state: RunnerState) -> bool {
        match &self.events {
            None => true,
            Some(filter) => filter.contains(&state),
        }
    }

    /// `host:port` the delivery connects to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Path the delivery POSTs to.
    pub fn path(&self) -> &str {
        &self.path
    }
}

/// One pending delivery in the queue.
struct Delivery {
    /// Prediction id (also the jitter discriminator).
    id: u64,
    /// Connect target.
    addr: String,
    /// POST path.
    path: String,
    /// Full prediction JSON at terminal time.
    body: Json,
    /// POST attempts already made.
    attempts_made: u32,
    /// Earliest instant the next attempt may run (backoff gate).
    not_before: Instant,
    /// When the terminal transition happened (delivery-latency origin).
    terminal_at: Instant,
}

/// Queue state under the [`rank::WEBHOOK_QUEUE`] mutex.
struct QueueState {
    pending: VecDeque<Delivery>,
    /// Attempts currently executing outside the lock (so the flush can
    /// tell "empty queue" from "quiescent").
    inflight: usize,
    /// No new enqueues race the drain accounting after close.
    closed: bool,
    /// The drain deadline passed: failed attempts dead-letter instead
    /// of rescheduling.
    abandoned: bool,
}

/// The delivery subsystem: a bounded queue drained by worker threads.
/// Create with [`WebhookSender::start`], finish with
/// [`WebhookSender::flush_and_join`] (the runner does both).
pub struct WebhookSender {
    config: WebhookConfig,
    state: Mutex<QueueState>,
    cv: Condvar,
    enqueued: AtomicU64,
    attempts: AtomicU64,
    delivered: AtomicU64,
    retries: AtomicU64,
    dead_lettered: AtomicU64,
    overflowed: AtomicU64,
    /// Terminal-to-2xx seconds per success; a leaf lock, never held
    /// together with the queue mutex.
    latencies: Mutex<Vec<f64>>,
    joined: AtomicBool,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WebhookSender {
    /// Start the delivery workers.
    pub fn start(config: WebhookConfig) -> Arc<WebhookSender> {
        assert!(config.workers >= 1, "webhook delivery needs at least one worker");
        assert!(config.max_attempts >= 1, "a delivery needs at least one attempt");
        let sender = Arc::new(WebhookSender {
            config,
            state: Mutex::ranked(
                rank::WEBHOOK_QUEUE,
                "server.webhook_queue",
                QueueState {
                    pending: VecDeque::new(),
                    inflight: 0,
                    closed: false,
                    abandoned: false,
                },
            ),
            cv: Condvar::new(),
            enqueued: AtomicU64::new(0),
            attempts: AtomicU64::new(0),
            delivered: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            dead_lettered: AtomicU64::new(0),
            overflowed: AtomicU64::new(0),
            latencies: Mutex::new(Vec::new()),
            joined: AtomicBool::new(false),
            workers: Mutex::new(Vec::new()),
        });
        let mut workers = sender.workers.lock();
        for _ in 0..sender.config.workers {
            let s = Arc::clone(&sender);
            workers.push(std::thread::spawn(move || s.worker_loop()));
        }
        drop(workers);
        sender
    }

    /// The sender configuration.
    pub fn config(&self) -> &WebhookConfig {
        &self.config
    }

    /// Deliveries waiting (or backing off) right now.
    pub fn pending(&self) -> usize {
        lock_or_abort(&self.state).pending.len()
    }

    /// Accept one terminal transition for delivery. `terminal_at` is
    /// when the transition happened (origin of the delivery-latency
    /// sample). Over capacity the delivery is dead-lettered on the
    /// spot — webhook pressure must never back up into the runner.
    pub fn enqueue(&self, id: u64, webhook: &Webhook, body: Json, terminal_at: Instant) {
        let mut st = lock_or_abort(&self.state);
        if st.abandoned {
            // The drain deadline already passed; accounting only.
            self.dead_lettered.fetch_add(1, Ordering::Relaxed);
            return;
        }
        if st.pending.len() >= self.config.queue_capacity {
            self.overflowed.fetch_add(1, Ordering::Relaxed);
            self.dead_lettered.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.enqueued.fetch_add(1, Ordering::Relaxed);
        st.pending.push_back(Delivery {
            id,
            addr: webhook.addr().to_string(),
            path: webhook.path().to_string(),
            body,
            attempts_made: 0,
            not_before: Instant::now(),
            terminal_at,
        });
        drop(st);
        self.cv.notify_one();
    }

    /// Counter snapshot (latency samples cloned).
    pub fn stats(&self) -> WebhookStats {
        let ord = Ordering::Relaxed;
        WebhookStats {
            enqueued: self.enqueued.load(ord),
            attempts: self.attempts.load(ord),
            delivered: self.delivered.load(ord),
            retries: self.retries.load(ord),
            dead_lettered: self.dead_lettered.load(ord),
            overflowed: self.overflowed.load(ord),
            latency_seconds: lock_or_abort(&self.latencies).clone(),
        }
    }

    /// Flush the queue and stop: keep delivering (backoff schedules
    /// included) until everything pending has been delivered or
    /// dead-lettered, or until `deadline` passes — then dead-letter the
    /// remainder — and join the workers. Idempotent; drain-path locks
    /// abort on poisoning per the project policy.
    pub fn flush_and_join(&self, deadline: Duration) {
        let t_deadline = Instant::now() + deadline;
        {
            let mut st = lock_or_abort(&self.state);
            st.closed = true;
            // Notify under the lock: a worker is either before its
            // predicate re-check (sees closed) or parked (gets woken).
            self.cv.notify_all();
            loop {
                if st.pending.is_empty() && st.inflight == 0 {
                    break;
                }
                if !st.abandoned && Instant::now() >= t_deadline {
                    st.abandoned = true;
                    self.dead_lettered.fetch_add(st.pending.len() as u64, Ordering::Relaxed);
                    st.pending.clear();
                    self.cv.notify_all();
                    // Still-inflight attempts settle on their own (they
                    // observe `abandoned` and dead-letter instead of
                    // rescheduling); keep waiting for them below.
                }
                let (g, _timed_out) = self.cv.wait_timeout(st, Duration::from_millis(2));
                st = g;
            }
        }
        if self.joined.swap(true, Ordering::AcqRel) {
            return; // a prior flush already joined the workers
        }
        let handles: Vec<_> = lock_or_abort(&self.workers).drain(..).collect();
        for h in handles {
            h.join().expect("webhook delivery worker panicked");
        }
    }

    fn worker_loop(&self) {
        loop {
            let Some(job) = self.next_job() else {
                return;
            };
            let delivered = self.attempt(&job);
            self.settle(job, delivered);
        }
    }

    /// Block until a delivery is ready to run (its `not_before` gate
    /// passed), claim it, and mark it inflight; `None` once the queue
    /// is closed and empty (worker exit).
    fn next_job(&self) -> Option<Delivery> {
        let mut st = lock_or_abort(&self.state);
        loop {
            let now = Instant::now();
            if let Some(i) = st.pending.iter().position(|d| d.not_before <= now) {
                let job = st.pending.remove(i).expect("position is in range");
                st.inflight += 1;
                return Some(job);
            }
            if st.pending.is_empty() {
                if st.closed {
                    return None;
                }
                st = self.cv.wait(st);
            } else {
                // Everything pending is backing off: sleep until the
                // earliest gate (or a notify for new work / close).
                let earliest =
                    st.pending.iter().map(|d| d.not_before).min().expect("pending nonempty");
                let dur = earliest.saturating_duration_since(now).max(Duration::from_millis(1));
                let (g, _timed_out) = self.cv.wait_timeout(st, dur);
                st = g;
            }
        }
    }

    /// One HTTP attempt; `true` on a 2xx acknowledgment.
    fn attempt(&self, job: &Delivery) -> bool {
        self.attempts.fetch_add(1, Ordering::Relaxed);
        let resp = http::http_call_timeout(
            &job.addr,
            "POST",
            &job.path,
            Some(&job.body),
            Duration::from_millis(self.config.connect_timeout_ms),
            Duration::from_millis(self.config.read_timeout_ms),
        );
        matches!(resp, Ok(r) if (200..300).contains(&r.status))
    }

    /// Record an attempt's result: count a success, reschedule a
    /// failure with backoff, or dead-letter past the budget (or past
    /// the drain deadline).
    fn settle(&self, mut job: Delivery, delivered: bool) {
        if delivered {
            self.delivered.fetch_add(1, Ordering::Relaxed);
            lock_or_abort(&self.latencies).push(job.terminal_at.elapsed().as_secs_f64());
        } else {
            job.attempts_made += 1;
        }
        let mut st = lock_or_abort(&self.state);
        st.inflight -= 1;
        if !delivered {
            if job.attempts_made >= self.config.max_attempts || st.abandoned {
                self.dead_lettered.fetch_add(1, Ordering::Relaxed);
            } else {
                self.retries.fetch_add(1, Ordering::Relaxed);
                let delay = backoff_delay_ms(
                    self.config.base_backoff_ms,
                    self.config.max_backoff_ms,
                    job.attempts_made,
                    self.config.jitter_seed,
                    job.id,
                );
                job.not_before = Instant::now() + Duration::from_millis(delay);
                st.pending.push_back(job);
            }
        }
        drop(st);
        // Wake backoff sleepers (the new gate may be earlier than what
        // they are sleeping toward) and the flush waiter.
        self.cv.notify_all();
    }
}

/// How the fault-injection receiver treats one incoming connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Read the request and acknowledge with `200` (records the body).
    Ok,
    /// Accept, then drop the connection without responding.
    DropConnection,
    /// Read the request, answer with this status (e.g. `503`).
    Status(u16),
    /// Read the request, stall this long, then answer `200` — pushes a
    /// client whose read timeout is shorter into a timeout failure
    /// (records the body: the response was sent, the client gave up).
    StallMs(u64),
}

/// A loopback webhook receiver with scripted faults, used by the
/// delivery tests and the load generator's webhook phase. Connections
/// consume faults from the script in order; an exhausted script means
/// [`Fault::Ok`]. Connections are handled serially on the accept
/// thread (a stall blocks later arrivals — fine for fault injection,
/// wrong for a real server).
pub struct FaultReceiver {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    shared: Arc<ReceiverShared>,
    thread: Option<std::thread::JoinHandle<()>>,
}

struct ReceiverShared {
    script: Mutex<VecDeque<Fault>>,
    /// Bodies of requests answered `200`, with their arrival instants.
    delivered: Mutex<Vec<(Instant, Json)>>,
    /// Arrival instant of every connection (dropped ones included).
    hits: Mutex<Vec<Instant>>,
}

impl FaultReceiver {
    /// Bind an ephemeral loopback port and start accepting.
    pub fn start(script: Vec<Fault>) -> std::io::Result<FaultReceiver> {
        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(ReceiverShared {
            script: Mutex::new(script.into()),
            delivered: Mutex::new(Vec::new()),
            hits: Mutex::new(Vec::new()),
        });
        let thread = {
            let stop = Arc::clone(&stop);
            let shared = Arc::clone(&shared);
            Some(std::thread::spawn(move || receiver_loop(listener, shared, stop)))
        };
        Ok(FaultReceiver { addr, stop, shared, thread })
    }

    /// The webhook URL clients should register, with `path` appended.
    pub fn url(&self, path: &str) -> String {
        format!("http://{}{}", self.addr, path)
    }

    /// The bound loopback address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Append faults to the script.
    pub fn push_faults(&self, faults: impl IntoIterator<Item = Fault>) {
        self.shared.script.lock().extend(faults);
    }

    /// Bodies acknowledged with `200`, in arrival order.
    pub fn delivered(&self) -> Vec<Json> {
        self.shared.delivered.lock().iter().map(|(_, b)| b.clone()).collect()
    }

    /// Arrival instants of acknowledged deliveries, in order.
    pub fn delivered_at(&self) -> Vec<Instant> {
        self.shared.delivered.lock().iter().map(|(t, _)| *t).collect()
    }

    /// Count of acknowledged deliveries.
    pub fn delivered_count(&self) -> usize {
        self.shared.delivered.lock().len()
    }

    /// Arrival instants of every connection (faulted ones included) —
    /// what the backoff-schedule assertions measure gaps over.
    pub fn hits(&self) -> Vec<Instant> {
        self.shared.hits.lock().clone()
    }

    /// Stop accepting and join the accept thread.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FaultReceiver {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

fn receiver_loop(
    listener: std::net::TcpListener,
    shared: Arc<ReceiverShared>,
    stop: Arc<AtomicBool>,
) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.hits.lock().push(Instant::now());
                let fault = shared.script.lock().pop_front().unwrap_or(Fault::Ok);
                serve_faulted(stream, fault, &shared);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(_) => break,
        }
    }
}

fn serve_faulted(stream: std::net::TcpStream, fault: Fault, shared: &ReceiverShared) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    if fault == Fault::DropConnection {
        return; // drop without reading: the client sees a torn connection
    }
    let mut reader = std::io::BufReader::new(stream);
    let Ok(req) = http::read_request(&mut reader) else {
        return;
    };
    let (status, record) = match fault {
        Fault::Ok => (200, true),
        Fault::Status(code) => (code, false),
        Fault::StallMs(ms) => {
            std::thread::sleep(Duration::from_millis(ms));
            (200, true)
        }
        Fault::DropConnection => unreachable!("handled before reading"),
    };
    if record {
        if let Ok(text) = std::str::from_utf8(&req.body) {
            if let Ok(body) = Json::parse(text) {
                shared.delivered.lock().push((Instant::now(), body));
            }
        }
    }
    let resp = http::Response::json(status, &Json::obj(vec![("ok", Json::Bool(status == 200))]));
    let mut stream = reader.into_inner();
    let _ = resp.write_to(&mut stream);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_schedule_is_pinned() {
        // Mirrored bit-for-bit by python/replica/serve_http_replica.py:
        // change either side and both CI gates fail.
        let cfg = WebhookConfig::default(); // base 50, cap 2000, seed 0xC0FFEE
        assert_eq!(backoff_schedule(&cfg, 1, 4), vec![45, 62, 134, 288]);
        assert_eq!(backoff_schedule(&cfg, 2, 4), vec![34, 97, 112, 276]);
        assert_eq!(backoff_schedule(&cfg, 3, 4), vec![26, 54, 178, 287]);
        let smoke =
            WebhookConfig { base_backoff_ms: 10, max_backoff_ms: 50, jitter_seed: 7, ..cfg };
        assert_eq!(backoff_schedule(&smoke, 1, 4), vec![6, 14, 21, 44]);
        assert_eq!(backoff_schedule(&smoke, 2, 4), vec![6, 13, 27, 26]);
    }

    #[test]
    fn backoff_delay_stays_in_the_jitter_window() {
        let (base, cap) = (50u64, 2000u64);
        for id in 0..50u64 {
            for attempt in 1..=8u32 {
                let term = base.saturating_mul(1 << (attempt - 1).min(16)).min(cap);
                let half = (term / 2).max(1);
                let d = backoff_delay_ms(base, cap, attempt, 0xC0FFEE, id);
                assert!(
                    (half..2 * half).contains(&d),
                    "id {id} attempt {attempt}: {d} outside [{half}, {})",
                    2 * half
                );
            }
        }
    }

    #[test]
    fn webhook_url_parsing() {
        let w = Webhook::parse("http://127.0.0.1:9999/hooks/done").unwrap();
        assert_eq!(w.addr(), "127.0.0.1:9999");
        assert_eq!(w.path(), "/hooks/done");
        let w = Webhook::parse("http://example.com").unwrap();
        assert_eq!(w.addr(), "example.com:80", "port defaults to 80");
        assert_eq!(w.path(), "/", "path defaults to /");
        for bad in [
            "https://example.com/hook", // no TLS in the zero-dep client
            "example.com/hook",
            "http://",
            "http:///path",
            "http://user@host/x",
            "http://host:notaport/x",
            "http://host:99999/x",
            "http://:8080/x",
        ] {
            assert!(Webhook::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn events_filter_gates_delivery() {
        let w = Webhook::parse("http://127.0.0.1:1/x").unwrap();
        assert!(w.wants(RunnerState::Succeeded), "no filter delivers everything");
        assert!(w.wants(RunnerState::Cancelled));
        let w = w.with_events(vec![RunnerState::Succeeded, RunnerState::Failed]);
        assert!(w.wants(RunnerState::Succeeded));
        assert!(!w.wants(RunnerState::Cancelled));
        assert!(!w.wants(RunnerState::Expired));
    }

    /// Deterministic, receiver-free delivery check: an unbound loopback
    /// port refuses instantly, so every attempt fails fast and the
    /// dead-letter accounting is exact.
    #[test]
    fn exhausted_budget_dead_letters() {
        let port = {
            // Bind-then-drop: the port is free again, connects refuse.
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let cfg = WebhookConfig {
            max_attempts: 3,
            base_backoff_ms: 2,
            max_backoff_ms: 8,
            connect_timeout_ms: 250,
            read_timeout_ms: 250,
            ..WebhookConfig::default()
        };
        let sender = WebhookSender::start(cfg);
        let wh = Webhook::parse(&format!("http://127.0.0.1:{port}/gone")).unwrap();
        sender.enqueue(1, &wh, Json::obj(vec![("id", Json::Num(1.0))]), Instant::now());
        sender.flush_and_join(Duration::from_secs(10));
        let stats = sender.stats();
        assert_eq!(stats.enqueued, 1);
        assert_eq!(stats.attempts, 3, "budget spent exactly");
        assert_eq!(stats.retries, 2);
        assert_eq!(stats.delivered, 0);
        assert_eq!(stats.dead_lettered, 1);
        assert!(stats.latency_seconds.is_empty());
    }

    #[test]
    fn overflow_dead_letters_without_blocking() {
        let port = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let cfg = WebhookConfig {
            queue_capacity: 1,
            max_attempts: 1,
            connect_timeout_ms: 250,
            read_timeout_ms: 250,
            ..WebhookConfig::default()
        };
        let sender = WebhookSender::start(cfg);
        let wh = Webhook::parse(&format!("http://127.0.0.1:{port}/gone")).unwrap();
        // Flood faster than one refused connect can drain.
        for id in 0..20u64 {
            sender.enqueue(id, &wh, Json::obj(vec![("id", Json::Num(id as f64))]), Instant::now());
        }
        sender.flush_and_join(Duration::from_secs(10));
        let stats = sender.stats();
        assert_eq!(stats.delivered, 0);
        assert_eq!(stats.enqueued + stats.overflowed, 20, "every enqueue accounted");
        assert_eq!(stats.dead_lettered, 20, "all 20 dead-letter: refused or overflowed");
    }

    #[test]
    fn flush_is_idempotent() {
        let sender = WebhookSender::start(WebhookConfig::default());
        sender.flush_and_join(Duration::from_millis(100));
        sender.flush_and_join(Duration::from_millis(100));
        assert_eq!(sender.stats(), WebhookStats::default());
    }
}
