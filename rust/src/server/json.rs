//! A miniature JSON value type with renderer and parser.
//!
//! The vendored crate set has no serde, so the HTTP API speaks through
//! this: an ordered object model (predictable field order in responses,
//! so curl output and tests are stable), a renderer that escapes
//! control characters and maps non-finite floats to `null`, and a
//! recursive-descent parser covering the full grammar (nested
//! containers, escapes incl. `\uXXXX` surrogate pairs). Scoped to what
//! the serving API needs — numbers are f64, like JavaScript.

/// A JSON value. Object fields keep insertion order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (rendered via shortest-roundtrip `{}` formatting).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, fields in insertion order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Build an object from `(key, value)` pairs.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object field lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The element slice, if this is an array (used e.g. to validate
    /// `webhook_events_filter` lists on create).
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric payload as a non-negative integer (rejects
    /// fractional, negative, and non-numeric values).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// Render to compact JSON text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    // `{}` prints integers without ".0" and roundtrips
                    // doubles; JSON has no NaN/Inf, so those are null.
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => render_string(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_string(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse JSON text. Trailing non-whitespace is an error.
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after value"));
        }
        Ok(v)
    }
}

fn render_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Where and why parsing failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// Byte offset of the failure.
    pub at: usize,
    /// What went wrong.
    pub msg: &'static str,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &'static str) -> ParseError {
        ParseError { at: self.pos, msg }
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, lit: &str) -> bool {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'n') => {
                if self.eat("null") {
                    Ok(Json::Null)
                } else {
                    Err(self.err("invalid literal"))
                }
            }
            Some(b't') => {
                if self.eat("true") {
                    Ok(Json::Bool(true))
                } else {
                    Err(self.err("invalid literal"))
                }
            }
            Some(b'f') => {
                if self.eat("false") {
                    Ok(Json::Bool(false))
                } else {
                    Err(self.err("invalid literal"))
                }
            }
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.pos += 1; // '['
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.pos += 1; // '{'
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            if self.peek() != Some(b'"') {
                return Err(self.err("expected object key"));
            }
            let key = self.string()?;
            self.skip_ws();
            if self.peek() != Some(b':') {
                return Err(self.err("expected ':'"));
            }
            self.pos += 1;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.pos += 1; // opening '"'
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                return Err(self.err("unterminated string"));
            };
            match b {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let Some(esc) = self.peek() else {
                        return Err(self.err("unterminated escape"));
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // High surrogate: a \uXXXX low half must follow.
                                if !self.eat("\\u") {
                                    return Err(self.err("unpaired surrogate"));
                                }
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                let cp =
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("invalid code point"))?
                            } else {
                                char::from_u32(hi)
                                    .ok_or_else(|| self.err("unpaired surrogate"))?
                            };
                            out.push(c);
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                }
                0x00..=0x1F => return Err(self.err("raw control character in string")),
                _ => {
                    // Multi-byte UTF-8 sequences pass through unchanged:
                    // the input is a &str, so byte slices at char
                    // boundaries are valid.
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.bytes[start..]).expect("input is a str");
                    let c = s.chars().next().expect("non-empty");
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v: u32 = 0;
        for _ in 0..4 {
            let Some(b) = self.peek() else {
                return Err(self.err("truncated \\u escape"));
            };
            let d = match b {
                b'0'..=b'9' => (b - b'0') as u32,
                b'a'..=b'f' => (b - b'a') as u32 + 10,
                b'A'..=b'F' => (b - b'A') as u32 + 10,
                _ => return Err(self.err("invalid hex digit")),
            };
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii");
        text.parse::<f64>().map(Json::Num).map_err(|_| ParseError {
            at: start,
            msg: "invalid number",
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_compactly_in_field_order() {
        let v = Json::obj(vec![
            ("id", Json::Num(7.0)),
            ("status", Json::Str("queued".into())),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            ("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)])),
        ]);
        assert_eq!(
            v.render(),
            r#"{"id":7,"status":"queued","ok":true,"none":null,"xs":[1,2.5]}"#
        );
    }

    #[test]
    fn escapes_and_non_finite() {
        let v = Json::obj(vec![
            ("s", Json::Str("a\"b\\c\nd\u{1}".into())),
            ("nan", Json::Num(f64::NAN)),
        ]);
        assert_eq!(v.render(), r#"{"s":"a\"b\\c\nd","nan":null}"#);
    }

    #[test]
    fn roundtrips_nested_values() {
        let text = r#" {"a": [1, -2.5e3, true, null], "b": {"c": "x y", "d": []}} "#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap(), &Json::Arr(vec![
            Json::Num(1.0),
            Json::Num(-2500.0),
            Json::Bool(true),
            Json::Null,
        ]));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x y"));
        let re = Json::parse(&v.render()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn parses_escapes_and_surrogate_pairs() {
        let v = Json::parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ A 😀"));
        assert!(Json::parse(r#""\ud83d""#).is_err(), "unpaired surrogate");
        assert!(Json::parse(r#""\ud83dA""#).is_err(), "bad low half");
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in ["", "{", "[1,", "{\"a\"}", "nul", "1.2.3", "\"abc", "{}x", "[01]x"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn integer_accessor_is_strict() {
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::Num(3.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Str("3".into()).as_u64(), None);
    }

    #[test]
    fn array_accessor() {
        let v = Json::parse(r#"["succeeded","failed"]"#).unwrap();
        let items = v.as_arr().unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[0].as_str(), Some("succeeded"));
        assert_eq!(Json::Num(1.0).as_arr(), None);
    }
}
