//! Hand-rolled HTTP/1.1 framing over `std::io` streams.
//!
//! The build image has no hyper/axum, so the server speaks a minimal,
//! strict subset: one request per connection (`Connection: close` on
//! every response), `Content-Length` bodies only (no chunked encoding),
//! bounded header count/line length/body size so a misbehaving client
//! can't balloon memory. Both directions are implemented — the server
//! parses [`Request`]s and renders [`Response`]s; the load generator
//! and tests reuse the same framing as a client via [`http_call`].

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Longest accepted request line or header line, in bytes.
pub const MAX_LINE_BYTES: usize = 8 * 1024;
/// Most headers accepted per message.
pub const MAX_HEADERS: usize = 64;
/// Largest accepted request/response body, in bytes.
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Upper-cased method (`GET`, `POST`, ...).
    pub method: String,
    /// Path component, query string stripped.
    pub path: String,
    /// `(lower-cased name, value)` pairs in arrival order.
    pub headers: Vec<(String, String)>,
    /// Decoded body (empty without `Content-Length`).
    pub body: Vec<u8>,
}

impl Request {
    /// First value of a header, by case-insensitive name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let lower = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == lower).map(|(_, v)| v.as_str())
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    /// Status code (200, 404, ...).
    pub status: u16,
    /// Extra headers beyond the always-present set.
    pub headers: Vec<(String, String)>,
    /// Body bytes.
    pub body: Vec<u8>,
}

impl Response {
    /// A JSON response with the given status.
    pub fn json(status: u16, body: &super::json::Json) -> Response {
        Response {
            status,
            headers: vec![("Content-Type".into(), "application/json".into())],
            body: body.render().into_bytes(),
        }
    }

    /// A plain-text response.
    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            headers: vec![("Content-Type".into(), "text/plain".into())],
            body: body.as_bytes().to_vec(),
        }
    }

    /// Append a header.
    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.into(), value.into()));
        self
    }

    /// Serialize and write the response; always `Connection: close`.
    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        let reason = reason_phrase(self.status);
        write!(w, "HTTP/1.1 {} {}\r\n", self.status, reason)?;
        for (k, v) in &self.headers {
            write!(w, "{k}: {v}\r\n")?;
        }
        write!(w, "Content-Length: {}\r\n", self.body.len())?;
        write!(w, "Connection: close\r\n\r\n")?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Response",
    }
}

/// Why reading a message failed.
#[derive(Debug)]
pub enum HttpError {
    /// The underlying stream failed.
    Io(std::io::Error),
    /// The bytes were not a well-formed message (or exceeded a bound).
    Malformed(&'static str),
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Io(e) => write!(f, "http io error: {e}"),
            HttpError::Malformed(msg) => write!(f, "malformed http message: {msg}"),
        }
    }
}

impl std::error::Error for HttpError {}

impl From<std::io::Error> for HttpError {
    fn from(e: std::io::Error) -> HttpError {
        HttpError::Io(e)
    }
}

/// Read one CRLF- (or LF-) terminated line, without the terminator.
fn read_line<R: BufRead>(r: &mut R) -> Result<String, HttpError> {
    let mut line = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        let n = r.read(&mut byte)?;
        if n == 0 {
            if line.is_empty() {
                return Err(HttpError::Malformed("unexpected end of stream"));
            }
            break;
        }
        if byte[0] == b'\n' {
            break;
        }
        line.push(byte[0]);
        if line.len() > MAX_LINE_BYTES {
            return Err(HttpError::Malformed("line too long"));
        }
    }
    if line.last() == Some(&b'\r') {
        line.pop();
    }
    String::from_utf8(line).map_err(|_| HttpError::Malformed("non-utf8 line"))
}

fn read_headers<R: BufRead>(r: &mut R) -> Result<Vec<(String, String)>, HttpError> {
    let mut headers = Vec::new();
    loop {
        let line = read_line(r)?;
        if line.is_empty() {
            return Ok(headers);
        }
        if headers.len() >= MAX_HEADERS {
            return Err(HttpError::Malformed("too many headers"));
        }
        let (name, value) =
            line.split_once(':').ok_or_else(|| HttpError::Malformed("header without ':'"))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
}

/// Strict `Content-Length` value parse: nonempty, ASCII digits only.
/// `usize::from_str` alone is too lax — it accepts a leading `+`
/// (`"+10"` parses), and sign/whitespace variance across parsers is
/// exactly what request-smuggling shapes exploit.
fn parse_content_length(v: &str) -> Result<usize, HttpError> {
    if v.is_empty() || !v.bytes().all(|b| b.is_ascii_digit()) {
        return Err(HttpError::Malformed("invalid content-length"));
    }
    v.parse::<usize>().map_err(|_| HttpError::Malformed("invalid content-length"))
}

fn read_body<R: BufRead>(
    r: &mut R,
    headers: &[(String, String)],
) -> Result<Vec<u8>, HttpError> {
    // Duplicate Content-Length headers are the classic smuggling shape:
    // two framing layers picking different values desynchronize on the
    // body boundary. One header or none — even agreeing duplicates are
    // rejected, per RFC 9112 §6.3's "reject as malformed" option.
    let mut lengths = headers.iter().filter(|(k, _)| k == "content-length");
    let len = match lengths.next() {
        None => 0,
        Some((_, v)) => {
            if lengths.next().is_some() {
                return Err(HttpError::Malformed("duplicate content-length"));
            }
            parse_content_length(v)?
        }
    };
    if len > MAX_BODY_BYTES {
        return Err(HttpError::Malformed("body too large"));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).map_err(|_| HttpError::Malformed("truncated body"))?;
    Ok(body)
}

/// Read and parse one request from a stream.
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Request, HttpError> {
    let line = read_line(r)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| HttpError::Malformed("empty request line"))?;
    let target = parts.next().ok_or_else(|| HttpError::Malformed("request line without target"))?;
    let version = parts.next().ok_or_else(|| HttpError::Malformed("request line without version"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Malformed("unsupported http version"));
    }
    // The query string is irrelevant to this API; strip it.
    let path = target.split('?').next().expect("split yields at least one part");
    let headers = read_headers(r)?;
    let body = read_body(r, &headers)?;
    Ok(Request {
        method: method.to_ascii_uppercase(),
        path: path.to_string(),
        headers,
        body,
    })
}

/// A parsed response (client side).
#[derive(Debug, Clone)]
pub struct ClientResponse {
    /// Status code.
    pub status: u16,
    /// `(lower-cased name, value)` pairs.
    pub headers: Vec<(String, String)>,
    /// Body bytes.
    pub body: Vec<u8>,
}

impl ClientResponse {
    /// First value of a header, by case-insensitive name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let lower = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == lower).map(|(_, v)| v.as_str())
    }

    /// Parse the body as JSON.
    pub fn json(&self) -> Result<super::json::Json, HttpError> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|_| HttpError::Malformed("non-utf8 body"))?;
        super::json::Json::parse(text).map_err(|_| HttpError::Malformed("body is not json"))
    }
}

/// Read and parse one response from a stream.
pub fn read_response<R: BufRead>(r: &mut R) -> Result<ClientResponse, HttpError> {
    let line = read_line(r)?;
    let mut parts = line.split_whitespace();
    let version = parts.next().ok_or_else(|| HttpError::Malformed("empty status line"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Malformed("unsupported http version"));
    }
    let status = parts
        .next()
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| HttpError::Malformed("status line without code"))?;
    let headers = read_headers(r)?;
    let body = read_body(r, &headers)?;
    Ok(ClientResponse { status, headers, body })
}

/// One-shot HTTP client call over a fresh TCP connection: connect,
/// send `method path` with an optional body, read the response. The
/// request lifecycle tests and [`examples/load_gen`] drive the server
/// through this.
pub fn http_call(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&super::json::Json>,
) -> Result<ClientResponse, HttpError> {
    let mut stream = TcpStream::connect(addr)?;
    let payload = body.map(|b| b.render().into_bytes()).unwrap_or_default();
    write!(stream, "{method} {path} HTTP/1.1\r\n")?;
    write!(stream, "Host: {addr}\r\n")?;
    if body.is_some() {
        write!(stream, "Content-Type: application/json\r\n")?;
    }
    write!(stream, "Content-Length: {}\r\n", payload.len())?;
    write!(stream, "Connection: close\r\n\r\n")?;
    stream.write_all(&payload)?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    read_response(&mut reader)
}

/// Like [`http_call`] but with explicit per-attempt timeouts: a bounded
/// connect, and read/write deadlines on the socket. This is the client
/// the webhook delivery workers use — a stalled or black-holed receiver
/// must cost one bounded attempt, never wedge a delivery worker.
pub fn http_call_timeout(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&super::json::Json>,
    connect_timeout: std::time::Duration,
    io_timeout: std::time::Duration,
) -> Result<ClientResponse, HttpError> {
    use std::net::ToSocketAddrs;
    let sock = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| HttpError::Malformed("unresolvable address"))?;
    let mut stream = TcpStream::connect_timeout(&sock, connect_timeout)?;
    stream.set_read_timeout(Some(io_timeout))?;
    stream.set_write_timeout(Some(io_timeout))?;
    let payload = body.map(|b| b.render().into_bytes()).unwrap_or_default();
    write!(stream, "{method} {path} HTTP/1.1\r\n")?;
    write!(stream, "Host: {addr}\r\n")?;
    if body.is_some() {
        write!(stream, "Content-Type: application/json\r\n")?;
    }
    write!(stream, "Content-Length: {}\r\n", payload.len())?;
    write!(stream, "Connection: close\r\n\r\n")?;
    stream.write_all(&payload)?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    read_response(&mut reader)
}

#[cfg(test)]
mod tests {
    use super::super::json::Json;
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_a_post_with_body() {
        let body = "{\"prompt\":\"a\"}";
        let raw = format!(
            "POST /predictions?x=1 HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let mut r = Cursor::new(raw.into_bytes());
        let req = read_request(&mut r).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/predictions", "query string stripped");
        assert_eq!(req.header("host"), Some("localhost"));
        assert_eq!(req.header("HOST"), Some("localhost"), "case-insensitive");
        assert_eq!(req.body, body.as_bytes());
    }

    #[test]
    fn missing_content_length_means_empty_body() {
        let mut r = Cursor::new(b"GET /healthz HTTP/1.1\r\n\r\n".to_vec());
        let req = read_request(&mut r).unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_malformed_requests() {
        for raw in [
            &b"GARBAGE\r\n\r\n"[..],
            &b"GET /x SPDY/3\r\n\r\n"[..],
            &b"GET /x HTTP/1.1\r\nbadheader\r\n\r\n"[..],
            &b"POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n"[..],
            &b"POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort"[..],
            &b""[..],
        ] {
            let mut r = Cursor::new(raw.to_vec());
            assert!(read_request(&mut r).is_err(), "{raw:?} must not parse");
        }
    }

    #[test]
    fn rejects_duplicate_content_length_headers() {
        // Smuggling shape: two Content-Length headers, conflicting
        // values — and even agreeing duplicates are malformed here.
        for raw in [
            &b"POST /x HTTP/1.1\r\nContent-Length: 5\r\nContent-Length: 0\r\n\r\nhello"[..],
            &b"POST /x HTTP/1.1\r\nContent-Length: 5\r\nContent-Length: 5\r\n\r\nhello"[..],
        ] {
            let mut r = Cursor::new(raw.to_vec());
            let err = read_request(&mut r);
            assert!(
                matches!(err, Err(HttpError::Malformed("duplicate content-length"))),
                "{raw:?} must be rejected as malformed"
            );
        }
    }

    #[test]
    fn rejects_non_digit_content_length() {
        // `usize::from_str` accepts "+10"; the wire grammar does not.
        // (Surrounding whitespace is already stripped by the header
        // parser, so digits-only is the full residual grammar.)
        for cl in ["+10", "-1", "0x10", "1_0", ""] {
            let raw = format!("POST /x HTTP/1.1\r\nContent-Length:{cl}\r\n\r\n0123456789");
            let mut r = Cursor::new(raw.into_bytes());
            assert!(
                matches!(read_request(&mut r), Err(HttpError::Malformed("invalid content-length"))),
                "Content-Length {cl:?} must be rejected"
            );
        }
    }

    #[test]
    fn bounds_are_enforced() {
        let long_line = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_LINE_BYTES + 1));
        let mut r = Cursor::new(long_line.into_bytes());
        assert!(matches!(read_request(&mut r), Err(HttpError::Malformed("line too long"))));

        let mut many = String::from("GET /x HTTP/1.1\r\n");
        for i in 0..(MAX_HEADERS + 1) {
            many.push_str(&format!("h{i}: v\r\n"));
        }
        many.push_str("\r\n");
        let mut r = Cursor::new(many.into_bytes());
        assert!(matches!(read_request(&mut r), Err(HttpError::Malformed("too many headers"))));

        let huge = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        let mut r = Cursor::new(huge.into_bytes());
        assert!(matches!(read_request(&mut r), Err(HttpError::Malformed("body too large"))));
    }

    #[test]
    fn response_roundtrips_through_client_parser() {
        let resp = Response::json(202, &Json::obj(vec![("id", Json::Num(3.0))]))
            .with_header("Retry-After", "2");
        let mut wire = Vec::new();
        resp.write_to(&mut wire).unwrap();
        let text = String::from_utf8(wire.clone()).unwrap();
        assert!(text.starts_with("HTTP/1.1 202 Accepted\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        let mut r = Cursor::new(wire);
        let parsed = read_response(&mut r).unwrap();
        assert_eq!(parsed.status, 202);
        assert_eq!(parsed.header("retry-after"), Some("2"));
        assert_eq!(parsed.json().unwrap().get("id").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn bare_lf_line_endings_are_tolerated() {
        let mut r = Cursor::new(b"GET /healthz HTTP/1.1\nHost: x\n\n".to_vec());
        let req = read_request(&mut r).unwrap();
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.header("host"), Some("x"));
    }
}
