//! `Q8_K`: 8-bit activation quantization over 256-element super-blocks
//! (GGML `block_q8_K`).
//!
//! This is the format GGML quantizes *activations* into before a k-quant
//! vec-dot (`vec_dot_q3_K_q8_K` takes Q3_K weights × Q8_K activations).
//! Unlike `Q8_0` it keeps an f32 scale and per-16-element partial sums
//! (`bsums`) that k-quant kernels use to fold the weights' minimums in.

use super::{nearest_i32, QK_K};

/// One Q8_K super-block: f32 scale, 256 signed bytes, 16 partial sums.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockQ8K {
    /// Super-block scale (f32, matching GGML).
    pub d: f32,
    /// Quantized values.
    pub qs: [i8; QK_K],
    /// Sum of each 16-element group of `qs` (i16 in GGML).
    pub bsums: [i16; QK_K / 16],
}

impl Default for BlockQ8K {
    fn default() -> Self {
        BlockQ8K { d: 0.0, qs: [0; QK_K], bsums: [0; QK_K / 16] }
    }
}

impl BlockQ8K {
    /// Quantize 256 floats, reproducing `quantize_row_q8_K_ref`: the scale
    /// anchors the most-negative representation at -128.
    pub fn quantize(x: &[f32; QK_K]) -> BlockQ8K {
        let mut amax = 0.0f32;
        let mut max = 0.0f32;
        for &v in x.iter() {
            if v.abs() > amax {
                amax = v.abs();
                max = v;
            }
        }
        if amax == 0.0 {
            return BlockQ8K::default();
        }
        let iscale = -128.0 / max;
        let mut qs = [0i8; QK_K];
        for (q, &v) in qs.iter_mut().zip(x.iter()) {
            *q = nearest_i32(iscale * v).min(127) as i8;
        }
        let mut bsums = [0i16; QK_K / 16];
        for (g, chunk) in qs.chunks_exact(16).enumerate() {
            bsums[g] = chunk.iter().map(|&q| q as i16).sum();
        }
        BlockQ8K { d: 1.0 / iscale, qs, bsums }
    }

    /// Dequantize into 256 floats.
    pub fn dequantize(&self, out: &mut [f32; QK_K]) {
        for (o, &q) in out.iter_mut().zip(self.qs.iter()) {
            *o = self.d * q as f32;
        }
    }
}

/// Quantize a row; `x.len()` must be a multiple of 256.
pub fn quantize_row(x: &[f32]) -> Vec<BlockQ8K> {
    assert!(
        x.len() % QK_K == 0,
        "Q8_K rows must be a multiple of {QK_K} (got {})",
        x.len()
    );
    x.chunks_exact(QK_K)
        .map(|c| BlockQ8K::quantize(c.try_into().unwrap()))
        .collect()
}

/// Dequantize a row of blocks.
pub fn dequantize_row(blocks: &[BlockQ8K]) -> Vec<f32> {
    let mut out = vec![0.0f32; blocks.len() * QK_K];
    let mut buf = [0.0f32; QK_K];
    for (i, b) in blocks.iter().enumerate() {
        b.dequantize(&mut buf);
        out[i * QK_K..(i + 1) * QK_K].copy_from_slice(&buf);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn random_row(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn zero_block_is_default() {
        let b = BlockQ8K::quantize(&[0.0; QK_K]);
        assert_eq!(b, BlockQ8K::default());
    }

    #[test]
    fn extreme_value_maps_to_neg128_anchor() {
        let mut x = [0.0f32; QK_K];
        x[0] = 4.0; // max-magnitude is positive -> iscale negative
        let b = BlockQ8K::quantize(&x);
        assert_eq!(b.qs[0], -128);
        assert!((b.d * b.qs[0] as f32 - 4.0).abs() < 1e-6);
    }

    #[test]
    fn negative_anchor() {
        let mut x = [0.0f32; QK_K];
        x[10] = -2.0;
        let b = BlockQ8K::quantize(&x);
        assert_eq!(b.qs[10], -128);
        assert!((b.d * -128.0 - -2.0).abs() < 1e-6);
    }

    #[test]
    fn bsums_are_group_sums() {
        let x: Vec<f32> = random_row(QK_K, 5);
        let b = BlockQ8K::quantize(x.as_slice().try_into().unwrap());
        for (g, chunk) in b.qs.chunks_exact(16).enumerate() {
            let s: i16 = chunk.iter().map(|&q| q as i16).sum();
            assert_eq!(b.bsums[g], s, "group {g}");
        }
    }

    #[test]
    fn round_trip_error_bound() {
        let x: Vec<f32> = random_row(QK_K, 6);
        let b = BlockQ8K::quantize(x.as_slice().try_into().unwrap());
        let mut out = [0.0f32; QK_K];
        b.dequantize(&mut out);
        let step = b.d.abs();
        for (orig, deq) in x.iter().zip(out.iter()) {
            assert!((orig - deq).abs() <= 0.5 * step + 1e-6);
        }
    }

    #[test]
    fn row_helpers() {
        let x = random_row(2 * QK_K, 7);
        let blocks = quantize_row(&x);
        assert_eq!(blocks.len(), 2);
        let back = dequantize_row(&blocks);
        assert_eq!(back.len(), x.len());
    }

    #[test]
    #[should_panic(expected = "multiple of 256")]
    fn ragged_rejected() {
        quantize_row(&vec![0.0; 100]);
    }
}
