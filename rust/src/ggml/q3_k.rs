//! `Q3_K`: 3-bit k-quant super-block quantization (GGML `block_q3_K`).
//!
//! 256 elements per super-block, laid out exactly like GGML:
//!
//! ```text
//! hmask[32]   high (3rd) bit of each quant, bit b of byte l covers
//!             element 32*b + l
//! qs[64]      low 2 bits; element order: 128-element halves, within a
//!             half 4 shift-planes (0,2,4,6) of 32 consecutive bytes
//! scales[12]  16 × 6-bit signed sub-block scales (stored +32), packed
//!             as 8 low nibbles + 8 high nibbles + 16 top-2-bit pairs
//! d           f16 super-block scale
//! ```
//!
//! Element value: `d * (sc_j - 32) * (q - (hmask bit ? 0 : 4))` where `q`
//! is the low 2 bits — i.e. signed 3-bit weights in `[-4, 3]` with a
//! signed 6-bit scale per 16 elements.
//!
//! This module also implements the paper's **IMAX restructuring**
//! (§III-B): the 6-bit scales are approximated to 5 bits and the 2+1-bit
//! quants are repacked into a unified 3-bit stream, which is what
//! `OP_CVT53` consumes in hardware. [`to_imax_stream`] produces exactly
//! that operand layout for the simulator, and the `*_imax5` functions
//! quantify the accuracy cost of the 5-bit scale approximation (the paper
//! reports "almost no effect").

use super::{nearest_i32, QK_K};
use crate::util::f16::F16;

/// One 110-byte Q3_K super-block.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockQ3K {
    /// High-bit mask (1 bit per element).
    pub hmask: [u8; QK_K / 8],
    /// Low 2 bits of each quant.
    pub qs: [u8; QK_K / 4],
    /// Packed 6-bit sub-block scales.
    pub scales: [u8; 12],
    /// Super-block scale.
    pub d: F16,
}

impl Default for BlockQ3K {
    fn default() -> Self {
        BlockQ3K { hmask: [0; QK_K / 8], qs: [0; QK_K / 4], scales: [0; 12], d: F16::ZERO }
    }
}

impl BlockQ3K {
    /// Serialized size in bytes (32 + 64 + 12 + 2 = 110), the DMA unit.
    pub const BYTES: usize = QK_K / 8 + QK_K / 4 + 12 + 2;

    /// Unpack the 16 6-bit scales to signed values in `[-32, 31]`
    /// (i.e. stored value minus 32), reproducing GGML's kmask unpack.
    pub fn unpack_scales(&self) -> [i8; 16] {
        let mut out = [0i8; 16];
        for j in 0..16 {
            let low4 = if j < 8 {
                self.scales[j] & 0x0F
            } else {
                self.scales[j - 8] >> 4
            };
            let hi2 = (self.scales[8 + j % 4] >> (2 * (j / 4))) & 3;
            out[j] = ((low4 | (hi2 << 4)) as i8) - 32;
        }
        out
    }

    /// Pack 16 signed scales in `[-32, 31]` into the 12-byte layout.
    pub fn pack_scales(scales: &[i8; 16]) -> [u8; 12] {
        let mut out = [0u8; 12];
        for (j, &s) in scales.iter().enumerate() {
            let l = (s as i32 + 32) as u8; // 0..63
            if j < 8 {
                out[j] |= l & 0x0F;
            } else {
                out[j - 8] |= (l & 0x0F) << 4;
            }
            out[8 + j % 4] |= (l >> 4) << (2 * (j / 4));
        }
        out
    }

    /// Extract the signed 3-bit quant of element `idx` in `[-4, 3]`.
    pub fn quant(&self, idx: usize) -> i8 {
        debug_assert!(idx < QK_K);
        let half = idx / 128; // 0 or 1
        let within = idx % 128;
        let shift = 2 * (within / 32);
        let l = within % 32;
        let low2 = ((self.qs[half * 32 + l] >> shift) & 3) as i8;
        let hbit_group = idx / 32; // 0..7 -> mask bit
        let hbyte = idx % 32;
        let high_set = self.hmask[hbyte] & (1 << hbit_group) != 0;
        low2 - if high_set { 0 } else { 4 }
    }

    /// Unpack all 256 signed quants plane-wise (the GGML `aux8` walk).
    ///
    /// §Perf: this replaces 256 independent [`BlockQ3K::quant`] bit
    /// extractions (each recomputing shift/mask) with four shift-plane
    /// sweeps per 128-half — ~4× faster per super-block and the reason
    /// the Q3_K dot kernels run at Q8_0-like speed.
    pub fn unpack_quants(&self) -> [i8; QK_K] {
        let mut out = [0i8; QK_K];
        for half in 0..2 {
            let qs = &self.qs[half * 32..half * 32 + 32];
            for shift in 0..4usize {
                let hbit = (half * 4 + shift) as u8;
                let base = half * 128 + shift * 32;
                for l in 0..32 {
                    let low2 = ((qs[l] >> (2 * shift)) & 3) as i8;
                    let sub = (((self.hmask[l] >> hbit) & 1) ^ 1) << 2; // 0 or 4
                    out[base + l] = low2 - sub as i8;
                }
            }
        }
        out
    }

    /// Dequantize the super-block into 256 floats (GGML
    /// `dequantize_row_q3_K` semantics).
    pub fn dequantize(&self, out: &mut [f32; QK_K]) {
        let d_all = self.d.to_f32();
        let scales = self.unpack_scales();
        let q = self.unpack_quants();
        for (idx, o) in out.iter_mut().enumerate() {
            let dl = d_all * scales[idx / 16] as f32;
            *o = dl * q[idx] as f32;
        }
    }

    /// Quantize 256 floats, reproducing `quantize_row_q3_K_ref` (including
    /// the rmse-refined per-16 scale search of `make_q3_quants`).
    pub fn quantize(x: &[f32; QK_K]) -> BlockQ3K {
        // Per-16 sub-block scales.
        let mut sub_scales = [0.0f32; 16];
        for j in 0..16 {
            let chunk: &[f32; 16] = x[16 * j..16 * (j + 1)].try_into().unwrap();
            sub_scales[j] = make_q3_scale(chunk);
        }

        // Super-scale: 6-bit code per sub-block.
        let mut max_scale = 0.0f32;
        let mut amax = 0.0f32;
        for &s in &sub_scales {
            if s.abs() > amax {
                amax = s.abs();
                max_scale = s;
            }
        }
        let mut blk = BlockQ3K::default();
        if max_scale == 0.0 {
            return blk;
        }
        let iscale = -32.0 / max_scale;
        let mut coded = [0i8; 16];
        for j in 0..16 {
            coded[j] = nearest_i32(iscale * sub_scales[j]).clamp(-32, 31) as i8;
        }
        blk.scales = Self::pack_scales(&coded);
        blk.d = F16::from_f32(1.0 / iscale);

        // Re-quantize elements against the coded (lossy) scales.
        let d_f16 = blk.d.to_f32();
        let mut l_vals = [0u8; QK_K]; // q + 4 in [0, 7]
        for j in 0..16 {
            let dl = d_f16 * coded[j] as f32;
            if dl == 0.0 {
                // GGML leaves L at 0 here, which decodes as -4 * 0-scale = 0.
                for l in &mut l_vals[16 * j..16 * (j + 1)] {
                    *l = 4; // encode q = 0 so dequant(0-scale) stays 0 cleanly
                }
                continue;
            }
            for ii in 0..16 {
                let q = nearest_i32(x[16 * j + ii] / dl).clamp(-4, 3);
                l_vals[16 * j + ii] = (q + 4) as u8;
            }
        }

        // Pack hmask (bit set when q >= 0, i.e. L > 3).
        for (idx, l) in l_vals.iter_mut().enumerate() {
            if *l > 3 {
                blk.hmask[idx % 32] |= 1 << (idx / 32);
                *l -= 4;
            }
        }
        // Pack low 2 bits: per 128-half, 4 shift planes over 32 bytes.
        for half in 0..2 {
            for l in 0..32 {
                let base = half * 128 + l;
                blk.qs[half * 32 + l] = l_vals[base]
                    | (l_vals[base + 32] << 2)
                    | (l_vals[base + 64] << 4)
                    | (l_vals[base + 96] << 6);
            }
        }
        blk
    }

    /// Serialize to GGML's on-disk layout.
    pub fn to_bytes(&self) -> [u8; Self::BYTES] {
        let mut out = [0u8; Self::BYTES];
        out[..32].copy_from_slice(&self.hmask);
        out[32..96].copy_from_slice(&self.qs);
        out[96..108].copy_from_slice(&self.scales);
        out[108..110].copy_from_slice(&self.d.0.to_le_bytes());
        out
    }

    /// Parse from GGML's on-disk layout.
    pub fn from_bytes(b: &[u8]) -> BlockQ3K {
        assert_eq!(b.len(), Self::BYTES);
        let mut blk = BlockQ3K::default();
        blk.hmask.copy_from_slice(&b[..32]);
        blk.qs.copy_from_slice(&b[32..96]);
        blk.scales.copy_from_slice(&b[96..108]);
        blk.d = F16(u16::from_le_bytes([b[108], b[109]]));
        blk
    }
}

/// `make_q3_quants(n=16, nmax=4, do_rmse=true)`: find the best per-16
/// scale with greedy least-squares refinement, returning the scale
/// (quants themselves are re-derived later against the coded scale).
fn make_q3_scale(x: &[f32; 16]) -> f32 {
    let nmax = 4i32;
    let mut amax = 0.0f32;
    let mut max = 0.0f32;
    for &v in x.iter() {
        if v.abs() > amax {
            amax = v.abs();
            max = v;
        }
    }
    if amax == 0.0 {
        return 0.0;
    }
    let iscale = -(nmax as f32) / max;
    let mut l_vals = [0i32; 16];
    let mut sumlx = 0.0f32;
    let mut suml2 = 0.0f32;
    for (i, &v) in x.iter().enumerate() {
        let l = nearest_i32(iscale * v).clamp(-nmax, nmax - 1);
        l_vals[i] = l;
        sumlx += v * l as f32;
        suml2 += (l * l) as f32;
    }
    for _try in 0..5 {
        let mut n_changed = 0;
        for i in 0..16 {
            let slx = sumlx - x[i] * l_vals[i] as f32;
            let sl2 = suml2 - (l_vals[i] * l_vals[i]) as f32;
            if slx > 0.0 && sl2 > 0.0 {
                let new_l = nearest_i32(x[i] * sl2 / slx).clamp(-nmax, nmax - 1);
                if new_l != l_vals[i] {
                    let slx2 = slx + x[i] * new_l as f32;
                    let sl22 = sl2 + (new_l * new_l) as f32;
                    if sl22 > 0.0 && slx2 * slx2 * suml2 > sumlx * sumlx * sl22 {
                        l_vals[i] = new_l;
                        sumlx = slx2;
                        suml2 = sl22;
                        n_changed += 1;
                    }
                }
            }
        }
        if n_changed == 0 {
            break;
        }
    }
    if suml2 == 0.0 {
        0.0
    } else {
        sumlx / suml2
    }
}

/// Quantize a row; `x.len()` must be a multiple of 256.
pub fn quantize_row(x: &[f32]) -> Vec<BlockQ3K> {
    assert!(
        x.len() % QK_K == 0,
        "Q3_K rows must be a multiple of {QK_K} (got {})",
        x.len()
    );
    x.chunks_exact(QK_K)
        .map(|c| BlockQ3K::quantize(c.try_into().unwrap()))
        .collect()
}

/// Dequantize a row of super-blocks.
pub fn dequantize_row(blocks: &[BlockQ3K]) -> Vec<f32> {
    let mut out = vec![0.0f32; blocks.len() * QK_K];
    let mut buf = [0.0f32; QK_K];
    for (i, b) in blocks.iter().enumerate() {
        b.dequantize(&mut buf);
        out[i * QK_K..(i + 1) * QK_K].copy_from_slice(&buf);
    }
    out
}

/// `vec_dot_q3_K_q8_K`: Q3_K weights × Q8_K activations.
///
/// Per super-block: unpack signed 3-bit weights, multiply with the 8-bit
/// activations into 16-bit products, weight each 16-group by its signed
/// 6-bit scale into i32 accumulators, then one f32 multiply by
/// `d_w * d_a`. This integer-dominant structure is what the paper maps
/// onto 51 PEs with `OP_CVT53` + `OP_SML8` + `OP_AD24`.
pub fn vec_dot(w: &[BlockQ3K], a: &[super::q8_k::BlockQ8K]) -> f32 {
    assert_eq!(w.len(), a.len(), "row super-block count mismatch");
    let mut sumf = 0.0f32;
    for (bw, ba) in w.iter().zip(a.iter()) {
        let scales = bw.unpack_scales();
        let q = bw.unpack_quants();
        let mut isum: i32 = 0;
        for j in 0..16 {
            let mut group: i32 = 0;
            for l in 0..16 {
                let idx = 16 * j + l;
                group += q[idx] as i32 * ba.qs[idx] as i32;
            }
            isum += scales[j] as i32 * group;
        }
        sumf += bw.d.to_f32() * ba.d * isum as f32;
    }
    sumf
}

// ---------------------------------------------------------------------------
// IMAX restructuring (paper §III-B)
// ---------------------------------------------------------------------------

/// A Q3_K super-block restructured into the operand stream the IMAX
/// kernel consumes: unified 3-bit quants (stored as `q + 4` in `[0, 7]`)
/// and 5-bit approximated scales, as produced in hardware by `OP_CVT53`.
#[derive(Debug, Clone, PartialEq)]
pub struct ImaxQ3Stream {
    /// `q + 4` for each element, one packed 3-bit value per entry.
    pub q3: [u8; QK_K],
    /// 5-bit signed scales in `[-16, 15]`; effective scale is `2 * s5`.
    pub scales5: [i8; 16],
    /// Unchanged f16 super-scale.
    pub d: F16,
}

/// Restructure a block for IMAX: pack 2-bit + 1-bit into 3-bit, round the
/// 6-bit scales to 5 bits (effective value `2 * round(sc / 2)`).
pub fn to_imax_stream(b: &BlockQ3K) -> ImaxQ3Stream {
    let mut q3 = [0u8; QK_K];
    for (idx, (q, &signed)) in q3.iter_mut().zip(b.unpack_quants().iter()).enumerate() {
        let _ = idx;
        *q = (signed + 4) as u8;
    }
    let scales = b.unpack_scales();
    let mut scales5 = [0i8; 16];
    for (s5, &s6) in scales5.iter_mut().zip(scales.iter()) {
        *s5 = div2_round(s6).clamp(-16, 15);
    }
    ImaxQ3Stream { q3, scales5, d: b.d }
}

/// Round-half-away division by two (what a shift-with-round unit does).
#[inline]
fn div2_round(s: i8) -> i8 {
    let v = s as i32;
    ((v + if v >= 0 { 1 } else { -1 }) / 2) as i8
}

/// Dequantize through the restructured (5-bit scale) representation —
/// used to measure the approximation error the paper calls negligible.
pub fn dequantize_row_imax5(blocks: &[BlockQ3K]) -> Vec<f32> {
    let mut out = Vec::with_capacity(blocks.len() * QK_K);
    for b in blocks {
        let s = to_imax_stream(b);
        let d = s.d.to_f32();
        for idx in 0..QK_K {
            let dl = d * (2 * s.scales5[idx / 16]) as f32;
            out.push(dl * (s.q3[idx] as i32 - 4) as f32);
        }
    }
    out
}

/// Q3_K×Q8_K dot through the IMAX-restructured operands (5-bit scales) —
/// the arithmetic the simulator's Q3_K kernel performs.
pub fn vec_dot_imax5(w: &[BlockQ3K], a: &[super::q8_k::BlockQ8K]) -> f32 {
    assert_eq!(w.len(), a.len());
    let mut sumf = 0.0f32;
    for (bw, ba) in w.iter().zip(a.iter()) {
        let s = to_imax_stream(bw);
        let mut isum: i32 = 0;
        for j in 0..16 {
            let mut group: i32 = 0;
            for l in 0..16 {
                let idx = 16 * j + l;
                group += (s.q3[idx] as i32 - 4) * ba.qs[idx] as i32;
            }
            isum += 2 * s.scales5[j] as i32 * group;
        }
        sumf += s.d.to_f32() * ba.d * isum as f32;
    }
    sumf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ggml::q8_k;
    use crate::util::rng::Xoshiro256pp;

    fn random_row(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn scale_pack_unpack_round_trip() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        for _ in 0..200 {
            let mut scales = [0i8; 16];
            for s in &mut scales {
                *s = rng.range_i64(-32, 31) as i8;
            }
            let packed = BlockQ3K::pack_scales(&scales);
            let blk = BlockQ3K { scales: packed, ..Default::default() };
            assert_eq!(blk.unpack_scales(), scales);
        }
    }

    #[test]
    fn quant_extraction_covers_full_range() {
        // Build a block with known quants via quantize of a crafted input
        // and verify every element decodes within [-4, 3].
        let x: Vec<f32> = random_row(QK_K, 2);
        let b = BlockQ3K::quantize(x.as_slice().try_into().unwrap());
        for idx in 0..QK_K {
            let q = b.quant(idx);
            assert!((-4..=3).contains(&q), "q={q} at {idx}");
        }
    }

    #[test]
    fn zero_block() {
        let b = BlockQ3K::quantize(&[0.0; QK_K]);
        assert_eq!(b.d, F16::ZERO);
        let mut out = [1.0f32; QK_K];
        b.dequantize(&mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn quantization_relative_error_reasonable() {
        // 3-bit with per-16 scales: expect coarse but bounded error on
        // smooth gaussian data.
        let x: Vec<f32> = random_row(QK_K * 4, 3);
        let blocks = quantize_row(&x);
        let back = dequantize_row(&blocks);
        let num: f32 = x.iter().zip(back.iter()).map(|(a, b)| (a - b).powi(2)).sum();
        let den: f32 = x.iter().map(|a| a * a).sum();
        let rel_rmse = (num / den).sqrt();
        assert!(rel_rmse < 0.25, "relative RMSE {rel_rmse} too high for Q3_K");
    }

    #[test]
    fn bytes_round_trip() {
        let x: Vec<f32> = random_row(QK_K, 4);
        let b = BlockQ3K::quantize(x.as_slice().try_into().unwrap());
        assert_eq!(BlockQ3K::from_bytes(&b.to_bytes()), b);
        assert_eq!(BlockQ3K::BYTES, 110);
    }

    #[test]
    fn dot_matches_dequant_reference() {
        // vec_dot must equal the f32 dot of (dequant weights, dequant
        // activations) up to f32 summation order noise.
        let n = QK_K * 2;
        let xw = random_row(n, 5);
        let xa = random_row(n, 6);
        let w = quantize_row(&xw);
        let a = q8_k::quantize_row(&xa);
        let got = vec_dot(&w, &a);

        let dw = dequantize_row(&w);
        let da = q8_k::dequantize_row(&a);
        let reference: f32 = dw.iter().zip(da.iter()).map(|(x, y)| x * y).sum();
        let tol = 1e-3 * reference.abs().max(1.0);
        assert!((got - reference).abs() < tol, "got {got}, ref {reference}");
    }

    #[test]
    fn dot_approximates_f32_dot() {
        let n = QK_K * 4;
        let xw = random_row(n, 7);
        let xa = random_row(n, 8);
        let w = quantize_row(&xw);
        let a = q8_k::quantize_row(&xa);
        let got = vec_dot(&w, &a);
        let truth: f32 = xw.iter().zip(xa.iter()).map(|(x, y)| x * y).sum();
        // 3-bit quantization noise over n=1024 gaussian terms: loose bound.
        let scale = (n as f32).sqrt();
        assert!(
            (got - truth).abs() < 0.5 * scale,
            "got {got}, truth {truth}, n {n}"
        );
    }

    #[test]
    fn imax_stream_is_faithful_repack() {
        // The 3-bit repack itself is lossless: q3 - 4 == quant(idx).
        let x: Vec<f32> = random_row(QK_K, 9);
        let b = BlockQ3K::quantize(x.as_slice().try_into().unwrap());
        let s = to_imax_stream(&b);
        for idx in 0..QK_K {
            assert_eq!(s.q3[idx] as i32 - 4, b.quant(idx) as i32);
            assert!(s.q3[idx] <= 7, "3-bit envelope violated");
        }
    }

    #[test]
    fn imax5_scale_approx_error_small() {
        // Paper §III-B: 5-bit scale approximation has "almost no effect".
        // Quantify: relative RMSE increase must stay under 6 % absolute.
        let x: Vec<f32> = random_row(QK_K * 8, 10);
        let blocks = quantize_row(&x);
        let exact = dequantize_row(&blocks);
        let approx = dequantize_row_imax5(&blocks);
        let den: f32 = x.iter().map(|a| a * a).sum();
        let rmse = |y: &[f32]| {
            let num: f32 = x.iter().zip(y.iter()).map(|(a, b)| (a - b).powi(2)).sum();
            (num / den).sqrt()
        };
        let (e_exact, e_approx) = (rmse(&exact), rmse(&approx));
        assert!(
            e_approx - e_exact < 0.06,
            "5-bit scales cost {} RMSE (exact {e_exact}, approx {e_approx})",
            e_approx - e_exact
        );
    }

    #[test]
    fn imax5_dot_close_to_exact_dot() {
        let n = QK_K * 4;
        let xw = random_row(n, 11);
        let xa = random_row(n, 12);
        let w = quantize_row(&xw);
        let a = q8_k::quantize_row(&xa);
        let exact = vec_dot(&w, &a);
        let approx = vec_dot_imax5(&w, &a);
        let denom = exact.abs().max(1.0);
        assert!(
            (exact - approx).abs() / denom < 0.15,
            "exact {exact} vs imax5 {approx}"
        );
    }

    #[test]
    fn div2_round_half_away() {
        assert_eq!(div2_round(3), 2);
        assert_eq!(div2_round(-3), -2);
        assert_eq!(div2_round(4), 2);
        assert_eq!(div2_round(-4), -2);
        assert_eq!(div2_round(1), 1);
        assert_eq!(div2_round(-1), -1);
        assert_eq!(div2_round(0), 0);
    }

    #[test]
    fn hmask_layout_matches_ggml_bit_order() {
        // Element idx uses bit (idx / 32) of byte (idx % 32).
        let mut b = BlockQ3K::default();
        // Set hmask for element 37: byte 5, bit 1.
        b.hmask[5] = 1 << 1;
        // Element 37 low2 = 0 -> with hbit set, quant = 0; without, -4.
        assert_eq!(b.quant(37), 0);
        assert_eq!(b.quant(36), -4);
    }

    #[test]
    fn qs_layout_matches_ggml_shift_planes() {
        let mut b = BlockQ3K::default();
        // Give every element the hbit so values are the raw low2 bits.
        b.hmask = [0xFF; 32];
        // Element 96 (half 0, shift plane 3, byte 0): set bits 6..7 of qs[0].
        b.qs[0] = 0b11 << 6;
        assert_eq!(b.quant(96), 3);
        // Element 128 (half 1, plane 0, byte 32): low bits of qs[32].
        b.qs[32] = 0b10;
        assert_eq!(b.quant(128), 2);
        assert_eq!(b.quant(0), 0);
    }
}
