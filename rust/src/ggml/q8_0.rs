//! `Q8_0`: 8-bit block quantization (GGML `block_q8_0`).
//!
//! 32 elements per block, one shared f16 scale:
//! `x[j] ≈ d * qs[j]`, `qs ∈ [-127, 127]`, `d = max|x| / 127`.
//!
//! The dot product of two Q8_0 rows is the kernel the paper maps onto 46
//! IMAX PEs (Fig. 3): 8-bit multiplies accumulated into wide integers
//! (`OP_SML8` → 24-bit, `OP_AD24` aggregation) followed by a single f32
//! multiply with `d_a * d_b` per block.

use super::{nearest_i32, QK8_0};
use crate::util::f16::F16;

/// One 34-byte Q8_0 block: scale + 32 signed bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockQ8_0 {
    /// Block scale (stored as f16, exactly as GGML does).
    pub d: F16,
    /// Quantized values.
    pub qs: [i8; QK8_0],
}

impl Default for BlockQ8_0 {
    fn default() -> Self {
        BlockQ8_0 { d: F16::ZERO, qs: [0; QK8_0] }
    }
}

impl BlockQ8_0 {
    /// Serialized size in bytes (2 + 32), the paper's DMA-volume unit.
    pub const BYTES: usize = 2 + QK8_0;

    /// Quantize 32 floats.
    pub fn quantize(x: &[f32; QK8_0]) -> BlockQ8_0 {
        let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let d = amax / 127.0;
        let id = if d != 0.0 { 1.0 / d } else { 0.0 };
        let mut qs = [0i8; QK8_0];
        for (q, &v) in qs.iter_mut().zip(x.iter()) {
            *q = nearest_i32(v * id).clamp(-127, 127) as i8;
        }
        BlockQ8_0 { d: F16::from_f32(d), qs }
    }

    /// Dequantize into 32 floats.
    pub fn dequantize(&self, out: &mut [f32; QK8_0]) {
        let d = self.d.to_f32();
        for (o, &q) in out.iter_mut().zip(self.qs.iter()) {
            *o = d * q as f32;
        }
    }

    /// Serialize to GGML's on-disk layout (little-endian f16, then qs).
    pub fn to_bytes(&self) -> [u8; Self::BYTES] {
        let mut out = [0u8; Self::BYTES];
        out[..2].copy_from_slice(&self.d.0.to_le_bytes());
        for (o, &q) in out[2..].iter_mut().zip(self.qs.iter()) {
            *o = q as u8;
        }
        out
    }

    /// Parse from GGML's on-disk layout.
    pub fn from_bytes(b: &[u8]) -> BlockQ8_0 {
        assert_eq!(b.len(), Self::BYTES);
        let d = F16(u16::from_le_bytes([b[0], b[1]]));
        let mut qs = [0i8; QK8_0];
        for (q, &byte) in qs.iter_mut().zip(b[2..].iter()) {
            *q = byte as i8;
        }
        BlockQ8_0 { d, qs }
    }
}

/// Quantize a row; `x.len()` must be a multiple of 32.
pub fn quantize_row(x: &[f32]) -> Vec<BlockQ8_0> {
    assert!(
        x.len() % QK8_0 == 0,
        "Q8_0 rows must be a multiple of {QK8_0} (got {})",
        x.len()
    );
    x.chunks_exact(QK8_0)
        .map(|c| BlockQ8_0::quantize(c.try_into().unwrap()))
        .collect()
}

/// Dequantize a row of blocks.
pub fn dequantize_row(blocks: &[BlockQ8_0]) -> Vec<f32> {
    let mut out = vec![0.0f32; blocks.len() * QK8_0];
    let mut buf = [0.0f32; QK8_0];
    for (i, b) in blocks.iter().enumerate() {
        b.dequantize(&mut buf);
        out[i * QK8_0..(i + 1) * QK8_0].copy_from_slice(&buf);
    }
    out
}

/// `vec_dot_q8_0_q8_0`: the exact arithmetic the IMAX Q8_0 kernel performs.
///
/// Per block: 32 signed 8-bit products summed exactly in i32 (IMAX chains
/// `OP_SML8`/`OP_AD24` into a 24-bit accumulator, which cannot overflow:
/// `32 * 127 * 127 = 516_128 < 2^23`), then scaled by `d_a * d_b` in f32.
pub fn vec_dot(a: &[BlockQ8_0], b: &[BlockQ8_0]) -> f32 {
    assert_eq!(a.len(), b.len(), "row block-count mismatch");
    let mut acc = 0.0f32;
    for (ba, bb) in a.iter().zip(b.iter()) {
        let mut isum: i32 = 0;
        for (&qa, &qb) in ba.qs.iter().zip(bb.qs.iter()) {
            isum += qa as i32 * qb as i32;
        }
        acc += isum as f32 * ba.d.to_f32() * bb.d.to_f32();
    }
    acc
}

/// Worst-case magnitude of a per-block integer accumulator — the invariant
/// that lets IMAX use 24-bit adders (`OP_AD24`).
pub const MAX_BLOCK_ISUM: i32 = (QK8_0 as i32) * 127 * 127;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn random_row(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| r.normal() * scale).collect()
    }

    #[test]
    fn zero_block() {
        let b = BlockQ8_0::quantize(&[0.0; QK8_0]);
        assert_eq!(b.d.to_f32(), 0.0);
        assert!(b.qs.iter().all(|&q| q == 0));
    }

    #[test]
    fn quantize_error_bound() {
        // |x - dequant(quant(x))| <= d/2 + f16 rounding of d.
        let x: Vec<f32> = random_row(QK8_0, 1, 2.0);
        let b = BlockQ8_0::quantize(x.as_slice().try_into().unwrap());
        let d = b.d.to_f32();
        let mut out = [0.0; QK8_0];
        b.dequantize(&mut out);
        for (orig, deq) in x.iter().zip(out.iter()) {
            assert!(
                (orig - deq).abs() <= 0.5 * d * 1.01 + 1e-6,
                "error {} exceeds half-step {}",
                (orig - deq).abs(),
                0.5 * d
            );
        }
    }

    #[test]
    fn max_magnitude_hits_127() {
        let mut x = [0.0f32; QK8_0];
        x[5] = -3.0; // largest magnitude
        x[9] = 1.5;
        let b = BlockQ8_0::quantize(&x);
        assert_eq!(b.qs[5], -127);
        assert_eq!(b.qs[9], 64); // 1.5/ (3/127) = 63.5 -> 64 (round half away)
    }

    #[test]
    fn bytes_round_trip() {
        let x: Vec<f32> = random_row(QK8_0, 2, 1.0);
        let b = BlockQ8_0::quantize(x.as_slice().try_into().unwrap());
        let back = BlockQ8_0::from_bytes(&b.to_bytes());
        assert_eq!(b, back);
    }

    #[test]
    fn row_quantize_shape_checked() {
        assert_eq!(quantize_row(&vec![0.0; 96]).len(), 3);
    }

    #[test]
    #[should_panic(expected = "multiple of 32")]
    fn row_quantize_rejects_ragged() {
        quantize_row(&vec![0.0; 33]);
    }

    #[test]
    fn dot_matches_float_reference() {
        // Quantized dot must approximate the f32 dot of the dequantized
        // rows *exactly* (same arithmetic), and the original rows closely.
        let n = 256;
        let xa = random_row(n, 3, 1.0);
        let xb = random_row(n, 4, 1.0);
        let qa = quantize_row(&xa);
        let qb = quantize_row(&xb);
        let got = vec_dot(&qa, &qb);

        // Reference over dequantized values.
        let da = dequantize_row(&qa);
        let db = dequantize_row(&qb);
        let ref_deq: f32 = da.iter().zip(db.iter()).map(|(a, b)| a * b).sum();
        assert!(
            (got - ref_deq).abs() < 1e-2 * ref_deq.abs().max(1.0),
            "got {got}, dequant-ref {ref_deq}"
        );

        // And the true f32 dot within quantization noise.
        let true_dot: f32 = xa.iter().zip(xb.iter()).map(|(a, b)| a * b).sum();
        let rel = (got - true_dot).abs() / true_dot.abs().max(1.0);
        assert!(rel < 0.05, "relative error {rel} vs f32 dot");
    }

    #[test]
    fn isum_fits_24_bits() {
        assert!(MAX_BLOCK_ISUM < (1 << 23), "OP_AD24 would overflow");
        // Adversarial block: all +127 × all -127.
        let a = BlockQ8_0 { d: F16::ONE, qs: [127; QK8_0] };
        let b = BlockQ8_0 { d: F16::ONE, qs: [-127; QK8_0] };
        let got = vec_dot(&[a], &[b]);
        assert_eq!(got, -(MAX_BLOCK_ISUM as f32));
    }

    #[test]
    fn dot_is_symmetric() {
        let qa = quantize_row(&random_row(128, 7, 0.5));
        let qb = quantize_row(&random_row(128, 8, 0.5));
        // Symmetric up to f32 multiply ordering ((i*da)*db vs (i*db)*da).
        let (ab, ba) = (vec_dot(&qa, &qb), vec_dot(&qb, &qa));
        assert!((ab - ba).abs() <= 1e-6 + 1e-4 * ab.abs(), "{ab} vs {ba}");
    }
}
