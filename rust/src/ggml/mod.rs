//! Bit-faithful reimplementation of the GGML block-quantization formats
//! used by `stable-diffusion.cpp` and offloaded to IMAX3 in the paper.
//!
//! The paper reuses the quantized dot-product kernels of the GGML tensor
//! library (`Q8_0` 8-bit blocks and `Q3_K` 3-bit k-quant super-blocks) for
//! the U-Net's linear layers. This module reproduces:
//!
//! * the exact block layouts (`block_q8_0` = f16 scale + 32×i8,
//!   `block_q3_K` = 32 B hmask + 64 B low-2-bit + 12 B packed 6-bit scales +
//!   f16 super-scale, `block_q8_K` activation blocks with per-16 bsums),
//! * quantize / dequantize rows,
//! * the integer vec-dot kernels (`vec_dot_q8_0_q8_0`,
//!   `vec_dot_q3_K_q8_K`) whose arithmetic IMAX executes with `OP_SML8` /
//!   `OP_AD24` / `OP_CVT53`,
//! * the paper's IMAX-specific **Q3_K restructuring** (6-bit scales
//!   approximated to 5 bits, 2+1-bit quants repacked to 3 bits) together
//!   with an ablation of its accuracy cost (§III-B: "almost no effect"),
//! * an f32/f16/quantized [`tensor::Tensor`] and the GGML-style
//!   `mul_mat` used by the graph executor in [`crate::sd`].

pub mod dot;
pub mod q3_k;
pub mod q8_0;
pub mod q8_k;
pub mod tensor;

pub use dot::{mul_mat, vec_dot};
pub use tensor::{DType, Tensor, WeightId};

/// Elements per Q8_0 block.
pub const QK8_0: usize = 32;
/// Elements per k-quant super-block.
pub const QK_K: usize = 256;

/// Round-to-nearest (ties away from zero), matching GGML's `nearest_int`
/// on the value ranges quantization produces.
#[inline]
pub(crate) fn nearest_i32(x: f32) -> i32 {
    // GGML uses magic-number float rounding equivalent to rint() in
    // round-half-to-even mode... except it applies it to scaled values
    // where ties are vanishingly rare; llama.cpp's scalar fallback is
    // lroundf. We use round-half-away like lroundf.
    x.round() as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_matches_lround_semantics() {
        assert_eq!(nearest_i32(2.5), 3);
        assert_eq!(nearest_i32(-2.5), -3);
        assert_eq!(nearest_i32(2.4), 2);
        assert_eq!(nearest_i32(-2.4), -2);
        assert_eq!(nearest_i32(0.0), 0);
    }
}
