//! A GGML-style 2-D tensor holding f32, f16, or quantized rows.
//!
//! Weight matrices live row-major with each row independently quantized
//! (exactly like GGML: a `[M, K]` weight has `M` rows of `K/block`
//! blocks). Activations stay f32 until a `mul_mat` quantizes them on the
//! fly into the vec-dot partner format (`Q8_0` for `Q8_0`, `Q8_K` for
//! `Q3_K`), which is GGML's `ggml_compute_forward_mul_mat` flow.

use super::{q3_k, q8_0, q8_k, QK8_0, QK_K};
use crate::util::f16::F16;

/// Element/storage type of a tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 16-bit float.
    F16,
    /// 8-bit block quantization (32/block, f16 scale).
    Q8_0,
    /// 3-bit k-quant (256/super-block).
    Q3K,
    /// 8-bit k-quant activation format (256/super-block, f32 scale+bsums).
    Q8K,
}

impl DType {
    /// Bytes per element-block / elements per block, for size accounting.
    pub fn block_size(self) -> usize {
        match self {
            DType::F32 | DType::F16 => 1,
            DType::Q8_0 => QK8_0,
            DType::Q3K | DType::Q8K => QK_K,
        }
    }

    /// Bytes occupied by one block (one element for float types).
    pub fn block_bytes(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F16 => 2,
            DType::Q8_0 => q8_0::BlockQ8_0::BYTES,
            DType::Q3K => q3_k::BlockQ3K::BYTES,
            DType::Q8K => 4 + QK_K + 2 * (QK_K / 16),
        }
    }

    /// Bytes needed to store `n` elements (must divide evenly).
    pub fn row_bytes(self, n: usize) -> usize {
        assert!(n % self.block_size() == 0, "{n} not a multiple of {:?} block", self);
        n / self.block_size() * self.block_bytes()
    }

    /// Effective bits per weight (the paper's model-size axis).
    pub fn bits_per_weight(self) -> f64 {
        self.row_bytes(self.block_size().max(256)) as f64 * 8.0
            / self.block_size().max(256) as f64
    }

    /// Short name matching GGML spelling.
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "F32",
            DType::F16 => "F16",
            DType::Q8_0 => "Q8_0",
            DType::Q3K => "Q3_K",
            DType::Q8K => "Q8_K",
        }
    }
}

/// Stable content identity of a model weight tensor.
///
/// Assigned by [`crate::sd::weights::WeightFactory`] from the weight's
/// `(seed, layer name, dtype)` triple, so the same logical weight names
/// the same bytes across denoising steps, serving requests, pipelines
/// and processes. Everything above the kernel level keys on this id:
/// the LMM residency cache ([`crate::imax::lmm::Lmm`]), the
/// residency-aware lane scheduler
/// ([`crate::coordinator::Coordinator`]) and the serving batcher's
/// cross-request rendezvous ([`crate::serve::batcher`]). Activation
/// tensors and ad-hoc tensors carry no id (`Tensor::wid == None`) and
/// are always treated as transient.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WeightId(pub u64);

/// Tensor storage.
#[derive(Debug, Clone)]
pub enum Storage {
    /// Plain f32 values.
    F32(Vec<f32>),
    /// f16 values (stored as raw bits).
    F16(Vec<F16>),
    /// Q8_0 blocks, `cols / 32` per row.
    Q8_0(Vec<q8_0::BlockQ8_0>),
    /// Q3_K super-blocks, `cols / 256` per row.
    Q3K(Vec<q3_k::BlockQ3K>),
    /// Q8_K super-blocks, `cols / 256` per row.
    Q8K(Vec<q8_k::BlockQ8K>),
}

/// A 2-D tensor `[rows, cols]`, row-major.
#[derive(Debug, Clone)]
pub struct Tensor {
    /// Number of rows (output features for weights).
    pub rows: usize,
    /// Number of columns (the contraction dimension K).
    pub cols: usize,
    /// Storage payload.
    pub data: Storage,
    /// Weight identity, when this tensor is a named model weight.
    ///
    /// `None` for activations and ad-hoc tensors. Deliberately *not*
    /// propagated by [`Tensor::quantize`]/[`Tensor::to_f32`]: the id
    /// names one exact byte content, and re-encoded bytes are a
    /// different content.
    pub wid: Option<WeightId>,
}

impl Tensor {
    /// f32 tensor from data.
    pub fn f32(rows: usize, cols: usize, data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), rows * cols);
        Tensor { rows, cols, data: Storage::F32(data), wid: None }
    }

    /// Tag this tensor with a weight identity (builder style).
    pub fn with_wid(mut self, wid: WeightId) -> Tensor {
        self.wid = Some(wid);
        self
    }

    /// f32 tensor of zeros.
    pub fn zeros(rows: usize, cols: usize) -> Tensor {
        Tensor::f32(rows, cols, vec![0.0; rows * cols])
    }

    /// f16 tensor from f32 data (rounded).
    pub fn f16_from(rows: usize, cols: usize, data: &[f32]) -> Tensor {
        assert_eq!(data.len(), rows * cols);
        Tensor {
            rows,
            cols,
            data: Storage::F16(data.iter().map(|&v| F16::from_f32(v)).collect()),
            wid: None,
        }
    }

    /// DType of the storage.
    pub fn dtype(&self) -> DType {
        match &self.data {
            Storage::F32(_) => DType::F32,
            Storage::F16(_) => DType::F16,
            Storage::Q8_0(_) => DType::Q8_0,
            Storage::Q3K(_) => DType::Q3K,
            Storage::Q8K(_) => DType::Q8K,
        }
    }

    /// Total elements.
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serialized byte size (the DMA-volume unit for offload modelling).
    pub fn byte_size(&self) -> usize {
        self.rows * self.dtype().row_bytes(self.cols)
    }

    /// Quantize an f32 tensor's rows into `dtype`.
    pub fn quantize(&self, dtype: DType) -> Tensor {
        let src = match &self.data {
            Storage::F32(v) => v,
            _ => panic!("quantize expects an f32 source tensor"),
        };
        let data = match dtype {
            DType::F32 => Storage::F32(src.clone()),
            DType::F16 => Storage::F16(src.iter().map(|&v| F16::from_f32(v)).collect()),
            DType::Q8_0 => {
                let mut blocks = Vec::with_capacity(self.rows * self.cols / QK8_0);
                for r in 0..self.rows {
                    blocks.extend(q8_0::quantize_row(&src[r * self.cols..(r + 1) * self.cols]));
                }
                Storage::Q8_0(blocks)
            }
            DType::Q3K => {
                let mut blocks = Vec::with_capacity(self.rows * self.cols / QK_K);
                for r in 0..self.rows {
                    blocks.extend(q3_k::quantize_row(&src[r * self.cols..(r + 1) * self.cols]));
                }
                Storage::Q3K(blocks)
            }
            DType::Q8K => {
                let mut blocks = Vec::with_capacity(self.rows * self.cols / QK_K);
                for r in 0..self.rows {
                    blocks.extend(q8_k::quantize_row(&src[r * self.cols..(r + 1) * self.cols]));
                }
                Storage::Q8K(blocks)
            }
        };
        Tensor { rows: self.rows, cols: self.cols, data, wid: None }
    }

    /// Dequantize/convert to a fresh f32 tensor.
    pub fn to_f32(&self) -> Tensor {
        let data = match &self.data {
            Storage::F32(v) => v.clone(),
            Storage::F16(v) => v.iter().map(|h| h.to_f32()).collect(),
            Storage::Q8_0(blocks) => q8_0::dequantize_row(blocks),
            Storage::Q3K(blocks) => q3_k::dequantize_row(blocks),
            Storage::Q8K(blocks) => q8_k::dequantize_row(blocks),
        };
        Tensor::f32(self.rows, self.cols, data)
    }

    /// Borrow f32 data (panics for non-f32 storage).
    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            Storage::F32(v) => v,
            other => panic!("expected f32 storage, got {:?}", dtype_of(other)),
        }
    }

    /// One f32 row.
    pub fn row_f32(&self, r: usize) -> &[f32] {
        let v = self.as_f32();
        &v[r * self.cols..(r + 1) * self.cols]
    }

    /// Blocks-per-row for quantized storage.
    pub fn blocks_per_row(&self) -> usize {
        self.cols / self.dtype().block_size()
    }
}

fn dtype_of(s: &Storage) -> DType {
    match s {
        Storage::F32(_) => DType::F32,
        Storage::F16(_) => DType::F16,
        Storage::Q8_0(_) => DType::Q8_0,
        Storage::Q3K(_) => DType::Q3K,
        Storage::Q8K(_) => DType::Q8K,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn random(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let mut v = vec![0.0f32; rows * cols];
        r.fill_normal(&mut v, 1.0);
        Tensor::f32(rows, cols, v)
    }

    #[test]
    fn byte_sizes_match_ggml() {
        // Q8_0: 34 bytes / 32 weights = 8.5 bpw. Q3_K: 110 / 256 = 3.4375.
        let t = random(4, 256, 1);
        assert_eq!(t.quantize(DType::Q8_0).byte_size(), 4 * 8 * 34);
        assert_eq!(t.quantize(DType::Q3K).byte_size(), 4 * 110);
        assert_eq!(t.byte_size(), 4 * 256 * 4);
        assert_eq!(t.quantize(DType::F16).byte_size(), 4 * 256 * 2);
    }

    #[test]
    fn bits_per_weight() {
        assert!((DType::Q8_0.bits_per_weight() - 8.5).abs() < 1e-9);
        assert!((DType::Q3K.bits_per_weight() - 3.4375).abs() < 1e-9);
        assert_eq!(DType::F32.bits_per_weight(), 32.0);
    }

    #[test]
    fn quantize_dequantize_f16_exact_for_halves() {
        let t = Tensor::f32(1, 32, (0..32).map(|i| i as f32 * 0.25).collect());
        let h = t.quantize(DType::F16);
        assert_eq!(h.to_f32().as_f32(), t.as_f32());
    }

    #[test]
    fn q8_0_round_trip_close() {
        let t = random(3, 64, 2);
        let q = t.quantize(DType::Q8_0);
        let back = q.to_f32();
        for (a, b) in t.as_f32().iter().zip(back.as_f32().iter()) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn rows_quantized_independently() {
        // Changing one row must not change another row's blocks.
        let mut base = random(2, 256, 3);
        let q1 = base.quantize(DType::Q3K);
        if let Storage::F32(v) = &mut base.data {
            for x in v[256..512].iter_mut() {
                *x *= 5.0;
            }
        }
        let q2 = base.quantize(DType::Q3K);
        let (b1, b2) = match (&q1.data, &q2.data) {
            (Storage::Q3K(a), Storage::Q3K(b)) => (a, b),
            _ => unreachable!(),
        };
        assert_eq!(b1[0], b2[0], "row 0 blocks must be unchanged");
        assert_ne!(b1[1], b2[1], "row 1 blocks must differ");
    }

    #[test]
    #[should_panic(expected = "expected f32 storage")]
    fn as_f32_type_checked() {
        random(1, 32, 4).quantize(DType::Q8_0).as_f32();
    }

    #[test]
    fn row_accessor() {
        let t = Tensor::f32(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row_f32(1), &[4., 5., 6.]);
    }

    #[test]
    fn weight_id_tagging_and_non_propagation() {
        let t = random(1, 32, 5).with_wid(WeightId(42));
        assert_eq!(t.wid, Some(WeightId(42)));
        // Re-encoding produces different bytes => identity must not leak.
        assert_eq!(t.quantize(DType::Q8_0).wid, None);
        assert_eq!(t.to_f32().wid, None);
        // Plain constructors are untagged.
        assert_eq!(Tensor::zeros(2, 2).wid, None);
        assert_eq!(Tensor::f16_from(1, 2, &[0.5, 1.0]).wid, None);
    }
}
