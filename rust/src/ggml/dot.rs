//! Vec-dot dispatch and the GGML-style `mul_mat`.
//!
//! `mul_mat(w, x)` computes `out[n, m] = Σ_k w[m, k] · x[n, k]` — GGML's
//! convention where both operands are row-major with rows of length K and
//! the weight tensor supplies M rows. Activations are quantized once per
//! call into the weight type's vec-dot partner (`Q8_0`→`Q8_0`,
//! `Q3_K`→`Q8_K`), then every output element is one quantized vec-dot.
//! This is the exact op `stable-diffusion.cpp` dispatches for every
//! linear/conv(im2col) layer, and the unit of offload in the paper.

use super::tensor::{DType, Storage, Tensor};
use super::{q3_k, q8_0, q8_k};
use crate::util::pool::parallel_chunks;

/// Dot product of two f32 slices (the F32 "kernel" that stays on host).
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: keeps the compiler vectorizing and
    // matches GGML's split-accumulator summation order closely enough.
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Dot product of an f16 row with an f32 activation row (GGML's
/// `F16 × F32 -> F32` path used for conv/im2col weights).
pub fn dot_f16_f32(a: &[crate::util::f16::F16], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f32;
    for (x, y) in a.iter().zip(b.iter()) {
        s += x.to_f32() * y;
    }
    s
}

/// Generic quantized vec-dot between one weight row and one pre-quantized
/// activation row, dispatched on the weight dtype.
pub fn vec_dot(w: &Tensor, w_row: usize, act: &QuantizedActs, a_row: usize) -> f32 {
    let bpr = w.blocks_per_row();
    match (&w.data, act) {
        (Storage::Q8_0(blocks), QuantizedActs::Q8_0(acts)) => {
            let wb = &blocks[w_row * bpr..(w_row + 1) * bpr];
            let ab = &acts[a_row * bpr..(a_row + 1) * bpr];
            q8_0::vec_dot(wb, ab)
        }
        (Storage::Q3K(blocks), QuantizedActs::Q8K(acts)) => {
            let wb = &blocks[w_row * bpr..(w_row + 1) * bpr];
            let ab = &acts[a_row * bpr..(a_row + 1) * bpr];
            q3_k::vec_dot(wb, ab)
        }
        _ => panic!("mismatched weight/activation quantization pairing"),
    }
}

/// Activations quantized into the vec-dot partner format of a weight type.
pub enum QuantizedActs {
    /// Partner of `Q8_0` weights.
    Q8_0(Vec<q8_0::BlockQ8_0>),
    /// Partner of `Q3_K` weights.
    Q8K(Vec<q8_k::BlockQ8K>),
}

/// Quantize an `[N, K]` f32 activation tensor into the partner format for
/// `weight_dtype` (GGML's "quantize src1 once, reuse per row" step).
pub fn quantize_acts(x: &Tensor, weight_dtype: DType) -> QuantizedActs {
    let data = x.as_f32();
    match weight_dtype {
        DType::Q8_0 => {
            let mut blocks = Vec::with_capacity(x.len() / 32);
            for r in 0..x.rows {
                blocks.extend(q8_0::quantize_row(&data[r * x.cols..(r + 1) * x.cols]));
            }
            QuantizedActs::Q8_0(blocks)
        }
        DType::Q3K => {
            let mut blocks = Vec::with_capacity(x.len() / 256);
            for r in 0..x.rows {
                blocks.extend(q8_k::quantize_row(&data[r * x.cols..(r + 1) * x.cols]));
            }
            QuantizedActs::Q8K(blocks)
        }
        other => panic!("no activation partner for {other:?}"),
    }
}

/// `out[n, m] = Σ_k w[m, k] * x[n, k]`, parallelized across `threads`
/// host workers over the N dimension (GGML parallelizes identically).
pub fn mul_mat(w: &Tensor, x: &Tensor, threads: usize) -> Tensor {
    assert_eq!(w.cols, x.cols, "contraction dim mismatch: {} vs {}", w.cols, x.cols);
    let (m, n, _k) = (w.rows, x.rows, w.cols);
    let mut out = vec![0.0f32; n * m];

    match w.dtype() {
        DType::F32 => {
            let wd = w.as_f32();
            let xd = x.as_f32();
            let out_ptr = SendPtr(out.as_mut_ptr());
            parallel_chunks(n, threads, |s, e| {
                let out_ptr = &out_ptr;
                for nn in s..e {
                    let xr = &xd[nn * x.cols..(nn + 1) * x.cols];
                    for mm in 0..m {
                        let wr = &wd[mm * w.cols..(mm + 1) * w.cols];
                        // SAFETY: each (nn, mm) cell written exactly once,
                        // rows partitioned disjointly across workers.
                        unsafe { *out_ptr.0.add(nn * m + mm) = dot_f32(wr, xr) };
                    }
                }
            });
        }
        DType::F16 => {
            let wd = match &w.data {
                Storage::F16(v) => v,
                _ => unreachable!(),
            };
            let xd = x.as_f32();
            let out_ptr = SendPtr(out.as_mut_ptr());
            parallel_chunks(n, threads, |s, e| {
                let out_ptr = &out_ptr;
                for nn in s..e {
                    let xr = &xd[nn * x.cols..(nn + 1) * x.cols];
                    for mm in 0..m {
                        let wr = &wd[mm * w.cols..(mm + 1) * w.cols];
                        unsafe { *out_ptr.0.add(nn * m + mm) = dot_f16_f32(wr, xr) };
                    }
                }
            });
        }
        DType::Q8_0 | DType::Q3K => {
            let acts = quantize_acts(x, w.dtype());
            let out_ptr = SendPtr(out.as_mut_ptr());
            parallel_chunks(n, threads, |s, e| {
                let out_ptr = &out_ptr;
                for nn in s..e {
                    for mm in 0..m {
                        let v = vec_dot(w, mm, &acts, nn);
                        unsafe { *out_ptr.0.add(nn * m + mm) = v };
                    }
                }
            });
        }
        DType::Q8K => panic!("Q8_K is an activation-only format"),
    }
    Tensor::f32(n, m, out)
}

/// Raw pointer wrapper asserting Send/Sync for disjoint-write parallelism.
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn random(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let mut v = vec![0.0f32; rows * cols];
        r.fill_normal(&mut v, 0.5);
        Tensor::f32(rows, cols, v)
    }

    fn naive_matmul(w: &Tensor, x: &Tensor) -> Vec<f32> {
        let mut out = vec![0.0f32; x.rows * w.rows];
        for n in 0..x.rows {
            for m in 0..w.rows {
                let mut s = 0.0;
                for k in 0..w.cols {
                    s += w.as_f32()[m * w.cols + k] * x.as_f32()[n * x.cols + k];
                }
                out[n * w.rows + m] = s;
            }
        }
        out
    }

    #[test]
    fn dot_f32_matches_naive() {
        let a = random(1, 100, 1);
        let b = random(1, 100, 2);
        let naive: f32 = a.as_f32().iter().zip(b.as_f32().iter()).map(|(x, y)| x * y).sum();
        assert!((dot_f32(a.as_f32(), b.as_f32()) - naive).abs() < 1e-4);
    }

    #[test]
    fn f32_mul_mat_exact() {
        let w = random(5, 64, 3);
        let x = random(7, 64, 4);
        let got = mul_mat(&w, &x, 1);
        let want = naive_matmul(&w, &x);
        assert_eq!(got.rows, 7);
        assert_eq!(got.cols, 5);
        for (g, w_) in got.as_f32().iter().zip(want.iter()) {
            assert!((g - w_).abs() < 1e-4);
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let w = random(9, 128, 5);
        let x = random(13, 128, 6);
        let a = mul_mat(&w, &x, 1);
        let b = mul_mat(&w, &x, 4);
        assert_eq!(a.as_f32(), b.as_f32(), "thread count must not change results");
    }

    #[test]
    fn f16_mul_mat_close_to_f32() {
        let w = random(4, 96, 7);
        let x = random(3, 96, 8);
        let exact = mul_mat(&w, &x, 1);
        let wh = w.quantize(DType::F16);
        let got = mul_mat(&wh, &x, 1);
        for (g, e) in got.as_f32().iter().zip(exact.as_f32().iter()) {
            assert!((g - e).abs() < 0.02 * e.abs().max(1.0), "{g} vs {e}");
        }
    }

    #[test]
    fn q8_0_mul_mat_close_to_f32() {
        let w = random(6, 128, 9);
        let x = random(5, 128, 10);
        let exact = mul_mat(&w, &x, 1);
        let got = mul_mat(&w.quantize(DType::Q8_0), &x, 2);
        for (g, e) in got.as_f32().iter().zip(exact.as_f32().iter()) {
            assert!((g - e).abs() < 0.05 * e.abs().max(1.0), "{g} vs {e}");
        }
    }

    #[test]
    fn q3_k_mul_mat_tracks_f32() {
        let w = random(4, 512, 11);
        let x = random(3, 512, 12);
        let exact = mul_mat(&w, &x, 1);
        let got = mul_mat(&w.quantize(DType::Q3K), &x, 2);
        // 3-bit weights: coarse; dot of 512 gaussian terms has std ~sqrt(512)/4.
        for (g, e) in got.as_f32().iter().zip(exact.as_f32().iter()) {
            assert!((g - e).abs() < 3.0, "{g} vs {e}");
        }
    }

    #[test]
    #[should_panic(expected = "contraction dim mismatch")]
    fn shape_mismatch_panics() {
        mul_mat(&random(2, 32, 13), &random(2, 64, 14), 1);
    }

    #[test]
    #[should_panic(expected = "activation-only")]
    fn q8k_weights_rejected() {
        let w = random(2, 256, 15).quantize(DType::Q8K);
        mul_mat(&w, &random(2, 256, 16), 1);
    }
}
