//! PJRT client wrapper + artifact registry with a compile cache.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One compiled HLO artifact ready to execute.
pub struct CompiledArtifact {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact file name (for reports).
    pub name: String,
}

impl CompiledArtifact {
    /// Execute with literal inputs; returns the untupled output literals.
    ///
    /// Artifacts are lowered with `return_tuple=True`, so the single
    /// result is a tuple we decompose.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.decompose_tuple()?)
    }

    /// Execute and read output 0 as an `f32` vector.
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let outs = self.run(inputs)?;
        Ok(outs[0].to_vec::<f32>()?)
    }
}

/// The runtime: one PJRT CPU client + compiled-executable cache.
pub struct ArtifactRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, CompiledArtifact>,
}

impl ArtifactRuntime {
    /// Create over an artifact directory.
    pub fn new(dir: impl AsRef<Path>) -> Result<ArtifactRuntime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(ArtifactRuntime { client, dir: dir.as_ref().to_path_buf(), cache: HashMap::new() })
    }

    /// Platform string (e.g. "cpu") for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by file name.
    pub fn load(&mut self, name: &str) -> Result<&CompiledArtifact> {
        if !self.cache.contains_key(name) {
            let path = self.dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path utf-8")?,
            )
            .with_context(|| format!("parse HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
            self.cache
                .insert(name.to_string(), CompiledArtifact { exe, name: name.to_string() });
        }
        Ok(&self.cache[name])
    }

    /// Number of compiled artifacts resident.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}

/// Build an `i8` (S8) literal of shape `[rows, cols]` from a slice.
pub fn literal_i8(data: &[i8], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), rows * cols);
    // The crate has no i8 NativeType; go through the untyped-bytes path.
    let bytes: &[u8] = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S8,
        &[rows, cols],
        bytes,
    )?)
}

/// Build an `f32` literal of shape `[rows, cols]` from a slice.
pub fn literal_f32(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), rows * cols);
    let lit = xla::Literal::vec1(data);
    Ok(lit.reshape(&[rows as i64, cols as i64])?)
}
