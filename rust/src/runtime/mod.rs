//! PJRT runtime: load and execute the AOT artifacts from rust.
//!
//! Python runs once (`make artifacts`) to lower the L2 jax graph +
//! L1 Pallas kernels to HLO **text**; this module wraps the `xla`
//! crate's PJRT CPU client to compile those artifacts and execute them
//! on the request path with zero python. Text is the interchange format
//! because the crate's xla_extension 0.5.1 rejects jax ≥ 0.5's
//! 64-bit-id serialized protos (see /opt/xla-example/README.md).

pub mod client;

pub use client::{ArtifactRuntime, CompiledArtifact};

/// Default artifact directory relative to the repo root.
pub const ARTIFACT_DIR: &str = "artifacts";

/// Names of the artifacts `make artifacts` produces.
pub const ARTIFACTS: [&str; 4] = [
    "model.hlo.txt",
    "q8_0_matmul.hlo.txt",
    "q3k_matmul.hlo.txt",
    "f16_matmul.hlo.txt",
];

/// Locate the artifact directory from the current dir or ancestors
/// (tests run from the workspace root; examples may run elsewhere).
pub fn find_artifact_dir() -> Option<std::path::PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join(ARTIFACT_DIR);
        if cand.join(ARTIFACTS[0]).exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}
