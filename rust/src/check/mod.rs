//! Concurrency-correctness subsystem: machine-checked evidence that
//! the crate's hand-rolled synchronization (lane pool, lockstep
//! rendezvous, cancel CAS, drain ordering) is deadlock-free and
//! determinism-preserving.
//!
//! Three cooperating analyses (see DESIGN.md, "Lock hierarchy &
//! invariants catalog"):
//!
//! - [`sched`] — a deterministic bounded interleaving explorer
//!   (sleep-set DPOR cut + preemption bound) run over the [`models`]
//!   of the four hot protocols; proves no-deadlock / no-lost-wakeup /
//!   schedule-invariant outputs over *every* bounded schedule, and
//!   convicts the seeded mutants in `tests/conc_check.rs`.
//! - [`lockorder`] — a runtime held-locks witness (cycle detection +
//!   rank hierarchy) that the `conc-check` feature wires into every
//!   [`crate::util::sync`] acquire/release.
//! - [`lint`] — a text-level project lint over `rust/src/`
//!   (`cargo run --example lint`, gating in CI): predicate loops
//!   around condvar waits, no raw `std::sync` primitives outside the
//!   shim, poisoning policy, submit/sync pairing.

pub mod lint;
pub mod lockorder;
pub mod models;
pub mod sched;
