//! Runtime lock-order witness: records the global lock-acquisition
//! graph and fails on any **potential**-deadlock edge pair — a cycle in
//! acquisition order is reported the first time both directions have
//! ever been *observed*, long before a schedule actually interleaves
//! them into a deadlock.
//!
//! Two independent checks run on every nested acquisition:
//!
//! 1. **Cycle check.** Acquiring `B` while holding `A` adds the edge
//!    `A → B`. If `B` can already reach `A` through recorded edges, the
//!    pair closes a cycle: some pair of threads can each hold one lock
//!    and want the other. The full cycle path is reported.
//! 2. **Rank check.** Locks carry the declared hierarchy position from
//!    [`crate::util::sync::rank`]; a nested acquisition whose rank is
//!    not strictly greater than every *ranked* lock already held is an
//!    inversion even if no reverse edge has been observed yet (the
//!    hierarchy is the spec, the graph is the evidence).
//!
//! Re-entrant acquisition of the same lock is reported too — the shim's
//! mutexes are non-recursive, so that is a guaranteed self-deadlock.
//!
//! Under the `conc-check` feature, [`crate::util::sync::Mutex`] routes
//! every acquire/release (including the release/reacquire inside
//! [`crate::util::sync::Condvar::wait`]) through the [`global`]
//! witness, which panics on the first violation so the owning test
//! fails loudly. The same machinery is usable stand-alone (collect
//! mode) for unit tests and for replaying traces from the
//! [`crate::check::sched`] explorer.
//!
//! The witness's own state cell is the one deliberate raw
//! `std::sync::Mutex` outside `util/sync.rs` (it cannot instrument
//! itself); the lint pass allowlists exactly this file.

use std::collections::{BTreeMap, BTreeSet};

/// Identity + metadata of one lock instance at an acquisition site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LockTag {
    /// Process-unique lock instance id (minted by [`mint_lock_id`]).
    pub id: usize,
    /// Hierarchy position (`0` = unranked, exempt from the rank rule).
    pub rank: u16,
    /// Static label for reports.
    pub name: &'static str,
}

/// What the witness records per observed edge `from → to`.
#[derive(Debug, Clone)]
struct EdgeInfo {
    from_name: &'static str,
    to_name: &'static str,
}

#[derive(Default)]
struct WitnessState {
    /// Adjacency: lock id → set of lock ids acquired while holding it.
    edges: BTreeMap<usize, BTreeMap<usize, EdgeInfo>>,
    /// Per-thread stack of currently-held tags.
    held: BTreeMap<u64, Vec<LockTag>>,
    violations: Vec<String>,
    acquisitions: u64,
}

/// The held-locks-graph recorder. `panic_on_violation` selects between
/// fail-fast mode (the global conc-check witness: first violation
/// panics inside the offending test) and collect mode (unit tests and
/// trace replay: violations accumulate for inspection).
pub struct LockOrderWitness {
    // Deliberate raw std mutex: the witness cannot route through the
    // shim it instruments. Allowlisted by the lint pass.
    state: std::sync::Mutex<WitnessState>,
    panic_on_violation: bool,
}

impl LockOrderWitness {
    /// An empty witness.
    pub fn new(panic_on_violation: bool) -> LockOrderWitness {
        LockOrderWitness {
            state: std::sync::Mutex::new(WitnessState::default()),
            panic_on_violation,
        }
    }

    /// Record that the current thread is acquiring `tag`, checking the
    /// cycle and rank rules against everything the thread already
    /// holds. Call **before** blocking on the real lock — a potential
    /// deadlock must be reported even when this particular schedule
    /// would have survived it.
    pub fn acquire(&self, tag: LockTag) {
        self.acquire_as(current_thread_key(), tag);
    }

    /// [`LockOrderWitness::acquire`] with an explicit thread key
    /// (trace replay from the explorer's schedules).
    pub fn acquire_as(&self, thread: u64, tag: LockTag) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        st.acquisitions += 1;
        let held = st.held.entry(thread).or_default().clone();
        let mut found: Vec<String> = Vec::new();
        for h in &held {
            if h.id == tag.id {
                found.push(format!(
                    "re-entrant acquisition of `{}` (id {}) — non-recursive mutex self-deadlock",
                    tag.name, tag.id
                ));
                continue;
            }
            if h.rank != 0 && tag.rank != 0 && tag.rank <= h.rank {
                found.push(format!(
                    "rank inversion: acquiring `{}` (rank {}) while holding `{}` (rank {})",
                    tag.name, tag.rank, h.name, h.rank
                ));
            }
            // Would-be edge h → tag. A path tag →* h closes a cycle.
            if let Some(path) = reach_path(&st.edges, tag.id, h.id) {
                let names: Vec<&str> = path
                    .windows(2)
                    .filter_map(|w| st.edges.get(&w[0]).and_then(|m| m.get(&w[1])))
                    .map(|e| e.to_name)
                    .collect();
                found.push(format!(
                    "potential deadlock: edge `{}` → `{}` closes a cycle (reverse path {} → {})",
                    h.name,
                    tag.name,
                    tag.name,
                    names.join(" → ")
                ));
            }
            st.edges
                .entry(h.id)
                .or_default()
                .entry(tag.id)
                .or_insert(EdgeInfo { from_name: h.name, to_name: tag.name });
        }
        st.held.entry(thread).or_default().push(tag);
        st.violations.extend(found.iter().cloned());
        drop(st);
        if self.panic_on_violation {
            if let Some(v) = found.first() {
                panic!("lock-order witness: {v}");
            }
        }
    }

    /// Record that the current thread released lock `id` (guard drop or
    /// the release half of a condvar wait).
    pub fn release(&self, id: usize) {
        self.release_as(current_thread_key(), id);
    }

    /// [`LockOrderWitness::release`] with an explicit thread key.
    pub fn release_as(&self, thread: u64, id: usize) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(stack) = st.held.get_mut(&thread) {
            // Releases are almost always LIFO but guards can drop out of
            // order: remove the matching entry wherever it sits.
            if let Some(pos) = stack.iter().rposition(|t| t.id == id) {
                stack.remove(pos);
            }
        }
    }

    /// All violations recorded so far (collect mode).
    pub fn violations(&self) -> Vec<String> {
        self.state.lock().unwrap_or_else(|p| p.into_inner()).violations.clone()
    }

    /// Number of directed edges observed (held → acquired pairs).
    pub fn edge_count(&self) -> usize {
        let st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        st.edges.values().map(|m| m.len()).sum()
    }

    /// Total acquisitions witnessed.
    pub fn acquisitions(&self) -> u64 {
        self.state.lock().unwrap_or_else(|p| p.into_inner()).acquisitions
    }
}

/// BFS path `from →* to` over the recorded edges (inclusive node list),
/// or `None` when unreachable.
fn reach_path(
    edges: &BTreeMap<usize, BTreeMap<usize, EdgeInfo>>,
    from: usize,
    to: usize,
) -> Option<Vec<usize>> {
    let mut prev: BTreeMap<usize, usize> = BTreeMap::new();
    let mut queue = std::collections::VecDeque::from([from]);
    let mut seen = BTreeSet::from([from]);
    while let Some(n) = queue.pop_front() {
        if n == to {
            let mut path = vec![to];
            let mut cur = to;
            while cur != from {
                cur = prev[&cur];
                path.push(cur);
            }
            path.reverse();
            return Some(path);
        }
        if let Some(next) = edges.get(&n) {
            for &m in next.keys() {
                if seen.insert(m) {
                    prev.insert(m, n);
                    queue.push_back(m);
                }
            }
        }
    }
    None
}

static NEXT_LOCK_ID: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(1);

/// Mint a process-unique nonzero lock id (the shim assigns them lazily
/// so `Mutex::new` stays const).
pub fn mint_lock_id() -> usize {
    NEXT_LOCK_ID.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
}

static NEXT_THREAD_KEY: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

thread_local! {
    static THREAD_KEY: u64 =
        NEXT_THREAD_KEY.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
}

/// Stable per-thread key (dense, unlike `ThreadId`'s opaque handle).
pub fn current_thread_key() -> u64 {
    THREAD_KEY.with(|k| *k)
}

/// The process-global witness the `conc-check` shim reports into.
/// Fail-fast: the first potential-deadlock edge pair or rank inversion
/// panics inside the acquiring test.
pub fn global() -> &'static LockOrderWitness {
    static GLOBAL: std::sync::OnceLock<LockOrderWitness> = std::sync::OnceLock::new();
    GLOBAL.get_or_init(|| LockOrderWitness::new(true))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tag(id: usize, rank: u16, name: &'static str) -> LockTag {
        LockTag { id, rank, name }
    }

    #[test]
    fn ordered_nesting_is_clean() {
        let w = LockOrderWitness::new(false);
        let (a, b) = (tag(1, 10, "a"), tag(2, 20, "b"));
        for _ in 0..3 {
            w.acquire_as(1, a);
            w.acquire_as(1, b);
            w.release_as(1, b.id);
            w.release_as(1, a.id);
        }
        assert!(w.violations().is_empty(), "{:?}", w.violations());
        assert_eq!(w.edge_count(), 1);
        assert_eq!(w.acquisitions(), 6);
    }

    #[test]
    fn inverted_edge_pair_is_a_potential_deadlock_even_without_deadlocking() {
        // Sequential execution — no actual deadlock is possible — but
        // the two acquisition orders A→B and B→A have both been seen.
        let w = LockOrderWitness::new(false);
        let (a, b) = (tag(1, 0, "a"), tag(2, 0, "b"));
        w.acquire_as(1, a);
        w.acquire_as(1, b);
        w.release_as(1, b.id);
        w.release_as(1, a.id);
        w.acquire_as(2, b);
        w.acquire_as(2, a); // closes the cycle
        let v = w.violations();
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("potential deadlock"), "{v:?}");
        assert!(v[0].contains('a') && v[0].contains('b'));
    }

    #[test]
    fn transitive_cycles_are_found() {
        // a→b (thread 1), b→c (thread 2), c→a (thread 3) — no pair
        // inverts directly; the cycle is length 3.
        let w = LockOrderWitness::new(false);
        let (a, b, c) = (tag(1, 0, "a"), tag(2, 0, "b"), tag(3, 0, "c"));
        for (t, (x, y)) in [(1u64, (a, b)), (2, (b, c))] {
            w.acquire_as(t, x);
            w.acquire_as(t, y);
            w.release_as(t, y.id);
            w.release_as(t, x.id);
        }
        assert!(w.violations().is_empty());
        w.acquire_as(3, c);
        w.acquire_as(3, a);
        let v = w.violations();
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("potential deadlock"), "{v:?}");
    }

    #[test]
    fn rank_inversion_is_reported_before_any_reverse_edge_exists() {
        let w = LockOrderWitness::new(false);
        let lane = tag(1, crate::util::sync::rank::IMAX_LANE, "imax.lane");
        let batch = tag(2, crate::util::sync::rank::SERVE_BATCH, "serve.batch");
        w.acquire_as(1, lane);
        w.acquire_as(1, batch); // 30 <= 50: inversion by declared rank
        let v = w.violations();
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("rank inversion"), "{v:?}");
    }

    #[test]
    fn unranked_locks_skip_the_rank_rule_but_not_the_cycle_rule() {
        let w = LockOrderWitness::new(false);
        let (a, b) = (tag(1, 0, "ad-hoc-a"), tag(2, 40, "ranked-b"));
        // Unranked under ranked and vice versa: no rank finding.
        w.acquire_as(1, b);
        w.acquire_as(1, a);
        w.release_as(1, a.id);
        w.release_as(1, b.id);
        assert!(w.violations().is_empty(), "{:?}", w.violations());
    }

    #[test]
    fn reentrant_acquisition_is_a_self_deadlock() {
        let w = LockOrderWitness::new(false);
        let a = tag(1, 0, "a");
        w.acquire_as(1, a);
        w.acquire_as(1, a);
        let v = w.violations();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("re-entrant"), "{v:?}");
    }

    #[test]
    fn disjoint_threads_never_interact() {
        let w = LockOrderWitness::new(false);
        let (a, b) = (tag(1, 0, "a"), tag(2, 0, "b"));
        w.acquire_as(1, a);
        w.acquire_as(2, b); // different thread: no edge, no violation
        assert_eq!(w.edge_count(), 0);
        assert!(w.violations().is_empty());
    }

    #[test]
    #[should_panic(expected = "lock-order witness")]
    fn fail_fast_mode_panics_on_first_violation() {
        let w = LockOrderWitness::new(true);
        let a = tag(1, 0, "a");
        w.acquire_as(1, a);
        w.acquire_as(1, a);
    }

    #[test]
    fn mint_ids_are_unique_and_nonzero() {
        let a = mint_lock_id();
        let b = mint_lock_id();
        assert!(a != 0 && b != 0 && a != b);
    }
}
