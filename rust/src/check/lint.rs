//! Text-level concurrency lint over `rust/src/`, run as a cargo
//! example (`cargo run --example lint`) and gating in CI.
//!
//! Four project invariants, matching the zero-dependency style of the
//! rest of the crate (no syn/proc-macro parse — a comment/string-aware
//! line scanner with brace tracking is enough for the patterns these
//! rules target, and it keeps the lint runnable anywhere the crate
//! builds):
//!
//! - **`condvar-wait-loop`** — every `Condvar::wait(guard)` call must
//!   sit inside a `loop`/`while` block, the predicate re-check that
//!   makes spurious and broadcast wakeups safe. (`CompletionSlot::wait()`
//!   takes no guard argument and loops internally; zero-argument
//!   `.wait()` calls are exempt.)
//! - **`raw-sync-primitive`** — no `std::sync::{Mutex, Condvar,
//!   RwLock, Barrier}` outside `util/sync.rs` (the shim itself) and
//!   `check/lockorder.rs` (the witness cannot instrument itself).
//!   Everything else must use the shim so the `conc-check` feature can
//!   observe it. `Arc`, atomics, `mpsc`, and `OnceLock` stay raw —
//!   they carry no lock-order or wakeup obligations.
//! - **`lock-poison-unwrap`** — no `.lock().unwrap()`: the shim's
//!   `lock()` owns the poisoning policy (panic with the lock's name),
//!   and drain/shutdown/request paths use `lock_or_abort` so a
//!   panicked worker cannot cascade into a hung drain (policy in
//!   DESIGN.md).
//! - **`submit-without-sync`** — a file whose non-test code calls
//!   `.submit(` / `.submit_to(` / `.start_sharded(` must also contain
//!   a matching completion call (`.sync(`, `.join_sharded(`, or a
//!   `.wait(` on a completion slot). A per-file textual
//!   reachability check, deliberately coarse: it catches the real
//!   failure mode (a fire-and-forget submission whose handle is
//!   dropped on the floor), not arbitrary inter-procedural flows.
//!
//! `#[cfg(test)]` modules are skipped: the invariants protect
//! production paths, and fixtures deliberately violate them.

use std::path::Path;

/// One lint hit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the lint root (stable across machines).
    pub file: String,
    /// 1-indexed source line.
    pub line: usize,
    /// Rule id (kebab-case, stable for CI grepping).
    pub rule: &'static str,
    /// Human-readable explanation.
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Files exempt from a rule (shim + witness internals).
fn allowlisted(file: &str, rule: &'static str) -> bool {
    let shim = file.ends_with("util/sync.rs");
    let witness = file.ends_with("check/lockorder.rs");
    match rule {
        "condvar-wait-loop" => shim, // the shim *implements* wait
        "raw-sync-primitive" => shim || witness,
        "lock-poison-unwrap" => shim || witness,
        _ => false,
    }
}

/// Blank comments and string/char-literal *contents* (keeping line
/// length) so brace tracking and pattern matching only see code.
/// Returns the blanked line and the block-comment state after it.
fn blank_noncode(line: &str, mut in_block_comment: bool) -> (String, bool) {
    let bytes: Vec<char> = line.chars().collect();
    let mut out = String::with_capacity(line.len());
    let mut i = 0;
    let mut in_string = false;
    let mut in_char = false;
    while i < bytes.len() {
        let c = bytes[i];
        let next = bytes.get(i + 1).copied();
        if in_block_comment {
            if c == '*' && next == Some('/') {
                in_block_comment = false;
                out.push_str("  ");
                i += 2;
            } else {
                out.push(' ');
                i += 1;
            }
        } else if in_string {
            if c == '\\' {
                out.push_str("  ");
                i += 2;
            } else if c == '"' {
                in_string = false;
                out.push('"');
                i += 1;
            } else {
                out.push(' ');
                i += 1;
            }
        } else if in_char {
            if c == '\\' {
                out.push_str("  ");
                i += 2;
            } else if c == '\'' {
                in_char = false;
                out.push('\'');
                i += 1;
            } else {
                out.push(' ');
                i += 1;
            }
        } else if c == '/' && next == Some('/') {
            break; // line comment: drop the rest
        } else if c == '/' && next == Some('*') {
            in_block_comment = true;
            out.push_str("  ");
            i += 2;
        } else if c == '"' {
            in_string = true;
            out.push('"');
            i += 1;
        } else if c == '\'' {
            // Lifetime (`'a`) vs char literal: a char literal closes
            // with a quote within a few chars; a lifetime never does.
            let is_char_lit = matches!(next, Some(n) if n == '\\')
                || bytes.get(i + 2).copied() == Some('\'');
            if is_char_lit {
                in_char = true;
            }
            out.push('\'');
            i += 1;
        } else {
            out.push(c);
            i += 1;
        }
    }
    (out, in_block_comment)
}

#[derive(Clone, Copy, PartialEq)]
enum Block {
    Loop,
    Plain,
    TestMod,
}

fn is_ident_char(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Whole-word occurrence check on a blanked code line.
fn has_keyword(code: &str, word: &str) -> bool {
    let b = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let start = from + pos;
        let end = start + word.len();
        let ok_before = start == 0 || !is_ident_char(b[start - 1]);
        let ok_after = end == code.len() || !is_ident_char(b[end]);
        if ok_before && ok_after {
            return true;
        }
        from = end;
    }
    false
}

const RAW_PRIMITIVES: [&str; 4] = ["Mutex", "Condvar", "RwLock", "Barrier"];

/// Lint one file's source. `file` is the path label used in findings
/// and allowlists (use forward slashes).
pub fn lint_source(file: &str, src: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut in_block_comment = false;
    // Brace-tracked block stack; each entry is one `{`.
    let mut stack: Vec<Block> = Vec::new();
    let mut pending_loop = false;
    let mut pending_cfg_test = false;
    let mut test_depth: Option<usize> = None;

    let mut submit_sites: Vec<usize> = Vec::new();
    let mut sync_sites = 0usize;

    for (idx, raw) in src.lines().enumerate() {
        let lineno = idx + 1;
        let (code, next_state) = blank_noncode(raw, in_block_comment);
        in_block_comment = next_state;
        let trimmed = code.trim();
        if trimmed.starts_with("#[cfg(test)]") {
            pending_cfg_test = true;
        } else if pending_cfg_test && !trimmed.is_empty() && !trimmed.starts_with("#[") {
            if has_keyword(trimmed, "mod") && test_depth.is_none() {
                test_depth = Some(stack.len());
            }
            pending_cfg_test = false;
        }
        let in_test = test_depth.is_some_and(|d| stack.len() > d || trimmed.contains('{'));

        let in_loop_before = stack.iter().any(|b| *b == Block::Loop);

        // Scan the line's braces, classifying each opened block.
        let mut header_is_loop =
            pending_loop || has_keyword(&code, "loop") || has_keyword(&code, "while");
        let mut seen_open_on_line = false;
        for ch in code.chars() {
            match ch {
                '{' => {
                    // The first `{` after a `loop`/`while` keyword (or
                    // a `#[cfg(test)] mod` header) owns that role; any
                    // further braces on the line are plain blocks.
                    let kind = if test_depth == Some(stack.len()) && !seen_open_on_line {
                        Block::TestMod
                    } else if header_is_loop {
                        header_is_loop = false;
                        Block::Loop
                    } else {
                        Block::Plain
                    };
                    stack.push(kind);
                    seen_open_on_line = true;
                }
                '}' => {
                    stack.pop();
                    if test_depth.is_some_and(|d| stack.len() <= d) {
                        test_depth = None;
                    }
                }
                _ => {}
            }
        }
        pending_loop = header_is_loop
            && !code.contains('{')
            && (has_keyword(&code, "loop") || has_keyword(&code, "while"));

        if in_test || test_depth.is_some() {
            continue;
        }

        // Rule: condvar-wait-loop — `.wait(<guard>)` needs a loop.
        if !allowlisted(file, "condvar-wait-loop") {
            let mut from = 0;
            while let Some(pos) = code[from..].find(".wait(") {
                let start = from + pos;
                let at = start + ".wait(".len();
                let rest = code[at..].trim_start();
                // Guarded if an enclosing loop block was already open,
                // or this very line opens one before the wait
                // (single-line `while p { g = cv.wait(g); }`).
                let guarded = in_loop_before
                    || has_keyword(&code[..start], "loop")
                    || has_keyword(&code[..start], "while");
                if !rest.starts_with(')') && !guarded {
                    findings.push(Finding {
                        file: file.to_string(),
                        line: lineno,
                        rule: "condvar-wait-loop",
                        message: "Condvar::wait(guard) outside a predicate re-check loop \
                                  (spurious/broadcast wakeups make single waits unsound)"
                            .to_string(),
                    });
                }
                from = at;
            }
        }

        // Rule: raw-sync-primitive.
        if !allowlisted(file, "raw-sync-primitive") {
            let mut from = 0;
            while let Some(pos) = code[from..].find("std::sync::") {
                let at = from + pos + "std::sync::".len();
                let rest = &code[at..];
                let hit = if rest.starts_with('{') {
                    RAW_PRIMITIVES.iter().find(|p| {
                        rest[1..rest.find('}').unwrap_or(rest.len())]
                            .split(',')
                            .any(|item| item.trim() == **p)
                    })
                } else {
                    RAW_PRIMITIVES.iter().find(|p| {
                        rest.starts_with(**p)
                            && !rest.as_bytes().get(p.len()).is_some_and(|&c| is_ident_char(c))
                    })
                };
                if let Some(p) = hit {
                    findings.push(Finding {
                        file: file.to_string(),
                        line: lineno,
                        rule: "raw-sync-primitive",
                        message: format!(
                            "raw std::sync::{p} outside util/sync.rs — use the \
                             crate::util::sync shim so conc-check can observe it"
                        ),
                    });
                }
                from = at;
            }
        }

        // Rule: lock-poison-unwrap.
        if !allowlisted(file, "lock-poison-unwrap") && code.contains(".lock().unwrap()") {
            findings.push(Finding {
                file: file.to_string(),
                line: lineno,
                rule: "lock-poison-unwrap",
                message: ".lock().unwrap() bypasses the poisoning policy — use the \
                          sync shim's lock() or lock_or_abort() (see DESIGN.md)"
                    .to_string(),
            });
        }

        // Rule: submit-without-sync (per-file accumulation).
        for pat in [".submit(", ".submit_to(", ".start_sharded("] {
            if code.contains(pat) {
                submit_sites.push(lineno);
            }
        }
        for pat in [".sync(", ".join_sharded(", ".wait("] {
            if code.contains(pat) {
                sync_sites += 1;
            }
        }
    }

    if !submit_sites.is_empty() && sync_sites == 0 {
        findings.push(Finding {
            file: file.to_string(),
            line: submit_sites[0],
            rule: "submit-without-sync",
            message: format!(
                "{} submit call(s) with no .sync()/.join_sharded()/.wait() in this \
                 file — a dropped OpHandle never merges its counters",
                submit_sites.len()
            ),
        });
    }

    findings
}

/// Recursively lint every `*.rs` file under `root`, labeling findings
/// with paths relative to `root`. Files are visited in sorted order so
/// output is deterministic.
pub fn lint_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for path in files {
        let src = std::fs::read_to_string(&path)?;
        let label = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        findings.extend(lint_source(&label, &src));
    }
    Ok(findings)
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn unguarded_condvar_wait_is_flagged() {
        let bad = r#"
fn f(cv: &Condvar, m: &Mutex<bool>) {
    let mut g = m.lock();
    if !*g {
        g = cv.wait(g);
    }
}
"#;
        let f = lint_source("serve/fixture.rs", bad);
        assert_eq!(rules(&f), vec!["condvar-wait-loop"], "{f:?}");
        assert_eq!(f[0].line, 5);
    }

    #[test]
    fn wait_inside_predicate_loop_is_clean() {
        let good = r#"
fn f(cv: &Condvar, m: &Mutex<bool>) {
    let mut g = m.lock();
    while !*g {
        g = cv.wait(g);
    }
    loop {
        if *g { break; }
        g = cv.wait(g);
    }
}
"#;
        let f = lint_source("serve/fixture.rs", good);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn zero_arg_completion_wait_is_exempt() {
        let good = "fn f(s: &CompletionSlot<u8>) -> u8 { s.wait() }\n";
        assert!(lint_source("sd/fixture.rs", good).is_empty());
    }

    #[test]
    fn doc_comment_wait_examples_are_ignored() {
        let good = "/// let g = cv.wait(g); // docs, not code\nfn f() {}\n";
        assert!(lint_source("sd/fixture.rs", good).is_empty());
    }

    #[test]
    fn raw_primitives_flagged_outside_the_shim() {
        let bad = "use std::sync::{Arc, Mutex};\nstatic C: std::sync::Condvar = std::sync::Condvar::new();\n";
        let f = lint_source("serve/fixture.rs", bad);
        assert_eq!(rules(&f), vec!["raw-sync-primitive", "raw-sync-primitive"], "{f:?}");
        let ok = "use std::sync::Arc;\nuse std::sync::atomic::AtomicUsize;\nuse std::sync::mpsc;\n";
        assert!(lint_source("serve/fixture.rs", ok).is_empty());
    }

    #[test]
    fn shim_and_witness_are_allowlisted() {
        let raw = "use std::sync::{Mutex, Condvar};\n";
        assert!(lint_source("util/sync.rs", raw).is_empty());
        assert!(lint_source("check/lockorder.rs", raw).is_empty());
        assert!(!lint_source("serve/queue.rs", raw).is_empty());
    }

    #[test]
    fn lock_unwrap_is_flagged() {
        let bad = "fn f(m: &std::sync::Mutex<u8>) { *m.lock().unwrap() += 1; }\n";
        let f = lint_source("server/fixture.rs", bad);
        assert_eq!(rules(&f), vec!["raw-sync-primitive", "lock-poison-unwrap"], "{f:?}");
    }

    #[test]
    fn cfg_test_modules_are_skipped() {
        let src = r#"
fn prod() {}
#[cfg(test)]
mod tests {
    use std::sync::Mutex;
    fn t(m: &Mutex<u8>) { *m.lock().unwrap() += 1; }
}
"#;
        assert!(lint_source("serve/fixture.rs", src).is_empty());
    }

    #[test]
    fn submit_without_sync_is_flagged_per_file() {
        let bad = "fn f(b: &B) { let _h = b.submit(op()); }\n";
        let f = lint_source("sd/fixture.rs", bad);
        assert_eq!(rules(&f), vec!["submit-without-sync"], "{f:?}");
        let good = "fn f(b: &B) { let h = b.submit(op()); b.sync(h); }\n";
        assert!(lint_source("sd/fixture.rs", good).is_empty());
    }

    #[test]
    fn string_literals_do_not_confuse_brace_tracking() {
        let src = "fn f() {\n    let s = format!(\"{} open {{\", 1);\n    let mut g = m.lock();\n    while !*g { g = cv.wait(g); }\n}\n";
        assert!(lint_source("sd/fixture.rs", src).is_empty(), "braces in strings miscounted");
    }

    #[test]
    fn real_tree_has_zero_findings() {
        // The gating claim, also enforced by `cargo run --example
        // lint` in CI: the crate's own sources are clean.
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let findings = lint_tree(&root).expect("lint walk");
        assert!(
            findings.is_empty(),
            "lint findings on rust/src:\n{}",
            findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
