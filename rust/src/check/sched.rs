//! Deterministic interleaving explorer: a loom-style, zero-dependency
//! bounded model checker for the crate's hot synchronization protocols.
//!
//! A protocol is expressed as a [`Model`]: a handful of threads, each a
//! small explicit-PC state machine over shared state, where one
//! [`Model::step`] is one *atomic* action (a monitor section for the
//! coarse models, a single lock/read/notify for the fine-grained ones).
//! [`explore`] then runs a depth-first search over every choice of
//! which enabled thread steps next, checking after every step that the
//! model's safety invariant holds, and at every terminal state that
//! either all threads finished (no deadlock) and the final-state check
//! passes, or reporting the exact schedule that got stuck.
//!
//! Two cuts keep the search exhaustive-but-bounded:
//!
//! - **Sleep sets (DPOR-lite).** After exploring thread `t` from a
//!   state, `t` is added to the *sleep set* for the sibling branches;
//!   a sleeping thread is only woken (removed) when a later step is
//!   *dependent* on its next action (touches the same object with at
//!   least one write) or changes its enabledness. Schedules that only
//!   commute independent steps are never revisited.
//! - **Preemption bound.** Switching away from a thread that is still
//!   enabled costs one preemption; schedules exceeding the bound are
//!   pruned (and counted). Empirically almost all concurrency bugs
//!   need ≤ 2 preemptions; the default sweep uses bound 3.
//!
//! Determinism matters doubly here: the search itself is deterministic
//! (threads tried in ascending id order, no randomness), so the
//! explored-schedule counts are exact, reproducible constants — pinned
//! in `tests/conc_check.rs` and cross-checked against an independent
//! Python implementation (`python/replica/conc_check_replica.py`).
//!
//! The [`Report::results`] set carries each model's schedule-invariance
//! claim: every complete schedule contributes its stitched
//! output/merged counters as a string, and the set must end up with at
//! most one element — bit-identity over *all* bounded schedules, not a
//! handful of stress-test repetitions.

use std::collections::BTreeSet;

/// One shared-object touch performed by a step; the dependence relation
/// for the sleep-set cut. Two accesses conflict iff they touch the same
/// object id and at least one writes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Access {
    /// Model-assigned shared-object id (mutex, counter, slot, ...).
    pub obj: usize,
    /// Whether the touch mutates the object (waitset changes count).
    pub write: bool,
}

impl Access {
    /// Read touch on `obj`.
    pub fn read(obj: usize) -> Access {
        Access { obj, write: false }
    }
    /// Write touch on `obj`.
    pub fn write(obj: usize) -> Access {
        Access { obj, write: true }
    }
}

fn conflicts(a: &[Access], b: &[Access]) -> bool {
    a.iter().any(|x| b.iter().any(|y| x.obj == y.obj && (x.write || y.write)))
}

/// A small concurrent protocol the explorer can exhaustively schedule.
///
/// Implementations are plain state machines: `Clone` is the search's
/// state snapshot, so keep state small (a few ints and tiny vecs).
pub trait Model: Clone {
    /// Number of threads (ids `0..threads()`).
    fn threads(&self) -> usize;
    /// True when `tid` has run to completion.
    fn finished(&self, tid: usize) -> bool;
    /// True when `tid` can take a step now (not finished, not blocked
    /// on a held mutex, not parked in a condvar waitset).
    fn enabled(&self, tid: usize) -> bool;
    /// Execute `tid`'s next atomic action; returns the shared-object
    /// accesses it performed. Only called when `enabled(tid)`.
    fn step(&mut self, tid: usize) -> Vec<Access>;
    /// Safety invariant checked after *every* step (quorum never
    /// underflows, counters never negative, ...).
    fn safety(&self) -> Result<(), String> {
        Ok(())
    }
    /// Checked at terminal states where every thread finished.
    fn final_check(&self) -> Result<(), String>;
    /// Canonical output of a complete schedule; `explore` collects the
    /// distinct values — schedule invariance means the set has ≤ 1.
    fn result(&self) -> String;
}

/// Search bounds.
#[derive(Debug, Clone)]
pub struct Config {
    /// Max context switches away from a still-enabled thread;
    /// `None` = unbounded (full exhaustive search).
    pub preemption_bound: Option<usize>,
    /// Hard cap on terminal schedules before the search reports
    /// truncation (runaway-model backstop, not a tuning knob).
    pub max_schedules: u64,
    /// Hard cap on schedule length (steps).
    pub max_depth: usize,
}

impl Config {
    /// The standard sweep: preemption bound 3, generous caps.
    pub fn bounded(preemption_bound: usize) -> Config {
        Config {
            preemption_bound: Some(preemption_bound),
            max_schedules: 5_000_000,
            max_depth: 256,
        }
    }

    /// Full exhaustive search (still sleep-set-reduced).
    pub fn exhaustive() -> Config {
        Config { preemption_bound: None, max_schedules: 5_000_000, max_depth: 256 }
    }
}

/// What a sweep found.
#[derive(Debug, Default)]
pub struct Report {
    /// Terminal schedules reached (complete + deadlocked + unsafe).
    pub schedules: u64,
    /// Schedules that ended with unfinished-but-blocked threads.
    pub deadlocks: u64,
    /// Human-readable violations (deadlock traces, safety/final-check
    /// failures), each with the exact schedule that produced it.
    pub violations: Vec<String>,
    /// Distinct `Model::result()` strings over complete schedules.
    pub results: BTreeSet<String>,
    /// Branches skipped by the preemption bound.
    pub preempt_pruned: u64,
    /// Branches skipped by the sleep-set cut.
    pub sleep_pruned: u64,
    /// True if a cap fired — counts are then lower bounds, not exact.
    pub truncated: bool,
}

impl Report {
    /// No deadlock, no violation, outputs schedule-invariant.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty() && self.deadlocks == 0 && self.results.len() <= 1
    }
}

/// Exhaustively (within `cfg`) explore every schedule of `model`.
pub fn explore<M: Model>(model: &M, cfg: &Config) -> Report {
    let mut report = Report::default();
    let mut trace: Vec<usize> = Vec::new();
    dfs(model, None, 0, &BTreeSet::new(), cfg, &mut report, &mut trace);
    report
}

fn trace_str(trace: &[usize]) -> String {
    let s: Vec<String> = trace.iter().map(|t| t.to_string()).collect();
    s.join(",")
}

fn dfs<M: Model>(
    state: &M,
    last: Option<usize>,
    preemptions: usize,
    sleep: &BTreeSet<usize>,
    cfg: &Config,
    report: &mut Report,
    trace: &mut Vec<usize>,
) {
    if report.truncated {
        return;
    }
    let n = state.threads();
    let enabled: Vec<usize> = (0..n).filter(|&t| state.enabled(t)).collect();
    if enabled.is_empty() {
        if report.schedules >= cfg.max_schedules {
            report.truncated = true;
            return;
        }
        report.schedules += 1;
        if (0..n).all(|t| state.finished(t)) {
            match state.final_check() {
                Ok(()) => {
                    report.results.insert(state.result());
                }
                Err(e) => report
                    .violations
                    .push(format!("final-check failed after [{}]: {e}", trace_str(trace))),
            }
        } else {
            let stuck: Vec<String> = (0..n)
                .filter(|&t| !state.finished(t))
                .map(|t| format!("T{t}"))
                .collect();
            report.deadlocks += 1;
            report.violations.push(format!(
                "deadlock after [{}]: {} blocked with no enabled thread",
                trace_str(trace),
                stuck.join(" ")
            ));
        }
        return;
    }
    if trace.len() >= cfg.max_depth {
        report.truncated = true;
        return;
    }
    let mut local_sleep = sleep.clone();
    for &t in &enabled {
        if local_sleep.contains(&t) {
            report.sleep_pruned += 1;
            continue;
        }
        let p = match last {
            Some(l) if l != t && state.enabled(l) => preemptions + 1,
            _ => preemptions,
        };
        if let Some(bound) = cfg.preemption_bound {
            if p > bound {
                report.preempt_pruned += 1;
                continue;
            }
        }
        let mut next = state.clone();
        let acc = next.step(t);
        trace.push(t);
        if let Err(e) = next.safety() {
            if report.schedules >= cfg.max_schedules {
                report.truncated = true;
            } else {
                report.schedules += 1;
                report
                    .violations
                    .push(format!("safety violated after [{}]: {e}", trace_str(trace)));
            }
        } else {
            // A sleeping thread stays asleep only while it remains
            // enabled with its next action independent of the step just
            // taken; anything else wakes it (conservative = explore).
            let mut child_sleep = BTreeSet::new();
            for &s in &local_sleep {
                if s == t || !next.enabled(s) {
                    continue;
                }
                let mut probe = next.clone();
                let acc_s = probe.step(s);
                if !conflicts(&acc, &acc_s) {
                    child_sleep.insert(s);
                }
            }
            dfs(&next, Some(t), p, &child_sleep, cfg, report, trace);
        }
        trace.pop();
        local_sleep.insert(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two threads, each one independent write to its own object.
    #[derive(Clone)]
    struct TwoIndependent {
        done: [bool; 2],
    }

    impl Model for TwoIndependent {
        fn threads(&self) -> usize {
            2
        }
        fn finished(&self, tid: usize) -> bool {
            self.done[tid]
        }
        fn enabled(&self, tid: usize) -> bool {
            !self.done[tid]
        }
        fn step(&mut self, tid: usize) -> Vec<Access> {
            self.done[tid] = true;
            vec![Access::write(tid)]
        }
        fn final_check(&self) -> Result<(), String> {
            Ok(())
        }
        fn result(&self) -> String {
            String::new()
        }
    }

    /// Two threads, one dependent write each to a shared counter.
    #[derive(Clone)]
    struct TwoDependent {
        steps: [bool; 2],
        counter: i32,
    }

    impl Model for TwoDependent {
        fn threads(&self) -> usize {
            2
        }
        fn finished(&self, tid: usize) -> bool {
            self.steps[tid]
        }
        fn enabled(&self, tid: usize) -> bool {
            !self.steps[tid]
        }
        fn step(&mut self, tid: usize) -> Vec<Access> {
            self.steps[tid] = true;
            self.counter += 1;
            vec![Access::write(0)]
        }
        fn final_check(&self) -> Result<(), String> {
            if self.counter == 2 {
                Ok(())
            } else {
                Err(format!("counter {} != 2", self.counter))
            }
        }
        fn result(&self) -> String {
            format!("counter={}", self.counter)
        }
    }

    /// A thread that blocks forever once the other has run.
    #[derive(Clone)]
    struct Stuck {
        ran0: bool,
    }

    impl Model for Stuck {
        fn threads(&self) -> usize {
            2
        }
        fn finished(&self, tid: usize) -> bool {
            tid == 0 && self.ran0
        }
        fn enabled(&self, tid: usize) -> bool {
            tid == 0 && !self.ran0
        }
        fn step(&mut self, _tid: usize) -> Vec<Access> {
            self.ran0 = true;
            vec![Access::write(0)]
        }
        fn final_check(&self) -> Result<(), String> {
            Ok(())
        }
        fn result(&self) -> String {
            String::new()
        }
    }

    #[test]
    fn independent_steps_collapse_to_one_schedule() {
        let r = explore(&TwoIndependent { done: [false, false] }, &Config::exhaustive());
        assert!(r.is_clean(), "{:?}", r.violations);
        assert_eq!(r.schedules, 1, "sleep set must prune the commuted order");
        assert_eq!(r.sleep_pruned, 1);
    }

    #[test]
    fn dependent_steps_explore_both_orders() {
        let m = TwoDependent { steps: [false, false], counter: 0 };
        let r = explore(&m, &Config::exhaustive());
        assert!(r.is_clean(), "{:?}", r.violations);
        assert_eq!(r.schedules, 2);
        assert_eq!(r.results.iter().next().unwrap(), "counter=2");
    }

    #[test]
    fn deadlock_is_reported_with_its_schedule() {
        let r = explore(&Stuck { ran0: false }, &Config::exhaustive());
        assert_eq!(r.deadlocks, 1);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("deadlock after [0]"), "{:?}", r.violations);
        assert!(r.violations[0].contains("T1"), "{:?}", r.violations);
    }

    #[test]
    fn preemption_bound_zero_keeps_run_to_completion_schedules() {
        // Bound 0 still explores every non-preemptive order: each
        // thread runs to a blocking point before another is scheduled.
        let m = TwoDependent { steps: [false, false], counter: 0 };
        let r = explore(&m, &Config { preemption_bound: Some(0), ..Config::exhaustive() });
        // One step each: every switch happens at thread completion, so
        // nothing is pruned and both orders survive.
        assert_eq!(r.schedules, 2);
        assert_eq!(r.preempt_pruned, 0);
    }

    #[test]
    fn schedule_cap_marks_truncation() {
        let m = TwoDependent { steps: [false, false], counter: 0 };
        let r = explore(&m, &Config { max_schedules: 1, ..Config::exhaustive() });
        assert!(r.truncated);
        assert_eq!(r.schedules, 1);
    }
}
