//! Explorer models of the crate's four hot synchronization protocols,
//! plus the fine-grained `ThreadPool::wait_idle` model that captures
//! the lost-wakeup bug class this PR fixed in `util/pool.rs`.
//!
//! Each model is a deliberately small instance (2–3 threads) of the
//! real protocol, with one [`Model::step`] per atomic action. The
//! coarse models treat a monitor section (lock + act + unlock, or
//! lock + predicate-check + wait) as a single step — faithful to the
//! real code, where the predicate is always re-checked under the mutex
//! the condvar wait atomically releases. The pool-idle model is
//! fine-grained (separate lock/read/notify steps) because the bug it
//! exists to catch lives *between* those steps.
//!
//! Every model carries `mutant` switches that re-introduce a specific
//! bug class (dropped notify, inverted lock order, missing quorum
//! re-check, notify outside the mutex). `tests/conc_check.rs` asserts
//! the explorer reports a violation for every mutant and a clean,
//! schedule-invariant sweep for the real protocol. `notify_one` is not
//! modeled (the real code only uses `notify_all`); waitset wakes are
//! always broadcast.
//!
//! Changing a model changes its explored-schedule count: update the
//! pinned constants in `tests/conc_check.rs`, mirror the change in
//! `python/replica/conc_check_replica.py`, and re-run the replica to
//! confirm both enumerations still agree (see DESIGN.md, "Lock
//! hierarchy & invariants catalog", for the recipe).

use super::sched::{Access, Model};

// ---------------------------------------------------------------------------
// Cancel-vs-expire first-cause CAS (util/cancel.rs → sync::StateCell)
// ---------------------------------------------------------------------------

const LIVE: u8 = 0;
const CANCELLED: u8 = 1;
const EXPIRED: u8 = 2;

/// Threads: T0 `cancel()` (CAS LIVE→CANCELLED), T1 `expire()`
/// (CAS LIVE→EXPIRED), T2 an observer reading the cell twice.
///
/// Checked claims: exactly one CAS wins across every schedule, the
/// cell never returns to LIVE, and an observed terminal cause is
/// stable (the observer never sees CANCELLED then EXPIRED).
#[derive(Clone)]
pub struct CancelModel {
    state: u8,
    wins: [bool; 2],
    /// Observer pc: 0 = first read pending, 1 = second read pending,
    /// 2 = finished. Writer pcs are `wins`-adjacent `done` flags.
    writer_done: [bool; 2],
    obs_pc: u8,
    obs_first: u8,
    unstable: bool,
}

impl CancelModel {
    /// Fresh LIVE cell, nothing run.
    pub fn new() -> CancelModel {
        CancelModel {
            state: LIVE,
            wins: [false; 2],
            writer_done: [false; 2],
            obs_pc: 0,
            obs_first: LIVE,
            unstable: false,
        }
    }
}

impl Default for CancelModel {
    fn default() -> Self {
        Self::new()
    }
}

impl Model for CancelModel {
    fn threads(&self) -> usize {
        3
    }

    fn finished(&self, tid: usize) -> bool {
        match tid {
            0 | 1 => self.writer_done[tid],
            _ => self.obs_pc == 2,
        }
    }

    fn enabled(&self, tid: usize) -> bool {
        !self.finished(tid)
    }

    fn step(&mut self, tid: usize) -> Vec<Access> {
        match tid {
            0 | 1 => {
                let cause = if tid == 0 { CANCELLED } else { EXPIRED };
                if self.state == LIVE {
                    self.state = cause;
                    self.wins[tid] = true;
                }
                self.writer_done[tid] = true;
                vec![Access::write(0)]
            }
            _ => {
                if self.obs_pc == 0 {
                    self.obs_first = self.state;
                    self.obs_pc = 1;
                } else {
                    if self.obs_first != LIVE && self.state != self.obs_first {
                        self.unstable = true;
                    }
                    self.obs_pc = 2;
                }
                vec![Access::read(0)]
            }
        }
    }

    fn safety(&self) -> Result<(), String> {
        if self.wins[0] && self.wins[1] {
            return Err("both cancel and expire won the CAS".into());
        }
        if self.unstable {
            return Err(format!(
                "terminal cause changed after being observed (first saw {})",
                self.obs_first
            ));
        }
        Ok(())
    }

    fn final_check(&self) -> Result<(), String> {
        let wins = self.wins[0] as u8 + self.wins[1] as u8;
        if wins != 1 {
            return Err(format!("{wins} terminal causes recorded, want exactly 1"));
        }
        if self.state == LIVE {
            return Err("cell still LIVE after both writers ran".into());
        }
        Ok(())
    }

    fn result(&self) -> String {
        // Which cause wins is schedule-dependent by design; the
        // schedule-invariant claim is the *count* of winners.
        format!("winners={}", self.wins[0] as u8 + self.wins[1] as u8)
    }
}

// ---------------------------------------------------------------------------
// CompletionSlot submit/sync with out-of-order sync (util/sync.rs)
// ---------------------------------------------------------------------------

/// Threads: P0 fills slot 0 with 10, P1 fills slot 1 with 20, C syncs
/// slot 1 *first*, then slot 0 — the out-of-order `sync` pattern
/// `join_sharded` relies on (shards complete in any order; the join
/// walks them in shard order regardless).
///
/// A fill is one monitor step: set value + broadcast. A sync attempt is
/// one monitor step: take the value if filled, else park on the slot's
/// waitset (predicate re-checked on every wake — the coarse step *is*
/// the predicate loop).
#[derive(Clone)]
pub struct SlotModel {
    filled: [bool; 2],
    val: [i64; 2],
    got: [i64; 2],
    producer_done: [bool; 2],
    /// Consumer: 0 = syncing slot 1, 1 = syncing slot 0, 2 = finished.
    consumer_pc: u8,
    consumer_waiting_on: Option<usize>,
    /// Mutant: fill writes the value but never notifies.
    pub mutant_drop_notify: bool,
}

impl SlotModel {
    /// Fresh model; `mutant_drop_notify` re-introduces the lost-wakeup
    /// bug the shim's `fill` (notify under the lock) prevents.
    pub fn new(mutant_drop_notify: bool) -> SlotModel {
        SlotModel {
            filled: [false; 2],
            val: [0; 2],
            got: [0; 2],
            producer_done: [false; 2],
            consumer_pc: 0,
            consumer_waiting_on: None,
            mutant_drop_notify,
        }
    }

    fn sync_order(pc: u8) -> usize {
        if pc == 0 {
            1
        } else {
            0
        }
    }
}

impl Model for SlotModel {
    fn threads(&self) -> usize {
        3
    }

    fn finished(&self, tid: usize) -> bool {
        match tid {
            0 | 1 => self.producer_done[tid],
            _ => self.consumer_pc == 2,
        }
    }

    fn enabled(&self, tid: usize) -> bool {
        match tid {
            0 | 1 => !self.producer_done[tid],
            _ => self.consumer_pc != 2 && self.consumer_waiting_on.is_none(),
        }
    }

    fn step(&mut self, tid: usize) -> Vec<Access> {
        match tid {
            0 | 1 => {
                self.val[tid] = 10 * (tid as i64 + 1);
                self.filled[tid] = true;
                self.producer_done[tid] = true;
                if !self.mutant_drop_notify && self.consumer_waiting_on == Some(tid) {
                    self.consumer_waiting_on = None; // broadcast wake
                }
                vec![Access::write(tid)]
            }
            _ => {
                let s = Self::sync_order(self.consumer_pc);
                if self.filled[s] {
                    self.got[s] = self.val[s];
                    self.consumer_pc += 1;
                } else {
                    self.consumer_waiting_on = Some(s);
                }
                vec![Access::write(s)]
            }
        }
    }

    fn final_check(&self) -> Result<(), String> {
        if self.got != [10, 20] {
            return Err(format!("stitched values {:?}, want [10, 20]", self.got));
        }
        Ok(())
    }

    fn result(&self) -> String {
        format!("got1={} got0={}", self.got[1], self.got[0])
    }
}

// ---------------------------------------------------------------------------
// Two-lock ordering (the lock-hierarchy discipline, check/lockorder.rs)
// ---------------------------------------------------------------------------

/// Threads: two, each acquiring two mutexes then releasing them. In the
/// correct protocol both follow the hierarchy (A then B); the mutant
/// inverts thread 1's order, which the explorer convicts with a
/// concrete deadlocking schedule (and the lock-order witness convicts
/// from a *single* sequential run, without needing the schedule).
#[derive(Clone)]
pub struct TwoLockModel {
    owner: [Option<usize>; 2],
    pc: [u8; 2],
    /// Mutant: thread 1 takes B before A.
    pub mutant_inverted: bool,
}

impl TwoLockModel {
    /// Fresh model; `mutant_inverted` seeds the classic AB/BA deadlock.
    pub fn new(mutant_inverted: bool) -> TwoLockModel {
        TwoLockModel { owner: [None; 2], pc: [0; 2], mutant_inverted }
    }

    fn order(&self, tid: usize) -> [usize; 2] {
        if tid == 1 && self.mutant_inverted {
            [1, 0]
        } else {
            [0, 1]
        }
    }
}

impl Model for TwoLockModel {
    fn threads(&self) -> usize {
        2
    }

    fn finished(&self, tid: usize) -> bool {
        self.pc[tid] == 4
    }

    fn enabled(&self, tid: usize) -> bool {
        let pc = self.pc[tid];
        if pc >= 4 {
            return false;
        }
        let ord = self.order(tid);
        match pc {
            0 => self.owner[ord[0]].is_none(),
            1 => self.owner[ord[1]].is_none(),
            _ => true,
        }
    }

    fn step(&mut self, tid: usize) -> Vec<Access> {
        let ord = self.order(tid);
        let pc = self.pc[tid];
        let lock = match pc {
            0 => {
                self.owner[ord[0]] = Some(tid);
                ord[0]
            }
            1 => {
                self.owner[ord[1]] = Some(tid);
                ord[1]
            }
            2 => {
                self.owner[ord[1]] = None;
                ord[1]
            }
            _ => {
                self.owner[ord[0]] = None;
                ord[0]
            }
        };
        self.pc[tid] = pc + 1;
        vec![Access::write(lock)]
    }

    fn final_check(&self) -> Result<(), String> {
        if self.owner != [None, None] {
            return Err(format!("locks still held at exit: {:?}", self.owner));
        }
        Ok(())
    }

    fn result(&self) -> String {
        String::new()
    }
}

// ---------------------------------------------------------------------------
// Rendezvous join / leave() / waiter promotion (serve/batcher.rs)
// ---------------------------------------------------------------------------

/// Threads: members M0 (input 1) and M1 (input 2) rendezvous; T2 is a
/// cancelled member calling `leave()` without staging. Quorum starts at
/// 3 and shrinks to 2.
///
/// Coarse monitor steps, matching `SharedBatch`:
/// - join: stage input, `arrived += 1`; if `arrived == active` the
///   joiner is the leader: merge-execute, bump the generation,
///   broadcast; otherwise park on the batch condvar.
/// - wake re-check: if the generation advanced, take the output; else
///   if `arrived == active` (quorum shrank while parked) the waiter
///   *promotes itself to leader*; else park again.
/// - leave: `active -= 1`, broadcast.
///
/// Mutants: `drop_notify` (leave forgets the broadcast — the parked
/// members never learn the quorum shrank) and `no_requeue_check` (a
/// woken waiter only looks at the generation, missing the promotion
/// case — both deadlock).
#[derive(Clone)]
pub struct RendezvousModel {
    arrived: u32,
    active: u32,
    generation: u32,
    staged_sum: i64,
    output: Option<i64>,
    /// Member pc: 0 = joining, 1 = parked, 2 = woken (re-check), 3 =
    /// finished. Leaver pc in `leaver_done`.
    member_pc: [u8; 2],
    member_out: [i64; 2],
    leaver_done: bool,
    /// Mutant: `leave()` skips the broadcast.
    pub mutant_drop_notify: bool,
    /// Mutant: a woken waiter skips the quorum re-check (no promotion).
    pub mutant_no_requeue_check: bool,
}

impl RendezvousModel {
    /// Fresh model with both mutants off unless selected.
    pub fn new(mutant_drop_notify: bool, mutant_no_requeue_check: bool) -> RendezvousModel {
        RendezvousModel {
            arrived: 0,
            active: 3,
            generation: 0,
            staged_sum: 0,
            output: None,
            member_pc: [0; 2],
            member_out: [0; 2],
            leaver_done: false,
            mutant_drop_notify,
            mutant_no_requeue_check,
        }
    }

    fn complete(&mut self) {
        self.output = Some(self.staged_sum);
        self.generation += 1;
        self.broadcast();
    }

    fn broadcast(&mut self) {
        for pc in self.member_pc.iter_mut() {
            if *pc == 1 {
                *pc = 2;
            }
        }
    }
}

impl Model for RendezvousModel {
    fn threads(&self) -> usize {
        3
    }

    fn finished(&self, tid: usize) -> bool {
        match tid {
            0 | 1 => self.member_pc[tid] == 3,
            _ => self.leaver_done,
        }
    }

    fn enabled(&self, tid: usize) -> bool {
        match tid {
            0 | 1 => self.member_pc[tid] == 0 || self.member_pc[tid] == 2,
            _ => !self.leaver_done,
        }
    }

    fn step(&mut self, tid: usize) -> Vec<Access> {
        if tid == 2 {
            self.active -= 1;
            if !self.mutant_drop_notify {
                self.broadcast();
            }
            self.leaver_done = true;
            return vec![Access::write(0)];
        }
        match self.member_pc[tid] {
            0 => {
                self.staged_sum += tid as i64 + 1;
                self.arrived += 1;
                if self.arrived == self.active {
                    self.complete();
                    self.member_out[tid] = self.output.unwrap();
                    self.member_pc[tid] = 3;
                } else {
                    self.member_pc[tid] = 1;
                }
            }
            2 => {
                if self.generation > 0 {
                    self.member_out[tid] = self.output.unwrap();
                    self.member_pc[tid] = 3;
                } else if !self.mutant_no_requeue_check && self.arrived == self.active {
                    self.complete();
                    self.member_out[tid] = self.output.unwrap();
                    self.member_pc[tid] = 3;
                } else {
                    self.member_pc[tid] = 1;
                }
            }
            pc => unreachable!("member {tid} stepped at pc {pc}"),
        }
        vec![Access::write(0)]
    }

    fn safety(&self) -> Result<(), String> {
        if self.active > 3 {
            return Err(format!("quorum grew: active {}", self.active));
        }
        if self.arrived > 3 {
            return Err(format!("arrived {} overran the membership", self.arrived));
        }
        if self.generation > 1 {
            return Err("batch completed twice".into());
        }
        Ok(())
    }

    fn final_check(&self) -> Result<(), String> {
        if self.generation != 1 {
            return Err(format!("generation {} != 1 at exit", self.generation));
        }
        if self.member_out != [3, 3] {
            return Err(format!("member outputs {:?}, want [3, 3]", self.member_out));
        }
        if self.arrived != self.active {
            return Err(format!(
                "arrived {} != active {} at exit",
                self.arrived, self.active
            ));
        }
        Ok(())
    }

    fn result(&self) -> String {
        format!(
            "gen={} out={},{} merged={}",
            self.generation,
            self.member_out[0],
            self.member_out[1],
            self.output.unwrap_or(-1)
        )
    }
}

// ---------------------------------------------------------------------------
// Drain-then-refuse (serve/queue.rs close + server/runner.rs shutdown)
// ---------------------------------------------------------------------------

/// Threads: T0 a producer pushing two requests (the second races the
/// close), T1 the drainer calling `close()`, T2 a worker popping until
/// the queue reports closed-and-empty.
///
/// Checked claims: every *accepted* push is popped exactly once (drain
/// loses nothing), every push after close is refused, and the worker
/// always terminates (close broadcast reaches a parked worker).
#[derive(Clone)]
pub struct DrainModel {
    queue: Vec<i64>,
    closed: bool,
    /// Producer pc: 0 = push #1, 1 = push #2, 2 = finished.
    producer_pc: u8,
    accepted: u32,
    refused: u32,
    drainer_done: bool,
    popped: Vec<i64>,
    worker_done: bool,
    worker_waiting: bool,
    /// Mutant: close() forgets to wake the parked worker.
    pub mutant_drop_notify: bool,
}

impl DrainModel {
    /// Fresh open queue; `mutant_drop_notify` makes `close()` skip the
    /// broadcast that unparks an idle worker.
    pub fn new(mutant_drop_notify: bool) -> DrainModel {
        DrainModel {
            queue: Vec::new(),
            closed: false,
            producer_pc: 0,
            accepted: 0,
            refused: 0,
            drainer_done: false,
            popped: Vec::new(),
            worker_done: false,
            worker_waiting: false,
            mutant_drop_notify,
        }
    }
}

impl Model for DrainModel {
    fn threads(&self) -> usize {
        3
    }

    fn finished(&self, tid: usize) -> bool {
        match tid {
            0 => self.producer_pc == 2,
            1 => self.drainer_done,
            _ => self.worker_done,
        }
    }

    fn enabled(&self, tid: usize) -> bool {
        match tid {
            0 => self.producer_pc != 2,
            1 => !self.drainer_done,
            _ => !self.worker_done && !self.worker_waiting,
        }
    }

    fn step(&mut self, tid: usize) -> Vec<Access> {
        match tid {
            0 => {
                let v = self.producer_pc as i64 + 1;
                if self.closed {
                    self.refused += 1;
                } else {
                    self.queue.push(v);
                    self.accepted += 1;
                    self.worker_waiting = false; // push broadcasts
                }
                self.producer_pc += 1;
                vec![Access::write(0)]
            }
            1 => {
                self.closed = true;
                if !self.mutant_drop_notify {
                    self.worker_waiting = false; // close broadcasts
                }
                self.drainer_done = true;
                vec![Access::write(0)]
            }
            _ => {
                if !self.queue.is_empty() {
                    self.popped.push(self.queue.remove(0));
                } else if self.closed {
                    self.worker_done = true;
                } else {
                    self.worker_waiting = true;
                }
                vec![Access::write(0)]
            }
        }
    }

    fn safety(&self) -> Result<(), String> {
        if self.accepted + self.refused > 2 {
            return Err("producer pushed more than twice".into());
        }
        Ok(())
    }

    fn final_check(&self) -> Result<(), String> {
        if self.popped.len() as u32 != self.accepted {
            return Err(format!(
                "accepted {} requests but drained {} — drain lost work",
                self.accepted,
                self.popped.len()
            ));
        }
        if !self.queue.is_empty() {
            return Err(format!("{} requests stranded in the queue", self.queue.len()));
        }
        if self.accepted + self.refused != 2 {
            return Err("push accounting does not cover both attempts".into());
        }
        Ok(())
    }

    fn result(&self) -> String {
        // accepted/refused split is schedule-dependent (the race with
        // close is real); the invariant is conservation, checked above.
        String::new()
    }
}

// ---------------------------------------------------------------------------
// ThreadPool::wait_idle lost wakeup (util/pool.rs) — fine-grained
// ---------------------------------------------------------------------------

/// Threads: W a worker finishing the last job, V a waiter in
/// `wait_idle`. Fine-grained steps with an explicitly modeled mutex,
/// because the bug lives between them:
///
/// - W: `in_flight.fetch_sub` → (fixed) lock the done mutex → broadcast
///   → unlock. The mutant broadcasts *without* taking the mutex — the
///   pre-PR `util/pool.rs` code — which can fire in the window between
///   V reading the counter and V parking, a classic lost wakeup.
/// - V: lock → read `in_flight` → if zero, unlock and return; else park
///   (atomically releasing the mutex) → on wake, re-lock → re-read.
#[derive(Clone)]
pub struct PoolIdleModel {
    in_flight: i64,
    mutex_owner: Option<usize>,
    waiter_parked: bool,
    /// Worker pc: 0 = fetch_sub, then fixed: 1 = lock, 2 = broadcast,
    /// 3 = unlock, 4 = done; mutant: 1 = broadcast, 2 = done.
    worker_pc: u8,
    /// Waiter pc: 0 = lock, 1 = read/decide, 2 = unlock+return,
    /// 3 = park, 4 = re-lock after wake, 5 = done.
    waiter_pc: u8,
    last_read: i64,
    /// Mutant: broadcast without holding the done mutex.
    pub mutant_unlocked_notify: bool,
}

const OBJ_CTR: usize = 0;
const OBJ_MTX: usize = 1;
const OBJ_CV: usize = 2;

impl PoolIdleModel {
    /// One job in flight; `mutant_unlocked_notify` reproduces the
    /// pre-PR bug.
    pub fn new(mutant_unlocked_notify: bool) -> PoolIdleModel {
        PoolIdleModel {
            in_flight: 1,
            mutex_owner: None,
            waiter_parked: false,
            worker_pc: 0,
            waiter_pc: 0,
            last_read: -1,
            mutant_unlocked_notify,
        }
    }

    fn worker_done_pc(&self) -> u8 {
        if self.mutant_unlocked_notify {
            2
        } else {
            4
        }
    }
}

impl Model for PoolIdleModel {
    fn threads(&self) -> usize {
        2
    }

    fn finished(&self, tid: usize) -> bool {
        if tid == 0 {
            self.worker_pc == self.worker_done_pc()
        } else {
            self.waiter_pc == 5
        }
    }

    fn enabled(&self, tid: usize) -> bool {
        if tid == 0 {
            match (self.mutant_unlocked_notify, self.worker_pc) {
                (_, pc) if pc == self.worker_done_pc() => false,
                (false, 1) => self.mutex_owner.is_none(),
                _ => true,
            }
        } else {
            match self.waiter_pc {
                0 | 4 => self.mutex_owner.is_none(),
                // pc 3 is the park step itself (release + join waitset,
                // one atomic action); once parked it stays at pc 3 with
                // the flag set until the broadcast moves it to pc 4.
                3 => !self.waiter_parked,
                5 => false,
                _ => true,
            }
        }
    }

    fn step(&mut self, tid: usize) -> Vec<Access> {
        if tid == 0 {
            if self.mutant_unlocked_notify {
                match self.worker_pc {
                    0 => {
                        self.in_flight -= 1;
                        self.worker_pc = 1;
                        vec![Access::write(OBJ_CTR)]
                    }
                    _ => {
                        if self.waiter_parked {
                            self.waiter_parked = false;
                            self.waiter_pc = 4;
                        }
                        self.worker_pc = 2;
                        vec![Access::write(OBJ_CV)]
                    }
                }
            } else {
                match self.worker_pc {
                    0 => {
                        self.in_flight -= 1;
                        self.worker_pc = 1;
                        vec![Access::write(OBJ_CTR)]
                    }
                    1 => {
                        self.mutex_owner = Some(0);
                        self.worker_pc = 2;
                        vec![Access::write(OBJ_MTX)]
                    }
                    2 => {
                        if self.waiter_parked {
                            self.waiter_parked = false;
                            self.waiter_pc = 4;
                        }
                        self.worker_pc = 3;
                        vec![Access::write(OBJ_CV)]
                    }
                    _ => {
                        self.mutex_owner = None;
                        self.worker_pc = 4;
                        vec![Access::write(OBJ_MTX)]
                    }
                }
            }
        } else {
            match self.waiter_pc {
                0 | 4 => {
                    self.mutex_owner = Some(1);
                    self.waiter_pc = 1;
                    vec![Access::write(OBJ_MTX)]
                }
                1 => {
                    self.last_read = self.in_flight;
                    self.waiter_pc = if self.last_read == 0 { 2 } else { 3 };
                    vec![Access::read(OBJ_CTR)]
                }
                2 => {
                    self.mutex_owner = None;
                    self.waiter_pc = 5;
                    vec![Access::write(OBJ_MTX)]
                }
                _ => {
                    // park: atomically release the mutex + join waitset
                    self.mutex_owner = None;
                    self.waiter_parked = true;
                    vec![Access::write(OBJ_MTX), Access::write(OBJ_CV)]
                }
            }
        }
    }

    fn safety(&self) -> Result<(), String> {
        if self.in_flight < 0 {
            return Err(format!("in_flight underflowed: {}", self.in_flight));
        }
        Ok(())
    }

    fn final_check(&self) -> Result<(), String> {
        if self.in_flight != 0 {
            return Err(format!("in_flight {} != 0 at exit", self.in_flight));
        }
        if self.mutex_owner.is_some() {
            return Err("done mutex still held at exit".into());
        }
        if self.last_read != 0 {
            return Err("waiter returned without observing idle".into());
        }
        Ok(())
    }

    fn result(&self) -> String {
        format!("idle_observed={}", (self.last_read == 0) as u8)
    }
}

#[cfg(test)]
mod tests {
    use super::super::sched::{explore, Config};
    use super::*;

    // Sanity smoke: real (non-mutant) models are clean under a tight
    // bound. The full sweeps with pinned schedule counts and the
    // mutant convictions live in tests/conc_check.rs.
    #[test]
    fn real_models_clean_at_bound_two() {
        let cfg = Config::bounded(2);
        assert!(explore(&CancelModel::new(), &cfg).is_clean());
        assert!(explore(&SlotModel::new(false), &cfg).is_clean());
        assert!(explore(&TwoLockModel::new(false), &cfg).is_clean());
        assert!(explore(&RendezvousModel::new(false, false), &cfg).is_clean());
        assert!(explore(&DrainModel::new(false), &cfg).is_clean());
        assert!(explore(&PoolIdleModel::new(false), &cfg).is_clean());
    }

    #[test]
    fn unlocked_notify_mutant_loses_the_wakeup() {
        let r = explore(&PoolIdleModel::new(true), &Config::bounded(2));
        assert!(r.deadlocks > 0, "mutant must deadlock somewhere: {r:?}");
    }

    #[test]
    fn inverted_lock_order_mutant_deadlocks() {
        let r = explore(&TwoLockModel::new(true), &Config::bounded(2));
        assert!(r.deadlocks > 0, "{r:?}");
    }

    #[test]
    fn missing_quorum_recheck_mutant_deadlocks() {
        let r = explore(&RendezvousModel::new(false, true), &Config::bounded(2));
        assert!(r.deadlocks > 0, "{r:?}");
    }
}
