//! Analytic CPU/GPU baselines (ARM A72, Xeon w5-2465X, GTX 1080 Ti).
//!
//! Each baseline is a per-dtype *effective mat-mul throughput* model plus
//! a fixed non-mat-mul overhead (sampler, normalization, softmax, im2col
//! marshalling, model management). The throughputs are **effective**, not
//! peak: they absorb each platform's GGML kernel efficiency, including
//! the pathological memory behavior of sd.cpp's materialized-attention
//! F32 mat-muls (a 4096×4096 f32 score matrix is 67 MB per head — DRAM
//! bound on every platform).
//!
//! ## Calibration (see `EXPERIMENTS.md` §Calibration)
//!
//! * **ARM**: with the SD-Turbo trace volumes (F32 80.3 / F16 1516.4 or
//!   1449.3 / Q3_K 68.9 / Q8_0 136.0 GMACs), the four throughputs below
//!   are the solution reproducing the paper's two ARM end-to-end points
//!   (809.7 s Q3_K, 625.1 s Q8_0) — the 184.6 s model-to-model delta
//!   pins the Q3_K:Q8_0 throughput ratio (scalar k-quant unpacking is
//!   catastrophically slow on the armv8.0 A72, which has no dot-product
//!   extension).
//! * **Xeon**: throughputs reproduce Table I's Q3_K-model proportions
//!   (30.7 / 59.0 / 10.3 %) exactly and the 59.3 s end-to-end.
//! * **GPU**: reproduces the ~16 s end-to-end; most of it is F16 conv
//!   GEMMs and fixed launch/transfer overhead.

use super::Device;
use crate::ggml::DType;
use crate::sd::{QuantModel, WorkloadTrace};

/// Per-dtype effective throughput model for a self-contained device.
#[derive(Debug, Clone)]
pub struct CpuGpuModel {
    /// Device name (paper spelling).
    pub name: &'static str,
    /// Physical cores (threads beyond this do not scale).
    pub cores: usize,
    /// Effective whole-chip GMAC/s for F32 mat-muls.
    pub gmacs_f32: f64,
    /// Effective whole-chip GMAC/s for F16 mat-muls.
    pub gmacs_f16: f64,
    /// Effective whole-chip GMAC/s for Q3_K mat-muls.
    pub gmacs_q3k: f64,
    /// Effective whole-chip GMAC/s for Q8_0 mat-muls.
    pub gmacs_q8_0: f64,
    /// Non-mat-mul pipeline overhead per image (seconds).
    pub overhead_s: f64,
    /// TDP / estimated power (W).
    pub tdp_watts: f64,
}

impl CpuGpuModel {
    /// Whole-chip throughput for a dtype.
    pub fn gmacs(&self, dtype: DType) -> f64 {
        match dtype {
            DType::F32 => self.gmacs_f32,
            DType::F16 => self.gmacs_f16,
            DType::Q3K => self.gmacs_q3k,
            DType::Q8_0 | DType::Q8K => self.gmacs_q8_0,
        }
    }

    /// Seconds for all mat-muls of the trace at full thread count.
    pub fn dot_seconds(&self, trace: &WorkloadTrace, model: QuantModel) -> f64 {
        trace
            .ops
            .iter()
            .map(|op| op.macs() as f64 / 1e9 / self.gmacs(op.dtype(model)))
            .sum()
    }

    /// Seconds per dtype (Table I's rows). Returns `(dtype, seconds)`.
    pub fn dot_seconds_by_dtype(
        &self,
        trace: &WorkloadTrace,
        model: QuantModel,
    ) -> Vec<(&'static str, f64)> {
        let mut acc: std::collections::BTreeMap<&'static str, f64> = Default::default();
        for op in &trace.ops {
            let d = op.dtype(model);
            *acc.entry(d.name()).or_insert(0.0) += op.macs() as f64 / 1e9 / self.gmacs(d);
        }
        acc.into_iter().collect()
    }
}

impl Device for CpuGpuModel {
    fn name(&self) -> String {
        self.name.to_string()
    }

    fn e2e_seconds(&self, trace: &WorkloadTrace, model: QuantModel) -> f64 {
        self.dot_seconds(trace, model) + self.overhead_s
    }

    fn kernel_seconds(&self, trace: &WorkloadTrace, model: QuantModel, threads: usize) -> f64 {
        let macs = trace.offloaded_macs(model) as f64 / 1e9;
        let thr_full = self.gmacs(model.weight_dtype());
        // The CPU thread axis does not apply to the GPU (Figs. 9-10 plot
        // it as a flat line): a kernel launch engages the whole device.
        let eff_threads = if self.cores > 64 {
            self.cores as f64
        } else {
            threads.clamp(1, self.cores) as f64
        };
        macs / (thr_full * eff_threads / self.cores as f64)
    }

    fn compute_watts(&self, _model: QuantModel) -> f64 {
        self.tdp_watts
    }

    fn host_watts(&self) -> Option<f64> {
        None
    }

    fn e2e_split(&self, trace: &WorkloadTrace, model: QuantModel) -> (f64, f64) {
        (self.e2e_seconds(trace, model), 0.0)
    }
}

/// The host ARM Cortex-A72 (2 cores @ 1.4 GHz, Table II).
pub fn arm_a72() -> CpuGpuModel {
    CpuGpuModel {
        name: "ARM Cortex-A72",
        cores: 2,
        gmacs_f32: 3.0,
        gmacs_f16: 3.0,
        gmacs_q3k: 0.2706, // scalar k-quant unpack, no SDOT on armv8.0
        gmacs_q8_0: 1.475,
        overhead_s: 23.0,
        tdp_watts: 1.5,
    }
}

/// Intel Xeon w5-2465X (16 cores @ 3.1 GHz, AVX-512).
pub fn xeon_w5() -> CpuGpuModel {
    CpuGpuModel {
        name: "Intel Xeon w5-2465X",
        cores: 16,
        gmacs_f32: 5.24, // attention mat-muls: DRAM-bound (67 MB scores)
        gmacs_f16: 51.4,
        gmacs_q3k: 13.4,
        gmacs_q8_0: 18.3,
        overhead_s: 9.3,
        tdp_watts: 200.0,
    }
}

/// NVIDIA GTX 1080 Ti (3584 CUDA cores; sd.cpp CUDA backend).
pub fn gtx_1080ti() -> CpuGpuModel {
    CpuGpuModel {
        name: "NVIDIA GTX 1080 Ti",
        cores: 3584,
        gmacs_f32: 50.0,
        gmacs_f16: 220.0,
        gmacs_q3k: 50.0,
        gmacs_q8_0: 250.0,
        overhead_s: 6.3, // kernel launches, H2D/D2H, CPU-side sampler
        tdp_watts: 250.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sd::arch::sd_turbo_512;

    #[test]
    fn arm_reproduces_paper_e2e_points() {
        let t = sd_turbo_512(1);
        let arm = arm_a72();
        let q3k = arm.e2e_seconds(&t, QuantModel::Q3K);
        let q8 = arm.e2e_seconds(&t, QuantModel::Q8_0);
        assert!((q3k - 809.7).abs() < 810.0 * 0.02, "ARM Q3_K e2e {q3k} (paper 809.7)");
        assert!((q8 - 625.1).abs() < 625.0 * 0.02, "ARM Q8_0 e2e {q8} (paper 625.1)");
        assert!(q3k > q8, "Q3_K model is slower on ARM (paper ordering)");
    }

    #[test]
    fn xeon_reproduces_table1_proportions() {
        let t = sd_turbo_512(1);
        let xeon = xeon_w5();
        let by = xeon.dot_seconds_by_dtype(&t, QuantModel::Q3K);
        let total: f64 = by.iter().map(|(_, s)| s).sum();
        let share = |name: &str| {
            by.iter().find(|(n, _)| *n == name).map(|(_, s)| s / total * 100.0).unwrap()
        };
        assert!((share("F32") - 30.7).abs() < 1.5, "F32 share {}", share("F32"));
        assert!((share("F16") - 59.0).abs() < 1.5, "F16 share {}", share("F16"));
        assert!((share("Q3_K") - 10.3).abs() < 1.5, "Q3_K share {}", share("Q3_K"));
    }

    #[test]
    fn xeon_and_gpu_e2e_near_paper() {
        let t = sd_turbo_512(1);
        let xeon = xeon_w5().e2e_seconds(&t, QuantModel::Q3K);
        let gpu = gtx_1080ti().e2e_seconds(&t, QuantModel::Q3K);
        assert!((xeon - 59.3).abs() < 3.0, "Xeon e2e {xeon} (paper 59.3)");
        assert!((gpu - 16.2).abs() < 1.5, "GPU e2e {gpu} (paper 16.2)");
    }

    #[test]
    fn device_ordering_gpu_fastest_arm_slowest() {
        let t = sd_turbo_512(1);
        for m in [QuantModel::Q3K, QuantModel::Q8_0] {
            let a = arm_a72().e2e_seconds(&t, m);
            let x = xeon_w5().e2e_seconds(&t, m);
            let g = gtx_1080ti().e2e_seconds(&t, m);
            assert!(g < x && x < a, "{m:?}: gpu {g} xeon {x} arm {a}");
        }
    }

    #[test]
    fn kernel_seconds_scales_with_threads_then_saturates() {
        let t = sd_turbo_512(1);
        let arm = arm_a72();
        let t1 = arm.kernel_seconds(&t, QuantModel::Q3K, 1);
        let t2 = arm.kernel_seconds(&t, QuantModel::Q3K, 2);
        let t4 = arm.kernel_seconds(&t, QuantModel::Q3K, 4);
        assert!((t1 / t2 - 2.0).abs() < 1e-9, "2 cores: perfect to 2 threads");
        assert_eq!(t2, t4, "no gain beyond physical cores");
    }

    #[test]
    fn arm_q3k_kernel_time_matches_calibration() {
        // The Fig. 9 anchor: ARM takes ~255 s on the Q3_K kernels.
        let t = sd_turbo_512(1);
        let secs = arm_a72().kernel_seconds(&t, QuantModel::Q3K, 2);
        assert!((secs - 254.6).abs() < 6.0, "ARM Q3_K kernels {secs}");
    }
}
