//! IMAX3 as an evaluated device: simulator-derived accelerator time plus
//! a host-dispatch model around the ARM baseline.
//!
//! An IMAX end-to-end run decomposes as (§IV, §V):
//!
//! ```text
//! e2e = host_residual            non-offloaded dots + pipeline overhead,
//!                                priced by the ARM baseline model
//!     + host_dispatch            per-offload marshalling on the A72:
//!                                activation quantization into the vec-dot
//!                                partner format (Q8_0 / Q8_K super-blocks)
//!                                and DMA-buffer memcpy + driver calls
//!     + imax_busy                CONF/REGV/RANGE/LOAD/EXEC/DRAIN cycles
//!                                from the lane simulator (analytic mode)
//! ```
//!
//! The dispatch rates are the two constants calibrated on the paper's
//! four published IMAX end-to-end latencies (790.3/754.5 s Q3_K,
//! 654.7/558.0 s Q8_0); the Q8_K path is far slower per byte than the
//! Q8_0 path, reflecting super-block quantization with per-16 bsums and
//! the paper's unquantified driver overheads (see `EXPERIMENTS.md`
//! §Calibration for the full derivation and residuals).
//!
//! Figs. 9–10 ("kernel execution time" vs lanes) exclude host
//! marshalling — matching the paper's kernel profiling methodology
//! ("pure computation time with memory copy overhead excluded") — and
//! model the dual-core host's supply ceiling: beyond ~2.5 lanes' worth
//! of descriptor service the curve saturates (§V-A).

use super::baseline::{arm_a72, CpuGpuModel};
use super::Device;
use crate::imax::lane::LaneSim;
use crate::imax::power::kernel_power;
use crate::imax::timing::{PhaseBreakdown, PhaseSeconds};
use crate::imax::{ImaxConfig, KernelKind, Target};
use crate::sd::{MatMulOp, QuantModel, WorkloadTrace};

/// Host-side marshalling rate for Q8_K activations (bytes of f32
/// activations processed per second) — calibrated, see module docs.
pub const HOST_MARSHAL_Q8K_BPS: f64 = 1.39e6;

/// Host-side marshalling rate for Q8_0 activations — calibrated.
pub const HOST_MARSHAL_Q8_0_BPS: f64 = 17.0e6;

/// Host descriptor-service ceiling: the 2-core A72 can keep ~2.5 lanes
/// supplied before lane scaling saturates (§V-A: efficient to 2 lanes,
/// diminishing at ≥3).
pub const HOST_LANE_SERVICE_CEILING: f64 = 2.5;

/// IMAX3 as a benchmarked device (FPGA prototype or projected ASIC).
pub struct ImaxDevice {
    /// Physical configuration (clock, DMA, LMM).
    pub imax: ImaxConfig,
    /// The host model (always the on-board A72 pair in the paper).
    pub host: CpuGpuModel,
}

impl ImaxDevice {
    /// FPGA prototype with `lanes` active lanes.
    pub fn fpga(lanes: usize) -> ImaxDevice {
        ImaxDevice { imax: ImaxConfig::fpga(lanes), host: arm_a72() }
    }

    /// Projected 28 nm ASIC.
    pub fn asic(lanes: usize) -> ImaxDevice {
        ImaxDevice { imax: ImaxConfig::asic(lanes), host: arm_a72() }
    }

    /// Kernel kind used by a quantized model.
    pub fn kernel_kind(model: QuantModel) -> KernelKind {
        match model {
            QuantModel::Q3K => KernelKind::Q3K,
            QuantModel::Q8_0 => KernelKind::Q8_0,
        }
    }

    /// Host marshalling seconds for one offloaded op: the f32 activation
    /// tensor must be quantized into the vec-dot partner format and
    /// staged into the DMA buffer.
    pub fn dispatch_seconds(op: &MatMulOp, model: QuantModel) -> f64 {
        let act_f32_bytes = (op.n * op.repeats) as f64 * op.k as f64 * 4.0;
        let rate = match model {
            QuantModel::Q3K => HOST_MARSHAL_Q8K_BPS,
            QuantModel::Q8_0 => HOST_MARSHAL_Q8_0_BPS,
        };
        act_f32_bytes / rate
    }

    /// Aggregate accelerator-side phase breakdown for all offloaded ops
    /// of a trace (single lane; the paper's e2e setup, §IV-A).
    pub fn offload_breakdown(&self, trace: &WorkloadTrace, model: QuantModel) -> PhaseBreakdown {
        let kind = Self::kernel_kind(model);
        let lane = LaneSim::new(self.imax.clone());
        let mut total = PhaseBreakdown::default();
        let mut first = true;
        for op in trace.offloaded_ops(model) {
            let bd = lane
                .analytic_mul_mat(kind, op.m, op.n * op.repeats, op.k, first)
                .expect("SD shapes fit the 512 KiB LMM");
            total += bd;
            first = false; // kernel stays configured across ops
        }
        total
    }

    /// Accelerator phase seconds (Fig. 11 input).
    pub fn offload_phase_seconds(&self, trace: &WorkloadTrace, model: QuantModel) -> PhaseSeconds {
        self.offload_breakdown(trace, model).seconds(self.imax.clock_hz)
    }

    /// Total host marshalling seconds for a trace.
    pub fn total_dispatch_seconds(&self, trace: &WorkloadTrace, model: QuantModel) -> f64 {
        trace
            .offloaded_ops(model)
            .iter()
            .map(|op| Self::dispatch_seconds(op, model))
            .sum()
    }

    /// Host residual: everything the paper leaves on the CPU (the
    /// non-quantized dots and pipeline overhead).
    pub fn host_residual_seconds(&self, trace: &WorkloadTrace, model: QuantModel) -> f64 {
        let host_dots: f64 = trace
            .ops
            .iter()
            .filter(|op| !op.offloaded(model))
            .map(|op| op.macs() as f64 / 1e9 / self.host.gmacs(op.dtype(model)))
            .sum();
        host_dots + self.host.overhead_s
    }
}

impl Device for ImaxDevice {
    fn name(&self) -> String {
        match self.imax.target {
            Target::Fpga => "IMAX3 (FPGA 145 MHz)".to_string(),
            Target::Asic => "IMAX3 (ASIC 840 MHz)".to_string(),
        }
    }

    fn e2e_seconds(&self, trace: &WorkloadTrace, model: QuantModel) -> f64 {
        let busy = self.offload_breakdown(trace, model).seconds(self.imax.clock_hz).total();
        self.host_residual_seconds(trace, model)
            + self.total_dispatch_seconds(trace, model)
            + busy
    }

    fn kernel_seconds(&self, trace: &WorkloadTrace, model: QuantModel, lanes: usize) -> f64 {
        // Offloaded rows split across lanes; the dual-core host caps the
        // sustainable lane parallelism (Figs. 9-10's saturation).
        let busy = self.offload_breakdown(trace, model).seconds(self.imax.clock_hz).total();
        let effective = (lanes as f64).min(HOST_LANE_SERVICE_CEILING).max(1.0);
        busy / effective
    }

    fn compute_watts(&self, model: QuantModel) -> f64 {
        kernel_power(self.imax.target, Self::kernel_kind(model))
    }

    fn host_watts(&self) -> Option<f64> {
        Some(self.host.tdp_watts)
    }

    fn e2e_split(&self, trace: &WorkloadTrace, model: QuantModel) -> (f64, f64) {
        let busy = self.offload_breakdown(trace, model).seconds(self.imax.clock_hz).total();
        let host = self.host_residual_seconds(trace, model)
            + self.total_dispatch_seconds(trace, model);
        (host, busy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sd::arch::sd_turbo_512;

    fn trace() -> WorkloadTrace {
        sd_turbo_512(1)
    }

    #[test]
    fn fig6_q3k_ordering_and_magnitudes() {
        // Paper Fig. 6: ARM 809.7, FPGA 790.3, ASIC 754.5.
        let t = trace();
        let arm = arm_a72().e2e_seconds(&t, QuantModel::Q3K);
        let fpga = ImaxDevice::fpga(1).e2e_seconds(&t, QuantModel::Q3K);
        let asic = ImaxDevice::asic(1).e2e_seconds(&t, QuantModel::Q3K);
        assert!(fpga < arm, "FPGA ({fpga}) must beat ARM ({arm}) on Q3_K");
        assert!(asic < fpga, "ASIC ({asic}) must beat FPGA ({fpga})");
        assert!((fpga - 790.3).abs() < 790.0 * 0.03, "FPGA Q3_K e2e {fpga}");
        assert!((asic - 754.5).abs() < 754.0 * 0.04, "ASIC Q3_K e2e {asic}");
    }

    #[test]
    fn fig7_q8_0_fpga_loses_to_arm_asic_wins() {
        // Paper Fig. 7: ARM 625.1, FPGA 654.7 (transfer-bound!), ASIC 558.0.
        let t = trace();
        let arm = arm_a72().e2e_seconds(&t, QuantModel::Q8_0);
        let fpga = ImaxDevice::fpga(1).e2e_seconds(&t, QuantModel::Q8_0);
        let asic = ImaxDevice::asic(1).e2e_seconds(&t, QuantModel::Q8_0);
        assert!(
            fpga > arm,
            "the paper's headline crossover: Q8_0 transfer volume makes the \
             FPGA ({fpga}) slower than standalone ARM ({arm})"
        );
        assert!(asic < arm, "ASIC ({asic}) must still beat ARM ({arm})");
        assert!((fpga - 654.7).abs() < 655.0 * 0.03, "FPGA Q8_0 e2e {fpga}");
        assert!((asic - 558.0).abs() < 558.0 * 0.06, "ASIC Q8_0 e2e {asic}");
    }

    #[test]
    fn fig9_fpga_kernel_beats_arm_q3k() {
        let t = trace();
        let fpga = ImaxDevice::fpga(1).kernel_seconds(&t, QuantModel::Q3K, 1);
        let arm = arm_a72().kernel_seconds(&t, QuantModel::Q3K, 1);
        assert!(fpga < arm, "145 MHz FPGA ({fpga}) beats ARM ({arm}) on kernels");
    }

    #[test]
    fn fig9_asic_competitive_with_xeon() {
        let t = trace();
        let asic = ImaxDevice::asic(1).kernel_seconds(&t, QuantModel::Q3K, 1);
        let xeon = super::super::baseline::xeon_w5().kernel_seconds(&t, QuantModel::Q3K, 16);
        let ratio = asic / xeon;
        assert!(
            (0.3..3.0).contains(&ratio),
            "ASIC ({asic}) should be same order as full Xeon ({xeon})"
        );
    }

    #[test]
    fn lane_scaling_saturates_past_two(){
        let t = trace();
        let dev = ImaxDevice::fpga(1);
        let t1 = dev.kernel_seconds(&t, QuantModel::Q3K, 1);
        let t2 = dev.kernel_seconds(&t, QuantModel::Q3K, 2);
        let t3 = dev.kernel_seconds(&t, QuantModel::Q3K, 3);
        let t8 = dev.kernel_seconds(&t, QuantModel::Q3K, 8);
        assert!((t1 / t2 - 2.0).abs() < 1e-9, "perfect scaling to 2 lanes");
        assert!(t3 > t2 * 2.0 / 3.0 * 0.99, "diminishing returns at 3");
        assert_eq!(t3, t8, "host-bound plateau beyond the service ceiling");
    }

    #[test]
    fn q8_0_busy_is_load_dominated_and_bigger_than_q3k() {
        // Fig. 11's asymmetry: Q8_0 moves ~3x the bytes.
        let t = trace();
        let dev = ImaxDevice::fpga(1);
        let p3 = dev.offload_phase_seconds(&t, QuantModel::Q3K);
        let p8 = dev.offload_phase_seconds(&t, QuantModel::Q8_0);
        assert!(p8.load > p3.load, "Q8_0 LOAD {} vs Q3_K {}", p8.load, p3.load);
        assert!(p8.load > p8.exec, "LOAD dominates EXEC on the FPGA");
        assert!(p3.load > p3.exec * 0.5, "Q3_K also transfer-heavy");
    }

    #[test]
    fn asic_shrinks_busy_by_clock_ratio_but_not_dispatch() {
        let t = trace();
        let fpga = ImaxDevice::fpga(1);
        let asic = ImaxDevice::asic(1);
        let m = QuantModel::Q8_0;
        let bf = fpga.offload_phase_seconds(&t, m).total();
        let ba = asic.offload_phase_seconds(&t, m).total();
        assert!((bf / ba - 840.0 / 145.0).abs() < 0.01, "busy scales with clock");
        let df = fpga.total_dispatch_seconds(&t, m);
        let da = asic.total_dispatch_seconds(&t, m);
        assert_eq!(df, da, "host marshalling does not scale with the ASIC clock");
    }
}
