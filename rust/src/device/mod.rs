//! Device performance/power models for the paper's comparison platforms.
//!
//! The paper benchmarks five devices (Table II): the host ARM Cortex-A72,
//! IMAX3 on the VPK180 FPGA, a projected IMAX3 28 nm ASIC, an Intel Xeon
//! w5-2465X, and an NVIDIA GTX 1080 Ti. We have none of that hardware;
//! per the substitution ledger the CPU/GPU baselines are **analytic
//! models calibrated on the paper's own published measurements** (they
//! enter the evaluation only through per-dtype mat-mul throughput and
//! TDP), while the IMAX devices are **derived from the simulator** in
//! [`crate::imax`] plus a host-dispatch model.
//!
//! Calibration identities (see `EXPERIMENTS.md` §Calibration for the
//! derivation): the ARM model's four per-dtype throughputs are the unique
//! solution reproducing both end-to-end points of Figs. 6–7; the Xeon's
//! reproduce Table I's Q3_K-model proportions exactly; the IMAX DMA and
//! host-marshalling rates are fixed by the four published FPGA/ASIC
//! end-to-end latencies.

pub mod baseline;
pub mod future;
pub mod imax_dev;
pub mod pdp;

pub use baseline::{arm_a72, gtx_1080ti, xeon_w5, CpuGpuModel};
pub use imax_dev::ImaxDevice;
pub use pdp::{pdp_joules, PdpEntry};

use crate::sd::{QuantModel, WorkloadTrace};

/// Common interface every evaluated platform implements.
pub trait Device {
    /// Display name as in the paper's figures.
    fn name(&self) -> String;

    /// End-to-end latency (seconds) for one image generation.
    fn e2e_seconds(&self, trace: &WorkloadTrace, model: QuantModel) -> f64;

    /// Kernel-only latency (seconds) for the *offloaded* quantized dot
    /// ops at a given thread/lane count (Figs. 9–10: "execution time of
    /// only the offloaded quantized dot-product kernels", marshalling and
    /// memory-copy overhead excluded as §III-A's profiling does).
    fn kernel_seconds(&self, trace: &WorkloadTrace, model: QuantModel, threads: usize) -> f64;

    /// Power during compute phases (W) — used for the PDP of Fig. 8.
    fn compute_watts(&self, model: QuantModel) -> f64;

    /// Power of the host portion if the device is an accelerator
    /// (`None` for self-contained devices).
    fn host_watts(&self) -> Option<f64>;

    /// Seconds spent in host phases vs accelerator phases for an e2e run
    /// (`(host_s, accel_s)`); self-contained devices put everything in
    /// the first slot.
    fn e2e_split(&self, trace: &WorkloadTrace, model: QuantModel) -> (f64, f64);
}

/// Table II rows (static spec data), used by the `table2_platforms` bench.
#[derive(Debug, Clone)]
pub struct PlatformSpec {
    /// Device name.
    pub device: &'static str,
    /// Host CPU if the device is an accelerator.
    pub host: &'static str,
    /// Core/PE count description.
    pub cores: &'static str,
    /// Die area (mm²) when published.
    pub area_mm2: &'static str,
    /// Process node.
    pub process: &'static str,
    /// Operating frequency.
    pub frequency: &'static str,
    /// Memory configuration.
    pub memory: &'static str,
    /// Power (W, TDP or estimated).
    pub power: &'static str,
}

/// The five Table II rows.
pub fn table2_specs() -> Vec<PlatformSpec> {
    vec![
        PlatformSpec {
            device: "ARM Cortex-A72 (on Versal)",
            host: "-",
            cores: "2",
            area_mm2: "-",
            process: "7 nm",
            frequency: "1.4 GHz",
            memory: "8 GB DDR4",
            power: "1.5",
        },
        PlatformSpec {
            device: "IMAX3 (Xilinx VPK180)",
            host: "ARM Cortex-A72",
            cores: "64/lane",
            area_mm2: "-",
            process: "7 nm",
            frequency: "145 MHz",
            memory: "8 + 4 GB DDR4",
            power: "180",
        },
        PlatformSpec {
            device: "IMAX3 (28nm)",
            host: "-",
            cores: "64/lane",
            area_mm2: "14.6",
            process: "28 nm",
            frequency: "800 MHz",
            memory: "-",
            power: "47.7 / 52.8",
        },
        PlatformSpec {
            device: "Intel Xeon w5-2465X",
            host: "-",
            cores: "16",
            area_mm2: "-",
            process: "Intel 7",
            frequency: "3.1 GHz",
            memory: "512 GB DDR5",
            power: "200",
        },
        PlatformSpec {
            device: "NVIDIA GTX 1080 Ti",
            host: "Xeon w5-2465X",
            cores: "3584",
            area_mm2: "471",
            process: "16 nm",
            frequency: "1480 MHz",
            memory: "11 GB GDDR5X",
            power: "250",
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_five_rows_matching_paper() {
        let rows = table2_specs();
        assert_eq!(rows.len(), 5);
        assert!(rows.iter().any(|r| r.device.contains("VPK180")));
        assert!(rows.iter().any(|r| r.device.contains("28nm")));
        assert_eq!(rows[4].cores, "3584");
    }
}
