//! §VI future-work projections, implemented.
//!
//! The paper's conclusion names three follow-ups; this module models all
//! of them on top of the calibrated substrate so the `future_work` bench
//! can quantify each:
//!
//! 1. **"Implement a wider variety of kernels to increase the offload
//!    ratio"** — an F16 mat-mul kernel mapping for the lane (the FP
//!    multiply/add units the base IMAX ISA already has), offloading the
//!    conv-im2col and VAE GEMMs that dominate Table I.
//! 2. **"Strengthen the integration with a multi-core host"** — the host
//!    core count parameterizes both marshalling throughput and the lane
//!    service ceiling (§V-A knee).
//! 3. **"Evaluating scalability with larger image resolutions"** — the
//!    trace generator is resolution-parametric; see the bench.

use super::baseline::arm_a72;
use super::imax_dev::{ImaxDevice, HOST_LANE_SERVICE_CEILING};
use super::Device;
use crate::ggml::DType;
use crate::imax::dma::transfer_cycles;
use crate::imax::timing::PhaseBreakdown;
use crate::imax::ImaxConfig;
#[cfg(test)]
use crate::imax::PES_PER_LANE;
use crate::sd::{MatMulOp, QuantModel, WorkloadTrace};

/// The hypothetical F16 kernel mapping (future work #1).
///
/// Uses the base-ISA FP units: per 12-PE group, 2 loaders stream f16
/// word pairs, 8 FMA PEs retire 2 f16 MACs each per beat (f16 operands
/// packed two per 32-bit lane), 2 PEs run the f32 accumulation spine.
/// Three groups plus an 8-PE shared drain/reduce spine = 44 PEs — within
/// the 64-PE lane like the quantized kernels.
#[derive(Debug, Clone, Copy)]
pub struct F16Kernel;

impl F16Kernel {
    /// PEs occupied.
    pub const PE_COUNT: usize = 44;
    /// f16 MACs per beat for the lane (3 groups × 16).
    pub const MACS_PER_BEAT: usize = 48;
    /// Pipeline depth.
    pub const DEPTH: usize = 16;

    /// Weight-row bytes for K elements (f16).
    pub fn w_row_bytes(k: usize) -> usize {
        2 * k
    }

    /// Activation-row bytes (host converts f32 → f16 while staging).
    pub fn a_row_bytes(k: usize) -> usize {
        2 * k
    }

    /// Analytic phase breakdown of one offloaded F16 mul_mat, using the
    /// same tiling scheme as the quantized kernels.
    pub fn analytic_mul_mat(
        imax: &ImaxConfig,
        m: usize,
        n: usize,
        k: usize,
        reconf: bool,
    ) -> PhaseBreakdown {
        let w_row = Self::w_row_bytes(k);
        let a_row = Self::a_row_bytes(k);
        let lmm = imax.lmm_bytes;
        // Activation tile at most half the LMM; shrink until a weight
        // row + result column fits (mirrors TilePlan::new).
        let mut a_tile = (lmm / 2 / a_row).clamp(1, n);
        while a_tile > 1 && lmm.saturating_sub(a_tile * a_row) < w_row + a_tile * 4 {
            a_tile /= 2;
        }
        let rem = lmm - a_tile * a_row;
        let w_tile = (rem / (w_row + a_tile * 4)).max(1).min(m);

        let mut bd = PhaseBreakdown::default();
        let pe = Self::PE_COUNT as u64;
        if reconf {
            bd.conf = imax.conf_cycles_per_pe * pe;
        }
        let beats_per_dot = (k as u64).div_ceil(Self::MACS_PER_BEAT as u64);
        let mut at0 = 0;
        while at0 < n {
            let at1 = (at0 + a_tile).min(n);
            bd.load += transfer_cycles(imax, ((at1 - at0) * a_row) as u64);
            let mut wt0 = 0;
            while wt0 < m {
                let wt1 = (wt0 + w_tile).min(m);
                bd.regv += imax.regv_cycles_per_pe * pe;
                bd.range += imax.range_cycles_per_pe * pe;
                bd.load += transfer_cycles(imax, ((wt1 - wt0) * w_row) as u64);
                let dots = ((wt1 - wt0) * (at1 - at0)) as u64;
                bd.exec += Self::DEPTH as u64 + dots * (beats_per_dot + 2);
                bd.drain += transfer_cycles(imax, ((wt1 - wt0) * (at1 - at0) * 4) as u64);
                wt0 = wt1;
            }
            at0 = at1;
        }
        bd
    }
}

/// Host marshalling rate for the F16 path (f32→f16 conversion + memcpy —
/// far cheaper than block quantization; ~A72 streaming rate).
pub const HOST_MARSHAL_F16_BPS: f64 = 120.0e6;

/// Future-work device: the calibrated IMAX device plus the F16 kernel
/// and a parameterized host.
pub struct ImaxFutureDevice {
    /// The baseline device (quantized kernels, calibrated).
    pub base: ImaxDevice,
    /// Offload F16 ops too (future work #1).
    pub offload_f16: bool,
    /// Host cores (future work #2; the paper's prototype has 2).
    pub host_cores: usize,
}

impl ImaxFutureDevice {
    /// The paper's prototype configuration (no extra kernels, 2 cores).
    pub fn baseline(imax: ImaxConfig) -> ImaxFutureDevice {
        ImaxFutureDevice { base: ImaxDevice { imax, host: arm_a72() }, offload_f16: false, host_cores: 2 }
    }

    /// With the F16 kernel enabled and `host_cores` host cores.
    pub fn extended(imax: ImaxConfig, host_cores: usize) -> ImaxFutureDevice {
        let mut host = arm_a72();
        // More host cores scale the CPU-side throughputs linearly (the
        // A72 numbers are 2-core figures).
        let scale = host_cores as f64 / 2.0;
        host.gmacs_f32 *= scale;
        host.gmacs_f16 *= scale;
        host.gmacs_q3k *= scale;
        host.gmacs_q8_0 *= scale;
        ImaxFutureDevice {
            base: ImaxDevice { imax, host },
            offload_f16: true,
            host_cores,
        }
    }

    fn f16_ops<'t>(&self, trace: &'t WorkloadTrace, model: QuantModel) -> Vec<&'t MatMulOp> {
        trace
            .ops
            .iter()
            .filter(|op| op.dtype(model) == DType::F16)
            .collect()
    }

    /// Marshalling seconds for the F16 offloads (scaled by host cores).
    fn f16_dispatch_seconds(&self, trace: &WorkloadTrace, model: QuantModel) -> f64 {
        let bytes: f64 = self
            .f16_ops(trace, model)
            .iter()
            .map(|op| (op.n * op.repeats) as f64 * op.k as f64 * 4.0)
            .sum();
        bytes / (HOST_MARSHAL_F16_BPS * self.host_cores as f64 / 2.0)
    }

    /// F16 accelerator busy seconds.
    fn f16_busy_seconds(&self, trace: &WorkloadTrace, model: QuantModel) -> f64 {
        let mut total = PhaseBreakdown::default();
        let mut first = true;
        for op in self.f16_ops(trace, model) {
            total += F16Kernel::analytic_mul_mat(
                &self.base.imax,
                op.m,
                op.n * op.repeats,
                op.k,
                first,
            );
            first = false;
        }
        total.seconds(self.base.imax.clock_hz).total()
    }

    /// Offload ratio in MACs under this configuration.
    pub fn offload_ratio(&self, trace: &WorkloadTrace, model: QuantModel) -> f64 {
        let mut off = trace.offloaded_macs(model) as f64;
        if self.offload_f16 {
            off += self
                .f16_ops(trace, model)
                .iter()
                .map(|op| op.macs() as f64)
                .sum::<f64>();
        }
        off / trace.total_macs() as f64
    }
}

impl Device for ImaxFutureDevice {
    fn name(&self) -> String {
        let mut n = self.base.name();
        if self.offload_f16 {
            n.push_str(" +F16");
        }
        if self.host_cores != 2 {
            n.push_str(&format!(" {}c-host", self.host_cores));
        }
        n
    }

    fn e2e_seconds(&self, trace: &WorkloadTrace, model: QuantModel) -> f64 {
        let (host, accel) = self.e2e_split(trace, model);
        host + accel
    }

    fn kernel_seconds(&self, trace: &WorkloadTrace, model: QuantModel, lanes: usize) -> f64 {
        let mut busy = self
            .base
            .offload_breakdown(trace, model)
            .seconds(self.base.imax.clock_hz)
            .total();
        if self.offload_f16 {
            busy += self.f16_busy_seconds(trace, model);
        }
        // More host cores lift the §V-A service ceiling proportionally.
        let ceiling = HOST_LANE_SERVICE_CEILING * self.host_cores as f64 / 2.0;
        busy / (lanes as f64).min(ceiling).max(1.0)
    }

    fn compute_watts(&self, model: QuantModel) -> f64 {
        // With the F16 kernel resident too, more units are active; use
        // the larger of the kernel powers plus the F16 kernel's units.
        let base = self.base.compute_watts(model);
        if self.offload_f16 && self.base.imax.target == crate::imax::Target::Asic {
            crate::imax::power::asic_power_units(
                F16Kernel::PE_COUNT.max(match model {
                    QuantModel::Q3K => 51,
                    QuantModel::Q8_0 => 46,
                }),
            )
        } else {
            base
        }
    }

    fn host_watts(&self) -> Option<f64> {
        // Scale the A72 power estimate with core count.
        Some(self.base.host.tdp_watts * self.host_cores as f64 / 2.0)
    }

    fn e2e_split(&self, trace: &WorkloadTrace, model: QuantModel) -> (f64, f64) {
        let core_scale = self.host_cores as f64 / 2.0;
        let mut host_dots: f64 = trace
            .ops
            .iter()
            .filter(|op| {
                !(op.offloaded(model)
                    || (self.offload_f16 && op.dtype(model) == DType::F16))
            })
            .map(|op| op.macs() as f64 / 1e9 / self.base.host.gmacs(op.dtype(model)))
            .sum();
        host_dots += self.base.host.overhead_s / core_scale.min(2.0); // sampler etc. partly parallel
        let mut dispatch = self.base.total_dispatch_seconds(trace, model) / core_scale;
        let mut busy = self
            .base
            .offload_breakdown(trace, model)
            .seconds(self.base.imax.clock_hz)
            .total();
        if self.offload_f16 {
            dispatch += self.f16_dispatch_seconds(trace, model);
            busy += self.f16_busy_seconds(trace, model);
        }
        (host_dots + dispatch, busy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sd::arch::sd_turbo_512;

    #[test]
    fn f16_kernel_fits_the_lane() {
        assert!(F16Kernel::PE_COUNT <= PES_PER_LANE);
    }

    #[test]
    fn baseline_future_device_matches_imax_device() {
        let t = sd_turbo_512(1);
        let base = ImaxDevice::fpga(1);
        let fut = ImaxFutureDevice::baseline(ImaxConfig::fpga(1));
        for m in [QuantModel::Q3K, QuantModel::Q8_0] {
            let a = base.e2e_seconds(&t, m);
            let b = fut.e2e_seconds(&t, m);
            assert!((a - b).abs() / a < 0.02, "{m:?}: {a} vs {b}");
        }
    }

    #[test]
    fn f16_offload_raises_offload_ratio_dramatically() {
        let t = sd_turbo_512(1);
        let base = ImaxFutureDevice::baseline(ImaxConfig::asic(1));
        let ext = ImaxFutureDevice::extended(ImaxConfig::asic(1), 2);
        let m = QuantModel::Q8_0;
        let r0 = base.offload_ratio(&t, m);
        let r1 = ext.offload_ratio(&t, m);
        assert!(r0 < 0.20, "paper's current ratio {r0}");
        assert!(r1 > 0.85, "F16 kernels lift the ratio to {r1}");
    }

    #[test]
    fn f16_offload_loses_on_the_prototype_dma() {
        // Honest finding: with the calibrated prototype LOAD path
        // (~28 MB/s effective), offloading the F16 GEMMs makes e2e WORSE
        // — the future work only pays off together with a fixed
        // interconnect, exactly what Fig. 11's LOAD dominance implies.
        let t = sd_turbo_512(1);
        let ext = ImaxFutureDevice::extended(ImaxConfig::asic(1), 4);
        let m = QuantModel::Q8_0;
        assert!(
            ext.e2e_seconds(&t, m) > ImaxDevice::asic(1).e2e_seconds(&t, m),
            "slow-DMA F16 offload should regress"
        );
    }

    #[test]
    fn asic_with_f16_kernel_and_fixed_dma_approaches_xeon() {
        // The paper's thesis: higher offload ratio + ASIC + a production
        // interconnect closes the gap to the CPU class.
        let t = sd_turbo_512(1);
        let mut imax = ImaxConfig::asic(1);
        imax.dma_bytes_per_cycle = 8.0; // ~6.7 GB/s on-package at 840 MHz
        let ext = ImaxFutureDevice::extended(imax, 4);
        let m = QuantModel::Q8_0;
        let e2e = ext.e2e_seconds(&t, m);
        let baseline = ImaxDevice::asic(1).e2e_seconds(&t, m);
        assert!(
            e2e < baseline / 2.0,
            "F16 offload + fast DMA must at least halve e2e: {e2e} vs {baseline}"
        );
        let xeon = super::super::baseline::xeon_w5().e2e_seconds(&t, m);
        assert!(
            e2e < xeon * 4.0,
            "projected ASIC ({e2e}) should reach the Xeon's order ({xeon})"
        );
    }

    #[test]
    fn more_host_cores_lift_the_lane_ceiling() {
        let t = sd_turbo_512(1);
        let m = QuantModel::Q3K;
        let two = ImaxFutureDevice::extended(ImaxConfig::fpga(1), 2);
        let eight = ImaxFutureDevice::extended(ImaxConfig::fpga(1), 8);
        let k2 = two.kernel_seconds(&t, m, 8);
        let k8 = eight.kernel_seconds(&t, m, 8);
        assert!(k8 < k2 * 0.5, "8-core host unlocks lane scaling: {k8} vs {k2}");
    }

    #[test]
    fn f16_busy_scales_with_clock() {
        let t = sd_turbo_512(1);
        let f = ImaxFutureDevice::extended(ImaxConfig::fpga(1), 2);
        let a = ImaxFutureDevice::extended(ImaxConfig::asic(1), 2);
        let bf = f.f16_busy_seconds(&t, QuantModel::Q8_0);
        let ba = a.f16_busy_seconds(&t, QuantModel::Q8_0);
        assert!((bf / ba - 840.0 / 145.0).abs() < 0.01);
    }
}
