//! Power-Delay Product (Fig. 8).
//!
//! Equation (1): `PDP = execution time × power`, "considering the power
//! consumption during each distinct execution phase" — so for the IMAX
//! devices host phases are priced at the A72's power and accelerator
//! phases at the kernel power (board power for the FPGA, the synthesis
//! estimate for the ASIC).

use super::Device;
use crate::sd::{QuantModel, WorkloadTrace};

/// One Fig. 8 bar.
#[derive(Debug, Clone)]
pub struct PdpEntry {
    /// Device name.
    pub device: String,
    /// End-to-end seconds.
    pub seconds: f64,
    /// Phase-weighted energy (J).
    pub joules: f64,
}

/// Phase-weighted PDP for a device on a workload.
pub fn pdp_joules(dev: &dyn Device, trace: &WorkloadTrace, model: QuantModel) -> PdpEntry {
    let (host_s, accel_s) = dev.e2e_split(trace, model);
    let joules = match dev.host_watts() {
        Some(hw) => host_s * hw + accel_s * dev.compute_watts(model),
        None => (host_s + accel_s) * dev.compute_watts(model),
    };
    PdpEntry { device: dev.name(), seconds: host_s + accel_s, joules }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{arm_a72, gtx_1080ti, xeon_w5, ImaxDevice};
    use crate::sd::arch::sd_turbo_512;

    #[test]
    fn fig8_orderings_hold() {
        let t = sd_turbo_512(1);
        for m in [QuantModel::Q3K, QuantModel::Q8_0] {
            let arm = pdp_joules(&arm_a72(), &t, m).joules;
            let xeon = pdp_joules(&xeon_w5(), &t, m).joules;
            let gpu = pdp_joules(&gtx_1080ti(), &t, m).joules;
            let asic = pdp_joules(&ImaxDevice::asic(1), &t, m).joules;
            let fpga = pdp_joules(&ImaxDevice::fpga(1), &t, m).joules;
            // "the low-power ARM Cortex-A72 exhibited the lowest PDP".
            assert!(arm < asic && arm < xeon && arm < gpu && arm < fpga, "{m:?}");
            // "the projected PDP for the ASIC version significantly
            // surpassed that of the high-performance Xeon CPU for both".
            assert!(asic < xeon, "{m:?}: asic {asic} vs xeon {xeon}");
            // FPGA board power keeps the prototype above the ASIC.
            assert!(asic < fpga, "{m:?}");
        }
        // "In the Q3_K case, IMAX (28 nm) achieved a lower PDP than the GPU."
        let asic3 = pdp_joules(&ImaxDevice::asic(1), &t, QuantModel::Q3K).joules;
        let gpu3 = pdp_joules(&gtx_1080ti(), &t, QuantModel::Q3K).joules;
        assert!(asic3 < gpu3, "asic {asic3} vs gpu {gpu3}");
    }

    #[test]
    fn pdp_is_time_times_power_for_flat_devices() {
        let t = sd_turbo_512(1);
        let x = xeon_w5();
        let e = pdp_joules(&x, &t, QuantModel::Q3K);
        let direct = x.e2e_seconds(&t, QuantModel::Q3K) * 200.0;
        assert!((e.joules - direct).abs() < 1e-6);
    }

    #[test]
    fn imax_pdp_splits_host_and_accel_power() {
        let t = sd_turbo_512(1);
        let dev = ImaxDevice::asic(1);
        let (h, a) = dev.e2e_split(&t, QuantModel::Q3K);
        let e = pdp_joules(&dev, &t, QuantModel::Q3K);
        assert!((e.joules - (h * 1.5 + a * 52.8)).abs() < 1e-6);
        assert!(h > a, "host phases dominate the e2e run");
    }
}
