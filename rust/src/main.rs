//! `imax-sd`: CLI for the IMAX3 Stable-Diffusion reproduction.
//!
//! Subcommands map onto the paper's evaluation:
//!
//! * `generate`  — run the mini pipeline end-to-end and write a PNG (Fig. 5)
//! * `e2e`       — device end-to-end latency comparison (Figs. 6–7)
//! * `pdp`       — power-delay product (Fig. 8)
//! * `scaling`   — kernel time vs lanes/threads (Figs. 9–10)
//! * `breakdown` — IMAX phase breakdown (Fig. 11)
//! * `table1`    — dot-time by dtype (Table I)
//! * `trace`     — dump the SD-Turbo mat-mul trace summary
//! * `serve`     — HTTP prediction server (create / poll / cancel, SLO shedding)

use imax_sd::device::{arm_a72, gtx_1080ti, pdp_joules, xeon_w5, Device, ImaxDevice};
use imax_sd::sd::arch::sd_turbo_512;
use imax_sd::sd::pipeline::{to_rgb8, Pipeline, PipelineConfig};
use imax_sd::sd::profiler::table1_shares;
use imax_sd::sd::QuantModel;
use imax_sd::serve::{RunnerState, ServeConfig, ServeHarness};
use imax_sd::server::{RunnerConfig, Server};
use imax_sd::util::cli::{App, Arg, BackendFlags, BackendKind};
use imax_sd::util::png::{write_png, ColorType};
use imax_sd::util::tables::{BarChart, StackedBars, Table};

fn model_of(s: &str) -> QuantModel {
    match s {
        "q3_k" | "q3k" | "Q3_K" => QuantModel::Q3K,
        "q8_0" | "q8" | "Q8_0" => QuantModel::Q8_0,
        other => {
            eprintln!("unknown model '{other}' (use q3_k or q8_0)");
            std::process::exit(2);
        }
    }
}

fn devices() -> Vec<Box<dyn Device>> {
    vec![
        Box::new(arm_a72()),
        Box::new(ImaxDevice::fpga(1)),
        Box::new(ImaxDevice::asic(1)),
        Box::new(xeon_w5()),
        Box::new(gtx_1080ti()),
    ]
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let app = App::new("imax-sd", "Stable Diffusion on the IMAX3 CGLA — reproduction CLI")
        .subcommand(
            App::new("generate", "generate an image with the mini pipeline (Fig. 5)")
                .arg(Arg::opt("prompt", 'p', "TEXT", "text prompt").default("a lovely cat"))
                .arg(Arg::opt("model", 'm', "TYPE", "q3_k or q8_0").default("q8_0"))
                .arg(Arg::opt("seed", 's', "N", "latent seed").default("42"))
                .arg(Arg::opt("steps", 'n', "N", "denoising steps").default("1"))
                .arg(Arg::opt("out", 'o', "PATH", "output PNG").default("out.png"))
                .args(BackendFlags::args()),
        )
        .subcommand(
            App::new("e2e", "device end-to-end latency comparison (Figs. 6-7)")
                .arg(Arg::opt("model", 'm', "TYPE", "q3_k or q8_0").default("q3_k")),
        )
        .subcommand(App::new("pdp", "power-delay product per device (Fig. 8)"))
        .subcommand(
            App::new("scaling", "kernel time vs lanes/threads (Figs. 9-10)")
                .arg(Arg::opt("model", 'm', "TYPE", "q3_k or q8_0").default("q3_k")),
        )
        .subcommand(
            App::new("breakdown", "IMAX phase breakdown (Fig. 11)")
                .arg(Arg::opt("target", 't', "T", "fpga or asic").default("fpga")),
        )
        .subcommand(App::new("table1", "dot-product time by dtype (Table I)"))
        .subcommand(App::new("trace", "dump the SD-Turbo workload trace summary"))
        .subcommand(
            App::new("serve", "HTTP prediction server: POST /predictions, poll, cancel")
                .arg(Arg::opt("addr", 'a', "HOST:PORT", "bind address").default("127.0.0.1:8080"))
                .arg(Arg::opt("model", 'm', "TYPE", "q3_k or q8_0").default("q8_0"))
                .arg(
                    Arg::opt("slo", '\0', "SECONDS", "queue-latency SLO; above it creates get 429")
                        .default("2.0"),
                )
                .arg(Arg::opt("queue", 'q', "N", "admission queue capacity").default("64"))
                .arg(Arg::opt("steps", 'n', "N", "default denoising steps").default("1"))
                .arg(
                    Arg::opt("max-steps", '\0', "N", "largest per-request step count accepted")
                        .default("8"),
                )
                .arg(Arg::opt("batch", '\0', "N", "micro-batch size").default("4"))
                .arg(Arg::opt("workers", 'w', "N", "serving worker threads").default("2"))
                .args(BackendFlags::args()),
        );

    let m = app.parse_env();
    let Some(sub) = m.sub else {
        println!("{}", app.help_text());
        return Ok(());
    };
    let trace = sd_turbo_512(1);

    match sub.command.as_str() {
        "generate" => {
            let model = model_of(sub.str("model"));
            let sel = BackendFlags::parse(&sub)?;
            let pipe = Pipeline::new(PipelineConfig {
                weight_seed: 0x5D_7B0,
                model: Some(model),
                steps: sub.usize("steps")?,
                backend: sel.pipeline_backend(),
                conv_offload: sel.conv_offload,
            });
            let (img, report) = pipe.generate(sub.str("prompt"), sub.u64("seed")?);
            let out = sub.str("out");
            write_png(out, img.w as u32, img.h as u32, ColorType::Rgb, &to_rgb8(&img))?;
            println!(
                "wrote {out}: {} ops ({} offloaded over {} lane submissions), {:.2} s wall",
                report.matmul_calls,
                report.offloaded_calls,
                report.lane_submissions,
                report.wall_seconds
            );
            let c = report.cache;
            if c.hits + c.misses > 0 {
                println!(
                    "weight cache: {}/{} hits ({:.0} %), {} B LOAD skipped, {} B evicted",
                    c.hits,
                    c.hits + c.misses,
                    100.0 * c.hit_rate(),
                    c.hit_bytes,
                    c.evicted_bytes
                );
            }
        }
        "e2e" => {
            let model = model_of(sub.str("model"));
            let mut c = BarChart::new(
                &format!("E2E latency, {} model (s)", model.name()),
                "s",
            )
            .log();
            for d in devices() {
                c.bar(&d.name(), d.e2e_seconds(&trace, model));
            }
            c.print();
        }
        "pdp" => {
            let mut t = Table::new(
                "PDP (J)",
                &["Device", "Q3_K", "Q8_0"],
            );
            for d in devices() {
                t.row(&[
                    d.name(),
                    format!("{:.0}", pdp_joules(d.as_ref(), &trace, QuantModel::Q3K).joules),
                    format!("{:.0}", pdp_joules(d.as_ref(), &trace, QuantModel::Q8_0).joules),
                ]);
            }
            t.print();
        }
        "scaling" => {
            let model = model_of(sub.str("model"));
            let mut t = Table::new(
                &format!("{} kernel seconds vs threads/lanes", model.name()),
                &["Device", "1", "2", "4", "8"],
            );
            for d in devices() {
                let mut row = vec![d.name()];
                for l in [1usize, 2, 4, 8] {
                    row.push(format!("{:.2}", d.kernel_seconds(&trace, model, l)));
                }
                t.row(&row);
            }
            t.print();
        }
        "breakdown" => {
            let dev = match sub.str("target") {
                "asic" => ImaxDevice::asic(1),
                _ => ImaxDevice::fpga(1),
            };
            let mut sb = StackedBars::new(
                &format!("IMAX phase breakdown ({})", dev.name()),
                "s",
                &["EXEC", "LOAD", "DRAIN", "CONF", "REGV", "RANGE"],
            );
            for model in [QuantModel::Q3K, QuantModel::Q8_0] {
                sb.bar(model.name(), &dev.offload_phase_seconds(&trace, model).fig11_order());
            }
            sb.print();
        }
        "table1" => {
            let dev = xeon_w5();
            let mut t = Table::new("Table I (% of dot time)", &["Model", "F32", "F16", "Quant"]);
            for model in [QuantModel::Q3K, QuantModel::Q8_0] {
                let shares = table1_shares(&trace, &dev, model);
                let get = |n: &str| {
                    shares
                        .iter()
                        .find(|(m, _)| *m == n)
                        .map(|(_, v)| format!("{v:.1} %"))
                        .unwrap_or_else(|| "-".into())
                };
                t.row(&[
                    model.name().to_string(),
                    get("F32"),
                    get("F16"),
                    get(model.weight_dtype().name()),
                ]);
            }
            t.print();
        }
        "trace" => {
            println!("SD-Turbo 512x512, 1 step: {} mat-muls, {:.1} GMACs total", trace.ops.len(), trace.total_macs() as f64 / 1e9);
            for model in [QuantModel::Q3K, QuantModel::Q8_0] {
                println!("\n{} model:", model.name());
                for (d, v) in trace.macs_by_dtype(model) {
                    println!("  {d:<5} {:>8.1} GMACs", v as f64 / 1e9);
                }
                println!(
                    "  offload: {:.1} % of MACs across {} ops",
                    100.0 * trace.offloaded_macs(model) as f64 / trace.total_macs() as f64,
                    trace.offloaded_ops(model).len()
                );
            }
        }
        "serve" => {
            let model = model_of(sub.str("model"));
            let sel = BackendFlags::parse(&sub)?;
            if sel.kind == BackendKind::Host {
                eprintln!("serve needs the lane coordinator; use --backend imax or sharded");
                std::process::exit(2);
            }
            let serve_cfg = ServeConfig {
                lanes: sel.lanes,
                host_threads: sel.threads,
                max_batch: sub.usize("batch")?,
                workers: sub.usize("workers")?,
                sharded: sel.kind == BackendKind::Sharded,
                queue_capacity: sub.usize("queue")?,
            };
            let mut imax = imax_sd::imax::ImaxConfig::fpga(sel.lanes);
            imax.weight_cache_bytes = sel.cache_bytes;
            let harness = ServeHarness::with_imax(
                PipelineConfig {
                    weight_seed: 0x5D_7B0,
                    model: Some(model),
                    steps: sub.usize("steps")?,
                    backend: imax_sd::sd::pipeline::Backend::Host { threads: 2 },
                    conv_offload: sel.conv_offload,
                },
                serve_cfg,
                imax,
            );
            let runner_cfg = RunnerConfig {
                slo_seconds: sub.f64("slo")?,
                default_steps: sub.usize("steps")?,
                max_steps: sub.usize("max-steps")?,
                ..RunnerConfig::default()
            };
            let server = Server::start(sub.str("addr"), harness, runner_cfg)?;
            println!("imax-sd serve: listening on http://{}", server.addr());
            println!(
                "  POST /predictions            {{\"prompt\": \"...\", \"seed\": 7, \
                 \"webhook\": \"http://...\"}}"
            );
            println!("  GET  /predictions/<id>       poll state and metrics");
            println!("  POST /predictions/<id>/cancel abort remaining denoising steps");
            println!("  GET  /healthz                queue depth, inflight, wait estimate");
            println!("ctrl-c or SIGTERM drains in-flight requests, then exits.");
            let report = server.run_until_signalled();
            let served = report.outcomes.len();
            println!("\ndrained: {served} requests served, {} rejected", report.rejected);
            for state in [
                RunnerState::Succeeded,
                RunnerState::Cancelled,
                RunnerState::Expired,
                RunnerState::Failed,
            ] {
                let n = report.count(state);
                if n > 0 {
                    println!("  {:<10} {n}", state.name());
                }
            }
            if let Some(lat) = report.succeeded_latency_summary() {
                println!(
                    "  latency      p50 {:.3} s  p95 {:.3} s  p99 {:.3} s",
                    lat.median, lat.p95, lat.p99
                );
            }
            println!(
                "  peaks        queue depth {}  inflight {}",
                report.queue_depth_peak, report.inflight_peak
            );
            let wh = &report.webhook;
            if wh.enqueued > 0 {
                println!(
                    "  webhooks     {} delivered / {} enqueued ({} retries, {} dead-lettered)",
                    wh.delivered, wh.enqueued, wh.retries, wh.dead_lettered
                );
            }
        }
        other => unreachable!("unhandled subcommand {other}"),
    }
    Ok(())
}
