//! Cross-request op coalescing: the rendezvous at the heart of the
//! serving layer.
//!
//! Every request in a micro-batch runs the identical op sequence over a
//! shared read-only [`crate::sd::pipeline::Pipeline`], so the i-th
//! submission of every request names the *same weight tensor with the
//! same [`OpKind`]*. Each request thread drives a [`BatchMember`]
//! backend; model-weight ops rendezvous in the shared [`SharedBatch`]:
//! the last arrival (the leader) concatenates all members' activation
//! rows, performs **one** coordinator submission for the whole
//! micro-batch, splits the stacked output rows back, and wakes the
//! waiters. In sharded mode the merged submission itself row-tile-shards
//! across the lanes ([`crate::coordinator::Coordinator::submit_sharded`])
//! — batching amortizes per-op overheads *across requests* while
//! sharding splits the op *across lanes*; the two compose. When the
//! coordinator's lane worker pool is enabled (`host_threads > 1`), the
//! merged submission's shards run **concurrently** on their lanes'
//! worker threads, so one rendezvous occupies all lanes at once instead
//! of visiting them in sequence — outputs and counters stay
//! bit-identical either way (see the "Concurrency model" chapter in
//! `DESIGN.md`).
//!
//! Attention score/value ops declare [`OpKind::per_request_operands`]
//! (F32, per-request tensors, so there is nothing shared to batch):
//! they bypass the rendezvous and run immediately on the coordinator's
//! host path — which is also the paper's routing (F32 never offloads).
//!
//! Determinism: each output row of a GGML-style mat-mul is an
//! independent vec-dot of one weight row and one activation row, and
//! activation quantization is per-row — so batched (and sharded)
//! outputs are **bit-identical** to serial per-request execution
//! (regression-tested in `tests/serve_batching.rs`).
//!
//! Cancellation: a member whose request is cancelled (or whose deadline
//! expired) calls [`SharedBatch::leave`] between rendezvous points. The
//! batch's *active* member count drops, and if the leaver was the only
//! thing the others were waiting on, one of the already-arrived waiters
//! is woken to act as leader and complete the rendezvous with the
//! survivors' rows only. Because every output row is an independent
//! vec-dot, the survivors' outputs are bit-identical to a run that
//! never contained the cancelled member (proved in
//! `tests/serve_lifecycle.rs`).

use crate::coordinator::Coordinator;
use crate::ggml::tensor::Storage;
use crate::ggml::{DType, Tensor, WeightId};
use crate::sd::backend::{
    resolve_request, Completions, EngineStats, ExecBackend, OpDesc, OpHandle, OpKind, RequestId,
};
use crate::util::sync::{rank, Condvar, Mutex};
use std::sync::Arc;

/// Identity fingerprint of a weight tensor at a rendezvous point.
///
/// Model weights carry a stable [`WeightId`] content identity, which is
/// the preferred key: it survives address changes, composes with the
/// coordinator's residency-aware lane routing (the merged submission
/// lands on the lane caching that weight), and holds across pipelines
/// built from the same seed. Ad-hoc tensors without an id fall back to
/// storage address + shape (stable inside one shared pipeline).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum WeightFp {
    /// Stable content identity.
    Wid(WeightId),
    /// Address + shape fallback.
    Addr {
        addr: usize,
        rows: usize,
        cols: usize,
    },
}

fn fingerprint(op: &OpDesc<'_>) -> WeightFp {
    // OpDesc.wid is the weight identity everywhere (defaulted to the
    // tensor's own id by the constructors, overridable via `with_wid`).
    if let Some(id) = op.wid {
        return WeightFp::Wid(id);
    }
    let w = op.w;
    let addr = match &w.data {
        Storage::F32(v) => v.as_ptr() as usize,
        Storage::F16(v) => v.as_ptr() as usize,
        Storage::Q8_0(v) => v.as_ptr() as usize,
        Storage::Q3K(v) => v.as_ptr() as usize,
        Storage::Q8K(v) => v.as_ptr() as usize,
    };
    WeightFp::Addr { addr, rows: w.rows, cols: w.cols }
}

/// The full rendezvous key: lockstep members must agree on the weight
/// *and* on what the op is — a `(WeightId, OpKind)` pair. The kind
/// guard catches desynchronized members that happen to reuse a weight
/// under a different op (and gives mixed-kind traffic distinct
/// rendezvous points by construction).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct RendezvousKey {
    fp: WeightFp,
    kind: OpKind,
}

struct Pending {
    key: RendezvousKey,
    x: Tensor,
}

struct BatchState {
    inputs: Vec<Option<Pending>>,
    outputs: Vec<Option<Tensor>>,
    arrived: usize,
    /// Members still participating: starts at the batch size, drops on
    /// [`SharedBatch::leave`]. A rendezvous completes when `arrived`
    /// reaches `active`, so survivors never wait for a departed member.
    active: usize,
    generation: u64,
}

/// Rendezvous shared by the members of one micro-batch.
pub struct SharedBatch {
    size: usize,
    coordinator: Arc<Coordinator>,
    /// Route merged submissions through the sharded path (row-tiles
    /// split across all lanes) instead of whole-op lane affinity.
    sharded: bool,
    state: Mutex<BatchState>,
    cv: Condvar,
}

impl SharedBatch {
    /// New rendezvous for `size` lockstep members. `sharded` selects the
    /// merged submissions' lane routing: whole-op residency affinity
    /// (`false`) or single-op row-tile sharding across all lanes
    /// (`true`); outputs are bit-identical either way.
    pub fn new(size: usize, coordinator: Arc<Coordinator>, sharded: bool) -> Arc<SharedBatch> {
        assert!(size >= 1, "a batch needs at least one member");
        Arc::new(SharedBatch {
            size,
            coordinator,
            sharded,
            state: Mutex::ranked(
                rank::SERVE_BATCH,
                "serve.batch",
                BatchState {
                    inputs: (0..size).map(|_| None).collect(),
                    outputs: (0..size).map(|_| None).collect(),
                    arrived: 0,
                    active: size,
                    generation: 0,
                },
            ),
            cv: Condvar::new(),
        })
    }

    /// Members in the micro-batch.
    pub fn size(&self) -> usize {
        self.size
    }

    /// The coordinator executing the merged submissions.
    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.coordinator
    }

    /// One merged (or solo) submission on the coordinator, honoring the
    /// sharded routing mode.
    fn execute(&self, op: &OpDesc<'_>) -> Tensor {
        if self.sharded && self.coordinator.shardable(op) {
            self.coordinator.submit_sharded(op).out
        } else {
            self.coordinator.submit_op(op)
        }
    }

    /// Complete the rendezvous for the currently-arrived members:
    /// concatenate their activation rows in slot order, execute once,
    /// split the stacked output rows back, reset for the next round and
    /// bump the generation. Caller holds the state lock and passes its
    /// *own* op's weight/kind — all arrived members agreed on the key,
    /// so any member's weight reference serves (which is what lets a
    /// waiter complete the round after a departed member's
    /// [`SharedBatch::leave`]).
    fn complete_locked(&self, st: &mut BatchState, key: RendezvousKey, op: &OpDesc<'_>) {
        let (w, kind) = (op.w, op.kind);
        let (m, k) = (w.rows, w.cols);
        let members = st.arrived;
        let mut total_rows = 0;
        for p in st.inputs.iter().flatten() {
            assert_eq!(
                p.key, key,
                "lockstep members diverged at a rendezvous point (weight or op kind)"
            );
            total_rows += p.x.rows;
        }
        let mut data = Vec::with_capacity(total_rows * k);
        for p in st.inputs.iter().flatten() {
            data.extend_from_slice(p.x.as_f32());
        }
        let x_cat = Tensor::f32(total_rows, k, data);
        let mut merged = OpDesc::new(kind, w, &x_cat);
        merged.wid = op.wid; // members agreed on the key, so on the id
        let y = self.execute(&merged); // [total_rows, m]
        // Count the merge only when it coalesced >= 2 requests AND
        // actually reached a lane, so `batched_submissions` stays
        // comparable with `Coordinator::execute_coalesced` ("merged
        // *lane* submissions"); merged host ops (F16 linears, or convs
        // under a quantized-only policy) are not lane submissions, and a
        // sole survivor's solo round is not a merge.
        let on_lane = self.coordinator.policy.offloads_op(w, kind) && self.coordinator.lanes() > 0;
        if members >= 2 && on_lane {
            self.coordinator.metrics.record_batch(members as u64);
        }
        // Split the stacked output rows back per member, by slot (the
        // iteration must track slot indices: departed slots stay None).
        let mut row = 0;
        for (i, slot_input) in st.inputs.iter_mut().enumerate() {
            if let Some(p) = slot_input.take() {
                let n_i = p.x.rows;
                let slice = &y.as_f32()[row * m..(row + n_i) * m];
                st.outputs[i] = Some(Tensor::f32(n_i, m, slice.to_vec()));
                row += n_i;
            }
        }
        st.arrived = 0;
        st.generation = st.generation.wrapping_add(1);
    }

    /// Rendezvous: block until every *active* member has submitted its
    /// activations for the current op, execute once, return this
    /// member's `[n_slot, m]` output. Whichever member observes the
    /// batch complete acts as leader — normally the last arrival, but
    /// after a [`SharedBatch::leave`] it can be an already-waiting
    /// member woken to finish the round without the departed peer.
    fn rendezvous(&self, slot: usize, op: &OpDesc<'_>) -> Tensor {
        if self.size == 1 {
            // Nothing to merge: skip the rendezvous (and its activation
            // clone) entirely — this is the serial baseline path.
            return self.execute(op);
        }
        let key = RendezvousKey { fp: fingerprint(op), kind: op.kind };
        let mut st = self.state.lock();
        assert!(st.active > 0, "rendezvous on a batch with no active members");
        assert!(
            st.inputs[slot].is_none(),
            "member {slot} submitted twice before the rendezvous completed"
        );
        st.inputs[slot] = Some(Pending { key, x: op.x.clone() });
        st.arrived += 1;
        let gen = st.generation;
        loop {
            if st.generation != gen {
                // Another member led this round while we waited.
                return st.outputs[slot].take().expect("rendezvous output present");
            }
            if st.arrived == st.active {
                self.complete_locked(&mut st, key, op);
                let mine = st.outputs[slot].take().expect("leader output present");
                self.cv.notify_all();
                return mine;
            }
            st = self.cv.wait(st);
        }
    }

    /// Withdraw a member from the batch (cancelled or deadline-expired
    /// request). Any activation it already staged for the current round
    /// is dropped, the active count shrinks, and if the remaining
    /// members were only waiting on the leaver, one of them is woken to
    /// lead the round to completion — so survivors never deadlock and
    /// their outputs stay bit-identical (fewer concatenated rows, same
    /// independent per-row vec-dots). Idempotent use is the caller's
    /// responsibility: leave once per departing member.
    pub fn leave(&self, slot: usize) {
        let mut st = self.state.lock();
        assert!(st.active > 0, "leave on a batch with no active members");
        if st.inputs[slot].take().is_some() {
            st.arrived -= 1;
        }
        st.active -= 1;
        drop(st);
        // Wake waiters: one of them re-checks `arrived == active` and
        // completes the round the leaver would have unblocked.
        self.cv.notify_all();
    }

    /// Members still participating (size minus leavers).
    pub fn active(&self) -> usize {
        self.state.lock().active
    }
}

/// Per-request backend participating in a [`SharedBatch`].
pub struct BatchMember {
    shared: Arc<SharedBatch>,
    slot: usize,
    request: RequestId,
    stats: EngineStats,
    done: Completions,
}

impl BatchMember {
    /// Member backend for `slot` (0-based, unique within the batch).
    pub fn new(shared: Arc<SharedBatch>, slot: usize, request: RequestId) -> BatchMember {
        assert!(slot < shared.size(), "slot out of range");
        BatchMember {
            shared,
            slot,
            request,
            stats: EngineStats::default(),
            done: Completions::default(),
        }
    }

    /// Withdraw this member from the micro-batch after its request was
    /// cancelled or its deadline expired — see [`SharedBatch::leave`].
    /// Call exactly once, between rendezvous points (the pipeline's
    /// step-boundary cancel checks guarantee that placement).
    pub fn leave(&self) {
        self.shared.leave(self.slot);
    }
}

impl ExecBackend for BatchMember {
    fn submit(&mut self, op: OpDesc<'_>) -> OpHandle {
        let t0 = std::time::Instant::now();
        let macs = op.macs();
        let offloads = self.shared.coordinator().policy.offloads_op(op.w, op.kind);
        let out = if op.kind.per_request_operands() || op.w.dtype() == DType::F32 {
            // Per-request operand as "weight": nothing shared to batch;
            // run on the coordinator immediately (host path for F32).
            self.shared.coordinator().submit_op(&op)
        } else {
            self.shared.rendezvous(self.slot, &op)
        };
        if offloads {
            self.stats.offloaded_calls += 1;
            // One submission attributable to this request's op; the
            // merge/shard decomposition of the rendezvous is visible in
            // the shared CoordinatorMetrics, not per-member stats.
            self.stats.lane_submissions += 1;
        }
        let request = resolve_request(&op, self.request);
        self.stats.record(request, op.w.dtype(), macs, t0.elapsed().as_secs_f64());
        self.done.complete(out)
    }

    fn sync(&mut self, h: OpHandle) -> Tensor {
        self.done.take(h)
    }

    fn stats(&self) -> &EngineStats {
        &self.stats
    }

    fn begin_request(&mut self, id: RequestId) {
        self.request = id;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::OffloadPolicy;
    use crate::ggml;
    use crate::imax::ImaxConfig;
    use crate::util::rng::Xoshiro256pp;

    fn rnd(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let mut v = vec![0.0f32; rows * cols];
        r.fill_normal(&mut v, 0.5);
        Tensor::f32(rows, cols, v)
    }

    fn coordinator(lanes: usize) -> Arc<Coordinator> {
        Arc::new(Coordinator::new(ImaxConfig::fpga(1), lanes, 2, OffloadPolicy::QuantizedOnly))
    }

    #[test]
    fn single_member_batch_executes_inline() {
        let shared = SharedBatch::new(1, coordinator(1), false);
        let w = rnd(4, 64, 1).quantize(DType::Q8_0);
        let x = rnd(3, 64, 2);
        let mut eng = BatchMember::new(shared, 0, RequestId(1));
        let got = eng.submit_now(OpDesc::linear(&w, &x));
        let want = ggml::mul_mat(&w, &x, 1);
        for (a, b) in got.as_f32().iter().zip(want.as_f32()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(eng.stats().offloaded_calls, 1);
        assert_eq!(eng.stats().macs_by_request[&1], 4 * 64 * 3);
    }

    #[test]
    fn rendezvous_merges_and_splits_per_member() {
        let coord = coordinator(2);
        let w = rnd(6, 128, 3).quantize(DType::Q8_0);
        let xs: Vec<Tensor> = (0..3).map(|i| rnd(2 + i, 128, 10 + i as u64)).collect();
        let shared = SharedBatch::new(3, Arc::clone(&coord), false);
        let outs: Vec<Tensor> = std::thread::scope(|scope| {
            let handles: Vec<_> = xs
                .iter()
                .enumerate()
                .map(|(slot, x)| {
                    let shared = Arc::clone(&shared);
                    let w = &w;
                    scope.spawn(move || {
                        let mut eng = BatchMember::new(shared, slot, RequestId(slot as u64));
                        eng.submit_now(OpDesc::linear(w, x))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (x, got) in xs.iter().zip(&outs) {
            let want = ggml::mul_mat(&w, x, 1);
            assert_eq!((got.rows, got.cols), (x.rows, 6));
            for (a, b) in got.as_f32().iter().zip(want.as_f32()) {
                assert_eq!(a.to_bits(), b.to_bits(), "batched row == serial row");
            }
        }
        let ord = std::sync::atomic::Ordering::Relaxed;
        assert_eq!(coord.metrics.offloaded_jobs.load(ord), 1, "one merged submission");
        assert_eq!(coord.metrics.coalesced_jobs.load(ord), 3);
    }

    #[test]
    fn sharded_rendezvous_merges_then_shards_bit_identically() {
        let w = rnd(8, 128, 30).quantize(DType::Q8_0).with_wid(WeightId(44));
        let xs: Vec<Tensor> = (0..2).map(|i| rnd(2, 128, 60 + i as u64)).collect();
        let run = |sharded: bool| {
            let coord = coordinator(2);
            // The assertions below want the 8-row merged op split across
            // both lanes; disable the cost-model shard threshold.
            coord.set_min_shard_rows(1);
            let shared = SharedBatch::new(2, Arc::clone(&coord), sharded);
            let outs: Vec<Tensor> = std::thread::scope(|scope| {
                let handles: Vec<_> = xs
                    .iter()
                    .enumerate()
                    .map(|(slot, x)| {
                        let shared = Arc::clone(&shared);
                        let w = &w;
                        scope.spawn(move || {
                            let mut eng = BatchMember::new(shared, slot, RequestId(slot as u64));
                            eng.submit_now(OpDesc::linear(w, x))
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            (outs, coord)
        };
        let (plain, _) = run(false);
        let (sharded, coord) = run(true);
        for (a, b) in plain.iter().zip(&sharded) {
            for (p, q) in a.as_f32().iter().zip(b.as_f32()) {
                assert_eq!(p.to_bits(), q.to_bits(), "sharded rendezvous stays bit-exact");
            }
        }
        let ord = std::sync::atomic::Ordering::Relaxed;
        assert_eq!(coord.metrics.sharded_ops.load(ord), 1, "one merged sharded submission");
        assert_eq!(coord.metrics.shard_submissions.load(ord), 2, "split across both lanes");
        assert_eq!(coord.metrics.batched_submissions.load(ord), 1);
    }

    #[test]
    fn per_request_ops_bypass_the_rendezvous() {
        // With batch size 2 but only ONE member issuing an attention op,
        // a rendezvous would deadlock — bypass means it must complete.
        let shared = SharedBatch::new(2, coordinator(1), false);
        let w = rnd(4, 32, 5); // F32 per-head keys (attention-score pattern)
        let x = rnd(3, 32, 6);
        let mut eng = BatchMember::new(shared, 0, RequestId(0));
        let got = eng.submit_now(OpDesc::attn_scores(&w, &x));
        let want = ggml::mul_mat(&w, &x, 1);
        for (a, b) in got.as_f32().iter().zip(want.as_f32()) {
            assert!((a - b).abs() < 1e-5);
        }
        assert_eq!(eng.stats().offloaded_calls, 0);
    }

    #[test]
    fn fingerprint_prefers_weight_identity_over_address() {
        let x = rnd(2, 64, 10);
        let w = rnd(4, 64, 11).quantize(DType::Q8_0).with_wid(WeightId(5));
        let clone = w.clone(); // different storage address, same identity
        assert_eq!(
            fingerprint(&OpDesc::linear(&w, &x)),
            fingerprint(&OpDesc::linear(&clone, &x)),
            "WeightId keys the rendezvous"
        );
        let anon = rnd(4, 64, 11).quantize(DType::Q8_0);
        let anon2 = anon.clone();
        assert_ne!(
            fingerprint(&OpDesc::linear(&anon, &x)),
            fingerprint(&OpDesc::linear(&anon2, &x)),
            "anonymous tensors fall back to address identity"
        );
        assert_ne!(
            fingerprint(&OpDesc::linear(&w, &x)),
            fingerprint(&OpDesc::linear(&anon, &x))
        );
        assert_eq!(
            fingerprint(&OpDesc::linear(&anon, &x).with_wid(WeightId(5))),
            fingerprint(&OpDesc::linear(&w, &x)),
            "an explicit wid override keys the rendezvous too"
        );
    }

    #[test]
    fn rendezvous_key_includes_op_kind() {
        let x = rnd(2, 64, 13);
        let w = rnd(4, 64, 12).quantize(DType::Q8_0).with_wid(WeightId(6));
        let fp = fingerprint(&OpDesc::linear(&w, &x));
        let a = RendezvousKey { fp, kind: OpKind::Linear };
        let b = RendezvousKey { fp, kind: OpKind::TimeEmbed };
        assert_ne!(a, b, "same weight under different kinds must not rendezvous");
    }

    #[test]
    fn leave_mid_sequence_lets_survivors_complete_bit_identically() {
        // Member 2 submits one op, then leaves; members 0 and 1 run
        // three ops. Survivor outputs must be bit-identical to a batch
        // that never contained member 2.
        let w = rnd(6, 128, 70).quantize(DType::Q8_0).with_wid(WeightId(9));
        let xs: Vec<Vec<Tensor>> = (0..3)
            .map(|slot| (0..3).map(|round| rnd(2, 128, 200 + 10 * slot + round)).collect())
            .collect();
        let run = |with_leaver: bool| -> Vec<Vec<Tensor>> {
            let members = if with_leaver { 3 } else { 2 };
            let shared = SharedBatch::new(members, coordinator(2), false);
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..2usize)
                    .map(|slot| {
                        let shared = Arc::clone(&shared);
                        let (w, xs) = (&w, &xs);
                        scope.spawn(move || {
                            let mut eng = BatchMember::new(shared, slot, RequestId(slot as u64));
                            xs[slot]
                                .iter()
                                .map(|x| eng.submit_now(OpDesc::linear(w, x)))
                                .collect::<Vec<_>>()
                        })
                    })
                    .collect();
                if with_leaver {
                    let shared = Arc::clone(&shared);
                    let (w, xs) = (&w, &xs);
                    scope
                        .spawn(move || {
                            let mut eng = BatchMember::new(shared, 2, RequestId(2));
                            let _ = eng.submit_now(OpDesc::linear(w, &xs[2][0]));
                            // Simulated cancel between rendezvous points.
                            eng.leave();
                        })
                        .join()
                        .unwrap();
                }
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            })
        };
        let with_leaver = run(true);
        let without = run(false);
        for (a_rounds, b_rounds) in with_leaver.iter().zip(&without) {
            for (a, b) in a_rounds.iter().zip(b_rounds) {
                for (p, q) in a.as_f32().iter().zip(b.as_f32()) {
                    assert_eq!(p.to_bits(), q.to_bits(), "survivors unaffected by the leaver");
                }
            }
        }
        // And against the serial ground truth.
        for (slot, rounds) in without.iter().enumerate() {
            for (round, got) in rounds.iter().enumerate() {
                let want = ggml::mul_mat(&w, &xs[slot][round], 1);
                for (p, q) in got.as_f32().iter().zip(want.as_f32()) {
                    assert_eq!(p.to_bits(), q.to_bits(), "round {round} == serial");
                }
            }
        }
    }

    #[test]
    fn leave_wakes_waiters_blocked_on_the_leaver() {
        // Both survivors arrive FIRST and block; the leaver departs
        // without ever submitting — a waiter must take over leadership.
        let coord = coordinator(1);
        let w = rnd(4, 64, 80).quantize(DType::Q8_0);
        let shared = SharedBatch::new(3, Arc::clone(&coord), false);
        let outs: Vec<Tensor> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..2usize)
                .map(|slot| {
                    let shared = Arc::clone(&shared);
                    let w = &w;
                    scope.spawn(move || {
                        let mut eng = BatchMember::new(shared, slot, RequestId(slot as u64));
                        eng.submit_now(OpDesc::linear(w, &rnd(2, 64, 90 + slot as u64)))
                    })
                })
                .collect();
            // Give the survivors time to arrive and block on the
            // rendezvous before the third member leaves.
            std::thread::sleep(std::time::Duration::from_millis(20));
            shared.leave(2);
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(shared.active(), 2);
        for (slot, got) in outs.iter().enumerate() {
            let want = ggml::mul_mat(&w, &rnd(2, 64, 90 + slot as u64), 1);
            for (p, q) in got.as_f32().iter().zip(want.as_f32()) {
                assert_eq!(p.to_bits(), q.to_bits());
            }
        }
    }

    #[test]
    fn sole_survivor_continues_solo_without_batch_counters() {
        let coord = coordinator(1);
        let shared = SharedBatch::new(2, Arc::clone(&coord), false);
        let w = rnd(4, 64, 95).quantize(DType::Q8_0);
        let x = rnd(3, 64, 96);
        shared.leave(1);
        let mut eng = BatchMember::new(Arc::clone(&shared), 0, RequestId(0));
        let got = eng.submit_now(OpDesc::linear(&w, &x));
        let want = ggml::mul_mat(&w, &x, 1);
        for (p, q) in got.as_f32().iter().zip(want.as_f32()) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
        let ord = std::sync::atomic::Ordering::Relaxed;
        assert_eq!(
            coord.metrics.batched_submissions.load(ord),
            0,
            "a solo round is not a merged submission"
        );
    }

    #[test]
    fn repeated_rendezvous_across_generations() {
        let coord = coordinator(1);
        let w1 = rnd(4, 64, 7).quantize(DType::Q8_0);
        let w2 = rnd(8, 64, 8).quantize(DType::F16);
        let shared = SharedBatch::new(2, Arc::clone(&coord), false);
        std::thread::scope(|scope| {
            for slot in 0..2usize {
                let shared = Arc::clone(&shared);
                let (w1, w2) = (&w1, &w2);
                scope.spawn(move || {
                    let mut eng = BatchMember::new(shared, slot, RequestId(slot as u64));
                    for round in 0..4u64 {
                        let x = rnd(2, 64, 100 + 10 * round + slot as u64);
                        let a = eng.submit_now(OpDesc::linear(w1, &x));
                        let b = eng.submit_now(OpDesc::linear(w2, &x));
                        assert_eq!(a.rows, 2);
                        assert_eq!(b.cols, 8);
                    }
                });
            }
        });
        let ord = std::sync::atomic::Ordering::Relaxed;
        // 4 rounds × 1 quantized rendezvous each -> 4 merged lane
        // submissions; the F16 rendezvous runs merged on the host path
        // and therefore does NOT count as a batched lane submission.
        assert_eq!(coord.metrics.offloaded_jobs.load(ord), 4);
        assert_eq!(coord.metrics.batched_submissions.load(ord), 4);
        assert_eq!(coord.metrics.coalesced_jobs.load(ord), 8);
    }
}
