//! Serving metrics: the request state machine, per-request latency, and
//! aggregate throughput/tail-latency for one serving run.

use crate::sd::graph::RequestId;
use crate::util::cancel::CancelCause;
use crate::util::stats::Summary;

/// Lifecycle state of a served request (the cog-style runner states).
///
/// ```text
/// Queued ──▶ Running ──▶ Succeeded | Failed | Cancelled | Expired
///    │
///    └──(cancel while queued / deadline passes in queue)──▶ Cancelled | Expired
/// ```
///
/// `Rejected` never enters the queue at all — it is the backpressure
/// outcome (HTTP 429) counted in [`ServeReport::rejected`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RunnerState {
    /// Admitted, waiting for a worker slot.
    Queued,
    /// A worker is generating the image.
    Running,
    /// Finished; the image and metrics are available.
    Succeeded,
    /// The worker panicked or the pipeline errored.
    Failed,
    /// A cancel request fired the token before completion.
    Cancelled,
    /// The per-request deadline passed before completion.
    Expired,
    /// Refused at admission (queue full past the SLO) — never ran.
    Rejected,
}

impl RunnerState {
    /// True for states no transition leaves.
    pub fn terminal(self) -> bool {
        !matches!(self, RunnerState::Queued | RunnerState::Running)
    }

    /// Wire name (the HTTP status field value).
    pub fn name(self) -> &'static str {
        match self {
            RunnerState::Queued => "queued",
            RunnerState::Running => "running",
            RunnerState::Succeeded => "succeeded",
            RunnerState::Failed => "failed",
            RunnerState::Cancelled => "cancelled",
            RunnerState::Expired => "expired",
            RunnerState::Rejected => "rejected",
        }
    }

    /// The terminal state an abort cause maps to.
    pub fn from_cause(cause: CancelCause) -> RunnerState {
        match cause {
            CancelCause::Cancelled => RunnerState::Cancelled,
            CancelCause::DeadlineExpired => RunnerState::Expired,
        }
    }
}

/// Outcome of one served request.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    /// Request identity.
    pub id: RequestId,
    /// The prompt served.
    pub prompt: String,
    /// Terminal state ([`RunnerState::Succeeded`] for a full run).
    pub state: RunnerState,
    /// Queue-to-image latency in seconds (includes time spent waiting
    /// for micro-batch peers at rendezvous points).
    pub latency_seconds: f64,
    /// Seconds spent waiting in the queue before a worker picked the
    /// request up.
    pub queue_seconds: f64,
    /// Denoising steps completed (equals the requested steps on
    /// success; smaller when cancel/deadline aborted mid-denoise).
    pub steps_completed: usize,
    /// Mat-mul ops executed for this request.
    pub matmul_calls: u64,
    /// MACs attributed to this request.
    pub macs: u64,
    /// CRC-32 of the RGB8 image bytes (determinism fingerprint; 0 for
    /// aborted requests, which produce no image).
    pub image_crc32: u32,
}

/// Webhook delivery counters for one serving run (produced by
/// `server::webhook::WebhookSender`, zeroed when no prediction carried
/// a webhook). The invariant the load generator asserts:
/// `delivered + dead_lettered == enqueued` after a flushed shutdown.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WebhookStats {
    /// Terminal transitions accepted into the delivery queue.
    pub enqueued: u64,
    /// HTTP POST attempts made (per-attempt, so `>= delivered`).
    pub attempts: u64,
    /// Deliveries acknowledged with a 2xx.
    pub delivered: u64,
    /// Failed attempts that were rescheduled with backoff.
    pub retries: u64,
    /// Deliveries abandoned: retry budget exhausted, queue overflow, or
    /// drain deadline hit at shutdown.
    pub dead_lettered: u64,
    /// Subset of `dead_lettered` dropped because the bounded queue was
    /// full at enqueue time.
    pub overflowed: u64,
    /// Terminal-to-acknowledged latency of each successful delivery, in
    /// seconds (includes every backoff wait before the 2xx).
    pub latency_seconds: Vec<f64>,
}

impl WebhookStats {
    /// Delivery-latency distribution; `None` when nothing delivered.
    pub fn latency_summary(&self) -> Option<Summary> {
        if self.latency_seconds.is_empty() {
            None
        } else {
            Some(Summary::of(&self.latency_seconds))
        }
    }
}

/// Aggregate report for one serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Per-request outcomes, sorted by request id.
    pub outcomes: Vec<RequestOutcome>,
    /// Wall-clock seconds for the whole run.
    pub wall_seconds: f64,
    /// Total MACs across requests (engine-side accounting).
    pub total_macs: u64,
    /// MACs offloaded to IMAX lanes.
    pub offloaded_macs: u64,
    /// Simulated IMAX cycles across lanes.
    pub imax_cycles: u64,
    /// Lane submissions. A merged rendezvous submission counts once
    /// under affinity routing; under sharded routing it counts once
    /// **per shard** (the op decomposes into per-lane submissions —
    /// compare against `CoordinatorMetrics::shard_submissions`).
    pub lane_submissions: u64,
    /// Merged lane submissions covering more than one request.
    pub batched_submissions: u64,
    /// Jobs folded into merged submissions.
    pub coalesced_jobs: u64,
    /// Weight LOAD bytes skipped because the weight was LMM-resident.
    pub cache_hit_bytes: u64,
    /// Weight bytes DMA'd on residency-cache misses.
    pub cache_miss_bytes: u64,
    /// Requests refused at admission (backpressure; they have no
    /// outcome entry).
    pub rejected: u64,
    /// Peak queue depth observed during the run.
    pub queue_depth_peak: usize,
    /// Peak number of requests running concurrently in workers.
    pub inflight_peak: usize,
    /// Webhook delivery counters (all-zero when no prediction carried a
    /// webhook, e.g. offline `ServeHarness::serve` runs).
    pub webhook: WebhookStats,
}

impl ServeReport {
    /// Requests with an outcome (admitted; excludes rejected).
    pub fn requests(&self) -> usize {
        self.outcomes.len()
    }

    /// How many outcomes ended in `state`.
    pub fn count(&self, state: RunnerState) -> usize {
        self.outcomes.iter().filter(|o| o.state == state).count()
    }

    /// Aggregate MAC throughput over the run (MAC/s of wall time).
    pub fn macs_per_second(&self) -> f64 {
        if self.wall_seconds == 0.0 {
            0.0
        } else {
            self.total_macs as f64 / self.wall_seconds
        }
    }

    /// Requests per second of wall time.
    pub fn requests_per_second(&self) -> f64 {
        if self.wall_seconds == 0.0 {
            0.0
        } else {
            self.requests() as f64 / self.wall_seconds
        }
    }

    /// Simulated IMAX cycles per offloaded MAC — the lane-utilization
    /// figure (lower = better amortization of DMA/CONF overhead).
    pub fn cycles_per_offloaded_mac(&self) -> f64 {
        if self.offloaded_macs == 0 {
            0.0
        } else {
            self.imax_cycles as f64 / self.offloaded_macs as f64
        }
    }

    /// Latency distribution across all admitted requests (empty runs
    /// panic, like [`Summary::of`]).
    pub fn latency_summary(&self) -> Summary {
        let samples: Vec<f64> = self.outcomes.iter().map(|o| o.latency_seconds).collect();
        Summary::of(&samples)
    }

    /// Latency distribution over **succeeded** requests only — the
    /// SLO-facing figure (cancelled/expired latencies would deflate the
    /// tail). `None` when nothing succeeded.
    pub fn succeeded_latency_summary(&self) -> Option<Summary> {
        let samples: Vec<f64> = self
            .outcomes
            .iter()
            .filter(|o| o.state == RunnerState::Succeeded)
            .map(|o| o.latency_seconds)
            .collect();
        if samples.is_empty() {
            None
        } else {
            Some(Summary::of(&samples))
        }
    }

    /// Fraction of arrivals refused at admission, in `[0, 1]`.
    pub fn rejection_rate(&self) -> f64 {
        let offered = self.outcomes.len() as u64 + self.rejected;
        if offered == 0 {
            0.0
        } else {
            self.rejected as f64 / offered as f64
        }
    }

    /// Fraction of weight LOAD bytes the residency cache elided, in
    /// `[0, 1]` (0 when nothing was looked up).
    pub fn cache_byte_hit_rate(&self) -> f64 {
        let total = self.cache_hit_bytes + self.cache_miss_bytes;
        if total == 0 {
            0.0
        } else {
            self.cache_hit_bytes as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(id: u64, latency: f64, macs: u64) -> RequestOutcome {
        RequestOutcome {
            id: RequestId(id),
            prompt: format!("p{id}"),
            state: RunnerState::Succeeded,
            latency_seconds: latency,
            queue_seconds: 0.1,
            steps_completed: 1,
            matmul_calls: 10,
            macs,
            image_crc32: 0,
        }
    }

    #[test]
    fn aggregates_compute() {
        let r = ServeReport {
            outcomes: vec![outcome(1, 0.5, 1000), outcome(2, 1.5, 3000)],
            wall_seconds: 2.0,
            total_macs: 4000,
            offloaded_macs: 800,
            imax_cycles: 400,
            lane_submissions: 3,
            batched_submissions: 1,
            coalesced_jobs: 2,
            cache_hit_bytes: 300,
            cache_miss_bytes: 100,
            rejected: 0,
            queue_depth_peak: 2,
            inflight_peak: 2,
            webhook: WebhookStats::default(),
        };
        assert_eq!(r.requests(), 2);
        assert_eq!(r.count(RunnerState::Succeeded), 2);
        assert!((r.macs_per_second() - 2000.0).abs() < 1e-9);
        assert!((r.requests_per_second() - 1.0).abs() < 1e-9);
        assert!((r.cycles_per_offloaded_mac() - 0.5).abs() < 1e-9);
        assert!((r.cache_byte_hit_rate() - 0.75).abs() < 1e-9);
        assert_eq!(r.rejection_rate(), 0.0);
        let lat = r.latency_summary();
        assert!((lat.mean - 1.0).abs() < 1e-9);
        assert_eq!(lat.n, 2);
    }

    #[test]
    fn zero_denominators_are_safe() {
        let r = ServeReport {
            outcomes: Vec::new(),
            wall_seconds: 0.0,
            total_macs: 0,
            offloaded_macs: 0,
            imax_cycles: 0,
            lane_submissions: 0,
            batched_submissions: 0,
            coalesced_jobs: 0,
            cache_hit_bytes: 0,
            cache_miss_bytes: 0,
            rejected: 0,
            queue_depth_peak: 0,
            inflight_peak: 0,
            webhook: WebhookStats::default(),
        };
        assert_eq!(r.macs_per_second(), 0.0);
        assert_eq!(r.requests_per_second(), 0.0);
        assert_eq!(r.cycles_per_offloaded_mac(), 0.0);
        assert_eq!(r.cache_byte_hit_rate(), 0.0);
        assert_eq!(r.rejection_rate(), 0.0);
        assert!(r.succeeded_latency_summary().is_none());
    }

    #[test]
    fn per_state_counters_and_slo_facing_tail() {
        let mut cancelled = outcome(3, 0.05, 100);
        cancelled.state = RunnerState::Cancelled;
        cancelled.steps_completed = 2;
        cancelled.image_crc32 = 0;
        let mut expired = outcome(4, 0.01, 0);
        expired.state = RunnerState::Expired;
        expired.steps_completed = 0;
        let r = ServeReport {
            outcomes: vec![outcome(1, 1.0, 1000), outcome(2, 3.0, 1000), cancelled, expired],
            wall_seconds: 4.0,
            total_macs: 2100,
            offloaded_macs: 0,
            imax_cycles: 0,
            lane_submissions: 0,
            batched_submissions: 0,
            coalesced_jobs: 0,
            cache_hit_bytes: 0,
            cache_miss_bytes: 0,
            rejected: 4,
            queue_depth_peak: 5,
            inflight_peak: 2,
            webhook: WebhookStats::default(),
        };
        assert_eq!(r.count(RunnerState::Succeeded), 2);
        assert_eq!(r.count(RunnerState::Cancelled), 1);
        assert_eq!(r.count(RunnerState::Expired), 1);
        assert_eq!(r.count(RunnerState::Failed), 0);
        // 4 admitted + 4 rejected offered => 50% shed.
        assert!((r.rejection_rate() - 0.5).abs() < 1e-12);
        // The SLO tail ignores the fast abort latencies.
        let ok = r.succeeded_latency_summary().expect("two succeeded");
        assert_eq!(ok.n, 2);
        assert!((ok.mean - 2.0).abs() < 1e-12);
        // The all-outcomes summary includes them.
        assert_eq!(r.latency_summary().n, 4);
    }

    #[test]
    fn webhook_stats_latency_summary() {
        let mut w = WebhookStats::default();
        assert!(w.latency_summary().is_none(), "nothing delivered yet");
        w.latency_seconds = vec![0.010, 0.030, 0.020];
        let s = w.latency_summary().expect("three samples");
        assert_eq!(s.n, 3);
        assert!((s.median - 0.020).abs() < 1e-12);
        // A NaN sample (clock anomaly) degrades the summary, never
        // panics the report (regression pinned in util::stats too).
        w.latency_seconds.push(f64::NAN);
        let s = w.latency_summary().expect("still three usable samples");
        assert_eq!(s.n, 3);
        assert_eq!(s.nan, 1);
    }

    #[test]
    fn state_machine_names_and_terminality() {
        for (s, name, terminal) in [
            (RunnerState::Queued, "queued", false),
            (RunnerState::Running, "running", false),
            (RunnerState::Succeeded, "succeeded", true),
            (RunnerState::Failed, "failed", true),
            (RunnerState::Cancelled, "cancelled", true),
            (RunnerState::Expired, "expired", true),
            (RunnerState::Rejected, "rejected", true),
        ] {
            assert_eq!(s.name(), name);
            assert_eq!(s.terminal(), terminal);
        }
        assert_eq!(RunnerState::from_cause(CancelCause::Cancelled), RunnerState::Cancelled);
        assert_eq!(RunnerState::from_cause(CancelCause::DeadlineExpired), RunnerState::Expired);
    }
}
