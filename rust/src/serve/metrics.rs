//! Serving metrics: per-request latency plus aggregate throughput.

use crate::sd::graph::RequestId;
use crate::util::stats::Summary;

/// Outcome of one served request.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    /// Request identity.
    pub id: RequestId,
    /// The prompt served.
    pub prompt: String,
    /// Queue-to-image latency in seconds (includes time spent waiting
    /// for micro-batch peers at rendezvous points).
    pub latency_seconds: f64,
    /// Mat-mul ops executed for this request.
    pub matmul_calls: u64,
    /// MACs attributed to this request.
    pub macs: u64,
    /// CRC-32 of the RGB8 image bytes (determinism fingerprint).
    pub image_crc32: u32,
}

/// Aggregate report for one serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Per-request outcomes, sorted by request id.
    pub outcomes: Vec<RequestOutcome>,
    /// Wall-clock seconds for the whole run.
    pub wall_seconds: f64,
    /// Total MACs across requests (engine-side accounting).
    pub total_macs: u64,
    /// MACs offloaded to IMAX lanes.
    pub offloaded_macs: u64,
    /// Simulated IMAX cycles across lanes.
    pub imax_cycles: u64,
    /// Lane submissions. A merged rendezvous submission counts once
    /// under affinity routing; under sharded routing it counts once
    /// **per shard** (the op decomposes into per-lane submissions —
    /// compare against `CoordinatorMetrics::shard_submissions`).
    pub lane_submissions: u64,
    /// Merged lane submissions covering more than one request.
    pub batched_submissions: u64,
    /// Jobs folded into merged submissions.
    pub coalesced_jobs: u64,
    /// Weight LOAD bytes skipped because the weight was LMM-resident.
    pub cache_hit_bytes: u64,
    /// Weight bytes DMA'd on residency-cache misses.
    pub cache_miss_bytes: u64,
}

impl ServeReport {
    /// Requests served.
    pub fn requests(&self) -> usize {
        self.outcomes.len()
    }

    /// Aggregate MAC throughput over the run (MAC/s of wall time).
    pub fn macs_per_second(&self) -> f64 {
        if self.wall_seconds == 0.0 {
            0.0
        } else {
            self.total_macs as f64 / self.wall_seconds
        }
    }

    /// Requests per second of wall time.
    pub fn requests_per_second(&self) -> f64 {
        if self.wall_seconds == 0.0 {
            0.0
        } else {
            self.requests() as f64 / self.wall_seconds
        }
    }

    /// Simulated IMAX cycles per offloaded MAC — the lane-utilization
    /// figure (lower = better amortization of DMA/CONF overhead).
    pub fn cycles_per_offloaded_mac(&self) -> f64 {
        if self.offloaded_macs == 0 {
            0.0
        } else {
            self.imax_cycles as f64 / self.offloaded_macs as f64
        }
    }

    /// Latency distribution across requests (empty runs panic, like
    /// [`Summary::of`]).
    pub fn latency_summary(&self) -> Summary {
        let samples: Vec<f64> = self.outcomes.iter().map(|o| o.latency_seconds).collect();
        Summary::of(&samples)
    }

    /// Fraction of weight LOAD bytes the residency cache elided, in
    /// `[0, 1]` (0 when nothing was looked up).
    pub fn cache_byte_hit_rate(&self) -> f64 {
        let total = self.cache_hit_bytes + self.cache_miss_bytes;
        if total == 0 {
            0.0
        } else {
            self.cache_hit_bytes as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(id: u64, latency: f64, macs: u64) -> RequestOutcome {
        RequestOutcome {
            id: RequestId(id),
            prompt: format!("p{id}"),
            latency_seconds: latency,
            matmul_calls: 10,
            macs,
            image_crc32: 0,
        }
    }

    #[test]
    fn aggregates_compute() {
        let r = ServeReport {
            outcomes: vec![outcome(1, 0.5, 1000), outcome(2, 1.5, 3000)],
            wall_seconds: 2.0,
            total_macs: 4000,
            offloaded_macs: 800,
            imax_cycles: 400,
            lane_submissions: 3,
            batched_submissions: 1,
            coalesced_jobs: 2,
            cache_hit_bytes: 300,
            cache_miss_bytes: 100,
        };
        assert_eq!(r.requests(), 2);
        assert!((r.macs_per_second() - 2000.0).abs() < 1e-9);
        assert!((r.requests_per_second() - 1.0).abs() < 1e-9);
        assert!((r.cycles_per_offloaded_mac() - 0.5).abs() < 1e-9);
        assert!((r.cache_byte_hit_rate() - 0.75).abs() < 1e-9);
        let lat = r.latency_summary();
        assert!((lat.mean - 1.0).abs() < 1e-9);
        assert_eq!(lat.n, 2);
    }

    #[test]
    fn zero_denominators_are_safe() {
        let r = ServeReport {
            outcomes: Vec::new(),
            wall_seconds: 0.0,
            total_macs: 0,
            offloaded_macs: 0,
            imax_cycles: 0,
            lane_submissions: 0,
            batched_submissions: 0,
            coalesced_jobs: 0,
            cache_hit_bytes: 0,
            cache_miss_bytes: 0,
        };
        assert_eq!(r.macs_per_second(), 0.0);
        assert_eq!(r.requests_per_second(), 0.0);
        assert_eq!(r.cycles_per_offloaded_mac(), 0.0);
        assert_eq!(r.cache_byte_hit_rate(), 0.0);
    }
}
