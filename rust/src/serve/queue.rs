//! Concurrent request queue feeding the serving workers.
//!
//! A bounded-complexity MPMC queue on `Mutex` + `Condvar` (the vendored
//! crate set has no channel/async runtime): producers [`RequestQueue::push`]
//! requests, workers block in [`RequestQueue::pop_batch`] until work (or
//! close), then drain up to a micro-batch worth in FIFO order.

use crate::sd::graph::RequestId;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// One image-generation request.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    /// Request identity (threaded through engines and reports).
    pub id: RequestId,
    /// Text prompt.
    pub prompt: String,
    /// Latent seed.
    pub seed: u64,
}

struct QueueState {
    pending: VecDeque<ServeRequest>,
    closed: bool,
}

/// FIFO request queue with close semantics.
pub struct RequestQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl Default for RequestQueue {
    fn default() -> Self {
        RequestQueue::new()
    }
}

impl RequestQueue {
    /// New, open, empty queue.
    pub fn new() -> RequestQueue {
        RequestQueue {
            state: Mutex::new(QueueState { pending: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request. Panics if the queue was closed.
    pub fn push(&self, req: ServeRequest) {
        let mut st = self.state.lock().unwrap();
        assert!(!st.closed, "push after close");
        st.pending.push_back(req);
        drop(st);
        self.cv.notify_one();
    }

    /// Close the queue: workers drain what is left, then see empty pops.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.cv.notify_all();
    }

    /// Requests currently waiting.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().pending.len()
    }

    /// True when no request is waiting.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Block until at least one request is available (or the queue is
    /// closed and drained), then take up to `max` requests in FIFO
    /// order. An empty vec means "closed and drained" — the worker's
    /// stop signal.
    pub fn pop_batch(&self, max: usize) -> Vec<ServeRequest> {
        assert!(max >= 1, "micro-batch size must be >= 1");
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.pending.is_empty() {
                let take = st.pending.len().min(max);
                return st.pending.drain(..take).collect();
            }
            if st.closed {
                return Vec::new();
            }
            st = self.cv.wait(st).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn req(id: u64) -> ServeRequest {
        ServeRequest { id: RequestId(id), prompt: format!("p{id}"), seed: id }
    }

    #[test]
    fn fifo_order_and_batch_limit() {
        let q = RequestQueue::new();
        for i in 0..5 {
            q.push(req(i));
        }
        q.close();
        let a = q.pop_batch(3);
        assert_eq!(a.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![0, 1, 2]);
        let b = q.pop_batch(3);
        assert_eq!(b.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![3, 4]);
        assert!(q.pop_batch(3).is_empty(), "closed + drained = stop signal");
    }

    #[test]
    fn workers_drain_everything_exactly_once() {
        let q = RequestQueue::new();
        for i in 0..40 {
            q.push(req(i));
        }
        q.close();
        let served = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| loop {
                    let batch = q.pop_batch(4);
                    if batch.is_empty() {
                        break;
                    }
                    served.fetch_add(batch.len(), Ordering::Relaxed);
                });
            }
        });
        assert_eq!(served.load(Ordering::Relaxed), 40);
        assert!(q.is_empty());
    }

    #[test]
    fn pop_blocks_until_push() {
        let q = RequestQueue::new();
        std::thread::scope(|scope| {
            let h = scope.spawn(|| q.pop_batch(2));
            std::thread::sleep(std::time::Duration::from_millis(10));
            q.push(req(9));
            let got = h.join().unwrap();
            assert_eq!(got.len(), 1);
            assert_eq!(got[0].id.0, 9);
        });
        q.close();
    }

    #[test]
    #[should_panic(expected = "push after close")]
    fn push_after_close_rejected() {
        let q = RequestQueue::new();
        q.close();
        q.push(req(1));
    }
}
