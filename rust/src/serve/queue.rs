//! Concurrent request queue feeding the serving workers.
//!
//! A bounded MPMC queue on `Mutex` + `Condvar` (the vendored crate set
//! has no channel/async runtime): producers [`RequestQueue::try_push`]
//! requests (an explicit [`PushError::Full`] is the backpressure signal
//! the HTTP 429 path builds on), workers block in
//! [`RequestQueue::pop_batch`] until work (or close), then drain up to a
//! micro-batch worth in FIFO order — restricted to requests with the
//! **same step count**, because every member of a lockstep micro-batch
//! must run the identical op sequence through the rendezvous
//! ([`crate::serve::batcher::SharedBatch`]). Mixed-step traffic
//! therefore forms per-step-count batches: the FIFO head defines the
//! batch's step count and later same-step requests are pulled forward
//! past differing ones (bounded overtaking; FIFO order is preserved
//! within each step class).
//!
//! The close/drain protocol (accepted work is drained exactly once,
//! post-close pushes refused, parked workers always woken) is
//! model-checked over every bounded schedule by
//! [`crate::check::models::DrainModel`].

use crate::sd::graph::RequestId;
use crate::util::cancel::CancelToken;
use crate::util::sync::{lock_or_abort, rank, Condvar, Mutex};
use std::collections::VecDeque;
use std::time::Instant;

/// One image-generation request.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    /// Request identity (threaded through engines and reports).
    pub id: RequestId,
    /// Text prompt.
    pub prompt: String,
    /// Latent seed.
    pub seed: u64,
    /// Denoising steps (1 = SD-Turbo). Micro-batches are formed from
    /// same-step requests so lockstep members stay in sync.
    pub steps: usize,
    /// Cooperative cancel/deadline token, checked at step boundaries.
    pub cancel: CancelToken,
    /// When the request entered the queue (queue-wait accounting).
    pub enqueued: Instant,
}

impl ServeRequest {
    /// A live request enqueued now, with a fresh (never-firing) token.
    pub fn new(id: RequestId, prompt: String, seed: u64, steps: usize) -> ServeRequest {
        assert!(steps >= 1, "a request needs at least one denoising step");
        let (cancel, enqueued) = (CancelToken::new(), Instant::now());
        ServeRequest { id, prompt, seed, steps, cancel, enqueued }
    }

    /// Replace the token (cancel routes and deadlines hold clones of it).
    pub fn with_cancel(mut self, cancel: CancelToken) -> ServeRequest {
        self.cancel = cancel;
        self
    }
}

/// Why a push was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    /// The queue is at capacity — shed load (HTTP 429 + `Retry-After`).
    Full {
        /// The configured bound.
        capacity: usize,
    },
    /// The queue was closed (graceful shutdown drains, then rejects).
    Closed,
}

impl std::fmt::Display for PushError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PushError::Full { capacity } => write!(f, "queue full (capacity {capacity})"),
            PushError::Closed => write!(f, "queue closed"),
        }
    }
}

impl std::error::Error for PushError {}

struct QueueState {
    pending: VecDeque<ServeRequest>,
    closed: bool,
}

/// FIFO request queue with a capacity bound and close semantics.
pub struct RequestQueue {
    capacity: usize,
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl Default for RequestQueue {
    fn default() -> Self {
        RequestQueue::new()
    }
}

impl RequestQueue {
    /// New, open, empty queue without a capacity bound (offline batch
    /// runs that enqueue a known request set up front).
    pub fn new() -> RequestQueue {
        RequestQueue::bounded(usize::MAX)
    }

    /// New queue admitting at most `capacity` waiting requests; a push
    /// at the bound returns [`PushError::Full`] instead of growing.
    pub fn bounded(capacity: usize) -> RequestQueue {
        assert!(capacity >= 1, "queue capacity must be >= 1");
        RequestQueue {
            capacity,
            state: Mutex::ranked(
                rank::SERVE_QUEUE,
                "serve.queue",
                QueueState { pending: VecDeque::new(), closed: false },
            ),
            cv: Condvar::new(),
        }
    }

    /// The capacity bound (`usize::MAX` for unbounded queues).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Enqueue a request, refusing instead of blocking: [`PushError::Full`]
    /// at capacity, [`PushError::Closed`] after [`RequestQueue::close`].
    pub fn try_push(&self, req: ServeRequest) -> Result<(), PushError> {
        let mut st = self.state.lock();
        if st.closed {
            return Err(PushError::Closed);
        }
        if st.pending.len() >= self.capacity {
            return Err(PushError::Full { capacity: self.capacity });
        }
        st.pending.push_back(req);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Enqueue a request. Panics if the queue was closed or is full —
    /// the infallible producer path for offline runs with a known bound.
    pub fn push(&self, req: ServeRequest) {
        match self.try_push(req) {
            Ok(()) => {}
            Err(PushError::Closed) => panic!("push after close"),
            Err(PushError::Full { capacity }) => panic!("queue full (capacity {capacity})"),
        }
    }

    /// Close the queue: workers drain what is left, then see empty pops.
    /// Runs on the drain path, so a poisoned queue aborts instead of
    /// cascading a second panic into a hung shutdown (see the poisoning
    /// policy in [`crate::util::sync`]).
    pub fn close(&self) {
        let mut st = lock_or_abort(&self.state);
        st.closed = true;
        drop(st);
        self.cv.notify_all();
    }

    /// True once [`RequestQueue::close`] ran.
    pub fn is_closed(&self) -> bool {
        self.state.lock().closed
    }

    /// Requests currently waiting.
    pub fn len(&self) -> usize {
        self.state.lock().pending.len()
    }

    /// True when no request is waiting.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Block until at least one request is available (or the queue is
    /// closed and drained), then take up to `max` requests sharing the
    /// FIFO head's step count (lockstep batches must be step-homogeneous).
    /// An empty vec means "closed and drained" — the worker's stop
    /// signal.
    pub fn pop_batch(&self, max: usize) -> Vec<ServeRequest> {
        assert!(max >= 1, "micro-batch size must be >= 1");
        let mut st = self.state.lock();
        loop {
            if !st.pending.is_empty() {
                let steps = st.pending.front().expect("non-empty").steps;
                let mut batch = Vec::with_capacity(max.min(st.pending.len()));
                let mut i = 0;
                while i < st.pending.len() && batch.len() < max {
                    if st.pending[i].steps == steps {
                        batch.push(st.pending.remove(i).expect("index in range"));
                    } else {
                        i += 1;
                    }
                }
                return batch;
            }
            if st.closed {
                return Vec::new();
            }
            st = self.cv.wait(st);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn req(id: u64) -> ServeRequest {
        ServeRequest::new(RequestId(id), format!("p{id}"), id, 1)
    }

    fn req_steps(id: u64, steps: usize) -> ServeRequest {
        ServeRequest::new(RequestId(id), format!("p{id}"), id, steps)
    }

    #[test]
    fn fifo_order_and_batch_limit() {
        let q = RequestQueue::new();
        for i in 0..5 {
            q.push(req(i));
        }
        q.close();
        let a = q.pop_batch(3);
        assert_eq!(a.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![0, 1, 2]);
        let b = q.pop_batch(3);
        assert_eq!(b.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![3, 4]);
        assert!(q.pop_batch(3).is_empty(), "closed + drained = stop signal");
    }

    #[test]
    fn workers_drain_everything_exactly_once() {
        let q = RequestQueue::new();
        for i in 0..40 {
            q.push(req(i));
        }
        q.close();
        let served = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| loop {
                    let batch = q.pop_batch(4);
                    if batch.is_empty() {
                        break;
                    }
                    served.fetch_add(batch.len(), Ordering::Relaxed);
                });
            }
        });
        assert_eq!(served.load(Ordering::Relaxed), 40);
        assert!(q.is_empty());
    }

    #[test]
    fn pop_blocks_until_push() {
        let q = RequestQueue::new();
        std::thread::scope(|scope| {
            let h = scope.spawn(|| q.pop_batch(2));
            std::thread::sleep(std::time::Duration::from_millis(10));
            q.push(req(9));
            let got = h.join().unwrap();
            assert_eq!(got.len(), 1);
            assert_eq!(got[0].id.0, 9);
        });
        q.close();
    }

    #[test]
    #[should_panic(expected = "push after close")]
    fn push_after_close_rejected() {
        let q = RequestQueue::new();
        q.close();
        q.push(req(1));
    }

    #[test]
    fn bounded_queue_rejects_at_capacity_until_a_pop_frees_a_slot() {
        let q = RequestQueue::bounded(2);
        assert_eq!(q.capacity(), 2);
        assert_eq!(q.try_push(req(1)), Ok(()));
        assert_eq!(q.try_push(req(2)), Ok(()));
        assert_eq!(q.try_push(req(3)), Err(PushError::Full { capacity: 2 }));
        assert_eq!(q.len(), 2, "the rejected request never entered");
        assert_eq!(q.pop_batch(1).len(), 1);
        assert_eq!(q.try_push(req(3)), Ok(()), "a freed slot admits again");
        q.close();
        assert_eq!(q.try_push(req(4)), Err(PushError::Closed));
    }

    #[test]
    fn batches_are_step_homogeneous_with_bounded_overtaking() {
        let q = RequestQueue::new();
        for (id, steps) in [(1, 1), (2, 1), (3, 4), (4, 1), (5, 4)] {
            q.push(req_steps(id, steps));
        }
        q.close();
        // Head has steps=1: the two queued 1-step peers join, overtaking
        // the 4-step request in the middle.
        let a = q.pop_batch(4);
        assert_eq!(a.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![1, 2, 4]);
        assert!(a.iter().all(|r| r.steps == 1));
        let b = q.pop_batch(4);
        assert_eq!(b.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![3, 5]);
        assert!(b.iter().all(|r| r.steps == 4));
    }

    #[test]
    fn step_grouping_respects_the_batch_limit() {
        let q = RequestQueue::new();
        for i in 0..5 {
            q.push(req_steps(i, 2));
        }
        q.close();
        assert_eq!(q.pop_batch(3).len(), 3);
        assert_eq!(q.pop_batch(3).len(), 2);
    }
}
