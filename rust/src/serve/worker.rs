//! The serving harness: workers pulling micro-batches off the queue and
//! running them lockstep over the shared pipeline.

use super::batcher::{BatchMember, SharedBatch};
use super::metrics::{RequestOutcome, RunnerState, ServeReport};
use super::queue::{RequestQueue, ServeRequest};
use crate::coordinator::{Coordinator, OffloadPolicy};
use crate::imax::ImaxConfig;
use crate::sd::graph::RequestId;
use crate::sd::pipeline::{to_rgb8, Pipeline, PipelineConfig};
use crate::util::png::crc32;
use crate::util::sync::Mutex;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Serving-side knobs (the pipeline/model side comes from
/// [`PipelineConfig`]).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// IMAX lanes behind the coordinator (1–8).
    pub lanes: usize,
    /// Host threads for non-offloaded GGML ops. `> 1` also enables the
    /// coordinator's **lane worker pool**: sharded submissions enqueue
    /// their row-tile shards on per-lane worker threads and run
    /// concurrently (outputs and simulated counters are bit-identical to
    /// `host_threads == 1`, which executes every shard inline).
    pub host_threads: usize,
    /// Maximum requests coalesced into one micro-batch.
    pub max_batch: usize,
    /// Concurrent micro-batch workers.
    pub workers: usize,
    /// Route merged submissions through single-op row-tile sharding
    /// across all lanes (bit-identical; see
    /// [`crate::coordinator::Coordinator::submit_sharded`]) instead of
    /// whole-op lane affinity.
    pub sharded: bool,
    /// Admission bound: at most this many requests wait in the queue;
    /// past it, pushes fail (the HTTP layer's 429 signal). Offline
    /// [`ServeHarness::serve`] runs widen the bound to the request-set
    /// size, so the cap only bites in online serving.
    pub queue_capacity: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            lanes: 2,
            host_threads: 2,
            max_batch: 4,
            workers: 2,
            sharded: false,
            queue_capacity: 64,
        }
    }
}

impl ServeConfig {
    /// The serial baseline: one request at a time, no coalescing — the
    /// paper's one-image-per-invocation mode, for comparison benches.
    pub fn serial(lanes: usize, host_threads: usize) -> ServeConfig {
        ServeConfig {
            lanes,
            host_threads,
            max_batch: 1,
            workers: 1,
            sharded: false,
            queue_capacity: 64,
        }
    }
}

/// The serving stack: shared pipeline + coordinator + worker pool.
pub struct ServeHarness {
    pipeline: Arc<Pipeline>,
    coordinator: Arc<Coordinator>,
    /// Serving configuration.
    pub config: ServeConfig,
}

impl ServeHarness {
    /// Build the harness. `pipe_cfg.backend` is ignored — execution is
    /// always routed through the coordinator (lanes + host pool).
    /// Equivalent to [`ServeHarness::with_imax`] over the default FPGA
    /// configuration (weight cache on).
    pub fn new(pipe_cfg: PipelineConfig, config: ServeConfig) -> ServeHarness {
        let imax = ImaxConfig::fpga(config.lanes);
        ServeHarness::with_imax(pipe_cfg, config, imax)
    }

    /// [`ServeHarness::new`] over an explicit IMAX configuration — the
    /// seam the CLI uses to thread `--lmm-cache` / `--no-weight-cache`
    /// through to the lanes. When the weight cache is enabled, the
    /// pipeline's compiled plan shards and pins the hottest weights
    /// across the lanes before any request runs.
    pub fn with_imax(
        pipe_cfg: PipelineConfig,
        config: ServeConfig,
        imax: ImaxConfig,
    ) -> ServeHarness {
        assert!(config.max_batch >= 1, "max_batch must be >= 1");
        assert!(config.workers >= 1, "workers must be >= 1");
        assert!(config.queue_capacity >= 1, "queue_capacity must be >= 1");
        let cache_enabled = imax.weight_cache_bytes > 0;
        // The pipeline config's routing policy (its `backend` field is
        // ignored, but `conv_offload` is honored): QuantizedAndConv
        // additionally sends F16 ConvIm2col GEMMs to the lanes.
        let policy: OffloadPolicy = pipe_cfg.policy();
        let coordinator = Arc::new(Coordinator::new(
            imax,
            config.lanes,
            config.host_threads,
            policy,
        ));
        let pipeline = Arc::new(Pipeline::new(pipe_cfg));
        if cache_enabled && config.lanes > 0 {
            // The prefetch/pin pass matching the routing mode: row-tile
            // shards per lane (sharded) or whole weights (affinity).
            if config.sharded {
                coordinator.apply_plan_sharded(&pipeline.plan());
            } else {
                coordinator.apply_plan(&pipeline.plan());
            }
        }
        ServeHarness { pipeline, coordinator, config }
    }

    /// The shared coordinator (for metric inspection).
    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.coordinator
    }

    /// The shared pipeline.
    pub fn pipeline(&self) -> &Arc<Pipeline> {
        &self.pipeline
    }

    /// Serve a set of `(prompt, seed)` requests to completion and report
    /// per-request latency plus aggregate throughput.
    pub fn serve(&self, prompts: &[(String, u64)]) -> ServeReport {
        let t_start = std::time::Instant::now();
        // Snapshot the shared counters so a reused harness reports this
        // run's deltas, not lifetime totals.
        let ord = std::sync::atomic::Ordering::Relaxed;
        let m = &self.coordinator.metrics;
        let base_offloaded_macs = m.offloaded_macs.load(ord);
        let base_imax_cycles = m.imax_cycles.load(ord);
        let base_lane_submissions = m.offloaded_jobs.load(ord);
        let base_batched_submissions = m.batched_submissions.load(ord);
        let base_coalesced_jobs = m.coalesced_jobs.load(ord);
        let base_cache_hit_bytes = m.cache_hit_bytes.load(ord);
        let base_cache_miss_bytes = m.cache_miss_bytes.load(ord);
        // Offline runs enqueue everything up front, so the admission cap
        // is widened to the request-set size (backpressure is an online
        // concern — the HTTP runner uses the strict bound).
        let queue =
            RequestQueue::bounded(self.config.queue_capacity.max(prompts.len()).max(1));
        let steps = self.pipeline.config.steps;
        for (i, (prompt, seed)) in prompts.iter().enumerate() {
            queue.push(ServeRequest::new(RequestId(i as u64 + 1), prompt.clone(), *seed, steps));
        }
        queue.close();
        let queue_depth_peak = prompts.len();

        let inflight = AtomicUsize::new(0);
        let inflight_peak = AtomicUsize::new(0);
        let outcomes: Mutex<Vec<RequestOutcome>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for _ in 0..self.config.workers {
                scope.spawn(|| loop {
                    let batch = queue.pop_batch(self.config.max_batch);
                    if batch.is_empty() {
                        break;
                    }
                    let now = inflight.fetch_add(batch.len(), Ordering::Relaxed) + batch.len();
                    inflight_peak.fetch_max(now, Ordering::Relaxed);
                    let done = self.run_batch(&batch);
                    inflight.fetch_sub(batch.len(), Ordering::Relaxed);
                    outcomes.lock().extend(done);
                });
            }
        });

        let mut outcomes = outcomes.into_inner();
        outcomes.sort_by_key(|o| o.id);
        let total_macs = outcomes.iter().map(|o| o.macs).sum();
        ServeReport {
            outcomes,
            wall_seconds: t_start.elapsed().as_secs_f64(),
            total_macs,
            offloaded_macs: m.offloaded_macs.load(ord) - base_offloaded_macs,
            imax_cycles: m.imax_cycles.load(ord) - base_imax_cycles,
            lane_submissions: m.offloaded_jobs.load(ord) - base_lane_submissions,
            batched_submissions: m.batched_submissions.load(ord) - base_batched_submissions,
            coalesced_jobs: m.coalesced_jobs.load(ord) - base_coalesced_jobs,
            cache_hit_bytes: m.cache_hit_bytes.load(ord) - base_cache_hit_bytes,
            cache_miss_bytes: m.cache_miss_bytes.load(ord) - base_cache_miss_bytes,
            rejected: 0,
            queue_depth_peak,
            inflight_peak: inflight_peak.load(Ordering::Relaxed),
            webhook: crate::serve::WebhookStats::default(),
        }
    }

    /// Run one micro-batch to completion: one thread per request,
    /// lockstep through the shared rendezvous. Requests whose token
    /// fires (cancel route, deadline) abort at their next step boundary
    /// and [`BatchMember::leave`] the rendezvous, so the surviving
    /// members complete normally — and bit-identically to a batch that
    /// never contained the leaver (each output row is an independent
    /// vec-dot). Outcomes are returned sorted by request id.
    ///
    /// This is the execution core [`ServeHarness::serve`] and the HTTP
    /// runner's workers share.
    pub fn run_batch(&self, batch: &[ServeRequest]) -> Vec<RequestOutcome> {
        let shared = SharedBatch::new(batch.len(), Arc::clone(&self.coordinator), self.config.sharded);
        let outcomes: Mutex<Vec<RequestOutcome>> = Mutex::new(Vec::with_capacity(batch.len()));
        std::thread::scope(|scope| {
            for (slot, req) in batch.iter().enumerate() {
                let shared = Arc::clone(&shared);
                let outcomes = &outcomes;
                scope.spawn(move || {
                    let queue_seconds = req.enqueued.elapsed().as_secs_f64();
                    let t0 = std::time::Instant::now();
                    let mut eng = BatchMember::new(shared, slot, req.id);
                    let outcome = match self.pipeline.generate_request(
                        &mut eng,
                        req.id,
                        &req.prompt,
                        req.seed,
                        req.steps,
                        &req.cancel,
                    ) {
                        Ok((img, report)) => {
                            let macs: u64 =
                                report.macs_by_dtype.iter().map(|(_, v)| *v).sum();
                            RequestOutcome {
                                id: req.id,
                                prompt: req.prompt.clone(),
                                state: RunnerState::Succeeded,
                                latency_seconds: queue_seconds + t0.elapsed().as_secs_f64(),
                                queue_seconds,
                                steps_completed: req.steps,
                                matmul_calls: report.matmul_calls,
                                macs,
                                image_crc32: crc32(&to_rgb8(&img)),
                            }
                        }
                        Err(aborted) => {
                            // Give the slot back so peers blocked at a
                            // rendezvous stop waiting for this member.
                            eng.leave();
                            let stats = eng.stats();
                            let macs: u64 =
                                stats.macs_by_dtype.iter().map(|(_, v)| *v).sum();
                            RequestOutcome {
                                id: req.id,
                                prompt: req.prompt.clone(),
                                state: RunnerState::from_cause(aborted.cause),
                                latency_seconds: queue_seconds + t0.elapsed().as_secs_f64(),
                                queue_seconds,
                                steps_completed: aborted.steps_completed,
                                matmul_calls: stats.calls,
                                macs,
                                image_crc32: 0,
                            }
                        }
                    };
                    outcomes.lock().push(outcome);
                });
            }
        });
        let mut out = outcomes.into_inner();
        out.sort_by_key(|o| o.id);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sd::trace::QuantModel;

    // Paper §III-B routing (convs on host): the counter expectations in
    // the long-standing tests below were written against it; the
    // conv-offload serving path has its own test.
    fn pipe_cfg() -> PipelineConfig {
        PipelineConfig {
            weight_seed: 99,
            model: Some(QuantModel::Q8_0),
            steps: 1,
            backend: crate::sd::pipeline::Backend::Host { threads: 2 },
            conv_offload: false,
        }
    }

    fn prompts(n: usize) -> Vec<(String, u64)> {
        (0..n).map(|i| (format!("a lovely cat number {i}"), 7 + i as u64)).collect()
    }

    #[test]
    fn serves_all_requests_with_metrics() {
        let h = ServeHarness::new(
            pipe_cfg(),
            ServeConfig {
                lanes: 2,
                host_threads: 2,
                max_batch: 2,
                workers: 2,
                sharded: false,
                queue_capacity: 64,
            },
        );
        let report = h.serve(&prompts(4));
        assert_eq!(report.requests(), 4);
        assert_eq!(
            report.outcomes.iter().map(|o| o.id.0).collect::<Vec<_>>(),
            vec![1, 2, 3, 4],
            "outcomes sorted by request id"
        );
        assert!(report.total_macs > 0);
        assert!(report.offloaded_macs > 0, "quantized layers offloaded");
        assert!(report.batched_submissions > 0, "micro-batches coalesced ops");
        assert!(report.outcomes.iter().all(|o| o.latency_seconds > 0.0));
        assert!(report.outcomes.iter().all(|o| o.state == RunnerState::Succeeded));
        assert!(report.outcomes.iter().all(|o| o.queue_seconds >= 0.0));
        assert!(report.outcomes.iter().all(|o| o.steps_completed == 1));
        assert_eq!(report.count(RunnerState::Succeeded), 4);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.queue_depth_peak, 4);
        assert!(report.inflight_peak >= 1);
        assert!(report.macs_per_second() > 0.0);
        assert!(report.latency_summary().n == 4);
    }

    #[test]
    fn run_batch_drops_a_precancelled_member_and_peers_match() {
        let h = ServeHarness::new(
            pipe_cfg(),
            ServeConfig {
                lanes: 1,
                host_threads: 2,
                max_batch: 3,
                workers: 1,
                sharded: false,
                queue_capacity: 64,
            },
        );
        let reqs = prompts(2);
        let reference = h.serve(&reqs);
        let batch = vec![
            ServeRequest::new(RequestId(1), reqs[0].0.clone(), reqs[0].1, 1),
            ServeRequest::new(RequestId(2), reqs[1].0.clone(), reqs[1].1, 1),
            ServeRequest::new(RequestId(3), "doomed".into(), 99, 1),
        ];
        batch[2].cancel.cancel();
        let out = h.run_batch(&batch);
        assert_eq!(out.len(), 3);
        assert_eq!(out[2].state, RunnerState::Cancelled);
        assert_eq!(out[2].steps_completed, 0);
        assert_eq!(out[2].matmul_calls, 0, "cancelled before any op was submitted");
        assert_eq!(out[2].image_crc32, 0, "no image for an aborted request");
        for (a, b) in reference.outcomes.iter().zip(&out) {
            assert_eq!(b.state, RunnerState::Succeeded);
            assert_eq!(
                a.image_crc32, b.image_crc32,
                "survivors bit-identical to a batch without the leaver"
            );
        }
    }

    #[test]
    fn batched_serving_is_deterministic_and_matches_serial() {
        let reqs = prompts(3);
        let serial = ServeHarness::new(pipe_cfg(), ServeConfig::serial(1, 2)).serve(&reqs);
        let batched = ServeHarness::new(
            pipe_cfg(),
            ServeConfig {
                lanes: 1,
                host_threads: 2,
                max_batch: 3,
                workers: 1,
                sharded: false,
                queue_capacity: 64,
            },
        )
        .serve(&reqs);
        for (a, b) in serial.outcomes.iter().zip(&batched.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.image_crc32, b.image_crc32, "bit-identical images for {:?}", a.id);
            assert_eq!(a.macs, b.macs);
        }
        assert_eq!(serial.offloaded_macs, batched.offloaded_macs, "same offloaded work");
        assert!(
            batched.imax_cycles < serial.imax_cycles,
            "coalescing must save simulated lane cycles: {} vs {}",
            batched.imax_cycles,
            serial.imax_cycles
        );
        assert_eq!(serial.batched_submissions, 0);
        assert!(batched.batched_submissions > 0);
    }

    #[test]
    fn reused_harness_reports_per_run_deltas() {
        let h = ServeHarness::new(pipe_cfg(), ServeConfig::serial(1, 2));
        let a = h.serve(&prompts(1));
        let b = h.serve(&prompts(1));
        assert_eq!(a.requests(), 1);
        assert_eq!(b.requests(), 1);
        assert_eq!(a.offloaded_macs, b.offloaded_macs, "deltas, not lifetime totals");
        assert_eq!(a.lane_submissions, b.lane_submissions);
        // The lane stays configured across runs, so run B skips CONF.
        assert!(b.imax_cycles > 0 && b.imax_cycles <= a.imax_cycles);
    }

    #[test]
    fn cross_request_weight_reuse_shows_in_cache_metrics() {
        let h = ServeHarness::new(pipe_cfg(), ServeConfig::serial(1, 2));
        let report = h.serve(&prompts(2));
        assert!(report.cache_hit_bytes > 0, "request 2 reuses request 1's residents");
        assert!(report.cache_byte_hit_rate() > 0.0);

        let mut imax = ImaxConfig::fpga(1);
        imax.weight_cache_bytes = 0;
        let off = ServeHarness::with_imax(pipe_cfg(), ServeConfig::serial(1, 2), imax);
        let off_report = off.serve(&prompts(2));
        assert_eq!(
            off_report.cache_hit_bytes + off_report.cache_miss_bytes,
            0,
            "--no-weight-cache means no cache traffic at all"
        );
        for (a, b) in report.outcomes.iter().zip(&off_report.outcomes) {
            assert_eq!(a.image_crc32, b.image_crc32, "cache on/off images bit-identical");
        }
        assert!(
            report.imax_cycles < off_report.imax_cycles,
            "residency must save simulated lane cycles: {} vs {}",
            report.imax_cycles,
            off_report.imax_cycles
        );
    }

    #[test]
    fn sharded_serving_matches_affinity_serving_bit_identically() {
        let reqs = prompts(2);
        let plain = ServeHarness::new(
            pipe_cfg(),
            ServeConfig {
                lanes: 2,
                host_threads: 2,
                max_batch: 2,
                workers: 1,
                sharded: false,
                queue_capacity: 64,
            },
        )
        .serve(&reqs);
        let sharded_h = ServeHarness::new(
            pipe_cfg(),
            ServeConfig {
                lanes: 2,
                host_threads: 2,
                max_batch: 2,
                workers: 1,
                sharded: true,
                queue_capacity: 64,
            },
        );
        let sharded = sharded_h.serve(&reqs);
        for (a, b) in plain.outcomes.iter().zip(&sharded.outcomes) {
            assert_eq!(a.image_crc32, b.image_crc32, "sharded routing must not change bits");
        }
        let ord = std::sync::atomic::Ordering::Relaxed;
        let m = sharded_h.coordinator().metrics.as_ref();
        assert!(m.sharded_ops.load(ord) > 0, "merged submissions went through the sharded path");
        assert!(
            m.shard_submissions.load(ord) > m.sharded_ops.load(ord),
            "ops split across both lanes"
        );
    }

    #[test]
    fn conv_offload_serving_is_bit_identical_and_adds_lane_work() {
        let reqs = prompts(2);
        let mut on_cfg = pipe_cfg();
        on_cfg.conv_offload = true;
        // Serial: conv offload must not change a bit, only move MACs.
        let base = ServeHarness::new(pipe_cfg(), ServeConfig::serial(1, 2)).serve(&reqs);
        let on = ServeHarness::new(on_cfg.clone(), ServeConfig::serial(1, 2)).serve(&reqs);
        for (a, b) in base.outcomes.iter().zip(&on.outcomes) {
            assert_eq!(a.image_crc32, b.image_crc32, "conv offload must not change bits");
        }
        assert!(
            on.offloaded_macs > base.offloaded_macs,
            "F16 conv MACs joined the offload population: {} vs {}",
            on.offloaded_macs,
            base.offloaded_macs
        );
        assert!(on.imax_cycles > base.imax_cycles, "conv GEMMs now spend lane cycles");
        // Batched: the conv rendezvous (keyed by WeightId + OpKind) now
        // lands on a lane, so its merges count as batched submissions.
        let batch = ServeConfig {
            lanes: 1,
            host_threads: 2,
            max_batch: 2,
            workers: 1,
            sharded: false,
            queue_capacity: 64,
        };
        let off_b = ServeHarness::new(pipe_cfg(), batch.clone()).serve(&reqs);
        let on_b = ServeHarness::new(on_cfg, batch).serve(&reqs);
        for (a, b) in base.outcomes.iter().zip(&on_b.outcomes) {
            assert_eq!(a.image_crc32, b.image_crc32, "batched conv offload stays bit-identical");
        }
        assert!(
            on_b.batched_submissions > off_b.batched_submissions,
            "merged conv rendezvous are lane submissions now: {} vs {}",
            on_b.batched_submissions,
            off_b.batched_submissions
        );
    }

    #[test]
    fn different_prompts_yield_different_images() {
        let h = ServeHarness::new(pipe_cfg(), ServeConfig::default());
        let report = h.serve(&[("a lovely cat".into(), 7), ("an angry robot".into(), 7)]);
        assert_ne!(report.outcomes[0].image_crc32, report.outcomes[1].image_crc32);
    }
}
