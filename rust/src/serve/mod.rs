//! Batched multi-lane serving: many concurrent image-generation
//! requests over one shared pipeline + coordinator.
//!
//! The paper evaluates single-image runs; the system-level lever it
//! leaves on the table (and the companion CGLA/LLM work shows is the
//! dominant one) is **lane utilization under concurrent kernels**. This
//! subsystem turns the mini pipeline into a serving stack:
//!
//! ```text
//! requests ──▶ RequestQueue ──▶ worker (micro-batch of ≤ B requests)
//!                                  │ one thread per request, lockstep
//!                                  ▼
//!                             SharedBatch rendezvous per mat-mul
//!                                  │ same-shape jobs coalesced:
//!                                  │ activation rows concatenated
//!                                  ▼
//!                        Coordinator ──▶ LaneSim lanes (round-robin)
//!                                  └──▶ host GGML pool (F32/F16 ops)
//! ```
//!
//! All requests in a micro-batch share one [`crate::sd::pipeline::Pipeline`]
//! (weights are read-only), so every request executes the identical op
//! sequence; the rendezvous in [`batcher`] exploits that to merge each
//! model-weight mat-mul across requests into **one** lane submission,
//! amortizing DMA descriptors, weight-tile streaming and CONF/REGV/RANGE
//! configuration — strictly fewer simulated cycles per MAC than serial
//! per-request submission, with bit-identical outputs (each output row is
//! an independent vec-dot). Multiple workers serve disjoint micro-batches
//! concurrently, spreading merged submissions over the lanes.
//!
//! The rendezvous keys on [`crate::ggml::WeightId`] content identity
//! (not storage addresses), and the coordinator routes each merged
//! submission to the lane whose LMM already caches that weight — so
//! cross-request coalescing and cross-step weight residency compose:
//! the first micro-batch pays the weight LOADs once, every later one
//! pays almost none.
//!
//! Metrics: per-request latency plus aggregate throughput and tail
//! percentiles in [`metrics::ServeReport`], built on the extended
//! [`crate::coordinator::CoordinatorMetrics`] batch counters, plus the
//! residency cache's hit/miss byte volumes.
//!
//! Requests carry a [`crate::util::cancel::CancelToken`] and a step
//! count: micro-batches are formed from same-step requests (lockstep
//! members must run the identical op sequence), a fired token aborts
//! its request at the next step boundary, and the aborting member
//! [`batcher::BatchMember::leave`]s the rendezvous so the surviving
//! members complete — bit-identically to a batch that never contained
//! it. The [`RequestQueue`] is capacity-bounded; a full queue refuses
//! admission ([`queue::PushError::Full`]), which the HTTP front-end in
//! [`crate::server`] surfaces as `429 Too Many Requests`.

pub mod batcher;
pub mod metrics;
pub mod queue;
pub mod worker;

pub use batcher::{BatchMember, SharedBatch};
pub use metrics::{RequestOutcome, RunnerState, ServeReport, WebhookStats};
pub use queue::{PushError, RequestQueue, ServeRequest};
pub use worker::{ServeConfig, ServeHarness};
