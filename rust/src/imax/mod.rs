//! IMAX3 CGLA simulator — the paper's accelerator substrate.
//!
//! IMAX3 (Akabe et al., IEEE Access 2025) is a Coarse-Grained **Linear**
//! Array: 64 Processing Elements per lane, each PE interleaving an ALU
//! pipeline with a slice of Local Memory (LMM), up to 8 lanes, attached to
//! an ARM host over DMA. The paper maps two GGML quantized dot-product
//! kernels onto it:
//!
//! * **Q8_0** across **46 PEs** — `OP_SML8` 8-bit multiply-add chains
//!   aggregated into 24-bit integers over every 12-PE group (Fig. 3),
//! * **Q3_K** across **51 PEs** — `OP_CVT53` restructuring (6-bit scales →
//!   5-bit, 2+1-bit quants → 3-bit) feeding the same MAC spine (Fig. 4).
//!
//! We do not have the Verilog or the VPK180; this module is the
//! substitution mandated by the reproduction brief: a transaction-level
//! simulator whose **numerics** execute every arithmetic op through the
//! ISA functions in [`isa`], and whose **timing** is derived cycle-by-cycle
//! from the same kernel geometry (beats through a systolic chain, DMA
//! bytes over a bus, per-phase configuration costs). Two execution modes
//! are provided and property-tested to agree exactly:
//!
//! * functional — streams real blocks through the PE chain (numerics +
//!   cycles); used by tests and the image-generation example.
//! * analytic — closed-form cycle counts from the same [`conf::KernelConfig`];
//!   used for paper-scale workloads (the full SD U-Net trace).
//!
//! Phase accounting follows the paper's Fig. 11 decomposition:
//! `CONF` / `REGV` / `RANGE` (configuration), `LOAD` (DDR→LMM), `EXEC`
//! (systolic compute), `DRAIN` (LMM→DDR).

pub mod conf;
pub mod dma;
pub mod isa;
pub mod kernels;
pub mod lane;
pub mod lmm;
pub mod power;
pub mod timing;

pub use conf::{KernelConfig, KernelKind};
pub use lane::LaneSim;
pub use lmm::CacheStats;
pub use timing::{Phase, PhaseBreakdown};

/// Number of PEs in one IMAX3 lane (Table II: "64 cores per lane").
pub const PES_PER_LANE: usize = 64;

/// Maximum lane count of the evaluated prototype (Figs. 9–10 sweep 1–8).
pub const MAX_LANES: usize = 8;

/// Target silicon/bitstream the simulator models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// AMD Versal VPK180 prototype @ 145 MHz (Table II).
    Fpga,
    /// Projected TSMC 28 nm ASIC @ 840 MHz (§IV-A static timing analysis).
    Asic,
}

/// Physical configuration of an IMAX3 instance.
#[derive(Debug, Clone)]
pub struct ImaxConfig {
    /// Silicon target.
    pub target: Target,
    /// Core clock in Hz.
    pub clock_hz: f64,
    /// Number of active lanes (1–8).
    pub lanes: usize,
    /// LMM capacity per lane in bytes (512 KiB configuration, §IV-A).
    pub lmm_bytes: usize,
    /// Bytes of the LMM reserved as the resident weight cache
    /// ([`lmm::Lmm`] high partition). `0` disables weight residency and
    /// restores the paper's stream-every-call behavior. Clamped by
    /// [`lane::LaneSim::new`] to 3/4 of `lmm_bytes` so transient tiles
    /// always keep working room.
    pub weight_cache_bytes: usize,
    /// DMA payload bytes transferred per core cycle once streaming.
    ///
    /// The VPK180 prototype moves data PS-DDR → host-memcpy → DMA buffer
    /// → NoC → PL; the paper's Fig. 11 shows this LOAD path dominating
    /// both kernels. The effective 0.193 B/cycle (≈28 MB/s at 145 MHz)
    /// is calibrated on the published FPGA/ASIC end-to-end deltas (see
    /// `EXPERIMENTS.md` §Calibration).
    pub dma_bytes_per_cycle: f64,
    /// Fixed cycles per DMA descriptor (setup + interrupt + host driver).
    pub dma_setup_cycles: u64,
    /// Configuration cycles per PE (CONF phase).
    pub conf_cycles_per_pe: u64,
    /// Register-init cycles per PE (REGV phase).
    pub regv_cycles_per_pe: u64,
    /// Address-range setup cycles per PE (RANGE phase).
    pub range_cycles_per_pe: u64,
}

impl ImaxConfig {
    /// The FPGA prototype (Table II row "IMAX3 (Xilinx VPK180)").
    pub fn fpga(lanes: usize) -> ImaxConfig {
        assert!((1..=MAX_LANES).contains(&lanes));
        ImaxConfig {
            target: Target::Fpga,
            clock_hz: 145.0e6,
            lanes,
            lmm_bytes: 512 * 1024,
            weight_cache_bytes: 256 * 1024,
            dma_bytes_per_cycle: 0.193,
            dma_setup_cycles: 4_000,
            conf_cycles_per_pe: 16,
            regv_cycles_per_pe: 4,
            range_cycles_per_pe: 4,
        }
    }

    /// The projected 28 nm ASIC (§IV-A: 840 MHz from static timing
    /// analysis; same microarchitecture, so per-cycle constants carry
    /// over and wall-clock scales with the clock — the paper's "≈5.8×
    /// computation-time reduction").
    pub fn asic(lanes: usize) -> ImaxConfig {
        ImaxConfig {
            target: Target::Asic,
            clock_hz: 840.0e6,
            // ASIC DMA: on-package interface keeps pace with the core
            // clock at the same bytes/cycle; absolute bandwidth scales
            // 840/145 like the compute.
            ..ImaxConfig::fpga(lanes)
        }
    }

    /// Seconds for a cycle count at this clock.
    pub fn seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / self.clock_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fpga_asic_clock_ratio_is_papers_5_8x() {
        let f = ImaxConfig::fpga(1);
        let a = ImaxConfig::asic(1);
        let ratio = a.clock_hz / f.clock_hz;
        assert!((ratio - 5.793).abs() < 0.01, "840/145 = {ratio}");
        // Same cycles -> 5.8x less wall-clock.
        let c = 1_000_000;
        assert!((f.seconds(c) / a.seconds(c) - ratio).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn lane_bounds_enforced() {
        ImaxConfig::fpga(9);
    }

    #[test]
    fn lmm_is_512k() {
        assert_eq!(ImaxConfig::fpga(1).lmm_bytes, 512 * 1024);
    }
}
