//! Phase-level time accounting (the paper's Fig. 11 decomposition).
//!
//! Every offloaded kernel invocation decomposes into the six phases the
//! paper measures on the FPGA: `CONF`/`REGV`/`RANGE` (writing the CGLA
//! configuration, initial register values, and LMM address ranges),
//! `LOAD` (DDR → LMM DMA), `EXEC` (systolic compute), `DRAIN` (LMM → DDR
//! write-back).

use std::ops::{Add, AddAssign};

/// One of the six measured phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Configuration-word write.
    Conf,
    /// Register-value initialization.
    Regv,
    /// LMM address-range setup.
    Range,
    /// DDR → LMM data transfer.
    Load,
    /// Systolic execution.
    Exec,
    /// LMM → DDR result write-back.
    Drain,
}

impl Phase {
    /// All phases in the paper's Fig. 11 order.
    pub const ALL: [Phase; 6] =
        [Phase::Exec, Phase::Load, Phase::Drain, Phase::Conf, Phase::Regv, Phase::Range];

    /// Display name as the paper labels it.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Conf => "CONF",
            Phase::Regv => "REGV",
            Phase::Range => "RANGE",
            Phase::Load => "LOAD",
            Phase::Exec => "EXEC",
            Phase::Drain => "DRAIN",
        }
    }
}

/// Cycle counts per phase for one or more kernel invocations.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PhaseBreakdown {
    /// CONF cycles.
    pub conf: u64,
    /// REGV cycles.
    pub regv: u64,
    /// RANGE cycles.
    pub range: u64,
    /// LOAD cycles.
    pub load: u64,
    /// EXEC cycles.
    pub exec: u64,
    /// DRAIN cycles.
    pub drain: u64,
}

impl PhaseBreakdown {
    /// Cycles in one phase.
    pub fn get(&self, p: Phase) -> u64 {
        match p {
            Phase::Conf => self.conf,
            Phase::Regv => self.regv,
            Phase::Range => self.range,
            Phase::Load => self.load,
            Phase::Exec => self.exec,
            Phase::Drain => self.drain,
        }
    }

    /// Add cycles to one phase.
    pub fn add(&mut self, p: Phase, cycles: u64) {
        match p {
            Phase::Conf => self.conf += cycles,
            Phase::Regv => self.regv += cycles,
            Phase::Range => self.range += cycles,
            Phase::Load => self.load += cycles,
            Phase::Exec => self.exec += cycles,
            Phase::Drain => self.drain += cycles,
        }
    }

    /// Total cycles across phases (phases serialize on the prototype:
    /// the host driver runs CONF→LOAD→EXEC→DRAIN per invocation).
    pub fn total(&self) -> u64 {
        self.conf + self.regv + self.range + self.load + self.exec + self.drain
    }

    /// Configuration overhead (CONF + REGV + RANGE), as Fig. 11 groups it.
    pub fn config_total(&self) -> u64 {
        self.conf + self.regv + self.range
    }

    /// Fraction of total spent in a phase (0 when total is 0).
    pub fn fraction(&self, p: Phase) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            self.get(p) as f64 / t as f64
        }
    }

    /// Seconds per phase at a clock.
    pub fn seconds(&self, clock_hz: f64) -> PhaseSeconds {
        PhaseSeconds {
            conf: self.conf as f64 / clock_hz,
            regv: self.regv as f64 / clock_hz,
            range: self.range as f64 / clock_hz,
            load: self.load as f64 / clock_hz,
            exec: self.exec as f64 / clock_hz,
            drain: self.drain as f64 / clock_hz,
        }
    }
}

impl Add for PhaseBreakdown {
    type Output = PhaseBreakdown;
    fn add(self, o: PhaseBreakdown) -> PhaseBreakdown {
        PhaseBreakdown {
            conf: self.conf + o.conf,
            regv: self.regv + o.regv,
            range: self.range + o.range,
            load: self.load + o.load,
            exec: self.exec + o.exec,
            drain: self.drain + o.drain,
        }
    }
}

impl AddAssign for PhaseBreakdown {
    fn add_assign(&mut self, o: PhaseBreakdown) {
        *self = *self + o;
    }
}

/// Wall-clock seconds per phase.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseSeconds {
    /// CONF seconds.
    pub conf: f64,
    /// REGV seconds.
    pub regv: f64,
    /// RANGE seconds.
    pub range: f64,
    /// LOAD seconds.
    pub load: f64,
    /// EXEC seconds.
    pub exec: f64,
    /// DRAIN seconds.
    pub drain: f64,
}

impl PhaseSeconds {
    /// Total seconds.
    pub fn total(&self) -> f64 {
        self.conf + self.regv + self.range + self.load + self.exec + self.drain
    }

    /// Values in Fig. 11 order (EXEC, LOAD, DRAIN, CONF, REGV, RANGE).
    pub fn fig11_order(&self) -> [f64; 6] {
        [self.exec, self.load, self.drain, self.conf, self.regv, self.range]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_total() {
        let mut a = PhaseBreakdown { conf: 1, regv: 2, range: 3, load: 4, exec: 5, drain: 6 };
        let b = PhaseBreakdown { conf: 10, ..Default::default() };
        a += b;
        assert_eq!(a.conf, 11);
        assert_eq!(a.total(), 31);
        assert_eq!(a.config_total(), 16);
    }

    #[test]
    fn get_matches_fields() {
        let p = PhaseBreakdown { conf: 1, regv: 2, range: 3, load: 4, exec: 5, drain: 6 };
        for (ph, want) in Phase::ALL.iter().zip([5u64, 4, 6, 1, 2, 3]) {
            assert_eq!(p.get(*ph), want, "{}", ph.name());
        }
    }

    #[test]
    fn fractions_sum_to_one() {
        let p = PhaseBreakdown { conf: 10, regv: 20, range: 30, load: 15, exec: 15, drain: 10 };
        let s: f64 = Phase::ALL.iter().map(|&ph| p.fraction(ph)).sum();
        assert!((s - 1.0).abs() < 1e-12);
        assert_eq!(PhaseBreakdown::default().fraction(Phase::Exec), 0.0);
    }

    #[test]
    fn seconds_conversion() {
        let p = PhaseBreakdown { exec: 145_000_000, ..Default::default() };
        let s = p.seconds(145.0e6);
        assert!((s.exec - 1.0).abs() < 1e-12);
        assert!((s.total() - 1.0).abs() < 1e-12);
    }
}
