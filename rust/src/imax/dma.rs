//! DMA transfer model: DDR4 (host) ↔ LMM (lane).
//!
//! On the VPK180 prototype the host A72 programs DMA descriptors over the
//! NoC; each transfer pays a fixed setup cost (descriptor write, doorbell,
//! completion interrupt, driver overhead) plus a streaming cost at the
//! effective bus rate. The paper's Fig. 11 shows LOAD dominating both
//! kernels and Q8_0 hurt most by volume (8.5 bits/weight vs 3.4375 —
//! §IV-B "the larger data transfer volume degraded the FPGA version's
//! performance"), which this model reproduces from first principles.

use super::ImaxConfig;

/// Accumulated DMA statistics for one offload session.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DmaStats {
    /// Bytes moved host → LMM.
    pub load_bytes: u64,
    /// Bytes moved LMM → host.
    pub drain_bytes: u64,
    /// Number of LOAD descriptors issued.
    pub load_transfers: u64,
    /// Number of DRAIN descriptors issued.
    pub drain_transfers: u64,
}

impl DmaStats {
    /// Record a LOAD transfer.
    pub fn record_load(&mut self, bytes: u64) {
        self.load_bytes += bytes;
        self.load_transfers += 1;
    }

    /// Record a DRAIN transfer.
    pub fn record_drain(&mut self, bytes: u64) {
        self.drain_bytes += bytes;
        self.drain_transfers += 1;
    }
}

/// Cycles for one DMA transfer of `bytes` under `cfg`.
pub fn transfer_cycles(cfg: &ImaxConfig, bytes: u64) -> u64 {
    if bytes == 0 {
        return 0;
    }
    cfg.dma_setup_cycles + (bytes as f64 / cfg.dma_bytes_per_cycle).ceil() as u64
}

/// Cycles for a whole stats bundle (loads + drains serialized, as the
/// single-channel prototype does).
pub fn total_cycles(cfg: &ImaxConfig, stats: &DmaStats) -> (u64, u64) {
    let load = stats.load_transfers * cfg.dma_setup_cycles
        + (stats.load_bytes as f64 / cfg.dma_bytes_per_cycle).ceil() as u64;
    let drain = stats.drain_transfers * cfg.dma_setup_cycles
        + (stats.drain_bytes as f64 / cfg.dma_bytes_per_cycle).ceil() as u64;
    (load, drain)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_bytes_is_free() {
        let cfg = ImaxConfig::fpga(1);
        assert_eq!(transfer_cycles(&cfg, 0), 0);
    }

    #[test]
    fn setup_dominates_small_transfers() {
        let cfg = ImaxConfig::fpga(1);
        let small = transfer_cycles(&cfg, 64);
        let stream = (64.0 / cfg.dma_bytes_per_cycle).ceil() as u64;
        assert!(small >= cfg.dma_setup_cycles);
        assert_eq!(small, cfg.dma_setup_cycles + stream);
        assert!(cfg.dma_setup_cycles > stream, "setup dominates 64 B");
    }

    #[test]
    fn streaming_dominates_large_transfers() {
        let cfg = ImaxConfig::fpga(1);
        let big = transfer_cycles(&cfg, 8 * 1024 * 1024);
        let stream = (8.0 * 1024.0 * 1024.0 / cfg.dma_bytes_per_cycle) as u64;
        assert!(big >= stream);
        assert!((big - stream) as f64 / big as f64 <= 0.01, "setup share small");
    }

    #[test]
    fn stats_accumulate_and_convert() {
        let cfg = ImaxConfig::fpga(1);
        let mut s = DmaStats::default();
        s.record_load(1000);
        s.record_load(2000);
        s.record_drain(500);
        assert_eq!(s.load_bytes, 3000);
        assert_eq!(s.load_transfers, 2);
        let (load, drain) = total_cycles(&cfg, &s);
        let bpc = cfg.dma_bytes_per_cycle;
        assert_eq!(load, 2 * cfg.dma_setup_cycles + (3000f64 / bpc).ceil() as u64);
        assert_eq!(drain, cfg.dma_setup_cycles + (500f64 / bpc).ceil() as u64);
    }

    #[test]
    fn q8_0_rows_cost_more_than_q3_k_rows() {
        // Same logical K=4096 row: Q8_0 = 4096/32·34 B, Q3_K = 4096/256·110 B.
        let cfg = ImaxConfig::fpga(1);
        let q8 = transfer_cycles(&cfg, (4096 / 32 * 34) as u64);
        let q3 = transfer_cycles(&cfg, (4096 / 256 * 110) as u64);
        assert!(q8 > q3, "paper §IV-B: Q8_0 transfer volume larger");
    }
}
