//! Functional execution of the two offloaded kernels through the ISA.
//!
//! These interpreters stream real GGML blocks through the PE structure of
//! [`super::conf::KernelConfig`], performing every arithmetic step with
//! the [`super::isa`] op functions. They are **bit-exact** with the host
//! reference implementations:
//!
//! * [`dot_q8_0`] ≡ [`crate::ggml::q8_0::vec_dot`] (same integer block
//!   sums, same f32 multiply/accumulate order), and
//! * [`dot_q3_k`] ≡ [`crate::ggml::q3_k::vec_dot_imax5`] — the *IMAX
//!   restructured* variant with 5-bit scales, because that is what the
//!   hardware executes after `OP_CVT53` (§III-B), and
//! * [`dot_f16`] ≡ [`crate::ggml::dot::dot_f16_f32`] (OP_SML16 pairs
//!   accumulate in element order over exact f16→f32 unpacks — §VI).
//!
//! Each call also reports the beats consumed, which the timing model in
//! [`super::lane`] converts to EXEC cycles — so numerics and timing come
//! from one walk over the data.

use super::conf::KernelConfig;
use super::isa::{
    op_ad24, op_add32, op_cvt53_scale, op_cvt53_unpack, op_cvti2f, op_fadd, op_fmul, op_sml8,
    op_sml16, op_sml16_tail, pack_word, Pair8, PairF16,
};
use crate::ggml::q3_k::{to_imax_stream, BlockQ3K};
use crate::ggml::q8_0::BlockQ8_0;
use crate::ggml::q8_k::BlockQ8K;
use crate::ggml::{QK8_0, QK_K};

/// Result of one functional dot: value plus consumed lane beats.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DotResult {
    /// The dot product.
    pub value: f32,
    /// Lane beats consumed (all groups advancing together).
    pub beats: u64,
}

/// One 32-element Q8_0 block through a 12-PE group: 8 × OP_SML8 (4
/// products each, two SIMD lanes) chained with OP_AD24, lanes folded by
/// the final OP_AD24 → exact block integer sum.
fn q8_0_group_beat(w: &BlockQ8_0, a: &BlockQ8_0) -> i32 {
    let mut lane_acc = [0i32, 0i32];
    for seg in 0..8 {
        // Load PEs stream one 4-byte word pair per SML8 stage.
        let wq = pack_word(&w.qs[seg * 4..seg * 4 + 4]);
        let aq = pack_word(&a.qs[seg * 4..seg * 4 + 4]);
        // Two SIMD lanes, each 2 products, accumulated along the chain.
        lane_acc[0] = op_ad24(lane_acc[0], op_sml8(wq[0], aq[0]));
        lane_acc[1] = op_ad24(lane_acc[1], op_sml8(wq[1], aq[1]));
    }
    // AD24 stage folds the two 24-bit SIMD lanes.
    op_ad24(lane_acc[0], lane_acc[1])
}

/// Functional Q8_0 × Q8_0 dot over block rows.
///
/// Blocks stride over the 3 groups (block `b` → group `b % 3`); the
/// shared FMA spine applies `isum · d_w · d_a` and accumulates **in block
/// order**, which makes the result bit-identical to the host
/// `vec_dot_q8_0_q8_0` loop.
pub fn dot_q8_0(cfg: &KernelConfig, w: &[BlockQ8_0], a: &[BlockQ8_0]) -> DotResult {
    assert_eq!(w.len(), a.len(), "row block-count mismatch");
    debug_assert_eq!(cfg.elems_per_beat, QK8_0);
    let mut acc = 0.0f32;
    for (bw, ba) in w.iter().zip(a.iter()) {
        let isum = q8_0_group_beat(bw, ba);
        // CvtI2F then the shared FMA spine: (isum * d_w) * d_a, block order.
        let prod = op_fmul(op_fmul(op_cvti2f(isum), bw.d.to_f32()), ba.d.to_f32());
        acc = op_fadd(acc, prod);
    }
    DotResult { value: acc, beats: cfg.beats_for_dot(w.len() * QK8_0) }
}

/// One 16-element Q3_K sub-block through a 14-PE group: OP_CVT53 unpacks
/// the 3-bit quants, 4 × OP_SML8 chains produce the 24-bit partial,
/// OP_CVT53's scale path multiplies by the doubled 5-bit scale.
fn q3_k_group_beat(q3: &[u8], acts: &[i8], s5: i8) -> i32 {
    debug_assert_eq!(q3.len(), 16);
    debug_assert_eq!(acts.len(), 16);
    let mut lane_acc = [0i32, 0i32];
    for seg in 0..4 {
        // CVT53 unpack stage: 3-bit (stored q+4) -> signed 8-bit operands.
        let wq: [i8; 4] = [
            op_cvt53_unpack(q3[seg * 4]),
            op_cvt53_unpack(q3[seg * 4 + 1]),
            op_cvt53_unpack(q3[seg * 4 + 2]),
            op_cvt53_unpack(q3[seg * 4 + 3]),
        ];
        let ww = [Pair8(wq[0], wq[1]), Pair8(wq[2], wq[3])];
        let aw = pack_word(&acts[seg * 4..seg * 4 + 4]);
        lane_acc[0] = op_ad24(lane_acc[0], op_sml8(ww[0], aw[0]));
        lane_acc[1] = op_ad24(lane_acc[1], op_sml8(ww[1], aw[1]));
    }
    let partial = op_ad24(lane_acc[0], lane_acc[1]);
    // CVT53 scale path: × (2 · s5).
    op_cvt53_scale(partial, s5)
}

/// Functional Q3_K × Q8_K dot over super-block rows (IMAX restructured
/// operands, 5-bit scales) — bit-identical to
/// [`crate::ggml::q3_k::vec_dot_imax5`].
pub fn dot_q3_k(cfg: &KernelConfig, w: &[BlockQ3K], a: &[BlockQ8K]) -> DotResult {
    assert_eq!(w.len(), a.len(), "row super-block count mismatch");
    debug_assert_eq!(cfg.elems_per_beat, 16);
    let mut acc = 0.0f32;
    for (bw, ba) in w.iter().zip(a.iter()) {
        // OP_CVT53 restructuring happens as the operands stream from LMM.
        let s = to_imax_stream(bw);
        // 16 sub-blocks strided over the 3 groups; Add32 PEs accumulate
        // the super-block isum (exact integer, order-free).
        let mut isum = 0i32;
        for j in 0..16 {
            let scaled = q3_k_group_beat(
                &s.q3[16 * j..16 * (j + 1)],
                &ba.qs[16 * j..16 * (j + 1)],
                s.scales5[j],
            );
            isum = op_add32(isum, scaled);
        }
        // Shared spine: (d_w * d_a) * isum, super-block order.
        let prod = op_fmul(op_fmul(s.d.to_f32(), ba.d), op_cvti2f(isum));
        acc = op_fadd(acc, prod);
    }
    DotResult { value: acc, beats: cfg.beats_for_dot(w.len() * QK_K) }
}

/// Functional F16 × f32 dot over one weight row (§VI OP_SML16 kernel).
///
/// Weight halves stream from LMM two-per-32-bit-word; activations stay
/// f32 (marshalling them to f16 would perturb the numerics — see
/// [`crate::imax::isa::op_sml16`]). The OP_SML16 chain accumulates in
/// element order, so the result is bit-identical to the host
/// [`crate::ggml::dot::dot_f16_f32`] loop. Beat accounting follows the
/// group geometry exactly like the quantized kernels: 16-element slices
/// strided over the 3 groups.
pub fn dot_f16(cfg: &KernelConfig, w: &[crate::util::f16::F16], a: &[f32]) -> DotResult {
    assert_eq!(w.len(), a.len(), "row length mismatch");
    debug_assert_eq!(cfg.kind, super::conf::KernelKind::F16);
    let mut acc = 0.0f32;
    let pairs = w.len() / 2;
    for i in 0..pairs {
        acc = op_sml16(acc, PairF16(w[2 * i], w[2 * i + 1]), [a[2 * i], a[2 * i + 1]]);
    }
    if w.len() % 2 == 1 {
        acc = op_sml16_tail(acc, w[w.len() - 1], a[w.len() - 1]);
    }
    DotResult { value: acc, beats: cfg.beats_for_dot(w.len()) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ggml::{q3_k, q8_0, q8_k};
    use crate::util::rng::Xoshiro256pp;

    fn random_row(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn q8_0_bit_exact_vs_host_reference() {
        let cfg = KernelConfig::q8_0();
        for seed in 0..20 {
            let k = 32 * (1 + (seed as usize % 7) * 3);
            let w = q8_0::quantize_row(&random_row(k, seed * 2 + 1));
            let a = q8_0::quantize_row(&random_row(k, seed * 2 + 2));
            let sim = dot_q8_0(&cfg, &w, &a);
            let host = q8_0::vec_dot(&w, &a);
            assert_eq!(
                sim.value.to_bits(),
                host.to_bits(),
                "seed {seed}: sim {} vs host {host}",
                sim.value
            );
        }
    }

    #[test]
    fn q3_k_bit_exact_vs_imax5_reference() {
        let cfg = KernelConfig::q3_k();
        for seed in 0..20 {
            let k = 256 * (1 + seed as usize % 4);
            let w = q3_k::quantize_row(&random_row(k, seed * 2 + 101));
            let a = q8_k::quantize_row(&random_row(k, seed * 2 + 102));
            let sim = dot_q3_k(&cfg, &w, &a);
            let host = q3_k::vec_dot_imax5(&w, &a);
            assert_eq!(
                sim.value.to_bits(),
                host.to_bits(),
                "seed {seed}: sim {} vs host {host}",
                sim.value
            );
        }
    }

    #[test]
    fn q3_k_close_to_exact_6bit_reference() {
        // The hardware's 5-bit scales approximate the exact Q3_K dot.
        let cfg = KernelConfig::q3_k();
        let k = 1024;
        let w = q3_k::quantize_row(&random_row(k, 7));
        let a = q8_k::quantize_row(&random_row(k, 8));
        let sim = dot_q3_k(&cfg, &w, &a).value;
        let exact = q3_k::vec_dot(&w, &a);
        assert!(
            (sim - exact).abs() < 0.15 * exact.abs().max(1.0),
            "sim {sim} vs exact {exact}"
        );
    }

    #[test]
    fn beats_match_config_formula() {
        let q8 = KernelConfig::q8_0();
        let w = q8_0::quantize_row(&random_row(256, 1));
        let a = q8_0::quantize_row(&random_row(256, 2));
        assert_eq!(dot_q8_0(&q8, &w, &a).beats, 3); // 8 blocks / 3 groups

        let q3 = KernelConfig::q3_k();
        let w = q3_k::quantize_row(&random_row(512, 3));
        let a = q8_k::quantize_row(&random_row(512, 4));
        assert_eq!(dot_q3_k(&q3, &w, &a).beats, 11); // 32 sub-blocks / 3
    }

    #[test]
    fn adversarial_blocks_do_not_overflow() {
        // All-max-magnitude blocks exercise the 24-bit envelope.
        let cfg = KernelConfig::q8_0();
        let w = vec![BlockQ8_0 { d: crate::util::f16::F16::ONE, qs: [127; 32] }; 4];
        let a = vec![BlockQ8_0 { d: crate::util::f16::F16::ONE, qs: [-127; 32] }; 4];
        let sim = dot_q8_0(&cfg, &w, &a);
        let host = q8_0::vec_dot(&w, &a);
        assert_eq!(sim.value, host);
        assert_eq!(sim.value, -(4.0 * 32.0 * 127.0 * 127.0));
    }

    #[test]
    fn empty_rows_are_zero() {
        assert_eq!(dot_q8_0(&KernelConfig::q8_0(), &[], &[]).value, 0.0);
        assert_eq!(dot_q3_k(&KernelConfig::q3_k(), &[], &[]).value, 0.0);
        assert_eq!(dot_f16(&KernelConfig::f16(), &[], &[]).value, 0.0);
    }

    #[test]
    fn f16_bit_exact_vs_host_reference() {
        use crate::ggml::dot::dot_f16_f32;
        use crate::util::f16::F16;
        let cfg = KernelConfig::f16();
        // Odd, even, sub-slice and multi-slice lengths, conv-like K too.
        for (seed, k) in [(1u64, 1usize), (2, 2), (3, 15), (4, 16), (5, 17), (6, 48), (7, 1152)]
        {
            let w: Vec<F16> =
                random_row(k, seed * 2 + 201).iter().map(|&v| F16::from_f32(v)).collect();
            let a = random_row(k, seed * 2 + 202);
            let sim = dot_f16(&cfg, &w, &a);
            let host = dot_f16_f32(&w, &a);
            assert_eq!(
                sim.value.to_bits(),
                host.to_bits(),
                "k {k}: sim {} vs host {host}",
                sim.value
            );
        }
    }

    #[test]
    fn f16_beats_match_config_formula() {
        use crate::util::f16::F16;
        let cfg = KernelConfig::f16();
        let w: Vec<F16> = random_row(1152, 9).iter().map(|&v| F16::from_f32(v)).collect();
        let a = random_row(1152, 10);
        // 72 slices of 16 over 3 groups -> 24 beats.
        assert_eq!(dot_f16(&cfg, &w, &a).beats, 24);
        let w1 = vec![F16::ONE; 17];
        let a1 = vec![1.0f32; 17];
        assert_eq!(dot_f16(&cfg, &w1, &a1).beats, 1);
        assert_eq!(dot_f16(&cfg, &w1, &a1).value, 17.0);
    }
}
