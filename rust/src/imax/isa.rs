//! The IMAX3 instruction semantics used by the dot-product kernels.
//!
//! IMAX PEs operate on 64-bit words viewed as two 32-bit SIMD lanes. The
//! paper adds three instructions for the Stable-Diffusion kernels
//! (§III-B); the remainder are base-ISA ops the earlier CNN/LLM ports
//! already used. Every arithmetic the simulator performs goes through
//! these functions, so ISA-level unit tests pin the hardware semantics
//! (sign extension, 24-bit wrap-around, conversion rounding) in one place.
//!
//! New instructions (paper §III-B):
//!
//! * [`op_sml8`] — **OP_SML8**: 2-way SIMD signed 8-bit multiply-add. Each
//!   32-bit lane multiplies its two 8-bit segments with the corresponding
//!   segments of the second operand and sums the two products into a
//!   sign-extended 24-bit result.
//! * [`op_ad24`] — **OP_AD24**: 2-way 24-bit integer addition aggregating
//!   OP_SML8 partials (wraps modulo 2^24, as hardware adders do).
//! * [`op_cvt53`] — **OP_CVT53**: Q3_K restructuring: expands packed 3-bit
//!   quants (stored `q+4`) to signed 8-bit and applies the 5-bit scale
//!   path (effective scale `2·s5`) by signed multiplication.
//! * [`op_sml16`] — **OP_SML16**: 2-way SIMD F16 multiply with f32
//!   accumulation, the §VI future-work instruction that moves the F16
//!   conv GEMMs (the pipeline's dominant MAC population, Table I) onto
//!   the lane. One 32-bit weight lane carries two packed halves; the
//!   matching activations stay f32 (converting them to f16 would change
//!   the numerics — f16→f32 conversion is exact, so keeping f32
//!   activations makes the lane dot bit-identical to the host
//!   `dot_f16_f32` reference by construction).

use crate::util::f16::F16;

/// Two signed 8-bit segments packed in a 32-bit SIMD lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pair8(pub i8, pub i8);

/// Sign-extend a 24-bit value held in an i32.
#[inline]
pub fn sext24(v: i32) -> i32 {
    (v << 8) >> 8
}

/// **OP_SML8** (one 32-bit lane): `a.0*b.0 + a.1*b.1`, sign-extended
/// 24-bit output. Max magnitude `2·127·127 = 32 258`, comfortably within
/// 24 bits, so the result is exact.
#[inline]
pub fn op_sml8(a: Pair8, b: Pair8) -> i32 {
    let s = a.0 as i32 * b.0 as i32 + a.1 as i32 * b.1 as i32;
    sext24(s)
}

/// **OP_SML8** full 64-bit word: both lanes independently.
#[inline]
pub fn op_sml8_w(a: [Pair8; 2], b: [Pair8; 2]) -> [i32; 2] {
    [op_sml8(a[0], b[0]), op_sml8(a[1], b[1])]
}

/// **OP_AD24** (one lane): 24-bit addition with hardware wrap-around.
#[inline]
pub fn op_ad24(a: i32, b: i32) -> i32 {
    sext24(a.wrapping_add(b))
}

/// **OP_AD24** full word.
#[inline]
pub fn op_ad24_w(a: [i32; 2], b: [i32; 2]) -> [i32; 2] {
    [op_ad24(a[0], b[0]), op_ad24(a[1], b[1])]
}

/// **OP_CVT53** unpack half: expand a packed 3-bit quant (stored `q+4` in
/// `[0,7]`) into a signed 8-bit value in `[-4,3]`.
#[inline]
pub fn op_cvt53_unpack(q3_plus4: u8) -> i8 {
    debug_assert!(q3_plus4 <= 7, "3-bit envelope");
    q3_plus4 as i8 - 4
}

/// **OP_CVT53** scale half: apply the 5-bit-approximated Q3_K sub-block
/// scale to a 24-bit group partial: `partial · (2·s5)`, widening to i32.
/// `s5 ∈ [-16, 15]`; worst case `|partial| ≤ 16·4·127 = 8 128` so the
/// product magnitude is ≤ 260 096 · … well inside i32.
#[inline]
pub fn op_cvt53_scale(partial24: i32, s5: i8) -> i32 {
    debug_assert!((-16..=15).contains(&s5), "5-bit scale envelope");
    partial24 * (2 * s5 as i32)
}

/// Base ISA: 32-bit integer add (isum accumulation across sub-blocks).
#[inline]
pub fn op_add32(a: i32, b: i32) -> i32 {
    a.wrapping_add(b)
}

/// Base ISA: signed i32 → f32 conversion (exact for |v| < 2^24, which the
/// kernels guarantee per super-block).
#[inline]
pub fn op_cvti2f(v: i32) -> f32 {
    v as f32
}

/// Base ISA: f32 multiply.
#[inline]
pub fn op_fmul(a: f32, b: f32) -> f32 {
    a * b
}

/// Base ISA: f32 add.
#[inline]
pub fn op_fadd(a: f32, b: f32) -> f32 {
    a + b
}

/// Base ISA: f32 fused multiply-add as separate mul+add (IMAX FP units
/// are mul + add stages, not fused — keep f32 rounding at each step so
/// numerics match the chained-unit hardware).
#[inline]
pub fn op_fma(acc: f32, a: f32, b: f32) -> f32 {
    op_fadd(acc, op_fmul(a, b))
}

/// Two IEEE binary16 weight values packed in one 32-bit SIMD lane word —
/// the LMM-side layout OP_SML16 consumes (halves the weight DMA volume
/// versus f32 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PairF16(pub F16, pub F16);

/// **OP_SML16** (one 32-bit weight lane × one f32 activation word):
/// unpacks the two halves (exact f16→f32 conversion), multiplies each
/// with its f32 activation, and folds both products into the running
/// f32 accumulator **in element order**: `((acc + w0·a0) + w1·a1)`.
///
/// The in-order accumulation is the load-bearing property: the host
/// reference `ggml::dot::dot_f16_f32` is the same sequential
/// `s += wᵢ·aᵢ` loop, and since f16→f32 is exact and the multiplies /
/// adds round identically, the lane dot is bit-identical to the host
/// dot by construction — no tolerance windows anywhere above.
#[inline]
pub fn op_sml16(acc: f32, w: PairF16, a: [f32; 2]) -> f32 {
    let p0 = op_fmul(w.0.to_f32(), a[0]);
    let p1 = op_fmul(w.1.to_f32(), a[1]);
    op_fadd(op_fadd(acc, p0), p1)
}

/// **OP_SML16** tail half: odd-length rows finish with a single
/// half×f32 product folded into the accumulator (the second SIMD slot
/// streams a zero weight on hardware; the simulator skips it).
#[inline]
pub fn op_sml16_tail(acc: f32, w: F16, a: f32) -> f32 {
    op_fadd(acc, op_fmul(w.to_f32(), a))
}

/// Pack 4 consecutive i8 values into the `[Pair8; 2]` word layout OP_SML8
/// consumes — the LMM-side byte arrangement.
#[inline]
pub fn pack_word(v: &[i8]) -> [Pair8; 2] {
    debug_assert!(v.len() >= 4);
    [Pair8(v[0], v[1]), Pair8(v[2], v[3])]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sext24_behaviour() {
        assert_eq!(sext24(0x007F_FFFF), 0x007F_FFFF); // max positive 24-bit
        assert_eq!(sext24(0x0080_0000), -0x0080_0000); // min negative
        assert_eq!(sext24(0x00FF_FFFF), -1);
        assert_eq!(sext24(0), 0);
        assert_eq!(sext24(123), 123);
    }

    #[test]
    fn sml8_exact_products() {
        assert_eq!(op_sml8(Pair8(3, -4), Pair8(5, 6)), 15 - 24);
        assert_eq!(op_sml8(Pair8(127, 127), Pair8(127, 127)), 2 * 127 * 127);
        assert_eq!(op_sml8(Pair8(-128, -128), Pair8(127, 127)), -2 * 128 * 127);
        assert_eq!(op_sml8(Pair8(0, 0), Pair8(99, -99)), 0);
    }

    #[test]
    fn sml8_word_lanes_independent() {
        let r = op_sml8_w(
            [Pair8(1, 2), Pair8(3, 4)],
            [Pair8(10, 10), Pair8(-1, -1)],
        );
        assert_eq!(r, [30, -7]);
    }

    #[test]
    fn ad24_wraps_like_hardware() {
        let max24 = 0x007F_FFFF;
        assert_eq!(op_ad24(max24, 1), -0x0080_0000, "24-bit overflow wraps");
        assert_eq!(op_ad24(-0x0080_0000, -1), max24);
        assert_eq!(op_ad24(1000, 2345), 3345);
        assert_eq!(op_ad24_w([1, -1], [2, -2]), [3, -3]);
    }

    #[test]
    fn q8_0_block_chain_never_wraps() {
        // Invariant from q8_0.rs: 32·127·127 < 2^23, so a full block chained
        // through OP_AD24 stays exact.
        let mut acc = 0i32;
        for _ in 0..8 {
            let p = op_sml8_w(
                [Pair8(127, 127), Pair8(127, 127)],
                [Pair8(127, 127), Pair8(127, 127)],
            );
            acc = op_ad24(acc, op_ad24(p[0], p[1]));
        }
        assert_eq!(acc, 32 * 127 * 127);
        assert_eq!(sext24(acc), acc, "still a valid 24-bit value");
    }

    #[test]
    fn cvt53_unpack_range() {
        for q in 0..=7u8 {
            let v = op_cvt53_unpack(q);
            assert_eq!(v as i32, q as i32 - 4);
            assert!((-4..=3).contains(&v));
        }
    }

    #[test]
    fn cvt53_scale_is_doubled_5bit() {
        assert_eq!(op_cvt53_scale(100, 3), 600);
        assert_eq!(op_cvt53_scale(100, -16), -3200);
        assert_eq!(op_cvt53_scale(-50, 15), -1500);
        assert_eq!(op_cvt53_scale(12345, 0), 0);
    }

    #[test]
    fn q3_k_superblock_isum_fits_i32() {
        // Worst case per sub-block: 16 · 4 · 127 = 8128; scaled by 2·16 = 32
        // and 16 sub-blocks: 8128 · 32 · 16 = 4 161 536 — exact in i32 and
        // exactly convertible to f32 (< 2^24).
        let worst = op_cvt53_scale(16 * 4 * 127, -16).abs() * 16;
        assert!(worst < (1 << 24));
        assert_eq!(op_cvti2f(worst) as i32, worst);
    }

    #[test]
    fn float_ops_match_ieee() {
        assert_eq!(op_fmul(1.5, 2.0), 3.0);
        assert_eq!(op_fadd(0.1f32, 0.2f32), 0.1f32 + 0.2f32);
        assert_eq!(op_fma(1.0, 2.0, 3.0), 7.0);
        // Not fused: rounding happens after the multiply.
        let a = 1.0f32 + f32::EPSILON;
        assert_eq!(op_fma(0.0, a, a), a * a);
    }

    #[test]
    fn pack_word_layout() {
        let w = pack_word(&[1, -2, 3, -4]);
        assert_eq!(w, [Pair8(1, -2), Pair8(3, -4)]);
    }

    #[test]
    fn sml16_matches_sequential_host_order() {
        // ((acc + w0·a0) + w1·a1) must equal the host loop's two
        // successive `s += w·a` steps bit-for-bit.
        let w = PairF16(F16::from_f32(1.5), F16::from_f32(-0.25));
        let a = [3.0f32, 7.0f32];
        let acc = 0.125f32;
        let mut host = acc;
        host += F16::from_f32(1.5).to_f32() * a[0];
        host += F16::from_f32(-0.25).to_f32() * a[1];
        assert_eq!(op_sml16(acc, w, a).to_bits(), host.to_bits());
    }

    #[test]
    fn sml16_accumulation_is_not_reassociated() {
        // Catastrophic-cancellation probe: reordering the two adds gives
        // a different f32 result, so bit-identity pins the order.
        let w = PairF16(F16::from_f32(1.0), F16::from_f32(1.0));
        let a = [1.0e-8f32, -1.0f32];
        let seq = op_sml16(1.0, w, a);
        let reassoc = 1.0f32 + (1.0e-8f32 + -1.0f32);
        assert_ne!(seq.to_bits(), reassoc.to_bits());
    }

    #[test]
    fn sml16_tail_single_product() {
        let got = op_sml16_tail(2.0, F16::from_f32(0.5), 8.0);
        assert_eq!(got, 6.0);
        // Exactness of the f16→f32 unpack: subnormal half survives.
        let tiny = F16(1); // 2^-24
        assert_eq!(op_sml16_tail(0.0, tiny, 1.0), tiny.to_f32());
    }
}
