//! Local Memory Module (LMM) model.
//!
//! IMAX interleaves a slice of local memory with every PE; architecturally
//! the lane's LMM behaves as a software-managed scratchpad that DMA fills
//! (LOAD) and drains (DRAIN). The simulator tracks capacity, the resident
//! regions, and access counts; kernels allocate regions for weight tiles,
//! activation rows and result buffers, and the tiling logic in
//! [`super::lane`] uses the capacity to decide how many weight rows fit
//! per pass — which in turn drives the LOAD-phase DMA volume (the paper's
//! Q8_0-vs-Q3_K asymmetry in Fig. 11).

/// Identifies an allocated LMM region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegionId(usize);

/// One resident region.
#[derive(Debug, Clone)]
struct Region {
    bytes: usize,
    label: &'static str,
    live: bool,
}

/// A lane's LMM: bounded scratchpad with accounting.
#[derive(Debug)]
pub struct Lmm {
    capacity: usize,
    used: usize,
    regions: Vec<Region>,
    /// Total bytes ever written by DMA LOAD.
    pub loaded_bytes: u64,
    /// Total bytes ever read back by DMA DRAIN.
    pub drained_bytes: u64,
    /// Peak occupancy seen.
    pub peak_used: usize,
}

impl Lmm {
    /// New LMM with `capacity` bytes.
    pub fn new(capacity: usize) -> Lmm {
        Lmm {
            capacity,
            used: 0,
            regions: Vec::new(),
            loaded_bytes: 0,
            drained_bytes: 0,
            peak_used: 0,
        }
    }

    /// Capacity in bytes.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Bytes currently allocated.
    pub fn used(&self) -> usize {
        self.used
    }

    /// Bytes free.
    pub fn free_bytes(&self) -> usize {
        self.capacity - self.used
    }

    /// Allocate a region; `Err` when it does not fit (caller must tile).
    pub fn alloc(&mut self, bytes: usize, label: &'static str) -> Result<RegionId, LmmError> {
        if bytes > self.free_bytes() {
            return Err(LmmError::OutOfMemory {
                requested: bytes,
                free: self.free_bytes(),
                label,
            });
        }
        self.used += bytes;
        self.peak_used = self.peak_used.max(self.used);
        self.regions.push(Region { bytes, label, live: true });
        Ok(RegionId(self.regions.len() - 1))
    }

    /// Free a region (idempotent).
    pub fn release(&mut self, id: RegionId) {
        let r = &mut self.regions[id.0];
        if r.live {
            r.live = false;
            self.used -= r.bytes;
        }
    }

    /// Record a DMA fill of a region (LOAD phase bookkeeping).
    pub fn record_load(&mut self, id: RegionId) {
        let r = &self.regions[id.0];
        assert!(r.live, "load into released region '{}'", r.label);
        self.loaded_bytes += r.bytes as u64;
    }

    /// Record a DMA write-back of `bytes` (DRAIN phase bookkeeping).
    pub fn record_drain(&mut self, bytes: usize) {
        self.drained_bytes += bytes as u64;
    }

    /// Drop all regions (between kernel invocations).
    pub fn reset(&mut self) {
        self.regions.clear();
        self.used = 0;
    }
}

/// LMM failure modes.
#[derive(Debug, PartialEq, Eq)]
pub enum LmmError {
    /// Allocation exceeded free capacity.
    OutOfMemory {
        /// Bytes requested.
        requested: usize,
        /// Bytes free at the time.
        free: usize,
        /// Region label.
        label: &'static str,
    },
}

impl std::fmt::Display for LmmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LmmError::OutOfMemory { requested, free, label } => {
                write!(f, "LMM OOM allocating {requested} B for '{label}' ({free} B free)")
            }
        }
    }
}

impl std::error::Error for LmmError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_and_release_accounting() {
        let mut lmm = Lmm::new(1000);
        let a = lmm.alloc(400, "w").unwrap();
        let b = lmm.alloc(500, "act").unwrap();
        assert_eq!(lmm.used(), 900);
        assert_eq!(lmm.peak_used, 900);
        lmm.release(a);
        assert_eq!(lmm.used(), 500);
        lmm.release(a); // idempotent
        assert_eq!(lmm.used(), 500);
        lmm.release(b);
        assert_eq!(lmm.used(), 0);
        assert_eq!(lmm.peak_used, 900);
    }

    #[test]
    fn oom_is_reported_not_panicked() {
        let mut lmm = Lmm::new(100);
        let err = lmm.alloc(101, "w").unwrap_err();
        assert_eq!(
            err,
            LmmError::OutOfMemory { requested: 101, free: 100, label: "w" }
        );
    }

    #[test]
    fn load_drain_volumes() {
        let mut lmm = Lmm::new(1 << 20);
        let a = lmm.alloc(4096, "w").unwrap();
        lmm.record_load(a);
        lmm.record_load(a); // re-fill (second tile pass)
        lmm.record_drain(512);
        assert_eq!(lmm.loaded_bytes, 8192);
        assert_eq!(lmm.drained_bytes, 512);
    }

    #[test]
    fn reset_clears_occupancy_not_volumes() {
        let mut lmm = Lmm::new(1024);
        let a = lmm.alloc(1024, "w").unwrap();
        lmm.record_load(a);
        lmm.reset();
        assert_eq!(lmm.used(), 0);
        assert_eq!(lmm.loaded_bytes, 1024);
        assert!(lmm.alloc(1024, "w2").is_ok());
    }
}
