//! Local Memory Module (LMM) model: scratchpad allocator + resident
//! weight cache.
//!
//! IMAX interleaves a slice of local memory with every PE; architecturally
//! the lane's LMM behaves as a software-managed scratchpad that DMA fills
//! (LOAD) and drains (DRAIN). The simulator tracks capacity, the resident
//! regions, and access counts; kernels allocate regions for weight tiles,
//! activation rows and result buffers, and the tiling logic in
//! [`super::lane`] uses the capacity to decide how many weight rows fit
//! per pass — which in turn drives the LOAD-phase DMA volume (the paper's
//! Q8_0-vs-Q3_K asymmetry in Fig. 11).
//!
//! # Weight residency
//!
//! The paper's Fig. 11 shows LOAD dominating lane time, yet a diffusion
//! run replays the *identical* weights every denoising step (and the
//! serving layer replays them across requests). The LMM therefore
//! supports two region lifetimes:
//!
//! * **transient** — activation rows, result buffers and streamed weight
//!   tiles; allocated and released within one kernel invocation, placed
//!   first-fit in the low partition `[0, capacity - cache_budget)`.
//! * **cached** — weight tiles keyed by [`crate::ggml::WeightId`] content
//!   identity; they survive across invocations in the high partition
//!   `[capacity - cache_budget, capacity)` under an LRU policy, except
//!   for **pinned** entries (chosen by the plan compiler's prefetch
//!   pass), which eviction never touches. A resident weight skips its
//!   LOAD DMA entirely on every later invocation that names it.
//!
//! Residency never changes operands — a cached tile holds the same bytes
//! DMA would have streamed — so caching elides transfer cycles only and
//! results stay bit-identical (regression-tested in
//! `tests/weight_cache.rs`).

use std::collections::HashMap;

/// Identifies an allocated LMM region (opaque handle, never reused).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegionId(usize);

/// One live region (transient or cached).
#[derive(Debug, Clone)]
struct Region {
    id: usize,
    offset: usize,
    bytes: usize,
    label: &'static str,
    cached: bool,
}

/// One cache directory entry, keyed by weight identity.
#[derive(Debug, Clone)]
struct CacheEntry {
    region: usize,
    bytes: usize,
    tick: u64,
    pinned: bool,
}

/// Counters for the weight-residency cache (all cumulative).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups that found the weight resident.
    pub hits: u64,
    /// Lookups that missed.
    pub misses: u64,
    /// Weight bytes whose LOAD was skipped thanks to residency.
    pub hit_bytes: u64,
    /// Weight bytes that had to be DMA'd because they were not resident.
    pub miss_bytes: u64,
    /// Bytes freed by LRU eviction.
    pub evicted_bytes: u64,
    /// Inserts rejected (weight larger than the budget, or only pinned
    /// entries left to evict).
    pub insert_failures: u64,
}

impl CacheStats {
    /// Hit rate over lookups in `[0, 1]` (0 when no lookups happened).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl std::ops::Add for CacheStats {
    type Output = CacheStats;
    fn add(self, o: CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits + o.hits,
            misses: self.misses + o.misses,
            hit_bytes: self.hit_bytes + o.hit_bytes,
            miss_bytes: self.miss_bytes + o.miss_bytes,
            evicted_bytes: self.evicted_bytes + o.evicted_bytes,
            insert_failures: self.insert_failures + o.insert_failures,
        }
    }
}

impl std::ops::AddAssign for CacheStats {
    fn add_assign(&mut self, o: CacheStats) {
        *self = *self + o;
    }
}

impl std::ops::Sub for CacheStats {
    type Output = CacheStats;
    fn sub(self, o: CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - o.hits,
            misses: self.misses - o.misses,
            hit_bytes: self.hit_bytes - o.hit_bytes,
            miss_bytes: self.miss_bytes - o.miss_bytes,
            evicted_bytes: self.evicted_bytes - o.evicted_bytes,
            insert_failures: self.insert_failures - o.insert_failures,
        }
    }
}

/// A lane's LMM: bounded scratchpad with accounting and a resident
/// weight cache.
#[derive(Debug)]
pub struct Lmm {
    capacity: usize,
    cache_budget: usize,
    /// Bytes held by live transient regions.
    trans_used: usize,
    /// Bytes held by cached regions.
    cache_used: usize,
    /// Live regions, sorted by offset.
    regions: Vec<Region>,
    next_id: usize,
    /// Cache directory: weight key → entry.
    cache: HashMap<u64, CacheEntry>,
    /// Keys requested pinned (applies at insert time too).
    pin_wish: std::collections::HashSet<u64>,
    tick: u64,
    stats: CacheStats,
    /// Total bytes ever written by DMA LOAD.
    pub loaded_bytes: u64,
    /// Subset of [`Lmm::loaded_bytes`] spent on weight tiles (streamed
    /// tiles and cache fills; activation rows excluded) — the per-lane
    /// metric the shard-scaling experiment reports.
    pub loaded_weight_bytes: u64,
    /// Total bytes ever read back by DMA DRAIN.
    pub drained_bytes: u64,
    /// Peak occupancy seen (transient + cached).
    pub peak_used: usize,
}

impl Lmm {
    /// New LMM with `capacity` bytes and no cache partition.
    pub fn new(capacity: usize) -> Lmm {
        Lmm {
            capacity,
            cache_budget: 0,
            trans_used: 0,
            cache_used: 0,
            regions: Vec::new(),
            next_id: 0,
            cache: HashMap::new(),
            pin_wish: std::collections::HashSet::new(),
            tick: 0,
            stats: CacheStats::default(),
            loaded_bytes: 0,
            loaded_weight_bytes: 0,
            drained_bytes: 0,
            peak_used: 0,
        }
    }

    /// Capacity in bytes.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Bytes currently allocated (transient + cached).
    pub fn used(&self) -> usize {
        self.trans_used + self.cache_used
    }

    /// Bytes free across the whole LMM.
    pub fn free_bytes(&self) -> usize {
        self.capacity - self.used()
    }

    // -- internal placement --------------------------------------------------

    /// First-fit hole of `bytes` inside `[lo, hi)`, if any.
    fn find_hole(&self, lo: usize, hi: usize, bytes: usize) -> Option<usize> {
        let mut cur = lo;
        for r in &self.regions {
            if r.offset + r.bytes <= lo {
                continue;
            }
            if r.offset >= hi {
                break;
            }
            if r.offset - cur >= bytes {
                return Some(cur);
            }
            cur = r.offset + r.bytes;
        }
        if hi >= cur && hi - cur >= bytes {
            Some(cur)
        } else {
            None
        }
    }

    /// Insert a region keeping the vec sorted by offset; returns its id.
    fn place(&mut self, offset: usize, bytes: usize, label: &'static str, cached: bool) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        let at = self.regions.partition_point(|r| r.offset < offset);
        self.regions.insert(at, Region { id, offset, bytes, label, cached });
        id
    }

    fn region_index(&self, id: usize) -> Option<usize> {
        self.regions.iter().position(|r| r.id == id)
    }

    // -- transient API -------------------------------------------------------

    /// Allocate a transient region; `Err` when it does not fit (caller
    /// must tile). Transients live in `[0, capacity - cache_budget)`.
    pub fn alloc(&mut self, bytes: usize, label: &'static str) -> Result<RegionId, LmmError> {
        let hi = self.capacity - self.cache_budget;
        let free = hi - self.trans_used;
        if bytes > free {
            return Err(LmmError::OutOfMemory { requested: bytes, free, label });
        }
        let offset = match self.find_hole(0, hi, bytes) {
            Some(o) => o,
            // All bytes exist but no contiguous hole (fragmentation).
            None => return Err(LmmError::OutOfMemory { requested: bytes, free, label }),
        };
        let id = self.place(offset, bytes, label, false);
        self.trans_used += bytes;
        self.peak_used = self.peak_used.max(self.used());
        Ok(RegionId(id))
    }

    /// Free a transient region (idempotent).
    pub fn release(&mut self, id: RegionId) {
        if let Some(at) = self.region_index(id.0) {
            assert!(
                !self.regions[at].cached,
                "release() on cached region '{}' — evict instead",
                self.regions[at].label
            );
            self.trans_used -= self.regions[at].bytes;
            self.regions.remove(at);
        }
    }

    /// Record a DMA fill of a region (LOAD phase bookkeeping).
    pub fn record_load(&mut self, id: RegionId) {
        let at = self.region_index(id.0).expect("load into released region");
        self.loaded_bytes += self.regions[at].bytes as u64;
        if self.regions[at].label == "weights" {
            self.loaded_weight_bytes += self.regions[at].bytes as u64;
        }
    }

    /// Record a DMA fill of `bytes` not tied to a handle (the
    /// whole-matrix weight fills of the residency cache).
    pub fn record_load_bytes(&mut self, bytes: u64) {
        self.loaded_bytes += bytes;
        self.loaded_weight_bytes += bytes;
    }

    /// Record a DMA write-back of `bytes` (DRAIN phase bookkeeping).
    pub fn record_drain(&mut self, bytes: usize) {
        self.drained_bytes += bytes as u64;
    }

    /// Drop all *transient* regions (between kernel invocations). Cached
    /// weights stay resident — that is their purpose.
    pub fn reset(&mut self) {
        self.regions.retain(|r| r.cached);
        self.trans_used = 0;
    }

    // -- residency cache API -------------------------------------------------

    /// Reserve `bytes` of the LMM as the resident weight cache. Must be
    /// called before any region exists; `bytes` must leave room for
    /// transient tiles.
    pub fn set_cache_budget(&mut self, bytes: usize) {
        assert!(self.regions.is_empty(), "set_cache_budget on a populated LMM");
        assert!(bytes <= self.capacity, "cache budget exceeds LMM capacity");
        self.cache_budget = bytes;
    }

    /// Bytes reserved for the weight cache (0 = caching disabled).
    pub fn cache_budget(&self) -> usize {
        self.cache_budget
    }

    /// Whether a cache partition exists.
    pub fn cache_enabled(&self) -> bool {
        self.cache_budget > 0
    }

    /// Bytes currently resident in the cache.
    pub fn resident_bytes(&self) -> usize {
        self.cache_used
    }

    /// Bytes resident and pinned.
    pub fn pinned_bytes(&self) -> usize {
        self.cache
            .values()
            .filter(|e| e.pinned)
            .map(|e| e.bytes)
            .sum()
    }

    /// Number of resident weights.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Whether `key` is resident (no bookkeeping side effects).
    pub fn cache_contains(&self, key: u64) -> bool {
        self.cache.contains_key(&key)
    }

    /// Cumulative cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.stats
    }

    /// Look `key` up: on a hit the entry's recency is refreshed and the
    /// caller may skip the weight LOAD. `bytes` is the weight's size (for
    /// hit/miss volume accounting; asserted equal to the resident copy).
    pub fn cache_lookup(&mut self, key: u64, bytes: usize) -> bool {
        self.tick += 1;
        match self.cache.get_mut(&key) {
            Some(e) => {
                assert_eq!(e.bytes, bytes, "one WeightId must always name the same bytes");
                e.tick = self.tick;
                self.stats.hits += 1;
                self.stats.hit_bytes += bytes as u64;
                true
            }
            None => {
                self.stats.misses += 1;
                self.stats.miss_bytes += bytes as u64;
                false
            }
        }
    }

    /// Try to make `key` resident (`bytes` of weight data), evicting LRU
    /// unpinned entries as needed. Returns `false` when it cannot fit —
    /// the caller then streams the weight transiently. Does *not* count
    /// DMA volume; the caller records the fill it actually performs.
    pub fn cache_insert(&mut self, key: u64, bytes: usize, label: &'static str) -> bool {
        if self.cache.contains_key(&key) {
            return true;
        }
        // Even evicting every unpinned entry cannot free pinned bytes —
        // reject up front rather than thrash residents in vain.
        if !self.cache_enabled() || bytes > self.cache_budget - self.pinned_bytes() {
            self.stats.insert_failures += 1;
            return false;
        }
        let lo = self.capacity - self.cache_budget;
        loop {
            if self.cache_budget - self.cache_used >= bytes {
                // Enough bytes exist; defragment the cache partition if
                // no contiguous hole does (resident tiles are relocated
                // by bookkeeping — a software-managed scratchpad can
                // slide tiles during idle, and placement here is a model,
                // not an address contract). This is what makes the pin
                // guarantee robust: a pinned weight that fits the
                // remaining budget always becomes resident, regardless
                // of how earlier inserts fragmented the partition.
                let offset = match self.find_hole(lo, self.capacity, bytes) {
                    Some(o) => o,
                    None => {
                        self.compact_cache(lo);
                        self.find_hole(lo, self.capacity, bytes)
                            .expect("compacted cache must have a hole for fitting bytes")
                    }
                };
                let region = self.place(offset, bytes, label, true);
                self.cache_used += bytes;
                self.peak_used = self.peak_used.max(self.used());
                self.tick += 1;
                let pinned = self.pin_wish.contains(&key);
                self.cache.insert(key, CacheEntry { region, bytes, tick: self.tick, pinned });
                return true;
            }
            // Evict the least-recently-used unpinned entry and retry.
            let victim = self
                .cache
                .iter()
                .filter(|(_, e)| !e.pinned)
                .min_by_key(|(_, e)| e.tick)
                .map(|(k, _)| *k);
            match victim {
                Some(k) => self.cache_evict(k),
                None => {
                    self.stats.insert_failures += 1;
                    return false;
                }
            }
        }
    }

    /// Slide all cached regions down to `lo`, eliminating holes between
    /// them (cached regions are the sorted tail of the region list —
    /// transients live strictly below `lo`).
    fn compact_cache(&mut self, lo: usize) {
        let mut cur = lo;
        for r in self.regions.iter_mut().filter(|r| r.cached) {
            r.offset = cur;
            cur += r.bytes;
        }
    }

    /// Evict `key` if resident (pinned entries included — explicit
    /// eviction is the owner's call; only the *LRU policy* honors pins).
    pub fn cache_evict(&mut self, key: u64) {
        if let Some(e) = self.cache.remove(&key) {
            if let Some(at) = self.region_index(e.region) {
                self.regions.remove(at);
            }
            self.cache_used -= e.bytes;
            self.stats.evicted_bytes += e.bytes as u64;
        }
    }

    /// Mark `key` pinned: the LRU policy will never evict it. Applies to
    /// a current resident entry and to any future insert of the key.
    pub fn cache_pin(&mut self, key: u64) {
        self.pin_wish.insert(key);
        if let Some(e) = self.cache.get_mut(&key) {
            e.pinned = true;
        }
    }

    /// Remove a pin (entry becomes a normal LRU citizen).
    pub fn cache_unpin(&mut self, key: u64) {
        self.pin_wish.remove(&key);
        if let Some(e) = self.cache.get_mut(&key) {
            e.pinned = false;
        }
    }

    /// Live `(offset, bytes)` extents, sorted by offset — introspection
    /// for the allocator property tests.
    pub fn live_regions(&self) -> Vec<(usize, usize)> {
        self.regions.iter().map(|r| (r.offset, r.bytes)).collect()
    }
}

/// LMM failure modes.
#[derive(Debug, PartialEq, Eq)]
pub enum LmmError {
    /// Allocation exceeded free capacity.
    OutOfMemory {
        /// Bytes requested.
        requested: usize,
        /// Bytes free at the time.
        free: usize,
        /// Region label.
        label: &'static str,
    },
}

impl std::fmt::Display for LmmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LmmError::OutOfMemory { requested, free, label } => {
                write!(f, "LMM OOM allocating {requested} B for '{label}' ({free} B free)")
            }
        }
    }
}

impl std::error::Error for LmmError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_and_release_accounting() {
        let mut lmm = Lmm::new(1000);
        let a = lmm.alloc(400, "w").unwrap();
        let b = lmm.alloc(500, "act").unwrap();
        assert_eq!(lmm.used(), 900);
        assert_eq!(lmm.peak_used, 900);
        lmm.release(a);
        assert_eq!(lmm.used(), 500);
        lmm.release(a); // idempotent
        assert_eq!(lmm.used(), 500);
        lmm.release(b);
        assert_eq!(lmm.used(), 0);
        assert_eq!(lmm.peak_used, 900);
    }

    #[test]
    fn oom_is_reported_not_panicked() {
        let mut lmm = Lmm::new(100);
        let err = lmm.alloc(101, "w").unwrap_err();
        assert_eq!(
            err,
            LmmError::OutOfMemory { requested: 101, free: 100, label: "w" }
        );
    }

    #[test]
    fn load_drain_volumes() {
        let mut lmm = Lmm::new(1 << 20);
        let a = lmm.alloc(4096, "w").unwrap();
        lmm.record_load(a);
        lmm.record_load(a); // re-fill (second tile pass)
        lmm.record_drain(512);
        assert_eq!(lmm.loaded_bytes, 8192);
        assert_eq!(lmm.drained_bytes, 512);
    }

    #[test]
    fn reset_clears_occupancy_not_volumes() {
        let mut lmm = Lmm::new(1024);
        let a = lmm.alloc(1024, "w").unwrap();
        lmm.record_load(a);
        lmm.reset();
        assert_eq!(lmm.used(), 0);
        assert_eq!(lmm.loaded_bytes, 1024);
        assert!(lmm.alloc(1024, "w2").is_ok());
    }

    #[test]
    fn regions_are_placed_without_overlap_and_holes_are_reused() {
        let mut lmm = Lmm::new(100);
        let a = lmm.alloc(30, "a").unwrap();
        let _b = lmm.alloc(30, "b").unwrap();
        let _c = lmm.alloc(30, "c").unwrap();
        lmm.release(a); // hole at [0, 30)
        let d = lmm.alloc(20, "d").unwrap();
        let regions = lmm.live_regions();
        assert_eq!(regions[0], (0, 20), "first-fit reuses the hole");
        for w in regions.windows(2) {
            assert!(w[0].0 + w[0].1 <= w[1].0, "regions overlap: {regions:?}");
        }
        lmm.release(d);
    }

    #[test]
    fn cache_partition_reserves_space_from_transients() {
        let mut lmm = Lmm::new(1000);
        lmm.set_cache_budget(400);
        assert!(lmm.cache_enabled());
        let err = lmm.alloc(700, "acts").unwrap_err();
        assert_eq!(err, LmmError::OutOfMemory { requested: 700, free: 600, label: "acts" });
        assert!(lmm.alloc(600, "acts").is_ok());
    }

    #[test]
    fn cache_hit_miss_and_lru_eviction() {
        let mut lmm = Lmm::new(1000);
        lmm.set_cache_budget(300);
        assert!(!lmm.cache_lookup(1, 200), "cold miss");
        assert!(lmm.cache_insert(1, 200, "w1"));
        assert!(lmm.cache_lookup(1, 200), "warm hit");
        assert!(lmm.cache_insert(2, 100, "w2"));
        assert_eq!(lmm.resident_bytes(), 300);
        // Inserting w3 must evict the LRU entry. Key 1 was touched after
        // key 2 was inserted... so refresh key 2 then insert.
        assert!(lmm.cache_lookup(2, 100));
        assert!(lmm.cache_insert(3, 250, "w3"), "evicts key 1 (LRU)");
        assert!(!lmm.cache_contains(1));
        assert!(!lmm.cache_contains(2), "250 B also needed key 2's space");
        let s = lmm.cache_stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert_eq!(s.hit_bytes, 300);
        assert_eq!(s.evicted_bytes, 300);
    }

    #[test]
    fn pinned_entries_survive_eviction_pressure() {
        let mut lmm = Lmm::new(1000);
        lmm.set_cache_budget(300);
        lmm.cache_pin(1);
        assert!(lmm.cache_insert(1, 200, "hot"));
        assert!(lmm.cache_insert(2, 100, "cold"));
        // 200 B fits only by evicting — key 2 goes, key 1 is untouchable.
        assert!(lmm.cache_insert(3, 100, "new"));
        assert!(lmm.cache_contains(1));
        assert!(!lmm.cache_contains(2));
        // A weight bigger than what unpinned eviction can free fails.
        assert!(!lmm.cache_insert(4, 300, "huge"));
        assert_eq!(lmm.cache_stats().insert_failures, 1);
        assert_eq!(lmm.pinned_bytes(), 200);
    }

    #[test]
    fn fragmented_partition_compacts_so_fitting_pins_always_land() {
        // X(80) | pinned A(100) | Y(120) fill a 300 B budget. Inserting
        // pinned B(200) evicts X and Y, leaving 200 free bytes split
        // around A — compaction must merge them so B lands.
        let mut lmm = Lmm::new(1000);
        lmm.set_cache_budget(300);
        assert!(lmm.cache_insert(1, 80, "x"));
        lmm.cache_pin(2);
        assert!(lmm.cache_insert(2, 100, "a"));
        assert!(lmm.cache_insert(3, 120, "y"));
        lmm.cache_pin(4);
        assert!(lmm.cache_insert(4, 200, "b"), "fragmentation must not defeat a fitting pin");
        assert!(lmm.cache_contains(2) && lmm.cache_contains(4));
        assert_eq!(lmm.resident_bytes(), 300);
        assert_eq!(lmm.cache_stats().insert_failures, 0);
        // Compaction keeps extents disjoint and inside the partition.
        let regions = lmm.live_regions();
        for w in regions.windows(2) {
            assert!(w[0].0 + w[0].1 <= w[1].0, "overlap after compaction: {regions:?}");
        }
        assert!(regions.iter().all(|&(off, b)| off >= 700 && off + b <= 1000));
    }

    #[test]
    fn oversized_weight_is_rejected_not_thrashed() {
        let mut lmm = Lmm::new(1000);
        lmm.set_cache_budget(100);
        assert!(lmm.cache_insert(7, 50, "ok"));
        assert!(!lmm.cache_insert(8, 101, "too big"));
        assert!(lmm.cache_contains(7), "rejection must not evict residents");
    }

    #[test]
    fn cached_regions_survive_reset() {
        let mut lmm = Lmm::new(1000);
        lmm.set_cache_budget(300);
        assert!(lmm.cache_insert(1, 200, "w"));
        let t = lmm.alloc(100, "acts").unwrap();
        lmm.record_load(t);
        lmm.reset();
        assert!(lmm.cache_contains(1));
        assert_eq!(lmm.used(), 200, "cached bytes stay, transients drop");
    }

    #[test]
    fn hit_rate_and_stats_algebra() {
        let mut a = CacheStats { hits: 3, misses: 1, ..Default::default() };
        assert!((a.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
        let b = CacheStats { hits: 1, miss_bytes: 10, ..Default::default() };
        a += b;
        assert_eq!(a.hits, 4);
        assert_eq!((a - b).hits, 3);
    }
}
