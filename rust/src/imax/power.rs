//! IMAX power model.
//!
//! §IV-A: "The power draw of IMAX depends on the LMM size and the number
//! of active functional units. In our 512 KB LMM configuration, we
//! estimated the power at 47.7 W for the Q8_0 kernel (46 units) and
//! 52.8 W for the Q3_K kernel (51 units)" — from Synopsys DC synthesis
//! on TSMC 28 nm. Two published points determine a linear
//! per-active-unit model exactly:
//!
//! ```text
//! P(u) = base + u · per_unit,   per_unit = (52.8 − 47.7) / (51 − 46) = 1.02 W
//!                               base     = 47.7 − 46 · 1.02       = 0.78 W
//! ```
//!
//! The FPGA prototype's wall power is the board figure from Table II
//! (180 W for the VPK180 kit).

use super::conf::KernelKind;
use super::Target;

/// Watts per active functional unit in the 28 nm ASIC synthesis.
pub const ASIC_WATTS_PER_UNIT: f64 = (52.8 - 47.7) / (51.0 - 46.0);

/// Baseline (LMM + clock tree + idle array) watts in the ASIC synthesis.
pub const ASIC_BASE_WATTS: f64 = 47.7 - 46.0 * ASIC_WATTS_PER_UNIT;

/// VPK180 evaluation-kit board power (Table II).
pub const FPGA_BOARD_WATTS: f64 = 180.0;

/// ASIC power for `active_units` functional units (512 KB LMM config).
pub fn asic_power_units(active_units: usize) -> f64 {
    ASIC_BASE_WATTS + active_units as f64 * ASIC_WATTS_PER_UNIT
}

/// Power draw of one lane running a kernel on a target.
pub fn kernel_power(target: Target, kind: KernelKind) -> f64 {
    let units = match kind {
        KernelKind::Q8_0 => 46,
        KernelKind::Q3K => 51,
    };
    match target {
        Target::Fpga => FPGA_BOARD_WATTS,
        Target::Asic => asic_power_units(units),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_reproduces_published_points() {
        assert!(
            (kernel_power(Target::Asic, KernelKind::Q8_0) - 47.7).abs() < 1e-9,
            "Q8_0 / 46 units must give the paper's 47.7 W"
        );
        assert!(
            (kernel_power(Target::Asic, KernelKind::Q3K) - 52.8).abs() < 1e-9,
            "Q3_K / 51 units must give the paper's 52.8 W"
        );
    }

    #[test]
    fn fpga_is_board_power() {
        assert_eq!(kernel_power(Target::Fpga, KernelKind::Q8_0), 180.0);
        assert_eq!(kernel_power(Target::Fpga, KernelKind::Q3K), 180.0);
    }

    #[test]
    fn per_unit_slope_positive_and_sane() {
        assert!(ASIC_WATTS_PER_UNIT > 0.5 && ASIC_WATTS_PER_UNIT < 2.0);
        assert!(ASIC_BASE_WATTS > 0.0, "base power must be positive");
        // A hypothetical full 64-unit kernel stays under 70 W.
        assert!(asic_power_units(64) < 70.0);
    }
}
