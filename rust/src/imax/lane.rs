//! Lane-level offload simulation: tiling, phase timing, and functional
//! matrix-multiply execution.
//!
//! One offloaded `mul_mat` (weights `[M, K]` quantized, activations
//! `[N, K]` pre-quantized by the host) runs as:
//!
//! ```text
//! CONF            once per kernel switch (write 46/51 PE configs)
//! for each activation tile (rows that fit half the LMM):
//!     LOAD acts   one DMA descriptor
//!     for each weight tile:
//!         REGV/RANGE   per-pass register & address setup
//!         LOAD weights one DMA descriptor
//!         EXEC         w_tile × a_tile dots, beats from the kernel config
//!         DRAIN        f32 results, one descriptor
//! ```
//!
//! [`TilePlan`] fixes the tile geometry from the LMM capacity;
//! [`LaneSim::analytic_mul_mat`] prices the loops in closed form and
//! [`LaneSim::mul_mat_q8_0`]/[`LaneSim::mul_mat_q3_k`] execute the same
//! loops functionally (numerics via [`super::kernels`]), so a property
//! test can require cycle-exact agreement between the two modes.
//! (Precisely: analytic `Streamed` prices the cache-less full-LMM
//! baseline; functional runs plan over the transient partition, which
//! is the whole LMM when `weight_cache_bytes == 0` and the two agree
//! exactly — with a cache partition reserved, functional streaming of
//! shapes too large for the partition legitimately tiles finer. See
//! [`LaneSim::analytic_mul_mat_with_residency`].)
//!
//! # Weight residency
//!
//! When the caller names the weight operand with a
//! [`crate::ggml::WeightId`] (`mul_mat_*_cached`), the lane consults the
//! LMM's resident weight cache first: a **resident** weight skips the
//! weight LOAD phase entirely (the dominant Fig. 11 cost), a **miss**
//! DMAs the weight once into the cache partition (one descriptor instead
//! of one per tile pass), and a weight that cannot fit streams exactly
//! as the uncached path does. Residency is a pure DMA-elision: the EXEC
//! numerics consume the same blocks either way, so outputs are
//! bit-identical across all three modes.

use super::conf::{KernelConfig, KernelKind};
use super::dma::{transfer_cycles, DmaStats};
use super::kernels;
use super::lmm::{CacheStats, Lmm, LmmError};
use super::timing::PhaseBreakdown;
use super::ImaxConfig;
use crate::ggml::q3_k::BlockQ3K;
use crate::ggml::q8_0::BlockQ8_0;
use crate::ggml::q8_k::BlockQ8K;
use crate::ggml::tensor::WeightId;
use crate::ggml::{QK8_0, QK_K};
use crate::util::f16::F16;

/// How the weight operand of one offloaded mul_mat reaches the LMM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightResidency {
    /// Streamed transiently, one DMA descriptor per weight tile per
    /// activation tile (the paper's baseline behavior).
    Streamed,
    /// Cache miss: DMA'd once into the cache partition, then read in
    /// place for every tile pass of this and later invocations.
    Inserted,
    /// Cache hit: already resident, no weight DMA at all.
    Resident,
}

/// Bytes of one lane-format weight row of `k` elements (F16 rows pack
/// two halves per 32-bit word, so 2 bytes/element — half the f32 DMA).
pub fn weight_row_bytes(kind: KernelKind, k: usize) -> usize {
    match kind {
        KernelKind::Q8_0 => k / QK8_0 * BlockQ8_0::BYTES,
        KernelKind::Q3K => k / QK_K * BlockQ3K::BYTES,
        KernelKind::F16 => k * 2,
    }
}

/// Bytes of one activation row of `k` elements in the kernel's partner
/// format (Q8_0 → Q8_0, Q3_K → Q8_K, F16 → raw f32: the OP_SML16 kernel
/// keeps activations in f32 so the lane dot stays bit-identical to the
/// host reference — see [`crate::imax::isa::op_sml16`]).
pub fn act_row_bytes(kind: KernelKind, k: usize) -> usize {
    match kind {
        KernelKind::Q8_0 => k / QK8_0 * BlockQ8_0::BYTES,
        KernelKind::Q3K => k / QK_K * (4 + QK_K + 2 * (QK_K / 16)),
        KernelKind::F16 => k * 4,
    }
}

/// Tile geometry for one offloaded mul_mat.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TilePlan {
    /// Weight rows (output features).
    pub m: usize,
    /// Activation rows.
    pub n: usize,
    /// Contraction length.
    pub k: usize,
    /// Activation rows per tile.
    pub a_tile: usize,
    /// Weight rows per tile.
    pub w_tile: usize,
    /// Bytes per weight row.
    pub w_row_bytes: usize,
    /// Bytes per activation row.
    pub a_row_bytes: usize,
}

impl TilePlan {
    /// Build a plan that fits the LMM, or report why it cannot.
    pub fn new(
        imax: &ImaxConfig,
        kind: KernelKind,
        m: usize,
        n: usize,
        k: usize,
    ) -> Result<TilePlan, LmmError> {
        TilePlan::with_capacity(imax.lmm_bytes, kind, m, n, k)
    }

    /// [`TilePlan::new`] over an explicit byte capacity — the cached
    /// execution paths plan over the transient partition only
    /// (`lmm_bytes - cache_budget`), so tile geometry is identical for
    /// cold and warm runs and cycle deltas come purely from LOAD.
    pub fn with_capacity(
        capacity: usize,
        kind: KernelKind,
        m: usize,
        n: usize,
        k: usize,
    ) -> Result<TilePlan, LmmError> {
        assert!(m > 0 && n > 0 && k > 0, "degenerate mul_mat shape");
        let block = match kind {
            KernelKind::Q8_0 => QK8_0,
            KernelKind::Q3K => QK_K,
            KernelKind::F16 => 1,
        };
        assert!(k % block == 0, "K={k} not a multiple of the {kind:?} block");
        let w_row_bytes = weight_row_bytes(kind, k);
        let a_row_bytes = act_row_bytes(kind, k);
        let lmm = capacity;

        // Activations take at most half the LMM; weights + result buffer
        // share the rest. Shrink the activation tile until at least one
        // weight row fits.
        let mut a_tile = (lmm / 2 / a_row_bytes).clamp(1, n.max(1)).min(n);
        loop {
            let a_bytes = a_tile * a_row_bytes;
            if a_bytes <= lmm {
                let rem = lmm - a_bytes;
                let per_w_row = w_row_bytes + a_tile * 4; // row + its results
                if rem >= per_w_row {
                    let w_tile = (rem / per_w_row).min(m);
                    return Ok(TilePlan { m, n, k, a_tile, w_tile, w_row_bytes, a_row_bytes });
                }
            }
            if a_tile == 1 {
                return Err(LmmError::OutOfMemory {
                    requested: a_row_bytes + w_row_bytes + 4,
                    free: lmm,
                    label: "mul_mat tile (K too large for LMM)",
                });
            }
            a_tile /= 2;
        }
    }

    /// Number of activation tiles.
    pub fn a_tiles(&self) -> usize {
        self.n.div_ceil(self.a_tile)
    }

    /// Number of weight tiles.
    pub fn w_tiles(&self) -> usize {
        self.m.div_ceil(self.w_tile)
    }

    /// Bytes of activation rows DMA-loaded (once per row).
    pub fn act_load_bytes(&self) -> u64 {
        (self.n * self.a_row_bytes) as u64
    }

    /// Bytes of the whole weight matrix (one full streaming pass).
    pub fn weight_bytes(&self) -> u64 {
        (self.m * self.w_row_bytes) as u64
    }

    /// Total bytes DMA-loaded when weights stream transiently (they
    /// re-stream once per activation tile).
    pub fn load_bytes(&self) -> u64 {
        self.act_load_bytes() + self.weight_bytes() * self.a_tiles() as u64
    }

    /// Total result bytes drained (f32 outputs).
    pub fn drain_bytes(&self) -> u64 {
        (self.m * self.n * 4) as u64
    }
}

/// Cycles the lane spends executing one (w_rows × a_rows) tile.
fn exec_cycles_tile(kcfg: &KernelConfig, w_rows: usize, a_rows: usize, k: usize) -> u64 {
    let dots = (w_rows * a_rows) as u64;
    let beats = kcfg.beats_for_dot(k);
    // Pipeline fill once per tile, then dots stream back-to-back with a
    // 2-cycle accumulator handoff between consecutive dots.
    kcfg.pipeline_depth as u64 + dots * (beats + 2)
}

/// A single IMAX lane plus its offload state.
pub struct LaneSim {
    /// Physical configuration.
    pub imax: ImaxConfig,
    /// Currently loaded kernel configuration (None = unconfigured).
    configured: Option<KernelKind>,
    /// LMM occupancy model.
    pub lmm: Lmm,
    /// Cumulative DMA statistics.
    pub dma: DmaStats,
    /// Cumulative phase breakdown across all offloads on this lane.
    pub total: PhaseBreakdown,
    /// Activation broadcast elision (see [`LaneSim::set_act_byte_elision`]).
    elide_act_bytes: bool,
}

impl LaneSim {
    /// Fresh lane. The resident weight cache gets
    /// `imax.weight_cache_bytes` of the LMM (clamped to 3/4 of capacity
    /// so transient tiles always keep working room).
    pub fn new(imax: ImaxConfig) -> LaneSim {
        let mut lmm = Lmm::new(imax.lmm_bytes);
        lmm.set_cache_budget(imax.weight_cache_bytes.min(imax.lmm_bytes / 4 * 3));
        LaneSim {
            imax,
            configured: None,
            lmm,
            dma: DmaStats::default(),
            total: PhaseBreakdown::default(),
            elide_act_bytes: false,
        }
    }

    /// Whether the next `kind` kernel needs a CONF phase.
    pub fn needs_conf(&self, kind: KernelKind) -> bool {
        self.configured != Some(kind)
    }

    /// Activation **broadcast elision** for the sharded path: every shard
    /// of one op streams the *same* activation tiles, so the coordinator
    /// models the replicated streams as one coherent DDR-side broadcast —
    /// the op's activation bytes are charged once (on the shard that has
    /// this flag off) and elided on the remaining shards. Only the DMA
    /// *byte* ledgers are gated; transfer cycles are unchanged (each
    /// lane's DMA window is still occupied for the tile's duration), so
    /// phase breakdowns and outputs stay bit-identical with the flag in
    /// either state. Defaults to off; the coordinator toggles it around
    /// each non-primary shard while holding the lane lock.
    pub fn set_act_byte_elision(&mut self, elide: bool) {
        self.elide_act_bytes = elide;
    }

    /// Pin a weight: once resident it is never LRU-evicted. Called by
    /// the plan compiler's prefetch pass for the hottest weights that
    /// fit the cache budget.
    pub fn pin_weight(&mut self, wid: WeightId) {
        self.lmm.cache_pin(wid.0);
    }

    /// Whether a weight is currently resident in this lane's LMM.
    pub fn weight_resident(&self, wid: WeightId) -> bool {
        self.lmm.cache_contains(wid.0)
    }

    /// Snapshot of the lane's residency-cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.lmm.cache_stats()
    }

    /// Closed-form phase breakdown for one offloaded mul_mat, without
    /// touching data. `reconf` forces a CONF phase price.
    pub fn analytic_mul_mat(
        &self,
        kind: KernelKind,
        m: usize,
        n: usize,
        k: usize,
        reconf: bool,
    ) -> Result<PhaseBreakdown, LmmError> {
        self.analytic_mul_mat_with_residency(kind, m, n, k, reconf, WeightResidency::Streamed)
    }

    /// [`LaneSim::analytic_mul_mat`] under an assumed weight residency —
    /// prices warm steps (`Resident`) and first-touch cache fills
    /// (`Inserted`) without executing; those two use the same
    /// transient-partition tile plan the cached functional path uses, so
    /// they agree with it cycle-exactly. `Streamed` prices the
    /// **cache-less baseline** over the full LMM (what the calibrated
    /// device models publish); a functional run on a lane *with* a cache
    /// partition streams through the smaller transient window instead,
    /// so for shapes large enough to tile differently the two `Streamed`
    /// prices legitimately diverge.
    pub fn analytic_mul_mat_with_residency(
        &self,
        kind: KernelKind,
        m: usize,
        n: usize,
        k: usize,
        reconf: bool,
        residency: WeightResidency,
    ) -> Result<PhaseBreakdown, LmmError> {
        let capacity = match residency {
            WeightResidency::Streamed => self.imax.lmm_bytes,
            _ => self.imax.lmm_bytes - self.lmm.cache_budget(),
        };
        let plan = TilePlan::with_capacity(capacity, kind, m, n, k)?;
        let kcfg = KernelConfig::for_kind(kind);
        Ok(breakdown_for_plan_with_residency(&self.imax, &kcfg, &plan, reconf, residency))
    }

    /// Decide how this invocation's weight reaches the LMM, and plan the
    /// tiles accordingly. Every functional execution plans over the
    /// transient partition (`lmm_bytes - cache_budget`) — the space its
    /// allocations actually come from, so a plan that succeeds can never
    /// fail to allocate. With the cache disabled the partition is the
    /// whole LMM and behavior is exactly the paper's baseline; anonymous
    /// weights always stream.
    fn prepare(
        &mut self,
        kind: KernelKind,
        wid: Option<WeightId>,
        m: usize,
        n: usize,
        k: usize,
    ) -> Result<(TilePlan, WeightResidency), LmmError> {
        let transient = self.imax.lmm_bytes - self.lmm.cache_budget();
        let plan = TilePlan::with_capacity(transient, kind, m, n, k)?;
        if let Some(id) = wid {
            if self.lmm.cache_enabled() {
                let w_bytes = m * plan.w_row_bytes;
                let residency = if self.lmm.cache_lookup(id.0, w_bytes) {
                    WeightResidency::Resident
                } else if self.lmm.cache_insert(id.0, w_bytes, "weight cache") {
                    // One whole-matrix DMA fill of the cached copy.
                    self.lmm.record_load_bytes(w_bytes as u64);
                    WeightResidency::Inserted
                } else {
                    WeightResidency::Streamed
                };
                return Ok((plan, residency));
            }
        }
        Ok((plan, WeightResidency::Streamed))
    }

    /// Functional offloaded Q8_0 mul_mat: `w` is `m` rows × `k/32`
    /// blocks, `acts` is `n` rows in the same layout. Returns the `[n, m]`
    /// f32 output (row-major) and the phase breakdown.
    pub fn mul_mat_q8_0(
        &mut self,
        w: &[BlockQ8_0],
        m: usize,
        acts: &[BlockQ8_0],
        n: usize,
        k: usize,
    ) -> Result<(Vec<f32>, PhaseBreakdown), LmmError> {
        self.mul_mat_q8_0_cached(None, w, m, acts, n, k)
    }

    /// [`LaneSim::mul_mat_q8_0`] with a weight identity: resident
    /// weights skip the LOAD phase (see the module docs). Bit-identical
    /// output in every residency mode.
    pub fn mul_mat_q8_0_cached(
        &mut self,
        wid: Option<WeightId>,
        w: &[BlockQ8_0],
        m: usize,
        acts: &[BlockQ8_0],
        n: usize,
        k: usize,
    ) -> Result<(Vec<f32>, PhaseBreakdown), LmmError> {
        let bpr = k / QK8_0;
        assert_eq!(w.len(), m * bpr, "weight block count");
        assert_eq!(acts.len(), n * bpr, "activation block count");
        let (plan, residency) = self.prepare(KernelKind::Q8_0, wid, m, n, k)?;
        let kcfg = KernelConfig::q8_0();
        let reconf = self.needs_conf(KernelKind::Q8_0);

        let mut out = vec![0.0f32; n * m];
        self.walk_tiles(&plan, residency, |wt0, wt1, at0, at1| {
            for a_row in at0..at1 {
                for w_row in wt0..wt1 {
                    let r = kernels::dot_q8_0(
                        &kcfg,
                        &w[w_row * bpr..(w_row + 1) * bpr],
                        &acts[a_row * bpr..(a_row + 1) * bpr],
                    );
                    out[a_row * m + w_row] = r.value;
                }
            }
        });

        let bd = breakdown_for_plan_with_residency(&self.imax, &kcfg, &plan, reconf, residency);
        self.commit(KernelKind::Q8_0, &plan, bd, residency);
        Ok((out, bd))
    }

    /// Functional offloaded Q3_K mul_mat (IMAX-restructured numerics).
    pub fn mul_mat_q3_k(
        &mut self,
        w: &[BlockQ3K],
        m: usize,
        acts: &[BlockQ8K],
        n: usize,
        k: usize,
    ) -> Result<(Vec<f32>, PhaseBreakdown), LmmError> {
        self.mul_mat_q3_k_cached(None, w, m, acts, n, k)
    }

    /// [`LaneSim::mul_mat_q3_k`] with a weight identity (cache-aware).
    pub fn mul_mat_q3_k_cached(
        &mut self,
        wid: Option<WeightId>,
        w: &[BlockQ3K],
        m: usize,
        acts: &[BlockQ8K],
        n: usize,
        k: usize,
    ) -> Result<(Vec<f32>, PhaseBreakdown), LmmError> {
        let bpr = k / QK_K;
        assert_eq!(w.len(), m * bpr, "weight super-block count");
        assert_eq!(acts.len(), n * bpr, "activation super-block count");
        let (plan, residency) = self.prepare(KernelKind::Q3K, wid, m, n, k)?;
        let kcfg = KernelConfig::q3_k();
        let reconf = self.needs_conf(KernelKind::Q3K);

        let mut out = vec![0.0f32; n * m];
        self.walk_tiles(&plan, residency, |wt0, wt1, at0, at1| {
            for a_row in at0..at1 {
                for w_row in wt0..wt1 {
                    let r = kernels::dot_q3_k(
                        &kcfg,
                        &w[w_row * bpr..(w_row + 1) * bpr],
                        &acts[a_row * bpr..(a_row + 1) * bpr],
                    );
                    out[a_row * m + w_row] = r.value;
                }
            }
        });

        let bd = breakdown_for_plan_with_residency(&self.imax, &kcfg, &plan, reconf, residency);
        self.commit(KernelKind::Q3K, &plan, bd, residency);
        Ok((out, bd))
    }

    /// Functional offloaded F16 mul_mat (§VI OP_SML16 kernel): `w` is
    /// `m` rows × `k` halves, `acts` is `n` f32 rows of length `k`.
    pub fn mul_mat_f16(
        &mut self,
        w: &[F16],
        m: usize,
        acts: &[f32],
        n: usize,
        k: usize,
    ) -> Result<(Vec<f32>, PhaseBreakdown), LmmError> {
        self.mul_mat_f16_cached(None, w, m, acts, n, k)
    }

    /// [`LaneSim::mul_mat_f16`] with a weight identity: resident F16
    /// conv weights skip the weight LOAD phase exactly like the
    /// quantized kernels (residency is a pure DMA elision — outputs are
    /// bit-identical to the host `mul_mat` F16 path in every mode).
    pub fn mul_mat_f16_cached(
        &mut self,
        wid: Option<WeightId>,
        w: &[F16],
        m: usize,
        acts: &[f32],
        n: usize,
        k: usize,
    ) -> Result<(Vec<f32>, PhaseBreakdown), LmmError> {
        assert_eq!(w.len(), m * k, "weight element count");
        assert_eq!(acts.len(), n * k, "activation element count");
        let (plan, residency) = self.prepare(KernelKind::F16, wid, m, n, k)?;
        let kcfg = KernelConfig::f16();
        let reconf = self.needs_conf(KernelKind::F16);

        let mut out = vec![0.0f32; n * m];
        self.walk_tiles(&plan, residency, |wt0, wt1, at0, at1| {
            for a_row in at0..at1 {
                for w_row in wt0..wt1 {
                    let r = kernels::dot_f16(
                        &kcfg,
                        &w[w_row * k..(w_row + 1) * k],
                        &acts[a_row * k..(a_row + 1) * k],
                    );
                    out[a_row * m + w_row] = r.value;
                }
            }
        });

        let bd = breakdown_for_plan_with_residency(&self.imax, &kcfg, &plan, reconf, residency);
        self.commit(KernelKind::F16, &plan, bd, residency);
        Ok((out, bd))
    }

    /// Iterate tile pairs in the canonical order (acts outer, weights
    /// inner), exercising the LMM allocator for every pass. Non-streamed
    /// weights are read in place from the cache partition, so no
    /// transient weight region (and no weight LOAD) exists for them.
    fn walk_tiles(
        &mut self,
        plan: &TilePlan,
        residency: WeightResidency,
        mut body: impl FnMut(usize, usize, usize, usize),
    ) {
        let stream_weights = residency == WeightResidency::Streamed;
        let mut at0 = 0;
        while at0 < plan.n {
            let at1 = (at0 + plan.a_tile).min(plan.n);
            let a_region = self
                .lmm
                .alloc((at1 - at0) * plan.a_row_bytes, "acts")
                .expect("plan guarantees the activation tile fits");
            if !self.elide_act_bytes {
                self.lmm.record_load(a_region);
            }
            let mut wt0 = 0;
            while wt0 < plan.m {
                let wt1 = (wt0 + plan.w_tile).min(plan.m);
                let w_region = if stream_weights {
                    let r = self
                        .lmm
                        .alloc((wt1 - wt0) * plan.w_row_bytes, "weights")
                        .expect("plan guarantees the weight tile fits");
                    self.lmm.record_load(r);
                    Some(r)
                } else {
                    None
                };
                let o_region = self
                    .lmm
                    .alloc((wt1 - wt0) * (at1 - at0) * 4, "out")
                    .expect("plan guarantees the output tile fits");
                body(wt0, wt1, at0, at1);
                self.lmm.record_drain((wt1 - wt0) * (at1 - at0) * 4);
                if let Some(r) = w_region {
                    self.lmm.release(r);
                }
                self.lmm.release(o_region);
                wt0 = wt1;
            }
            self.lmm.release(a_region);
            at0 = at1;
        }
    }

    /// Book the finished offload into the lane's cumulative state.
    fn commit(
        &mut self,
        kind: KernelKind,
        plan: &TilePlan,
        bd: PhaseBreakdown,
        residency: WeightResidency,
    ) {
        self.configured = Some(kind);
        self.total += bd;
        let mut load_bytes = match residency {
            WeightResidency::Streamed => plan.load_bytes(),
            WeightResidency::Inserted => plan.act_load_bytes() + plan.weight_bytes(),
            WeightResidency::Resident => plan.act_load_bytes(),
        };
        if self.elide_act_bytes {
            load_bytes -= plan.act_load_bytes();
        }
        self.dma.record_load(load_bytes);
        self.dma.record_drain(plan.drain_bytes());
    }
}

/// Price a tile plan's loops in cycles (the single source of truth for
/// both the analytic and functional paths), weights streamed per pass.
pub fn breakdown_for_plan(
    imax: &ImaxConfig,
    kcfg: &KernelConfig,
    plan: &TilePlan,
    reconf: bool,
) -> PhaseBreakdown {
    breakdown_for_plan_with_residency(imax, kcfg, plan, reconf, WeightResidency::Streamed)
}

/// [`breakdown_for_plan`] under a weight-residency mode: `Resident`
/// omits every weight LOAD, `Inserted` replaces the per-pass weight
/// streams by a single whole-matrix fill. CONF/REGV/RANGE/EXEC/DRAIN are
/// identical across modes — caching only elides DMA.
pub fn breakdown_for_plan_with_residency(
    imax: &ImaxConfig,
    kcfg: &KernelConfig,
    plan: &TilePlan,
    reconf: bool,
    residency: WeightResidency,
) -> PhaseBreakdown {
    let mut bd = PhaseBreakdown::default();
    let pe = kcfg.pe_count() as u64;
    if reconf {
        bd.conf = imax.conf_cycles_per_pe * pe;
    }
    if residency == WeightResidency::Inserted {
        bd.load += transfer_cycles(imax, plan.weight_bytes());
    }

    let mut at0 = 0;
    while at0 < plan.n {
        let at1 = (at0 + plan.a_tile).min(plan.n);
        bd.load += transfer_cycles(imax, ((at1 - at0) * plan.a_row_bytes) as u64);
        let mut wt0 = 0;
        while wt0 < plan.m {
            let wt1 = (wt0 + plan.w_tile).min(plan.m);
            bd.regv += imax.regv_cycles_per_pe * pe;
            bd.range += imax.range_cycles_per_pe * pe;
            if residency == WeightResidency::Streamed {
                bd.load += transfer_cycles(imax, ((wt1 - wt0) * plan.w_row_bytes) as u64);
            }
            bd.exec += exec_cycles_tile(kcfg, wt1 - wt0, at1 - at0, plan.k);
            bd.drain += transfer_cycles(imax, ((wt1 - wt0) * (at1 - at0) * 4) as u64);
            wt0 = wt1;
        }
        at0 = at1;
    }
    bd
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ggml::{q3_k, q8_0, q8_k, DType, Tensor};
    use crate::util::rng::Xoshiro256pp;

    fn random_tensor(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let mut v = vec![0.0f32; rows * cols];
        r.fill_normal(&mut v, 0.7);
        Tensor::f32(rows, cols, v)
    }

    #[test]
    fn plan_small_shapes_single_tile() {
        let imax = ImaxConfig::fpga(1);
        let p = TilePlan::new(&imax, KernelKind::Q8_0, 8, 4, 128).unwrap();
        assert_eq!(p.a_tiles(), 1);
        assert_eq!(p.w_tiles(), 1);
        assert_eq!(p.load_bytes(), (4 * p.a_row_bytes + 8 * p.w_row_bytes) as u64);
        assert_eq!(p.drain_bytes(), 8 * 4 * 4);
    }

    #[test]
    fn plan_tiles_when_acts_exceed_lmm() {
        let imax = ImaxConfig::fpga(1);
        // 4096 act rows of K=320 Q8_0: 4096 * 340 B = 1.36 MB > 512 KiB.
        let p = TilePlan::new(&imax, KernelKind::Q8_0, 320, 4096, 320).unwrap();
        assert!(p.a_tiles() > 1, "{p:?}");
        // Weights must re-stream once per activation tile.
        assert!(p.load_bytes() > (4096 * p.a_row_bytes + 320 * p.w_row_bytes) as u64);
    }

    #[test]
    fn plan_rejects_k_too_large_for_lmm() {
        let mut imax = ImaxConfig::fpga(1);
        imax.lmm_bytes = 1024; // tiny LMM
        let err = TilePlan::new(&imax, KernelKind::Q8_0, 4, 4, 4096).unwrap_err();
        assert!(matches!(err, LmmError::OutOfMemory { .. }));
    }

    #[test]
    fn functional_q8_0_matches_host_mul_mat() {
        let imax = ImaxConfig::fpga(1);
        let (m, n, k) = (5, 3, 128);
        let wt = random_tensor(m, k, 1);
        let xt = random_tensor(n, k, 2);
        let wq = wt.quantize(DType::Q8_0);
        let w_blocks = match &wq.data {
            crate::ggml::tensor::Storage::Q8_0(b) => b.clone(),
            _ => unreachable!(),
        };
        let acts: Vec<_> = (0..n).flat_map(|r| q8_0::quantize_row(xt.row_f32(r))).collect();

        let mut lane = LaneSim::new(imax);
        let (out, bd) = lane.mul_mat_q8_0(&w_blocks, m, &acts, n, k).unwrap();

        // Host reference: ggml mul_mat with the same weight blocks.
        let host = crate::ggml::mul_mat(&wq, &xt, 1);
        for (a, b) in out.iter().zip(host.as_f32().iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "sim vs host mul_mat");
        }
        assert!(bd.exec > 0 && bd.load > 0 && bd.drain > 0);
        assert_eq!(bd.conf, 46 * lane.imax.conf_cycles_per_pe);
    }

    #[test]
    fn functional_q3_k_matches_imax5_reference() {
        let imax = ImaxConfig::fpga(1);
        let (m, n, k) = (4, 2, 512);
        let wt = random_tensor(m, k, 3);
        let xt = random_tensor(n, k, 4);
        let w_blocks: Vec<_> = (0..m).flat_map(|r| q3_k::quantize_row(wt.row_f32(r))).collect();
        let acts: Vec<_> = (0..n).flat_map(|r| q8_k::quantize_row(xt.row_f32(r))).collect();

        let mut lane = LaneSim::new(imax);
        let (out, _) = lane.mul_mat_q3_k(&w_blocks, m, &acts, n, k).unwrap();

        let bpr = k / 256;
        for a_row in 0..n {
            for w_row in 0..m {
                let want = q3_k::vec_dot_imax5(
                    &w_blocks[w_row * bpr..(w_row + 1) * bpr],
                    &acts[a_row * bpr..(a_row + 1) * bpr],
                );
                assert_eq!(out[a_row * m + w_row].to_bits(), want.to_bits());
            }
        }
    }

    #[test]
    fn functional_dma_volume_matches_plan() {
        let imax = ImaxConfig::fpga(1);
        let (m, n, k) = (6, 4, 256);
        let wt = random_tensor(m, k, 5);
        let xt = random_tensor(n, k, 6);
        let w_blocks: Vec<_> = (0..m).flat_map(|r| q8_0::quantize_row(wt.row_f32(r))).collect();
        let acts: Vec<_> = (0..n).flat_map(|r| q8_0::quantize_row(xt.row_f32(r))).collect();
        let plan = TilePlan::new(&imax, KernelKind::Q8_0, m, n, k).unwrap();

        let mut lane = LaneSim::new(imax);
        lane.mul_mat_q8_0(&w_blocks, m, &acts, n, k).unwrap();
        assert_eq!(lane.lmm.loaded_bytes, plan.load_bytes());
        assert_eq!(lane.lmm.drained_bytes, plan.drain_bytes());
    }

    #[test]
    fn act_byte_elision_gates_bytes_not_cycles_or_output() {
        let imax = ImaxConfig::fpga(1);
        let (m, n, k) = (6, 4, 256);
        let wt = random_tensor(m, k, 31);
        let xt = random_tensor(n, k, 32);
        let w_blocks: Vec<_> = (0..m).flat_map(|r| q8_0::quantize_row(wt.row_f32(r))).collect();
        let acts: Vec<_> = (0..n).flat_map(|r| q8_0::quantize_row(xt.row_f32(r))).collect();

        let mut plain = LaneSim::new(imax.clone());
        let (want, bd_plain) = plain.mul_mat_q8_0(&w_blocks, m, &acts, n, k).unwrap();

        let mut elided = LaneSim::new(imax);
        elided.set_act_byte_elision(true);
        let (out, bd) = elided.mul_mat_q8_0(&w_blocks, m, &acts, n, k).unwrap();
        elided.set_act_byte_elision(false);

        for (a, b) in out.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits(), "elision never touches numerics");
        }
        assert_eq!(bd, bd_plain, "elision never touches cycles");
        let plan = TilePlan::with_capacity(
            elided.imax.lmm_bytes - elided.lmm.cache_budget(),
            KernelKind::Q8_0,
            m,
            n,
            k,
        )
        .unwrap();
        assert_eq!(
            plain.lmm.loaded_bytes - elided.lmm.loaded_bytes,
            plan.act_load_bytes(),
            "exactly the activation bytes are elided"
        );
        assert_eq!(
            plain.dma.load_bytes - elided.dma.load_bytes,
            plan.act_load_bytes(),
            "the DMA ledger agrees"
        );
    }

    #[test]
    fn analytic_equals_functional_breakdown() {
        let imax = ImaxConfig::fpga(1);
        let (m, n, k) = (7, 5, 256);
        let wt = random_tensor(m, k, 7);
        let xt = random_tensor(n, k, 8);
        let w_blocks: Vec<_> = (0..m).flat_map(|r| q8_0::quantize_row(wt.row_f32(r))).collect();
        let acts: Vec<_> = (0..n).flat_map(|r| q8_0::quantize_row(xt.row_f32(r))).collect();

        let mut lane = LaneSim::new(imax.clone());
        let analytic = lane.analytic_mul_mat(KernelKind::Q8_0, m, n, k, true).unwrap();
        let (_, functional) = lane.mul_mat_q8_0(&w_blocks, m, &acts, n, k).unwrap();
        assert_eq!(analytic, functional, "modes must agree cycle-exactly");
    }

    #[test]
    fn conf_charged_only_on_kernel_switch() {
        let imax = ImaxConfig::fpga(1);
        let mut lane = LaneSim::new(imax);
        let (m, n, k) = (2, 2, 256);
        let wt = random_tensor(m, k, 9);
        let xt = random_tensor(n, k, 10);
        let w8: Vec<_> = (0..m).flat_map(|r| q8_0::quantize_row(wt.row_f32(r))).collect();
        let a8: Vec<_> = (0..n).flat_map(|r| q8_0::quantize_row(xt.row_f32(r))).collect();
        let (_, bd1) = lane.mul_mat_q8_0(&w8, m, &a8, n, k).unwrap();
        assert!(bd1.conf > 0, "first run configures");
        let (_, bd2) = lane.mul_mat_q8_0(&w8, m, &a8, n, k).unwrap();
        assert_eq!(bd2.conf, 0, "same kernel stays configured");
        let w3: Vec<_> = (0..m).flat_map(|r| q3_k::quantize_row(wt.row_f32(r))).collect();
        let a3: Vec<_> = (0..n).flat_map(|r| q8_k::quantize_row(xt.row_f32(r))).collect();
        let (_, bd3) = lane.mul_mat_q3_k(&w3, m, &a3, n, k).unwrap();
        assert!(bd3.conf > 0, "kernel switch reconfigures");
    }

    #[test]
    fn asic_same_cycles_less_time() {
        let (m, n, k) = (8, 8, 512);
        let fpga = LaneSim::new(ImaxConfig::fpga(1));
        let asic = LaneSim::new(ImaxConfig::asic(1));
        let b_f = fpga.analytic_mul_mat(KernelKind::Q3K, m, n, k, true).unwrap();
        let b_a = asic.analytic_mul_mat(KernelKind::Q3K, m, n, k, true).unwrap();
        assert_eq!(b_f, b_a, "same microarchitecture, same cycles");
        let t_f = b_f.seconds(fpga.imax.clock_hz).total();
        let t_a = b_a.seconds(asic.imax.clock_hz).total();
        assert!((t_f / t_a - 840.0 / 145.0).abs() < 1e-9);
    }

    #[test]
    fn cached_weight_skips_load_on_warm_call_bit_exactly() {
        let imax = ImaxConfig::fpga(1);
        let (m, n, k) = (8, 4, 256);
        let wt = random_tensor(m, k, 21);
        let xt = random_tensor(n, k, 22);
        let w_blocks: Vec<_> = (0..m).flat_map(|r| q8_0::quantize_row(wt.row_f32(r))).collect();
        let acts: Vec<_> = (0..n).flat_map(|r| q8_0::quantize_row(xt.row_f32(r))).collect();
        let wid = Some(crate::ggml::WeightId(0xCAFE));

        let mut plain = LaneSim::new(imax.clone());
        let (want, _) = plain.mul_mat_q8_0(&w_blocks, m, &acts, n, k).unwrap();

        let mut lane = LaneSim::new(imax);
        let (cold_out, cold) = lane.mul_mat_q8_0_cached(wid, &w_blocks, m, &acts, n, k).unwrap();
        let loaded_after_cold = lane.lmm.loaded_bytes;
        let (warm_out, warm) = lane.mul_mat_q8_0_cached(wid, &w_blocks, m, &acts, n, k).unwrap();

        for (a, b) in cold_out.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits(), "cold cached == uncached");
        }
        for (a, b) in warm_out.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits(), "warm cached == uncached");
        }
        assert!(warm.load < cold.load, "resident weight skips LOAD: {warm:?} vs {cold:?}");
        assert_eq!(warm.exec, cold.exec, "EXEC is residency-independent");
        assert_eq!(warm.drain, cold.drain);
        let plan = TilePlan::with_capacity(
            lane.imax.lmm_bytes - lane.lmm.cache_budget(),
            KernelKind::Q8_0,
            m,
            n,
            k,
        )
        .unwrap();
        assert_eq!(
            lane.lmm.loaded_bytes - loaded_after_cold,
            plan.act_load_bytes(),
            "warm call DMAs activations only"
        );
        let s = lane.cache_stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!(lane.weight_resident(crate::ggml::WeightId(0xCAFE)));
    }

    #[test]
    fn cache_disabled_restores_streaming_behavior() {
        let mut imax = ImaxConfig::fpga(1);
        imax.weight_cache_bytes = 0;
        let (m, n, k) = (4, 3, 128);
        let wt = random_tensor(m, k, 23);
        let xt = random_tensor(n, k, 24);
        let w_blocks: Vec<_> = (0..m).flat_map(|r| q8_0::quantize_row(wt.row_f32(r))).collect();
        let acts: Vec<_> = (0..n).flat_map(|r| q8_0::quantize_row(xt.row_f32(r))).collect();
        let mut a = LaneSim::new(imax.clone());
        let (_, bd_plain) = a.mul_mat_q8_0(&w_blocks, m, &acts, n, k).unwrap();
        let mut b = LaneSim::new(imax);
        let (_, bd_tagged) = b
            .mul_mat_q8_0_cached(Some(crate::ggml::WeightId(9)), &w_blocks, m, &acts, n, k)
            .unwrap();
        assert_eq!(bd_plain, bd_tagged, "no cache partition => identical pricing");
        assert_eq!(b.cache_stats().hits + b.cache_stats().misses, 0);
    }

    #[test]
    fn analytic_warm_matches_functional_warm_q3_k() {
        let imax = ImaxConfig::fpga(1);
        let (m, n, k) = (6, 3, 512);
        let wt = random_tensor(m, k, 25);
        let xt = random_tensor(n, k, 26);
        let w_blocks: Vec<_> = (0..m).flat_map(|r| q3_k::quantize_row(wt.row_f32(r))).collect();
        let acts: Vec<_> = (0..n).flat_map(|r| q8_k::quantize_row(xt.row_f32(r))).collect();
        let wid = Some(crate::ggml::WeightId(7));
        let mut lane = LaneSim::new(imax);
        let (_, cold) = lane.mul_mat_q3_k_cached(wid, &w_blocks, m, &acts, n, k).unwrap();
        let (_, warm) = lane.mul_mat_q3_k_cached(wid, &w_blocks, m, &acts, n, k).unwrap();
        let analytic_warm = lane
            .analytic_mul_mat_with_residency(
                KernelKind::Q3K,
                m,
                n,
                k,
                false,
                WeightResidency::Resident,
            )
            .unwrap();
        assert_eq!(warm, analytic_warm, "warm functional == warm analytic");
        let analytic_cold = lane
            .analytic_mul_mat_with_residency(
                KernelKind::Q3K,
                m,
                n,
                k,
                true,
                WeightResidency::Inserted,
            )
            .unwrap();
        assert_eq!(cold, analytic_cold, "cold cached functional == Inserted analytic");
    }

    #[test]
    fn functional_f16_matches_host_mul_mat() {
        let imax = ImaxConfig::fpga(1);
        // Conv-like shape: K = cin·k·k = 2·9, odd M, N = oh·ow.
        let (m, n, k) = (5, 9, 18);
        let wt = random_tensor(m, k, 41);
        let xt = random_tensor(n, k, 42);
        let wq = wt.quantize(DType::F16);
        let w_halves = match &wq.data {
            crate::ggml::tensor::Storage::F16(v) => v.clone(),
            _ => unreachable!(),
        };
        let mut lane = LaneSim::new(imax);
        let (out, bd) = lane.mul_mat_f16(&w_halves, m, xt.as_f32(), n, k).unwrap();
        let host = crate::ggml::mul_mat(&wq, &xt, 1);
        for (a, b) in out.iter().zip(host.as_f32().iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "sim vs host F16 mul_mat");
        }
        assert!(bd.exec > 0 && bd.load > 0 && bd.drain > 0);
        assert_eq!(bd.conf, 46 * lane.imax.conf_cycles_per_pe);
    }

    #[test]
    fn cached_f16_weight_skips_load_warm_bit_exactly() {
        let imax = ImaxConfig::fpga(1);
        let (m, n, k) = (8, 6, 144); // cin=16, 3×3 conv row
        let wt = random_tensor(m, k, 43);
        let xt = random_tensor(n, k, 44);
        let wq = wt.quantize(DType::F16);
        let w_halves = match &wq.data {
            crate::ggml::tensor::Storage::F16(v) => v.clone(),
            _ => unreachable!(),
        };
        let wid = Some(crate::ggml::WeightId(0xF16));

        let mut plain = LaneSim::new(imax.clone());
        let (want, _) = plain.mul_mat_f16(&w_halves, m, xt.as_f32(), n, k).unwrap();

        let mut lane = LaneSim::new(imax);
        let (cold_out, cold) =
            lane.mul_mat_f16_cached(wid, &w_halves, m, xt.as_f32(), n, k).unwrap();
        let loaded_after_cold = lane.lmm.loaded_bytes;
        let (warm_out, warm) =
            lane.mul_mat_f16_cached(wid, &w_halves, m, xt.as_f32(), n, k).unwrap();
        for (a, b) in cold_out.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits(), "cold cached == uncached");
        }
        for (a, b) in warm_out.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits(), "warm cached == uncached");
        }
        assert!(warm.load < cold.load, "resident F16 weight skips LOAD");
        assert_eq!(warm.exec, cold.exec);
        assert_eq!(warm.drain, cold.drain);
        let plan = TilePlan::with_capacity(
            lane.imax.lmm_bytes - lane.lmm.cache_budget(),
            KernelKind::F16,
            m,
            n,
            k,
        )
        .unwrap();
        assert_eq!(
            lane.lmm.loaded_bytes - loaded_after_cold,
            plan.act_load_bytes(),
            "warm F16 call DMAs activations only"
        );
        assert!(lane.weight_resident(crate::ggml::WeightId(0xF16)));
    }

    #[test]
    fn analytic_warm_matches_functional_warm_f16() {
        let imax = ImaxConfig::fpga(1);
        let (m, n, k) = (4, 3, 36);
        let wt = random_tensor(m, k, 45);
        let xt = random_tensor(n, k, 46);
        let w_halves: Vec<F16> =
            wt.as_f32().iter().map(|&v| F16::from_f32(v)).collect();
        let wid = Some(crate::ggml::WeightId(77));
        let mut lane = LaneSim::new(imax);
        let (_, cold) = lane.mul_mat_f16_cached(wid, &w_halves, m, xt.as_f32(), n, k).unwrap();
        let (_, warm) = lane.mul_mat_f16_cached(wid, &w_halves, m, xt.as_f32(), n, k).unwrap();
        let analytic_warm = lane
            .analytic_mul_mat_with_residency(
                KernelKind::F16,
                m,
                n,
                k,
                false,
                WeightResidency::Resident,
            )
            .unwrap();
        assert_eq!(warm, analytic_warm, "warm functional == warm analytic");
        let analytic_cold = lane
            .analytic_mul_mat_with_residency(
                KernelKind::F16,
                m,
                n,
                k,
                true,
                WeightResidency::Inserted,
            )
            .unwrap();
        assert_eq!(cold, analytic_cold, "cold cached functional == Inserted analytic");
    }

    #[test]
    fn f16_row_bytes_model() {
        assert_eq!(weight_row_bytes(KernelKind::F16, 1152), 2304, "2 B per half");
        assert_eq!(act_row_bytes(KernelKind::F16, 1152), 4608, "acts stay f32");
        // Odd K is legal for the F16 kernel (block size 1).
        let p = TilePlan::new(&ImaxConfig::fpga(1), KernelKind::F16, 3, 2, 17).unwrap();
        assert_eq!(p.w_row_bytes, 34);
        assert_eq!(p.a_row_bytes, 68);
    }

    #[test]
    fn q8_0_loads_more_bytes_than_q3_k_same_shape() {
        let imax = ImaxConfig::fpga(1);
        let (m, n, k) = (64, 32, 1024);
        let p8 = TilePlan::new(&imax, KernelKind::Q8_0, m, n, k).unwrap();
        let p3 = TilePlan::new(&imax, KernelKind::Q3K, m, n, k).unwrap();
        assert!(
            p8.load_bytes() > p3.load_bytes(),
            "paper §IV-B: Q8_0 moves more data ({} vs {})",
            p8.load_bytes(),
            p3.load_bytes()
        );
    }
}
