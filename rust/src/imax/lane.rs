//! Lane-level offload simulation: tiling, phase timing, and functional
//! matrix-multiply execution.
//!
//! One offloaded `mul_mat` (weights `[M, K]` quantized, activations
//! `[N, K]` pre-quantized by the host) runs as:
//!
//! ```text
//! CONF            once per kernel switch (write 46/51 PE configs)
//! for each activation tile (rows that fit half the LMM):
//!     LOAD acts   one DMA descriptor
//!     for each weight tile:
//!         REGV/RANGE   per-pass register & address setup
//!         LOAD weights one DMA descriptor
//!         EXEC         w_tile × a_tile dots, beats from the kernel config
//!         DRAIN        f32 results, one descriptor
//! ```
//!
//! [`TilePlan`] fixes the tile geometry from the LMM capacity;
//! [`LaneSim::analytic_mul_mat`] prices the loops in closed form and
//! [`LaneSim::mul_mat_q8_0`]/[`LaneSim::mul_mat_q3_k`] execute the same
//! loops functionally (numerics via [`super::kernels`]), so a property
//! test can require cycle-exact agreement between the two modes.

use super::conf::{KernelConfig, KernelKind};
use super::dma::{transfer_cycles, DmaStats};
use super::kernels;
use super::lmm::{Lmm, LmmError};
use super::timing::PhaseBreakdown;
use super::ImaxConfig;
use crate::ggml::q3_k::BlockQ3K;
use crate::ggml::q8_0::BlockQ8_0;
use crate::ggml::q8_k::BlockQ8K;
use crate::ggml::{QK8_0, QK_K};

/// Bytes of one quantized weight row of `k` elements.
pub fn weight_row_bytes(kind: KernelKind, k: usize) -> usize {
    match kind {
        KernelKind::Q8_0 => k / QK8_0 * BlockQ8_0::BYTES,
        KernelKind::Q3K => k / QK_K * BlockQ3K::BYTES,
    }
}

/// Bytes of one quantized activation row of `k` elements (the vec-dot
/// partner format: Q8_0 → Q8_0, Q3_K → Q8_K).
pub fn act_row_bytes(kind: KernelKind, k: usize) -> usize {
    match kind {
        KernelKind::Q8_0 => k / QK8_0 * BlockQ8_0::BYTES,
        KernelKind::Q3K => k / QK_K * (4 + QK_K + 2 * (QK_K / 16)),
    }
}

/// Tile geometry for one offloaded mul_mat.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TilePlan {
    /// Weight rows (output features).
    pub m: usize,
    /// Activation rows.
    pub n: usize,
    /// Contraction length.
    pub k: usize,
    /// Activation rows per tile.
    pub a_tile: usize,
    /// Weight rows per tile.
    pub w_tile: usize,
    /// Bytes per weight row.
    pub w_row_bytes: usize,
    /// Bytes per activation row.
    pub a_row_bytes: usize,
}

impl TilePlan {
    /// Build a plan that fits the LMM, or report why it cannot.
    pub fn new(
        imax: &ImaxConfig,
        kind: KernelKind,
        m: usize,
        n: usize,
        k: usize,
    ) -> Result<TilePlan, LmmError> {
        assert!(m > 0 && n > 0 && k > 0, "degenerate mul_mat shape");
        let block = match kind {
            KernelKind::Q8_0 => QK8_0,
            KernelKind::Q3K => QK_K,
        };
        assert!(k % block == 0, "K={k} not a multiple of the {kind:?} block");
        let w_row_bytes = weight_row_bytes(kind, k);
        let a_row_bytes = act_row_bytes(kind, k);
        let lmm = imax.lmm_bytes;

        // Activations take at most half the LMM; weights + result buffer
        // share the rest. Shrink the activation tile until at least one
        // weight row fits.
        let mut a_tile = (lmm / 2 / a_row_bytes).clamp(1, n.max(1)).min(n);
        loop {
            let a_bytes = a_tile * a_row_bytes;
            if a_bytes <= lmm {
                let rem = lmm - a_bytes;
                let per_w_row = w_row_bytes + a_tile * 4; // row + its results
                if rem >= per_w_row {
                    let w_tile = (rem / per_w_row).min(m);
                    return Ok(TilePlan { m, n, k, a_tile, w_tile, w_row_bytes, a_row_bytes });
                }
            }
            if a_tile == 1 {
                return Err(LmmError::OutOfMemory {
                    requested: a_row_bytes + w_row_bytes + 4,
                    free: lmm,
                    label: "mul_mat tile (K too large for LMM)",
                });
            }
            a_tile /= 2;
        }
    }

    /// Number of activation tiles.
    pub fn a_tiles(&self) -> usize {
        self.n.div_ceil(self.a_tile)
    }

    /// Number of weight tiles.
    pub fn w_tiles(&self) -> usize {
        self.m.div_ceil(self.w_tile)
    }

    /// Total bytes DMA-loaded (weights re-stream once per activation tile).
    pub fn load_bytes(&self) -> u64 {
        let acts = (self.n * self.a_row_bytes) as u64;
        let weights_once = (self.m * self.w_row_bytes) as u64;
        acts + weights_once * self.a_tiles() as u64
    }

    /// Total result bytes drained (f32 outputs).
    pub fn drain_bytes(&self) -> u64 {
        (self.m * self.n * 4) as u64
    }
}

/// Cycles the lane spends executing one (w_rows × a_rows) tile.
fn exec_cycles_tile(kcfg: &KernelConfig, w_rows: usize, a_rows: usize, k: usize) -> u64 {
    let dots = (w_rows * a_rows) as u64;
    let beats = kcfg.beats_for_dot(k);
    // Pipeline fill once per tile, then dots stream back-to-back with a
    // 2-cycle accumulator handoff between consecutive dots.
    kcfg.pipeline_depth as u64 + dots * (beats + 2)
}

/// A single IMAX lane plus its offload state.
pub struct LaneSim {
    /// Physical configuration.
    pub imax: ImaxConfig,
    /// Currently loaded kernel configuration (None = unconfigured).
    configured: Option<KernelKind>,
    /// LMM occupancy model.
    pub lmm: Lmm,
    /// Cumulative DMA statistics.
    pub dma: DmaStats,
    /// Cumulative phase breakdown across all offloads on this lane.
    pub total: PhaseBreakdown,
}

impl LaneSim {
    /// Fresh lane.
    pub fn new(imax: ImaxConfig) -> LaneSim {
        let lmm = Lmm::new(imax.lmm_bytes);
        LaneSim { imax, configured: None, lmm, dma: DmaStats::default(), total: PhaseBreakdown::default() }
    }

    /// Whether the next `kind` kernel needs a CONF phase.
    pub fn needs_conf(&self, kind: KernelKind) -> bool {
        self.configured != Some(kind)
    }

    /// Closed-form phase breakdown for one offloaded mul_mat, without
    /// touching data. `reconf` forces a CONF phase price.
    pub fn analytic_mul_mat(
        &self,
        kind: KernelKind,
        m: usize,
        n: usize,
        k: usize,
        reconf: bool,
    ) -> Result<PhaseBreakdown, LmmError> {
        let plan = TilePlan::new(&self.imax, kind, m, n, k)?;
        let kcfg = KernelConfig::for_kind(kind);
        Ok(breakdown_for_plan(&self.imax, &kcfg, &plan, reconf))
    }

    /// Functional offloaded Q8_0 mul_mat: `w` is `m` rows × `k/32`
    /// blocks, `acts` is `n` rows in the same layout. Returns the `[n, m]`
    /// f32 output (row-major) and the phase breakdown.
    pub fn mul_mat_q8_0(
        &mut self,
        w: &[BlockQ8_0],
        m: usize,
        acts: &[BlockQ8_0],
        n: usize,
        k: usize,
    ) -> Result<(Vec<f32>, PhaseBreakdown), LmmError> {
        let bpr = k / QK8_0;
        assert_eq!(w.len(), m * bpr, "weight block count");
        assert_eq!(acts.len(), n * bpr, "activation block count");
        let plan = TilePlan::new(&self.imax, KernelKind::Q8_0, m, n, k)?;
        let kcfg = KernelConfig::q8_0();
        let reconf = self.needs_conf(KernelKind::Q8_0);

        let mut out = vec![0.0f32; n * m];
        self.walk_tiles(&plan, |wt0, wt1, at0, at1| {
            for a_row in at0..at1 {
                for w_row in wt0..wt1 {
                    let r = kernels::dot_q8_0(
                        &kcfg,
                        &w[w_row * bpr..(w_row + 1) * bpr],
                        &acts[a_row * bpr..(a_row + 1) * bpr],
                    );
                    out[a_row * m + w_row] = r.value;
                }
            }
        });

        let bd = breakdown_for_plan(&self.imax, &kcfg, &plan, reconf);
        self.commit(KernelKind::Q8_0, &plan, bd);
        Ok((out, bd))
    }

    /// Functional offloaded Q3_K mul_mat (IMAX-restructured numerics).
    pub fn mul_mat_q3_k(
        &mut self,
        w: &[BlockQ3K],
        m: usize,
        acts: &[BlockQ8K],
        n: usize,
        k: usize,
    ) -> Result<(Vec<f32>, PhaseBreakdown), LmmError> {
        let bpr = k / QK_K;
        assert_eq!(w.len(), m * bpr, "weight super-block count");
        assert_eq!(acts.len(), n * bpr, "activation super-block count");
        let plan = TilePlan::new(&self.imax, KernelKind::Q3K, m, n, k)?;
        let kcfg = KernelConfig::q3_k();
        let reconf = self.needs_conf(KernelKind::Q3K);

        let mut out = vec![0.0f32; n * m];
        self.walk_tiles(&plan, |wt0, wt1, at0, at1| {
            for a_row in at0..at1 {
                for w_row in wt0..wt1 {
                    let r = kernels::dot_q3_k(
                        &kcfg,
                        &w[w_row * bpr..(w_row + 1) * bpr],
                        &acts[a_row * bpr..(a_row + 1) * bpr],
                    );
                    out[a_row * m + w_row] = r.value;
                }
            }
        });

        let bd = breakdown_for_plan(&self.imax, &kcfg, &plan, reconf);
        self.commit(KernelKind::Q3K, &plan, bd);
        Ok((out, bd))
    }

    /// Iterate tile pairs in the canonical order (acts outer, weights
    /// inner), exercising the LMM allocator for every pass.
    fn walk_tiles(&mut self, plan: &TilePlan, mut body: impl FnMut(usize, usize, usize, usize)) {
        let mut at0 = 0;
        while at0 < plan.n {
            let at1 = (at0 + plan.a_tile).min(plan.n);
            let a_region = self
                .lmm
                .alloc((at1 - at0) * plan.a_row_bytes, "acts")
                .expect("plan guarantees the activation tile fits");
            self.lmm.record_load(a_region);
            let mut wt0 = 0;
            while wt0 < plan.m {
                let wt1 = (wt0 + plan.w_tile).min(plan.m);
                let w_region = self
                    .lmm
                    .alloc((wt1 - wt0) * plan.w_row_bytes, "weights")
                    .expect("plan guarantees the weight tile fits");
                let o_region = self
                    .lmm
                    .alloc((wt1 - wt0) * (at1 - at0) * 4, "out")
                    .expect("plan guarantees the output tile fits");
                self.lmm.record_load(w_region);
                body(wt0, wt1, at0, at1);
                self.lmm.record_drain((wt1 - wt0) * (at1 - at0) * 4);
                self.lmm.release(w_region);
                self.lmm.release(o_region);
                wt0 = wt1;
            }
            self.lmm.release(a_region);
            at0 = at1;
        }
    }

    /// Book the finished offload into the lane's cumulative state.
    fn commit(&mut self, kind: KernelKind, plan: &TilePlan, bd: PhaseBreakdown) {
        self.configured = Some(kind);
        self.total += bd;
        self.dma.record_load(plan.load_bytes());
        self.dma.record_drain(plan.drain_bytes());
    }
}

/// Price a tile plan's loops in cycles (the single source of truth for
/// both the analytic and functional paths).
pub fn breakdown_for_plan(
    imax: &ImaxConfig,
    kcfg: &KernelConfig,
    plan: &TilePlan,
    reconf: bool,
) -> PhaseBreakdown {
    let mut bd = PhaseBreakdown::default();
    let pe = kcfg.pe_count() as u64;
    if reconf {
        bd.conf = imax.conf_cycles_per_pe * pe;
    }

    let mut at0 = 0;
    while at0 < plan.n {
        let at1 = (at0 + plan.a_tile).min(plan.n);
        bd.load += transfer_cycles(imax, ((at1 - at0) * plan.a_row_bytes) as u64);
        let mut wt0 = 0;
        while wt0 < plan.m {
            let wt1 = (wt0 + plan.w_tile).min(plan.m);
            bd.regv += imax.regv_cycles_per_pe * pe;
            bd.range += imax.range_cycles_per_pe * pe;
            bd.load += transfer_cycles(imax, ((wt1 - wt0) * plan.w_row_bytes) as u64);
            bd.exec += exec_cycles_tile(kcfg, wt1 - wt0, at1 - at0, plan.k);
            bd.drain += transfer_cycles(imax, ((wt1 - wt0) * (at1 - at0) * 4) as u64);
            wt0 = wt1;
        }
        at0 = at1;
    }
    bd
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ggml::{q3_k, q8_0, q8_k, DType, Tensor};
    use crate::util::rng::Xoshiro256pp;

    fn random_tensor(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let mut v = vec![0.0f32; rows * cols];
        r.fill_normal(&mut v, 0.7);
        Tensor::f32(rows, cols, v)
    }

    #[test]
    fn plan_small_shapes_single_tile() {
        let imax = ImaxConfig::fpga(1);
        let p = TilePlan::new(&imax, KernelKind::Q8_0, 8, 4, 128).unwrap();
        assert_eq!(p.a_tiles(), 1);
        assert_eq!(p.w_tiles(), 1);
        assert_eq!(p.load_bytes(), (4 * p.a_row_bytes + 8 * p.w_row_bytes) as u64);
        assert_eq!(p.drain_bytes(), 8 * 4 * 4);
    }

    #[test]
    fn plan_tiles_when_acts_exceed_lmm() {
        let imax = ImaxConfig::fpga(1);
        // 4096 act rows of K=320 Q8_0: 4096 * 340 B = 1.36 MB > 512 KiB.
        let p = TilePlan::new(&imax, KernelKind::Q8_0, 320, 4096, 320).unwrap();
        assert!(p.a_tiles() > 1, "{p:?}");
        // Weights must re-stream once per activation tile.
        assert!(p.load_bytes() > (4096 * p.a_row_bytes + 320 * p.w_row_bytes) as u64);
    }

    #[test]
    fn plan_rejects_k_too_large_for_lmm() {
        let mut imax = ImaxConfig::fpga(1);
        imax.lmm_bytes = 1024; // tiny LMM
        let err = TilePlan::new(&imax, KernelKind::Q8_0, 4, 4, 4096).unwrap_err();
        assert!(matches!(err, LmmError::OutOfMemory { .. }));
    }

    #[test]
    fn functional_q8_0_matches_host_mul_mat() {
        let imax = ImaxConfig::fpga(1);
        let (m, n, k) = (5, 3, 128);
        let wt = random_tensor(m, k, 1);
        let xt = random_tensor(n, k, 2);
        let wq = wt.quantize(DType::Q8_0);
        let w_blocks = match &wq.data {
            crate::ggml::tensor::Storage::Q8_0(b) => b.clone(),
            _ => unreachable!(),
        };
        let acts: Vec<_> = (0..n).flat_map(|r| q8_0::quantize_row(xt.row_f32(r))).collect();

        let mut lane = LaneSim::new(imax);
        let (out, bd) = lane.mul_mat_q8_0(&w_blocks, m, &acts, n, k).unwrap();

        // Host reference: ggml mul_mat with the same weight blocks.
        let host = crate::ggml::mul_mat(&wq, &xt, 1);
        for (a, b) in out.iter().zip(host.as_f32().iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "sim vs host mul_mat");
        }
        assert!(bd.exec > 0 && bd.load > 0 && bd.drain > 0);
        assert_eq!(bd.conf, 46 * lane.imax.conf_cycles_per_pe);
    }

    #[test]
    fn functional_q3_k_matches_imax5_reference() {
        let imax = ImaxConfig::fpga(1);
        let (m, n, k) = (4, 2, 512);
        let wt = random_tensor(m, k, 3);
        let xt = random_tensor(n, k, 4);
        let w_blocks: Vec<_> = (0..m).flat_map(|r| q3_k::quantize_row(wt.row_f32(r))).collect();
        let acts: Vec<_> = (0..n).flat_map(|r| q8_k::quantize_row(xt.row_f32(r))).collect();

        let mut lane = LaneSim::new(imax);
        let (out, _) = lane.mul_mat_q3_k(&w_blocks, m, &acts, n, k).unwrap();

        let bpr = k / 256;
        for a_row in 0..n {
            for w_row in 0..m {
                let want = q3_k::vec_dot_imax5(
                    &w_blocks[w_row * bpr..(w_row + 1) * bpr],
                    &acts[a_row * bpr..(a_row + 1) * bpr],
                );
                assert_eq!(out[a_row * m + w_row].to_bits(), want.to_bits());
            }
        }
    }

    #[test]
    fn functional_dma_volume_matches_plan() {
        let imax = ImaxConfig::fpga(1);
        let (m, n, k) = (6, 4, 256);
        let wt = random_tensor(m, k, 5);
        let xt = random_tensor(n, k, 6);
        let w_blocks: Vec<_> = (0..m).flat_map(|r| q8_0::quantize_row(wt.row_f32(r))).collect();
        let acts: Vec<_> = (0..n).flat_map(|r| q8_0::quantize_row(xt.row_f32(r))).collect();
        let plan = TilePlan::new(&imax, KernelKind::Q8_0, m, n, k).unwrap();

        let mut lane = LaneSim::new(imax);
        lane.mul_mat_q8_0(&w_blocks, m, &acts, n, k).unwrap();
        assert_eq!(lane.lmm.loaded_bytes, plan.load_bytes());
        assert_eq!(lane.lmm.drained_bytes, plan.drain_bytes());
    }

    #[test]
    fn analytic_equals_functional_breakdown() {
        let imax = ImaxConfig::fpga(1);
        let (m, n, k) = (7, 5, 256);
        let wt = random_tensor(m, k, 7);
        let xt = random_tensor(n, k, 8);
        let w_blocks: Vec<_> = (0..m).flat_map(|r| q8_0::quantize_row(wt.row_f32(r))).collect();
        let acts: Vec<_> = (0..n).flat_map(|r| q8_0::quantize_row(xt.row_f32(r))).collect();

        let mut lane = LaneSim::new(imax.clone());
        let analytic = lane.analytic_mul_mat(KernelKind::Q8_0, m, n, k, true).unwrap();
        let (_, functional) = lane.mul_mat_q8_0(&w_blocks, m, &acts, n, k).unwrap();
        assert_eq!(analytic, functional, "modes must agree cycle-exactly");
    }

    #[test]
    fn conf_charged_only_on_kernel_switch() {
        let imax = ImaxConfig::fpga(1);
        let mut lane = LaneSim::new(imax);
        let (m, n, k) = (2, 2, 256);
        let wt = random_tensor(m, k, 9);
        let xt = random_tensor(n, k, 10);
        let w8: Vec<_> = (0..m).flat_map(|r| q8_0::quantize_row(wt.row_f32(r))).collect();
        let a8: Vec<_> = (0..n).flat_map(|r| q8_0::quantize_row(xt.row_f32(r))).collect();
        let (_, bd1) = lane.mul_mat_q8_0(&w8, m, &a8, n, k).unwrap();
        assert!(bd1.conf > 0, "first run configures");
        let (_, bd2) = lane.mul_mat_q8_0(&w8, m, &a8, n, k).unwrap();
        assert_eq!(bd2.conf, 0, "same kernel stays configured");
        let w3: Vec<_> = (0..m).flat_map(|r| q3_k::quantize_row(wt.row_f32(r))).collect();
        let a3: Vec<_> = (0..n).flat_map(|r| q8_k::quantize_row(xt.row_f32(r))).collect();
        let (_, bd3) = lane.mul_mat_q3_k(&w3, m, &a3, n, k).unwrap();
        assert!(bd3.conf > 0, "kernel switch reconfigures");
    }

    #[test]
    fn asic_same_cycles_less_time() {
        let (m, n, k) = (8, 8, 512);
        let fpga = LaneSim::new(ImaxConfig::fpga(1));
        let asic = LaneSim::new(ImaxConfig::asic(1));
        let b_f = fpga.analytic_mul_mat(KernelKind::Q3K, m, n, k, true).unwrap();
        let b_a = asic.analytic_mul_mat(KernelKind::Q3K, m, n, k, true).unwrap();
        assert_eq!(b_f, b_a, "same microarchitecture, same cycles");
        let t_f = b_f.seconds(fpga.imax.clock_hz).total();
        let t_a = b_a.seconds(asic.imax.clock_hz).total();
        assert!((t_f / t_a - 840.0 / 145.0).abs() < 1e-9);
    }

    #[test]
    fn q8_0_loads_more_bytes_than_q3_k_same_shape() {
        let imax = ImaxConfig::fpga(1);
        let (m, n, k) = (64, 32, 1024);
        let p8 = TilePlan::new(&imax, KernelKind::Q8_0, m, n, k).unwrap();
        let p3 = TilePlan::new(&imax, KernelKind::Q3K, m, n, k).unwrap();
        assert!(
            p8.load_bytes() > p3.load_bytes(),
            "paper §IV-B: Q8_0 moves more data ({} vs {})",
            p8.load_bytes(),
            p3.load_bytes()
        );
    }
}
