//! Kernel configurations: how the two dot-product kernels map onto the
//! 64-PE linear array.
//!
//! The paper states the mappings' PE budgets — **Q3_K across 51 PEs,
//! Q8_0 across 46 PEs** (§III-B) — and the dataflow structure of Figs. 3
//! and 4 (8-bit MAC chains aggregated to 24-bit "across every 12 PEs",
//! with a final f32 multiply; Q3_K adds the OP_CVT53 restructuring
//! stage). The exact PE-by-PE placement is not published, so this module
//! fixes a concrete placement consistent with those constraints and
//! documents it; the timing model depends only on the group geometry
//! (elements per beat, pipeline depth, PE count), which *is* constrained
//! by the paper.

use super::PES_PER_LANE;

/// Which lane kernel a configuration implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Q8_0 × Q8_0 dot (Fig. 3).
    Q8_0,
    /// Q3_K × Q8_K dot with IMAX restructuring (Fig. 4).
    Q3K,
    /// F16 × f32 dot via OP_SML16 (§VI future work; carries the conv
    /// GEMMs, the pipeline's dominant MAC population per Table I).
    F16,
}

impl KernelKind {
    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Q8_0 => "Q8_0",
            KernelKind::Q3K => "Q3_K",
            KernelKind::F16 => "F16",
        }
    }

    /// The lane kernel a weight storage dtype selects (`None` for
    /// host-only dtypes) — the single dtype→kernel mapping the offload
    /// paths share. Note F16 maps to a kernel but only `ConvIm2col`
    /// sites route F16 to the lane (the offload *policy* is
    /// kind-aware; F16 linear fallbacks stay on the host, matching the
    /// paper's routing table with the §VI conv extension).
    pub fn of_dtype(dtype: crate::ggml::DType) -> Option<KernelKind> {
        match dtype {
            crate::ggml::DType::Q8_0 => Some(KernelKind::Q8_0),
            crate::ggml::DType::Q3K => Some(KernelKind::Q3K),
            crate::ggml::DType::F16 => Some(KernelKind::F16),
            _ => None,
        }
    }
}

/// Role a PE plays inside a group (for utilization/power accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeRole {
    /// Streams operand words out of LMM (address generation + load).
    Load,
    /// OP_SML8 multiply-add stage.
    Sml8,
    /// OP_SML16 F16×f32 multiply-accumulate stage (§VI).
    Sml16,
    /// OP_AD24 aggregation stage.
    Ad24,
    /// OP_CVT53 restructuring stage (Q3_K only).
    Cvt53,
    /// 32-bit integer accumulate (Q3_K isum across sub-blocks).
    Add32,
    /// Integer → float conversion.
    CvtI2F,
    /// f32 multiply/accumulate stage.
    Fma,
    /// Result drain / store.
    Store,
}

/// Static description of one kernel's lane mapping.
#[derive(Debug, Clone)]
pub struct KernelConfig {
    /// Kernel this config implements.
    pub kind: KernelKind,
    /// Number of parallel MAC groups in the lane.
    pub groups: usize,
    /// Elements (MACs) consumed per group per beat.
    pub elems_per_beat: usize,
    /// Pipeline depth in PE stages (first operand in → result at drain).
    pub pipeline_depth: usize,
    /// Per-group PE roles (placement of one group).
    pub group_pes: Vec<PeRole>,
    /// Shared PEs outside the groups (reduction, drain, control).
    pub shared_pes: Vec<PeRole>,
}

impl KernelConfig {
    /// The Q8_0 mapping: 46 PEs (§III-B).
    ///
    /// Three identical 12-PE groups, each retiring one 32-element block
    /// per beat, matching Fig. 3's "aggregate … into a 24-bit integer
    /// across every 12 PEs":
    ///
    /// ```text
    /// per group (12 PEs):
    ///   2 × Load     stream w-word + a-word (8 int8 each) per beat
    ///   8 × OP_SML8  4 products each (2 lanes × 2 segs)  = 32 MACs
    ///   1 × OP_AD24  fold the two SIMD 24-bit lanes
    ///   1 × CvtI2F   block isum → f32
    /// shared (10 PEs):
    ///   3 × Fma      × (d_w · d_a), one per group, in block order
    ///   3 × Fma      f32 accumulator chain (ordered reduction)
    ///   2 × Load     scale-word streaming
    ///   2 × Store    result drain to LMM
    /// total: 3 × 12 + 10 = 46
    /// ```
    pub fn q8_0() -> KernelConfig {
        use PeRole::*;
        let group = vec![
            Load, Load, Sml8, Sml8, Sml8, Sml8, Sml8, Sml8, Sml8, Sml8, Ad24, CvtI2F,
        ];
        let shared = vec![Fma, Fma, Fma, Fma, Fma, Fma, Load, Load, Store, Store];
        let cfg = KernelConfig {
            kind: KernelKind::Q8_0,
            groups: 3,
            elems_per_beat: 32,
            pipeline_depth: 12 + 4, // group stages + shared fma/drain spine
            group_pes: group,
            shared_pes: shared,
        };
        debug_assert_eq!(cfg.pe_count(), 46);
        cfg
    }

    /// The Q3_K mapping: 51 PEs (§III-B).
    ///
    /// Three 14-PE groups, each retiring one 16-element sub-block per
    /// beat (the Q3_K scale granularity), plus a 9-PE shared spine:
    ///
    /// ```text
    /// per group (14 PEs):
    ///   2 × Load      stream packed-3-bit w-word + a-word
    ///   2 × OP_CVT53  unpack 3-bit → signed 8-bit; 5-bit scale feed
    ///   4 × OP_SML8   4 products each                = 16 MACs
    ///   2 × OP_AD24   fold lanes + chain partials
    ///   1 × OP_CVT53  scale multiply (× 2·s5)
    ///   2 × Add32     isum accumulate across the 16 sub-blocks
    ///   1 × CvtI2F    super-block isum → f32
    /// shared (9 PEs):
    ///   3 × Fma       × (d_w · d_a) per group, in super-block order
    ///   2 × Fma       ordered f32 reduction
    ///   2 × Load      scale stream
    ///   2 × Store     drain
    /// total: 3 × 14 + 9 = 51
    /// ```
    pub fn q3_k() -> KernelConfig {
        use PeRole::*;
        let group = vec![
            Load, Load, Cvt53, Cvt53, Sml8, Sml8, Sml8, Sml8, Ad24, Ad24, Cvt53, Add32, Add32,
            CvtI2F,
        ];
        let shared = vec![Fma, Fma, Fma, Fma, Fma, Load, Load, Store, Store];
        let cfg = KernelConfig {
            kind: KernelKind::Q3K,
            groups: 3,
            elems_per_beat: 16,
            pipeline_depth: 14 + 4,
            group_pes: group,
            shared_pes: shared,
        };
        debug_assert_eq!(cfg.pe_count(), 51);
        cfg
    }

    /// The F16 mapping: 46 PEs (§VI future work, modelled).
    ///
    /// The paper does not publish an OP_SML16 placement — §VI only
    /// motivates the instruction — so this fixes a concrete mapping with
    /// the same group structure as Q8_0 (whose footprint OP_SML16 would
    /// share: one multiply stage per SIMD word, f32 spine unchanged).
    /// Each OP_SML16 PE retires 2 F16×f32 products per beat (one 32-bit
    /// weight lane = 2 packed halves, versus 4 int8 products for
    /// OP_SML8), so a group retires a 16-element slice per beat:
    ///
    /// ```text
    /// per group (12 PEs):
    ///   3 × Load      stream 1 packed w-word (4 halves) + 2 a-words
    ///   8 × OP_SML16  2 products each, in-order f32 accumulate = 16 MACs
    ///   1 × Fma       group-partial chain (ordered)
    /// shared (10 PEs):
    ///   6 × Fma       ordered cross-group f32 reduction spine
    ///   2 × Load      activation prefetch
    ///   2 × Store     result drain to LMM
    /// total: 3 × 12 + 10 = 46
    /// ```
    ///
    /// Groups stride a dot's 16-element slices exactly like the
    /// quantized kernels stride blocks; the ordered reduction spine
    /// keeps the *value* semantics sequential in element order, which is
    /// what makes the lane dot bit-identical to the host reference (see
    /// [`crate::imax::isa::op_sml16`]).
    pub fn f16() -> KernelConfig {
        use PeRole::*;
        let group = vec![
            Load, Load, Load, Sml16, Sml16, Sml16, Sml16, Sml16, Sml16, Sml16, Sml16, Fma,
        ];
        let shared = vec![Fma, Fma, Fma, Fma, Fma, Fma, Load, Load, Store, Store];
        let cfg = KernelConfig {
            kind: KernelKind::F16,
            groups: 3,
            elems_per_beat: 16,
            pipeline_depth: 12 + 4,
            group_pes: group,
            shared_pes: shared,
        };
        debug_assert_eq!(cfg.pe_count(), 46);
        cfg
    }

    /// Config for a kernel kind.
    pub fn for_kind(kind: KernelKind) -> KernelConfig {
        match kind {
            KernelKind::Q8_0 => KernelConfig::q8_0(),
            KernelKind::Q3K => KernelConfig::q3_k(),
            KernelKind::F16 => KernelConfig::f16(),
        }
    }

    /// Total PEs this kernel occupies (the paper's 46 / 51).
    pub fn pe_count(&self) -> usize {
        self.groups * self.group_pes.len() + self.shared_pes.len()
    }

    /// MAC throughput per beat for the whole lane.
    pub fn macs_per_beat(&self) -> usize {
        self.groups * self.elems_per_beat
    }

    /// PEs left idle in the 64-PE lane (general-purpose slack).
    pub fn idle_pes(&self) -> usize {
        PES_PER_LANE - self.pe_count()
    }

    /// Beats needed for one dot product over `k` elements.
    ///
    /// The three groups stride over the blocks of a single dot (block
    /// `b` → group `b mod 3`), so a dot of `nb` block-beats completes in
    /// `ceil(nb / groups)` beats.
    pub fn beats_for_dot(&self, k: usize) -> u64 {
        let nb = k.div_ceil(self.elems_per_beat) as u64;
        nb.div_ceil(self.groups as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pe_budgets_match_paper() {
        assert_eq!(KernelConfig::q8_0().pe_count(), 46, "paper: Q8_0 on 46 PEs");
        assert_eq!(KernelConfig::q3_k().pe_count(), 51, "paper: Q3_K on 51 PEs");
    }

    #[test]
    fn both_fit_in_a_64_pe_lane() {
        assert!(KernelConfig::q8_0().pe_count() <= PES_PER_LANE);
        assert!(KernelConfig::q3_k().pe_count() <= PES_PER_LANE);
        assert_eq!(KernelConfig::q8_0().idle_pes(), 18);
        assert_eq!(KernelConfig::q3_k().idle_pes(), 13);
    }

    #[test]
    fn q8_0_group_is_12_pes_as_fig3_states() {
        assert_eq!(KernelConfig::q8_0().group_pes.len(), 12);
    }

    #[test]
    fn sml8_count_covers_elems_per_beat() {
        for cfg in [KernelConfig::q8_0(), KernelConfig::q3_k()] {
            let sml8 = cfg.group_pes.iter().filter(|r| **r == PeRole::Sml8).count();
            // Each OP_SML8 PE performs 4 int8 products per beat.
            assert_eq!(sml8 * 4, cfg.elems_per_beat, "{:?}", cfg.kind);
        }
    }

    #[test]
    fn q3k_has_cvt53_q8_0_does_not() {
        let has_cvt = |c: &KernelConfig| c.group_pes.iter().any(|r| *r == PeRole::Cvt53);
        assert!(has_cvt(&KernelConfig::q3_k()));
        assert!(!has_cvt(&KernelConfig::q8_0()));
    }

    #[test]
    fn beats_for_dot_rounds_up() {
        let q8 = KernelConfig::q8_0();
        // k=256 -> 8 blocks over 3 groups -> 3 beats.
        assert_eq!(q8.beats_for_dot(256), 3);
        assert_eq!(q8.beats_for_dot(32), 1);
        assert_eq!(q8.beats_for_dot(96), 1);
        assert_eq!(q8.beats_for_dot(128), 2);
        let q3 = KernelConfig::q3_k();
        // k=256 -> 16 sub-blocks over 3 groups -> 6 beats.
        assert_eq!(q3.beats_for_dot(256), 6);
    }

    #[test]
    fn mac_rates() {
        assert_eq!(KernelConfig::q8_0().macs_per_beat(), 96);
        assert_eq!(KernelConfig::q3_k().macs_per_beat(), 48);
        assert_eq!(KernelConfig::f16().macs_per_beat(), 48);
    }

    #[test]
    fn f16_mapping_fits_and_covers_its_beat() {
        let cfg = KernelConfig::f16();
        assert_eq!(cfg.pe_count(), 46, "modelled §VI footprint mirrors Q8_0");
        assert!(cfg.pe_count() <= PES_PER_LANE);
        let sml16 = cfg.group_pes.iter().filter(|r| **r == PeRole::Sml16).count();
        // Each OP_SML16 PE performs 2 F16×f32 products per beat.
        assert_eq!(sml16 * 2, cfg.elems_per_beat);
        // k=1152 (cin=128, 3×3) -> 72 slices over 3 groups -> 24 beats.
        assert_eq!(cfg.beats_for_dot(1152), 24);
        // Odd tails still round up to a slice.
        assert_eq!(cfg.beats_for_dot(17), 1);
        assert_eq!(cfg.beats_for_dot(49), 2);
    }

    #[test]
    fn f16_dtype_selects_the_f16_kernel() {
        use crate::ggml::DType;
        assert_eq!(KernelKind::of_dtype(DType::F16), Some(KernelKind::F16));
        assert_eq!(KernelKind::of_dtype(DType::F32), None);
        assert_eq!(KernelKind::of_dtype(DType::Q8K), None);
    }
}
