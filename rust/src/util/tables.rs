//! ASCII table and bar-chart rendering for bench/report output.
//!
//! Every bench target regenerating a paper table or figure prints through
//! these so the terminal output visually mirrors the paper's tables (rows ×
//! columns) and bar figures (Figs 6–11).

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    /// Left-aligned (labels).
    Left,
    /// Right-aligned (numbers).
    Right,
}

/// A simple text table builder.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers (all right-aligned except
    /// the first).
    pub fn new(title: &str, headers: &[&str]) -> Table {
        let aligns = headers
            .iter()
            .enumerate()
            .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
            .collect();
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            aligns,
            rows: Vec::new(),
        }
    }

    /// Override column alignments.
    pub fn aligns(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    /// Append a row (must match header count).
    pub fn row(&mut self, cells: &[String]) -> &mut Table {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Append a row of `&str`.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Table {
        let owned: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&owned)
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].chars().count());
            }
        }
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for c in 0..ncol {
                let pad = widths[c] - cells[c].chars().count();
                match self.aligns[c] {
                    Align::Left => s.push_str(&format!(" {}{} |", cells[c], " ".repeat(pad))),
                    Align::Right => s.push_str(&format!(" {}{} |", " ".repeat(pad), cells[c])),
                }
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Horizontal bar chart, log- or linear-scaled, for latency figures.
#[derive(Debug, Clone)]
pub struct BarChart {
    title: String,
    unit: String,
    log_scale: bool,
    bars: Vec<(String, f64)>,
}

impl BarChart {
    /// New chart; `unit` is appended to each value label.
    pub fn new(title: &str, unit: &str) -> BarChart {
        BarChart {
            title: title.to_string(),
            unit: unit.to_string(),
            log_scale: false,
            bars: Vec::new(),
        }
    }

    /// Use log10 bar lengths (the paper's latency figures span 16 s – 810 s).
    pub fn log(mut self) -> BarChart {
        self.log_scale = true;
        self
    }

    /// Add one bar.
    pub fn bar(&mut self, label: &str, value: f64) -> &mut BarChart {
        self.bars.push((label.to_string(), value));
        self
    }

    /// Render to a string, bars scaled to `width` characters.
    pub fn render(&self, width: usize) -> String {
        let mut out = format!("{}\n", self.title);
        if self.bars.is_empty() {
            return out;
        }
        let label_w = self.bars.iter().map(|(l, _)| l.chars().count()).max().unwrap();
        let max_v = self.bars.iter().map(|&(_, v)| v).fold(f64::MIN, f64::max);
        let min_v = self
            .bars
            .iter()
            .map(|&(_, v)| v)
            .filter(|v| *v > 0.0)
            .fold(f64::MAX, f64::min);
        for (label, v) in &self.bars {
            let frac = if max_v <= 0.0 {
                0.0
            } else if self.log_scale && min_v < max_v && *v > 0.0 {
                // Map [min, max] onto [0.15, 1.0] in log space so the
                // smallest bar stays visible.
                let t = (v.ln() - min_v.ln()) / (max_v.ln() - min_v.ln());
                0.15 + 0.85 * t
            } else {
                (v / max_v).clamp(0.0, 1.0)
            };
            let n = ((width as f64) * frac).round() as usize;
            out.push_str(&format!(
                "  {label:<label_w$} |{} {v:.1} {}\n",
                "█".repeat(n),
                self.unit
            ));
        }
        out
    }

    /// Render with default width and print.
    pub fn print(&self) {
        print!("{}", self.render(48));
    }
}

/// Stacked horizontal bars for breakdowns (Fig. 11): each bar is a set of
/// named segments rendered with distinct glyphs plus a legend.
#[derive(Debug, Clone)]
pub struct StackedBars {
    title: String,
    unit: String,
    segments: Vec<String>,
    bars: Vec<(String, Vec<f64>)>,
}

const GLYPHS: [char; 8] = ['█', '▓', '▒', '░', '◆', '●', '▲', '■'];

impl StackedBars {
    /// New stacked chart with the segment names (<= 8).
    pub fn new(title: &str, unit: &str, segments: &[&str]) -> StackedBars {
        assert!(segments.len() <= GLYPHS.len());
        StackedBars {
            title: title.to_string(),
            unit: unit.to_string(),
            segments: segments.iter().map(|s| s.to_string()).collect(),
            bars: Vec::new(),
        }
    }

    /// Add one stacked bar; `values` aligns with the segment names.
    pub fn bar(&mut self, label: &str, values: &[f64]) -> &mut StackedBars {
        assert_eq!(values.len(), self.segments.len());
        self.bars.push((label.to_string(), values.to_vec()));
        self
    }

    /// Render to a string with total bar width `width`.
    pub fn render(&self, width: usize) -> String {
        let mut out = format!("{}\n", self.title);
        let label_w = self
            .bars
            .iter()
            .map(|(l, _)| l.chars().count())
            .max()
            .unwrap_or(0);
        let max_total: f64 = self
            .bars
            .iter()
            .map(|(_, v)| v.iter().sum::<f64>())
            .fold(0.0, f64::max);
        for (label, values) in &self.bars {
            let total: f64 = values.iter().sum();
            out.push_str(&format!("  {label:<label_w$} |"));
            for (i, v) in values.iter().enumerate() {
                let n = if max_total > 0.0 {
                    ((width as f64) * v / max_total).round() as usize
                } else {
                    0
                };
                out.push_str(&GLYPHS[i].to_string().repeat(n));
            }
            out.push_str(&format!(" {total:.2} {}\n", self.unit));
        }
        out.push_str("  legend:");
        for (i, s) in self.segments.iter().enumerate() {
            out.push_str(&format!(" {}={}", GLYPHS[i], s));
        }
        out.push('\n');
        out
    }

    /// Render with default width and print.
    pub fn print(&self) {
        print!("{}", self.render(60));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["dev", "lat"]);
        t.row_str(&["ARM", "809.7"]).row_str(&["IMAX3 (FPGA)", "790.3"]);
        let s = t.render();
        assert!(s.contains("| dev          |   lat |"), "{s}");
        assert!(s.contains("| ARM          | 809.7 |"), "{s}");
        assert!(s.contains("| IMAX3 (FPGA) | 790.3 |"), "{s}");
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_arity_checked() {
        Table::new("T", &["a", "b"]).row_str(&["x"]);
    }

    #[test]
    fn bar_chart_monotone_lengths() {
        let mut c = BarChart::new("latency", "s");
        c.bar("gpu", 16.2).bar("xeon", 59.3).bar("arm", 809.7);
        let s = c.render(40);
        let counts: Vec<usize> = s
            .lines()
            .skip(1)
            .map(|l| l.chars().filter(|&ch| ch == '█').count())
            .collect();
        assert!(counts[0] < counts[1] && counts[1] < counts[2], "{s}");
    }

    #[test]
    fn log_scale_keeps_small_bars_visible() {
        let mut c = BarChart::new("latency", "s").log();
        c.bar("gpu", 16.2).bar("arm", 809.7);
        let s = c.render(40);
        let first = s.lines().nth(1).unwrap();
        assert!(first.chars().filter(|&ch| ch == '█').count() >= 4, "{s}");
    }

    #[test]
    fn stacked_bars_sum_label() {
        let mut sb = StackedBars::new("breakdown", "s", &["EXEC", "LOAD", "DRAIN"]);
        sb.bar("Q3_K", &[1.0, 2.0, 3.0]);
        let s = sb.render(30);
        assert!(s.contains("6.00 s"), "{s}");
        assert!(s.contains("legend: █=EXEC ▓=LOAD ▒=DRAIN"), "{s}");
    }
}
