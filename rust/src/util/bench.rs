//! Micro-benchmark harness (criterion is not vendored in this image).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! fixed-duration sampling, and a [`crate::util::stats::Summary`] printed
//! through the table renderer.

use super::stats::{fmt_duration, Summary};
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case name.
    pub name: String,
    /// Per-iteration timing summary (seconds).
    pub summary: Summary,
    /// Optional throughput denominator (items per iteration).
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    /// items/second at the median, when a denominator was given.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.summary.median)
    }

    /// One-line report.
    pub fn line(&self) -> String {
        let tp = self
            .throughput()
            .map(|t| format!("  ({:.3e} items/s)", t))
            .unwrap_or_default();
        format!(
            "{:<44} median {}  (±{:.1}% over {} samples){tp}",
            self.name,
            fmt_duration(self.summary.median),
            self.summary.cv() * 100.0,
            self.summary.n,
        )
    }
}

/// Run `f` repeatedly for ~`budget` (after `warmup` iterations) and
/// summarize per-iteration latency.
pub fn bench(name: &str, warmup: u32, budget: Duration, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 5 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() >= 10_000 {
            break;
        }
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&samples), items_per_iter: None }
}

/// [`bench`] with a throughput denominator.
pub fn bench_throughput(
    name: &str,
    warmup: u32,
    budget: Duration,
    items_per_iter: f64,
    f: impl FnMut(),
) -> BenchResult {
    let mut r = bench(name, warmup, budget, f);
    r.items_per_iter = Some(items_per_iter);
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples_and_reports() {
        let r = bench("noop", 2, Duration::from_millis(20), || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.summary.n >= 5);
        assert!(r.line().contains("noop"));
    }

    #[test]
    fn throughput_computed() {
        let r = bench_throughput("t", 1, Duration::from_millis(10), 100.0, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.throughput().unwrap() > 0.0);
    }
}
