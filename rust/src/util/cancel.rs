//! Cooperative cancellation: one shared token threaded from the serving
//! front door down into the denoising step loop.
//!
//! A [`CancelToken`] is a cheap `Arc`-shared cell holding a three-state
//! flag (live / cancelled / deadline-expired) plus an optional deadline.
//! The HTTP cancel route and the admission layer hold one clone; the
//! request's pipeline run holds another and calls [`CancelToken::check`]
//! at every denoising-step boundary (and before the text encoder and the
//! VAE decoder), so a cancel or an expired deadline stops the request
//! before it submits another op — without interrupting an op mid-flight,
//! which keeps the lockstep micro-batch rendezvous sound (the aborting
//! member leaves the batch between rendezvous points, see
//! [`crate::serve::batcher::SharedBatch::leave`]).
//!
//! The first cause wins: once a token is cancelled it stays `Cancelled`
//! even if the deadline later passes, and vice versa — the surfaced
//! terminal state is stable. The cancel-vs-expire CAS race (exactly one
//! terminal cause, stable under every interleaving) is model-checked
//! over every bounded schedule by [`crate::check::models::CancelModel`].

use crate::util::sync::StateCell;
use std::sync::Arc;
use std::time::Instant;

/// Why a request stopped early.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelCause {
    /// Explicit cancel (`POST /predictions/{id}/cancel`).
    Cancelled,
    /// The per-request deadline passed.
    DeadlineExpired,
}

const LIVE: u8 = 0;
const CANCELLED: u8 = 1;
const EXPIRED: u8 = 2;

struct Inner {
    state: StateCell,
    deadline: Option<Instant>,
}

/// Shared cancellation token. Clones observe the same state.
#[derive(Clone)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

impl Default for CancelToken {
    fn default() -> Self {
        CancelToken::new()
    }
}

impl std::fmt::Debug for CancelToken {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CancelToken")
            .field("cause", &self.cause())
            .field("deadline", &self.inner.deadline)
            .finish()
    }
}

impl CancelToken {
    /// A live token with no deadline (never expires on its own).
    pub fn new() -> CancelToken {
        CancelToken {
            inner: Arc::new(Inner { state: StateCell::new(LIVE), deadline: None }),
        }
    }

    /// A live token that [`CancelToken::check`] flips to
    /// [`CancelCause::DeadlineExpired`] once `deadline` passes. The
    /// deadline is evaluated lazily at check points — no watcher thread.
    pub fn with_deadline(deadline: Instant) -> CancelToken {
        CancelToken {
            inner: Arc::new(Inner { state: StateCell::new(LIVE), deadline: Some(deadline) }),
        }
    }

    /// The deadline, if one was set.
    pub fn deadline(&self) -> Option<Instant> {
        self.inner.deadline
    }

    /// Request cancellation. Returns `true` if this call transitioned
    /// the token out of the live state (first cause wins; a second
    /// cancel or an already-expired token returns `false`).
    pub fn cancel(&self) -> bool {
        self.inner.state.transition(LIVE, CANCELLED)
    }

    /// Force the deadline transition now (deadline-watcher seams and
    /// tests). First cause wins, like [`CancelToken::cancel`].
    pub fn expire(&self) -> bool {
        self.inner.state.transition(LIVE, EXPIRED)
    }

    /// The cooperative check point: `Ok(())` while live, otherwise the
    /// cause. Evaluates the deadline lazily (transitioning the shared
    /// state so every clone observes the same cause afterwards).
    pub fn check(&self) -> Result<(), CancelCause> {
        match self.inner.state.load() {
            CANCELLED => return Err(CancelCause::Cancelled),
            EXPIRED => return Err(CancelCause::DeadlineExpired),
            _ => {}
        }
        if let Some(deadline) = self.inner.deadline {
            if Instant::now() >= deadline {
                // Lost races surface whichever cause won the CAS.
                self.expire();
                return Err(self.cause().expect("post-expire state is terminal"));
            }
        }
        Ok(())
    }

    /// The terminal cause, or `None` while live. Unlike
    /// [`CancelToken::check`] this does **not** evaluate the deadline —
    /// it reports only transitions that already happened.
    pub fn cause(&self) -> Option<CancelCause> {
        match self.inner.state.load() {
            CANCELLED => Some(CancelCause::Cancelled),
            EXPIRED => Some(CancelCause::DeadlineExpired),
            _ => None,
        }
    }

    /// True while neither cancelled nor expired.
    pub fn is_live(&self) -> bool {
        self.inner.state.load() == LIVE
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn live_token_checks_ok() {
        let t = CancelToken::new();
        assert!(t.is_live());
        assert_eq!(t.check(), Ok(()));
        assert_eq!(t.cause(), None);
    }

    #[test]
    fn cancel_is_sticky_and_shared_across_clones() {
        let t = CancelToken::new();
        let clone = t.clone();
        assert!(t.cancel(), "first cancel transitions");
        assert!(!t.cancel(), "second cancel is a no-op");
        assert_eq!(clone.check(), Err(CancelCause::Cancelled));
        assert_eq!(clone.cause(), Some(CancelCause::Cancelled));
        assert!(!clone.is_live());
    }

    #[test]
    fn past_deadline_expires_on_check() {
        let t = CancelToken::with_deadline(Instant::now() - Duration::from_millis(1));
        assert_eq!(t.cause(), None, "lazy: no transition before a check");
        assert_eq!(t.check(), Err(CancelCause::DeadlineExpired));
        assert_eq!(t.cause(), Some(CancelCause::DeadlineExpired));
    }

    #[test]
    fn future_deadline_stays_live() {
        let t = CancelToken::with_deadline(Instant::now() + Duration::from_secs(3600));
        assert_eq!(t.check(), Ok(()));
        assert!(t.deadline().is_some());
    }

    #[test]
    fn first_cause_wins() {
        let t = CancelToken::with_deadline(Instant::now() - Duration::from_millis(1));
        assert!(t.cancel(), "cancel landed before any deadline check");
        assert_eq!(t.check(), Err(CancelCause::Cancelled), "deadline does not overwrite");
        let u = CancelToken::new();
        assert!(u.expire());
        assert!(!u.cancel(), "cancel after expiry is a no-op");
        assert_eq!(u.check(), Err(CancelCause::DeadlineExpired));
    }
}
