//! Deterministic pseudo-random number generation.
//!
//! The vendored crate set has `rand_core` (traits only) but no generator
//! implementations, so this module provides the two generators the project
//! needs:
//!
//! * [`SplitMix64`] — seeds other generators; also used where a tiny stream
//!   is enough (e.g. hashing a prompt into a latent seed).
//! * [`Xoshiro256pp`] — the workhorse for weight synthesis, latent noise,
//!   and property-test case generation. Passes BigCrush per its authors.
//!
//! Everything is reproducible: a pipeline run with seed `s` produces the
//! same image on every host.

/// SplitMix64 (Steele, Lea, Flood 2014). Primarily a seed expander.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64 uniformly distributed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 (Blackman & Vigna 2019).
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 as the reference implementation recommends.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Xoshiro256pp { s }
    }

    /// Next 64 uniformly distributed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32 bits (upper half of the 64-bit output).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform `f64` in `[0, 1)` using the top 53 bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)` using the top 24 bits.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform `f32` in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Rejection-free fast path is fine here: bias is < 2^-32 for the
        // small ranges this project uses, but do the full widening anyway.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Standard normal sample (Box–Muller; caches nothing, two uniforms per
    /// call, adequate for weight/noise synthesis).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Fill a slice with N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * sigma;
        }
    }

    /// Fill a slice with U[lo, hi) samples.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.uniform(lo, hi);
        }
    }
}

/// FNV-1a 64-bit hash — used to turn prompt strings into latent seeds the
/// same way on every platform.
pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference outputs for seed 1234567 from the public-domain C code.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_seeds() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        let mut c = Xoshiro256pp::seed_from_u64(43);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_f32_in_range() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Xoshiro256pp::seed_from_u64(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        let n = 100_000;
        let mut sum = 0.0f64;
        let mut sum2 = 0.0f64;
        for _ in 0..n {
            let v = r.normal() as f64;
            sum += v;
            sum2 += v * v;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn fnv_known_values() {
        assert_eq!(fnv1a64(b""), 0xCBF2_9CE4_8422_2325);
        // "a lovely cat" must hash identically forever (it seeds Fig. 5).
        assert_eq!(fnv1a64(b"a lovely cat"), fnv1a64(b"a lovely cat"));
        assert_ne!(fnv1a64(b"a lovely cat"), fnv1a64(b"a lovely dog"));
    }
}
