//! IEEE 754 binary16 (half precision) conversion.
//!
//! GGML block formats ([`crate::ggml`]) store per-block scale factors as
//! `ggml_fp16_t`, i.e. raw binary16 bits. The conversions here are
//! round-trip exact for every representable half value and use
//! round-to-nearest-even on the f32 → f16 path, matching both hardware
//! `F16C`/`fcvt` behaviour and GGML's lookup-table implementation.

/// A raw IEEE 754 binary16 value stored as its bit pattern.
///
/// This is deliberately a transparent wrapper over `u16` so that quantized
/// blocks can be byte-serialized exactly like GGML's C structs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct F16(pub u16);

impl F16 {
    /// Positive zero.
    pub const ZERO: F16 = F16(0);
    /// One (0x3C00).
    pub const ONE: F16 = F16(0x3C00);
    /// Largest finite half value (65504.0).
    pub const MAX: F16 = F16(0x7BFF);

    /// Convert from `f32` with round-to-nearest-even.
    #[inline]
    pub fn from_f32(x: f32) -> F16 {
        F16(f32_to_f16_bits(x))
    }

    /// Convert to `f32` (exact; every half is representable in f32).
    #[inline]
    pub fn to_f32(self) -> f32 {
        f16_bits_to_f32(self.0)
    }

    /// True if the value is NaN.
    #[inline]
    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x03FF) != 0
    }

    /// True if the value is +/- infinity.
    #[inline]
    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }
}

impl From<f32> for F16 {
    fn from(x: f32) -> F16 {
        F16::from_f32(x)
    }
}

impl From<F16> for f32 {
    fn from(h: F16) -> f32 {
        h.to_f32()
    }
}

/// f32 → binary16 bit pattern, round-to-nearest-even, IEEE semantics
/// (overflow → infinity, subnormal halves produced exactly).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN. Preserve a quiet-NaN payload bit so NaN stays NaN.
        return if mant != 0 { sign | 0x7E00 } else { sign | 0x7C00 };
    }

    // Unbiased exponent, then re-bias for binary16 (bias 15).
    let unbiased = exp - 127;
    let half_exp = unbiased + 15;

    if half_exp >= 0x1F {
        // Overflow → infinity.
        return sign | 0x7C00;
    }

    if half_exp <= 0 {
        // Subnormal half or underflow to zero.
        if half_exp < -10 {
            return sign; // Rounds to +/- 0.
        }
        // Add the implicit bit, then shift right with round-to-nearest-even.
        let m = mant | 0x0080_0000;
        let shift = (14 - half_exp) as u32; // 14..24
        let halfway = 1u32 << (shift - 1);
        let mut q = m >> shift;
        let rem = m & ((1 << shift) - 1);
        if rem > halfway || (rem == halfway && (q & 1) == 1) {
            q += 1;
        }
        return sign | q as u16;
    }

    // Normal case: round 23-bit mantissa to 10 bits, nearest-even.
    let mut q = (mant >> 13) as u16;
    let rem = mant & 0x1FFF;
    let mut e = half_exp as u16;
    if rem > 0x1000 || (rem == 0x1000 && (q & 1) == 1) {
        q += 1;
        if q == 0x400 {
            // Mantissa rollover bumps the exponent.
            q = 0;
            e += 1;
            if e >= 0x1F {
                return sign | 0x7C00;
            }
        }
    }
    sign | (e << 10) | q
}

/// binary16 bit pattern → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;

    let bits = if exp == 0 {
        if mant == 0 {
            sign // +/- 0
        } else {
            // Subnormal: v = mant * 2^-24. Normalize so bit 10 is the
            // implicit one; each shift costs one exponent step from the
            // max subnormal exponent (2^-15 with implicit bit at 2^-1).
            let mut e = -1i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            // MSB of mant at position k => f32 biased exponent k + 103;
            // here e == k - 11, so biased exponent == e + 114.
            let exp32 = (e + 114) as u32;
            sign | (exp32 << 23) | ((m & 0x03FF) << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13) // Inf / NaN
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants() {
        assert_eq!(F16::ZERO.to_f32(), 0.0);
        assert_eq!(F16::ONE.to_f32(), 1.0);
        assert_eq!(F16::MAX.to_f32(), 65504.0);
    }

    #[test]
    fn simple_values_round_trip() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, -2.5, 1024.0, 0.125, 65504.0] {
            let h = F16::from_f32(v);
            assert_eq!(h.to_f32(), v, "value {v} must be exact in f16");
        }
    }

    #[test]
    fn all_halves_round_trip_through_f32() {
        // Every finite half must survive f16 -> f32 -> f16 bit-exactly.
        for bits in 0..=0xFFFFu16 {
            let h = F16(bits);
            if h.is_nan() {
                continue;
            }
            let back = F16::from_f32(h.to_f32());
            assert_eq!(back.0, bits, "bits {bits:#06x} failed round trip");
        }
    }

    #[test]
    fn rounding_is_nearest_even() {
        // 2049/2048 is halfway between two halves; even mantissa wins.
        let a = f32::from_bits(0x3880_1000); // exactly halfway case
        let h = F16::from_f32(a);
        assert_eq!(h.0 & 1, 0, "halfway must round to even");
    }

    #[test]
    fn overflow_to_infinity() {
        assert!(F16::from_f32(1e6).is_infinite());
        assert!(F16::from_f32(-1e6).is_infinite());
        assert_eq!(F16::from_f32(1e6).0, 0x7C00);
    }

    #[test]
    fn nan_stays_nan() {
        assert!(F16::from_f32(f32::NAN).is_nan());
        assert!(F16(0x7E00).to_f32().is_nan());
    }

    #[test]
    fn subnormals() {
        // Smallest positive subnormal half = 2^-24.
        let tiny = (2.0f32).powi(-24);
        let h = F16::from_f32(tiny);
        assert_eq!(h.0, 1);
        assert_eq!(h.to_f32(), tiny);
        // Below half of the smallest subnormal rounds to zero.
        assert_eq!(F16::from_f32(tiny / 4.0).0, 0);
    }

    #[test]
    fn known_bit_patterns() {
        // Canonical IEEE 754 binary16 vectors (value, bit pattern) —
        // cross-checked against the tables hardware F16C/fcvt implement.
        let vectors: &[(f32, u16)] = &[
            (0.0, 0x0000),
            (1.0, 0x3C00),
            (-1.0, 0xBC00),
            (2.0, 0x4000),
            (-2.0, 0xC000),
            (-2.5, 0xC100),
            (0.5, 0x3800),
            (0.0999755859375, 0x2E66),     // nearest half to 0.1
            (0.333251953125, 0x3555),      // nearest half to 1/3
            (65504.0, 0x7BFF),             // largest finite
            (6.103515625e-5, 0x0400),      // smallest normal, 2^-14
            (6.0975551605224609e-5, 0x03FF), // largest subnormal, 1023*2^-24
            (5.9604644775390625e-8, 0x0001), // smallest subnormal, 2^-24
        ];
        for &(v, bits) in vectors {
            assert_eq!(F16::from_f32(v).0, bits, "from_f32({v})");
            assert_eq!(F16(bits).to_f32(), v, "to_f32({bits:#06x})");
        }
        // Inexact decimals land on those same patterns.
        assert_eq!(F16::from_f32(0.1).0, 0x2E66);
        assert_eq!(F16::from_f32(1.0 / 3.0).0, 0x3555);
    }

    #[test]
    fn directed_ties_round_to_even() {
        // 1 + 2^-11 sits exactly between 0x3C00 and 0x3C01 → even wins.
        assert_eq!(F16::from_f32(1.0 + 2f32.powi(-11)).0, 0x3C00);
        // 1 + 3·2^-11 sits between 0x3C01 and 0x3C02 → even (0x3C02) wins.
        assert_eq!(F16::from_f32(1.0 + 3.0 * 2f32.powi(-11)).0, 0x3C02);
        // Just above/below a tie break away from even as usual.
        assert_eq!(F16::from_f32(1.0 + 2f32.powi(-11) + 2f32.powi(-20)).0, 0x3C01);
        assert_eq!(F16::from_f32(1.0 + 2f32.powi(-11) - 2f32.powi(-20)).0, 0x3C00);
        // Subnormal ties use the same rule: 1.5·2^-24 is halfway between
        // 0x0001 and 0x0002 → even (0x0002)... no: halfway between
        // 2^-24 (0x0001) and 2^-23 (0x0002) is 1.5·2^-24 → 0x0002 is even.
        assert_eq!(F16::from_f32(1.5 * 2f32.powi(-24)).0, 0x0002);
        // 0.5·2^-24 is halfway between 0 and 0x0001 → zero is even.
        assert_eq!(F16::from_f32(0.5 * 2f32.powi(-24)).0, 0x0000);
    }

    #[test]
    fn overflow_boundary_and_signs() {
        // 65520 is exactly halfway between 65504 and the (unrepresentable)
        // 65536 → ties-to-even rounds UP into infinity, per IEEE.
        assert_eq!(F16::from_f32(65520.0).0, 0x7C00);
        assert_eq!(F16::from_f32(-65520.0).0, 0xFC00);
        // Anything strictly below the halfway point stays finite max.
        assert_eq!(F16::from_f32(65519.0).0, 0x7BFF);
        assert_eq!(F16::from_f32(f32::INFINITY).0, 0x7C00);
        assert_eq!(F16::from_f32(f32::NEG_INFINITY).0, 0xFC00);
        // Signed zero survives both directions.
        assert_eq!(F16::from_f32(-0.0).0, 0x8000);
        assert!(F16(0x8000).to_f32().is_sign_negative());
        assert_eq!(F16(0x8000).to_f32(), 0.0);
        // NaN keeps its sign and a quiet payload.
        assert_eq!(F16::from_f32(-f32::NAN).0 & 0x8000, 0x8000);
        assert!(F16::from_f32(-f32::NAN).is_nan());
    }

    #[test]
    fn quantize_scale_range() {
        // Typical GGML scales: d = max(|x|)/127 with |x| <= ~30. All such
        // values must be representable with < 0.1% relative error.
        let mut v = 1e-4f32;
        while v < 1.0 {
            let err = (F16::from_f32(v).to_f32() - v).abs() / v;
            assert!(err < 1e-3, "relative error {err} too big at {v}");
            v *= 1.37;
        }
    }
}
