//! Miniature property-based testing framework (proptest is not vendored).
//!
//! Provides what the simulator/quantization invariant tests need: seeded
//! case generation from composable [`Gen`]s, a configurable number of cases,
//! and greedy shrinking of failing inputs via [`Shrink`].
//!
//! ```no_run
//! use imax_sd::util::prop::{run, Gen};
//! run("abs(len) preserved", 256, Gen::vec_f32(1..=64, -10.0..10.0), |xs| {
//!     let n = xs.len();
//!     if xs.iter().map(|x| x.abs()).count() != n { return Err("len".into()); }
//!     Ok(())
//! });
//! ```

use super::rng::Xoshiro256pp;
use std::ops::RangeInclusive;

/// A generator of values of type `T` from a PRNG.
pub struct Gen<T> {
    gen: Box<dyn Fn(&mut Xoshiro256pp) -> T>,
}

impl<T: 'static> Gen<T> {
    /// Build from a closure.
    pub fn from_fn(f: impl Fn(&mut Xoshiro256pp) -> T + 'static) -> Gen<T> {
        Gen { gen: Box::new(f) }
    }

    /// Sample one value.
    pub fn sample(&self, rng: &mut Xoshiro256pp) -> T {
        (self.gen)(rng)
    }

    /// Map the generated value.
    pub fn map<U: 'static>(self, f: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::from_fn(move |r| f((self.gen)(r)))
    }
}

impl Gen<usize> {
    /// Uniform usize in an inclusive range.
    pub fn usize_in(range: RangeInclusive<usize>) -> Gen<usize> {
        let (lo, hi) = (*range.start(), *range.end());
        Gen::from_fn(move |r| lo + r.below((hi - lo + 1) as u64) as usize)
    }
}

impl Gen<i64> {
    /// Uniform i64 in an inclusive range.
    pub fn i64_in(range: RangeInclusive<i64>) -> Gen<i64> {
        let (lo, hi) = (*range.start(), *range.end());
        Gen::from_fn(move |r| r.range_i64(lo, hi))
    }
}

impl Gen<f32> {
    /// Uniform f32 in `[lo, hi)`, with occasional exact-zero and boundary
    /// values mixed in (edge-case biasing).
    pub fn f32_in(range: std::ops::Range<f32>) -> Gen<f32> {
        let (lo, hi) = (range.start, range.end);
        Gen::from_fn(move |r| match r.below(16) {
            0 => 0.0,
            1 => lo,
            2 => hi - (hi - lo) * 1e-7,
            _ => r.uniform(lo, hi),
        })
    }
}

impl Gen<Vec<f32>> {
    /// Vector of f32 with random length in `len` and values in `vals`.
    pub fn vec_f32(len: RangeInclusive<usize>, vals: std::ops::Range<f32>) -> Gen<Vec<f32>> {
        let elem = Gen::f32_in(vals);
        let lgen = Gen::usize_in(len);
        Gen::from_fn(move |r| {
            let n = lgen.sample(r);
            (0..n).map(|_| elem.sample(r)).collect()
        })
    }
}

impl Gen<Vec<i8>> {
    /// Vector of i8 with random length and full-range values.
    pub fn vec_i8(len: RangeInclusive<usize>) -> Gen<Vec<i8>> {
        let lgen = Gen::usize_in(len);
        Gen::from_fn(move |r| {
            let n = lgen.sample(r);
            (0..n).map(|_| r.range_i64(-128, 127) as i8).collect()
        })
    }
}

/// Types that know how to propose smaller versions of themselves.
pub trait Shrink: Sized {
    /// Candidate shrinks, ordered most-aggressive first.
    fn shrinks(&self) -> Vec<Self>;
}

impl Shrink for Vec<f32> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let n = self.len();
        if n > 1 {
            out.push(self[..n / 2].to_vec());
            out.push(self[n / 2..].to_vec());
            out.push(self[..n - 1].to_vec());
        }
        // Zero out elements one at a time (first non-zero).
        if let Some(i) = self.iter().position(|&x| x != 0.0) {
            let mut v = self.clone();
            v[i] = 0.0;
            out.push(v);
        }
        out
    }
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for i64 {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(self / 2);
            if *self > 0 {
                out.push(self - 1);
            } else {
                out.push(self + 1);
            }
        }
        out
    }
}

/// Outcome of a property check on one input.
pub type PropResult = Result<(), String>;

/// Run `cases` random checks of `prop` over values from `gen`.
///
/// On failure the input is greedily shrunk (up to 200 shrink steps) and the
/// function panics with the minimal counterexample — the standard
/// property-test contract, usable directly inside `#[test]`s.
pub fn run<T>(name: &str, cases: u32, gen: Gen<T>, prop: impl Fn(&T) -> PropResult)
where
    T: Shrink + std::fmt::Debug + Clone + 'static,
{
    run_seeded(name, cases, 0xD1F_F05E, gen, prop)
}

/// [`run`] with an explicit seed (tests that must be stable across refactor).
pub fn run_seeded<T>(
    name: &str,
    cases: u32,
    seed: u64,
    gen: Gen<T>,
    prop: impl Fn(&T) -> PropResult,
) where
    T: Shrink + std::fmt::Debug + Clone + 'static,
{
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min, min_msg, steps) = shrink_loop(input, msg, &prop);
            panic!(
                "property '{name}' failed (case {case}/{cases}, shrunk {steps} steps)\n\
                 counterexample: {min:?}\nreason: {min_msg}"
            );
        }
    }
}

fn shrink_loop<T>(mut cur: T, mut msg: String, prop: &impl Fn(&T) -> PropResult) -> (T, String, u32)
where
    T: Shrink + Clone,
{
    let mut steps = 0;
    'outer: while steps < 200 {
        for cand in cur.shrinks() {
            if let Err(m) = prop(&cand) {
                cur = cand;
                msg = m;
                steps += 1;
                continue 'outer;
            }
        }
        break;
    }
    (cur, msg, steps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run("sum is finite", 64, Gen::vec_f32(0..=32, -5.0..5.0), |xs| {
            if xs.iter().sum::<f32>().is_finite() {
                Ok(())
            } else {
                Err("non-finite".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_counterexample() {
        run("always fails", 8, Gen::vec_f32(1..=8, 0.0..1.0), |_| Err("nope".into()));
    }

    #[test]
    fn shrinking_finds_small_case() {
        // Property: "no vector of length >= 3" — shrinker should land on
        // exactly length 3.
        let gen = Gen::vec_f32(10..=30, 0.0..1.0);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let input = gen.sample(&mut rng);
        let prop = |v: &Vec<f32>| if v.len() >= 3 { Err("too long".into()) } else { Ok(()) };
        let (min, _msg, _steps) = shrink_loop(input, "seed".into(), &prop);
        assert_eq!(min.len(), 3, "greedy shrink should reach minimal length");
    }

    #[test]
    fn generators_respect_ranges() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let g = Gen::usize_in(5..=9);
        for _ in 0..1000 {
            let v = g.sample(&mut rng);
            assert!((5..=9).contains(&v));
        }
        let g = Gen::i64_in(-3..=3);
        for _ in 0..1000 {
            let v = g.sample(&mut rng);
            assert!((-3..=3).contains(&v));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed| {
            let g = Gen::vec_f32(1..=4, -1.0..1.0);
            let mut r = Xoshiro256pp::seed_from_u64(seed);
            (0..8).map(|_| g.sample(&mut r)).collect::<Vec<_>>()
        };
        assert_eq!(collect(9), collect(9));
    }
}
