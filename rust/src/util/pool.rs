//! A scoped worker thread pool (rayon/tokio are not vendored).
//!
//! Four entry points:
//!
//! * [`ThreadPool`] — a long-lived pool with a shared work queue; the
//!   coordinator uses one pool to model host CPU cores driving IMAX lanes.
//! * [`LanePool`] — one single-threaded FIFO worker **per lane**: jobs
//!   submitted to the same lane run serially in submission order, jobs on
//!   different lanes run concurrently. This is the execution substrate of
//!   the coordinator's parallel shard path (see `DESIGN.md`, "Concurrency
//!   model").
//! * [`CompletionSlot`] — a one-shot rendezvous cell a worker fills and a
//!   caller blocks on; the coordinator parks one per in-flight shard.
//!   (Defined in [`crate::util::sync`] with the other blocking
//!   primitives; re-exported here for its long-standing callers.)
//! * [`parallel_chunks`] — fork-join helper: split an index range over N
//!   workers with `std::thread::scope`, used by the ggml matmul row loop.
//!
//! All blocking synchronization goes through the [`crate::util::sync`]
//! shim so the `conc-check` feature can witness it; the idle-barrier
//! protocol (`in_flight` + `done` condvar) is model-checked over every
//! bounded schedule by [`crate::check::models::PoolIdleModel`].

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::util::sync::{rank, Condvar, Mutex};

pub use crate::util::sync::CompletionSlot;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<QueueState>,
    cond: Condvar,
}

struct QueueState {
    pending: VecDeque<Job>,
    shutdown: bool,
}

/// Fixed-size pool of worker threads consuming a FIFO job queue.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
    done: Arc<(Mutex<()>, Condvar)>,
}

impl ThreadPool {
    /// Spawn a pool with `n` workers (`n >= 1`).
    pub fn new(n: usize) -> ThreadPool {
        assert!(n >= 1, "pool needs at least one worker");
        let queue = Arc::new(Queue {
            jobs: Mutex::ranked(
                rank::POOL_QUEUE,
                "pool.queue",
                QueueState { pending: VecDeque::new(), shutdown: false },
            ),
            cond: Condvar::new(),
        });
        let in_flight = Arc::new(AtomicUsize::new(0));
        let done = Arc::new((Mutex::ranked(rank::POOL_DONE, "pool.done", ()), Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for idx in 0..n {
            let q = Arc::clone(&queue);
            let fl = Arc::clone(&in_flight);
            let dn = Arc::clone(&done);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("imax-pool-{idx}"))
                    .spawn(move || loop {
                        let job = {
                            let mut st = q.jobs.lock();
                            loop {
                                if let Some(j) = st.pending.pop_front() {
                                    break j;
                                }
                                if st.shutdown {
                                    return;
                                }
                                st = q.cond.wait(st);
                            }
                        };
                        job();
                        if fl.fetch_sub(1, Ordering::AcqRel) == 1 {
                            // Notify while holding the done lock. An
                            // unlocked notify can fire in the window
                            // between `wait_idle` reading `in_flight`
                            // and parking — a lost wakeup that leaves
                            // the waiter blocked until the next job
                            // (PoolIdleModel's `unlocked_notify`
                            // mutant deadlocks on exactly this).
                            let (l, cv) = &*dn;
                            let _idle = l.lock();
                            cv.notify_all();
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { queue, workers, in_flight, done }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::AcqRel);
        let mut st = self.queue.jobs.lock();
        st.pending.push_back(Box::new(f));
        drop(st);
        self.queue.cond.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.done;
        let mut guard = lock.lock();
        while self.in_flight.load(Ordering::Acquire) != 0 {
            guard = cv.wait(guard);
        }
        drop(guard);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.queue.jobs.lock();
            st.shutdown = true;
        }
        self.queue.cond.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One serial FIFO worker thread per simulated lane.
///
/// The per-lane queue is the ordering guarantee of the parallel shard
/// path: jobs enqueued to one lane execute in exactly the order they were
/// submitted (so the lane's `LaneSim` state — cache LRU, CONF history,
/// cycle counters — evolves identically to sequential execution), while
/// jobs on *different* lanes overlap in wall-clock time.
///
/// ```
/// use imax_sd::util::pool::{CompletionSlot, LanePool};
///
/// let pool = LanePool::new(2);
/// let (a, b) = (CompletionSlot::new(), CompletionSlot::new());
/// let (fa, fb) = (a.clone(), b.clone());
/// pool.submit_to(0, move || fa.fill("lane0"));
/// pool.submit_to(1, move || fb.fill("lane1"));
/// assert_eq!((a.wait(), b.wait()), ("lane0", "lane1"));
/// pool.wait_idle();
/// ```
pub struct LanePool {
    lanes: Vec<ThreadPool>,
}

impl LanePool {
    /// Spawn one worker per lane (`lanes >= 1`).
    pub fn new(lanes: usize) -> LanePool {
        assert!(lanes >= 1, "lane pool needs at least one lane");
        LanePool { lanes: (0..lanes).map(|_| ThreadPool::new(1)).collect() }
    }

    /// Number of lane workers.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Enqueue a job on `lane`'s FIFO worker and return immediately.
    pub fn submit_to<F: FnOnce() + Send + 'static>(&self, lane: usize, f: F) {
        self.lanes[lane].submit(f);
    }

    /// Block until every lane's queue has drained.
    pub fn wait_idle(&self) {
        for lane in &self.lanes {
            lane.wait_idle();
        }
    }
}

/// Fork-join over `0..len` in `workers` contiguous chunks.
///
/// `f(chunk_start, chunk_end)` runs on its own scoped thread per chunk; the
/// call returns when all chunks complete. With `workers <= 1` (or tiny
/// ranges) it degrades to a plain call on the current thread.
pub fn parallel_chunks<F>(len: usize, workers: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if len == 0 {
        return;
    }
    let workers = workers.clamp(1, len);
    if workers == 1 {
        f(0, len);
        return;
    }
    let chunk = len.div_ceil(workers);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(len);
            if start >= end {
                break;
            }
            let fref = &f;
            scope.spawn(move || fref(start, end));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_everything() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn wait_idle_with_nothing_pending_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not deadlock
    }

    #[test]
    fn pool_reusable_after_wait() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_idle();
            assert_eq!(counter.load(Ordering::Relaxed), (round + 1) * 10);
        }
    }

    #[test]
    fn wait_idle_races_the_last_completion() {
        // Regression stress for the lost-wakeup fix: a single tiny job
        // per round maximizes the window between the worker's decrement
        // and the waiter's park. Before the locked notify, this test
        // could hang; the interleaving itself is proven impossible by
        // check::models::PoolIdleModel.
        let pool = ThreadPool::new(1);
        for _ in 0..200 {
            pool.submit(|| {});
            pool.wait_idle();
        }
    }

    #[test]
    fn lane_pool_preserves_per_lane_fifo_order() {
        // Each lane appends to its own log; per-lane order must be exactly
        // submission order no matter how the workers interleave globally.
        let pool = LanePool::new(3);
        let logs: Vec<Arc<Mutex<Vec<u64>>>> =
            (0..3).map(|_| Arc::new(Mutex::new(Vec::new()))).collect();
        for seq in 0..60u64 {
            let lane = (seq % 3) as usize;
            let log = Arc::clone(&logs[lane]);
            pool.submit_to(lane, move || log.lock().push(seq));
        }
        pool.wait_idle();
        for (lane, log) in logs.iter().enumerate() {
            let got = log.lock().clone();
            let want: Vec<u64> = (0..60).filter(|s| (s % 3) as usize == lane).collect();
            assert_eq!(got, want, "lane {lane} ran out of order");
        }
    }

    #[test]
    fn lane_pool_runs_lanes_concurrently() {
        // A job on lane 1 can complete while lane 0 is still blocked —
        // impossible on a single serial worker.
        let pool = LanePool::new(2);
        let gate = CompletionSlot::new();
        let fast = CompletionSlot::new();
        let (g, f) = (gate.clone(), fast.clone());
        pool.submit_to(0, move || {
            let _ = g.wait(); // parked until the test releases it
        });
        pool.submit_to(1, move || f.fill(1u8));
        assert_eq!(fast.wait(), 1, "lane 1 progressed past a blocked lane 0");
        gate.fill(0u8);
        pool.wait_idle();
    }

    #[test]
    fn parallel_chunks_covers_range_exactly_once() {
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(97, 8, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn parallel_chunks_degenerate_cases() {
        parallel_chunks(0, 4, |_, _| panic!("must not be called"));
        let n = AtomicU64::new(0);
        parallel_chunks(3, 16, |s, e| {
            n.fetch_add((e - s) as u64, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 3);
    }
}
