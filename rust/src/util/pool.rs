//! A scoped worker thread pool (rayon/tokio are not vendored).
//!
//! Two entry points:
//!
//! * [`ThreadPool`] — a long-lived pool with a work queue; the coordinator
//!   uses one pool to model host CPU cores driving IMAX lanes.
//! * [`parallel_chunks`] — fork-join helper: split an index range over N
//!   workers with `std::thread::scope`, used by the ggml matmul row loop.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<QueueState>,
    cond: Condvar,
}

struct QueueState {
    pending: VecDeque<Job>,
    shutdown: bool,
}

/// Fixed-size pool of worker threads consuming a FIFO job queue.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
    done: Arc<(Mutex<()>, Condvar)>,
}

impl ThreadPool {
    /// Spawn a pool with `n` workers (`n >= 1`).
    pub fn new(n: usize) -> ThreadPool {
        assert!(n >= 1, "pool needs at least one worker");
        let queue = Arc::new(Queue {
            jobs: Mutex::new(QueueState { pending: VecDeque::new(), shutdown: false }),
            cond: Condvar::new(),
        });
        let in_flight = Arc::new(AtomicUsize::new(0));
        let done = Arc::new((Mutex::new(()), Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for idx in 0..n {
            let q = Arc::clone(&queue);
            let fl = Arc::clone(&in_flight);
            let dn = Arc::clone(&done);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("imax-pool-{idx}"))
                    .spawn(move || loop {
                        let job = {
                            let mut st = q.jobs.lock().unwrap();
                            loop {
                                if let Some(j) = st.pending.pop_front() {
                                    break j;
                                }
                                if st.shutdown {
                                    return;
                                }
                                st = q.cond.wait(st).unwrap();
                            }
                        };
                        job();
                        if fl.fetch_sub(1, Ordering::AcqRel) == 1 {
                            let (_l, cv) = &*dn;
                            cv.notify_all();
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { queue, workers, in_flight, done }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::AcqRel);
        let mut st = self.queue.jobs.lock().unwrap();
        st.pending.push_back(Box::new(f));
        drop(st);
        self.queue.cond.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.done;
        let mut guard = lock.lock().unwrap();
        while self.in_flight.load(Ordering::Acquire) != 0 {
            guard = cv.wait(guard).unwrap();
        }
        drop(guard);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.queue.jobs.lock().unwrap();
            st.shutdown = true;
        }
        self.queue.cond.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Fork-join over `0..len` in `workers` contiguous chunks.
///
/// `f(chunk_start, chunk_end)` runs on its own scoped thread per chunk; the
/// call returns when all chunks complete. With `workers <= 1` (or tiny
/// ranges) it degrades to a plain call on the current thread.
pub fn parallel_chunks<F>(len: usize, workers: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if len == 0 {
        return;
    }
    let workers = workers.clamp(1, len);
    if workers == 1 {
        f(0, len);
        return;
    }
    let chunk = len.div_ceil(workers);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(len);
            if start >= end {
                break;
            }
            let fref = &f;
            scope.spawn(move || fref(start, end));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_everything() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn wait_idle_with_nothing_pending_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not deadlock
    }

    #[test]
    fn pool_reusable_after_wait() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_idle();
            assert_eq!(counter.load(Ordering::Relaxed), (round + 1) * 10);
        }
    }

    #[test]
    fn parallel_chunks_covers_range_exactly_once() {
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(97, 8, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn parallel_chunks_degenerate_cases() {
        parallel_chunks(0, 4, |_, _| panic!("must not be called"));
        let n = AtomicU64::new(0);
        parallel_chunks(3, 16, |s, e| {
            n.fetch_add((e - s) as u64, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 3);
    }
}
