//! The project's blocking-synchronization primitives: drop-in
//! [`Mutex`]/[`Condvar`]/[`CompletionSlot`] wrappers that every module
//! outside this file must use instead of `std::sync` (enforced by the
//! `raw-sync-primitive` rule in [`crate::check::lint`]).
//!
//! In the default build the wrappers are transparent shells over the
//! `std` types: no extra state, no extra branches on the lock path, and
//! the poison `Result` is collapsed at the shim boundary so call sites
//! never sprinkle `.unwrap()` (the `lock-poison-unwrap` lint rule bans
//! that everywhere). Under the **`conc-check`** feature every
//! acquire, release, condvar wait and notify additionally routes
//! through the process-global [`crate::check::lockorder`] witness,
//! which records the held-locks graph, fails on any potential-deadlock
//! edge pair (a cycle in acquisition order) and checks the declared
//! lock hierarchy below. `cargo test --features conc-check` therefore
//! turns the whole test suite into a lock-order regression harness.
//!
//! # Lock hierarchy
//!
//! Every long-lived lock in the system declares a **rank** from
//! [`rank`]; a thread may only acquire a lock of *strictly greater*
//! rank than any ranked lock it already holds (unranked ad-hoc locks,
//! rank 0, are exempt from the rank rule but still participate in
//! cycle detection). The full catalog — who holds what across which
//! calls — lives in `DESIGN.md`, "Lock hierarchy & invariants catalog".
//!
//! # Poisoning policy
//!
//! A poisoned lock means a thread panicked while holding it and the
//! guarded invariants are unknown. The project policy (see `DESIGN.md`):
//!
//! * [`Mutex::lock`] **panics** with the lock's name — the default for
//!   coordinator/serve internals, where the panic propagates into the
//!   owning test or scoped thread and surfaces the *original* failure.
//! * [`Mutex::lock_or_abort`] **aborts the process** — the rule for the
//!   server's request and drain paths, where a cascade of secondary
//!   poison panics would otherwise wedge the graceful drain (workers
//!   die one by one, `shutdown` hangs on a join) while the process
//!   keeps accepting traffic against corrupt state. An orchestrator
//!   restart is strictly better than either.

use std::sync::Arc;

#[cfg(feature = "conc-check")]
use crate::check::lockorder::{self, LockTag};
#[cfg(feature = "conc-check")]
use std::sync::atomic::{AtomicUsize, Ordering};

/// The declared lock hierarchy, lowest-to-highest. Acquire downward in
/// this list and the order is provably cycle-free; the conc-check
/// witness enforces strict rank increase on nested acquisition.
pub mod rank {
    /// Ad-hoc locks with no hierarchy position (exempt from the rank
    /// rule, still cycle-checked).
    pub const UNRANKED: u16 = 0;
    /// `server::Runner` prediction registry.
    pub const SERVER_REGISTRY: u16 = 10;
    /// `server::WebhookSender` delivery queue. Deliberately above the
    /// registry: the runner enqueues deliveries *after* dropping the
    /// registry lock, and the delivery workers never touch the
    /// registry, so the edge only ever points one way.
    pub const WEBHOOK_QUEUE: u16 = 15;
    /// `serve::RequestQueue` state.
    pub const SERVE_QUEUE: u16 = 20;
    /// `serve::SharedBatch` rendezvous state (held across the merged
    /// submission the round leader executes).
    pub const SERVE_BATCH: u16 = 30;
    /// `Coordinator` weight→lane affinity map.
    pub const COORD_AFFINITY: u16 = 40;
    /// One simulated lane's `LaneSim` (cache LRU, CONF history,
    /// cycle/byte ledgers).
    pub const IMAX_LANE: u16 = 50;
    /// A `ThreadPool` job queue (one per lane worker).
    pub const POOL_QUEUE: u16 = 60;
    /// A `ThreadPool`'s idle-barrier lock (`wait_idle`).
    pub const POOL_DONE: u16 = 70;
    /// A `CompletionSlot` cell — always a leaf.
    pub const SLOT: u16 = 80;
}

#[cfg(feature = "conc-check")]
struct LockMeta {
    id: AtomicUsize,
    rank: u16,
    name: &'static str,
}

#[cfg(feature = "conc-check")]
impl LockMeta {
    fn tag(&self) -> LockTag {
        let mut id = self.id.load(Ordering::Relaxed);
        if id == 0 {
            // Lazy identity so `new` stays const: first-use mint, and a
            // lost race just burns one id.
            let fresh = lockorder::mint_lock_id();
            match self.id.compare_exchange(0, fresh, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => id = fresh,
                Err(cur) => id = cur,
            }
        }
        LockTag { id, rank: self.rank, name: self.name }
    }
}

/// Mutual exclusion with project poisoning policy and (under
/// `conc-check`) lock-order witnessing. See the module docs.
pub struct Mutex<T> {
    inner: std::sync::Mutex<T>,
    #[cfg(feature = "conc-check")]
    meta: LockMeta,
}

impl<T> Mutex<T> {
    /// An unranked mutex (rank 0: exempt from the hierarchy rule,
    /// still cycle-checked under `conc-check`).
    pub const fn new(value: T) -> Mutex<T> {
        Mutex::ranked(rank::UNRANKED, "mutex", value)
    }

    /// A mutex at a declared position in the [`rank`] hierarchy.
    /// `name` labels witness reports and poison aborts.
    pub const fn ranked(rank: u16, name: &'static str, value: T) -> Mutex<T> {
        #[cfg(not(feature = "conc-check"))]
        let _ = (rank, name); // metadata only exists under conc-check
        Mutex {
            inner: std::sync::Mutex::new(value),
            #[cfg(feature = "conc-check")]
            meta: LockMeta { id: AtomicUsize::new(0), rank, name },
        }
    }

    /// Acquire. Panics (with the lock's name) if the lock is poisoned —
    /// the default policy outside the server's request/drain paths.
    #[cfg(not(feature = "conc-check"))]
    pub fn lock(&self) -> MutexGuard<'_, T> {
        match self.inner.lock() {
            Ok(g) => MutexGuard(g),
            Err(_) => panic!("poisoned mutex"),
        }
    }

    /// Acquire. Panics (with the lock's name) if the lock is poisoned —
    /// the default policy outside the server's request/drain paths.
    #[cfg(feature = "conc-check")]
    pub fn lock(&self) -> MutexGuard<'_, T> {
        let tag = self.meta.tag();
        lockorder::global().acquire(tag);
        match self.inner.lock() {
            Ok(g) => MutexGuard { inner: Some(g), meta: &self.meta },
            Err(_) => {
                lockorder::global().release(tag.id);
                panic!("poisoned mutex: {}", self.meta.name)
            }
        }
    }

    /// Acquire, or **abort the process** if the lock is poisoned — the
    /// mandated form in `server/` request and drain paths (see the
    /// `lock-poison-unwrap` lint rule), where secondary poison panics
    /// would cascade into a hung drain. See the module's poisoning
    /// policy notes.
    pub fn lock_or_abort(&self) -> MutexGuard<'_, T> {
        #[cfg(feature = "conc-check")]
        {
            let tag = self.meta.tag();
            lockorder::global().acquire(tag);
            match self.inner.lock() {
                Ok(g) => return MutexGuard { inner: Some(g), meta: &self.meta },
                Err(_) => {
                    eprintln!(
                        "fatal: poisoned mutex `{}` on a lifecycle path; aborting",
                        self.meta.name
                    );
                    std::process::abort();
                }
            }
        }
        #[cfg(not(feature = "conc-check"))]
        match self.inner.lock() {
            Ok(g) => MutexGuard(g),
            Err(_) => {
                eprintln!("fatal: poisoned mutex on a lifecycle path; aborting");
                std::process::abort();
            }
        }
    }

    /// Consume the mutex and return the protected value. Panics if a
    /// holder panicked (same policy as [`Mutex::lock`]).
    pub fn into_inner(self) -> T {
        match self.inner.into_inner() {
            Ok(v) => v,
            Err(_) => panic!("poisoned mutex at into_inner"),
        }
    }
}

/// Free-function spelling of [`Mutex::lock_or_abort`] for call sites
/// that read better with the policy name up front.
pub fn lock_or_abort<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock_or_abort()
}

/// RAII guard for [`Mutex`]. In the default build this is a transparent
/// newtype over `std::sync::MutexGuard` (no `Drop` impl of its own);
/// under `conc-check` dropping it reports the release to the witness.
#[cfg(not(feature = "conc-check"))]
pub struct MutexGuard<'a, T>(std::sync::MutexGuard<'a, T>);

/// RAII guard for [`Mutex`]. In the default build this is a transparent
/// newtype over `std::sync::MutexGuard` (no `Drop` impl of its own);
/// under `conc-check` dropping it reports the release to the witness.
#[cfg(feature = "conc-check")]
pub struct MutexGuard<'a, T> {
    /// `Some` while held; taken by [`Condvar::wait`] (which reports the
    /// release itself) so `Drop` only reports real releases.
    inner: Option<std::sync::MutexGuard<'a, T>>,
    meta: &'a LockMeta,
}

#[cfg(feature = "conc-check")]
impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if self.inner.is_some() {
            lockorder::global().release(self.meta.tag().id);
        }
    }
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        #[cfg(not(feature = "conc-check"))]
        {
            &self.0
        }
        #[cfg(feature = "conc-check")]
        {
            self.inner.as_ref().expect("guard present outside wait")
        }
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        #[cfg(not(feature = "conc-check"))]
        {
            &mut self.0
        }
        #[cfg(feature = "conc-check")]
        {
            self.inner.as_mut().expect("guard present outside wait")
        }
    }
}

/// Condition variable paired with [`Mutex`]. Project rule (enforced by
/// the `condvar-wait-loop` lint): every `wait` sits inside a
/// `loop`/`while` that re-checks its predicate under the lock —
/// wakeups may be spurious and `notify_all` wakes non-targets.
pub struct Condvar {
    inner: std::sync::Condvar,
}

impl Default for Condvar {
    fn default() -> Self {
        Condvar::new()
    }
}

impl Condvar {
    /// A fresh condition variable.
    pub const fn new() -> Condvar {
        Condvar { inner: std::sync::Condvar::new() }
    }

    /// Atomically release the guard's lock and block until notified;
    /// reacquires before returning. Panics on poison (a peer panicked
    /// while we slept).
    #[cfg(not(feature = "conc-check"))]
    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        match self.inner.wait(guard.0) {
            Ok(g) => MutexGuard(g),
            Err(_) => panic!("poisoned mutex at condvar wakeup"),
        }
    }

    /// Atomically release the guard's lock and block until notified;
    /// reacquires before returning. Panics on poison (a peer panicked
    /// while we slept).
    #[cfg(feature = "conc-check")]
    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        let meta = guard.meta;
        let tag = meta.tag();
        // The wait releases the lock: tell the witness so the blocked
        // interval doesn't hold a phantom edge source.
        lockorder::global().release(tag.id);
        let inner = guard.inner.take().expect("guard held before wait");
        drop(guard); // inner already taken: Drop reports nothing
        let inner = match self.inner.wait(inner) {
            Ok(g) => g,
            Err(_) => panic!("poisoned mutex at condvar wakeup: {}", meta.name),
        };
        lockorder::global().acquire(tag);
        MutexGuard { inner: Some(inner), meta }
    }

    /// Like [`Condvar::wait`] but with a timeout: reacquires the lock
    /// and additionally reports whether the timeout elapsed. The same
    /// project rule applies — call it inside a predicate loop; a timed
    /// wakeup proves nothing about the predicate.
    #[cfg(not(feature = "conc-check"))]
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: std::time::Duration,
    ) -> (MutexGuard<'a, T>, bool) {
        match self.inner.wait_timeout(guard.0, dur) {
            Ok((g, to)) => (MutexGuard(g), to.timed_out()),
            Err(_) => panic!("poisoned mutex at condvar wakeup"),
        }
    }

    /// Like [`Condvar::wait`] but with a timeout: reacquires the lock
    /// and additionally reports whether the timeout elapsed. The same
    /// project rule applies — call it inside a predicate loop; a timed
    /// wakeup proves nothing about the predicate.
    #[cfg(feature = "conc-check")]
    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
        dur: std::time::Duration,
    ) -> (MutexGuard<'a, T>, bool) {
        let meta = guard.meta;
        let tag = meta.tag();
        // Same witness protocol as `wait`: the blocked interval does
        // not hold the lock.
        lockorder::global().release(tag.id);
        let inner = guard.inner.take().expect("guard held before wait");
        drop(guard); // inner already taken: Drop reports nothing
        let (inner, timed_out) = match self.inner.wait_timeout(inner, dur) {
            Ok((g, to)) => (g, to.timed_out()),
            Err(_) => panic!("poisoned mutex at condvar wakeup: {}", meta.name),
        };
        lockorder::global().acquire(tag);
        (MutexGuard { inner: Some(inner), meta }, timed_out)
    }

    /// Wake one waiter.
    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    /// Wake every waiter.
    pub fn notify_all(&self) {
        self.inner.notify_all();
    }
}

/// A one-shot completion cell: a producer thread
/// [`fill`](CompletionSlot::fill)s it exactly once, a consumer
/// [`wait`](CompletionSlot::wait)s until the value arrives and takes
/// it. Clones share the same cell.
///
/// The coordinator parks one slot per in-flight shard: the lane worker
/// fills the slot with the shard's `(output, phases, cache delta)` and
/// the join side blocks on the slots **in shard order**, which is what
/// keeps counter merging deterministic under any thread interleaving.
/// The submit/sync protocol (including out-of-order sync) is
/// model-checked over every bounded schedule by
/// [`crate::check::models::SlotModel`].
///
/// ```
/// use imax_sd::util::sync::CompletionSlot;
///
/// let slot = CompletionSlot::new();
/// let producer = slot.clone();
/// let t = std::thread::spawn(move || producer.fill(6 * 7));
/// assert_eq!(slot.wait(), 42); // blocks until the producer fills it
/// t.join().unwrap();
/// ```
pub struct CompletionSlot<T> {
    cell: Arc<Cell<T>>,
}

struct Cell<T> {
    value: Mutex<Option<T>>,
    cv: Condvar,
}

impl<T> Clone for CompletionSlot<T> {
    fn clone(&self) -> Self {
        CompletionSlot { cell: Arc::clone(&self.cell) }
    }
}

impl<T> Default for CompletionSlot<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> CompletionSlot<T> {
    /// An empty slot.
    pub fn new() -> CompletionSlot<T> {
        CompletionSlot {
            cell: Arc::new(Cell {
                value: Mutex::ranked(rank::SLOT, "sync.slot", None),
                cv: Condvar::new(),
            }),
        }
    }

    /// Deposit the value and wake the waiter. Filling twice is a bug in
    /// the producer (the slot is one-shot) and panics.
    pub fn fill(&self, value: T) {
        let mut cell = self.cell.value.lock();
        assert!(cell.is_none(), "CompletionSlot filled twice");
        *cell = Some(value);
        // Notify while still holding the lock: a waiter is then either
        // before its predicate check (and will see the value) or parked
        // (and gets the wakeup) — never between the two.
        self.cell.cv.notify_all();
    }

    /// Block until the value arrives and take it. A slot that was
    /// already filled returns immediately — the sequential (pool-less)
    /// path fills slots inline at submit time and `wait` degrades to a
    /// take.
    pub fn wait(&self) -> T {
        let mut cell = self.cell.value.lock();
        loop {
            if let Some(v) = cell.take() {
                return v;
            }
            cell = self.cell.cv.wait(cell);
        }
    }
}

/// A one-byte first-cause-wins state cell — the single compare-exchange
/// primitive in the codebase, kept here so the raw-atomic protocol has
/// one audited home ([`crate::util::cancel::CancelToken`] is its only
/// production user; [`crate::check::models::CancelModel`] proves the
/// exactly-one-terminal-cause property over every bounded schedule).
pub struct StateCell {
    bits: std::sync::atomic::AtomicU8,
}

impl StateCell {
    /// A cell holding `initial`.
    pub const fn new(initial: u8) -> StateCell {
        StateCell { bits: std::sync::atomic::AtomicU8::new(initial) }
    }

    /// Current state (acquire ordering: observations of the terminal
    /// state happen-after the transition that installed it).
    pub fn load(&self) -> u8 {
        self.bits.load(std::sync::atomic::Ordering::Acquire)
    }

    /// One-shot transition `from → to`; returns whether **this call**
    /// performed it. A lost race leaves the winner's value in place —
    /// first cause wins, later transitions from the same `from` fail.
    pub fn transition(&self, from: u8, to: u8) -> bool {
        self.bits
            .compare_exchange(
                from,
                to,
                std::sync::atomic::Ordering::AcqRel,
                std::sync::atomic::Ordering::Acquire,
            )
            .is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutex_shim_locks_and_releases() {
        let m = Mutex::new(1u32);
        {
            let mut g = m.lock();
            *g += 1;
        }
        assert_eq!(*m.lock(), 2);
        assert_eq!(m.into_inner(), 2);
    }

    #[test]
    fn ranked_mutex_is_const_constructible() {
        static GLOBAL: Mutex<u64> = Mutex::ranked(rank::UNRANKED, "test.static", 7);
        assert_eq!(*GLOBAL.lock(), 7);
    }

    #[test]
    fn lock_or_abort_behaves_like_lock_when_healthy() {
        let m = Mutex::ranked(rank::SERVER_REGISTRY, "test.registry", vec![1, 2]);
        lock_or_abort(&m).push(3);
        assert_eq!(m.lock_or_abort().len(), 3);
    }

    #[test]
    fn condvar_wait_reacquires_with_state_visible() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let t = std::thread::spawn(move || {
            let (m, cv) = &*p2;
            let mut ready = m.lock();
            *ready = true;
            cv.notify_all();
            drop(ready);
        });
        let (m, cv) = &*pair;
        let mut ready = m.lock();
        while !*ready {
            ready = cv.wait(ready);
        }
        drop(ready);
        t.join().unwrap();
    }

    #[test]
    fn condvar_wait_timeout_times_out_and_reacquires() {
        let m = Mutex::new(0u32);
        let cv = Condvar::new();
        let mut g = m.lock();
        let mut timed_out = false;
        while *g == 0 {
            let (g2, to) = cv.wait_timeout(g, std::time::Duration::from_millis(5));
            g = g2;
            if to {
                timed_out = true;
                break;
            }
        }
        assert!(timed_out, "nobody notifies: the wait must time out");
        *g = 9; // the guard is live again after the timed-out wait
        drop(g);
        assert_eq!(*m.lock(), 9);
    }

    #[test]
    fn poisoned_lock_panics_with_policy_message() {
        let m = Arc::new(Mutex::new(0u8));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison it");
        })
        .join();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = m.lock();
        }))
        .expect_err("lock on a poisoned mutex must panic");
        let msg = err
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| err.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("poisoned mutex"), "got: {msg}");
    }

    #[test]
    fn state_cell_first_cause_wins() {
        let c = StateCell::new(0);
        assert!(c.transition(0, 1));
        assert!(!c.transition(0, 2), "second cause loses");
        assert_eq!(c.load(), 1);
    }

    #[test]
    fn completion_slot_passes_value_across_threads() {
        let slot = CompletionSlot::new();
        let producer = slot.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            producer.fill(vec![1u8, 2, 3]);
        });
        assert_eq!(slot.wait(), vec![1, 2, 3]);
        t.join().unwrap();
    }

    #[test]
    fn completion_slot_prefilled_returns_immediately() {
        let slot = CompletionSlot::new();
        slot.fill(7u32);
        assert_eq!(slot.wait(), 7);
    }

    #[test]
    #[should_panic(expected = "filled twice")]
    fn completion_slot_rejects_double_fill() {
        let slot = CompletionSlot::new();
        slot.fill(1u8);
        slot.fill(2u8);
    }
}
