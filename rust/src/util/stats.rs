//! Summary statistics for the bench harness and profiler.

/// Summary of a sample of measurements (times in seconds, or any unit).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Number of samples the statistics were computed over (NaN inputs
    /// are excluded; see [`Summary::nan`]).
    pub n: usize,
    /// Number of NaN inputs dropped before computing the statistics. A
    /// NaN latency sample (e.g. a clock anomaly) must degrade the
    /// report, not panic it at shutdown.
    pub nan: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Median (50th percentile, linear interpolation).
    pub median: f64,
    /// Sample standard deviation (n-1 denominator; 0 for n < 2).
    pub std_dev: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile (the serving tail-latency figure).
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample. NaN inputs are
    /// filtered out and counted in [`Summary::nan`] instead of
    /// poisoning the sort (a `partial_cmp(..).unwrap()` here used to
    /// panic the whole metrics path on one bad sample); if *every*
    /// input is NaN the summary is all-zero with `n == 0`.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "summary of empty sample");
        let mut sorted: Vec<f64> = samples.iter().copied().filter(|x| !x.is_nan()).collect();
        let nan = samples.len() - sorted.len();
        let n = sorted.len();
        if n == 0 {
            return Summary {
                n: 0,
                nan,
                mean: 0.0,
                min: 0.0,
                max: 0.0,
                median: 0.0,
                std_dev: 0.0,
                p95: 0.0,
                p99: 0.0,
            };
        }
        sorted.sort_by(f64::total_cmp);
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            nan,
            mean,
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile(&sorted, 50.0),
            std_dev: var.sqrt(),
            p95: percentile(&sorted, 95.0),
            p99: percentile(&sorted, 99.0),
        }
    }

    /// Relative std dev (coefficient of variation); 0 when mean is 0.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std_dev / self.mean
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice. The
/// caller is responsible for filtering NaN before sorting (as
/// [`Summary::of`] does): a NaN in the slice makes any "sorted" claim
/// meaningless, which is a caller bug, not a data condition.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    debug_assert!(sorted.iter().all(|x| !x.is_nan()), "percentile over NaN samples");
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean of strictly-positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive inputs");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Human-readable duration: picks ns/µs/ms/s.
pub fn fmt_duration(seconds: f64) -> String {
    let abs = seconds.abs();
    if abs >= 1.0 {
        format!("{seconds:.3} s")
    } else if abs >= 1e-3 {
        format!("{:.3} ms", seconds * 1e3)
    } else if abs >= 1e-6 {
        format!("{:.3} µs", seconds * 1e6)
    } else {
        format!("{:.1} ns", seconds * 1e9)
    }
}

/// Human-readable byte count.
pub fn fmt_bytes(bytes: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", v as u64, UNITS[u])
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
        assert!((s.std_dev - 1.5811388).abs() < 1e-6);
        assert!((s.p99 - 4.96).abs() < 1e-9, "p99 interpolates the tail");
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.p95, 7.0);
        assert_eq!(s.p99, 7.0);
    }

    #[test]
    fn summary_drops_nan_instead_of_panicking() {
        // Regression: `sort_by(partial_cmp().unwrap())` panicked on one
        // NaN sample, taking the whole metrics report down with it.
        let s = Summary::of(&[1.0, f64::NAN, 3.0]);
        assert_eq!(s.n, 2);
        assert_eq!(s.nan, 1);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
    }

    #[test]
    fn summary_of_all_nan_is_zeroed_not_a_panic() {
        let s = Summary::of(&[f64::NAN, f64::NAN]);
        assert_eq!(s.n, 0);
        assert_eq!(s.nan, 2);
        assert_eq!(s.mean, 0.0);
        assert_eq!(s.p99, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile(&v, 50.0), 5.0);
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 100.0), 10.0);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.5), "2.500 s");
        assert_eq!(fmt_duration(0.0025), "2.500 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500 µs");
        assert_eq!(fmt_duration(2.5e-9), "2.5 ns");
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert_eq!(fmt_bytes(3.5 * 1024.0 * 1024.0), "3.50 MiB");
    }
}
