//! Declarative command-line parsing (clap is not vendored in this image).
//!
//! Supports the subset the `imax-sd` binary needs: subcommands, long/short
//! flags, `--key value` and `--key=value` options, typed accessors with
//! defaults, required options, auto-generated `--help`, and unknown-flag
//! errors.
//!
//! ```no_run
//! use imax_sd::util::cli::{App, Arg};
//! let app = App::new("imax-sd", "IMAX3 Stable-Diffusion reproduction")
//!     .subcommand(
//!         App::new("generate", "Generate an image")
//!             .arg(Arg::opt("prompt", 'p', "PROMPT", "text prompt").default("a lovely cat"))
//!             .arg(Arg::flag("verbose", 'v', "chatty output")),
//!     );
//! ```

use std::collections::BTreeMap;
use std::fmt;

/// Error produced by the parser; rendered to the user with usage text.
#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Kind of argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ArgKind {
    /// Boolean presence flag (`--verbose`).
    Flag,
    /// Option taking a value (`--steps 4`).
    Opt,
    /// Positional argument.
    Positional,
}

/// Declaration of a single argument.
#[derive(Debug, Clone)]
pub struct Arg {
    name: &'static str,
    short: Option<char>,
    value_name: &'static str,
    help: &'static str,
    kind: ArgKind,
    default: Option<String>,
    required: bool,
}

impl Arg {
    /// A boolean flag: `--name` / `-s`.
    pub fn flag(name: &'static str, short: char, help: &'static str) -> Arg {
        Arg {
            name,
            short: if short == '\0' { None } else { Some(short) },
            value_name: "",
            help,
            kind: ArgKind::Flag,
            default: None,
            required: false,
        }
    }

    /// A valued option: `--name VALUE` / `-s VALUE` / `--name=VALUE`.
    pub fn opt(name: &'static str, short: char, value_name: &'static str, help: &'static str) -> Arg {
        Arg {
            name,
            short: if short == '\0' { None } else { Some(short) },
            value_name,
            help,
            kind: ArgKind::Opt,
            default: None,
            required: false,
        }
    }

    /// A positional argument, matched in declaration order.
    pub fn positional(name: &'static str, help: &'static str) -> Arg {
        Arg {
            name,
            short: None,
            value_name: "",
            help,
            kind: ArgKind::Positional,
            default: None,
            required: false,
        }
    }

    /// Provide a default value (implies not required).
    pub fn default(mut self, v: &str) -> Arg {
        self.default = Some(v.to_string());
        self
    }

    /// Mark the option as required.
    pub fn required(mut self) -> Arg {
        self.required = true;
        self
    }
}

/// An application or subcommand definition.
#[derive(Debug, Clone)]
pub struct App {
    name: &'static str,
    about: &'static str,
    args: Vec<Arg>,
    subs: Vec<App>,
}

impl App {
    /// New (sub)command with a one-line description.
    pub fn new(name: &'static str, about: &'static str) -> App {
        App { name, about, args: Vec::new(), subs: Vec::new() }
    }

    /// Add an argument declaration.
    pub fn arg(mut self, a: Arg) -> App {
        self.args.push(a);
        self
    }

    /// Add a batch of argument declarations (e.g.
    /// [`BackendFlags::args`]).
    pub fn args(mut self, list: Vec<Arg>) -> App {
        self.args.extend(list);
        self
    }

    /// Add a subcommand.
    pub fn subcommand(mut self, s: App) -> App {
        self.subs.push(s);
        self
    }

    /// Render `--help` text.
    pub fn help_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("{} — {}\n\nUSAGE:\n    {} ", self.name, self.about, self.name));
        if !self.subs.is_empty() {
            out.push_str("<SUBCOMMAND> ");
        }
        out.push_str("[OPTIONS]");
        for a in self.args.iter().filter(|a| a.kind == ArgKind::Positional) {
            out.push_str(&format!(" <{}>", a.name));
        }
        out.push('\n');
        if !self.args.is_empty() {
            out.push_str("\nOPTIONS:\n");
            for a in &self.args {
                let short = a.short.map(|c| format!("-{c}, ")).unwrap_or_else(|| "    ".into());
                let left = match a.kind {
                    ArgKind::Flag => format!("{short}--{}", a.name),
                    ArgKind::Opt => format!("{short}--{} <{}>", a.name, a.value_name),
                    ArgKind::Positional => format!("<{}>", a.name),
                };
                let def = a
                    .default
                    .as_ref()
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                out.push_str(&format!("    {left:<36} {}{def}\n", a.help));
            }
        }
        if !self.subs.is_empty() {
            out.push_str("\nSUBCOMMANDS:\n");
            for s in &self.subs {
                out.push_str(&format!("    {:<20} {}\n", s.name, s.about));
            }
        }
        out
    }

    /// Parse an argv slice (excluding the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Matches, CliError> {
        let mut m = Matches {
            command: self.name.to_string(),
            values: BTreeMap::new(),
            flags: Vec::new(),
            sub: None,
        };
        // Seed defaults.
        for a in &self.args {
            if let Some(d) = &a.default {
                m.values.insert(a.name.to_string(), d.clone());
            }
        }
        let mut positionals: Vec<&Arg> =
            self.args.iter().filter(|a| a.kind == ArgKind::Positional).collect();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(CliError(self.help_text()));
            }
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                let a = self
                    .args
                    .iter()
                    .find(|a| a.name == key)
                    .ok_or_else(|| CliError(format!("unknown flag --{key}\n\n{}", self.help_text())))?;
                match a.kind {
                    ArgKind::Flag => {
                        if inline.is_some() {
                            return Err(CliError(format!("flag --{key} takes no value")));
                        }
                        m.flags.push(a.name.to_string());
                    }
                    ArgKind::Opt => {
                        let v = match inline {
                            Some(v) => v,
                            None => {
                                i += 1;
                                argv.get(i)
                                    .cloned()
                                    .ok_or_else(|| CliError(format!("--{key} needs a value")))?
                            }
                        };
                        m.values.insert(a.name.to_string(), v);
                    }
                    ArgKind::Positional => unreachable!(),
                }
            } else if let Some(short) = tok.strip_prefix('-').filter(|s| s.len() == 1) {
                let c = short.chars().next().unwrap();
                let a = self
                    .args
                    .iter()
                    .find(|a| a.short == Some(c))
                    .ok_or_else(|| CliError(format!("unknown flag -{c}\n\n{}", self.help_text())))?;
                match a.kind {
                    ArgKind::Flag => m.flags.push(a.name.to_string()),
                    ArgKind::Opt => {
                        i += 1;
                        let v = argv
                            .get(i)
                            .cloned()
                            .ok_or_else(|| CliError(format!("-{c} needs a value")))?;
                        m.values.insert(a.name.to_string(), v);
                    }
                    ArgKind::Positional => unreachable!(),
                }
            } else if let Some(sub) = self.subs.iter().find(|s| s.name == *tok) {
                let inner = sub.parse(&argv[i + 1..])?;
                m.sub = Some(Box::new(inner));
                break;
            } else if !positionals.is_empty() {
                let a = positionals.remove(0);
                m.values.insert(a.name.to_string(), tok.clone());
            } else {
                return Err(CliError(format!(
                    "unexpected argument '{tok}'\n\n{}",
                    self.help_text()
                )));
            }
            i += 1;
        }
        // Required check (only when no subcommand consumed the tail).
        if m.sub.is_none() {
            for a in &self.args {
                if a.required && !m.values.contains_key(a.name) {
                    return Err(CliError(format!("missing required option --{}", a.name)));
                }
            }
        }
        Ok(m)
    }

    /// Parse `std::env::args()`, printing help/errors and exiting on failure.
    pub fn parse_env(&self) -> Matches {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&argv) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }
}

/// Parse result: typed accessors over matched values.
#[derive(Debug)]
pub struct Matches {
    /// Name of the command these matches belong to.
    pub command: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// The matched subcommand, if any.
    pub sub: Option<Box<Matches>>,
}

impl Matches {
    /// Raw string value of an option (default-filled if declared).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// String value, panicking if absent (use for defaulted/required opts).
    pub fn str(&self, name: &str) -> &str {
        self.get(name)
            .unwrap_or_else(|| panic!("option --{name} missing (declare a default)"))
    }

    /// Typed parse of an option value.
    pub fn parse_as<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError>
    where
        T::Err: fmt::Display,
    {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError(format!("option --{name} missing")))?;
        raw.parse::<T>()
            .map_err(|e| CliError(format!("--{name}={raw}: {e}")))
    }

    /// `usize` accessor.
    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        self.parse_as(name)
    }

    /// `u64` accessor.
    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        self.parse_as(name)
    }

    /// `f64` accessor.
    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.parse_as(name)
    }

    /// Whether a boolean flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

// ---------------------------------------------------------------------------
// Shared backend-selection flag set
// ---------------------------------------------------------------------------

/// Which execution backend a CLI run selects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Host GGML kernels only.
    Host,
    /// One IMAX lane (paper §III-B policy).
    Imax,
    /// Multi-lane coordinator with single-op row-tile sharding.
    Sharded,
}

/// Parsed backend selection shared by `imax-sd` and the serve demo —
/// one definition of `--backend`/`--lanes`/`--threads`/`--lmm-cache`/
/// `--no-weight-cache` so every binary exposes the same knobs. One
/// semantic caveat is inherent: the `imax` pipeline backend is
/// single-lane by construction, so `--lanes` only takes effect for the
/// sharded backend and for serving (whose coordinator always has a lane
/// pool) — the flag help says so.
#[derive(Debug, Clone)]
pub struct BackendSel {
    /// Selected backend.
    pub kind: BackendKind,
    /// IMAX lanes (sharded backend / serving coordinator).
    pub lanes: usize,
    /// Host threads (marshalling + residual ops). Values above 1 also
    /// enable the coordinator's lane worker pool — sharded submissions
    /// execute their shards concurrently, with bit-identical outputs
    /// and counters.
    pub threads: usize,
    /// Per-lane LMM bytes reserved as resident weight cache (0 =
    /// residency disabled, the paper's stream-every-call baseline).
    pub cache_bytes: usize,
    /// Offload F16 `ConvIm2col` GEMMs to the lanes via OP_SML16
    /// (`--conv-offload on`, the default). `off` keeps the paper's
    /// §III-B quantized-only routing with convs on the host.
    pub conv_offload: bool,
}

/// The shared flag declarations. Append these to any [`App`] that runs
/// the pipeline or the serving stack, then parse with
/// [`BackendFlags::parse`].
pub struct BackendFlags;

impl BackendFlags {
    /// Flag declarations (`--backend host|imax|sharded`, `--lanes N`,
    /// `--threads N`, `--lmm-cache BYTES`, `--no-weight-cache`).
    pub fn args() -> Vec<Arg> {
        vec![
            Arg::opt("backend", 'b', "KIND", "execution backend: host, imax or sharded")
                .default("imax"),
            Arg::opt(
                "lanes",
                'l',
                "N",
                "IMAX lanes (sharded backend and serving; the single-lane imax pipeline ignores it)",
            )
            .default("2"),
            Arg::opt(
                "threads",
                't',
                "N",
                "host threads for marshalling + residual ops; >1 also enables the \
                 per-lane worker pool (sharded backend: shards run concurrently, \
                 bit-identical to --threads 1)",
            )
            .default("2"),
            Arg::opt("lmm-cache", 'c', "BYTES", "LMM bytes reserved as resident weight cache")
                .default("262144"),
            Arg::flag(
                "no-weight-cache",
                '\0',
                "disable weight residency (stream every weight tile, paper baseline)",
            ),
            Arg::opt(
                "conv-offload",
                '\0',
                "on|off",
                "offload F16 conv (im2col) GEMMs to the lanes via OP_SML16; \
                 off = paper quantized-only routing (convs on host)",
            )
            .default("on"),
        ]
    }

    /// Parse the shared flags out of a [`Matches`].
    pub fn parse(m: &Matches) -> Result<BackendSel, CliError> {
        let kind = match m.str("backend") {
            "host" => BackendKind::Host,
            "imax" => BackendKind::Imax,
            "sharded" => BackendKind::Sharded,
            other => {
                return Err(CliError(format!(
                    "--backend={other}: expected host, imax or sharded"
                )))
            }
        };
        let lanes = m.usize("lanes")?;
        if !(1..=crate::imax::MAX_LANES).contains(&lanes) {
            return Err(CliError(format!(
                "--lanes={lanes}: the prototype supports 1..={} lanes",
                crate::imax::MAX_LANES
            )));
        }
        let threads = m.usize("threads")?;
        if threads == 0 {
            return Err(CliError("--threads=0: at least one host thread".into()));
        }
        let cache_bytes = if m.flag("no-weight-cache") { 0 } else { m.usize("lmm-cache")? };
        let conv_offload = match m.str("conv-offload") {
            "on" => true,
            "off" => false,
            other => {
                return Err(CliError(format!("--conv-offload={other}: expected on or off")))
            }
        };
        Ok(BackendSel { kind, lanes, threads, cache_bytes, conv_offload })
    }
}

impl BackendSel {
    /// The [`crate::coordinator::OffloadPolicy`] this selection routes
    /// with (`--conv-offload on` → `QuantizedAndConv`).
    pub fn policy(&self) -> crate::coordinator::OffloadPolicy {
        if self.conv_offload {
            crate::coordinator::OffloadPolicy::QuantizedAndConv
        } else {
            crate::coordinator::OffloadPolicy::QuantizedOnly
        }
    }

    /// The IMAX configuration this selection describes (FPGA prototype
    /// with the chosen lane count and cache partition).
    pub fn imax_config(&self) -> crate::imax::ImaxConfig {
        let lanes = match self.kind {
            BackendKind::Sharded => self.lanes,
            _ => 1,
        };
        let mut imax = crate::imax::ImaxConfig::fpga(lanes);
        imax.weight_cache_bytes = self.cache_bytes;
        imax
    }

    /// The pipeline [`crate::sd::pipeline::Backend`] this selection maps
    /// to.
    pub fn pipeline_backend(&self) -> crate::sd::pipeline::Backend {
        use crate::sd::pipeline::Backend;
        match self.kind {
            BackendKind::Host => Backend::Host { threads: self.threads },
            BackendKind::Imax => {
                Backend::Imax { config: self.imax_config(), threads: self.threads }
            }
            BackendKind::Sharded => {
                Backend::Sharded { config: self.imax_config(), threads: self.threads }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("t", "test app")
            .arg(Arg::opt("steps", 's', "N", "denoise steps").default("1"))
            .arg(Arg::flag("verbose", 'v', "chatty"))
            .subcommand(
                App::new("gen", "generate")
                    .arg(Arg::opt("prompt", 'p', "P", "prompt").default("a lovely cat"))
                    .arg(Arg::opt("seed", '\0', "S", "seed").required()),
            )
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let m = app().parse(&argv(&[])).unwrap();
        assert_eq!(m.str("steps"), "1");
        assert!(!m.flag("verbose"));
    }

    #[test]
    fn long_and_short_and_equals() {
        let m = app().parse(&argv(&["--steps", "4", "-v"])).unwrap();
        assert_eq!(m.usize("steps").unwrap(), 4);
        assert!(m.flag("verbose"));
        let m = app().parse(&argv(&["--steps=8"])).unwrap();
        assert_eq!(m.usize("steps").unwrap(), 8);
        let m = app().parse(&argv(&["-s", "2"])).unwrap();
        assert_eq!(m.usize("steps").unwrap(), 2);
    }

    #[test]
    fn subcommand_dispatch() {
        let m = app()
            .parse(&argv(&["gen", "--prompt", "hi there", "--seed", "7"]))
            .unwrap();
        let sub = m.sub.expect("sub");
        assert_eq!(sub.command, "gen");
        assert_eq!(sub.str("prompt"), "hi there");
        assert_eq!(sub.u64("seed").unwrap(), 7);
    }

    #[test]
    fn required_enforced() {
        let err = app().parse(&argv(&["gen"])).unwrap_err();
        assert!(err.0.contains("--seed"), "{}", err.0);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(app().parse(&argv(&["--nope"])).is_err());
        assert!(app().parse(&argv(&["-z"])).is_err());
    }

    #[test]
    fn bad_typed_value() {
        let m = app().parse(&argv(&["--steps", "abc"])).unwrap();
        assert!(m.usize("steps").is_err());
    }

    #[test]
    fn help_contains_everything() {
        let h = app().help_text();
        for needle in ["--steps", "--verbose", "gen", "test app"] {
            assert!(h.contains(needle), "help missing {needle}: {h}");
        }
    }

    fn backend_app() -> App {
        App::new("b", "backend test").args(BackendFlags::args())
    }

    #[test]
    fn backend_flags_defaults() {
        let m = backend_app().parse(&argv(&[])).unwrap();
        let sel = BackendFlags::parse(&m).unwrap();
        assert_eq!(sel.kind, BackendKind::Imax);
        assert_eq!(sel.lanes, 2);
        assert_eq!(sel.threads, 2);
        assert_eq!(sel.cache_bytes, 262144);
        assert!(sel.conv_offload, "conv offload defaults to on");
        assert_eq!(sel.policy(), crate::coordinator::OffloadPolicy::QuantizedAndConv);
        assert_eq!(sel.imax_config().lanes, 1, "non-sharded backends use one lane");
    }

    #[test]
    fn backend_flags_conv_offload_off_selects_quantized_only() {
        let m = backend_app().parse(&argv(&["--conv-offload", "off"])).unwrap();
        let sel = BackendFlags::parse(&m).unwrap();
        assert!(!sel.conv_offload);
        assert_eq!(sel.policy(), crate::coordinator::OffloadPolicy::QuantizedOnly);
        let m = backend_app().parse(&argv(&["--conv-offload=maybe"])).unwrap();
        assert!(BackendFlags::parse(&m).is_err(), "only on|off are accepted");
    }

    #[test]
    fn backend_flags_sharded_selection() {
        let m = backend_app()
            .parse(&argv(&["--backend", "sharded", "--lanes", "4", "--lmm-cache", "65536"]))
            .unwrap();
        let sel = BackendFlags::parse(&m).unwrap();
        assert_eq!(sel.kind, BackendKind::Sharded);
        let imax = sel.imax_config();
        assert_eq!(imax.lanes, 4);
        assert_eq!(imax.weight_cache_bytes, 65536);
        assert!(matches!(
            sel.pipeline_backend(),
            crate::sd::pipeline::Backend::Sharded { .. }
        ));
    }

    #[test]
    fn backend_flags_no_weight_cache_wins() {
        let m = backend_app()
            .parse(&argv(&["--no-weight-cache", "--lmm-cache", "123"]))
            .unwrap();
        assert_eq!(BackendFlags::parse(&m).unwrap().cache_bytes, 0);
    }

    #[test]
    fn backend_flags_reject_bad_values() {
        let m = backend_app().parse(&argv(&["--backend", "gpu"])).unwrap();
        assert!(BackendFlags::parse(&m).is_err());
        let m = backend_app().parse(&argv(&["--lanes", "9"])).unwrap();
        assert!(BackendFlags::parse(&m).is_err());
        let m = backend_app().parse(&argv(&["--threads", "0"])).unwrap();
        assert!(BackendFlags::parse(&m).is_err());
    }
}
