//! Minimal PNG encoder for Fig. 5 outputs (no `image`/`png` crate vendored).
//!
//! Writes 8-bit RGB or grayscale PNGs using *stored* (uncompressed) DEFLATE
//! blocks inside a zlib stream. Stored blocks are valid DEFLATE, decode in
//! every viewer, and keep the encoder dependency-free; the 512×512 RGB
//! outputs are ~790 KB, which is fine for experiment artifacts.

use std::io::Write;
use std::path::Path;

/// Pixel layout of the buffer handed to [`write_png`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColorType {
    /// 1 byte per pixel.
    Gray,
    /// 3 bytes per pixel, R then G then B.
    Rgb,
}

impl ColorType {
    fn png_code(self) -> u8 {
        match self {
            ColorType::Gray => 0,
            ColorType::Rgb => 2,
        }
    }

    /// Bytes per pixel.
    pub fn bpp(self) -> usize {
        match self {
            ColorType::Gray => 1,
            ColorType::Rgb => 3,
        }
    }
}

/// CRC-32 (IEEE 802.3), bit-reflected, as PNG requires.
pub fn crc32(data: &[u8]) -> u32 {
    // Build the table lazily once (std::sync::OnceLock keeps this
    // dependency-free; the vendored crate set has no once_cell).
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (n, slot) in t.iter_mut().enumerate() {
            let mut c = n as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Adler-32 checksum for the zlib wrapper.
pub fn adler32(data: &[u8]) -> u32 {
    const MOD: u32 = 65521;
    let (mut a, mut b) = (1u32, 0u32);
    for chunk in data.chunks(5552) {
        for &x in chunk {
            a += x as u32;
            b += a;
        }
        a %= MOD;
        b %= MOD;
    }
    (b << 16) | a
}

/// Wrap raw bytes in a zlib stream of stored DEFLATE blocks.
pub fn zlib_stored(raw: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(raw.len() + raw.len() / 65535 * 5 + 16);
    out.push(0x78); // CMF: deflate, 32K window
    out.push(0x01); // FLG: fastest, check bits valid
    let mut chunks = raw.chunks(65535).peekable();
    if raw.is_empty() {
        out.extend_from_slice(&[0x01, 0x00, 0x00, 0xFF, 0xFF]);
    }
    while let Some(chunk) = chunks.next() {
        let last = chunks.peek().is_none();
        out.push(if last { 1 } else { 0 });
        let len = chunk.len() as u16;
        out.extend_from_slice(&len.to_le_bytes());
        out.extend_from_slice(&(!len).to_le_bytes());
        out.extend_from_slice(chunk);
    }
    out.extend_from_slice(&adler32(raw).to_be_bytes());
    out
}

fn chunk(out: &mut Vec<u8>, tag: &[u8; 4], body: &[u8]) {
    out.extend_from_slice(&(body.len() as u32).to_be_bytes());
    let start = out.len();
    out.extend_from_slice(tag);
    out.extend_from_slice(body);
    let crc = crc32(&out[start..]);
    out.extend_from_slice(&crc.to_be_bytes());
}

/// Encode `pixels` (row-major, no padding) as a PNG byte vector.
///
/// `pixels.len()` must equal `width * height * color.bpp()`.
pub fn encode_png(width: u32, height: u32, color: ColorType, pixels: &[u8]) -> Vec<u8> {
    let bpp = color.bpp();
    assert_eq!(
        pixels.len(),
        width as usize * height as usize * bpp,
        "pixel buffer size mismatch"
    );
    let mut out = Vec::new();
    out.extend_from_slice(&[0x89, b'P', b'N', b'G', 0x0D, 0x0A, 0x1A, 0x0A]);

    let mut ihdr = Vec::with_capacity(13);
    ihdr.extend_from_slice(&width.to_be_bytes());
    ihdr.extend_from_slice(&height.to_be_bytes());
    ihdr.push(8); // bit depth
    ihdr.push(color.png_code());
    ihdr.extend_from_slice(&[0, 0, 0]); // compression, filter, interlace
    chunk(&mut out, b"IHDR", &ihdr);

    // Raw scanlines, each prefixed by filter byte 0 (None).
    let stride = width as usize * bpp;
    let mut raw = Vec::with_capacity((stride + 1) * height as usize);
    for row in pixels.chunks(stride) {
        raw.push(0);
        raw.extend_from_slice(row);
    }
    chunk(&mut out, b"IDAT", &zlib_stored(&raw));
    chunk(&mut out, b"IEND", &[]);
    out
}

/// Encode and write a PNG file.
pub fn write_png(
    path: impl AsRef<Path>,
    width: u32,
    height: u32,
    color: ColorType,
    pixels: &[u8],
) -> std::io::Result<()> {
    let bytes = encode_png(width, height, color, pixels);
    let mut f = std::fs::File::create(path)?;
    f.write_all(&bytes)
}

/// Convert an `[0,1]`-ish f32 channel buffer to u8 with clamping.
pub fn to_u8(values: &[f32]) -> Vec<u8> {
    values
        .iter()
        .map(|&v| (v.clamp(0.0, 1.0) * 255.0 + 0.5) as u8)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"IEND"), 0xAE42_6082); // the famous PNG IEND CRC
    }

    #[test]
    fn adler32_known_vectors() {
        assert_eq!(adler32(b""), 1);
        assert_eq!(adler32(b"Wikipedia"), 0x11E6_0398);
    }

    #[test]
    fn zlib_stream_shape() {
        let z = zlib_stored(&[1, 2, 3]);
        assert_eq!(&z[..2], &[0x78, 0x01]);
        assert_eq!(z[2], 1); // final stored block
        assert_eq!(&z[3..5], &[3, 0]); // LEN
        assert_eq!(&z[5..7], &[!3u8, 0xFF]); // NLEN
        assert_eq!(&z[7..10], &[1, 2, 3]);
        assert_eq!(&z[10..], &adler32(&[1, 2, 3]).to_be_bytes());
    }

    #[test]
    fn zlib_multi_block() {
        let big = vec![7u8; 70_000];
        let z = zlib_stored(&big);
        // Two stored blocks: 65535 + 4465.
        assert_eq!(z[2], 0, "first block not final");
        let len0 = u16::from_le_bytes([z[3], z[4]]) as usize;
        assert_eq!(len0, 65535);
        let second = 2 + 5 + len0;
        assert_eq!(z[second], 1, "second block final");
    }

    #[test]
    fn png_structure_valid() {
        let px = vec![128u8; 4 * 4 * 3];
        let png = encode_png(4, 4, ColorType::Rgb, &px);
        assert_eq!(&png[..8], &[0x89, b'P', b'N', b'G', 0x0D, 0x0A, 0x1A, 0x0A]);
        assert_eq!(&png[12..16], b"IHDR");
        // Width/height big-endian.
        assert_eq!(&png[16..20], &4u32.to_be_bytes());
        assert_eq!(&png[20..24], &4u32.to_be_bytes());
        assert_eq!(png[24], 8); // depth
        assert_eq!(png[25], 2); // RGB
        assert_eq!(&png[png.len() - 8..png.len() - 4], b"IEND");
        // Every chunk CRC must verify.
        let mut i = 8;
        while i < png.len() {
            let len = u32::from_be_bytes(png[i..i + 4].try_into().unwrap()) as usize;
            let body = &png[i + 4..i + 8 + len];
            let crc = u32::from_be_bytes(png[i + 8 + len..i + 12 + len].try_into().unwrap());
            assert_eq!(crc32(body), crc, "chunk at {i}");
            i += 12 + len;
        }
    }

    #[test]
    #[should_panic(expected = "pixel buffer size mismatch")]
    fn size_mismatch_panics() {
        encode_png(4, 4, ColorType::Rgb, &[0u8; 10]);
    }

    #[test]
    fn to_u8_clamps() {
        assert_eq!(to_u8(&[-1.0, 0.0, 0.5, 1.0, 2.0]), vec![0, 0, 128, 255, 255]);
    }

    #[test]
    fn gray_roundtrip_size() {
        let px = vec![0u8; 16 * 8];
        let png = encode_png(16, 8, ColorType::Gray, &px);
        assert!(png.len() > 16 * 8); // stored blocks: bigger than raw
    }
}
