//! Self-contained infrastructure substrates.
//!
//! The build image is fully offline and only vendors the crates the `xla`
//! bindings need, so the usual ecosystem crates (clap, tokio, criterion,
//! proptest, rand, image) are unavailable. Everything the rest of the
//! workspace needs from them is reimplemented here, scoped to exactly what
//! this project uses:
//!
//! * [`cancel`] — a cooperative cancel/deadline token (stand-in for tokio's
//!   `CancellationToken`), threaded from the HTTP layer into the step loop
//! * [`f16`] — IEEE 754 binary16 conversion (GGML stores block scales as f16)
//! * [`rng`] — SplitMix64 / xoshiro256++ deterministic PRNGs
//! * [`cli`] — a declarative flag/subcommand parser for the `imax-sd` binary
//! * [`pool`] — a scoped worker thread pool (stand-in for rayon/tokio tasks)
//! * [`prop`] — a miniature property-based testing framework with shrinking
//! * [`png`] — a PNG encoder (stored-deflate + zlib wrapper) for Fig. 5 output
//! * [`tables`] — ASCII table / horizontal-bar renderers for bench reports
//! * [`stats`] — summary statistics used by the bench harness

pub mod bench;
pub mod cancel;
pub mod cli;
pub mod f16;
pub mod png;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod tables;
