//! Mat-mul workload trace: the unit of performance modelling.
//!
//! The paper evaluates the dot-product kernels of `stable-diffusion.cpp`
//! generating one 512×512 image (SD-Turbo, 1 denoising step). We do not
//! ship the 2.5 GB model, but every performance-relevant property of the
//! workload is determined by the *mat-mul shape trace* — the (M, N, K)
//! and weight dtype of every `ggml_mul_mat` call — which is fully
//! derivable from the published SD v1.5 architecture. [`super::arch`]
//! reconstructs that trace; this module defines the op/trace types, the
//! dtype-assignment policy, and the per-dtype aggregation that Table I
//! and the figure benches consume.

use crate::ggml::DType;
use std::collections::BTreeMap;

/// What produced a mat-mul (determines its GGML dtype).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum OpCategory {
    /// U-Net conv lowered to im2col GEMM (F16 weights in sd.cpp).
    ConvIm2col,
    /// U-Net attention/projection/FF linear (quantized weights).
    Linear,
    /// Attention activation×activation mat-mul (QKᵀ and attn·V — F32).
    AttnScores,
    /// Time-embedding MLP linear (quantized).
    TimeEmbed,
    /// VAE decoder conv im2col (F16; the VAE is not quantized by sd.cpp).
    VaeConv,
    /// VAE attention activation mat-mul (F32).
    VaeAttn,
    /// CLIP text-encoder linear (quantized).
    TextLinear,
    /// CLIP attention activation mat-mul (F32).
    TextAttn,
}

/// Which quantized model file the run uses (the paper evaluates both).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QuantModel {
    /// 3-bit k-quants ("Q3_K model").
    Q3K,
    /// 8-bit blocks ("Q8_0 model").
    Q8_0,
}

impl QuantModel {
    /// The weight dtype quantized layers carry in this model.
    pub fn weight_dtype(self) -> DType {
        match self {
            QuantModel::Q3K => DType::Q3K,
            QuantModel::Q8_0 => DType::Q8_0,
        }
    }

    /// Display name as the paper writes it.
    pub fn name(self) -> &'static str {
        match self {
            QuantModel::Q3K => "Q3_K",
            QuantModel::Q8_0 => "Q8_0",
        }
    }
}

/// One logical `ggml_mul_mat` call: `out[N, M] = W[M, K] · X[N, K]ᵀ`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MatMulOp {
    /// Layer name for reports (e.g. `down0.res1.conv1`).
    pub name: String,
    /// Output features (weight rows).
    pub m: usize,
    /// Activation rows (tokens / pixels).
    pub n: usize,
    /// Contraction length.
    pub k: usize,
    /// Origin of the op.
    pub category: OpCategory,
    /// Batched repetitions (e.g. attention heads).
    pub repeats: usize,
}

impl MatMulOp {
    /// Construct with `repeats = 1`.
    pub fn new(name: impl Into<String>, m: usize, n: usize, k: usize, category: OpCategory) -> Self {
        MatMulOp { name: name.into(), m, n, k, category, repeats: 1 }
    }

    /// Multiply-accumulate count (one MAC = 2 FLOPs).
    pub fn macs(&self) -> u64 {
        self.m as u64 * self.n as u64 * self.k as u64 * self.repeats as u64
    }

    /// The dtype this op's weights carry under a given quantized model —
    /// the `stable-diffusion.cpp` assignment policy (§III-A/B): linear
    /// weights quantized; conv im2col and VAE in F16; attention
    /// activation×activation mat-muls in F32.
    pub fn dtype(&self, model: QuantModel) -> DType {
        match self.category {
            OpCategory::ConvIm2col | OpCategory::VaeConv => DType::F16,
            OpCategory::AttnScores | OpCategory::VaeAttn | OpCategory::TextAttn => DType::F32,
            OpCategory::Linear | OpCategory::TimeEmbed | OpCategory::TextLinear => {
                // Block-quantized dots need K divisible by the block size
                // (32 for Q8_0, 256 for k-quants); layers that do not
                // qualify stay F16, mirroring sd.cpp's per-tensor fallback.
                // (Table I accordingly has no Q8_0 row in the Q3_K model.)
                let d = model.weight_dtype();
                if self.k % d.block_size() == 0 {
                    d
                } else {
                    DType::F16
                }
            }
        }
    }

    /// True when this op is offloaded to IMAX under the paper's policy
    /// (only the model's quantized kernels are offloaded, §III-B).
    pub fn offloaded(&self, model: QuantModel) -> bool {
        self.dtype(model) == model.weight_dtype()
    }
}

/// A full pipeline trace (one image generation).
#[derive(Debug, Clone, Default)]
pub struct WorkloadTrace {
    /// Ops in execution order.
    pub ops: Vec<MatMulOp>,
}

impl WorkloadTrace {
    /// Total MACs.
    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|o| o.macs()).sum()
    }

    /// MACs grouped by effective dtype under a model.
    pub fn macs_by_dtype(&self, model: QuantModel) -> BTreeMap<&'static str, u64> {
        let mut out = BTreeMap::new();
        for op in &self.ops {
            *out.entry(op.dtype(model).name()).or_insert(0) += op.macs();
        }
        out
    }

    /// MACs offloaded to IMAX under a model (the numerator of the
    /// paper's "offload ratio of less than 20 %").
    pub fn offloaded_macs(&self, model: QuantModel) -> u64 {
        self.ops.iter().filter(|o| o.offloaded(model)).map(|o| o.macs()).sum()
    }

    /// The offloaded ops only.
    pub fn offloaded_ops(&self, model: QuantModel) -> Vec<&MatMulOp> {
        self.ops.iter().filter(|o| o.offloaded(model)).collect()
    }

    /// Weight + activation bytes a device touches for an op set — used by
    /// roofline-style device models (N.B. activations counted once).
    pub fn op_bytes(op: &MatMulOp, model: QuantModel) -> u64 {
        let d = op.dtype(model);
        let w = op.m as u64 * d.row_bytes(op.k.next_multiple_of(d.block_size())) as u64
            / op.k.next_multiple_of(d.block_size()) as u64
            * op.k as u64;
        let a = op.n as u64 * op.k as u64 * 4;
        (w + a) * op.repeats as u64
    }

    /// Concatenate traces.
    pub fn extend(&mut self, other: WorkloadTrace) {
        self.ops.extend(other.ops);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macs_include_repeats() {
        let mut op = MatMulOp::new("attn", 64, 64, 40, OpCategory::AttnScores);
        assert_eq!(op.macs(), 64 * 64 * 40);
        op.repeats = 8;
        assert_eq!(op.macs(), 8 * 64 * 64 * 40);
    }

    #[test]
    fn dtype_policy_conv_is_f16_attn_is_f32() {
        let conv = MatMulOp::new("c", 320, 4096, 2880, OpCategory::ConvIm2col);
        let attn = MatMulOp::new("a", 4096, 4096, 40, OpCategory::AttnScores);
        for m in [QuantModel::Q3K, QuantModel::Q8_0] {
            assert_eq!(conv.dtype(m), DType::F16);
            assert_eq!(attn.dtype(m), DType::F32);
            assert!(!conv.offloaded(m));
            assert!(!attn.offloaded(m));
        }
    }

    #[test]
    fn dtype_policy_linear_quantized_with_fallback() {
        let fat = MatMulOp::new("l", 320, 4096, 768, OpCategory::Linear);
        assert_eq!(fat.dtype(QuantModel::Q3K), DType::Q3K);
        assert_eq!(fat.dtype(QuantModel::Q8_0), DType::Q8_0);
        // K = 320: not a multiple of 256 -> Q3_K falls back to F16.
        let thin = MatMulOp::new("l", 320, 4096, 320, OpCategory::Linear);
        assert_eq!(thin.dtype(QuantModel::Q3K), DType::F16);
        assert_eq!(thin.dtype(QuantModel::Q8_0), DType::Q8_0);
        // K = 40: not even a Q8_0 block -> F16.
        let tiny = MatMulOp::new("l", 8, 8, 40, OpCategory::Linear);
        assert_eq!(tiny.dtype(QuantModel::Q8_0), DType::F16);
    }

    #[test]
    fn offload_filter_matches_model_dtype() {
        // A Q3_K-fallback-to-Q8_0 layer is NOT offloaded in the Q3_K model
        // (the lane is configured for the Q3_K kernel).
        let thin = MatMulOp::new("l", 320, 4096, 320, OpCategory::Linear);
        assert!(!thin.offloaded(QuantModel::Q3K));
        assert!(thin.offloaded(QuantModel::Q8_0));
    }

    #[test]
    fn totals_aggregate() {
        let mut t = WorkloadTrace::default();
        t.ops.push(MatMulOp::new("c", 10, 10, 32, OpCategory::ConvIm2col));
        t.ops.push(MatMulOp::new("l", 10, 10, 256, OpCategory::Linear));
        assert_eq!(t.total_macs(), 100 * 32 + 100 * 256);
        let by = t.macs_by_dtype(QuantModel::Q3K);
        assert_eq!(by["F16"], 100 * 32);
        assert_eq!(by["Q3_K"], 100 * 256);
        assert_eq!(t.offloaded_macs(QuantModel::Q3K), 100 * 256);
    }
}
