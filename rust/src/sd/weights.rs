//! Deterministic synthetic weight factory for the mini pipeline.
//!
//! We do not ship the SD-Turbo checkpoint; weights are synthesized
//! deterministically from `(seed, layer_name)` with 1/√K fan-in scaling,
//! then quantized according to the run's [`QuantModel`] with the same
//! per-tensor eligibility policy as the paper-scale trace (linear weights
//! quantized when K divides the block size, convs in F16).

use crate::ggml::{DType, Tensor, WeightId};
use crate::sd::trace::QuantModel;
use crate::util::rng::{fnv1a64, Xoshiro256pp};

/// Weight factory: one per pipeline instantiation.
#[derive(Debug, Clone)]
pub struct WeightFactory {
    /// Base seed (prompt-independent; the model is fixed).
    pub seed: u64,
    /// Quantized model type (`None` = full F16 reference pipeline).
    pub model: Option<QuantModel>,
}

impl WeightFactory {
    /// New factory.
    pub fn new(seed: u64, model: Option<QuantModel>) -> WeightFactory {
        WeightFactory { seed, model }
    }

    fn rng(&self, name: &str) -> Xoshiro256pp {
        Xoshiro256pp::seed_from_u64(self.seed ^ fnv1a64(name.as_bytes()))
    }

    /// Content identity of the weight named `name` in its final storage
    /// `dtype`. Weights are a pure function of `(seed, name)` and the
    /// encoding is a pure function of the dtype, so this triple *is* the
    /// byte content: two factories with the same seed and model mint
    /// equal ids for equal bytes, and re-encodings (e.g. the same layer
    /// under Q8_0 vs Q3_K) get distinct ids. Every layer above — the LMM
    /// residency cache, the residency-aware scheduler, the serving
    /// rendezvous — keys on this.
    pub fn weight_id(&self, name: &str, dtype: DType) -> WeightId {
        let h = fnv1a64(name.as_bytes())
            ^ self.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ fnv1a64(dtype.name().as_bytes()).rotate_left(32);
        WeightId(h)
    }

    /// Raw f32 matrix `[rows, cols]` with fan-in scaling.
    fn matrix(&self, name: &str, rows: usize, cols: usize) -> Tensor {
        let mut r = self.rng(name);
        let mut v = vec![0.0f32; rows * cols];
        r.fill_normal(&mut v, 1.0 / (cols as f32).sqrt());
        Tensor::f32(rows, cols, v)
    }

    /// Small bias vector.
    pub fn bias(&self, name: &str, n: usize) -> Vec<f32> {
        let mut r = self.rng(&format!("{name}.bias"));
        let mut v = vec![0.0f32; n];
        r.fill_normal(&mut v, 0.02);
        v
    }

    /// Linear weight `[dout, din]`, quantized when eligible, tagged with
    /// its [`WeightId`].
    pub fn linear(&self, name: &str, din: usize, dout: usize) -> Tensor {
        let w = self.matrix(name, dout, din);
        let q = match self.model {
            Some(m) if din % m.weight_dtype().block_size() == 0 => {
                w.quantize(m.weight_dtype())
            }
            _ => w.quantize(DType::F16),
        };
        let dtype = q.dtype();
        q.with_wid(self.weight_id(name, dtype))
    }

    /// Conv weight `[cout, cin·k·k]` — always F16 (sd.cpp policy).
    pub fn conv(&self, name: &str, cin: usize, cout: usize, k: usize) -> Tensor {
        self.matrix(name, cout, cin * k * k)
            .quantize(DType::F16)
            .with_wid(self.weight_id(name, DType::F16))
    }

    /// Norm parameters: gamma ≈ 1, beta ≈ 0.
    pub fn norm(&self, name: &str, c: usize) -> (Vec<f32>, Vec<f32>) {
        let mut r = self.rng(&format!("{name}.norm"));
        let gamma: Vec<f32> = (0..c).map(|_| 1.0 + r.normal() * 0.02).collect();
        let beta: Vec<f32> = (0..c).map(|_| r.normal() * 0.02).collect();
        (gamma, beta)
    }

    /// Embedding table `[vocab, dim]`.
    pub fn embedding(&self, name: &str, vocab: usize, dim: usize) -> Tensor {
        self.matrix(name, vocab, dim).with_wid(self.weight_id(name, DType::F32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_name() {
        let f = WeightFactory::new(7, None);
        let a = f.linear("layer.a", 64, 32);
        let b = f.linear("layer.a", 64, 32);
        let c = f.linear("layer.b", 64, 32);
        assert_eq!(a.to_f32().as_f32(), b.to_f32().as_f32());
        assert_ne!(a.to_f32().as_f32(), c.to_f32().as_f32());
    }

    #[test]
    fn quantization_policy_applied() {
        let q3 = WeightFactory::new(7, Some(QuantModel::Q3K));
        assert_eq!(q3.linear("x", 256, 64).dtype(), DType::Q3K);
        assert_eq!(q3.linear("x", 128, 64).dtype(), DType::F16, "K=128 fallback");
        let q8 = WeightFactory::new(7, Some(QuantModel::Q8_0));
        assert_eq!(q8.linear("x", 128, 64).dtype(), DType::Q8_0);
        assert_eq!(q8.conv("c", 16, 8, 3).dtype(), DType::F16, "convs stay F16");
        let f16 = WeightFactory::new(7, None);
        assert_eq!(f16.linear("x", 256, 64).dtype(), DType::F16);
    }

    #[test]
    fn weight_ids_are_stable_content_identities() {
        let a = WeightFactory::new(7, Some(QuantModel::Q8_0));
        let b = WeightFactory::new(7, Some(QuantModel::Q8_0));
        let wa = a.linear("layer.a", 64, 32);
        assert_eq!(wa.wid, b.linear("layer.a", 64, 32).wid, "same seed+name+model");
        assert!(wa.wid.is_some());
        assert_ne!(wa.wid, a.linear("layer.b", 64, 32).wid, "name enters the id");
        let other_seed = WeightFactory::new(8, Some(QuantModel::Q8_0));
        assert_ne!(wa.wid, other_seed.linear("layer.a", 64, 32).wid, "seed enters the id");
        // Same layer under a different encoding = different bytes = id.
        let q3 = WeightFactory::new(7, Some(QuantModel::Q3K));
        assert_ne!(
            a.linear("layer.q", 256, 32).wid,
            q3.linear("layer.q", 256, 32).wid,
            "dtype enters the id"
        );
        assert!(a.conv("c", 4, 4, 3).wid.is_some());
    }

    #[test]
    fn fan_in_scaling_keeps_outputs_bounded() {
        let f = WeightFactory::new(3, None);
        let w = f.linear("big", 512, 512).to_f32();
        let std: f32 = (w.as_f32().iter().map(|v| v * v).sum::<f32>() / w.len() as f32).sqrt();
        assert!((std - 1.0 / (512.0f32).sqrt()).abs() < 0.005, "std {std}");
    }
}
