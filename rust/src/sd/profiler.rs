//! Table I regeneration: per-dtype share of dot-product execution time.
//!
//! §III-A profiles the dot kernels "calculated from pure computation
//! time with memory copy overhead excluded". The proportions are
//! workstation measurements; we price the trace on the Xeon model
//! (whose throughputs were calibrated to reproduce the Q3_K column
//! exactly — see `device::baseline`).

use crate::device::baseline::CpuGpuModel;
use crate::sd::trace::{QuantModel, WorkloadTrace};

/// One Table I row: `(dtype name, percent of dot time)`.
pub type Table1Row = Vec<(&'static str, f64)>;

/// Compute the per-dtype share of dot time for a model on a device.
pub fn table1_shares(trace: &WorkloadTrace, device: &CpuGpuModel, model: QuantModel) -> Table1Row {
    let by = device.dot_seconds_by_dtype(trace, model);
    let total: f64 = by.iter().map(|(_, s)| s).sum();
    by.into_iter().map(|(d, s)| (d, 100.0 * s / total)).collect()
}

/// The paper's published Table I values for comparison columns.
pub fn paper_table1(model: QuantModel) -> Vec<(&'static str, f64)> {
    match model {
        QuantModel::Q3K => vec![("F32", 30.7), ("F16", 59.0), ("Q3_K", 10.3)],
        QuantModel::Q8_0 => vec![("F32", 21.8), ("F16", 62.0), ("Q8_0", 16.3)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::baseline::xeon_w5;
    use crate::sd::arch::sd_turbo_512;

    #[test]
    fn q3k_column_matches_paper_closely() {
        let t = sd_turbo_512(1);
        let shares = table1_shares(&t, &xeon_w5(), QuantModel::Q3K);
        for (name, want) in paper_table1(QuantModel::Q3K) {
            let got = shares.iter().find(|(n, _)| *n == name).map(|(_, v)| *v).unwrap();
            assert!((got - want).abs() < 2.0, "{name}: got {got}, paper {want}");
        }
    }

    #[test]
    fn q8_column_orderings_match_paper() {
        // The Q8_0 column cannot be matched simultaneously with the Q3_K
        // column under one static throughput model (see EXPERIMENTS.md);
        // orderings and rough levels must hold.
        let t = sd_turbo_512(1);
        let shares = table1_shares(&t, &xeon_w5(), QuantModel::Q8_0);
        let get = |n: &str| shares.iter().find(|(m, _)| *m == n).map(|(_, v)| *v).unwrap();
        assert!(get("F16") > get("F32"), "F16 dominates");
        assert!(get("F32") > get("Q8_0"), "F32 second");
        assert!(get("Q8_0") > 10.0 && get("Q8_0") < 20.0, "Q8_0 share {}", get("Q8_0"));
        // Q8_0 model's quantized share exceeds the Q3_K model's (paper:
        // 16.3 vs 10.3).
        let q3_shares = table1_shares(&t, &xeon_w5(), QuantModel::Q3K);
        let q3 = q3_shares.iter().find(|(n, _)| *n == "Q3_K").map(|(_, v)| *v).unwrap();
        assert!(get("Q8_0") > q3);
    }

    #[test]
    fn shares_sum_to_100() {
        let t = sd_turbo_512(1);
        for m in [QuantModel::Q3K, QuantModel::Q8_0] {
            let s: f64 = table1_shares(&t, &xeon_w5(), m).iter().map(|(_, v)| v).sum();
            assert!((s - 100.0).abs() < 1e-9);
        }
    }
}
