//! Architecture → mat-mul trace reconstruction for the paper's workload:
//! Stable Diffusion v1.5 (SD-Turbo weights share the architecture) at
//! 512×512, one denoising step.
//!
//! Everything here is derived from the published SD v1.5 architecture
//! (Rombach et al. 2022; the `stable-diffusion.cpp` implementation):
//!
//! * **U-Net**: model_channels 320, channel mult [1,2,4,4], 2 res-blocks
//!   per level, spatial transformers at the 64/32/16 latent resolutions,
//!   8 heads, context dim 768 (CLIP), time-embedding dim 1280.
//! * **VAE decoder**: z=4 → 512 ch at 64², upsampling 64→512 with channel
//!   schedule [512, 512, 256, 128], 3 res-blocks per level, one
//!   single-head attention block at 64².
//! * **CLIP text encoder** (ViT-L/14 text tower): 12 layers, width 768,
//!   12 heads, sequence 77, MLP ratio 4.
//!
//! One known approximation: skip-concat input channels on the U-Net up
//! path are taken as `ch_level + ch_skip` with the standard skip
//! schedule; this matches the real channel sums level-by-level.

use super::trace::{MatMulOp, OpCategory, WorkloadTrace};

/// Convolution as im2col GEMM: `M=cout, K=cin·kh·kw, N=out_h·out_w`.
fn conv(name: String, cin: usize, cout: usize, ksize: usize, out_res: usize, cat: OpCategory) -> MatMulOp {
    MatMulOp::new(name, cout, out_res * out_res, cin * ksize * ksize, cat)
}

/// Linear layer over `n` tokens.
fn linear(name: String, din: usize, dout: usize, n: usize, cat: OpCategory) -> MatMulOp {
    MatMulOp::new(name, dout, n, din, cat)
}

/// U-Net res-block: two 3×3 convs, a time-embedding projection, and a
/// 1×1 skip conv when the channel count changes.
fn res_block(ops: &mut Vec<MatMulOp>, name: &str, cin: usize, cout: usize, res: usize, temb: usize) {
    ops.push(conv(format!("{name}.conv1"), cin, cout, 3, res, OpCategory::ConvIm2col));
    ops.push(linear(format!("{name}.emb"), temb, cout, 1, OpCategory::TimeEmbed));
    ops.push(conv(format!("{name}.conv2"), cout, cout, 3, res, OpCategory::ConvIm2col));
    if cin != cout {
        ops.push(conv(format!("{name}.skip"), cin, cout, 1, res, OpCategory::ConvIm2col));
    }
}

/// Spatial transformer block (SD1.x: 1 basic transformer layer): self-
/// attention, cross-attention to the 77-token context, GEGLU feed-forward.
fn transformer(ops: &mut Vec<MatMulOp>, name: &str, ch: usize, res: usize, heads: usize, ctx: usize, ctx_len: usize) {
    let seq = res * res;
    let hd = ch / heads;
    ops.push(linear(format!("{name}.proj_in"), ch, ch, seq, OpCategory::Linear));
    // Self-attention.
    for p in ["q", "k", "v"] {
        ops.push(linear(format!("{name}.attn1.{p}"), ch, ch, seq, OpCategory::Linear));
    }
    let mut scores = MatMulOp::new(format!("{name}.attn1.qk"), seq, seq, hd, OpCategory::AttnScores);
    scores.repeats = heads;
    ops.push(scores);
    let mut av = MatMulOp::new(format!("{name}.attn1.v"), hd, seq, seq, OpCategory::AttnScores);
    av.repeats = heads;
    ops.push(av);
    ops.push(linear(format!("{name}.attn1.out"), ch, ch, seq, OpCategory::Linear));
    // Cross-attention (context = CLIP hidden states).
    ops.push(linear(format!("{name}.attn2.q"), ch, ch, seq, OpCategory::Linear));
    ops.push(linear(format!("{name}.attn2.k"), ctx, ch, ctx_len, OpCategory::Linear));
    ops.push(linear(format!("{name}.attn2.v"), ctx, ch, ctx_len, OpCategory::Linear));
    let mut xscores = MatMulOp::new(format!("{name}.attn2.qk"), ctx_len, seq, hd, OpCategory::AttnScores);
    xscores.repeats = heads;
    ops.push(xscores);
    let mut xav = MatMulOp::new(format!("{name}.attn2.v@"), hd, seq, ctx_len, OpCategory::AttnScores);
    xav.repeats = heads;
    ops.push(xav);
    ops.push(linear(format!("{name}.attn2.out"), ch, ch, seq, OpCategory::Linear));
    // GEGLU feed-forward: ch -> 2·4ch (gate+value), then 4ch -> ch.
    ops.push(linear(format!("{name}.ff1"), ch, 8 * ch, seq, OpCategory::Linear));
    ops.push(linear(format!("{name}.ff2"), 4 * ch, ch, seq, OpCategory::Linear));
    ops.push(linear(format!("{name}.proj_out"), ch, ch, seq, OpCategory::Linear));
}

/// SD v1.5 U-Net, one forward pass at latent resolution `lat` (64 for
/// 512×512 images).
pub fn unet_sd15(lat: usize) -> WorkloadTrace {
    let mut ops = Vec::new();
    let chs = [320usize, 640, 1280, 1280];
    let temb = 1280;
    let heads = 8;
    let (ctx, ctx_len) = (768, 77);
    let attn_levels = 3; // transformers at levels 0..3 (res 64/32/16)

    // Time-embedding MLP.
    ops.push(linear("time_embed.0".into(), 320, temb, 1, OpCategory::TimeEmbed));
    ops.push(linear("time_embed.2".into(), temb, temb, 1, OpCategory::TimeEmbed));

    ops.push(conv("conv_in".into(), 4, chs[0], 3, lat, OpCategory::ConvIm2col));

    // ---- Down path. Track skip channels for the up path.
    let mut skips: Vec<usize> = vec![chs[0]]; // conv_in output
    let mut ch = chs[0];
    for (l, &cl) in chs.iter().enumerate() {
        let res = lat >> l;
        for i in 0..2 {
            res_block(&mut ops, &format!("down{l}.res{i}"), ch, cl, res, temb);
            ch = cl;
            if l < attn_levels {
                transformer(&mut ops, &format!("down{l}.tf{i}"), cl, res, heads, ctx, ctx_len);
            }
            skips.push(cl);
        }
        if l < chs.len() - 1 {
            // Strided 3×3 downsample conv.
            ops.push(conv(format!("down{l}.down"), cl, cl, 3, res / 2, OpCategory::ConvIm2col));
            skips.push(cl);
        }
    }

    // ---- Middle.
    let mid_res = lat >> (chs.len() - 1);
    res_block(&mut ops, "mid.res0", ch, ch, mid_res, temb);
    transformer(&mut ops, "mid.tf", ch, mid_res, heads, ctx, ctx_len);
    res_block(&mut ops, "mid.res1", ch, ch, mid_res, temb);

    // ---- Up path: 3 res-blocks per level, consuming skips in reverse.
    for l in (0..chs.len()).rev() {
        let cl = chs[l];
        let res = lat >> l;
        for i in 0..3 {
            let skip = skips.pop().expect("skip stack balanced");
            res_block(&mut ops, &format!("up{l}.res{i}"), ch + skip, cl, res, temb);
            ch = cl;
            if l < attn_levels {
                transformer(&mut ops, &format!("up{l}.tf{i}"), cl, res, heads, ctx, ctx_len);
            }
        }
        if l > 0 {
            // Nearest-upsample + 3×3 conv at the doubled resolution.
            ops.push(conv(format!("up{l}.up"), cl, cl, 3, res * 2, OpCategory::ConvIm2col));
        }
    }
    debug_assert!(skips.is_empty(), "all skips consumed");

    ops.push(conv("conv_out".into(), chs[0], 4, 3, lat, OpCategory::ConvIm2col));
    WorkloadTrace { ops }
}

/// VAE res-block (no time embedding).
fn vae_res_block(ops: &mut Vec<MatMulOp>, name: &str, cin: usize, cout: usize, res: usize) {
    ops.push(conv(format!("{name}.conv1"), cin, cout, 3, res, OpCategory::VaeConv));
    ops.push(conv(format!("{name}.conv2"), cout, cout, 3, res, OpCategory::VaeConv));
    if cin != cout {
        ops.push(conv(format!("{name}.skip"), cin, cout, 1, res, OpCategory::VaeConv));
    }
}

/// SD v1.5 VAE decoder: latent `lat`² ×4 → image `(8·lat)`² ×3.
pub fn vae_decoder_sd15(lat: usize) -> WorkloadTrace {
    let mut ops = Vec::new();
    let chs = [512usize, 512, 256, 128]; // decoder channel schedule
    ops.push(conv("vae.conv_in".into(), 4, chs[0], 3, lat, OpCategory::VaeConv));

    // Mid: res + single-head attention at latent res + res.
    vae_res_block(&mut ops, "vae.mid.res0", chs[0], chs[0], lat);
    let seq = lat * lat;
    for p in ["q", "k", "v", "out"] {
        ops.push(linear(format!("vae.mid.attn.{p}"), chs[0], chs[0], seq, OpCategory::VaeConv));
    }
    ops.push(MatMulOp::new("vae.mid.attn.qk", seq, seq, chs[0], OpCategory::VaeAttn));
    ops.push(MatMulOp::new("vae.mid.attn.v@", chs[0], seq, seq, OpCategory::VaeAttn));
    vae_res_block(&mut ops, "vae.mid.res1", chs[0], chs[0], lat);

    // Up levels: 3 res-blocks each, upsample conv after all but the last.
    let mut ch = chs[0];
    let mut res = lat;
    for (l, &cl) in chs.iter().enumerate() {
        for i in 0..3 {
            vae_res_block(&mut ops, &format!("vae.up{l}.res{i}"), ch, cl, res);
            ch = cl;
        }
        if l < chs.len() - 1 {
            res *= 2;
            ops.push(conv(format!("vae.up{l}.up"), cl, cl, 3, res, OpCategory::VaeConv));
        }
    }
    ops.push(conv("vae.conv_out".into(), ch, 3, 3, res, OpCategory::VaeConv));
    WorkloadTrace { ops }
}

/// CLIP ViT-L/14 text encoder (the SD1.5 conditioner): 12 layers,
/// width 768, 12 heads, 77 tokens.
pub fn clip_text_sd15() -> WorkloadTrace {
    let mut ops = Vec::new();
    let (layers, ch, heads, seq) = (12usize, 768usize, 12usize, 77usize);
    let hd = ch / heads;
    for l in 0..layers {
        for p in ["q", "k", "v", "out"] {
            ops.push(linear(format!("clip.l{l}.attn.{p}"), ch, ch, seq, OpCategory::TextLinear));
        }
        let mut s = MatMulOp::new(format!("clip.l{l}.attn.qk"), seq, seq, hd, OpCategory::TextAttn);
        s.repeats = heads;
        ops.push(s);
        let mut av = MatMulOp::new(format!("clip.l{l}.attn.v@"), hd, seq, seq, OpCategory::TextAttn);
        av.repeats = heads;
        ops.push(av);
        ops.push(linear(format!("clip.l{l}.mlp1"), ch, 4 * ch, seq, OpCategory::TextLinear));
        ops.push(linear(format!("clip.l{l}.mlp2"), 4 * ch, ch, seq, OpCategory::TextLinear));
    }
    WorkloadTrace { ops }
}

/// The paper's full measured workload: one 512×512 SD-Turbo generation =
/// CLIP text encode + `steps` U-Net passes + VAE decode.
pub fn sd_turbo_512(steps: usize) -> WorkloadTrace {
    let mut t = clip_text_sd15();
    for _ in 0..steps {
        t.extend(unet_sd15(64));
    }
    t.extend(vae_decoder_sd15(64));
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sd::trace::QuantModel;

    #[test]
    fn unet_total_macs_in_published_range() {
        // SD v1.5 U-Net @512² is ~0.35 T MACs (≈0.7 TFLOPs) per step.
        let t = unet_sd15(64);
        let g = t.total_macs() as f64 / 1e9;
        assert!((250.0..500.0).contains(&g), "UNet GMACs {g}");
    }

    #[test]
    fn vae_dominates_unet_for_single_step() {
        // Known property of 1-step SD: VAE decode outweighs one U-Net pass.
        let unet = unet_sd15(64).total_macs();
        let vae = vae_decoder_sd15(64).total_macs();
        assert!(vae > unet, "vae {vae} vs unet {unet}");
        let g = vae as f64 / 1e9;
        assert!((700.0..1600.0).contains(&g), "VAE GMACs {g}");
    }

    #[test]
    fn clip_is_negligible() {
        let clip = clip_text_sd15().total_macs();
        let unet = unet_sd15(64).total_macs();
        assert!(clip * 50 < unet, "clip {clip} should be <2% of unet {unet}");
    }

    #[test]
    fn offload_ratio_below_20_percent_as_paper_states() {
        // §IV-B: "a limited offload ratio of less than 20 %".
        let t = sd_turbo_512(1);
        for m in [QuantModel::Q3K, QuantModel::Q8_0] {
            let ratio = t.offloaded_macs(m) as f64 / t.total_macs() as f64;
            assert!(ratio < 0.20, "{m:?} offload ratio {ratio}");
            assert!(ratio > 0.02, "{m:?} offload ratio {ratio} suspiciously low");
        }
    }

    #[test]
    fn q8_0_offloads_more_than_q3_k() {
        // Q3_K needs K % 256 == 0, so fewer layers qualify — consistent
        // with Table I (Q8_0 16.3 % vs Q3_K 10.3 % of dot time).
        let t = sd_turbo_512(1);
        assert!(t.offloaded_macs(QuantModel::Q8_0) > t.offloaded_macs(QuantModel::Q3K));
    }

    #[test]
    fn f16_is_the_dominant_dtype() {
        // Table I: F16 carries ~60 % of dot time; in volume it is even
        // more dominant (convs + VAE).
        let t = sd_turbo_512(1);
        let by = t.macs_by_dtype(QuantModel::Q3K);
        let f16 = by["F16"];
        assert!(f16 * 10 > t.total_macs() * 6, "F16 {} of {}", f16, t.total_macs());
    }

    #[test]
    fn skip_stack_balances_and_channels_match() {
        // If the skip bookkeeping broke, unet_sd15 would panic in debug;
        // also sanity-check the first up-block input channels: 1280+1280.
        let t = unet_sd15(64);
        let up0 = t.ops.iter().find(|o| o.name == "up3.res0.conv1").unwrap();
        assert_eq!(up0.k, (1280 + 1280) * 9);
        // Final level-0 res-block consumes the conv_in skip: 320+320.
        let last = t.ops.iter().find(|o| o.name == "up0.res2.conv1").unwrap();
        assert_eq!(last.k, (320 + 320) * 9);
    }

    #[test]
    fn trace_is_deterministic() {
        let a = sd_turbo_512(1);
        let b = sd_turbo_512(1);
        assert_eq!(a.ops.len(), b.ops.len());
        assert_eq!(a.total_macs(), b.total_macs());
    }

    #[test]
    fn multi_step_scales_unet_only() {
        let one = sd_turbo_512(1).total_macs();
        let four = sd_turbo_512(4).total_macs();
        let unet = unet_sd15(64).total_macs();
        assert_eq!(four - one, 3 * unet);
    }
}
