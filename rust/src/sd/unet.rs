//! Mini latent-diffusion U-Net (the SD-Turbo analog at toy scale).
//!
//! 4×16×16 latents, channel schedule 64→128, one spatial transformer at
//! the 8×8 bottleneck with inner dim 256 so its q/k/v/ff linears are
//! k-quant eligible (K ∈ {256, 512}) — the same layers the paper
//! offloads. Convs run F16 im2col GEMMs; attention scores run F32;
//! linears run Q8_0/Q3_K per the run's model. Structure per layer
//! mirrors SD v1.5 (res-blocks with time embedding, pre-norm transformer
//! with self-attn, cross-attn to the 77-token context, GEGLU-ish FF).

use super::graph::{
    attention, conv2d, group_norm, silu, upsample2x, ExecBackend, Feat, OpDesc,
};
use super::text::{CTX_LEN, DIM as TEXT_DIM};
use super::weights::WeightFactory;
use crate::ggml::Tensor;

/// Latent channels.
pub const LATENT_C: usize = 4;
/// Latent spatial size.
pub const LATENT_HW: usize = 16;
/// Base channels.
const C0: usize = 64;
/// Bottleneck channels.
const C1: usize = 128;
/// Transformer inner dim (k-quant eligible).
const TD: usize = 256;
/// Attention heads in the bottleneck transformer.
const HEADS: usize = 4;
/// GroupNorm groups.
const GROUPS: usize = 8;
/// Time-embedding dim.
pub const TEMB: usize = 256;

struct ResBlock {
    norm1: (Vec<f32>, Vec<f32>),
    conv1: Tensor,
    conv1_b: Vec<f32>,
    emb: Tensor,
    emb_b: Vec<f32>,
    norm2: (Vec<f32>, Vec<f32>),
    conv2: Tensor,
    conv2_b: Vec<f32>,
    skip: Option<(Tensor, Vec<f32>)>,
    cin: usize,
    cout: usize,
}

impl ResBlock {
    fn new(f: &WeightFactory, name: &str, cin: usize, cout: usize) -> ResBlock {
        ResBlock {
            norm1: f.norm(&format!("{name}.n1"), cin),
            conv1: f.conv(&format!("{name}.c1"), cin, cout, 3),
            conv1_b: f.bias(&format!("{name}.c1"), cout),
            emb: f.linear(&format!("{name}.emb"), TEMB, cout),
            emb_b: f.bias(&format!("{name}.emb"), cout),
            norm2: f.norm(&format!("{name}.n2"), cout),
            conv2: f.conv(&format!("{name}.c2"), cout, cout, 3),
            conv2_b: f.bias(&format!("{name}.c2"), cout),
            skip: (cin != cout).then(|| {
                (f.conv(&format!("{name}.skip"), cin, cout, 1), f.bias(&format!("{name}.skip"), cout))
            }),
            cin,
            cout,
        }
    }

    fn forward(&self, eng: &mut dyn ExecBackend, x: &Feat, temb: &Tensor) -> Feat {
        debug_assert_eq!(x.c, self.cin);
        let mut h = group_norm(x, GROUPS, &self.norm1.0, &self.norm1.1);
        silu(&mut h.data);
        let mut h = conv2d(eng, &self.conv1, &self.conv1_b, &h, 3, 1);
        // Add the per-channel time embedding projection.
        let e = eng.submit_now(OpDesc::time_embed(&self.emb, temb)); // [1, cout]
        let hw = h.hw();
        for c in 0..self.cout {
            let ev = e.as_f32()[c] + self.emb_b[c];
            for p in 0..hw {
                h.data[c * hw + p] += ev;
            }
        }
        let mut h2 = group_norm(&h, GROUPS, &self.norm2.0, &self.norm2.1);
        silu(&mut h2.data);
        let h2 = conv2d(eng, &self.conv2, &self.conv2_b, &h2, 3, 1);
        let res = match &self.skip {
            Some((w, b)) => conv2d(eng, w, b, x, 1, 1),
            None => x.clone(),
        };
        h2.add(&res)
    }
}

struct Transformer {
    norm: (Vec<f32>, Vec<f32>),
    proj_in: Tensor,
    proj_in_b: Vec<f32>,
    wq: Tensor,
    wk: Tensor,
    wv: Tensor,
    wo: Tensor,
    xq: Tensor,
    xk: Tensor,
    xv: Tensor,
    xo: Tensor,
    ff1: Tensor,
    ff1_b: Vec<f32>,
    ff2: Tensor,
    ff2_b: Vec<f32>,
    proj_out: Tensor,
    proj_out_b: Vec<f32>,
}

impl Transformer {
    fn new(f: &WeightFactory, name: &str, ch: usize) -> Transformer {
        Transformer {
            norm: f.norm(&format!("{name}.norm"), ch),
            proj_in: f.linear(&format!("{name}.proj_in"), ch, TD),
            proj_in_b: f.bias(&format!("{name}.proj_in"), TD),
            wq: f.linear(&format!("{name}.attn1.q"), TD, TD),
            wk: f.linear(&format!("{name}.attn1.k"), TD, TD),
            wv: f.linear(&format!("{name}.attn1.v"), TD, TD),
            wo: f.linear(&format!("{name}.attn1.o"), TD, TD),
            xq: f.linear(&format!("{name}.attn2.q"), TD, TD),
            xk: f.linear(&format!("{name}.attn2.k"), TEXT_DIM, TD),
            xv: f.linear(&format!("{name}.attn2.v"), TEXT_DIM, TD),
            xo: f.linear(&format!("{name}.attn2.o"), TD, TD),
            ff1: f.linear(&format!("{name}.ff1"), TD, 2 * TD),
            ff1_b: f.bias(&format!("{name}.ff1"), 2 * TD),
            ff2: f.linear(&format!("{name}.ff2"), TD, TD),
            ff2_b: f.bias(&format!("{name}.ff2"), TD),
            proj_out: f.linear(&format!("{name}.proj_out"), TD, ch),
            proj_out_b: f.bias(&format!("{name}.proj_out"), ch),
        }
    }

    fn forward(&self, eng: &mut dyn ExecBackend, x: &Feat, ctx: &Tensor) -> Feat {
        debug_assert_eq!(ctx.rows, CTX_LEN);
        let normed = group_norm(x, GROUPS, &self.norm.0, &self.norm.1);
        let toks = normed.to_tokens(); // [hw, ch]
        let mut h = eng.submit_now(OpDesc::linear(&self.proj_in, &toks)); // [hw, TD]
        add_bias(&mut h, &self.proj_in_b);

        // Self-attention + residual. Q/K/V are independent (all read
        // `h`), so submit all three before syncing any — on a parallel
        // backend they overlap across lanes.
        let hq = eng.submit(OpDesc::linear(&self.wq, &h));
        let hk = eng.submit(OpDesc::linear(&self.wk, &h));
        let hv = eng.submit(OpDesc::linear(&self.wv, &h));
        let (q, k, v) = (eng.sync(hq), eng.sync(hk), eng.sync(hv));
        let a = attention(eng, &q, &k, &v, HEADS);
        let o = eng.submit_now(OpDesc::linear(&self.wo, &a));
        h = add_t(&h, &o);

        // Cross-attention to the text context + residual. Same overlap:
        // Q reads `h`, K/V read `ctx`; no dependency between them.
        let hq = eng.submit(OpDesc::linear(&self.xq, &h));
        let hk = eng.submit(OpDesc::linear(&self.xk, ctx));
        let hv = eng.submit(OpDesc::linear(&self.xv, ctx));
        let (q, k, v) = (eng.sync(hq), eng.sync(hk), eng.sync(hv));
        let a = attention(eng, &q, &k, &v, HEADS);
        let o = eng.submit_now(OpDesc::linear(&self.xo, &a));
        h = add_t(&h, &o);

        // Gated feed-forward + residual.
        let mut m = eng.submit_now(OpDesc::linear(&self.ff1, &h)); // [hw, 2*TD]
        add_bias(&mut m, &self.ff1_b);
        // GEGLU: first half gated by GELU of second half.
        let hw = m.rows;
        let mut gated = vec![0.0f32; hw * TD];
        {
            let md = m.as_f32();
            for r in 0..hw {
                for c in 0..TD {
                    let val = md[r * 2 * TD + c];
                    let mut gate = [md[r * 2 * TD + TD + c]];
                    super::graph::gelu(&mut gate);
                    gated[r * TD + c] = val * gate[0];
                }
            }
        }
        let gated = Tensor::f32(hw, TD, gated);
        let mut m2 = eng.submit_now(OpDesc::linear(&self.ff2, &gated));
        add_bias(&mut m2, &self.ff2_b);
        h = add_t(&h, &m2);

        let mut out = eng.submit_now(OpDesc::linear(&self.proj_out, &h)); // [hw, ch]
        add_bias(&mut out, &self.proj_out_b);
        Feat::from_tokens(&out, x.h, x.w).add(x)
    }
}

fn add_bias(t: &mut Tensor, bias: &[f32]) {
    let cols = t.cols;
    if let crate::ggml::tensor::Storage::F32(v) = &mut t.data {
        for row in v.chunks_mut(cols) {
            for (x, b) in row.iter_mut().zip(bias) {
                *x += b;
            }
        }
    }
}

fn add_t(a: &Tensor, b: &Tensor) -> Tensor {
    let v = a.as_f32().iter().zip(b.as_f32()).map(|(x, y)| x + y).collect();
    Tensor::f32(a.rows, a.cols, v)
}

/// Sinusoidal timestep embedding (dim 64), as SD uses.
pub fn timestep_embedding(t: f32) -> Tensor {
    let half = 32;
    let mut v = vec![0.0f32; 64];
    for i in 0..half {
        let freq = (-(i as f32) * (10000.0f32).ln() / half as f32).exp();
        v[i] = (t * freq).cos();
        v[half + i] = (t * freq).sin();
    }
    Tensor::f32(1, 64, v)
}

/// The mini U-Net.
pub struct UNet {
    conv_in: (Tensor, Vec<f32>),
    temb1: Tensor,
    temb1_b: Vec<f32>,
    temb2: Tensor,
    temb2_b: Vec<f32>,
    rb_down0: ResBlock,
    down: (Tensor, Vec<f32>),
    rb_down1: ResBlock,
    transformer: Transformer,
    rb_mid: ResBlock,
    rb_up0: ResBlock,
    rb_up1: ResBlock,
    norm_out: (Vec<f32>, Vec<f32>),
    conv_out: (Tensor, Vec<f32>),
}

impl UNet {
    /// Build from a factory.
    pub fn new(f: &WeightFactory) -> UNet {
        UNet {
            conv_in: (f.conv("unet.conv_in", LATENT_C, C0, 3), f.bias("unet.conv_in", C0)),
            temb1: f.linear("unet.temb1", 64, TEMB),
            temb1_b: f.bias("unet.temb1", TEMB),
            temb2: f.linear("unet.temb2", TEMB, TEMB),
            temb2_b: f.bias("unet.temb2", TEMB),
            rb_down0: ResBlock::new(f, "unet.down0", C0, C0),
            down: (f.conv("unet.down", C0, C1, 3), f.bias("unet.down", C1)),
            rb_down1: ResBlock::new(f, "unet.down1", C1, C1),
            transformer: Transformer::new(f, "unet.mid.tf", C1),
            rb_mid: ResBlock::new(f, "unet.mid.rb", C1, C1),
            rb_up0: ResBlock::new(f, "unet.up0", C1 + C1, C1),
            rb_up1: ResBlock::new(f, "unet.up1", C1 + C0, C0),
            norm_out: f.norm("unet.norm_out", C0),
            conv_out: (f.conv("unet.conv_out", C0, LATENT_C, 3), f.bias("unet.conv_out", LATENT_C)),
        }
    }

    /// Predict noise for a latent at timestep `t` with text context.
    pub fn forward(&self, eng: &mut dyn ExecBackend, latent: &Feat, t: f32, ctx: &Tensor) -> Feat {
        assert_eq!((latent.c, latent.h, latent.w), (LATENT_C, LATENT_HW, LATENT_HW));
        // Time embedding MLP.
        let te = timestep_embedding(t);
        let mut e = eng.submit_now(OpDesc::time_embed(&self.temb1, &te));
        add_bias(&mut e, &self.temb1_b);
        if let crate::ggml::tensor::Storage::F32(v) = &mut e.data {
            silu(v);
        }
        let mut e = eng.submit_now(OpDesc::time_embed(&self.temb2, &e));
        add_bias(&mut e, &self.temb2_b);
        let temb = e; // [1, TEMB]

        // Encoder.
        let h0 = conv2d(eng, &self.conv_in.0, &self.conv_in.1, latent, 3, 1); // C0@16
        let h1 = self.rb_down0.forward(eng, &h0, &temb); // C0@16 (skip)
        let h2 = conv2d(eng, &self.down.0, &self.down.1, &h1, 3, 2); // C1@8
        let h3 = self.rb_down1.forward(eng, &h2, &temb); // C1@8 (skip)

        // Bottleneck.
        let m = self.transformer.forward(eng, &h3, ctx);
        let m = self.rb_mid.forward(eng, &m, &temb); // C1@8

        // Decoder with skip concats.
        let u0 = self.rb_up0.forward(eng, &m.concat(&h3), &temb); // C1@8
        let u0 = upsample2x(&u0); // C1@16
        let u1 = self.rb_up1.forward(eng, &u0.concat(&h1), &temb); // C0@16

        let mut out = group_norm(&u1, GROUPS, &self.norm_out.0, &self.norm_out.1);
        silu(&mut out.data);
        conv2d(eng, &self.conv_out.0, &self.conv_out.1, &out, 3, 1) // 4@16
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sd::graph::{HostBackend, ImaxBackend};
    use crate::sd::trace::QuantModel;
    use crate::util::rng::Xoshiro256pp;

    fn latent(seed: u64) -> Feat {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let mut d = vec![0.0f32; LATENT_C * LATENT_HW * LATENT_HW];
        r.fill_normal(&mut d, 1.0);
        Feat::new(LATENT_C, LATENT_HW, LATENT_HW, d)
    }

    fn ctx(seed: u64) -> Tensor {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let mut d = vec![0.0f32; CTX_LEN * TEXT_DIM];
        r.fill_normal(&mut d, 0.3);
        Tensor::f32(CTX_LEN, TEXT_DIM, d)
    }

    #[test]
    fn forward_shape_and_determinism() {
        let f = WeightFactory::new(1, None);
        let unet = UNet::new(&f);
        let mut eng = HostBackend::new(2);
        let out = unet.forward(&mut eng, &latent(5), 999.0, &ctx(6));
        assert_eq!((out.c, out.h, out.w), (LATENT_C, LATENT_HW, LATENT_HW));
        assert!(out.data.iter().all(|v| v.is_finite()));
        let mut eng2 = HostBackend::new(1);
        let out2 = unet.forward(&mut eng2, &latent(5), 999.0, &ctx(6));
        assert_eq!(out.data, out2.data);
    }

    #[test]
    fn quantized_unet_tracks_f16_reference() {
        let latent5 = latent(5);
        let c = ctx(6);
        let reference = {
            let f = WeightFactory::new(1, None);
            let unet = UNet::new(&f);
            let mut eng = HostBackend::new(2);
            unet.forward(&mut eng, &latent5, 500.0, &c)
        };
        for m in [QuantModel::Q8_0, QuantModel::Q3K] {
            let f = WeightFactory::new(1, Some(m));
            let unet = UNet::new(&f);
            let mut eng = HostBackend::new(2);
            let got = unet.forward(&mut eng, &latent5, 500.0, &c);
            // Cosine similarity between quantized and f16 outputs.
            let dot: f32 = got.data.iter().zip(&reference.data).map(|(a, b)| a * b).sum();
            let na: f32 = got.data.iter().map(|v| v * v).sum::<f32>().sqrt();
            let nb: f32 = reference.data.iter().map(|v| v * v).sum::<f32>().sqrt();
            let cos = dot / (na * nb);
            assert!(cos > 0.95, "{m:?} cosine {cos}");
        }
    }

    #[test]
    fn timestep_embedding_distinguishes_timesteps() {
        let a = timestep_embedding(0.0);
        let b = timestep_embedding(999.0);
        assert_ne!(a.as_f32(), b.as_f32());
        assert!(a.as_f32().iter().all(|v| v.abs() <= 1.0));
    }

    #[test]
    fn imax_offload_is_exercised_and_q8_bit_exact() {
        let f = WeightFactory::new(1, Some(QuantModel::Q8_0));
        let unet = UNet::new(&f);
        let l = latent(5);
        let c = ctx(6);
        let mut host = HostBackend::new(2);
        let a = unet.forward(&mut host, &l, 999.0, &c);
        let mut imax = ImaxBackend::new(crate::imax::ImaxConfig::fpga(1), 2);
        let b = unet.forward(&mut imax, &l, 999.0, &c);
        assert!(imax.stats().offloaded_calls > 0, "transformer linears offload");
        // Q8_0 lane kernel is bit-exact vs host GGML: whole U-Net agrees.
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
