//! Mini VAE decoder: 4×16×16 latent → 3×128×128 RGB image.
//!
//! Mirrors the SD VAE decoder's shape (conv_in, res-blocks, nearest-
//! upsample + conv per level, conv_out) at toy scale. All weights stay
//! F16 — sd.cpp never quantizes the VAE — so this is pure host-side F16
//! GEMM load, exactly the dominant dtype of Table I.

use super::graph::{conv2d, group_norm, silu, upsample2x, ExecBackend, Feat};
use super::weights::WeightFactory;
use crate::ggml::Tensor;

/// Decoder channel schedule per level (16→32→64→128 spatial).
const CHS: [usize; 4] = [64, 48, 32, 16];
const GROUPS: usize = 8;

struct VaeRes {
    norm1: (Vec<f32>, Vec<f32>),
    conv1: Tensor,
    conv1_b: Vec<f32>,
    norm2: (Vec<f32>, Vec<f32>),
    conv2: Tensor,
    conv2_b: Vec<f32>,
}

impl VaeRes {
    fn new(f: &WeightFactory, name: &str, ch: usize) -> VaeRes {
        VaeRes {
            norm1: f.norm(&format!("{name}.n1"), ch),
            conv1: f.conv(&format!("{name}.c1"), ch, ch, 3),
            conv1_b: f.bias(&format!("{name}.c1"), ch),
            norm2: f.norm(&format!("{name}.n2"), ch),
            conv2: f.conv(&format!("{name}.c2"), ch, ch, 3),
            conv2_b: f.bias(&format!("{name}.c2"), ch),
        }
    }

    fn forward(&self, eng: &mut dyn ExecBackend, x: &Feat) -> Feat {
        let mut h = group_norm(x, GROUPS, &self.norm1.0, &self.norm1.1);
        silu(&mut h.data);
        let h = conv2d(eng, &self.conv1, &self.conv1_b, &h, 3, 1);
        let mut h2 = group_norm(&h, GROUPS, &self.norm2.0, &self.norm2.1);
        silu(&mut h2.data);
        conv2d(eng, &self.conv2, &self.conv2_b, &h2, 3, 1).add(x)
    }
}

/// The decoder.
pub struct VaeDecoder {
    conv_in: (Tensor, Vec<f32>),
    levels: Vec<(VaeRes, Option<(Tensor, Vec<f32>)>)>,
    norm_out: (Vec<f32>, Vec<f32>),
    conv_out: (Tensor, Vec<f32>),
}

impl VaeDecoder {
    /// Build from a factory.
    pub fn new(f: &WeightFactory) -> VaeDecoder {
        let mut levels = Vec::new();
        for (l, &ch) in CHS.iter().enumerate() {
            let rb = VaeRes::new(f, &format!("vae.up{l}.rb"), ch);
            // Upsample conv to the next level's channels (none after last).
            let up = (l + 1 < CHS.len()).then(|| {
                (
                    f.conv(&format!("vae.up{l}.conv"), ch, CHS[l + 1], 3),
                    f.bias(&format!("vae.up{l}.conv"), CHS[l + 1]),
                )
            });
            levels.push((rb, up));
        }
        VaeDecoder {
            conv_in: (f.conv("vae.conv_in", 4, CHS[0], 3), f.bias("vae.conv_in", CHS[0])),
            levels,
            norm_out: f.norm("vae.norm_out", CHS[CHS.len() - 1]),
            conv_out: (
                f.conv("vae.conv_out", CHS[CHS.len() - 1], 3, 3),
                f.bias("vae.conv_out", 3),
            ),
        }
    }

    /// Decode a latent into an RGB image in `[0, 1]`.
    pub fn decode(&self, eng: &mut dyn ExecBackend, latent: &Feat) -> Feat {
        let mut h = conv2d(eng, &self.conv_in.0, &self.conv_in.1, latent, 3, 1);
        for (rb, up) in &self.levels {
            h = rb.forward(eng, &h);
            if let Some((w, b)) = up {
                h = upsample2x(&h);
                h = conv2d(eng, w, b, &h, 3, 1);
            }
        }
        let mut out = group_norm(&h, GROUPS, &self.norm_out.0, &self.norm_out.1);
        silu(&mut out.data);
        let mut img = conv2d(eng, &self.conv_out.0, &self.conv_out.1, &out, 3, 1);
        // tanh-squash into [0, 1] display range.
        for v in img.data.iter_mut() {
            *v = 0.5 * (v.tanh() + 1.0);
        }
        img
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sd::graph::HostBackend;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn decode_shape_range_determinism() {
        let f = WeightFactory::new(2, None);
        let vae = VaeDecoder::new(&f);
        let mut r = Xoshiro256pp::seed_from_u64(3);
        let mut d = vec![0.0f32; 4 * 16 * 16];
        r.fill_normal(&mut d, 1.0);
        let latent = Feat::new(4, 16, 16, d);
        let mut eng = HostBackend::new(2);
        let img = vae.decode(&mut eng, &latent);
        assert_eq!((img.c, img.h, img.w), (3, 128, 128));
        assert!(img.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let mut eng2 = HostBackend::new(1);
        let img2 = vae.decode(&mut eng2, &latent);
        assert_eq!(img.data, img2.data);
    }

    #[test]
    fn vae_weights_are_all_f16() {
        // The factory must keep every VAE tensor F16 even under a
        // quantized model (sd.cpp policy).
        let f = WeightFactory::new(2, Some(crate::sd::trace::QuantModel::Q8_0));
        let vae = VaeDecoder::new(&f);
        assert_eq!(vae.conv_in.0.dtype(), crate::ggml::DType::F16);
        assert_eq!(vae.conv_out.0.dtype(), crate::ggml::DType::F16);
    }

    #[test]
    fn different_latents_different_images() {
        let f = WeightFactory::new(2, None);
        let vae = VaeDecoder::new(&f);
        let mut eng = HostBackend::new(2);
        let a = vae.decode(&mut eng, &Feat::new(4, 16, 16, vec![0.5; 1024]));
        let b = vae.decode(&mut eng, &Feat::new(4, 16, 16, vec![-0.5; 1024]));
        assert_ne!(a.data, b.data);
    }
}
