//! Denoising samplers: single-step SD-Turbo and multi-step DDIM.
//!
//! The paper's evaluation uses SD-Turbo with **one** denoising step
//! (adversarial diffusion distillation makes one step sufficient); the
//! DDIM path exists for the multi-step ablation bench.

use super::graph::{ExecBackend, Feat};
use super::unet::UNet;
use crate::ggml::Tensor;
use crate::util::cancel::{CancelCause, CancelToken};
use crate::util::rng::Xoshiro256pp;

/// Linear-in-alpha-bar schedule point for timestep `t ∈ [0, 1000)`.
fn alpha_bar(t: f32) -> f32 {
    // Cosine-ish schedule clamped away from 0.
    let s = (t / 1000.0).clamp(0.0, 0.999);
    ((1.0 - s) * std::f32::consts::FRAC_PI_2).sin().powi(2).max(1e-4)
}

/// Draw the initial Gaussian latent for a seed.
pub fn initial_latent(seed: u64, c: usize, h: usize, w: usize) -> Feat {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut d = vec![0.0f32; c * h * w];
    r.fill_normal(&mut d, 1.0);
    Feat::new(c, h, w, d)
}

/// One-step SD-Turbo-style sampling: predict noise at the terminal
/// timestep and jump straight to the x0 estimate.
pub fn turbo_step(eng: &mut dyn ExecBackend, unet: &UNet, latent: &Feat, ctx: &Tensor) -> Feat {
    let t = 999.0;
    let ab = alpha_bar(t);
    let (a, s) = (ab.sqrt(), (1.0 - ab).sqrt());
    let eps = unet.forward(eng, latent, t, ctx);
    // x0 = (x_t - sigma * eps) / alpha
    let data = latent
        .data
        .iter()
        .zip(&eps.data)
        .map(|(x, e)| (x - s * e) / a)
        .collect();
    Feat { c: latent.c, h: latent.h, w: latent.w, data }
}

/// Multi-step deterministic DDIM (eta = 0).
pub fn ddim(
    eng: &mut dyn ExecBackend,
    unet: &UNet,
    latent: &Feat,
    ctx: &Tensor,
    steps: usize,
) -> Feat {
    ddim_cancellable(eng, unet, latent, ctx, steps, &CancelToken::new())
        .expect("a live token never aborts")
}

/// [`ddim`] with a cooperative cancel check at every step boundary: the
/// token is consulted **before** each U-Net forward, so a cancelled or
/// deadline-expired request stops submitting ops before its next
/// denoising step (the serving acceptance invariant). On abort, returns
/// the cause and the number of steps already completed.
pub fn ddim_cancellable(
    eng: &mut dyn ExecBackend,
    unet: &UNet,
    latent: &Feat,
    ctx: &Tensor,
    steps: usize,
    cancel: &CancelToken,
) -> Result<Feat, (CancelCause, usize)> {
    assert!(steps >= 1);
    let mut x = latent.clone();
    let ts: Vec<f32> = (0..steps).rev().map(|i| (i as f32 + 0.5) / steps as f32 * 999.0).collect();
    for (i, &t) in ts.iter().enumerate() {
        if let Err(cause) = cancel.check() {
            return Err((cause, i));
        }
        let ab_t = alpha_bar(t);
        let ab_prev = if i + 1 < ts.len() { alpha_bar(ts[i + 1]) } else { 1.0 };
        let (a_t, s_t) = (ab_t.sqrt(), (1.0 - ab_t).sqrt());
        let (a_p, s_p) = (ab_prev.sqrt(), (1.0 - ab_prev).sqrt());
        let eps = unet.forward(eng, &x, t, ctx);
        let data: Vec<f32> = x
            .data
            .iter()
            .zip(&eps.data)
            .map(|(xv, e)| {
                let x0 = (xv - s_t * e) / a_t;
                a_p * x0 + s_p * e
            })
            .collect();
        x = Feat { c: x.c, h: x.h, w: x.w, data };
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sd::graph::HostBackend;
    use crate::sd::unet::{LATENT_C, LATENT_HW};
    use crate::sd::weights::WeightFactory;

    fn setup() -> (UNet, Tensor) {
        let f = WeightFactory::new(3, None);
        let unet = UNet::new(&f);
        let mut r = Xoshiro256pp::seed_from_u64(4);
        let mut d = vec![0.0f32; 77 * 256];
        r.fill_normal(&mut d, 0.3);
        (unet, Tensor::f32(77, 256, d))
    }

    #[test]
    fn alpha_bar_monotone_decreasing() {
        let mut prev = alpha_bar(0.0);
        assert!(prev > 0.95, "t=0 nearly noise-free");
        for t in [100.0, 300.0, 500.0, 700.0, 999.0] {
            let a = alpha_bar(t);
            assert!(a < prev, "alpha_bar must decrease");
            assert!(a > 0.0);
            prev = a;
        }
    }

    #[test]
    fn initial_latent_seeded() {
        let a = initial_latent(9, 4, 16, 16);
        let b = initial_latent(9, 4, 16, 16);
        let c = initial_latent(10, 4, 16, 16);
        assert_eq!(a.data, b.data);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn turbo_step_produces_finite_latent() {
        let (unet, ctx) = setup();
        let mut eng = HostBackend::new(2);
        let z = initial_latent(1, LATENT_C, LATENT_HW, LATENT_HW);
        let x0 = turbo_step(&mut eng, &unet, &z, &ctx);
        assert_eq!(x0.data.len(), z.data.len());
        assert!(x0.data.iter().all(|v| v.is_finite()));
        assert_ne!(x0.data, z.data);
    }

    #[test]
    fn precancelled_token_aborts_before_any_op() {
        let (unet, ctx) = setup();
        let z = initial_latent(3, LATENT_C, LATENT_HW, LATENT_HW);
        let mut eng = HostBackend::new(2);
        let t = CancelToken::new();
        t.cancel();
        let got = ddim_cancellable(&mut eng, &unet, &z, &ctx, 4, &t);
        assert_eq!(got.unwrap_err(), (CancelCause::Cancelled, 0));
        assert_eq!(eng.stats().calls, 0, "no op submitted after a cancel");
    }

    #[test]
    fn cancel_mid_run_aborts_at_the_next_step_boundary() {
        // A backend wrapper fires the token once one full step's worth
        // of submissions completed — the loop must stop before step 2.
        use crate::sd::backend::{EngineStats, OpDesc, OpHandle, RequestId};
        struct CancelAfter<'a> {
            inner: HostBackend,
            token: &'a CancelToken,
            after: u64,
        }
        impl ExecBackend for CancelAfter<'_> {
            fn submit(&mut self, op: OpDesc<'_>) -> OpHandle {
                let h = self.inner.submit(op);
                if self.inner.stats().calls >= self.after {
                    self.token.cancel();
                }
                h
            }
            fn sync(&mut self, h: OpHandle) -> Tensor {
                self.inner.sync(h)
            }
            fn stats(&self) -> &EngineStats {
                self.inner.stats()
            }
            fn begin_request(&mut self, id: RequestId) {
                self.inner.begin_request(id)
            }
        }
        let (unet, ctx) = setup();
        let z = initial_latent(5, LATENT_C, LATENT_HW, LATENT_HW);
        let mut probe = HostBackend::new(2);
        let _ = ddim(&mut probe, &unet, &z, &ctx, 1);
        let per_step = probe.stats().calls;
        let t = CancelToken::new();
        let mut eng = CancelAfter { inner: HostBackend::new(2), token: &t, after: per_step };
        let got = ddim_cancellable(&mut eng, &unet, &z, &ctx, 6, &t);
        assert_eq!(got.unwrap_err(), (CancelCause::Cancelled, 1), "one step completed");
        assert_eq!(eng.stats().calls, per_step, "not a single op of step 2 was submitted");
    }

    #[test]
    fn expired_deadline_reports_expiry_cause() {
        let (unet, ctx) = setup();
        let z = initial_latent(6, LATENT_C, LATENT_HW, LATENT_HW);
        let mut eng = HostBackend::new(2);
        let t = CancelToken::with_deadline(std::time::Instant::now());
        let got = ddim_cancellable(&mut eng, &unet, &z, &ctx, 2, &t);
        assert_eq!(got.unwrap_err(), (CancelCause::DeadlineExpired, 0));
    }

    #[test]
    fn ddim_one_step_close_to_turbo() {
        // DDIM with 1 step uses t=499.5 vs turbo's 999 — different but
        // both must be finite and same shape; 4 steps must differ from 1.
        let (unet, ctx) = setup();
        let z = initial_latent(2, LATENT_C, LATENT_HW, LATENT_HW);
        let mut e1 = HostBackend::new(2);
        let one = ddim(&mut e1, &unet, &z, &ctx, 1);
        let mut e4 = HostBackend::new(2);
        let four = ddim(&mut e4, &unet, &z, &ctx, 4);
        assert!(one.data.iter().all(|v| v.is_finite()));
        assert!(four.data.iter().all(|v| v.is_finite()));
        assert_ne!(one.data, four.data);
        assert_eq!(e4.stats().calls, 4 * e1.stats().calls, "4x the mat-muls");
    }
}
