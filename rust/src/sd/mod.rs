//! Stable-Diffusion workload substrate (the `stable-diffusion.cpp` analog).
//!
//! Two layers of fidelity, per the substitution ledger in `DESIGN.md`:
//!
//! * **Paper scale** — [`arch`]/[`trace`] reconstruct the *mat-mul shape
//!   trace* of the real SD v1.5 / SD-Turbo pipeline at 512×512 (U-Net
//!   single step + VAE decoder + CLIP text encoder), with the dtype
//!   assignment `stable-diffusion.cpp` uses (conv-as-im2col in F16,
//!   attention score/value mat-muls in F32, linear weights quantized to
//!   the model's type). [`profiler`] turns the trace into Table I and
//!   the figure benches price it on every device model.
//! * **Mini scale** — [`graph`], [`unet`], [`vae`], [`text`],
//!   [`sampler`], [`pipeline`] implement a *runnable* latent-diffusion
//!   pipeline (~4 M parameters, 128×128 output) with synthetic weights,
//!   executed for real through the GGML kernels and (optionally) the
//!   IMAX functional simulator — this generates Fig. 5's images and is
//!   the end-to-end driver of `examples/generate_image.rs`.

pub mod arch;
pub mod backend;
pub mod graph;
pub mod pipeline;
pub mod plan;
pub mod profiler;
pub mod sampler;
pub mod text;
pub mod trace;
pub mod unet;
pub mod vae;
pub mod weights;

pub use backend::{
    EngineStats, ExecBackend, HostBackend, ImaxBackend, OpDesc, OpHandle, OpKind, RequestId,
    ShardedBackend,
};
pub use plan::{OpPlan, OpSite, PlanRecorder};
pub use trace::{MatMulOp, OpCategory, QuantModel, WorkloadTrace};
