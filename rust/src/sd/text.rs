//! Mini CLIP-style text encoder: prompt → `[77, 256]` context tensor.
//!
//! Byte-level hashing tokenizer (deterministic, dependency-free), learned
//! synthetic embeddings, and two pre-LN transformer layers whose linear
//! weights are quantized per the run's model — so the text encoder
//! exercises the same offload path the paper's CLIP does.

use super::graph::{attention, gelu, layer_norm, ExecBackend, OpDesc};
use super::weights::WeightFactory;
use crate::ggml::Tensor;
use crate::util::rng::fnv1a64;

/// Fixed context length (matching SD's CLIP).
pub const CTX_LEN: usize = 77;
/// Token vocabulary (byte-hash buckets).
pub const VOCAB: usize = 256;
/// Embedding width.
pub const DIM: usize = 256;
/// Attention heads.
pub const HEADS: usize = 4;
/// Transformer layers.
pub const LAYERS: usize = 2;

/// Hash-bucket tokenizer: words → `[0, VOCAB)` ids, padded/truncated to
/// [`CTX_LEN`].
pub fn tokenize(prompt: &str) -> Vec<usize> {
    let mut toks: Vec<usize> = prompt
        .split_whitespace()
        .map(|w| (fnv1a64(w.as_bytes()) % (VOCAB as u64 - 2)) as usize + 2)
        .collect();
    toks.insert(0, 0); // BOS
    toks.truncate(CTX_LEN - 1);
    toks.push(1); // EOS
    while toks.len() < CTX_LEN {
        toks.push(1);
    }
    toks
}

/// The encoder weights.
pub struct TextEncoder {
    emb: Tensor,
    pos: Tensor,
    layers: Vec<Layer>,
    final_norm: (Vec<f32>, Vec<f32>),
}

struct Layer {
    ln1: (Vec<f32>, Vec<f32>),
    wq: Tensor,
    wk: Tensor,
    wv: Tensor,
    wo: Tensor,
    ln2: (Vec<f32>, Vec<f32>),
    mlp1: Tensor,
    mlp1_b: Vec<f32>,
    mlp2: Tensor,
    mlp2_b: Vec<f32>,
}

impl TextEncoder {
    /// Build from a weight factory.
    pub fn new(f: &WeightFactory) -> TextEncoder {
        let layers = (0..LAYERS)
            .map(|l| Layer {
                ln1: f.norm(&format!("text.l{l}.ln1"), DIM),
                wq: f.linear(&format!("text.l{l}.q"), DIM, DIM),
                wk: f.linear(&format!("text.l{l}.k"), DIM, DIM),
                wv: f.linear(&format!("text.l{l}.v"), DIM, DIM),
                wo: f.linear(&format!("text.l{l}.o"), DIM, DIM),
                ln2: f.norm(&format!("text.l{l}.ln2"), DIM),
                mlp1: f.linear(&format!("text.l{l}.mlp1"), DIM, 2 * DIM),
                mlp1_b: f.bias(&format!("text.l{l}.mlp1"), 2 * DIM),
                mlp2: f.linear(&format!("text.l{l}.mlp2"), 2 * DIM, DIM),
                mlp2_b: f.bias(&format!("text.l{l}.mlp2"), DIM),
            })
            .collect();
        TextEncoder {
            emb: f.embedding("text.emb", VOCAB, DIM),
            pos: f.embedding("text.pos", CTX_LEN, DIM),
            layers,
            final_norm: f.norm("text.final", DIM),
        }
    }

    /// Encode a prompt into the `[77, 256]` context.
    pub fn encode(&self, eng: &mut dyn ExecBackend, prompt: &str) -> Tensor {
        let toks = tokenize(prompt);
        let mut x = vec![0.0f32; CTX_LEN * DIM];
        for (i, &t) in toks.iter().enumerate() {
            for d in 0..DIM {
                x[i * DIM + d] =
                    self.emb.as_f32()[t * DIM + d] + self.pos.as_f32()[i * DIM + d];
            }
        }
        let mut h = Tensor::f32(CTX_LEN, DIM, x);
        for l in &self.layers {
            // Pre-LN self-attention with residual. Q/K/V all read the
            // normed tokens and nothing else — submit the three before
            // syncing any so a parallel backend overlaps them.
            let n = layer_norm(&h, &l.ln1.0, &l.ln1.1);
            let hq = eng.submit(OpDesc::linear(&l.wq, &n));
            let hk = eng.submit(OpDesc::linear(&l.wk, &n));
            let hv = eng.submit(OpDesc::linear(&l.wv, &n));
            let (q, k, v) = (eng.sync(hq), eng.sync(hk), eng.sync(hv));
            let a = attention(eng, &q, &k, &v, HEADS);
            let o = eng.submit_now(OpDesc::linear(&l.wo, &a));
            let mut hd = h.as_f32().to_vec();
            for (dst, src) in hd.iter_mut().zip(o.as_f32()) {
                *dst += src;
            }
            h = Tensor::f32(CTX_LEN, DIM, hd);
            // Pre-LN MLP with residual.
            let n2 = layer_norm(&h, &l.ln2.0, &l.ln2.1);
            let mut m1 = eng.submit_now(OpDesc::linear(&l.mlp1, &n2));
            add_bias(&mut m1, &l.mlp1_b);
            if let crate::ggml::tensor::Storage::F32(vv) = &mut m1.data {
                gelu(vv);
            }
            let mut m2 = eng.submit_now(OpDesc::linear(&l.mlp2, &m1));
            add_bias(&mut m2, &l.mlp2_b);
            let mut hd = h.as_f32().to_vec();
            for (dst, src) in hd.iter_mut().zip(m2.as_f32()) {
                *dst += src;
            }
            h = Tensor::f32(CTX_LEN, DIM, hd);
        }
        layer_norm(&h, &self.final_norm.0, &self.final_norm.1)
    }
}

fn add_bias(t: &mut Tensor, bias: &[f32]) {
    let cols = t.cols;
    if let crate::ggml::tensor::Storage::F32(v) = &mut t.data {
        for row in v.chunks_mut(cols) {
            for (x, b) in row.iter_mut().zip(bias) {
                *x += b;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sd::graph::HostBackend;

    #[test]
    fn tokenizer_is_deterministic_and_padded() {
        let a = tokenize("a lovely cat");
        let b = tokenize("a lovely cat");
        assert_eq!(a, b);
        assert_eq!(a.len(), CTX_LEN);
        assert_eq!(a[0], 0, "BOS");
        assert!(a.iter().all(|&t| t < VOCAB));
        assert_ne!(tokenize("a lovely cat"), tokenize("a lovely dog"));
    }

    #[test]
    fn encode_shape_and_determinism() {
        let f = WeightFactory::new(42, None);
        let enc = TextEncoder::new(&f);
        let mut eng = HostBackend::new(1);
        let a = enc.encode(&mut eng, "a lovely cat");
        assert_eq!((a.rows, a.cols), (CTX_LEN, DIM));
        let mut eng2 = HostBackend::new(2);
        let b = enc.encode(&mut eng2, "a lovely cat");
        assert_eq!(a.as_f32(), b.as_f32(), "thread count must not change result");
    }

    #[test]
    fn different_prompts_different_contexts() {
        let f = WeightFactory::new(42, None);
        let enc = TextEncoder::new(&f);
        let mut eng = HostBackend::new(1);
        let a = enc.encode(&mut eng, "a lovely cat");
        let b = enc.encode(&mut eng, "an angry robot");
        assert_ne!(a.as_f32(), b.as_f32());
    }

    #[test]
    fn outputs_are_finite_and_normalized() {
        let f = WeightFactory::new(42, Some(crate::sd::trace::QuantModel::Q8_0));
        let enc = TextEncoder::new(&f);
        let mut eng = HostBackend::new(1);
        let a = enc.encode(&mut eng, "quantized path");
        assert!(a.as_f32().iter().all(|v| v.is_finite()));
    }
}
