//! Typed op-submission execution API — the offload seam.
//!
//! Historically every pipeline mat-mul went through an eager, kind-blind
//! `MatMulEngine::mul_mat(w, x) -> Tensor`. That seam could not express
//! what the dispatcher needs to *see* to schedule well: which kernel
//! kind an op is (the CGLA pays CONF on kind switches), which weight it
//! names (residency), and which request it serves (serving accounting).
//! This module replaces it with a **typed submission API**:
//!
//! * [`OpDesc`] — one operation: an [`OpKind`] (`Linear`,
//!   `ConvIm2col{k,stride}`, `AttnScores`, `AttnValues`, `TimeEmbed`),
//!   the weight identity, the operand tensors and the request tag;
//! * [`ExecBackend`] — `submit(OpDesc) -> OpHandle` plus
//!   `sync(OpHandle) -> Tensor` (and the [`ExecBackend::submit_now`]
//!   sugar for the synchronous callers);
//! * three implementations: [`HostBackend`] (GGML kernels on CPU
//!   threads), [`ImaxBackend`] (one simulated lane, paper §III-B
//!   policy), and [`ShardedBackend`] (the multi-lane coordinator with
//!   **single-op row-tile sharding**: one op's weight rows split across
//!   lanes, each lane loading/caching only its resident shard, outputs
//!   stitched bit-identically).
//!
//! [`crate::sd::plan::PlanRecorder`] is a fourth backend that records
//! the typed op sequence instead of executing it — the compiled
//! [`crate::sd::plan::OpPlan`] is what the prefetch/pin passes and the
//! per-lane CONF grouping consume.
//!
//! # Migration from `MatMulEngine`
//!
//! | old                              | new                                          |
//! |----------------------------------|----------------------------------------------|
//! | `eng.mul_mat(&w, &x)`            | `eng.submit_now(OpDesc::linear(&w, &x))`     |
//! | `HostEngine::new(t)`             | `HostBackend::new(t)`                        |
//! | `ImaxEngine::new(cfg, t)`        | `ImaxBackend::new(cfg, t)`                   |
//! | (not expressible)                | `ShardedBackend` / `Backend::Sharded`        |
//!
//! # Asynchronous submission
//!
//! On [`ShardedBackend`] the `submit`/`sync` split is a real concurrency
//! boundary: a shardable op is split into row-tile shards that are
//! enqueued onto their owning lanes' worker threads (one worker per
//! simulated lane — [`crate::util::pool::LanePool`]) and `submit`
//! returns its handle **immediately**; `sync` blocks on the per-shard
//! completion slots, stitches the output column-wise, and merges
//! per-lane counters in shard order. Shards of one op run concurrently,
//! and independent ops submitted back-to-back — the Q/K/V projections in
//! `sd/unet.rs`/`sd/text.rs`, merged rendezvous submissions in
//! `serve/batcher.rs` — overlap across lanes before any of them is
//! synced. Outputs and all simulated cycle/byte counters are
//! bit-identical to the sequential path regardless of thread
//! interleaving (per-lane queues are FIFO in submission order, and
//! counters merge at `sync` in deterministic shard order) — see the
//! "Concurrency model" chapter in `DESIGN.md` for the full argument.
//!
//! [`HostBackend`] and [`ImaxBackend`] still execute eagerly inside
//! `submit`, parking the result until `sync`. The trait contract is
//! identical either way: every handle must be synced exactly once, and
//! callers that want overlap simply submit independent ops before
//! syncing any of them.

use crate::coordinator::{Coordinator, PendingSharded};
use crate::ggml::{self, DType, Tensor, WeightId};
use crate::imax::lane::LaneSim;
use crate::imax::lmm::CacheStats;
use crate::imax::timing::PhaseBreakdown;
use crate::imax::ImaxConfig;
use crate::sd::plan::OpPlan;
use crate::sd::trace::QuantModel;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Identity of one serving request, threaded through the backends so a
/// shared profile can be split per request (the serving layer's latency
/// and accounting unit). Single-shot runs use [`RequestId::SOLO`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RequestId(pub u64);

impl RequestId {
    /// The id used by non-serving (single request) pipeline runs.
    pub const SOLO: RequestId = RequestId(0);
}

/// What a submitted mat-mul *is* in the graph — the kernel-kind-aware
/// dispatch key. The CGLA mapping work (and SD-Acc-style phase-aware
/// dispatch) both hinge on the dispatcher seeing this rather than an
/// opaque `(w, x)` pair: kinds group into CONF-compatible runs, identify
/// per-request operands (attention), and name the im2col geometry convs
/// would need for a future OP_SML16 offload.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Dense projection: quantized (or F16) model weight × activations.
    Linear,
    /// Convolution lowered to a GEMM over im2col patches of a `k×k`
    /// kernel with stride `stride` (weights `[cout, cin·k·k]`).
    ConvIm2col {
        /// Spatial kernel size.
        k: usize,
        /// Convolution stride.
        stride: usize,
    },
    /// Attention score mat-mul `q · kᵀ` (both operands per-request F32).
    AttnScores,
    /// Attention value mat-mul `softmax(scores) · v` (per-request F32).
    AttnValues,
    /// Timestep-embedding projection (dense, but n = 1 — the GEMV-style
    /// site the LLM-on-CGLA follow-up optimizes for).
    TimeEmbed,
}

impl OpKind {
    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            OpKind::Linear => "linear",
            OpKind::ConvIm2col { .. } => "conv_im2col",
            OpKind::AttnScores => "attn_scores",
            OpKind::AttnValues => "attn_values",
            OpKind::TimeEmbed => "time_embed",
        }
    }

    /// Whether both operands are per-request activation tensors (nothing
    /// shared between concurrent requests). These ops bypass the serving
    /// rendezvous and run immediately on the host path — which is also
    /// the paper's routing (attention F32 never offloads).
    pub fn per_request_operands(self) -> bool {
        matches!(self, OpKind::AttnScores | OpKind::AttnValues)
    }
}

/// One typed operation submitted to an [`ExecBackend`]:
/// `out[n, m] = Σ_k w[m, k] · x[n, k]` plus the dispatch metadata the
/// eager seam could not carry.
#[derive(Debug, Clone, Copy)]
pub struct OpDesc<'a> {
    /// What the op is in the graph.
    pub kind: OpKind,
    /// Weight content identity (`None` for anonymous tensors, e.g. the
    /// per-request attention operands).
    pub wid: Option<WeightId>,
    /// Weight operand `[m, k]`.
    pub w: &'a Tensor,
    /// Activation operand `[n, k]` (f32).
    pub x: &'a Tensor,
    /// Request the op serves. [`RequestId::SOLO`] (the constructor
    /// default) inherits the backend's current request set via
    /// [`ExecBackend::begin_request`]; an explicit tag overrides it.
    pub request: RequestId,
}

impl<'a> OpDesc<'a> {
    /// Build a descriptor of `kind` (weight identity taken from `w`).
    pub fn new(kind: OpKind, w: &'a Tensor, x: &'a Tensor) -> OpDesc<'a> {
        OpDesc { kind, wid: w.wid, w, x, request: RequestId::SOLO }
    }

    /// Dense projection.
    pub fn linear(w: &'a Tensor, x: &'a Tensor) -> OpDesc<'a> {
        OpDesc::new(OpKind::Linear, w, x)
    }

    /// im2col conv GEMM (`x` is the `[oh·ow, cin·k·k]` patch matrix).
    pub fn conv_im2col(w: &'a Tensor, cols: &'a Tensor, k: usize, stride: usize) -> OpDesc<'a> {
        OpDesc::new(OpKind::ConvIm2col { k, stride }, w, cols)
    }

    /// Attention scores: `w` = per-head keys `[m_tokens, d]`, `x` =
    /// per-head queries `[n, d]`.
    pub fn attn_scores(k_head: &'a Tensor, q_head: &'a Tensor) -> OpDesc<'a> {
        OpDesc::new(OpKind::AttnScores, k_head, q_head)
    }

    /// Attention values: `w` = transposed per-head values `[d, m_tokens]`,
    /// `x` = softmaxed scores `[n, m_tokens]`.
    pub fn attn_values(v_t: &'a Tensor, scores: &'a Tensor) -> OpDesc<'a> {
        OpDesc::new(OpKind::AttnValues, v_t, scores)
    }

    /// Timestep-embedding projection.
    pub fn time_embed(w: &'a Tensor, x: &'a Tensor) -> OpDesc<'a> {
        OpDesc::new(OpKind::TimeEmbed, w, x)
    }

    /// Tag the op with an explicit request.
    pub fn with_request(mut self, request: RequestId) -> OpDesc<'a> {
        self.request = request;
        self
    }

    /// Override the weight identity (the constructors default to
    /// `w.wid`). `OpDesc.wid` — not the tensor's own id — is what every
    /// consumer reads: plan recording, the dispatch check, lane
    /// affinity, residency caching and shard-id derivation. Overriding
    /// lets a caller alias distinct tensors to one cache entry (or
    /// split one tensor's uses into distinct entries).
    pub fn with_wid(mut self, wid: WeightId) -> OpDesc<'a> {
        self.wid = Some(wid);
        self
    }

    /// MAC count of the op.
    pub fn macs(&self) -> u64 {
        (self.w.rows * self.w.cols * self.x.rows) as u64
    }
}

/// Handle to a submitted op; redeem with [`ExecBackend::sync`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OpHandle(u64);

/// Completion store shared by the backend implementations: `submit`
/// parks the finished tensor here, `sync` takes it out. Slots are freed
/// on `sync`, so long-lived backends do not grow without bound.
#[derive(Debug, Default)]
pub struct Completions {
    next: u64,
    ready: std::collections::HashMap<u64, Tensor>,
}

impl Completions {
    /// Park a finished result, minting its handle.
    pub fn complete(&mut self, out: Tensor) -> OpHandle {
        let h = OpHandle(self.next);
        self.next += 1;
        self.ready.insert(h.0, out);
        h
    }

    /// Mint a handle with **no parked result** — for backends whose op
    /// is still in flight at submit time ([`ShardedBackend`] tracks the
    /// pending shards keyed by this handle and joins them on `sync`).
    /// Calling [`Completions::take`] on a deferred handle that was never
    /// completed panics, same as a double-sync.
    pub fn defer(&mut self) -> OpHandle {
        let h = OpHandle(self.next);
        self.next += 1;
        h
    }

    /// Redeem a handle (panics on double-sync or a foreign handle).
    pub fn take(&mut self, h: OpHandle) -> Tensor {
        self.ready
            .remove(&h.0)
            .unwrap_or_else(|| panic!("OpHandle {h:?} already synced (or from another backend)"))
    }

    /// Ops submitted but not yet synced.
    pub fn pending(&self) -> usize {
        self.ready.len()
    }
}

/// Per-backend run statistics (mini analog of the paper's profiling).
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Wall-clock seconds per weight dtype.
    pub seconds_by_dtype: BTreeMap<&'static str, f64>,
    /// MACs per weight dtype.
    pub macs_by_dtype: BTreeMap<&'static str, u64>,
    /// MACs per request id (one entry for non-serving runs).
    pub macs_by_request: BTreeMap<u64, u64>,
    /// Submitted ops.
    pub calls: u64,
    /// Ops executed on the IMAX simulator.
    pub offloaded_calls: u64,
    /// Lane submissions those ops decomposed into (> `offloaded_calls`
    /// when the backend shards single ops across lanes).
    pub lane_submissions: u64,
    /// Accumulated IMAX phase breakdown (zero for host-only runs).
    pub imax_phases: PhaseBreakdown,
    /// Weight-residency cache counters, summed over the lanes the
    /// backend executed on (zero for host-only runs).
    pub cache: CacheStats,
    /// Submissions that did not match the compiled [`OpPlan`] site at
    /// their position (0 when no plan is attached, or when dispatch
    /// followed the plan exactly).
    pub plan_divergences: u64,
}

impl EngineStats {
    /// Record one op for `request` (crate-visible so backend
    /// implementations outside this module, e.g. the serving batcher,
    /// account identically).
    pub(crate) fn record(&mut self, request: RequestId, dtype: DType, macs: u64, secs: f64) {
        *self.seconds_by_dtype.entry(dtype.name()).or_insert(0.0) += secs;
        *self.macs_by_dtype.entry(dtype.name()).or_insert(0) += macs;
        *self.macs_by_request.entry(request.0).or_insert(0) += macs;
        self.calls += 1;
    }
}

/// The offload seam: every pipeline mat-mul is submitted through here as
/// a typed [`OpDesc`].
pub trait ExecBackend {
    /// Submit one op; the returned handle is redeemed with
    /// [`ExecBackend::sync`].
    ///
    /// On [`ShardedBackend`] with worker threads enabled this returns
    /// **before** the op has executed — its shards are enqueued on their
    /// lanes' worker threads — so independent ops can be submitted
    /// back-to-back and overlap across lanes:
    ///
    /// ```rust
    /// use imax_sd::ggml::{DType, Tensor, WeightId};
    /// use imax_sd::imax::ImaxConfig;
    /// use imax_sd::sd::backend::{ExecBackend, OpDesc, ShardedBackend};
    ///
    /// let wq = Tensor::f32(8, 64, vec![0.5; 512]).quantize(DType::Q8_0).with_wid(WeightId(1));
    /// let wk = Tensor::f32(8, 64, vec![0.25; 512]).quantize(DType::Q8_0).with_wid(WeightId(2));
    /// let x = Tensor::f32(2, 64, vec![0.125; 128]);
    ///
    /// // 2 lanes, 2 host threads => lane worker pool enabled.
    /// let mut b = ShardedBackend::from_config(ImaxConfig::fpga(2), 2);
    /// let hq = b.submit(OpDesc::linear(&wq, &x)); // enqueued, returns immediately
    /// let hk = b.submit(OpDesc::linear(&wk, &x)); // overlaps with the first op
    /// let (q, k) = (b.sync(hq), b.sync(hk));      // blocks, stitches, merges counters
    /// assert_eq!((q.rows, q.cols), (2, 8));
    /// assert_eq!((k.rows, k.cols), (2, 8));
    /// ```
    fn submit(&mut self, op: OpDesc<'_>) -> OpHandle;

    /// Block until a submitted op's output is ready and take it.
    ///
    /// Each handle must be synced exactly once; double-sync (or a
    /// foreign handle) panics. Sync order is free — any order of syncing
    /// outstanding handles yields bit-identical tensors and counters:
    ///
    /// ```rust
    /// use imax_sd::ggml::{DType, Tensor, WeightId};
    /// use imax_sd::imax::ImaxConfig;
    /// use imax_sd::sd::backend::{ExecBackend, OpDesc, ShardedBackend};
    ///
    /// let w = Tensor::f32(4, 64, vec![0.5; 256]).quantize(DType::Q8_0).with_wid(WeightId(7));
    /// let x = Tensor::f32(1, 64, vec![0.25; 64]);
    /// let mut b = ShardedBackend::from_config(ImaxConfig::fpga(2), 2);
    /// let h1 = b.submit(OpDesc::linear(&w, &x));
    /// let h2 = b.submit(OpDesc::linear(&w, &x));
    /// let out2 = b.sync(h2); // syncing out of submission order is fine
    /// let out1 = b.sync(h1);
    /// for (a, b) in out1.as_f32().iter().zip(out2.as_f32()) {
    ///     assert_eq!(a.to_bits(), b.to_bits());
    /// }
    /// ```
    fn sync(&mut self, h: OpHandle) -> Tensor;

    /// Submit + sync in one call — the synchronous sugar every graph-
    /// level caller uses today.
    fn submit_now(&mut self, op: OpDesc<'_>) -> Tensor {
        let h = self.submit(op);
        self.sync(h)
    }

    /// Statistics so far.
    fn stats(&self) -> &EngineStats;

    /// Tag subsequent ops with a request id (default: keep SOLO).
    fn begin_request(&mut self, _id: RequestId) {}
}

/// Resolve the request an op is accounted to: an explicit tag wins,
/// otherwise the backend's current request.
pub(crate) fn resolve_request(op: &OpDesc<'_>, current: RequestId) -> RequestId {
    if op.request == RequestId::SOLO {
        current
    } else {
        op.request
    }
}

/// The compiled-plan dispatch check shared by the plan-aware backends:
/// armed with a recorded `(wid, kind)` sequence, it verifies each
/// submission against the site at its position.
#[derive(Default)]
struct PlanCheck {
    sites: Option<Vec<(Option<WeightId>, OpKind)>>,
    pos: usize,
}

impl PlanCheck {
    /// Arm the check with a compiled plan (resets the cursor).
    fn arm(&mut self, plan: &OpPlan) {
        self.sites = Some(plan.sites.iter().map(|s| (s.wid, s.kind)).collect());
        self.pos = 0;
    }

    /// Advance past one submission; `true` when it diverged from the
    /// armed plan (always `false` while unarmed).
    fn diverges(&mut self, op: &OpDesc<'_>) -> bool {
        let Some(sites) = &self.sites else {
            return false;
        };
        let ok = matches!(
            sites.get(self.pos),
            Some((wid, kind)) if *wid == op.wid && *kind == op.kind
        );
        self.pos += 1;
        !ok
    }
}

/// Execute one F16 im2col conv GEMM on `lane` in **LMM-capacity-aware
/// chunks of patch rows**: the `[oh·ow, cin·k·k]` patch matrix is split
/// into activation tiles whose f32 bytes fit half the lane's transient
/// partition (the same half-split [`crate::imax::lane::TilePlan`] uses,
/// so each chunk tiles without further subdivision pressure), every
/// chunk runs through [`LaneSim::mul_mat_f16_cached`] under the *same*
/// weight identity — the first chunk pays the cache fill, later chunks
/// and later calls hit the resident rows — and the chunk outputs are
/// stitched back row-wise. Each output element is one independent
/// OP_SML16 vec-dot of unchanged operand bytes, so the stitched result
/// is bit-identical to a single whole-op submission (and to the host
/// `dot_f16_f32` reference). Returns the output, the summed phases, and
/// the number of lane submissions the op decomposed into.
fn run_f16_conv_on_lane(
    lane: &mut LaneSim,
    wid: Option<WeightId>,
    w: &Tensor,
    x: &Tensor,
) -> (Vec<f32>, PhaseBreakdown, u64) {
    use crate::imax::conf::KernelKind;
    use crate::imax::lane::act_row_bytes;
    let halves = match &w.data {
        crate::ggml::tensor::Storage::F16(h) => h,
        _ => unreachable!("conv offload wants F16 weights"),
    };
    let (m, k, n) = (w.rows, w.cols, x.rows);
    let transient = lane.imax.lmm_bytes - lane.lmm.cache_budget();
    let rows_per = (transient / 2 / act_row_bytes(KernelKind::F16, k)).clamp(1, n);
    let acts = x.as_f32();
    let mut out = vec![0.0f32; n * m];
    let mut phases = PhaseBreakdown::default();
    let mut submissions = 0u64;
    let mut r0 = 0;
    while r0 < n {
        let r1 = (r0 + rows_per).min(n);
        let (data, bd) = lane
            .mul_mat_f16_cached(wid, halves, m, &acts[r0 * k..r1 * k], r1 - r0, k)
            .expect("im2col chunk fits LMM");
        out[r0 * m..r1 * m].copy_from_slice(&data);
        phases += bd;
        submissions += 1;
        r0 = r1;
    }
    (out, phases, submissions)
}

/// Quantize the activations and run one whole op on `lane`, caching
/// under `wid` — the single-lane analog of the coordinator's
/// marshal+run primitive. Returns `None` when `w` is not a lane dtype
/// (the caller falls back to the host kernels).
fn run_quantized_on_lane(
    lane: &mut LaneSim,
    wid: Option<WeightId>,
    w: &Tensor,
    x: &Tensor,
) -> Option<(Vec<f32>, PhaseBreakdown)> {
    match &w.data {
        crate::ggml::tensor::Storage::Q8_0(blocks) => {
            let acts: Vec<_> = (0..x.rows)
                .flat_map(|r| crate::ggml::q8_0::quantize_row(x.row_f32(r)))
                .collect();
            Some(
                lane.mul_mat_q8_0_cached(wid, blocks, w.rows, &acts, x.rows, w.cols)
                    .expect("mini shapes fit LMM"),
            )
        }
        crate::ggml::tensor::Storage::Q3K(blocks) => {
            let acts: Vec<_> = (0..x.rows)
                .flat_map(|r| crate::ggml::q8_k::quantize_row(x.row_f32(r)))
                .collect();
            Some(
                lane.mul_mat_q3_k_cached(wid, blocks, w.rows, &acts, x.rows, w.cols)
                    .expect("mini shapes fit LMM"),
            )
        }
        _ => None,
    }
}

/// Host backend: GGML kernels on CPU threads.
pub struct HostBackend {
    /// Worker threads for row-parallel mat-muls.
    pub threads: usize,
    request: RequestId,
    stats: EngineStats,
    done: Completions,
}

impl HostBackend {
    /// New host backend.
    pub fn new(threads: usize) -> HostBackend {
        HostBackend {
            threads,
            request: RequestId::SOLO,
            stats: EngineStats::default(),
            done: Completions::default(),
        }
    }
}

impl ExecBackend for HostBackend {
    fn submit(&mut self, op: OpDesc<'_>) -> OpHandle {
        let t0 = std::time::Instant::now();
        let out = ggml::mul_mat(op.w, op.x, self.threads);
        let request = resolve_request(&op, self.request);
        self.stats.record(request, op.w.dtype(), op.macs(), t0.elapsed().as_secs_f64());
        self.done.complete(out)
    }

    fn sync(&mut self, h: OpHandle) -> Tensor {
        self.done.take(h)
    }

    fn stats(&self) -> &EngineStats {
        &self.stats
    }

    fn begin_request(&mut self, id: RequestId) {
        self.request = id;
    }
}

/// IMAX backend: lane-eligible ops run functionally on one lane
/// simulator (bit-exact vs the hardware dataflow); everything else falls
/// back to the host path. The routing policy defaults to the paper's
/// §III-B quantized-only rule; [`ImaxBackend::with_policy`] (or the
/// `--conv-offload` CLI flag) enables the §VI extension that also runs
/// F16 `ConvIm2col` GEMMs on the lane via the OP_SML16 kernel.
pub struct ImaxBackend {
    lane: LaneSim,
    /// Host threads for the non-offloaded ops.
    pub threads: usize,
    /// Routing policy (kind-aware; see
    /// [`crate::coordinator::OffloadPolicy::offloads_op`]).
    pub policy: crate::coordinator::OffloadPolicy,
    request: RequestId,
    stats: EngineStats,
    done: Completions,
    plan: PlanCheck,
}

impl ImaxBackend {
    /// New backend over an IMAX configuration (quantized-only policy).
    pub fn new(imax: ImaxConfig, threads: usize) -> ImaxBackend {
        ImaxBackend::with_policy(imax, threads, crate::coordinator::OffloadPolicy::QuantizedOnly)
    }

    /// [`ImaxBackend::new`] with an explicit routing policy.
    pub fn with_policy(
        imax: ImaxConfig,
        threads: usize,
        policy: crate::coordinator::OffloadPolicy,
    ) -> ImaxBackend {
        ImaxBackend {
            lane: LaneSim::new(imax),
            threads,
            policy,
            request: RequestId::SOLO,
            stats: EngineStats::default(),
            done: Completions::default(),
            plan: PlanCheck::default(),
        }
    }

    /// Attach a compiled [`OpPlan`]: runs the prefetch/pin pass (pin the
    /// hottest weights **this backend's policy routes to the lane** that
    /// fit the cache budget) and arms the dispatch check — each
    /// submission is verified against the recorded `(wid, kind)` at its
    /// position. Call once, before the first submission, on a backend
    /// that will execute exactly one recorded sequence.
    pub fn apply_plan(&mut self, plan: &OpPlan) {
        for wid in plan.pin_set_for(self.lane.lmm.cache_budget(), self.policy) {
            self.lane.pin_weight(wid);
        }
        self.plan.arm(plan);
    }

    /// The simulated lane (cache/DMA/phase introspection).
    pub fn lane(&self) -> &LaneSim {
        &self.lane
    }

    /// Which quantized model a weight dtype's offloads correspond to.
    pub fn quant_model_of(dtype: DType) -> Option<QuantModel> {
        match dtype {
            DType::Q3K => Some(QuantModel::Q3K),
            DType::Q8_0 => Some(QuantModel::Q8_0),
            _ => None,
        }
    }
}

impl ExecBackend for ImaxBackend {
    fn submit(&mut self, op: OpDesc<'_>) -> OpHandle {
        let t0 = std::time::Instant::now();
        let macs = op.macs();
        if self.plan.diverges(&op) {
            self.stats.plan_divergences += 1;
        }
        let (w, x) = (op.w, op.x);
        let out = if w.dtype() == DType::F16 && self.policy.offloads_op(w, op.kind) {
            // §VI conv offload: F16 ConvIm2col runs on the OP_SML16
            // kernel in LMM-tiled im2col chunks (F16 linears never reach
            // this arm — the policy is kind-aware).
            let (data, bd, submissions) = run_f16_conv_on_lane(&mut self.lane, op.wid, w, x);
            self.stats.imax_phases += bd;
            self.stats.offloaded_calls += 1;
            self.stats.lane_submissions += submissions;
            self.stats.cache = self.lane.cache_stats();
            Tensor::f32(x.rows, w.rows, data)
        } else {
            match run_quantized_on_lane(&mut self.lane, op.wid, w, x) {
                Some((data, bd)) => {
                    self.stats.imax_phases += bd;
                    self.stats.offloaded_calls += 1;
                    self.stats.lane_submissions += 1;
                    self.stats.cache = self.lane.cache_stats();
                    Tensor::f32(x.rows, w.rows, data)
                }
                None => ggml::mul_mat(w, x, self.threads),
            }
        };
        let request = resolve_request(&op, self.request);
        self.stats.record(request, w.dtype(), macs, t0.elapsed().as_secs_f64());
        self.done.complete(out)
    }

    fn sync(&mut self, h: OpHandle) -> Tensor {
        self.done.take(h)
    }

    fn stats(&self) -> &EngineStats {
        &self.stats
    }

    fn begin_request(&mut self, id: RequestId) {
        self.request = id;
    }
}

/// Sharded backend: routes through the multi-lane [`Coordinator`] and
/// splits every offload-eligible op's weight **row-tiles across the
/// lanes** — each lane computes (and caches) only its shard, outputs are
/// stitched back column-wise, bit-identical to unsharded execution (each
/// output element is one independent vec-dot; no partial sums cross a
/// shard boundary).
///
/// This is the bandwidth-scaling mode the ROADMAP calls for: `L` lanes
/// hold `L×` the aggregate resident weight bytes, so the warm-step
/// weight LOAD per lane *shrinks* as lanes are added instead of every
/// lane re-streaming the full matrix.
///
/// With worker threads enabled (`host_threads > 1`), `submit` enqueues
/// the op's shards on their lanes' worker threads and returns
/// immediately; the pending shards are held in `inflight` keyed by the
/// deferred handle until `sync` joins them. With a single host thread
/// every shard runs inline during `submit` — same code path, same
/// counters, no threads.
pub struct ShardedBackend {
    coordinator: Arc<Coordinator>,
    request: RequestId,
    stats: EngineStats,
    done: Completions,
    inflight: std::collections::HashMap<u64, PendingSharded>,
    plan: PlanCheck,
}

impl ShardedBackend {
    /// New backend over a shared coordinator.
    pub fn new(coordinator: Arc<Coordinator>) -> ShardedBackend {
        ShardedBackend {
            coordinator,
            request: RequestId::SOLO,
            stats: EngineStats::default(),
            done: Completions::default(),
            inflight: std::collections::HashMap::new(),
            plan: PlanCheck::default(),
        }
    }

    /// Build a private coordinator: `imax.lanes` lanes, `host_threads`
    /// host workers, quantized-only offload policy.
    pub fn from_config(imax: ImaxConfig, host_threads: usize) -> ShardedBackend {
        ShardedBackend::from_config_policy(
            imax,
            host_threads,
            crate::coordinator::OffloadPolicy::QuantizedOnly,
        )
    }

    /// [`ShardedBackend::from_config`] with an explicit routing policy
    /// (`QuantizedAndConv` adds the §VI F16 conv offload).
    pub fn from_config_policy(
        imax: ImaxConfig,
        host_threads: usize,
        policy: crate::coordinator::OffloadPolicy,
    ) -> ShardedBackend {
        let lanes = imax.lanes;
        ShardedBackend::new(Arc::new(Coordinator::new(imax, lanes, host_threads, policy)))
    }

    /// The coordinator (lane/cache/metric introspection).
    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.coordinator
    }

    /// Run the sharded prefetch/pin pass for a compiled plan — each hot
    /// weight's row-tiles are pinned shard-by-shard on their owning
    /// lanes (see [`Coordinator::apply_plan_sharded`]) — and arm the
    /// dispatch check: each submission is verified against the recorded
    /// `(wid, kind)` at its position, like [`ImaxBackend::apply_plan`].
    pub fn apply_plan(&mut self, plan: &OpPlan) {
        self.coordinator.apply_plan_sharded(plan);
        self.plan.arm(plan);
    }
}

impl ExecBackend for ShardedBackend {
    fn submit(&mut self, op: OpDesc<'_>) -> OpHandle {
        let t0 = std::time::Instant::now();
        let macs = op.macs();
        let request = resolve_request(&op, self.request);
        if self.plan.diverges(&op) {
            self.stats.plan_divergences += 1;
        }
        let h = if self.coordinator.shardable(&op) {
            // Async path: fan the shards out to their lanes' worker
            // queues (or run them inline when the pool is disabled) and
            // defer the handle; `sync` joins and stitches.
            let pending = self.coordinator.start_sharded(&op);
            self.stats.offloaded_calls += 1;
            self.stats.lane_submissions += pending.shards() as u64;
            let h = self.done.defer();
            self.inflight.insert(h.0, pending);
            h
        } else {
            self.done.complete(self.coordinator.submit_op(&op))
        };
        self.stats.record(request, op.w.dtype(), macs, t0.elapsed().as_secs_f64());
        h
    }

    fn sync(&mut self, h: OpHandle) -> Tensor {
        match self.inflight.remove(&h.0) {
            Some(pending) => {
                let run = self.coordinator.join_sharded(pending);
                self.stats.imax_phases += run.phases;
                self.stats.cache += run.cache;
                run.out
            }
            None => self.done.take(h),
        }
    }

    fn stats(&self) -> &EngineStats {
        &self.stats
    }

    fn begin_request(&mut self, id: RequestId) {
        self.request = id;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::OffloadPolicy;
    use crate::util::rng::Xoshiro256pp;

    fn rnd(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let mut v = vec![0.0f32; rows * cols];
        r.fill_normal(&mut v, 0.5);
        Tensor::f32(rows, cols, v)
    }

    #[test]
    fn op_desc_constructors_carry_kind_and_wid() {
        let w = rnd(4, 64, 1).quantize(DType::Q8_0).with_wid(WeightId(9));
        let x = rnd(2, 64, 2);
        let d = OpDesc::linear(&w, &x);
        assert_eq!(d.kind, OpKind::Linear);
        assert_eq!(d.wid, Some(WeightId(9)));
        assert_eq!(d.request, RequestId::SOLO);
        assert_eq!(d.macs(), 4 * 64 * 2);
        let c = OpDesc::conv_im2col(&w, &x, 3, 2);
        assert_eq!(c.kind, OpKind::ConvIm2col { k: 3, stride: 2 });
        assert!(OpDesc::attn_scores(&x, &x).kind.per_request_operands());
        assert!(OpDesc::attn_values(&x, &x).kind.per_request_operands());
        assert!(!OpDesc::time_embed(&w, &x).kind.per_request_operands());
        assert_eq!(d.with_request(RequestId(7)).request, RequestId(7));
        assert_eq!(d.with_wid(WeightId(42)).wid, Some(WeightId(42)), "override wins");
    }

    #[test]
    fn submit_sync_round_trip_and_handle_reuse_panics() {
        let w = rnd(4, 64, 3).quantize(DType::Q8_0);
        let x = rnd(2, 64, 4);
        let mut b = HostBackend::new(1);
        let h = b.submit(OpDesc::linear(&w, &x));
        let out = b.sync(h);
        assert_eq!((out.rows, out.cols), (2, 4));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| b.sync(h)));
        assert!(r.is_err(), "double sync must panic");
    }

    #[test]
    fn host_backend_stats_accumulate() {
        let mut b = HostBackend::new(1);
        let w = rnd(4, 32, 5).quantize(DType::Q8_0);
        let x = rnd(2, 32, 6);
        b.submit_now(OpDesc::linear(&w, &x));
        assert_eq!(b.stats().calls, 1);
        assert_eq!(b.stats().macs_by_dtype["Q8_0"], 4 * 32 * 2);
    }

    #[test]
    fn request_tag_overrides_begin_request() {
        let mut b = HostBackend::new(1);
        let w = rnd(4, 32, 7).quantize(DType::Q8_0);
        let x = rnd(2, 32, 8);
        b.begin_request(RequestId(3));
        b.submit_now(OpDesc::linear(&w, &x)); // inherits 3
        b.submit_now(OpDesc::linear(&w, &x).with_request(RequestId(9)));
        assert_eq!(b.stats().macs_by_request[&3], 4 * 32 * 2);
        assert_eq!(b.stats().macs_by_request[&9], 4 * 32 * 2);
    }

    #[test]
    fn imax_backend_offloads_quantized_only() {
        let mut b = ImaxBackend::new(ImaxConfig::fpga(1), 1);
        let w_f = rnd(4, 32, 9);
        let w_q = w_f.quantize(DType::Q8_0);
        let x = rnd(2, 32, 10);
        b.submit_now(OpDesc::linear(&w_f, &x));
        assert_eq!(b.stats().offloaded_calls, 0, "f32 stays on host");
        b.submit_now(OpDesc::linear(&w_q, &x));
        assert_eq!(b.stats().offloaded_calls, 1, "quantized goes to IMAX");
        assert_eq!(b.stats().lane_submissions, 1);
        assert!(b.stats().imax_phases.total() > 0);
    }

    #[test]
    fn imax_backend_caches_identified_weights_across_calls() {
        let w = rnd(8, 64, 11).quantize(DType::Q8_0).with_wid(WeightId(0xBEEF));
        let x = rnd(2, 64, 12);
        let mut b = ImaxBackend::new(ImaxConfig::fpga(1), 1);
        let a = b.submit_now(OpDesc::linear(&w, &x));
        let cold_load = b.stats().imax_phases.load;
        let c = b.submit_now(OpDesc::linear(&w, &x));
        let warm_load = b.stats().imax_phases.load - cold_load;
        assert!(warm_load < cold_load, "second call hits the residency cache");
        assert_eq!(b.stats().cache.hits, 1);
        assert_eq!(b.stats().cache.misses, 1);
        for (p, q) in a.as_f32().iter().zip(c.as_f32()) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
    }

    #[test]
    fn imax_backend_plan_checks_wid_and_kind() {
        use crate::sd::plan::PlanRecorder;
        let w = rnd(4, 64, 13).quantize(DType::Q8_0).with_wid(WeightId(0xF00D));
        let x = rnd(2, 64, 14);
        let mut rec = PlanRecorder::new();
        rec.submit_now(OpDesc::linear(&w, &x));
        rec.submit_now(OpDesc::time_embed(&w, &x));
        let plan = rec.finish();

        let mut b = ImaxBackend::new(ImaxConfig::fpga(1), 1);
        b.apply_plan(&plan);
        b.submit_now(OpDesc::linear(&w, &x)); // matches site 0
        assert_eq!(b.stats().plan_divergences, 0);
        assert!(b.lane().weight_resident(WeightId(0xF00D)), "plan's weight cached");
        b.submit_now(OpDesc::linear(&w, &x)); // site 1 expects TimeEmbed
        assert_eq!(b.stats().plan_divergences, 1, "kind mismatch is a divergence");
    }

    #[test]
    fn sharded_backend_bit_identical_to_host_and_imax() {
        let w = rnd(13, 128, 15).quantize(DType::Q8_0).with_wid(WeightId(21));
        let x = rnd(3, 128, 16);
        let mut host = HostBackend::new(1);
        let want = host.submit_now(OpDesc::linear(&w, &x));
        for lanes in [1usize, 2, 4] {
            let mut b = ShardedBackend::from_config(ImaxConfig::fpga(lanes), 2);
            // The test asserts one shard per lane; disable the cost-model
            // threshold that would keep a 13-row op on a single lane.
            b.coordinator().set_min_shard_rows(1);
            let got = b.submit_now(OpDesc::linear(&w, &x));
            assert_eq!((got.rows, got.cols), (3, 13));
            for (p, q) in got.as_f32().iter().zip(want.as_f32()) {
                assert_eq!(p.to_bits(), q.to_bits(), "{lanes}-lane sharding stays bit-exact");
            }
            assert_eq!(b.stats().offloaded_calls, 1);
            assert_eq!(b.stats().lane_submissions, lanes as u64, "one shard per lane");
        }
    }

    #[test]
    fn sharded_backend_routes_f32_to_host() {
        let mut b = ShardedBackend::from_config(ImaxConfig::fpga(2), 2);
        let w = rnd(4, 32, 17);
        let x = rnd(2, 32, 18);
        let got = b.submit_now(OpDesc::attn_scores(&w, &x));
        let want = ggml::mul_mat(&w, &x, 1);
        for (p, q) in got.as_f32().iter().zip(want.as_f32()) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
        assert_eq!(b.stats().offloaded_calls, 0);
        let ord = std::sync::atomic::Ordering::Relaxed;
        assert_eq!(b.coordinator().metrics.host_jobs.load(ord), 1);
    }

    #[test]
    fn sharded_backend_warm_call_hits_per_lane_shards() {
        let w = rnd(16, 128, 19).quantize(DType::Q8_0).with_wid(WeightId(33));
        let x = rnd(2, 128, 20);
        let mut b = ShardedBackend::from_config(ImaxConfig::fpga(4), 2);
        b.coordinator().set_min_shard_rows(1); // force one shard per lane
        b.submit_now(OpDesc::linear(&w, &x));
        assert_eq!(b.stats().cache.misses, 4, "one cold miss per lane shard");
        b.submit_now(OpDesc::linear(&w, &x));
        assert_eq!(b.stats().cache.hits, 4, "every shard resident on its lane");
        let ord = std::sync::atomic::Ordering::Relaxed;
        assert_eq!(b.coordinator().metrics.sharded_ops.load(ord), 2);
        assert_eq!(b.coordinator().metrics.shard_submissions.load(ord), 8);
    }

    #[test]
    fn sharded_backend_plan_checks_wid_and_kind() {
        use crate::sd::plan::PlanRecorder;
        let w = rnd(8, 64, 23).quantize(DType::Q8_0).with_wid(WeightId(0xABC));
        let x = rnd(2, 64, 24);
        let mut rec = PlanRecorder::new();
        rec.submit_now(OpDesc::linear(&w, &x));
        rec.submit_now(OpDesc::time_embed(&w, &x));
        let plan = rec.finish();

        let mut b = ShardedBackend::from_config(ImaxConfig::fpga(2), 2);
        b.coordinator().set_min_shard_rows(1); // pin + exec one shard per lane
        b.apply_plan(&plan);
        b.submit_now(OpDesc::linear(&w, &x)); // matches site 0
        assert_eq!(b.stats().plan_divergences, 0);
        b.submit_now(OpDesc::linear(&w, &x)); // site 1 expects TimeEmbed
        assert_eq!(b.stats().plan_divergences, 1, "kind mismatch is a divergence");
        assert_eq!(b.stats().cache.hits, 2, "warm shards hit the pre-pinned ids");
    }

    #[test]
    fn sharded_backend_syncs_overlapped_submissions_in_any_order() {
        let wq = rnd(8, 128, 25).quantize(DType::Q8_0).with_wid(WeightId(51));
        let wk = rnd(8, 128, 26).quantize(DType::Q8_0).with_wid(WeightId(52));
        let x = rnd(2, 128, 27);
        let mut host = HostBackend::new(1);
        let want_q = host.submit_now(OpDesc::linear(&wq, &x));
        let want_k = host.submit_now(OpDesc::linear(&wk, &x));
        // threads=1 runs shards inline at submit; threads=2 enqueues
        // them on lane workers. Same results, same counters.
        for threads in [1usize, 2] {
            let mut b = ShardedBackend::from_config(ImaxConfig::fpga(2), threads);
            b.coordinator().set_min_shard_rows(1);
            let hq = b.submit(OpDesc::linear(&wq, &x));
            let hk = b.submit(OpDesc::linear(&wk, &x));
            let k = b.sync(hk); // reverse of submission order
            let q = b.sync(hq);
            for (p, want) in q.as_f32().iter().zip(want_q.as_f32()) {
                assert_eq!(p.to_bits(), want.to_bits());
            }
            for (p, want) in k.as_f32().iter().zip(want_k.as_f32()) {
                assert_eq!(p.to_bits(), want.to_bits());
            }
            assert_eq!(b.stats().offloaded_calls, 2);
            assert_eq!(b.stats().lane_submissions, 4, "two shards per op");
        }
    }

    #[test]
    fn imax_backend_offloads_f16_conv_under_conv_policy_only() {
        let w = rnd(6, 18, 40).quantize(DType::F16).with_wid(WeightId(0xC0));
        let x = rnd(8, 18, 41); // im2col patch matrix [oh·ow, cin·k·k]
        let mut host = HostBackend::new(1);
        let want = host.submit_now(OpDesc::conv_im2col(&w, &x, 3, 1));
        let mut b =
            ImaxBackend::with_policy(ImaxConfig::fpga(1), 1, OffloadPolicy::QuantizedAndConv);
        let got = b.submit_now(OpDesc::conv_im2col(&w, &x, 3, 1));
        assert_eq!(b.stats().offloaded_calls, 1, "conv routes to the lane");
        for (p, q) in got.as_f32().iter().zip(want.as_f32()) {
            assert_eq!(p.to_bits(), q.to_bits(), "lane conv == host conv bit-exact");
        }
        // Warm call: the resident conv weight skips its LOAD bytes.
        let cold_load = b.stats().imax_phases.load;
        b.submit_now(OpDesc::conv_im2col(&w, &x, 3, 1));
        assert!(b.stats().imax_phases.load - cold_load < cold_load, "warm conv loads less");
        assert_eq!(b.stats().cache.hits, 1);
        // F16 *linear* sites stay on the host even under the conv policy.
        b.submit_now(OpDesc::linear(&w, &x));
        assert_eq!(b.stats().offloaded_calls, 2, "F16 linear did not offload");
        // And the default (paper) policy keeps the conv on the host too.
        let mut off = ImaxBackend::new(ImaxConfig::fpga(1), 1);
        off.submit_now(OpDesc::conv_im2col(&w, &x, 3, 1));
        assert_eq!(off.stats().offloaded_calls, 0, "--conv-offload off baseline");
    }

    #[test]
    fn imax_backend_tiles_oversized_im2col_chunks_bit_exactly() {
        // k = 1152 (cin=128 · 3·3) with a 16 KiB LMM: one f32 patch row
        // is 4608 B, half the 12 KiB transient partition holds exactly
        // one row, so 3 patch rows must split into 3 lane submissions —
        // stitched bit-identically to the host reference.
        let mut imax = ImaxConfig::fpga(1);
        imax.lmm_bytes = 16 << 10;
        imax.weight_cache_bytes = 4 << 10;
        let w = rnd(4, 1152, 42).quantize(DType::F16).with_wid(WeightId(0xC2));
        let x = rnd(3, 1152, 43);
        let mut host = HostBackend::new(1);
        let want = host.submit_now(OpDesc::conv_im2col(&w, &x, 3, 1));
        let mut b = ImaxBackend::with_policy(imax, 1, OffloadPolicy::QuantizedAndConv);
        let got = b.submit_now(OpDesc::conv_im2col(&w, &x, 3, 1));
        assert_eq!(b.stats().offloaded_calls, 1);
        assert_eq!(b.stats().lane_submissions, 3, "one chunk per patch row");
        for (p, q) in got.as_f32().iter().zip(want.as_f32()) {
            assert_eq!(p.to_bits(), q.to_bits(), "tiled im2col stays bit-exact");
        }
    }

    #[test]
    fn sharded_backend_f16_conv_bit_identical_across_lane_counts() {
        let w = rnd(11, 36, 44).quantize(DType::F16).with_wid(WeightId(0xC3));
        let x = rnd(5, 36, 45);
        let mut host = HostBackend::new(1);
        let want = host.submit_now(OpDesc::conv_im2col(&w, &x, 3, 2));
        for lanes in [1usize, 2, 4] {
            let mut b = ShardedBackend::from_config_policy(
                ImaxConfig::fpga(lanes),
                2,
                OffloadPolicy::QuantizedAndConv,
            );
            b.coordinator().set_min_shard_rows(1);
            let got = b.submit_now(OpDesc::conv_im2col(&w, &x, 3, 2));
            assert_eq!(b.stats().offloaded_calls, 1);
            assert_eq!(b.stats().lane_submissions, lanes as u64, "one shard per lane");
            for (p, q) in got.as_f32().iter().zip(want.as_f32()) {
                assert_eq!(p.to_bits(), q.to_bits(), "{lanes}-lane F16 conv bit-exact");
            }
        }
        // QuantizedOnly (conv-offload off) keeps the same op on the host.
        let mut off = ShardedBackend::from_config(ImaxConfig::fpga(2), 2);
        off.submit_now(OpDesc::conv_im2col(&w, &x, 3, 2));
        assert_eq!(off.stats().offloaded_calls, 0);
    }

    #[test]
    fn host_only_policy_keeps_sharded_backend_on_host() {
        let coord = Arc::new(Coordinator::new(
            ImaxConfig::fpga(2),
            2,
            2,
            OffloadPolicy::HostOnly,
        ));
        let mut b = ShardedBackend::new(coord);
        let w = rnd(4, 64, 21).quantize(DType::Q8_0);
        let x = rnd(2, 64, 22);
        b.submit_now(OpDesc::linear(&w, &x));
        assert_eq!(b.stats().offloaded_calls, 0);
    }
}
