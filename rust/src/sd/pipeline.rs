//! End-to-end mini Stable-Diffusion pipeline (the runnable Fig. 5 driver).
//!
//! Text encode → U-Net denoise (1-step turbo or N-step DDIM) → VAE
//! decode → RGB image, with every mat-mul submitted as a typed
//! [`crate::sd::backend::OpDesc`] through an [`ExecBackend`] — host
//! kernels, one IMAX lane, or the sharded multi-lane coordinator. The
//! prompt seeds the latent through FNV hashing (so "a lovely cat" is
//! reproducible forever), and the full run returns a [`RunReport`] with
//! the mini analog of the paper's profiling (per-dtype times, offload
//! counts, IMAX phase breakdown).

use super::backend::{ExecBackend, HostBackend, ImaxBackend, RequestId, ShardedBackend};
use super::graph::Feat;
use super::plan::{OpPlan, PlanRecorder};
use super::sampler;
use super::text::TextEncoder;
use super::trace::QuantModel;
use super::unet::{UNet, LATENT_C, LATENT_HW};
use super::vae::VaeDecoder;
use super::weights::WeightFactory;
use crate::imax::lmm::CacheStats;
use crate::imax::timing::PhaseBreakdown;
use crate::imax::ImaxConfig;
use crate::util::cancel::{CancelCause, CancelToken};
use crate::util::rng::fnv1a64;
use std::sync::{Arc, OnceLock};

/// Where the quantized mat-muls execute.
#[derive(Debug, Clone)]
pub enum Backend {
    /// Everything on host GGML kernels with N threads.
    Host {
        /// Worker threads.
        threads: usize,
    },
    /// Quantized ops on one IMAX lane simulator (paper §III-B policy).
    Imax {
        /// Simulated instance.
        config: ImaxConfig,
        /// Host threads for the residual ops.
        threads: usize,
    },
    /// Quantized ops row-tile-sharded across `config.lanes` lanes
    /// behind a [`crate::coordinator::Coordinator`] — one op executes
    /// on several lanes at once, each lane caching only its resident
    /// shard.
    Sharded {
        /// Simulated instance (`config.lanes` selects the lane count).
        config: ImaxConfig,
        /// Host threads for marshalling and the residual ops.
        threads: usize,
    },
}

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Weight seed (the "checkpoint").
    pub weight_seed: u64,
    /// Quantized model type (`None` = F16 reference).
    pub model: Option<QuantModel>,
    /// Denoising steps (1 = SD-Turbo mode, the paper's setting).
    pub steps: usize,
    /// Execution backend.
    pub backend: Backend,
    /// Offload F16 `ConvIm2col` GEMMs to the lanes via the OP_SML16
    /// kernel (the §VI extension targeting Table I's dominant MAC
    /// population). `false` is the paper's §III-B quantized-only
    /// routing. Ignored by [`Backend::Host`]. Defaults to `true`, like
    /// the CLI's `--conv-offload on`.
    pub conv_offload: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            weight_seed: 0x5D_7B0,
            model: Some(QuantModel::Q8_0),
            steps: 1,
            backend: Backend::Host { threads: 2 },
            conv_offload: true,
        }
    }
}

impl PipelineConfig {
    /// The [`OffloadPolicy`](crate::coordinator::OffloadPolicy) this
    /// configuration routes with.
    pub fn policy(&self) -> crate::coordinator::OffloadPolicy {
        if self.conv_offload {
            crate::coordinator::OffloadPolicy::QuantizedAndConv
        } else {
            crate::coordinator::OffloadPolicy::QuantizedOnly
        }
    }
}

/// A generation aborted cooperatively before finishing (the serving
/// cancellation/deadline path; see [`Pipeline::generate_request`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Aborted {
    /// Why the run stopped.
    pub cause: CancelCause,
    /// Denoising steps that completed before the stop (0 when the abort
    /// landed before the first U-Net forward).
    pub steps_completed: usize,
}

/// Run metadata returned alongside the image.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Request this run served ([`RequestId::SOLO`] outside serving).
    pub request: RequestId,
    /// Wall-clock seconds.
    pub wall_seconds: f64,
    /// Wall-clock seconds per weight dtype (mini Table I analog).
    pub seconds_by_dtype: Vec<(&'static str, f64)>,
    /// MACs per weight dtype.
    pub macs_by_dtype: Vec<(&'static str, u64)>,
    /// Total op submissions.
    pub matmul_calls: u64,
    /// Ops offloaded to IMAX.
    pub offloaded_calls: u64,
    /// Lane submissions those ops decomposed into (shards).
    pub lane_submissions: u64,
    /// IMAX phase breakdown (zero for host runs).
    pub imax_phases: PhaseBreakdown,
    /// IMAX clock for converting phases to seconds (0 for host runs).
    pub imax_clock_hz: f64,
    /// Weight-residency cache counters (zero for host runs or when the
    /// cache is disabled).
    pub cache: CacheStats,
    /// Dispatches that disagreed with the compiled plan (should be 0).
    pub plan_divergences: u64,
}

/// The assembled pipeline.
pub struct Pipeline {
    /// Configuration it was built with.
    pub config: PipelineConfig,
    text: TextEncoder,
    unet: UNet,
    vae: VaeDecoder,
    /// Lazily compiled dispatch plan (see [`Pipeline::plan`]).
    plan: OnceLock<Arc<OpPlan>>,
}

impl Pipeline {
    /// Build all three models from the weight seed.
    pub fn new(config: PipelineConfig) -> Pipeline {
        let f = WeightFactory::new(config.weight_seed, config.model);
        // The VAE is never quantized (sd.cpp policy): force F16 factory.
        let f_vae = WeightFactory::new(config.weight_seed, None);
        Pipeline {
            text: TextEncoder::new(&f),
            unet: UNet::new(&f),
            vae: VaeDecoder::new(&f_vae),
            config,
            plan: OnceLock::new(),
        }
    }

    /// The compiled [`OpPlan`] of one full generation under this
    /// configuration: every op site with kind, shapes, dtypes and weight
    /// ids, in dispatch order. Compiled lazily by replaying the graph
    /// against a [`PlanRecorder`] (zero-tensor outputs, no GEMM work —
    /// the dispatch sequence is prompt-independent because shapes are
    /// fixed and the graph has no data-dependent control flow), then
    /// shared by every backend and coordinator that executes this
    /// pipeline.
    pub fn plan(&self) -> Arc<OpPlan> {
        self.plan
            .get_or_init(|| {
                let mut rec = PlanRecorder::new();
                let _ = self.generate_with_backend(&mut rec, RequestId::SOLO, "", 0);
                Arc::new(rec.finish())
            })
            .clone()
    }

    fn make_backend(&self) -> Box<dyn ExecBackend> {
        match &self.config.backend {
            Backend::Host { threads } => Box::new(HostBackend::new(*threads)),
            Backend::Imax { config, threads } => {
                let mut eng =
                    ImaxBackend::with_policy(config.clone(), *threads, self.config.policy());
                if config.weight_cache_bytes > 0 {
                    // Prefetch/pin pass: the hottest weights of the
                    // compiled plan become permanent residents.
                    eng.apply_plan(&self.plan());
                }
                Box::new(eng)
            }
            Backend::Sharded { config, threads } => {
                let mut eng =
                    ShardedBackend::from_config_policy(config.clone(), *threads, self.config.policy());
                if config.weight_cache_bytes > 0 {
                    // Sharded prefetch/pin pass: each hot weight's
                    // row-tile shards are pinned on their owning lanes.
                    eng.apply_plan(&self.plan());
                }
                Box::new(eng)
            }
        }
    }

    /// Generate an image for a prompt + seed. Returns the RGB image
    /// (3×128×128, values in `[0,1]`) and the run report.
    pub fn generate(&self, prompt: &str, seed: u64) -> (Feat, RunReport) {
        let mut eng = self.make_backend();
        self.generate_with_backend(eng.as_mut(), RequestId::SOLO, prompt, seed)
    }

    /// [`Pipeline::generate`] over a caller-supplied backend, tagged
    /// with a request id — the entry point the serving layer uses so
    /// many concurrent requests can share one pipeline (weights are
    /// read-only) while each runs on its own backend (a batching member
    /// in [`crate::serve`]).
    pub fn generate_with_backend(
        &self,
        eng: &mut dyn ExecBackend,
        request: RequestId,
        prompt: &str,
        seed: u64,
    ) -> (Feat, RunReport) {
        self.generate_request(eng, request, prompt, seed, self.config.steps, &CancelToken::new())
            .expect("a live token never aborts")
    }

    /// [`Pipeline::generate_with_backend`] with a caller-chosen step
    /// count and a cooperative [`CancelToken`], consulted **before** the
    /// text encode, before every denoising step, and before the VAE
    /// decode. A fired token stops the run at the next check — no
    /// further op is submitted — and returns [`Aborted`] with the cause
    /// and how many denoising steps had completed. This is the seam the
    /// serving layer's cancel route and per-request deadline act
    /// through.
    pub fn generate_request(
        &self,
        eng: &mut dyn ExecBackend,
        request: RequestId,
        prompt: &str,
        seed: u64,
        steps: usize,
        cancel: &CancelToken,
    ) -> Result<(Feat, RunReport), Aborted> {
        assert!(steps >= 1, "a generation needs at least one denoising step");
        let t0 = std::time::Instant::now();
        eng.begin_request(request);
        if let Err(cause) = cancel.check() {
            return Err(Aborted { cause, steps_completed: 0 });
        }
        let ctx = self.text.encode(eng, prompt);
        let z_seed = seed ^ fnv1a64(prompt.as_bytes());
        let z = sampler::initial_latent(z_seed, LATENT_C, LATENT_HW, LATENT_HW);
        let x0 = if steps == 1 {
            if let Err(cause) = cancel.check() {
                return Err(Aborted { cause, steps_completed: 0 });
            }
            sampler::turbo_step(eng, &self.unet, &z, &ctx)
        } else {
            sampler::ddim_cancellable(eng, &self.unet, &z, &ctx, steps, cancel)
                .map_err(|(cause, steps_completed)| Aborted { cause, steps_completed })?
        };
        if let Err(cause) = cancel.check() {
            return Err(Aborted { cause, steps_completed: steps });
        }
        let img = self.vae.decode(eng, &x0);
        let stats = eng.stats();
        let clock = match &self.config.backend {
            Backend::Imax { config, .. } | Backend::Sharded { config, .. } => config.clock_hz,
            _ => 0.0,
        };
        let report = RunReport {
            request,
            wall_seconds: t0.elapsed().as_secs_f64(),
            seconds_by_dtype: stats.seconds_by_dtype.iter().map(|(k, v)| (*k, *v)).collect(),
            macs_by_dtype: stats.macs_by_dtype.iter().map(|(k, v)| (*k, *v)).collect(),
            matmul_calls: stats.calls,
            offloaded_calls: stats.offloaded_calls,
            lane_submissions: stats.lane_submissions,
            imax_phases: stats.imax_phases,
            imax_clock_hz: clock,
            cache: stats.cache,
            plan_divergences: stats.plan_divergences,
        };
        Ok((img, report))
    }
}

/// Convert an RGB [`Feat`] to interleaved 8-bit pixels for PNG encoding.
pub fn to_rgb8(img: &Feat) -> Vec<u8> {
    assert_eq!(img.c, 3);
    let hw = img.hw();
    let mut out = vec![0u8; hw * 3];
    for p in 0..hw {
        for c in 0..3 {
            out[p * 3 + c] = (img.data[c * hw + p].clamp(0.0, 1.0) * 255.0 + 0.5) as u8;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // Paper §III-B routing (convs on host) — the historical baseline the
    // counter expectations below were written against; conv offload is
    // exercised by the dedicated tests further down.
    fn cfg(model: Option<QuantModel>, backend: Backend) -> PipelineConfig {
        PipelineConfig { weight_seed: 99, model, steps: 1, backend, conv_offload: false }
    }

    #[test]
    fn generate_reproducible_image() {
        let p = Pipeline::new(cfg(Some(QuantModel::Q8_0), Backend::Host { threads: 2 }));
        let (a, ra) = p.generate("a lovely cat", 7);
        let (b, _) = p.generate("a lovely cat", 7);
        assert_eq!(a.data, b.data, "same prompt+seed => same image");
        assert_eq!((a.c, a.h, a.w), (3, 128, 128));
        assert!(ra.matmul_calls > 30, "pipeline ran: {} calls", ra.matmul_calls);
        let (c, _) = p.generate("a lovely dog", 7);
        assert_ne!(a.data, c.data, "prompt changes the image");
    }

    #[test]
    fn imax_backend_q8_matches_host_bitexactly() {
        let host = Pipeline::new(cfg(Some(QuantModel::Q8_0), Backend::Host { threads: 2 }));
        let imax = Pipeline::new(cfg(
            Some(QuantModel::Q8_0),
            Backend::Imax { config: ImaxConfig::fpga(1), threads: 2 },
        ));
        let (a, _) = host.generate("a lovely cat", 7);
        let (b, rb) = imax.generate("a lovely cat", 7);
        assert!(rb.offloaded_calls > 0, "offload must happen");
        assert!(rb.imax_phases.total() > 0);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "Q8_0 offload is bit-exact");
        }
    }

    #[test]
    fn imax_backend_q3k_close_to_host() {
        // Q3_K offload uses the 5-bit-scale hardware path: close, not equal.
        let host = Pipeline::new(cfg(Some(QuantModel::Q3K), Backend::Host { threads: 2 }));
        let imax = Pipeline::new(cfg(
            Some(QuantModel::Q3K),
            Backend::Imax { config: ImaxConfig::fpga(1), threads: 2 },
        ));
        let (a, _) = host.generate("a lovely cat", 7);
        let (b, rb) = imax.generate("a lovely cat", 7);
        assert!(rb.offloaded_calls > 0);
        let dot: f32 = a.data.iter().zip(&b.data).map(|(x, y)| x * y).sum();
        let na = a.data.iter().map(|v| v * v).sum::<f32>().sqrt();
        let nb = b.data.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(dot / (na * nb) > 0.99, "cosine {}", dot / (na * nb));
    }

    #[test]
    fn sharded_backend_matches_single_lane_imax_bitexactly() {
        // The acceptance invariant at pipeline level: sharding one op
        // across lanes must not change a single output bit.
        let imax = Pipeline::new(cfg(
            Some(QuantModel::Q8_0),
            Backend::Imax { config: ImaxConfig::fpga(1), threads: 2 },
        ));
        let (a, _) = imax.generate("a lovely cat", 7);
        for lanes in [1usize, 4] {
            let sharded = Pipeline::new(cfg(
                Some(QuantModel::Q8_0),
                Backend::Sharded { config: ImaxConfig::fpga(lanes), threads: 2 },
            ));
            let (b, rb) = sharded.generate("a lovely cat", 7);
            assert!(rb.offloaded_calls > 0);
            if lanes > 1 {
                assert!(
                    rb.lane_submissions > rb.offloaded_calls,
                    "multi-lane sharding splits ops: {} submissions for {} ops",
                    rb.lane_submissions,
                    rb.offloaded_calls
                );
            }
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "{lanes}-lane sharded == 1-lane imax");
            }
        }
    }

    #[test]
    fn conv_offload_is_bit_identical_and_reaches_the_lane() {
        let host = Pipeline::new(cfg(Some(QuantModel::Q8_0), Backend::Host { threads: 2 }));
        let (a, _) = host.generate("a lovely cat", 7);
        let mk = |conv_offload: bool| {
            let mut c = cfg(
                Some(QuantModel::Q8_0),
                Backend::Imax { config: ImaxConfig::fpga(1), threads: 2 },
            );
            c.conv_offload = conv_offload;
            Pipeline::new(c)
        };
        let (_, base) = mk(false).generate("a lovely cat", 7);
        let (b, conv) = mk(true).generate("a lovely cat", 7);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "F16 conv offload is bit-exact");
        }
        assert!(
            conv.offloaded_calls > base.offloaded_calls,
            "conv sites joined the offload population: {} vs {}",
            conv.offloaded_calls,
            base.offloaded_calls
        );
        assert!(
            conv.imax_phases.total() > base.imax_phases.total(),
            "conv GEMMs now spend lane cycles"
        );
        assert_eq!(conv.plan_divergences, 0, "conv routing still follows the compiled plan");
    }

    #[test]
    fn compiled_plan_matches_real_dispatch_and_warms_cache() {
        let p = Pipeline::new(PipelineConfig {
            weight_seed: 99,
            model: Some(QuantModel::Q8_0),
            steps: 2,
            backend: Backend::Imax { config: ImaxConfig::fpga(1), threads: 2 },
            conv_offload: false,
        });
        let plan = p.plan();
        assert!(plan.offloaded_sites() > 0, "quantized sites compiled");
        let (_, r) = p.generate("a lovely cat", 7);
        assert_eq!(plan.sites.len() as u64, r.matmul_calls, "plan covers every dispatch");
        assert_eq!(r.plan_divergences, 0, "dispatch followed the compiled plan");
        assert!(r.cache.hits > 0, "step 2 re-hits step 1's pinned residents");
        assert!(r.cache.hit_bytes > 0);
    }

    #[test]
    fn f16_dominates_mini_profile_too() {
        // Even at mini scale the dtype mix echoes Table I: F16 (convs +
        // VAE) carries the most MACs.
        let p = Pipeline::new(cfg(Some(QuantModel::Q8_0), Backend::Host { threads: 2 }));
        let (_, r) = p.generate("profile me", 1);
        let f16 = r.macs_by_dtype.iter().find(|(k, _)| *k == "F16").map(|(_, v)| *v).unwrap();
        let total: u64 = r.macs_by_dtype.iter().map(|(_, v)| *v).sum();
        assert!(f16 * 2 > total, "F16 {} of {}", f16, total);
    }

    #[test]
    fn generate_request_honors_cancel_and_deadline() {
        let p = Pipeline::new(cfg(Some(QuantModel::Q8_0), Backend::Host { threads: 2 }));
        let mut eng = HostBackend::new(2);
        let t = CancelToken::new();
        t.cancel();
        let got = p.generate_request(&mut eng, RequestId(1), "a lovely cat", 7, 4, &t);
        assert_eq!(got.unwrap_err(), Aborted { cause: CancelCause::Cancelled, steps_completed: 0 });
        assert_eq!(eng.stats().calls, 0, "abort before the text encode submits nothing");
        let d = CancelToken::with_deadline(std::time::Instant::now());
        let got = p.generate_request(&mut eng, RequestId(2), "a lovely cat", 7, 1, &d);
        assert_eq!(got.unwrap_err().cause, CancelCause::DeadlineExpired);
    }

    #[test]
    fn generate_request_with_live_token_matches_generate() {
        let p = Pipeline::new(cfg(Some(QuantModel::Q8_0), Backend::Host { threads: 2 }));
        let (a, _) = p.generate("a lovely cat", 7);
        let mut eng = HostBackend::new(2);
        let (b, rb) = p
            .generate_request(&mut eng, RequestId(3), "a lovely cat", 7, 1, &CancelToken::new())
            .expect("live token");
        assert_eq!(a.data, b.data, "the cancellable path is the same computation");
        assert_eq!(rb.request, RequestId(3));
    }

    #[test]
    fn to_rgb8_layout() {
        let mut img = Feat::zeros(3, 2, 2);
        img.data[0] = 1.0; // R of pixel 0
        img.data[4] = 0.5; // G of pixel 0 (channel 1, first pixel)
        let px = to_rgb8(&img);
        assert_eq!(px.len(), 12);
        assert_eq!(px[0], 255);
        assert_eq!(px[1], 128);
    }
}
