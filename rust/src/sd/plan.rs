//! Compiled execution plan: the explicit list of mat-mul sites a
//! pipeline run dispatches, with shapes, dtypes and weight identities.
//!
//! The mini pipeline (like `stable-diffusion.cpp`) historically
//! dispatched mat-muls implicitly, in whatever order the graph code
//! calls [`MatMulEngine::mul_mat`]. That order is *static* — shapes are
//! fixed by the architecture and there is no data-dependent control flow
//! — so it can be compiled once into an [`OpPlan`] by replaying the
//! graph against a [`PlanRecorder`] engine that records every site and
//! returns zero tensors instead of multiplying (compilation costs
//! host-op time only, no GEMM work).
//!
//! The plan buys three things:
//!
//! * a **prefetch/pin pass** ([`OpPlan::pin_set`]): rank weights by the
//!   DMA bytes they would stream per step (`bytes × uses`) and pin the
//!   hottest set that fits the LMM cache budget, so the residency cache
//!   in [`crate::imax::lmm`] keeps exactly the tiles that save the most
//!   LOAD time — immune to the LRU-defeating cyclic access pattern a
//!   denoising loop otherwise produces;
//! * **residency-aware lane sharding**
//!   ([`crate::coordinator::Coordinator::apply_plan`]): weights are
//!   distributed over lanes hottest-first so each lane's cache serves a
//!   disjoint slice of the model;
//! * a **dispatch check**: engines executing a plan verify the observed
//!   call sequence against the compiled one (divergences are counted,
//!   see [`crate::sd::graph::EngineStats::plan_divergences`]).

use crate::ggml::{DType, Tensor, WeightId};
use crate::sd::graph::{EngineStats, MatMulEngine};

/// One compiled mat-mul site.
#[derive(Debug, Clone)]
pub struct OpSite {
    /// Position in the dispatch order.
    pub seq: usize,
    /// Weight identity (`None` for activation×activation mat-muls).
    pub wid: Option<WeightId>,
    /// Weight storage dtype.
    pub dtype: DType,
    /// Weight rows (output features).
    pub m: usize,
    /// Contraction length.
    pub k: usize,
    /// Activation rows at record time (serving batches may widen this;
    /// everything else about the site is invariant).
    pub n: usize,
    /// Serialized weight bytes (the LOAD volume one streaming pass costs).
    pub weight_bytes: usize,
}

impl OpSite {
    /// Whether the offload policy routes this site to a lane kernel.
    pub fn offload_eligible(&self) -> bool {
        matches!(self.dtype, DType::Q8_0 | DType::Q3K)
    }
}

/// Aggregate use of one weight across a plan.
#[derive(Debug, Clone)]
pub struct WeightUse {
    /// The weight.
    pub wid: WeightId,
    /// Its storage dtype.
    pub dtype: DType,
    /// Serialized bytes (cache footprint).
    pub bytes: usize,
    /// Times the plan dispatches it.
    pub uses: u64,
    /// Bytes it would stream without residency (`bytes × uses`) — the
    /// hotness key for the pin pass.
    pub streamed_bytes: u64,
}

/// The compiled plan for one pipeline configuration.
#[derive(Debug, Clone, Default)]
pub struct OpPlan {
    /// Mat-mul sites in dispatch order.
    pub sites: Vec<OpSite>,
}

impl OpPlan {
    /// Offload-eligible weights aggregated across sites, hottest first
    /// (ties broken by id so the order is deterministic).
    pub fn weight_uses(&self) -> Vec<WeightUse> {
        let mut order: Vec<WeightUse> = Vec::new();
        let mut index: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
        for site in &self.sites {
            let wid = match site.wid {
                Some(w) if site.offload_eligible() => w,
                _ => continue,
            };
            match index.entry(wid.0) {
                std::collections::hash_map::Entry::Occupied(e) => {
                    let wu = &mut order[*e.get()];
                    wu.uses += 1;
                    wu.streamed_bytes += site.weight_bytes as u64;
                }
                std::collections::hash_map::Entry::Vacant(v) => {
                    v.insert(order.len());
                    order.push(WeightUse {
                        wid,
                        dtype: site.dtype,
                        bytes: site.weight_bytes,
                        uses: 1,
                        streamed_bytes: site.weight_bytes as u64,
                    });
                }
            }
        }
        order.sort_by(|a, b| {
            b.streamed_bytes.cmp(&a.streamed_bytes).then(a.wid.cmp(&b.wid))
        });
        order
    }

    /// The prefetch/pin pass: greedily take the hottest weights whose
    /// cumulative bytes fit `budget`. Pinning these guarantees warm
    /// steps hit on them even when the full weight set exceeds the LMM
    /// (where plain LRU over a cyclic replay would hit on nothing).
    pub fn pin_set(&self, budget: usize) -> Vec<WeightId> {
        let mut remaining = budget;
        let mut out = Vec::new();
        for wu in self.weight_uses() {
            if wu.bytes <= remaining {
                remaining -= wu.bytes;
                out.push(wu.wid);
            }
        }
        out
    }

    /// Total bytes a full streaming (cache-less) execution would LOAD
    /// for offload-eligible weights.
    pub fn streamed_weight_bytes(&self) -> u64 {
        self.weight_uses().iter().map(|w| w.streamed_bytes).sum()
    }

    /// Offload-eligible sites in the plan.
    pub fn offloaded_sites(&self) -> usize {
        self.sites.iter().filter(|s| s.offload_eligible()).count()
    }
}

/// Recording engine: captures every [`MatMulEngine::mul_mat`] site and
/// returns a zero tensor of the correct shape without multiplying. The
/// graph has no data-dependent control flow, so the recorded sequence is
/// exactly the sequence any real engine will dispatch.
#[derive(Default)]
pub struct PlanRecorder {
    sites: Vec<OpSite>,
    stats: EngineStats,
}

impl PlanRecorder {
    /// Fresh recorder.
    pub fn new() -> PlanRecorder {
        PlanRecorder::default()
    }

    /// Finish recording.
    pub fn finish(self) -> OpPlan {
        OpPlan { sites: self.sites }
    }
}

impl MatMulEngine for PlanRecorder {
    fn mul_mat(&mut self, w: &Tensor, x: &Tensor) -> Tensor {
        self.sites.push(OpSite {
            seq: self.sites.len(),
            wid: w.wid,
            dtype: w.dtype(),
            m: w.rows,
            k: w.cols,
            n: x.rows,
            weight_bytes: w.byte_size(),
        });
        Tensor::zeros(x.rows, w.rows)
    }

    fn stats(&self) -> &EngineStats {
        &self.stats
    }
}

/// Per-step cost of one replayed denoising step (all values are
/// simulator-deterministic, independent of the host machine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepCost {
    /// Simulated lane cycles the step spent.
    pub cycles: u64,
    /// DMA LOAD bytes the step moved into the LMM.
    pub load_bytes: u64,
    /// Residency-cache hits during the step.
    pub hits: u64,
    /// Weight bytes whose LOAD residency skipped.
    pub hit_bytes: u64,
}

/// Replay `steps` identical mini U-Net denoising steps on one simulated
/// lane (`lmm_bytes` of LMM, `cache_bytes` of it reserved as weight
/// cache, plan-pinned when non-zero) and return per-step cost deltas —
/// step 1 is the cold step, steps ≥ 2 are warm.
///
/// This is the **single definition of the cold-vs-warm experiment**,
/// shared by `benches/weight_reuse.rs` and the acceptance tests in
/// `tests/weight_cache.rs`, so the CI bench and the assertions always
/// measure the same thing.
pub fn replay_unet_steps(
    model: crate::sd::trace::QuantModel,
    lmm_bytes: usize,
    cache_bytes: usize,
    steps: usize,
) -> Vec<StepCost> {
    // `MatMulEngine` (for `eng.stats()`) is already in scope from the
    // module-level import.
    use crate::imax::ImaxConfig;
    use crate::sd::graph::{Feat, ImaxEngine};
    use crate::sd::text::{CTX_LEN, DIM};
    use crate::sd::unet::{UNet, LATENT_C, LATENT_HW};
    use crate::sd::weights::WeightFactory;
    use crate::util::rng::Xoshiro256pp;

    let f = WeightFactory::new(1, Some(model));
    let unet = UNet::new(&f);
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let mut latent_data = vec![0.0f32; LATENT_C * LATENT_HW * LATENT_HW];
    rng.fill_normal(&mut latent_data, 1.0);
    let latent = Feat::new(LATENT_C, LATENT_HW, LATENT_HW, latent_data);
    let mut ctx_data = vec![0.0f32; CTX_LEN * DIM];
    rng.fill_normal(&mut ctx_data, 0.3);
    let ctx = Tensor::f32(CTX_LEN, DIM, ctx_data);

    let mut rec = PlanRecorder::new();
    unet.forward(&mut rec, &latent, 999.0, &ctx);
    let plan = rec.finish();

    let mut imax = ImaxConfig::fpga(1);
    imax.lmm_bytes = lmm_bytes;
    imax.weight_cache_bytes = cache_bytes;
    let mut eng = ImaxEngine::new(imax, 1);
    if cache_bytes > 0 {
        eng.apply_plan(&plan);
    }

    (0..steps)
        .map(|_| {
            let c0 = eng.stats().imax_phases.total();
            let l0 = eng.lane().lmm.loaded_bytes;
            let s0 = eng.lane().cache_stats();
            unet.forward(&mut eng, &latent, 999.0, &ctx);
            let s1 = eng.lane().cache_stats();
            StepCost {
                cycles: eng.stats().imax_phases.total() - c0,
                load_bytes: eng.lane().lmm.loaded_bytes - l0,
                hits: s1.hits - s0.hits,
                hit_bytes: s1.hit_bytes - s0.hit_bytes,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ggml::DType;

    fn site(seq: usize, wid: Option<u64>, dtype: DType, bytes: usize) -> OpSite {
        OpSite {
            seq,
            wid: wid.map(WeightId),
            dtype,
            m: 4,
            k: 256,
            n: 2,
            weight_bytes: bytes,
        }
    }

    #[test]
    fn weight_uses_aggregates_and_ranks_by_streamed_bytes() {
        let plan = OpPlan {
            sites: vec![
                site(0, Some(1), DType::Q8_0, 100),
                site(1, Some(2), DType::Q8_0, 300),
                site(2, Some(1), DType::Q8_0, 100),
                site(3, Some(1), DType::Q8_0, 100),
                site(4, None, DType::F32, 0),
                site(5, Some(3), DType::F16, 999), // not offload-eligible
            ],
        };
        let uses = plan.weight_uses();
        assert_eq!(uses.len(), 2, "F32/F16 sites excluded");
        assert_eq!(uses[0].wid, WeightId(2), "300 streamed beats 3x100");
        assert_eq!(uses[1].uses, 3);
        assert_eq!(uses[1].streamed_bytes, 300);
        assert_eq!(plan.streamed_weight_bytes(), 600);
        assert_eq!(plan.offloaded_sites(), 4);
    }

    #[test]
    fn pin_set_greedy_within_budget() {
        let plan = OpPlan {
            sites: vec![
                site(0, Some(1), DType::Q3K, 200),
                site(1, Some(2), DType::Q3K, 150),
                site(2, Some(2), DType::Q3K, 150),
                site(3, Some(3), DType::Q3K, 60),
            ],
        };
        // Hotness: wid2 (300 streamed), wid1 (200), wid3 (60).
        assert_eq!(plan.pin_set(1000), vec![WeightId(2), WeightId(1), WeightId(3)]);
        // 200 B budget: wid2 fits (150), wid1 (200) does not, wid3 (60)
        // would exceed the 50 left — greedy still skips to nothing.
        assert_eq!(plan.pin_set(200), vec![WeightId(2)]);
        assert!(plan.pin_set(0).is_empty());
    }

    #[test]
    fn recorder_captures_sites_shapes_and_returns_zeros() {
        let w = Tensor::f32(4, 32, vec![0.5; 128])
            .quantize(DType::Q8_0)
            .with_wid(WeightId(11));
        let x = Tensor::f32(3, 32, vec![0.25; 96]);
        let mut rec = PlanRecorder::new();
        let out = rec.mul_mat(&w, &x);
        assert_eq!((out.rows, out.cols), (3, 4));
        assert!(out.as_f32().iter().all(|&v| v == 0.0));
        let plan = rec.finish();
        assert_eq!(plan.sites.len(), 1);
        let s = &plan.sites[0];
        assert_eq!((s.m, s.k, s.n), (4, 32, 3));
        assert_eq!(s.wid, Some(WeightId(11)));
        assert_eq!(s.dtype, DType::Q8_0);
        assert!(s.offload_eligible());
        assert_eq!(s.weight_bytes, 4 * 34);
    }
}
