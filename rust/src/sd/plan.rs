//! Compiled execution plan: the explicit list of typed op sites a
//! pipeline run dispatches, with kinds, shapes, dtypes and weight
//! identities.
//!
//! The mini pipeline (like `stable-diffusion.cpp`) historically
//! dispatched mat-muls implicitly, in whatever order the graph code
//! submitted them. That order is *static* — shapes are fixed by the
//! architecture and there is no data-dependent control flow — so it can
//! be compiled once into an [`OpPlan`] by replaying the graph against a
//! [`PlanRecorder`] backend that records every [`OpDesc`] and returns
//! zero tensors instead of multiplying (compilation costs host-op time
//! only, no GEMM work).
//!
//! The plan buys four things:
//!
//! * a **prefetch/pin pass** ([`OpPlan::pin_set`]): rank weights by the
//!   DMA bytes they would stream per step (`bytes × uses`) and pin the
//!   hottest set that fits the LMM cache budget, so the residency cache
//!   in [`crate::imax::lmm`] keeps exactly the tiles that save the most
//!   LOAD time — immune to the LRU-defeating cyclic access pattern a
//!   denoising loop otherwise produces;
//! * **residency-aware lane sharding**: whole weights
//!   ([`crate::coordinator::Coordinator::apply_plan`]) or row-tile
//!   shards ([`crate::coordinator::Coordinator::apply_plan_sharded`])
//!   are distributed over lanes hottest-first so each lane's cache
//!   serves a disjoint slice of the model;
//! * **plan-driven per-lane CONF grouping**
//!   ([`OpPlan::lane_assignment`]): because sites carry their
//!   [`OpKind`] and dtype, weights can be dealt to lanes kind-major —
//!   each lane sees one kernel kind where lane count allows, so
//!   consecutive submissions skip the CONF phase;
//! * a **dispatch check**: backends executing a plan verify the observed
//!   `(wid, kind)` sequence against the compiled one (divergences are
//!   counted, see [`crate::sd::backend::EngineStats::plan_divergences`]).

use crate::ggml::{DType, Tensor, WeightId};
use crate::sd::backend::{Completions, EngineStats, ExecBackend, OpDesc, OpHandle, OpKind};

/// One compiled op site.
#[derive(Debug, Clone)]
pub struct OpSite {
    /// Position in the dispatch order.
    pub seq: usize,
    /// What the op is in the graph.
    pub kind: OpKind,
    /// Weight identity (`None` for activation×activation mat-muls).
    pub wid: Option<WeightId>,
    /// Weight storage dtype.
    pub dtype: DType,
    /// Weight rows (output features).
    pub m: usize,
    /// Contraction length.
    pub k: usize,
    /// Activation rows at record time (serving batches may widen this;
    /// everything else about the site is invariant).
    pub n: usize,
    /// Serialized weight bytes (the LOAD volume one streaming pass costs).
    pub weight_bytes: usize,
}

impl OpSite {
    /// Whether *some* offload policy routes this site to a lane kernel
    /// (the maximal lane-eligibility): quantized weights under any kind,
    /// F16 weights only at `ConvIm2col` sites (the OP_SML16 kernel).
    /// F16 linear-fallback and all F32 sites never qualify. Policy-
    /// specific passes additionally filter with
    /// [`crate::coordinator::OffloadPolicy::offloads_use`].
    pub fn offload_eligible(&self) -> bool {
        matches!(self.dtype, DType::Q8_0 | DType::Q3K)
            || (self.dtype == DType::F16 && matches!(self.kind, OpKind::ConvIm2col { .. }))
    }
}

/// Aggregate use of one weight across a plan.
#[derive(Debug, Clone)]
pub struct WeightUse {
    /// The weight.
    pub wid: WeightId,
    /// Its storage dtype.
    pub dtype: DType,
    /// Weight rows (the row-tile shard axis).
    pub rows: usize,
    /// Contraction length (per-row byte geometry).
    pub k: usize,
    /// Activation rows at the first recorded site — the `n` the shard
    /// threshold (see
    /// [`crate::coordinator::Coordinator::min_shard_rows`]) amortizes
    /// per-shard fixed cost against. Serving may widen `n` at dispatch
    /// time; the pin pass using the recorded value is conservative
    /// (a wider `n` only makes sharding *more* worthwhile, and pins are
    /// an optimization, not a correctness input).
    pub n: usize,
    /// Serialized bytes (cache footprint).
    pub bytes: usize,
    /// Times the plan dispatches it.
    pub uses: u64,
    /// Bytes it would stream without residency (`bytes × uses`) — the
    /// hotness key for the pin pass.
    pub streamed_bytes: u64,
}

/// The compiled plan for one pipeline configuration.
#[derive(Debug, Clone, Default)]
pub struct OpPlan {
    /// Op sites in dispatch order.
    pub sites: Vec<OpSite>,
}

impl OpPlan {
    /// Offload-eligible weights aggregated across sites, hottest first
    /// (ties broken by id so the order is deterministic).
    pub fn weight_uses(&self) -> Vec<WeightUse> {
        let mut order: Vec<WeightUse> = Vec::new();
        let mut index: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
        for site in &self.sites {
            let wid = match site.wid {
                Some(w) if site.offload_eligible() => w,
                _ => continue,
            };
            match index.entry(wid.0) {
                std::collections::hash_map::Entry::Occupied(e) => {
                    let wu = &mut order[*e.get()];
                    wu.uses += 1;
                    wu.streamed_bytes += site.weight_bytes as u64;
                }
                std::collections::hash_map::Entry::Vacant(v) => {
                    v.insert(order.len());
                    order.push(WeightUse {
                        wid,
                        dtype: site.dtype,
                        rows: site.m,
                        k: site.k,
                        n: site.n,
                        bytes: site.weight_bytes,
                        uses: 1,
                        streamed_bytes: site.weight_bytes as u64,
                    });
                }
            }
        }
        order.sort_by(|a, b| {
            b.streamed_bytes.cmp(&a.streamed_bytes).then(a.wid.cmp(&b.wid))
        });
        order
    }

    /// The prefetch/pin pass: greedily take the hottest weights whose
    /// cumulative bytes fit `budget`. Pinning these guarantees warm
    /// steps hit on them even when the full weight set exceeds the LMM
    /// (where plain LRU over a cyclic replay would hit on nothing).
    pub fn pin_set(&self, budget: usize) -> Vec<WeightId> {
        self.pin_set_for(budget, crate::coordinator::OffloadPolicy::QuantizedAndConv)
    }

    /// [`OpPlan::pin_set`] filtered to the weights `policy` actually
    /// routes to a lane — a quantized-only backend must not burn cache
    /// budget pinning F16 conv weights it will run on the host.
    pub fn pin_set_for(
        &self,
        budget: usize,
        policy: crate::coordinator::OffloadPolicy,
    ) -> Vec<WeightId> {
        let mut remaining = budget;
        let mut out = Vec::new();
        for wu in self.weight_uses() {
            if !policy.offloads_use(wu.dtype) {
                continue;
            }
            if wu.bytes <= remaining {
                remaining -= wu.bytes;
                out.push(wu.wid);
            }
        }
        out
    }

    /// Plan-driven per-lane CONF grouping: distribute the
    /// offload-eligible weights over `lanes` so that each lane serves —
    /// as far as lane count allows — a **single kernel kind**, which
    /// means consecutive submissions on that lane skip the CONF phase.
    ///
    /// Kinds (weight dtypes, which select the lane kernel) get disjoint
    /// contiguous lane ranges sized proportionally to their streamed
    /// bytes (at least one lane each); within a range, weights deal
    /// round-robin hottest-first. With one kind, or more kinds than
    /// lanes, the grouping degenerates to plain hottest-first
    /// round-robin (the pre-plan behavior).
    pub fn lane_assignment(&self, lanes: usize) -> Vec<(WeightUse, usize)> {
        assert!(lanes > 0, "lane_assignment wants at least one lane");
        let uses = self.weight_uses();
        let kinds: std::collections::HashSet<&'static str> =
            uses.iter().map(|wu| wu.dtype.name()).collect();
        if kinds.len() <= 1 || kinds.len() > lanes {
            // Degenerate: hottest-first round-robin over all lanes.
            return uses.into_iter().enumerate().map(|(i, wu)| (wu, i % lanes)).collect();
        }
        // Group by dtype, preserving hottest-first order within groups.
        let mut groups: Vec<(DType, Vec<WeightUse>, u64)> = Vec::new();
        for wu in uses {
            match groups.iter_mut().find(|(d, _, _)| *d == wu.dtype) {
                Some((_, v, s)) => {
                    *s += wu.streamed_bytes;
                    v.push(wu);
                }
                None => {
                    let s = wu.streamed_bytes;
                    groups.push((wu.dtype, vec![wu], s));
                }
            }
        }
        // Proportional lane allocation, one lane minimum per kind,
        // largest-remainder rounding (deterministic).
        groups.sort_by(|a, b| b.2.cmp(&a.2));
        let total: u64 = groups.iter().map(|(_, _, s)| *s).sum::<u64>().max(1);
        let extra = (lanes - groups.len()) as u64;
        let mut alloc: Vec<u64> = groups.iter().map(|(_, _, s)| 1 + extra * s / total).collect();
        let mut leftover = lanes as u64 - alloc.iter().sum::<u64>();
        let mut by_rem: Vec<usize> = (0..groups.len()).collect();
        by_rem.sort_by_key(|&g| std::cmp::Reverse(extra * groups[g].2 % total));
        for &g in by_rem.iter().cycle() {
            if leftover == 0 {
                break;
            }
            alloc[g] += 1;
            leftover -= 1;
        }
        let mut out = Vec::new();
        let mut offset = 0usize;
        for ((_, wus, _), width) in groups.into_iter().zip(alloc) {
            let width = width as usize;
            for (j, wu) in wus.into_iter().enumerate() {
                out.push((wu, offset + j % width));
            }
            offset += width;
        }
        out
    }

    /// Total bytes a full streaming (cache-less) execution would LOAD
    /// for offload-eligible weights.
    pub fn streamed_weight_bytes(&self) -> u64 {
        self.weight_uses().iter().map(|w| w.streamed_bytes).sum()
    }

    /// Offload-eligible sites in the plan.
    pub fn offloaded_sites(&self) -> usize {
        self.sites.iter().filter(|s| s.offload_eligible()).count()
    }

    /// MACs of the F16 `ConvIm2col` sites — the work the §VI conv
    /// offload moves from the host to the OP_SML16 kernel. Pricing this
    /// at a host F16 GMAC rate gives the host-conv side of the
    /// offload-vs-host comparison in `tests/weight_cache.rs` and
    /// `EXPERIMENTS.md`.
    pub fn conv_f16_macs(&self) -> u64 {
        self.sites
            .iter()
            .filter(|s| s.dtype == DType::F16 && matches!(s.kind, OpKind::ConvIm2col { .. }))
            .map(|s| (s.m * s.n * s.k) as u64)
            .sum()
    }
}

/// Recording backend: captures every submitted [`OpDesc`] site and
/// returns a zero tensor of the correct shape without multiplying. The
/// graph has no data-dependent control flow, so the recorded sequence is
/// exactly the sequence any real backend will dispatch.
#[derive(Default)]
pub struct PlanRecorder {
    sites: Vec<OpSite>,
    stats: EngineStats,
    done: Completions,
}

impl PlanRecorder {
    /// Fresh recorder.
    pub fn new() -> PlanRecorder {
        PlanRecorder::default()
    }

    /// Finish recording.
    pub fn finish(self) -> OpPlan {
        OpPlan { sites: self.sites }
    }
}

impl ExecBackend for PlanRecorder {
    fn submit(&mut self, op: OpDesc<'_>) -> OpHandle {
        self.sites.push(OpSite {
            seq: self.sites.len(),
            kind: op.kind,
            wid: op.wid,
            dtype: op.w.dtype(),
            m: op.w.rows,
            k: op.w.cols,
            n: op.x.rows,
            weight_bytes: op.w.byte_size(),
        });
        self.done.complete(Tensor::zeros(op.x.rows, op.w.rows))
    }

    fn sync(&mut self, h: OpHandle) -> Tensor {
        self.done.take(h)
    }

    fn stats(&self) -> &EngineStats {
        &self.stats
    }
}

/// Per-step cost of one replayed denoising step (all values are
/// simulator-deterministic, independent of the host machine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepCost {
    /// Simulated lane cycles the step spent.
    pub cycles: u64,
    /// DMA LOAD bytes the step moved into the LMM.
    pub load_bytes: u64,
    /// Residency-cache hits during the step.
    pub hits: u64,
    /// Weight bytes whose LOAD residency skipped.
    pub hit_bytes: u64,
}

/// Build the deterministic mini U-Net replay fixture: the net itself,
/// one latent and one context tensor, plus the compiled plan.
fn unet_fixture(
    model: crate::sd::trace::QuantModel,
) -> (crate::sd::unet::UNet, super::graph::Feat, Tensor, OpPlan) {
    use crate::sd::graph::Feat;
    use crate::sd::text::{CTX_LEN, DIM};
    use crate::sd::unet::{UNet, LATENT_C, LATENT_HW};
    use crate::sd::weights::WeightFactory;
    use crate::util::rng::Xoshiro256pp;

    let f = WeightFactory::new(1, Some(model));
    let unet = UNet::new(&f);
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let mut latent_data = vec![0.0f32; LATENT_C * LATENT_HW * LATENT_HW];
    rng.fill_normal(&mut latent_data, 1.0);
    let latent = Feat::new(LATENT_C, LATENT_HW, LATENT_HW, latent_data);
    let mut ctx_data = vec![0.0f32; CTX_LEN * DIM];
    rng.fill_normal(&mut ctx_data, 0.3);
    let ctx = Tensor::f32(CTX_LEN, DIM, ctx_data);

    let mut rec = PlanRecorder::new();
    unet.forward(&mut rec, &latent, 999.0, &ctx);
    let plan = rec.finish();
    (unet, latent, ctx, plan)
}

/// Replay `steps` identical mini U-Net denoising steps on one simulated
/// lane (`lmm_bytes` of LMM, `cache_bytes` of it reserved as weight
/// cache, plan-pinned when non-zero) and return per-step cost deltas —
/// step 1 is the cold step, steps ≥ 2 are warm.
///
/// This is the **single definition of the cold-vs-warm experiment**,
/// shared by `benches/weight_reuse.rs` and the acceptance tests in
/// `tests/weight_cache.rs`, so the CI bench and the assertions always
/// measure the same thing.
pub fn replay_unet_steps(
    model: crate::sd::trace::QuantModel,
    lmm_bytes: usize,
    cache_bytes: usize,
    steps: usize,
) -> Vec<StepCost> {
    let mut imax = crate::imax::ImaxConfig::fpga(1);
    imax.lmm_bytes = lmm_bytes;
    imax.weight_cache_bytes = cache_bytes;
    replay_unet_steps_policy(model, imax, steps, crate::coordinator::OffloadPolicy::QuantizedOnly)
}

/// [`replay_unet_steps`] over an explicit [`crate::imax::ImaxConfig`]
/// and routing policy — the **conv-offload experiment**:
/// `QuantizedAndConv` routes the mini U-Net's F16 `ConvIm2col` GEMMs
/// (its dominant MAC population, [`OpPlan::conv_f16_macs`]) through the
/// OP_SML16 kernel with weight residency, `QuantizedOnly` replays the
/// paper's host-conv routing. Shared by `tests/weight_cache.rs`, the
/// `conv_offload` bench and `python/replica/conv_offload_replica.py` so
/// the recorded deltas all measure one definition. Taking the full
/// config (not just LMM/cache bytes) lets callers price the §VI
/// production-interconnect scenario (`dma_bytes_per_cycle` override) —
/// on the prototype DMA the F16 offload regresses, the Fig. 11 lesson.
pub fn replay_unet_steps_policy(
    model: crate::sd::trace::QuantModel,
    imax: crate::imax::ImaxConfig,
    steps: usize,
    policy: crate::coordinator::OffloadPolicy,
) -> Vec<StepCost> {
    use crate::sd::graph::ImaxBackend;

    let (unet, latent, ctx, plan) = unet_fixture(model);
    let cache_bytes = imax.weight_cache_bytes;
    let mut eng = ImaxBackend::with_policy(imax, 1, policy);

    (0..steps)
        .map(|_| {
            if cache_bytes > 0 {
                // Re-arm per step: the plan records exactly one forward,
                // and each replayed step dispatches that sequence once —
                // so the divergence diagnostic stays exact (re-pinning
                // is idempotent).
                eng.apply_plan(&plan);
            }
            let c0 = eng.stats().imax_phases.total();
            let l0 = eng.lane().lmm.loaded_bytes;
            let s0 = eng.lane().cache_stats();
            unet.forward(&mut eng, &latent, 999.0, &ctx);
            let s1 = eng.lane().cache_stats();
            StepCost {
                cycles: eng.stats().imax_phases.total() - c0,
                load_bytes: eng.lane().lmm.loaded_bytes - l0,
                hits: s1.hits - s0.hits,
                hit_bytes: s1.hit_bytes - s0.hit_bytes,
            }
        })
        .collect()
}

/// F16 conv MACs of one mini U-Net step (the replay fixture's plan) —
/// the host-side work the conv offload eliminates. See
/// [`OpPlan::conv_f16_macs`].
pub fn unet_step_conv_macs(model: crate::sd::trace::QuantModel) -> u64 {
    unet_fixture(model).3.conv_f16_macs()
}

/// Per-step cost of one sharded mini U-Net replay across `L` lanes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardStepCost {
    /// Cycles of the slowest lane (the parallel wall-clock of the step).
    pub max_lane_cycles: u64,
    /// Cycles summed over all lanes (total lane-seconds of work).
    pub total_cycles: u64,
    /// DMA **weight** LOAD bytes per lane (activation loads excluded) —
    /// the acceptance metric: on a warm step each lane streams only the
    /// shards that did not fit its cache, so this shrinks as lanes grow.
    pub weight_load_per_lane: Vec<u64>,
    /// All DMA LOAD bytes summed over lanes.
    pub load_bytes_total: u64,
    /// Residency-cache hits summed over lanes.
    pub hits: u64,
}

/// Replay `steps` identical mini U-Net denoising steps through a
/// [`crate::sd::backend::ShardedBackend`] over `lanes` lanes — the
/// single definition of the **shard-scaling experiment** shared by
/// `benches/shard_scaling.rs` and `tests/backend_equivalence.rs`. The
/// sharded prefetch/pin pass runs first whenever `cache_bytes > 0`.
pub fn replay_unet_steps_sharded(
    model: crate::sd::trace::QuantModel,
    lanes: usize,
    lmm_bytes: usize,
    cache_bytes: usize,
    steps: usize,
) -> Vec<ShardStepCost> {
    replay_unet_steps_sharded_threads(model, lanes, lmm_bytes, cache_bytes, steps, 2)
}

/// [`replay_unet_steps_sharded`] with an explicit `host_threads` knob:
/// `threads <= 1` keeps every shard inline on the coordinator thread,
/// `threads > 1` enables the lane worker pool. Simulated counters are
/// bit-identical either way (the determinism contract of
/// [`crate::coordinator::Coordinator::submit_sharded`]); only host
/// wall-clock differs, which is what `benches/shard_scaling.rs` measures.
pub fn replay_unet_steps_sharded_threads(
    model: crate::sd::trace::QuantModel,
    lanes: usize,
    lmm_bytes: usize,
    cache_bytes: usize,
    steps: usize,
    threads: usize,
) -> Vec<ShardStepCost> {
    replay_unet_steps_sharded_policy(
        model,
        lanes,
        lmm_bytes,
        cache_bytes,
        steps,
        threads,
        crate::coordinator::OffloadPolicy::QuantizedOnly,
    )
}

/// [`replay_unet_steps_sharded_threads`] with an explicit routing
/// policy: `QuantizedAndConv` row-tile-shards the F16 `ConvIm2col`
/// weights across lanes alongside the quantized set (the
/// `--conv-offload on` mode of `benches/shard_scaling.rs`). The
/// worker-pool determinism contract is policy-independent — simulated
/// counters are bit-identical at any `threads`.
#[allow(clippy::too_many_arguments)]
pub fn replay_unet_steps_sharded_policy(
    model: crate::sd::trace::QuantModel,
    lanes: usize,
    lmm_bytes: usize,
    cache_bytes: usize,
    steps: usize,
    threads: usize,
    policy: crate::coordinator::OffloadPolicy,
) -> Vec<ShardStepCost> {
    use crate::imax::ImaxConfig;
    use crate::sd::backend::ShardedBackend;

    let (unet, latent, ctx, plan) = unet_fixture(model);
    let mut imax = ImaxConfig::fpga(lanes);
    imax.lmm_bytes = lmm_bytes;
    imax.weight_cache_bytes = cache_bytes;
    let mut eng = ShardedBackend::from_config_policy(imax, threads, policy);

    (0..steps)
        .map(|_| {
            if cache_bytes > 0 {
                // Re-arm per step (see replay_unet_steps): one recorded
                // forward per replayed step keeps plan_divergences exact.
                eng.apply_plan(&plan);
            }
            let before = eng.coordinator().lane_costs();
            unet.forward(&mut eng, &latent, 999.0, &ctx);
            let after = eng.coordinator().lane_costs();
            let mut cost = ShardStepCost {
                max_lane_cycles: 0,
                total_cycles: 0,
                weight_load_per_lane: Vec::with_capacity(after.len()),
                load_bytes_total: 0,
                hits: 0,
            };
            for (b, a) in before.iter().zip(&after) {
                let cycles = a.cycles - b.cycles;
                cost.max_lane_cycles = cost.max_lane_cycles.max(cycles);
                cost.total_cycles += cycles;
                cost.weight_load_per_lane.push(a.weight_load_bytes - b.weight_load_bytes);
                cost.load_bytes_total += a.loaded_bytes - b.loaded_bytes;
                cost.hits += a.cache.hits - b.cache.hits;
            }
            cost
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ggml::DType;

    fn site(seq: usize, wid: Option<u64>, dtype: DType, bytes: usize) -> OpSite {
        OpSite {
            seq,
            kind: OpKind::Linear,
            wid: wid.map(WeightId),
            dtype,
            m: 4,
            k: 256,
            n: 2,
            weight_bytes: bytes,
        }
    }

    #[test]
    fn weight_uses_aggregates_and_ranks_by_streamed_bytes() {
        let plan = OpPlan {
            sites: vec![
                site(0, Some(1), DType::Q8_0, 100),
                site(1, Some(2), DType::Q8_0, 300),
                site(2, Some(1), DType::Q8_0, 100),
                site(3, Some(1), DType::Q8_0, 100),
                site(4, None, DType::F32, 0),
                site(5, Some(3), DType::F16, 999), // not offload-eligible
            ],
        };
        let uses = plan.weight_uses();
        assert_eq!(uses.len(), 2, "F32/F16 sites excluded");
        assert_eq!(uses[0].wid, WeightId(2), "300 streamed beats 3x100");
        assert_eq!(uses[0].rows, 4);
        assert_eq!(uses[1].uses, 3);
        assert_eq!(uses[1].streamed_bytes, 300);
        assert_eq!(plan.streamed_weight_bytes(), 600);
        assert_eq!(plan.offloaded_sites(), 4);
    }

    #[test]
    fn pin_set_greedy_within_budget() {
        let plan = OpPlan {
            sites: vec![
                site(0, Some(1), DType::Q3K, 200),
                site(1, Some(2), DType::Q3K, 150),
                site(2, Some(2), DType::Q3K, 150),
                site(3, Some(3), DType::Q3K, 60),
            ],
        };
        // Hotness: wid2 (300 streamed), wid1 (200), wid3 (60).
        assert_eq!(plan.pin_set(1000), vec![WeightId(2), WeightId(1), WeightId(3)]);
        // 200 B budget: wid2 fits (150), wid1 (200) does not, wid3 (60)
        // would exceed the 50 left — greedy still skips to nothing.
        assert_eq!(plan.pin_set(200), vec![WeightId(2)]);
        assert!(plan.pin_set(0).is_empty());
    }

    #[test]
    fn recorder_captures_typed_sites_and_returns_zeros() {
        let w = Tensor::f32(4, 32, vec![0.5; 128])
            .quantize(DType::Q8_0)
            .with_wid(WeightId(11));
        let x = Tensor::f32(3, 32, vec![0.25; 96]);
        let mut rec = PlanRecorder::new();
        let out = rec.submit_now(OpDesc::time_embed(&w, &x));
        assert_eq!((out.rows, out.cols), (3, 4));
        assert!(out.as_f32().iter().all(|&v| v == 0.0));
        let plan = rec.finish();
        assert_eq!(plan.sites.len(), 1);
        let s = &plan.sites[0];
        assert_eq!((s.m, s.k, s.n), (4, 32, 3));
        assert_eq!(s.kind, OpKind::TimeEmbed);
        assert_eq!(s.wid, Some(WeightId(11)));
        assert_eq!(s.dtype, DType::Q8_0);
        assert!(s.offload_eligible());
        assert_eq!(s.weight_bytes, 4 * 34);
    }

    #[test]
    fn lane_assignment_single_kind_round_robins() {
        let plan = OpPlan {
            sites: vec![
                site(0, Some(1), DType::Q8_0, 300),
                site(1, Some(2), DType::Q8_0, 200),
                site(2, Some(3), DType::Q8_0, 100),
            ],
        };
        let a = plan.lane_assignment(2);
        assert_eq!(
            a.iter().map(|(wu, l)| (wu.wid.0, *l)).collect::<Vec<_>>(),
            vec![(1, 0), (2, 1), (3, 0)],
            "hottest-first round-robin"
        );
    }

    #[test]
    fn lane_assignment_groups_kinds_onto_disjoint_lanes() {
        // Two kinds over four lanes: Q8_0 streams 3x the bytes of Q3_K,
        // so it gets 3 lanes and Q3_K gets 1 — and no lane ever sees
        // both kinds (zero CONF switches at steady state).
        let plan = OpPlan {
            sites: vec![
                site(0, Some(1), DType::Q8_0, 3000),
                site(1, Some(2), DType::Q8_0, 2000),
                site(2, Some(3), DType::Q8_0, 1000),
                site(3, Some(4), DType::Q3K, 1500),
                site(4, Some(5), DType::Q3K, 500),
            ],
        };
        let a = plan.lane_assignment(4);
        let mut lanes_by_dtype: std::collections::HashMap<&'static str, Vec<usize>> =
            std::collections::HashMap::new();
        for (wu, lane) in &a {
            lanes_by_dtype.entry(wu.dtype.name()).or_default().push(*lane);
        }
        let q8: std::collections::HashSet<_> = lanes_by_dtype["Q8_0"].iter().copied().collect();
        let q3: std::collections::HashSet<_> = lanes_by_dtype["Q3_K"].iter().copied().collect();
        assert!(q8.is_disjoint(&q3), "kinds must own disjoint lanes: {q8:?} vs {q3:?}");
        assert_eq!(q8.len() + q3.len(), 4, "all lanes used");
        assert!(q8.len() > q3.len(), "lane share follows streamed bytes");
    }

    #[test]
    fn lane_assignment_more_kinds_than_lanes_degenerates() {
        let plan = OpPlan {
            sites: vec![
                site(0, Some(1), DType::Q8_0, 300),
                site(1, Some(2), DType::Q3K, 200),
            ],
        };
        let a = plan.lane_assignment(1);
        assert_eq!(a.len(), 2);
        assert!(a.iter().all(|(_, l)| *l == 0));
    }
}
