//! Mini graph executor: the GGML-op substrate the runnable pipeline uses.
//!
//! Mirrors how `stable-diffusion.cpp` composes GGML ops: every mat-mul
//! is submitted as a typed [`OpDesc`] through an [`ExecBackend`] (host
//! kernels, the IMAX functional simulator, or the sharded multi-lane
//! coordinator — the offload seam the paper inserts, redesigned in
//! [`crate::sd::backend`]); everything else (norms, activations,
//! softmax, im2col, resampling) runs as host f32 ops here. The helpers
//! in this module are where ops acquire their kinds: [`conv2d`] submits
//! `ConvIm2col{k,stride}`, [`attention`] submits `AttnScores` /
//! `AttnValues`, and the model code submits `Linear` / `TimeEmbed`
//! directly.
//!
//! # Independent graph edges
//!
//! The graph's only declared-independent edges — ops submitted
//! before any of them is synced, so a parallel backend overlaps them —
//! are the Q/K/V projection triples in `sd/unet.rs` (self- and
//! cross-attention) and `sd/text.rs` (per encoder layer). Everything in
//! *this* module stays sequential on purpose: inside [`attention`] the
//! score → softmax → value chain is data-dependent (each op consumes the
//! previous op's output), and [`conv2d`]'s im2col GEMM is a single op.
//! The submission *order* of every op is part of the compiled-plan
//! contract (`tests` pin it), so overlap never reorders submissions —
//! only their completion waits.

use crate::ggml::Tensor;

// Narrow compatibility shim: the symbols this module's helpers consume
// plus the ones long-standing `sd::graph::X` callers import. Everything
// else lives canonically in [`crate::sd::backend`].
pub use super::backend::{ExecBackend, HostBackend, ImaxBackend, OpDesc, OpKind, RequestId};

/// A spatial feature map `[c, h, w]`, channel-major.
#[derive(Debug, Clone)]
pub struct Feat {
    /// Channels.
    pub c: usize,
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
    /// `c * h * w` values, channel-major.
    pub data: Vec<f32>,
}

impl Feat {
    /// Zero-filled feature map.
    pub fn zeros(c: usize, h: usize, w: usize) -> Feat {
        Feat { c, h, w, data: vec![0.0; c * h * w] }
    }

    /// From data (length-checked).
    pub fn new(c: usize, h: usize, w: usize, data: Vec<f32>) -> Feat {
        assert_eq!(data.len(), c * h * w);
        Feat { c, h, w, data }
    }

    /// Pixel count.
    pub fn hw(&self) -> usize {
        self.h * self.w
    }

    /// Channel slice.
    pub fn channel(&self, ch: usize) -> &[f32] {
        &self.data[ch * self.hw()..(ch + 1) * self.hw()]
    }

    /// Concatenate along channels.
    pub fn concat(&self, other: &Feat) -> Feat {
        assert_eq!((self.h, self.w), (other.h, other.w));
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Feat::new(self.c + other.c, self.h, self.w, data)
    }

    /// Elementwise add.
    pub fn add(&self, other: &Feat) -> Feat {
        assert_eq!(self.data.len(), other.data.len());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Feat { c: self.c, h: self.h, w: self.w, data }
    }

    /// Reinterpret as `[h*w, c]` token rows (for attention/linear ops).
    pub fn to_tokens(&self) -> Tensor {
        let hw = self.hw();
        let mut out = vec![0.0f32; hw * self.c];
        for ch in 0..self.c {
            let src = self.channel(ch);
            for p in 0..hw {
                out[p * self.c + ch] = src[p];
            }
        }
        Tensor::f32(hw, self.c, out)
    }

    /// Inverse of [`Feat::to_tokens`].
    pub fn from_tokens(t: &Tensor, h: usize, w: usize) -> Feat {
        let (hw, c) = (t.rows, t.cols);
        assert_eq!(hw, h * w);
        let src = t.as_f32();
        let mut data = vec![0.0f32; c * hw];
        for p in 0..hw {
            for ch in 0..c {
                data[ch * hw + p] = src[p * c + ch];
            }
        }
        Feat::new(c, h, w, data)
    }
}

// ---------------------------------------------------------------------------
// Host f32 ops (GGML non-mat-mul kernels)
// ---------------------------------------------------------------------------

/// SiLU activation in place.
pub fn silu(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = *v / (1.0 + (-*v).exp());
    }
}

/// GELU (tanh approximation, as GGML uses) in place.
pub fn gelu(x: &mut [f32]) {
    for v in x.iter_mut() {
        let c = 0.797_884_56_f32; // sqrt(2/pi)
        *v = 0.5 * *v * (1.0 + (c * (*v + 0.044715 * *v * *v * *v)).tanh());
    }
}

/// GroupNorm over a feature map (eps 1e-6).
pub fn group_norm(x: &Feat, groups: usize, gamma: &[f32], beta: &[f32]) -> Feat {
    assert_eq!(gamma.len(), x.c);
    assert_eq!(beta.len(), x.c);
    let groups = groups.min(x.c);
    assert_eq!(x.c % groups, 0, "channels divide groups");
    let cpg = x.c / groups;
    let hw = x.hw();
    let mut out = x.clone();
    for g in 0..groups {
        let span = g * cpg * hw..(g + 1) * cpg * hw;
        let slice = &x.data[span.clone()];
        let n = slice.len() as f32;
        let mean = slice.iter().sum::<f32>() / n;
        let var = slice.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / n;
        let inv = 1.0 / (var + 1e-6).sqrt();
        for (i, v) in out.data[span].iter_mut().enumerate() {
            let ch = g * cpg + i / hw;
            *v = (*v - mean) * inv * gamma[ch] + beta[ch];
        }
    }
    out
}

/// LayerNorm over the last dim of `[rows, cols]` tokens.
pub fn layer_norm(x: &Tensor, gamma: &[f32], beta: &[f32]) -> Tensor {
    assert_eq!(gamma.len(), x.cols);
    let mut out = vec![0.0f32; x.len()];
    for r in 0..x.rows {
        let row = x.row_f32(r);
        let mean = row.iter().sum::<f32>() / x.cols as f32;
        let var = row.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for c in 0..x.cols {
            out[r * x.cols + c] = (row[c] - mean) * inv * gamma[c] + beta[c];
        }
    }
    Tensor::f32(x.rows, x.cols, out)
}

/// Row-wise softmax in place over `[rows, cols]`.
pub fn softmax_rows(x: &mut Tensor) {
    let cols = x.cols;
    let data = match &mut x.data {
        crate::ggml::tensor::Storage::F32(v) => v,
        _ => panic!("softmax expects f32"),
    };
    for row in data.chunks_mut(cols) {
        let max = row.iter().fold(f32::MIN, |m, &v| m.max(v));
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// im2col for a `k×k` conv with stride `s` and `same`-style padding
/// `k/2`: returns `[out_h*out_w, cin*k*k]` rows ready for the conv GEMM.
pub fn im2col(x: &Feat, k: usize, stride: usize) -> Tensor {
    let pad = k / 2;
    let oh = (x.h + 2 * pad - k) / stride + 1;
    let ow = (x.w + 2 * pad - k) / stride + 1;
    let cols = x.c * k * k;
    let mut out = vec![0.0f32; oh * ow * cols];
    for oy in 0..oh {
        for ox in 0..ow {
            let row = oy * ow + ox;
            for c in 0..x.c {
                let chan = x.channel(c);
                for ky in 0..k {
                    for kx in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        let v = if iy >= 0 && iy < x.h as isize && ix >= 0 && ix < x.w as isize {
                            chan[iy as usize * x.w + ix as usize]
                        } else {
                            0.0
                        };
                        out[row * cols + c * k * k + ky * k + kx] = v;
                    }
                }
            }
        }
    }
    Tensor::f32(oh * ow, cols, out)
}

/// Conv2d via im2col + a typed `ConvIm2col` submission. `w` is
/// `[cout, cin·k·k]`.
pub fn conv2d(
    eng: &mut dyn ExecBackend,
    w: &Tensor,
    bias: &[f32],
    x: &Feat,
    k: usize,
    stride: usize,
) -> Feat {
    let pad = k / 2;
    let oh = (x.h + 2 * pad - k) / stride + 1;
    let ow = (x.w + 2 * pad - k) / stride + 1;
    assert_eq!(w.cols, x.c * k * k, "conv weight shape");
    assert_eq!(bias.len(), w.rows);
    let cols = im2col(x, k, stride);
    let out_tok = eng.submit_now(OpDesc::conv_im2col(w, &cols, k, stride)); // [oh*ow, cout]
    let mut f = Feat::from_tokens(&out_tok, oh, ow);
    let hw = f.hw();
    for c in 0..f.c {
        for p in 0..hw {
            f.data[c * hw + p] += bias[c];
        }
    }
    f
}

/// Nearest-neighbour 2× upsample.
pub fn upsample2x(x: &Feat) -> Feat {
    let (h2, w2) = (x.h * 2, x.w * 2);
    let mut out = Feat::zeros(x.c, h2, w2);
    for c in 0..x.c {
        let src = x.channel(c);
        for y in 0..h2 {
            for xx in 0..w2 {
                out.data[c * h2 * w2 + y * w2 + xx] = src[(y / 2) * x.w + xx / 2];
            }
        }
    }
    out
}

/// Multi-head attention over token tensors: `q:[n,d] k:[m,d] v:[m,d]`,
/// the score and value mat-muls submitted as typed `AttnScores` /
/// `AttnValues` ops (F32 per-request operands, like sd.cpp).
pub fn attention(
    eng: &mut dyn ExecBackend,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    heads: usize,
) -> Tensor {
    assert_eq!(q.cols, k.cols);
    assert_eq!(k.rows, v.rows);
    let d = q.cols / heads;
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; q.rows * q.cols];
    for h in 0..heads {
        // Slice head h: [n, d] / [m, d].
        let take = |t: &Tensor| {
            let mut s = vec![0.0f32; t.rows * d];
            for r in 0..t.rows {
                s[r * d..(r + 1) * d].copy_from_slice(&t.row_f32(r)[h * d..(h + 1) * d]);
            }
            Tensor::f32(t.rows, d, s)
        };
        let (qh, kh, vh) = (take(q), take(k), take(v));
        // scores[n, m] = q · kᵀ (weight operand = kh gives [n, m]).
        let mut scores = eng.submit_now(OpDesc::attn_scores(&kh, &qh));
        {
            let sdata = match &mut scores.data {
                crate::ggml::tensor::Storage::F32(vv) => vv,
                _ => unreachable!(),
            };
            for s in sdata.iter_mut() {
                *s *= scale;
            }
        }
        softmax_rows(&mut scores);
        // ctx[n, d] = scores · v — build vᵀ [d, m] rows for the mat-mul.
        let mut vt = vec![0.0f32; d * v.rows];
        for r in 0..v.rows {
            for c in 0..d {
                vt[c * v.rows + r] = vh.as_f32()[r * d + c];
            }
        }
        let vt = Tensor::f32(d, v.rows, vt);
        let ctx = eng.submit_now(OpDesc::attn_values(&vt, &scores)); // [n, d]
        for r in 0..q.rows {
            out[r * q.cols + h * d..r * q.cols + (h + 1) * d]
                .copy_from_slice(ctx.row_f32(r));
        }
    }
    Tensor::f32(q.rows, q.cols, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn rnd_feat(c: usize, h: usize, w: usize, seed: u64) -> Feat {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let mut d = vec![0.0f32; c * h * w];
        r.fill_normal(&mut d, 1.0);
        Feat::new(c, h, w, d)
    }

    #[test]
    fn tokens_round_trip() {
        let f = rnd_feat(3, 4, 5, 1);
        let t = f.to_tokens();
        assert_eq!((t.rows, t.cols), (20, 3));
        let back = Feat::from_tokens(&t, 4, 5);
        assert_eq!(back.data, f.data);
    }

    #[test]
    fn silu_and_gelu_known_points() {
        let mut v = [0.0f32, 1.0, -1.0];
        silu(&mut v);
        assert!((v[0]).abs() < 1e-7);
        assert!((v[1] - 0.731058).abs() < 1e-5);
        let mut g = [0.0f32, 1.0];
        gelu(&mut g);
        assert!(g[0].abs() < 1e-7);
        assert!((g[1] - 0.841192).abs() < 1e-4);
    }

    #[test]
    fn group_norm_zero_mean_unit_var() {
        let f = rnd_feat(8, 4, 4, 2);
        let gamma = vec![1.0; 8];
        let beta = vec![0.0; 8];
        let out = group_norm(&f, 4, &gamma, &beta);
        let hw = 16;
        for g in 0..4 {
            let s = &out.data[g * 2 * hw..(g + 1) * 2 * hw];
            let mean: f32 = s.iter().sum::<f32>() / s.len() as f32;
            let var: f32 = s.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / s.len() as f32;
            assert!(mean.abs() < 1e-4, "group {g} mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "group {g} var {var}");
        }
    }

    #[test]
    fn layer_norm_rows_normalized() {
        let f = rnd_feat(1, 4, 8, 3);
        let t = Tensor::f32(4, 8, f.data.clone());
        let out = layer_norm(&t, &vec![1.0; 8], &vec![0.0; 8]);
        for r in 0..out.rows {
            let row = out.row_f32(r);
            let mean: f32 = row.iter().sum::<f32>() / row.len() as f32;
            let var: f32 = row.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / row.len() as f32;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let f = rnd_feat(1, 3, 5, 4);
        let mut t = Tensor::f32(3, 5, f.data.clone());
        softmax_rows(&mut t);
        for r in 0..3 {
            let s: f32 = t.row_f32(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(t.row_f32(r).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1×1 conv im2col is just the token matrix.
        let f = rnd_feat(2, 3, 3, 5);
        let cols = im2col(&f, 1, 1);
        let toks = f.to_tokens();
        assert_eq!(cols.as_f32(), toks.as_f32());
    }

    #[test]
    fn conv2d_matches_direct_convolution() {
        let f = rnd_feat(2, 5, 5, 6);
        let mut r = Xoshiro256pp::seed_from_u64(7);
        let mut wdata = vec![0.0f32; 3 * 2 * 9];
        r.fill_normal(&mut wdata, 0.5);
        let w = Tensor::f32(3, 18, wdata.clone());
        let bias = vec![0.1f32, -0.2, 0.3];
        let mut eng = HostBackend::new(1);
        let out = conv2d(&mut eng, &w, &bias, &f, 3, 1);
        assert_eq!((out.c, out.h, out.w), (3, 5, 5));
        // Direct computation at interior pixel (2,2), channel 1.
        let mut want = bias[1];
        for c in 0..2 {
            for ky in 0..3 {
                for kx in 0..3 {
                    let iv = f.channel(c)[(2 + ky - 1) * 5 + (2 + kx - 1)];
                    want += wdata[18 + c * 9 + ky * 3 + kx] * iv;
                }
            }
        }
        let got = out.channel(1)[2 * 5 + 2];
        assert!((got - want).abs() < 1e-4, "{got} vs {want}");
    }

    #[test]
    fn strided_conv_halves_resolution() {
        let f = rnd_feat(2, 8, 8, 8);
        let w = Tensor::f32(2, 18, vec![0.1; 36]);
        let mut eng = HostBackend::new(1);
        let out = conv2d(&mut eng, &w, &[0.0, 0.0], &f, 3, 2);
        assert_eq!((out.h, out.w), (4, 4));
    }

    #[test]
    fn upsample_doubles() {
        let f = rnd_feat(1, 2, 2, 9);
        let up = upsample2x(&f);
        assert_eq!((up.h, up.w), (4, 4));
        assert_eq!(up.data[0], f.data[0]);
        assert_eq!(up.data[1], f.data[0]);
        assert_eq!(up.data[4], f.data[0]);
    }

    #[test]
    fn attention_uniform_scores_average_values() {
        // q ⟂ k (zeros) -> uniform softmax -> output = mean of V rows.
        let q = Tensor::zeros(2, 4);
        let k = Tensor::zeros(3, 4);
        let v = Tensor::f32(3, 4, (0..12).map(|i| i as f32).collect());
        let mut eng = HostBackend::new(1);
        let out = attention(&mut eng, &q, &k, &v, 2);
        let mean0 = (0.0 + 4.0 + 8.0) / 3.0;
        assert!((out.as_f32()[0] - mean0).abs() < 1e-5);
    }

    #[test]
    fn conv_and_attention_declare_their_kinds() {
        use crate::sd::plan::PlanRecorder;
        let f = rnd_feat(2, 4, 4, 10);
        let w = Tensor::f32(3, 18, vec![0.1; 54]);
        let mut rec = PlanRecorder::new();
        conv2d(&mut rec, &w, &[0.0; 3], &f, 3, 1);
        let q = Tensor::zeros(2, 4);
        let k = Tensor::zeros(3, 4);
        let v = Tensor::f32(3, 4, vec![0.5; 12]);
        attention(&mut rec, &q, &k, &v, 2);
        let plan = rec.finish();
        assert_eq!(plan.sites[0].kind, OpKind::ConvIm2col { k: 3, stride: 1 });
        assert_eq!(plan.sites[1].kind, OpKind::AttnScores);
        assert_eq!(plan.sites[2].kind, OpKind::AttnValues);
        // Two heads: scores/values alternate per head.
        assert_eq!(plan.sites[3].kind, OpKind::AttnScores);
        assert_eq!(plan.sites[4].kind, OpKind::AttnValues);
    }

    #[test]
    fn conv2d_bit_exact_across_host_and_imax_q8_0() {
        // Quantized conv weights are not produced by the factory (convs
        // stay F16), but the seam itself must be dtype-agnostic: a Q8_0
        // weight through conv2d agrees bit-exactly host vs IMAX.
        let f = rnd_feat(2, 4, 4, 11);
        let mut r = Xoshiro256pp::seed_from_u64(12);
        let mut wdata = vec![0.0f32; 4 * 2 * 16]; // [4, 32] => K=32 quantizable
        r.fill_normal(&mut wdata, 0.5);
        let w = Tensor::f32(4, 32, wdata).quantize(crate::ggml::DType::Q8_0);
        let mut host = HostBackend::new(1);
        let a = conv2d(&mut host, &w, &[0.0; 4], &f, 4, 2);
        let mut imax = ImaxBackend::new(crate::imax::ImaxConfig::fpga(1), 1);
        let b = conv2d(&mut imax, &w, &[0.0; 4], &f, 4, 2);
        assert!(imax.stats().offloaded_calls > 0);
        for (p, q) in a.data.iter().zip(&b.data) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
    }
}
