//! L3 coordinator: routing mat-mul jobs between the host CPU pool and
//! IMAX lanes.
//!
//! This is the paper's system layer made concrete: the host (a 2-core
//! A72 in the prototype) owns data supply and execution control for up
//! to 8 independent lanes (§II-B); quantized dot-products route to
//! lanes, everything else stays on the host. The scheduler demonstrates
//! the host-bottleneck behaviour the paper analyzes in §V-A — with more
//! lanes than host service capacity, lanes starve.

pub mod metrics;
pub mod offload;
pub mod scheduler;
pub mod shard;

pub use metrics::{CoordinatorMetrics, MetricsSnapshot};
pub use offload::OffloadPolicy;
pub use scheduler::{Coordinator, MatMulJob, PendingSharded, ShapeKey, ShardedRun};
pub use shard::{shard_wid, RowShard, ShardPlan};
