//! Multi-lane job scheduler.
//!
//! Jobs arrive in submission order; quantized mat-muls round-robin over
//! the configured IMAX lanes (each lane owned by one worker thread),
//! host jobs run on a bounded host pool sized like the A72 (2 cores).
//! Because the host workers also perform the marshalling (activation
//! quantization) for lane jobs, configuring more lanes than
//! `host_threads` ceases to help — the §V-A saturation, observable in
//! this scheduler's metrics.

use super::metrics::CoordinatorMetrics;
use super::offload::OffloadPolicy;
use crate::ggml::{self, q8_0, q8_k, DType, Tensor};
#[cfg(test)]
use crate::ggml::q3_k;
use crate::imax::lane::LaneSim;
use crate::imax::ImaxConfig;
use std::sync::{Arc, Mutex};

/// One mat-mul job: quantized weights × f32 activations.
#[derive(Debug, Clone)]
pub struct MatMulJob {
    /// Job label (layer name).
    pub name: String,
    /// Weight tensor.
    pub w: Arc<Tensor>,
    /// Activation tensor `[n, k]` f32.
    pub x: Arc<Tensor>,
}

impl MatMulJob {
    /// MAC count.
    pub fn macs(&self) -> u64 {
        (self.w.rows * self.w.cols * self.x.rows) as u64
    }
}

/// The coordinator: lanes + host pool + policy + metrics.
pub struct Coordinator {
    lanes: Vec<Mutex<LaneSim>>,
    /// Host worker threads (the A72 pair in the paper's setup).
    pub host_threads: usize,
    /// Routing policy.
    pub policy: OffloadPolicy,
    /// Shared counters.
    pub metrics: Arc<CoordinatorMetrics>,
    next_lane: std::sync::atomic::AtomicUsize,
}

impl Coordinator {
    /// Build with `lanes` IMAX lanes and a host pool.
    pub fn new(imax: ImaxConfig, lanes: usize, host_threads: usize, policy: OffloadPolicy) -> Coordinator {
        Coordinator {
            lanes: (0..lanes).map(|_| Mutex::new(LaneSim::new(imax.clone()))).collect(),
            host_threads,
            policy,
            metrics: Arc::new(CoordinatorMetrics::default()),
            next_lane: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    /// Number of lanes.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Execute one job synchronously, routing by policy. Returns the
    /// `[n, m]` f32 output.
    pub fn execute(&self, job: &MatMulJob) -> Tensor {
        if self.policy.offloads(&job.w) && !self.lanes.is_empty() {
            self.execute_on_lane(job)
        } else {
            self.metrics.record_host(job.macs());
            ggml::mul_mat(&job.w, &job.x, self.host_threads)
        }
    }

    /// Execute a batch of jobs, lane jobs in parallel across lanes and
    /// host threads (scoped). Results in submission order.
    pub fn execute_batch(&self, jobs: &[MatMulJob]) -> Vec<Tensor> {
        let mut out: Vec<Option<Tensor>> = (0..jobs.len()).map(|_| None).collect();
        let slots: Vec<Mutex<&mut Option<Tensor>>> =
            out.iter_mut().map(Mutex::new).collect();
        // Worker per host thread pulling from a shared index.
        let next = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..self.host_threads.max(1) {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    let r = self.execute(&jobs[i]);
                    **slots[i].lock().unwrap() = Some(r);
                });
            }
        });
        out.into_iter().map(|t| t.expect("all jobs completed")).collect()
    }

    fn execute_on_lane(&self, job: &MatMulJob) -> Tensor {
        let idx = self.next_lane.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
            % self.lanes.len();
        let (m, n, k) = (job.w.rows, job.x.rows, job.w.cols);
        // Host-side marshalling happens on the calling (host) thread.
        let result = match &job.w.data {
            crate::ggml::tensor::Storage::Q8_0(blocks) => {
                let acts: Vec<_> = (0..n)
                    .flat_map(|r| q8_0::quantize_row(job.x.row_f32(r)))
                    .collect();
                let mut lane = self.lanes[idx].lock().unwrap();
                let (data, bd) = lane
                    .mul_mat_q8_0(blocks, m, &acts, n, k)
                    .expect("job shapes fit LMM");
                self.metrics.record_offload(job.macs(), bd.total());
                Tensor::f32(n, m, data)
            }
            crate::ggml::tensor::Storage::Q3K(blocks) => {
                let acts: Vec<_> = (0..n)
                    .flat_map(|r| q8_k::quantize_row(job.x.row_f32(r)))
                    .collect();
                let mut lane = self.lanes[idx].lock().unwrap();
                let (data, bd) = lane
                    .mul_mat_q3_k(blocks, m, &acts, n, k)
                    .expect("job shapes fit LMM");
                self.metrics.record_offload(job.macs(), bd.total());
                Tensor::f32(n, m, data)
            }
            _ => unreachable!("policy only offloads quantized weights"),
        };
        result
    }
}

/// Helper: build a quantized job from f32 weights.
pub fn make_job(name: &str, w_f32: Tensor, dtype: DType, x: Tensor) -> MatMulJob {
    let w = match dtype {
        DType::F32 => w_f32,
        _ => w_f32.quantize(dtype),
    };
    MatMulJob { name: name.to_string(), w: Arc::new(w), x: Arc::new(x) }
}

// Re-exports used in tests and examples.
pub use crate::ggml::tensor::Storage;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn rnd(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let mut v = vec![0.0f32; rows * cols];
        r.fill_normal(&mut v, 0.5);
        Tensor::f32(rows, cols, v)
    }

    fn coordinator(lanes: usize) -> Coordinator {
        Coordinator::new(ImaxConfig::fpga(1), lanes, 2, OffloadPolicy::QuantizedOnly)
    }

    #[test]
    fn routes_by_policy_and_counts() {
        let c = coordinator(2);
        let jq = make_job("q", rnd(4, 64, 1), DType::Q8_0, rnd(3, 64, 2));
        let jf = make_job("f", rnd(4, 64, 3), DType::F16, rnd(3, 64, 4));
        c.execute(&jq);
        c.execute(&jf);
        assert_eq!(c.metrics.offloaded_jobs.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(c.metrics.host_jobs.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert!(c.metrics.offload_ratio() > 0.0);
    }

    #[test]
    fn coordinator_matches_direct_ggml_q8_0() {
        let c = coordinator(3);
        let w = rnd(6, 128, 5);
        let x = rnd(4, 128, 6);
        let job = make_job("m", w.clone(), DType::Q8_0, x.clone());
        let got = c.execute(&job);
        let want = ggml::mul_mat(&w.quantize(DType::Q8_0), &x, 1);
        for (a, b) in got.as_f32().iter().zip(want.as_f32()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn batch_preserves_order_and_uses_all_lanes() {
        let c = coordinator(4);
        let jobs: Vec<_> = (0..12)
            .map(|i| make_job(&format!("j{i}"), rnd(2, 64, 10 + i), DType::Q8_0, rnd(2, 64, 50 + i)))
            .collect();
        let outs = c.execute_batch(&jobs);
        assert_eq!(outs.len(), 12);
        // Verify each against direct computation (order preserved).
        for (job, out) in jobs.iter().zip(&outs) {
            let want = ggml::mul_mat(&job.w, &job.x, 1);
            assert_eq!(out.as_f32(), want.as_f32());
        }
        assert_eq!(
            c.metrics.offloaded_jobs.load(std::sync::atomic::Ordering::Relaxed),
            12
        );
    }

    #[test]
    fn host_only_policy_never_offloads() {
        let c = Coordinator::new(ImaxConfig::fpga(1), 2, 2, OffloadPolicy::HostOnly);
        let job = make_job("q", rnd(2, 64, 7), DType::Q8_0, rnd(2, 64, 8));
        c.execute(&job);
        assert_eq!(c.metrics.offloaded_jobs.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn q3k_jobs_route_and_compute() {
        let c = coordinator(1);
        let w = rnd(3, 256, 9);
        let x = rnd(2, 256, 10);
        let job = make_job("q3", w.clone(), DType::Q3K, x.clone());
        let got = c.execute(&job);
        // Lane computes the imax5 (5-bit scale) variant.
        let wq = w.quantize(DType::Q3K);
        let blocks = match &wq.data {
            Storage::Q3K(b) => b.clone(),
            _ => unreachable!(),
        };
        let acts: Vec<_> = (0..2).flat_map(|r| q8_k::quantize_row(x.row_f32(r))).collect();
        for a_row in 0..2 {
            for w_row in 0..3 {
                let want = q3_k::vec_dot_imax5(&blocks[w_row..w_row + 1], &acts[a_row..a_row + 1]);
                assert_eq!(got.as_f32()[a_row * 3 + w_row].to_bits(), want.to_bits());
            }
        }
    }
}
